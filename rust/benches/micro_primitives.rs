//! Microbenchmarks of every hot primitive — the §Perf foundation:
//! field ops, Lagrange weighted sums (encode/decode), Shamir sharing, MPC
//! degree reduction, TruncPr, and the encoded-gradient kernel — including
//! the **sequential-vs-parallel** comparison of the `field::par` execution
//! layer (weighted_sum / matvec / matvec_t / fused kernel at 1–8 threads).
//!
//! Results are also dumped to `BENCH_micro_primitives.json` so successive
//! commits accumulate a perf trajectory (see EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench micro_primitives`

use copml::bench::{harness::humanize, time_it, BenchStats};
use copml::field::{par, vecops, Field, KernelTier, MatShape, MontField, Parallelism};
use copml::lcc::Encoder;
use copml::prng::Rng;
use copml::report::Json;
use copml::runtime::{native::NativeKernel, GradKernel};
use copml::shamir;

/// Accumulate one stats row for the JSON dump.
fn record(rows: &mut Vec<Json>, stats: &BenchStats, threads: usize) {
    rows.push(Json::obj(vec![
        ("name", Json::str(&stats.name)),
        ("threads", Json::num(threads as f64)),
        ("median_s", Json::num(stats.median_s)),
        ("min_s", Json::num(stats.min_s)),
        ("mad_s", Json::num(stats.mad_s)),
        ("iters", Json::num(stats.iters as f64)),
    ]));
}

fn main() {
    let f = Field::paper_cifar();
    let p = f.modulus();
    let mut rng = Rng::seed_from_u64(0xBE7C);
    let mut json_rows: Vec<Json> = Vec::new();
    println!("{:<44} {:>12} {:>12} {:>12}", "benchmark", "median", "min", "mad");

    // --- field reduce/mul throughput -------------------------------------
    let xs: Vec<u64> = (0..1 << 20).map(|_| rng.next_u64()).collect();
    let stats = time_it("field/reduce 1M u64", 2, 9, || {
        let mut acc = 0u64;
        for &x in &xs {
            acc = acc.wrapping_add(f.reduce(x));
        }
        std::hint::black_box(acc);
    });
    println!("{}  [{:.0} M red/s]", stats.report(), 1e-6 * xs.len() as f64 / stats.median_s);
    record(&mut json_rows, &stats, 1);

    // --- dot (the paper's mod-after-inner-product trick) ------------------
    let a: Vec<u64> = (0..3072).map(|_| rng.gen_range(p)).collect();
    let b: Vec<u64> = (0..3072).map(|_| rng.gen_range(p)).collect();
    let stats = time_it("field/dot d=3072 (CIFAR row)", 5, 15, || {
        std::hint::black_box(vecops::dot(f, &a, &b));
    });
    println!("{}", stats.report());
    record(&mut json_rows, &stats, 1);

    // --- weighted_sum: Lagrange encode unit -------------------------------
    for (terms, len) in [(17usize, 1 << 16), (33, 1 << 16)] {
        let mats: Vec<Vec<u64>> = (0..terms)
            .map(|_| (0..len).map(|_| rng.gen_range(p)).collect())
            .collect();
        let coeffs: Vec<u64> = (0..terms).map(|_| rng.gen_range(p)).collect();
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; len];
        let stats = time_it(&format!("lcc/weighted_sum K+T={terms} 64k els"), 2, 9, || {
            vecops::weighted_sum(f, &coeffs, &views, &mut out);
            std::hint::black_box(&out);
        });
        println!(
            "{}  [{:.0} M muladd/s]",
            stats.report(),
            1e-6 * (terms * len) as f64 / stats.median_s
        );
        record(&mut json_rows, &stats, 1);
    }

    // --- sequential vs parallel weighted_sum (field::par) -----------------
    // Large shape (K+T = 17 Lagrange terms × 1M elements) — the regime the
    // per-client encode of a CIFAR-sized block lives in.
    {
        let (terms, len) = (17usize, 1 << 20);
        let mats: Vec<Vec<u64>> = (0..terms)
            .map(|_| (0..len).map(|_| rng.gen_range(p)).collect())
            .collect();
        let coeffs: Vec<u64> = (0..terms).map(|_| rng.gen_range(p)).collect();
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; len];
        let mut seq_median = 0.0f64;
        for threads in [1usize, 2, 4, 8] {
            let pp = Parallelism::threads(threads);
            let stats =
                time_it(&format!("par/weighted_sum 17x1M t={threads}"), 1, 7, || {
                    par::weighted_sum(f, pp, &coeffs, &views, &mut out);
                    std::hint::black_box(&out);
                });
            if threads == 1 {
                seq_median = stats.median_s;
                println!("{}", stats.report());
            } else {
                println!("{}  [{:.2}x vs seq]", stats.report(), seq_median / stats.median_s);
            }
            record(&mut json_rows, &stats, threads);
        }
    }

    // --- end-to-end LCC encode at CIFAR Case-1 block shape ---------------
    {
        let (k, t, n) = (16usize, 1usize, 50usize);
        let rows_k = 9024 / k;
        let len = rows_k * 3073;
        let enc = Encoder::standard(f, k, t, n);
        let parts: Vec<Vec<u64>> = (0..k + t)
            .map(|_| (0..len).map(|_| rng.gen_range(p)).collect())
            .collect();
        let views: Vec<&[u64]> = parts.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; len];
        for threads in [1usize, 4] {
            let pp = Parallelism::threads(threads);
            let stats = time_it(
                &format!("lcc/encode one client, CIFAR Case 1, t={threads}"),
                1,
                5,
                || {
                    enc.encode_one_par(pp, 7, &views, &mut out);
                    std::hint::black_box(&out);
                },
            );
            println!("{}", stats.report());
            record(&mut json_rows, &stats, threads);
        }
    }

    // --- Shamir sharing ----------------------------------------------------
    let secret: Vec<u64> = (0..1 << 16).map(|_| rng.gen_range(p)).collect();
    for (n, t) in [(10usize, 1usize), (50, 7)] {
        let stats = time_it(&format!("shamir/share 64k els N={n} T={t}"), 1, 5, || {
            let mut r2 = Rng::seed_from_u64(1);
            std::hint::black_box(shamir::share(f, &secret, n, t, &mut r2));
        });
        println!("{}", stats.report());
        record(&mut json_rows, &stats, 1);
    }

    // --- encoded-gradient kernel: sequential vs parallel at paper shapes --
    let shapes = [(564usize, 3073usize), (1024, 3073), (2048, 3073), (1200, 5000)];
    for (rows, cols) in shapes {
        let ff = if cols > 4096 { Field::paper_gisette() } else { f };
        let pp_mod = ff.modulus();
        let x: Vec<u64> = (0..rows * cols).map(|_| rng.gen_range(pp_mod)).collect();
        let w: Vec<u64> = (0..cols).map(|_| rng.gen_range(pp_mod)).collect();
        let cq = vec![rng.gen_range(pp_mod), rng.gen_range(pp_mod)];
        let shape = MatShape::new(rows, cols);
        let mut seq_median = 0.0f64;
        for threads in [1usize, 2, 4] {
            let kernel = NativeKernel::with_parallelism(ff, Parallelism::threads(threads));
            let stats = time_it(&format!("kernel/native {rows}x{cols} t={threads}"), 1, 5, || {
                std::hint::black_box(kernel.encoded_gradient(&x, shape, &w, &cq));
            });
            if threads == 1 {
                seq_median = stats.median_s;
                println!(
                    "{}  [{:.0} M cells/s]",
                    stats.report(),
                    1e-6 * (rows * cols) as f64 / stats.median_s
                );
            } else {
                println!(
                    "{}  [{:.0} M cells/s, {:.2}x vs seq]",
                    stats.report(),
                    1e-6 * (rows * cols) as f64 / stats.median_s,
                    seq_median / stats.median_s
                );
            }
            record(&mut json_rows, &stats, threads);
        }
    }

    // --- sequential vs parallel matvec / matvec_t at the full CIFAR shape --
    {
        let (rows, cols) = (2048usize, 3073usize);
        let x: Vec<u64> = (0..rows * cols).map(|_| rng.gen_range(p)).collect();
        let w: Vec<u64> = (0..cols).map(|_| rng.gen_range(p)).collect();
        let v: Vec<u64> = (0..rows).map(|_| rng.gen_range(p)).collect();
        let shape = MatShape::new(rows, cols);
        let mut seq_mv = 0.0f64;
        let mut seq_mvt = 0.0f64;
        for threads in [1usize, 2, 4, 8] {
            let pp = Parallelism::threads(threads);
            let stats = time_it(&format!("par/matvec {rows}x{cols} t={threads}"), 1, 7, || {
                std::hint::black_box(par::matvec(f, pp, &x, shape, &w));
            });
            if threads == 1 {
                seq_mv = stats.median_s;
                println!("{}", stats.report());
            } else {
                println!("{}  [{:.2}x vs seq]", stats.report(), seq_mv / stats.median_s);
            }
            record(&mut json_rows, &stats, threads);

            let stats = time_it(&format!("par/matvec_t {rows}x{cols} t={threads}"), 1, 7, || {
                std::hint::black_box(par::matvec_t(f, pp, &x, shape, &v));
            });
            if threads == 1 {
                seq_mvt = stats.median_s;
                println!("{}", stats.report());
            } else {
                println!("{}  [{:.2}x vs seq]", stats.report(), seq_mvt / stats.median_s);
            }
            record(&mut json_rows, &stats, threads);
        }
    }

    // --- kernel-tier ablation: Barrett vs batch-Montgomery ---------------
    // Sequential apples-to-apples at paper shapes, with bit-equality
    // asserted in the loop (the tiers must differ in cost, never in
    // value). Ratios land in BENCH_kernels.json (see EXPERIMENTS.md
    // §Kernel tiers).
    {
        let mut tier_rows: Vec<Json> = Vec::new();
        let pp1 = Parallelism::sequential();

        for (rows, cols) in [(2048usize, 3073usize), (1200, 5000)] {
            let ff = if cols > 4096 { Field::paper_gisette() } else { f };
            let pm = ff.modulus();
            let mf = MontField::new(ff);
            let x: Vec<u64> = (0..rows * cols).map(|_| rng.gen_range(pm)).collect();
            let w: Vec<u64> = (0..cols).map(|_| rng.gen_range(pm)).collect();
            let cq = vec![rng.gen_range(pm), rng.gen_range(pm)];
            let shape = MatShape::new(rows, cols);

            // matvec: Barrett oracle vs premont (conversion of w included
            // in the timed region — that is the amortization claim).
            assert_eq!(
                mf.matvec(&x, shape, &w),
                vecops::matvec(ff, &x, shape, &w),
                "kernel-tier matvec value drift at {rows}x{cols}"
            );
            let sb = time_it(&format!("kernel-tier/matvec barrett {rows}x{cols}"), 1, 7, || {
                std::hint::black_box(vecops::matvec(ff, &x, shape, &w));
            });
            println!("{}", sb.report());
            let sm = time_it(&format!("kernel-tier/matvec mont {rows}x{cols}"), 1, 7, || {
                std::hint::black_box(mf.matvec(&x, shape, &w));
            });
            println!("{}  [{:.2}x vs barrett]", sm.report(), sb.median_s / sm.median_s);
            tier_rows.push(Json::obj(vec![
                ("kernel", Json::str(&format!("matvec {rows}x{cols}"))),
                ("p", Json::num(pm as f64)),
                ("barrett_median_s", Json::num(sb.median_s)),
                ("mont_median_s", Json::num(sm.median_s)),
                ("speedup", Json::num(sb.median_s / sm.median_s)),
            ]));

            // Fused encoded-gradient kernel through NativeKernel's tier
            // switch — the protocol's per-iteration hot path.
            let kb = NativeKernel::with_tier(ff, pp1, KernelTier::Barrett);
            let km = NativeKernel::with_tier(ff, pp1, KernelTier::Mont);
            assert_eq!(
                km.encoded_gradient(&x, shape, &w, &cq),
                kb.encoded_gradient(&x, shape, &w, &cq),
                "kernel-tier fused value drift at {rows}x{cols}"
            );
            let sb = time_it(&format!("kernel-tier/fused barrett {rows}x{cols}"), 1, 5, || {
                std::hint::black_box(kb.encoded_gradient(&x, shape, &w, &cq));
            });
            println!("{}", sb.report());
            let sm = time_it(&format!("kernel-tier/fused mont {rows}x{cols}"), 1, 5, || {
                std::hint::black_box(km.encoded_gradient(&x, shape, &w, &cq));
            });
            println!("{}  [{:.2}x vs barrett]", sm.report(), sb.median_s / sm.median_s);
            tier_rows.push(Json::obj(vec![
                ("kernel", Json::str(&format!("fused {rows}x{cols}"))),
                ("p", Json::num(pm as f64)),
                ("barrett_median_s", Json::num(sb.median_s)),
                ("mont_median_s", Json::num(sm.median_s)),
                ("speedup", Json::num(sb.median_s / sm.median_s)),
            ]));
        }

        // weighted_sum (the LCC encode/decode unit): K+T = 17 × 64k els.
        {
            let (terms, len) = (17usize, 1 << 16);
            let mf = MontField::new(f);
            let mats: Vec<Vec<u64>> = (0..terms)
                .map(|_| (0..len).map(|_| rng.gen_range(p)).collect())
                .collect();
            let coeffs: Vec<u64> = (0..terms).map(|_| rng.gen_range(p)).collect();
            let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
            let mut ob = vec![0u64; len];
            vecops::weighted_sum(f, &coeffs, &views, &mut ob);
            let mut om = vec![0u64; len];
            mf.weighted_sum_premont(&mf.to_mont_vec(&coeffs), &views, &mut om);
            assert_eq!(om, ob, "kernel-tier weighted_sum value drift");
            let sb = time_it("kernel-tier/weighted_sum barrett 17x64k", 2, 9, || {
                vecops::weighted_sum(f, &coeffs, &views, &mut ob);
                std::hint::black_box(&ob);
            });
            println!("{}", sb.report());
            let sm = time_it("kernel-tier/weighted_sum mont 17x64k", 2, 9, || {
                let cm = mf.to_mont_vec(&coeffs);
                mf.weighted_sum_premont(&cm, &views, &mut om);
                std::hint::black_box(&om);
            });
            println!("{}  [{:.2}x vs barrett]", sm.report(), sb.median_s / sm.median_s);
            tier_rows.push(Json::obj(vec![
                ("kernel", Json::str("weighted_sum 17x64k")),
                ("p", Json::num(p as f64)),
                ("barrett_median_s", Json::num(sb.median_s)),
                ("mont_median_s", Json::num(sm.median_s)),
                ("speedup", Json::num(sb.median_s / sm.median_s)),
            ]));
        }

        let doc = Json::obj(vec![
            ("bench", Json::str("kernel_tiers")),
            ("results", Json::Arr(tier_rows)),
        ]);
        std::fs::write("BENCH_kernels.json", doc.to_string())
            .expect("writing BENCH_kernels.json");
        println!("wrote BENCH_kernels.json");
    }

    // PJRT side (needs `make artifacts` and `--features pjrt`).
    bench_pjrt(&shapes, p, &mut rng);

    // --- TruncPr + degree reduction over the threaded fabric -------------
    {
        use copml::coordinator::baseline::{train, BaselineConfig, MpcFlavor};
        use copml::data::{Dataset, SynthSpec};
        let ds = Dataset::synth(SynthSpec::tiny(), 1);
        let cfg = BaselineConfig {
            n: 7,
            t: 2,
            plan: copml::quant::FpPlan::paper_cifar(),
            iters: 3,
            batches: 1,
            eta: 2.0,
            seed: 1,
            fit_range: 4.0,
            flavor: MpcFlavor::Bh08,
            parallelism: Parallelism::sequential(),
            kernel: KernelTier::Barrett,
        };
        let stats = time_it("mpc/baseline-bh08 tiny 3 iters (7 threads)", 1, 5, || {
            std::hint::black_box(train(&cfg, &ds).unwrap());
        });
        println!("{}", stats.report());
        record(&mut json_rows, &stats, 1);
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("micro_primitives")),
        ("p", Json::num(p as f64)),
        ("results", Json::Arr(json_rows)),
    ]);
    std::fs::write("BENCH_micro_primitives.json", doc.to_string())
        .expect("writing BENCH_micro_primitives.json");
    println!("\nwrote BENCH_micro_primitives.json");
    println!("(reduce throughput target ≥ 300 M/s, weighted_sum ≥ 150 M muladd/s, parallel \
              weighted_sum/matvec ≥ 2x at 4 threads on large shapes — see EXPERIMENTS.md §Perf)");
    let _ = humanize(0.0);
}

#[cfg(feature = "pjrt")]
fn bench_pjrt(shapes: &[(usize, usize)], p: u64, rng: &mut Rng) {
    use copml::runtime::pjrt::PjrtRuntime;
    match PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        Err(e) => println!("kernel/pjrt: SKIPPED ({e})"),
        Ok(rt) => {
            for &(rows, cols) in shapes {
                let pp = if cols > 4096 { Field::paper_gisette().modulus() } else { p };
                if !rt.supports(pp, 1, rows, cols) {
                    println!("kernel/pjrt {rows}x{cols}: no artifact");
                    continue;
                }
                let x: Vec<u64> = (0..rows * cols).map(|_| rng.gen_range(pp)).collect();
                let w: Vec<u64> = (0..cols).map(|_| rng.gen_range(pp)).collect();
                let cq = vec![rng.gen_range(pp), rng.gen_range(pp)];
                let shape = MatShape::new(rows, cols);
                let stats = time_it(&format!("kernel/pjrt {rows}x{cols}"), 1, 5, || {
                    std::hint::black_box(rt.run(pp, &x, shape, &w, &cq).unwrap());
                });
                println!("{}", stats.report());
            }
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_pjrt(_shapes: &[(usize, usize)], _p: u64, _rng: &mut Rng) {
    println!("kernel/pjrt: SKIPPED (built without the `pjrt` feature)");
}
