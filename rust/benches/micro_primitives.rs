//! Microbenchmarks of every hot primitive — the §Perf foundation:
//! field ops, Lagrange weighted sums (encode/decode), Shamir sharing, MPC
//! degree reduction, TruncPr, and the encoded-gradient kernel (native rust
//! vs AOT/PJRT at paper block shapes).
//!
//! Run: `cargo bench --bench micro_primitives`

use copml::bench::{harness::humanize, time_it};
use copml::field::{vecops, Field, MatShape, P26};
use copml::lcc::Encoder;
use copml::prng::Rng;
use copml::runtime::{native::NativeKernel, pjrt::PjrtRuntime, GradKernel};
use copml::shamir;

fn main() {
    let f = Field::paper_cifar();
    let p = f.modulus();
    let mut rng = Rng::seed_from_u64(0xBE7C);
    println!("{:<44} {:>12} {:>12} {:>12}", "benchmark", "median", "min", "mad");

    // --- field reduce/mul throughput -------------------------------------
    let xs: Vec<u64> = (0..1 << 20).map(|_| rng.next_u64()).collect();
    let stats = time_it("field/reduce 1M u64", 2, 9, || {
        let mut acc = 0u64;
        for &x in &xs {
            acc = acc.wrapping_add(f.reduce(x));
        }
        std::hint::black_box(acc);
    });
    println!("{}  [{:.0} M red/s]", stats.report(), 1e-6 * xs.len() as f64 / stats.median_s);

    // --- dot (the paper's mod-after-inner-product trick) ------------------
    let a: Vec<u64> = (0..3072).map(|_| rng.gen_range(p)).collect();
    let b: Vec<u64> = (0..3072).map(|_| rng.gen_range(p)).collect();
    let stats = time_it("field/dot d=3072 (CIFAR row)", 5, 15, || {
        std::hint::black_box(vecops::dot(f, &a, &b));
    });
    println!("{}", stats.report());

    // --- weighted_sum: Lagrange encode unit -------------------------------
    for (terms, len) in [(17usize, 1 << 16), (33, 1 << 16)] {
        let mats: Vec<Vec<u64>> = (0..terms)
            .map(|_| (0..len).map(|_| rng.gen_range(p)).collect())
            .collect();
        let coeffs: Vec<u64> = (0..terms).map(|_| rng.gen_range(p)).collect();
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; len];
        let stats = time_it(&format!("lcc/weighted_sum K+T={terms} 64k els"), 2, 9, || {
            vecops::weighted_sum(f, &coeffs, &views, &mut out);
            std::hint::black_box(&out);
        });
        println!(
            "{}  [{:.0} M muladd/s]",
            stats.report(),
            1e-6 * (terms * len) as f64 / stats.median_s
        );
    }

    // --- end-to-end LCC encode at CIFAR Case-1 block shape ---------------
    {
        let (k, t, n) = (16usize, 1usize, 50usize);
        let rows_k = 9024 / k;
        let len = rows_k * 3073;
        let enc = Encoder::standard(f, k, t, n);
        let parts: Vec<Vec<u64>> = (0..k + t)
            .map(|_| (0..len).map(|_| rng.gen_range(p)).collect())
            .collect();
        let views: Vec<&[u64]> = parts.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; len];
        let stats = time_it("lcc/encode one client, CIFAR Case 1", 1, 5, || {
            enc.encode_one(7, &views, &mut out);
            std::hint::black_box(&out);
        });
        println!("{}", stats.report());
    }

    // --- Shamir sharing ----------------------------------------------------
    let secret: Vec<u64> = (0..1 << 16).map(|_| rng.gen_range(p)).collect();
    for (n, t) in [(10usize, 1usize), (50, 7)] {
        let stats = time_it(&format!("shamir/share 64k els N={n} T={t}"), 1, 5, || {
            let mut r2 = Rng::seed_from_u64(1);
            std::hint::black_box(shamir::share(f, &secret, n, t, &mut r2));
        });
        println!("{}", stats.report());
    }

    // --- encoded-gradient kernel: native vs PJRT at paper shapes ----------
    let shapes = [(564usize, 3073usize), (1024, 3073), (2048, 3073), (1200, 5000)];
    for (rows, cols) in shapes {
        let ff = if cols > 4096 { Field::paper_gisette() } else { f };
        let pp = ff.modulus();
        let x: Vec<u64> = (0..rows * cols).map(|_| rng.gen_range(pp)).collect();
        let w: Vec<u64> = (0..cols).map(|_| rng.gen_range(pp)).collect();
        let cq = vec![rng.gen_range(pp), rng.gen_range(pp)];
        let shape = MatShape::new(rows, cols);
        let kernel = NativeKernel::new(ff);
        let stats = time_it(&format!("kernel/native {rows}x{cols}"), 1, 5, || {
            std::hint::black_box(kernel.encoded_gradient(&x, shape, &w, &cq));
        });
        println!(
            "{}  [{:.0} M cells/s]",
            stats.report(),
            1e-6 * (rows * cols) as f64 / stats.median_s
        );
    }

    // PJRT side (needs `make artifacts`).
    match PjrtRuntime::load(&PjrtRuntime::default_dir()) {
        Err(e) => println!("kernel/pjrt: SKIPPED ({e})"),
        Ok(rt) => {
            for (rows, cols) in shapes {
                let pp = if cols > 4096 { Field::paper_gisette().modulus() } else { p };
                if !rt.supports(pp, 1, rows, cols) {
                    println!("kernel/pjrt {rows}x{cols}: no artifact");
                    continue;
                }
                let x: Vec<u64> = (0..rows * cols).map(|_| rng.gen_range(pp)).collect();
                let w: Vec<u64> = (0..cols).map(|_| rng.gen_range(pp)).collect();
                let cq = vec![rng.gen_range(pp), rng.gen_range(pp)];
                let shape = MatShape::new(rows, cols);
                let stats = time_it(&format!("kernel/pjrt {rows}x{cols}"), 1, 5, || {
                    std::hint::black_box(rt.run(pp, &x, shape, &w, &cq).unwrap());
                });
                println!("{}", stats.report());
            }
        }
    }

    // --- TruncPr + degree reduction over the threaded fabric -------------
    {
        use copml::coordinator::baseline::{train, BaselineConfig, MpcFlavor};
        use copml::data::{Dataset, SynthSpec};
        let ds = Dataset::synth(SynthSpec::tiny(), 1);
        let cfg = BaselineConfig {
            n: 7,
            t: 2,
            plan: copml::quant::FpPlan::paper_cifar(),
            iters: 3,
            eta: 2.0,
            seed: 1,
            fit_range: 4.0,
            flavor: MpcFlavor::Bh08,
        };
        let stats = time_it("mpc/baseline-bh08 tiny 3 iters (7 threads)", 1, 5, || {
            std::hint::black_box(train(&cfg, &ds).unwrap());
        });
        println!("{}", stats.report());
    }

    println!("\n(reduce throughput target ≥ 300 M/s, weighted_sum ≥ 150 M muladd/s — see EXPERIMENTS.md §Perf)");
    let _ = humanize(0.0);
}
