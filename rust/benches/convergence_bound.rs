//! Theorem 1 (Eq. 12): the expected loss of the averaged iterate is
//! bounded by ‖w⁰−w*‖²/(2ηJ) + ησ², with σ² = d·2^{2(k₁−1)}/m² the
//! truncation-noise variance. This harness trains COPML over several seeds
//! and checks the bound empirically — an *extension* experiment (the paper
//! proves but does not plot it).
//!
//! Run: `cargo bench --bench convergence_bound`

use copml::coordinator::{algo, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::ml;
use copml::quant;
use copml::report::Table;

fn main() {
    let ds = Dataset::synth(SynthSpec::smoke(), 31);
    let n = 10usize;
    let iters = 40usize;

    // Reference optimum w*: long plaintext run with the poly link (the
    // quantized recursion optimizes the poly-link objective).
    let poly = ml::fit_sigmoid(1, 4.0, 4000);
    let wstar = ml::train_logreg(
        &ds,
        &ml::LogRegOptions { iters: 3000, eta: 2.0, link: Some(poly), trace_accuracy: false },
    );
    let c_star = ml::cross_entropy(&ds.x, &ds.y, ds.d, &wstar.w);

    let mut table = Table::new(
        "Theorem 1 — loss of averaged iterate vs bound (smoke dataset, J = 40)",
        &["seed", "C(w̄) − C(w*)", "bound"],
    );
    let mut all_ok = true;
    for seed in [1u64, 2, 3, 4, 5] {
        let mut cfg = CopmlConfig::for_dataset(&ds, n, CaseParams::case1(n), seed);
        cfg.iters = iters;
        let out = algo::train(&cfg, &ds).expect("train");
        // averaged iterate w̄ = (1/J)Σ w^{(t)}
        let d = ds.d;
        let mut wbar = vec![0.0f64; d];
        for wq in &out.w_trace {
            let w = quant::dequantize_slice(cfg.plan.field, wq, cfg.plan.lw);
            for (a, b) in wbar.iter_mut().zip(&w) {
                *a += b / iters as f64;
            }
        }
        let gap = ml::cross_entropy(&ds.x, &ds.y, d, &wbar) - c_star;

        // Bound: ‖w⁰−w*‖²/(2ηJ) + ησ², w⁰ = 0.
        let w0_dist: f64 = wstar.w.iter().map(|v| v * v).sum();
        let k1 = cfg.plan.k1_total();
        let sigma2 = d as f64 * 2f64.powi(2 * (k1 as i32 - 1)) / (ds.m as f64 * ds.m as f64)
            / 2f64.powi(2 * cfg.plan.grad_scale() as i32); // scale back to real units
        let bound = w0_dist / (2.0 * cfg.eta * iters as f64) + cfg.eta * sigma2;
        let ok = gap <= bound * 1.05 || gap < 0.05; // small-noise floor
        all_ok &= ok;
        table.row(&[
            seed.to_string(),
            format!("{gap:.5}"),
            format!("{bound:.5}"),
        ]);
    }
    table.print();
    assert!(all_ok, "Theorem-1 bound violated");
    println!("convergence bound holds on all seeds");
}
