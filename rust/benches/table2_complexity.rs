//! Table II: COPML's asymptotic per-client complexity —
//! communication `O(mdN/K + dNJ)`, computation `O(md²/K)`, encoding
//! `O(mdN(K+T)/K + dN(K+T)J)` — verified **empirically**: the threaded
//! protocol's byte ledger and measured kernels are swept over m, d, N, K,
//! T, J and fitted against the formulas (each sweep doubles one driver and
//! checks the measured quantity scales by the predicted factor).
//!
//! Run: `cargo bench --bench table2_complexity`

use copml::coordinator::{protocol, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::report::Table;

struct Obs {
    comm_bytes: f64,
    comp_s: f64,
    encdec_s: f64,
}

/// Run the real threaded protocol and extract per-client means.
fn observe(m: usize, d: usize, n: usize, k: usize, t: usize, iters: usize) -> Obs {
    let spec = SynthSpec { m_train: m, m_test: 16, d, ..SynthSpec::tiny() };
    let ds = Dataset::synth(spec, 7);
    let mut cfg = CopmlConfig::for_dataset(&ds, n, CaseParams::explicit(k, t), 7);
    cfg.iters = iters;
    let out = protocol::train(&cfg, &ds).expect("protocol run");
    let nl = out.ledgers.len() as f64;
    // comm: encode-model + share-results + decode openings (per-iteration
    // phases; dataset sharing is the one-time offline step the paper
    // excludes via footnote 5).
    let comm: u64 = out.ledgers.iter().map(|l| l.bytes[3] + l.bytes[4] + l.bytes[6] + l.bytes[7]).sum();
    let comp: f64 = out.ledgers.iter().map(|l| l.seconds[5]).sum();
    let encdec: f64 = out.ledgers.iter().map(|l| l.seconds[3] + l.seconds[4] + l.seconds[7]).sum();
    Obs { comm_bytes: comm as f64 / nl, comp_s: comp / nl, encdec_s: encdec / nl }
}

fn check(label: &str, measured_ratio: f64, predicted_ratio: f64, tol: f64) -> [String; 4] {
    let ok = measured_ratio > predicted_ratio * (1.0 - tol)
        && measured_ratio < predicted_ratio * (1.0 + tol);
    assert!(
        ok,
        "{label}: measured ×{measured_ratio:.2} vs predicted ×{predicted_ratio:.2}"
    );
    [
        label.to_string(),
        format!("{measured_ratio:.2}×"),
        format!("{predicted_ratio:.2}×"),
        if ok { "✓".into() } else { "✗".into() },
    ]
}

fn main() {
    let mut table = Table::new(
        "Table II — empirical scaling of per-client cost vs the paper's formulas",
        &["sweep", "measured", "predicted", "ok"],
    );

    // Base configuration (small enough for the full threaded protocol).
    let (m, d, n, k, t, j) = (192usize, 24usize, 16usize, 2usize, 2usize, 4usize);
    let base = observe(m, d, n, k, t, j);

    // (1) communication ~ mdN/K + dNJ — doubling d doubles comm.
    let dd = observe(m, 2 * d, n, k, t, j);
    table.row(&check("comm: d → 2d", dd.comm_bytes / base.comm_bytes, 2.0, 0.35));

    // (2) communication: J → 2J scales only the dNJ term.
    let jj = observe(m, d, n, k, t, 2 * j);
    let pred = {
        // per-iteration comm dominates at this size; one-time encode-data
        // term stays: predict from the formula with exact terms
        let per_iter = (n - 1 + t) as f64 * d as f64; // results + encode msgs
        let one_time = (t + 1) as f64 * (m / k) as f64 * d as f64;
        (one_time + per_iter * (2 * j) as f64) / (one_time + per_iter * j as f64)
    };
    table.row(&check("comm: J → 2J", jj.comm_bytes / base.comm_bytes, pred, 0.35));

    // (3) computation ~ md²/K: K → 2K halves per-client gradient compute.
    let kk = observe(m, d, n, 2 * k, t, j);
    table.row(&check("comp: K → 2K", base.comp_s / kk.comp_s, 2.0, 0.6));

    // (4) computation ~ m: m → 2m doubles it.
    let mm = observe(2 * m, d, n, k, t, j);
    table.row(&check("comp: m → 2m", mm.comp_s / base.comp_s, 2.0, 0.6));

    // (5) encoding ~ (K+T): K+T → ~2(K+T) via T.
    let tt = observe(m, d, n, k, t + 2, j); // K+T: 4 → 6
    let pred_enc = 6.0 / 4.0;
    table.row(&check(
        "encdec: K+T → 1.5(K+T)",
        tt.encdec_s / base.encdec_s,
        pred_enc,
        0.8, // timing noise at µs scale; bytes-based checks above are tight
    ));

    table.print();

    // Offline column (live): under `--offline distributed` the randomness
    // generation is real ledger traffic — phase 0 — scaling with the bit
    // demand (≈ 2·d·J·(k₂+κ) bits); under the dealer it is exactly zero.
    let spec = SynthSpec { m_train: 96, m_test: 16, d: 12, ..SynthSpec::tiny() };
    let ds = Dataset::synth(spec, 9);
    let mut cfg = CopmlConfig::for_dataset(&ds, 7, CaseParams::explicit(2, 1), 9);
    cfg.iters = 2;
    let dealer = protocol::train(&cfg, &ds).expect("dealer run");
    cfg.offline = copml::mpc::OfflineMode::Distributed;
    let dist = protocol::train(&cfg, &ds).expect("distributed run");
    let dealer_off: u64 = dealer.ledgers.iter().map(|l| l.bytes[0]).sum();
    let dist_off: u64 = dist.ledgers.iter().map(|l| l.bytes[0]).sum();
    let online: u64 = dist.ledgers.iter().map(|l| l.bytes[1..].iter().sum::<u64>()).sum();
    println!(
        "offline column (live, N=7 K=2 T=1 J=2): dealer {dealer_off} B, \
         distributed {dist_off} B (online phases: {online} B)"
    );
    assert_eq!(dealer_off, 0, "dealer offline phase must be free on the wire");
    assert!(dist_off > 0, "distributed offline phase must appear in the ledger");
    println!("table2 scaling checks passed");
}
