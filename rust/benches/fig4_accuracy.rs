//! Fig. 4 (a)(b): test accuracy vs iteration — COPML (Case 2, N = 50)
//! against conventional (plaintext, exact-sigmoid) logistic regression,
//! at full paper scale: CIFAR-10-like (9019×3073, 2000 test) and
//! GISETTE-like (6000×5000, 1000 test), 50 iterations.
//!
//! COPML runs in algorithmic-fidelity mode — **bit-identical** to the full
//! protocol (rust/tests/protocol_equivalence.rs) — which is what makes the
//! paper-scale secure run tractable on one machine. Includes the
//! headroom-prime ablation (p = 2^31−1, more fractional bits).
//!
//! Run: `cargo bench --bench fig4_accuracy`

use copml::coordinator::{algo, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::ml;
use copml::quant::FpPlan;
use copml::report::Table;

fn run_dataset(spec: SynthSpec, paper_secure: f64, paper_plain: f64) {
    let ds = Dataset::synth(spec, 4242);
    let n = 50;
    let case = CaseParams::case2(n);
    println!(
        "\n=== {} ({}×{}, {} test) — COPML Case 2 (K={}, T={}), N={n} ===",
        ds.name,
        ds.m,
        ds.d,
        ds.y_test.len(),
        case.k,
        case.t
    );

    let mut cfg = CopmlConfig::for_dataset(&ds, n, case, 4242);
    cfg.iters = 50;

    let t0 = std::time::Instant::now();
    let secure = algo::train(&cfg, &ds).expect("secure training");
    let secure_time = t0.elapsed().as_secs_f64();

    let mut head_cfg = cfg.clone();
    head_cfg.plan = FpPlan::headroom();
    let headroom = algo::train(&head_cfg, &ds);

    let plain = ml::train_logreg(
        &ds,
        &ml::LogRegOptions { iters: cfg.iters, eta: cfg.eta, ..Default::default() },
    );

    let mut table = Table::new(
        "test accuracy vs iteration",
        &["iter", "COPML (paper plan)", "COPML (headroom p=2^31−1)", "plaintext LR"],
    );
    for i in (0..cfg.iters).step_by(5).chain([cfg.iters - 1]) {
        table.row(&[
            (i + 1).to_string(),
            format!("{:.4}", secure.test_accuracy[i]),
            headroom
                .as_ref()
                .map(|h| format!("{:.4}", h.test_accuracy[i]))
                .unwrap_or_else(|e| format!("err: {e:.8}")),
            format!("{:.4}", plain.test_accuracy[i]),
        ]);
    }
    table.print();
    let s = secure.test_accuracy.last().unwrap();
    let p = plain.test_accuracy.last().unwrap();
    println!(
        "final: secure {s:.4} vs plaintext {p:.4} (gap {:.4}); paper: {paper_secure} vs {paper_plain}",
        (p - s).abs()
    );
    println!("secure run time (central recursion): {secure_time:.1} s");
    assert!(
        (p - s).abs() < 0.04,
        "secure-vs-plaintext gap must stay within ~4 points (paper: 1.3)"
    );
}

fn main() {
    run_dataset(SynthSpec::cifar_like(), 0.8045, 0.8175);
    run_dataset(SynthSpec::gisette_like(), 0.975, 0.975);
    println!("\nfig4 shape assertions passed");
}
