//! `fig_straggler`: training-time insensitivity to the slowest `N − need`
//! parties — the headline scalability property of LCC encoding (paper
//! Theorem 1: any `(2r+1)(K+T−1)+1` client results decode).
//!
//! Three *real* full-protocol runs (N client threads over the Hub, live
//! quorum gathers, injected faults — nothing modeled):
//!
//! 1. **healthy** — no faults, the per-iteration baseline;
//! 2. **straggler** — one party sleeps ~10× the healthy iteration time in
//!    every compute phase, another is killed mid-training (`N − need ≥ 2`
//!    slack absorbs both);
//! 3. the claim: the fast parties' per-iteration time stays at the
//!    fastest-quorum latency — it does NOT inherit the injected delay,
//!    which a fixed-order gather would add to every round.
//!
//! The model trajectory is asserted bit-identical across all runs
//! (interpolation is exact, so quorum composition and faults cannot move
//! it). Results are dumped to `BENCH_straggler.json`.
//!
//! Run: `cargo bench --bench fig_straggler`

use copml::coordinator::protocol::ProtocolOutput;
use copml::coordinator::{algo, protocol, CaseParams, CopmlConfig, FaultPlan};
use copml::data::{Dataset, SynthSpec};
use copml::report::Json;

/// Mean per-iteration wall time of a *fast* party (the king), counting
/// only the per-iteration phases (model encode, compute, share results,
/// decode+update).
fn per_iter_seconds(po: &ProtocolOutput, iters: usize) -> f64 {
    let l = &po.ledgers[0];
    l.seconds[4..8].iter().sum::<f64>() / iters as f64
}

fn main() {
    let ds = Dataset::synth(SynthSpec::tiny(), 77);
    // N=11, T=1: subgroups {0,1}…{6,7} plus the tail group {8,9,10}. The
    // tail group is the fixture's point — killing ONE member leaves two,
    // still ≥ T+1, so the delayed member keeps straggling (live) instead
    // of dying as collateral.
    let (n, k, t, iters) = (11usize, 2usize, 1usize, 8usize);
    let mut cfg = CopmlConfig::for_dataset(&ds, n, CaseParams::explicit(k, t), 77);
    cfg.iters = iters;
    let need = cfg.recovery_threshold();
    assert!(n - need >= 2, "bench config needs quorum slack ≥ 2 (have {})", n - need);
    println!("fig_straggler: N={n} K={k} T={t} → recovery threshold {need}, slack {}", n - need);

    // Bit-identity oracle: the central recursion.
    let reference = algo::train(&cfg, &ds).expect("algo reference");

    // Healthy run (first-arrival quorums active: N > need).
    let healthy = protocol::train(&cfg, &ds).expect("healthy run");
    assert_eq!(
        healthy.train.w_trace, reference.w_trace,
        "healthy quorum run must match the central recursion bit for bit"
    );
    let healthy_iter_s = per_iter_seconds(&healthy, iters);
    for (i, q) in healthy.ledgers[0].quorums.iter().enumerate() {
        assert!(q.len() >= need, "round {i}: quorum of {} < need {need}", q.len());
    }

    // Straggler run: party 8 sleeps ~10× the healthy iteration every
    // round (a SUSTAINED live straggler — its late results are skipped
    // round after round until --max-lag excludes it and it self-halts);
    // its tail-group mate 10 is killed at iteration 1 (party 9 keeps the
    // group reconstructable). Exclusion after 2 consecutive misses.
    // 200 ms floor: the 0.5·delay assertion below compares wall-clock
    // measurements minutes apart on a possibly-shared runner, so the
    // threshold must dwarf any plausible load-induced per-iteration
    // inflation of this tiny workload.
    let delay_ms = ((healthy_iter_s * 10.0) * 1e3).ceil().max(200.0) as u64;
    let delay_s = delay_ms as f64 / 1e3;
    let mut faulted_cfg = cfg.clone();
    faulted_cfg.faults = FaultPlan { delays: vec![(8, delay_ms)], kills: vec![(10, 1)] };
    faulted_cfg.max_lag = Some(2);
    let faulted = protocol::train(&faulted_cfg, &ds)
        .expect("training must survive one straggler and one killed party");
    assert_eq!(
        faulted.train.w_trace, reference.w_trace,
        "faults may cost time, never accuracy: the trajectory must be bit-identical"
    );
    let faulted_iter_s = per_iter_seconds(&faulted, iters);
    let excluded = &faulted.ledgers[0].excluded;
    println!(
        "healthy {:.3} ms/iter · faulted {:.3} ms/iter · injected delay {delay_ms} ms · excluded {excluded:?}",
        healthy_iter_s * 1e3,
        faulted_iter_s * 1e3
    );

    // The claim. A fixed-order gather would stall ≥ delay_s on (almost)
    // every round that waits for party 8; the quorum path must stay well
    // under half that, bounded by the fastest-quorum latency.
    assert!(
        faulted_iter_s < 0.5 * delay_s,
        "per-iteration time {faulted_iter_s:.4}s is not insensitive to the \
         injected {delay_s:.4}s straggler delay"
    );
    assert!(
        excluded.contains(&8) && excluded.contains(&10),
        "delayed and killed parties must both be excluded: {excluded:?}"
    );

    let quorum_sizes: Vec<Json> = faulted.ledgers[0]
        .quorums
        .iter()
        .map(|q| Json::num(q.len() as f64))
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("fig_straggler")),
        ("n", Json::num(n as f64)),
        ("k", Json::num(k as f64)),
        ("t", Json::num(t as f64)),
        ("iters", Json::num(iters as f64)),
        ("recovery_threshold", Json::num(need as f64)),
        ("healthy_iter_s", Json::num(healthy_iter_s)),
        ("faulted_iter_s", Json::num(faulted_iter_s)),
        ("injected_delay_s", Json::num(delay_s)),
        (
            "slowdown_vs_delay",
            Json::num((faulted_iter_s - healthy_iter_s).max(0.0) / delay_s),
        ),
        (
            "excluded",
            Json::arr(excluded.iter().map(|&e| Json::num(e as f64))),
        ),
        ("faulted_quorum_sizes", Json::Arr(quorum_sizes)),
    ]);
    std::fs::write("BENCH_straggler.json", doc.to_string()).expect("writing BENCH_straggler.json");
    println!("wrote BENCH_straggler.json");
    println!("fig_straggler assertions passed");
}
