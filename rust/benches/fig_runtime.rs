//! `fig_runtime`: OS-thread and wall-clock accounting of the two TCP
//! party runtimes (`--runtime threaded|event`) on large-N loopback
//! meshes — the ISSUE-6 acceptance bench.
//!
//! The threaded runtime spawns one reader thread per connection end:
//! `N·(N−1)` across an N-party loopback process (600 at N=25), on top of
//! the N client threads. The event runtime drains every socket on ONE
//! shared `poll(2)` reactor thread, so the whole mesh adds a single OS
//! thread regardless of N. Three real full-protocol runs:
//!
//! 1. **N=25 threaded** — the ~N² baseline (peak threads ≥ N·(N−1));
//! 2. **N=25 event** — same protocol, same seed, peak threads ≤ N + 8,
//!    and a `w_trace` bit-identical to the threaded run and to the
//!    central recursion;
//! 3. **N=49 event** — a mesh the threaded runtime would drive to 2352
//!    reader threads, run with ≤ N + 8 (skipped with a log line if
//!    `RLIMIT_NOFILE` cannot cover the ~4·N² socket descriptors).
//!
//! Peak thread counts are sampled from `/proc/self/status` (Linux-only,
//! like the reactor itself). Results are dumped to `BENCH_runtime.json`.
//!
//! Run: `cargo bench --bench fig_runtime`

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use copml::coordinator::protocol::ProtocolOutput;
use copml::coordinator::{algo, protocol, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::net::Runtime;
use copml::report::Json;

/// Mean per-iteration wall time of a fast party (the king), counting only
/// the per-iteration phases (model encode, compute, share results,
/// decode+update) — same accounting as `fig_straggler`.
fn per_iter_seconds(po: &ProtocolOutput, iters: usize) -> f64 {
    let l = &po.ledgers[0];
    l.seconds[4..8].iter().sum::<f64>() / iters as f64
}

/// Current OS-thread count of this process, from the `Threads:` line of
/// `/proc/self/status`.
fn thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0)
}

/// Run `body` while a sampler thread records the peak thread count (2 ms
/// cadence — reader threads persist for the whole run, so the peak plateau
/// is seconds wide and cannot be missed).
fn with_thread_sampler<T>(body: impl FnOnce() -> T) -> (T, usize) {
    let stop = Arc::new(AtomicBool::new(false));
    let peak = Arc::new(AtomicUsize::new(thread_count()));
    let sampler = std::thread::Builder::new()
        .name("fig-runtime-sampler".into())
        .spawn({
            let stop = Arc::clone(&stop);
            let peak = Arc::clone(&peak);
            move || {
                while !stop.load(Ordering::Relaxed) {
                    peak.fetch_max(thread_count(), Ordering::Relaxed);
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
            }
        })
        .expect("spawning sampler");
    let out = body();
    stop.store(true, Ordering::Relaxed);
    sampler.join().expect("sampler panicked");
    (out, peak.load(Ordering::Relaxed))
}

// RLIMIT_NOFILE plumbing, same hand-rolled libc style as the reactor's
// poll(2) wrapper (no libc crate in the offline image; Linux x86-64 ABI).
#[repr(C)]
struct Rlimit {
    rlim_cur: u64,
    rlim_max: u64,
}
const RLIMIT_NOFILE: i32 = 7;
extern "C" {
    fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
}

/// Ensure the process may hold `want` file descriptors, raising the soft
/// limit toward the hard limit if needed. `false` means the hard limit is
/// below `want` — the caller skips the case instead of dying on EMFILE.
fn ensure_fd_budget(want: u64) -> bool {
    unsafe {
        let mut r = Rlimit { rlim_cur: 0, rlim_max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return false;
        }
        if r.rlim_cur >= want {
            return true;
        }
        if r.rlim_max >= want {
            let bumped = Rlimit { rlim_cur: r.rlim_max, rlim_max: r.rlim_max };
            return setrlimit(RLIMIT_NOFILE, &bumped) == 0;
        }
        false
    }
}

/// Every socket appears twice in the process (transport writer + reader
/// clone), plus listeners, the reactor wake pipe, and stdio headroom.
fn fd_budget(n: usize) -> u64 {
    (4 * n * n + 64) as u64
}

struct CaseRun {
    out: ProtocolOutput,
    wall_s: f64,
    peak_threads: usize,
}

fn run_case(ds: &Dataset, n: usize, k: usize, iters: usize, seed: u64, runtime: Runtime) -> CaseRun {
    let mut cfg = CopmlConfig::for_dataset(ds, n, CaseParams::explicit(k, 1), seed);
    cfg.iters = iters;
    cfg.runtime = runtime;
    let t0 = Instant::now();
    let (out, peak_threads) = with_thread_sampler(|| {
        protocol::train_tcp_loopback(&cfg, ds)
            .unwrap_or_else(|e| panic!("N={n} {runtime} loopback run failed: {e}"))
    });
    CaseRun { out, wall_s: t0.elapsed().as_secs_f64(), peak_threads }
}

fn case_json(n: usize, k: usize, iters: usize, runtime: Runtime, run: &CaseRun) -> Json {
    Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("k", Json::num(k as f64)),
        ("t", Json::num(1.0)),
        ("iters", Json::num(iters as f64)),
        ("runtime", Json::str(&runtime.to_string())),
        ("per_iter_s", Json::num(per_iter_seconds(&run.out, iters))),
        ("wall_s", Json::num(run.wall_s)),
        ("peak_threads", Json::num(run.peak_threads as f64)),
    ])
}

fn main() {
    let ds = Dataset::synth(SynthSpec::tiny(), 66);

    // N=25, K=7, T=1 → recovery threshold 3·7+1 = 22.
    let (n, k, iters) = (25usize, 7usize, 3usize);
    let mut cfg = CopmlConfig::for_dataset(&ds, n, CaseParams::explicit(k, 1), 66);
    cfg.iters = iters;
    let need = cfg.recovery_threshold();
    println!("fig_runtime: N={n} K={k} T=1 → recovery threshold {need}");
    assert!(
        ensure_fd_budget(fd_budget(n)),
        "cannot secure {} file descriptors for the N={n} mesh",
        fd_budget(n)
    );

    // Bit-identity oracle: the central recursion.
    let reference = algo::train(&cfg, &ds).expect("algo reference");

    let threaded = run_case(&ds, n, k, iters, 66, Runtime::Threaded);
    assert_eq!(
        threaded.out.train.w_trace, reference.w_trace,
        "threaded run must match the central recursion bit for bit"
    );
    let event = run_case(&ds, n, k, iters, 66, Runtime::Event);
    assert_eq!(
        event.out.train.w_trace, reference.w_trace,
        "event run must match the central recursion bit for bit"
    );

    let threaded_iter_s = per_iter_seconds(&threaded.out, iters);
    let event_iter_s = per_iter_seconds(&event.out, iters);
    println!(
        "N={n} threaded: peak {} threads · {:.3} ms/iter · {:.2}s wall",
        threaded.peak_threads,
        threaded_iter_s * 1e3,
        threaded.wall_s
    );
    println!(
        "N={n} event:    peak {} threads · {:.3} ms/iter · {:.2}s wall",
        event.peak_threads,
        event_iter_s * 1e3,
        event.wall_s
    );

    // The acceptance claims. Threaded: N clients + N·(N−1) readers — the
    // ~N² regime. Event: N clients + ONE reactor (+ main, sampler, and a
    // little headroom for short-lived mesh-setup threads).
    assert!(
        threaded.peak_threads >= n * (n - 1),
        "threaded peak {} below the N·(N−1) = {} reader-thread floor — \
         sampler broken?",
        threaded.peak_threads,
        n * (n - 1)
    );
    assert!(
        event.peak_threads <= n + 8,
        "event runtime peaked at {} threads (budget N+8 = {})",
        event.peak_threads,
        n + 8
    );
    // Wall-clock sanity (not a tight perf claim — this box may be a
    // single shared core): the reactor must not be pathologically slower
    // than 600 blocked reader threads.
    assert!(
        event_iter_s < 5.0 * threaded_iter_s.max(1e-3),
        "event per-iteration time {event_iter_s:.4}s is pathologically \
         slower than threaded {threaded_iter_s:.4}s"
    );

    let mut cases = vec![
        case_json(n, k, iters, Runtime::Threaded, &threaded),
        case_json(n, k, iters, Runtime::Event, &event),
    ];

    // N=49, K=15, T=1 → threshold 46. Threaded would need 2352 reader
    // threads here; the event runtime runs it on one reactor. Event-only:
    // the point is feasibility at a scale the threaded mesh thrashes.
    let (n_big, k_big, iters_big) = (49usize, 15usize, 2usize);
    if ensure_fd_budget(fd_budget(n_big)) {
        let big = run_case(&ds, n_big, k_big, iters_big, 66, Runtime::Event);
        let mut big_cfg = CopmlConfig::for_dataset(&ds, n_big, CaseParams::explicit(k_big, 1), 66);
        big_cfg.iters = iters_big;
        let big_ref = algo::train(&big_cfg, &ds).expect("N=49 algo reference");
        assert_eq!(
            big.out.train.w_trace, big_ref.w_trace,
            "N=49 event run must match the central recursion bit for bit"
        );
        assert!(
            big.peak_threads <= n_big + 8,
            "N={n_big} event runtime peaked at {} threads (budget N+8 = {})",
            big.peak_threads,
            n_big + 8
        );
        println!(
            "N={n_big} event:    peak {} threads · {:.3} ms/iter · {:.2}s wall \
             (threaded would hold {} reader threads)",
            big.peak_threads,
            per_iter_seconds(&big.out, iters_big) * 1e3,
            big.wall_s,
            n_big * (n_big - 1)
        );
        cases.push(case_json(n_big, k_big, iters_big, Runtime::Event, &big));
    } else {
        println!(
            "skipping N={n_big}: RLIMIT_NOFILE hard limit below the {} descriptors needed",
            fd_budget(n_big)
        );
        cases.push(Json::obj(vec![
            ("n", Json::num(n_big as f64)),
            ("runtime", Json::str("event")),
            ("skipped", Json::str("RLIMIT_NOFILE hard limit too low")),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("fig_runtime")),
        ("recovery_threshold_n25", Json::num(need as f64)),
        (
            "thread_reduction_n25",
            Json::num(threaded.peak_threads as f64 / event.peak_threads as f64),
        ),
        ("cases", Json::Arr(cases)),
    ]);
    std::fs::write("BENCH_runtime.json", doc.to_string()).expect("writing BENCH_runtime.json");
    println!("wrote BENCH_runtime.json");
    println!("fig_runtime assertions passed");
}
