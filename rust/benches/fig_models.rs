//! `fig_models`: the model zoo on real CSV benchmark sets — every workload
//! of the `ml::Model` trait trained securely and asserted against its own
//! cleartext reference at the fig4 tolerance (±4 accuracy/R² points).
//!
//! | workload    | dataset     | secure path                  | reference                |
//! |-------------|-------------|------------------------------|--------------------------|
//! | logreg      | breast.csv  | encoded-gradient GD          | exact-sigmoid f64 GD     |
//! | multinomial | iris.csv    | C one-vs-rest GD channels    | exact-sigmoid one-vs-rest|
//! | linreg      | breast.csv  | secure normal equations      | f64 ridge solve          |
//!
//! Secure runs use algorithmic-fidelity mode — bit-identical to the full
//! protocol (rust/tests/protocol_equivalence.rs, model_zoo_equivalence.rs),
//! which is what makes the sweep CI-fast. Linreg runs the headroom plan
//! (p = 2^31−1, more fractional bits): the one-shot closed form exposes the
//! raw data-quantization error directly, with no iteration loop to average
//! it out, so the paper plan's 2 fractional bits are too coarse for a
//! tight R² comparison (the same reason fig4 carries a headroom ablation).
//!
//! Datasets are deterministic surrogates with real-data shapes and
//! statistics — see data/README.md for provenance before citing numbers.
//!
//! Results land in `BENCH_models.json` (CI-uploaded artifact).
//!
//! Run: `cargo bench --bench fig_models`

use copml::coordinator::{algo, CaseParams, CopmlConfig};
use copml::data::csv::{self, CsvOptions};
use copml::data::Dataset;
use copml::ml::ModelKind;
use copml::quant::FpPlan;
use copml::report::{Json, Table};

fn load(file: &str) -> Dataset {
    let path = format!(concat!(env!("CARGO_MANIFEST_DIR"), "/../data/{}"), file);
    csv::load(&path, CsvOptions { seed: 4242, ..Default::default() })
        .unwrap_or_else(|e| panic!("loading {path}: {e}"))
}

struct Row {
    model: ModelKind,
    dataset: String,
    secure: f64,
    reference: f64,
    gap: f64,
    metrics: String,
}

fn run(kind: ModelKind, file: &str, iters: usize, plan: Option<FpPlan>) -> Row {
    let ds = load(file);
    let n = 10;
    let mut cfg = CopmlConfig::for_dataset(&ds, n, CaseParams::explicit(2, 1), 4242);
    cfg.model = kind;
    cfg.iters = iters;
    if let Some(p) = plan {
        cfg.plan = p;
    }
    let model = kind.model();
    println!(
        "\n=== {kind} on {} ({}×{}, {} classes, {} test) — K={} T={} iters={} ===",
        ds.name,
        ds.m,
        ds.d,
        ds.classes,
        ds.y_test.len(),
        cfg.k,
        cfg.t,
        cfg.iters
    );
    let t0 = std::time::Instant::now();
    let secure = algo::train(&cfg, &ds).expect("secure training");
    let secure_s = t0.elapsed().as_secs_f64();
    // Cleartext f64 reference with the exact link (the fig4 comparison:
    // the gap includes both the polynomial link and the quantization).
    let reference = model.reference(&ds, cfg.iters, cfg.eta, None);

    let s = *secure.test_accuracy.last().unwrap();
    let r = *reference.test_accuracy.last().unwrap();
    let gap = (s - r).abs();
    println!(
        "secure test score {s:.4} vs cleartext reference {r:.4} (gap {gap:.4}) in {secure_s:.2}s"
    );
    println!("secure final metrics: train[{}] test[{}]", secure.train_metrics, secure.test_metrics);
    assert!(
        gap < 0.04,
        "{kind} on {}: secure {s:.4} vs reference {r:.4} strays past the fig4 tolerance",
        ds.name
    );
    Row {
        model: kind,
        dataset: ds.name.clone(),
        secure: s,
        reference: r,
        gap,
        metrics: secure.test_metrics.to_string(),
    }
}

fn main() {
    let rows = vec![
        run(ModelKind::Logreg, "breast.csv", 40, None),
        run(ModelKind::Multinomial, "iris.csv", 60, None),
        run(ModelKind::Linreg, "breast.csv", 1, Some(FpPlan::headroom())),
    ];

    // Workload-specific quality floors (the surrogate datasets are built to
    // the real sets' separability — data/README.md): a regression here
    // means the secure pipeline lost model quality, not that the data moved.
    assert!(rows[0].secure > 0.85, "breast logreg accuracy {:.4}", rows[0].secure);
    assert!(rows[0].metrics.contains("auc="), "logreg must report AUC: {}", rows[0].metrics);
    assert!(rows[1].secure > 0.80, "iris multinomial accuracy {:.4}", rows[1].secure);
    assert!(rows[2].secure > 0.50, "breast linreg (LPM) R² {:.4}", rows[2].secure);
    assert!(rows[2].metrics.contains("r2="), "linreg must report R²: {}", rows[2].metrics);

    let mut table = Table::new(
        "model zoo vs cleartext reference (test split)",
        &["model", "dataset", "secure", "reference", "gap", "final metrics"],
    );
    let mut json_rows = Vec::new();
    for row in &rows {
        table.row(&[
            row.model.to_string(),
            row.dataset.clone(),
            format!("{:.4}", row.secure),
            format!("{:.4}", row.reference),
            format!("{:.4}", row.gap),
            row.metrics.clone(),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", Json::str(&row.model.to_string())),
            ("dataset", Json::str(&row.dataset)),
            ("secure_score", Json::num(row.secure)),
            ("reference_score", Json::num(row.reference)),
            ("gap", Json::num(row.gap)),
        ]));
    }
    table.print();

    let doc = Json::obj(vec![
        ("bench", Json::str("fig_models")),
        ("tolerance", Json::num(0.04)),
        ("results", Json::Arr(json_rows)),
    ]);
    std::fs::write("BENCH_models.json", doc.to_string()).expect("writing BENCH_models.json");
    println!("wrote BENCH_models.json");
    println!("fig_models: {} workloads within fig4 tolerance", rows.len());
}
