//! Table I: breakdown of the running time (Comp / Comm / Enc-Dec / Total)
//! at N = 50 on the CIFAR-10-like task for [BGW88], [BH08], COPML Case 1
//! and COPML Case 2 — plus the paper's own numbers side by side and the
//! structural ratios the paper highlights (computation speedup ≈ K/3·2,
//! BGW ≫ BH08 in comm).
//!
//! Includes the `round_batch` ablation: how much of the baselines' cost is
//! the gate-by-gate opening pattern (DESIGN.md §4 / cost-model docs); and
//! the wire-packing ablation (u64 MPI words vs packed u32 frames — the
//! same `Wire` knob the live socket transport exposes).
//!
//! Run: `cargo bench --bench table1_breakdown`

use copml::bench::{BaselineCost, Calibration, CopmlCost, PhaseBreakdown};
use copml::coordinator::CaseParams;
use copml::field::Field;
use copml::mpc::OfflineMode;
use copml::net::wan::WanModel;
use copml::net::Wire;
use copml::report::Table;

fn main() {
    let (n, m, d, iters) = (50usize, 9019usize, 3073usize, 50usize);
    println!("calibrating primitives …");
    let cal = Calibration::measure(Field::paper_cifar());
    let wan = WanModel::paper();

    let case1 = CaseParams::case1(n);
    let case2 = CaseParams::case2(n);
    let copml = |k: usize, t: usize| -> PhaseBreakdown {
        CopmlCost {
            n,
            k,
            t,
            r: 1,
            m,
            d,
            iters,
            batches: 1,
            subgroups: true,
            wire: Wire::U64,
            offline: OfflineMode::Dealer,
            trunc_bits: 25,
            stragglers: 0,
        }
        .estimate(&cal, &wan)
    };
    let c1 = copml(case1.k, case1.t);
    let c2 = copml(case2.k, case2.t);
    let bgw = BaselineCost::paper(n, m, d, iters, true).estimate(&cal, &wan);
    let bh08 = BaselineCost::paper(n, m, d, iters, false).estimate(&cal, &wan);

    let mut table = Table::new(
        &format!("Table I — breakdown at N = {n}, CIFAR-10-like, {iters} iterations"),
        &["Protocol", "Comp (s)", "Comm (s)", "Enc/Dec (s)", "Total (s)", "paper total"],
    );
    for (label, b, paper) in [
        ("MPC using [BGW88]", &bgw, 22384.0),
        ("MPC using [BH08]", &bh08, 7915.0),
        ("COPML (Case 1)", &c1, 440.0),
        ("COPML (Case 2)", &c2, 916.0),
    ] {
        table.row(&[
            label.to_string(),
            format!("{:.0}", b.comp_s),
            format!("{:.0}", b.comm_s),
            format!("{:.1}", b.encdec_s),
            format!("{:.0}", b.total_s()),
            format!("{paper:.0}"),
        ]);
    }
    table.print();

    // --- structural claims of the paper's Table I discussion -------------
    // (1) COPML computation ≈ (K/3)× faster than baselines (two share
    //     passes over m/3 rows vs one kernel pass over m/K rows).
    let comp_ratio = bh08.comp_s / c1.comp_s;
    let expected = 2.0 * case1.k as f64 / 3.0;
    println!(
        "computation speedup vs baseline: {comp_ratio:.1}× (K/3-law predicts ≈ {expected:.1}×, paper: 914/141 ≈ 6.5×)"
    );
    assert!(comp_ratio > expected * 0.5 && comp_ratio < expected * 2.0, "K/3 law violated");
    // (2) BGW ≫ BH08 in communication.
    assert!(bgw.comm_s > 2.0 * bh08.comm_s, "BGW must pay ≫ comm vs BH08");
    // (3) COPML wins overall.
    assert!(c1.total_s() < bh08.total_s() / 8.0);
    assert!(c2.total_s() < bh08.total_s() / 4.0);
    // (4) Case 2 trades time for privacy: slower than Case 1, T=7 vs T=1.
    assert!(c2.total_s() > c1.total_s());

    // --- ablation: gate-by-gate vs batched baseline openings -------------
    let mut table = Table::new(
        "ablation — [BH08] total vs opening batch size (why generic MPC loses)",
        &["round_batch", "Comm (s)", "Total (s)"],
    );
    for batch in [1usize, 8, 64, 512, usize::MAX] {
        let mut b = BaselineCost::paper(n, m, d, iters, false);
        b.round_batch = batch;
        let est = b.estimate(&cal, &wan);
        let label = if batch == usize::MAX { "whole-vector".into() } else { batch.to_string() };
        table.row(&[label, format!("{:.0}", est.comm_s), format!("{:.0}", est.total_s())]);
    }
    table.print();

    // --- ablation: wire packing (u64 MPI words vs packed u32 frames) -----
    // Every field element fits 32 bits (p < 2^32), so the socket transport
    // can halve payload bytes; this is the modeled counterpart of a
    // `--wire u32` protocol run (ledger validated in
    // rust/tests/cost_model_validation.rs).
    let mut table = Table::new(
        "ablation — COPML wire format (u64 words vs packed u32)",
        &["Protocol", "wire", "Comm (s)", "Total (s)"],
    );
    for (label, case) in [("COPML (Case 1)", case1), ("COPML (Case 2)", case2)] {
        let mk = |wire: Wire| {
            CopmlCost {
                n,
                k: case.k,
                t: case.t,
                r: 1,
                m,
                d,
                iters,
                batches: 1,
                subgroups: true,
                wire,
                offline: OfflineMode::Dealer,
                trunc_bits: 25,
                stragglers: 0,
            }
            .estimate(&cal, &wan)
        };
        let e64 = mk(Wire::U64);
        let e32 = mk(Wire::U32);
        for (wire, est) in [(Wire::U64, e64), (Wire::U32, e32)] {
            table.row(&[
                label.to_string(),
                wire.to_string(),
                format!("{:.0}", est.comm_s),
                format!("{:.0}", est.total_s()),
            ]);
        }
        assert!(e32.comm_s < e64.comm_s, "u32 packing must cut comm for {label}");
    }
    table.print();

    // --- ablation: offline-randomness source (trusted dealer vs DN07) ----
    // The paper's Table I treats the crypto-service provider as a free
    // offline oracle (footnote 3); the distributed offline phase makes
    // that cost a real, separately reported column — the price of
    // removing the last trusted component. Online columns are identical
    // by construction (only the pools' provenance changes).
    let mut table = Table::new(
        "ablation — offline randomness: dealer (free oracle) vs distributed (DN07)",
        &["Protocol", "offline", "Offline (s)", "Total (s)"],
    );
    let trunc_bits = {
        let plan = copml::quant::FpPlan::paper_cifar();
        plan.k2 + plan.kappa
    };
    for (label, case) in [("COPML (Case 1)", case1), ("COPML (Case 2)", case2)] {
        let mk = |offline: OfflineMode| {
            CopmlCost {
                n,
                k: case.k,
                t: case.t,
                r: 1,
                m,
                d,
                iters,
                batches: 1,
                subgroups: true,
                wire: Wire::U64,
                offline,
                trunc_bits,
                stragglers: 0,
            }
            .estimate(&cal, &wan)
        };
        let dealer = mk(OfflineMode::Dealer);
        let dist = mk(OfflineMode::Distributed);
        for (mode, est) in [(OfflineMode::Dealer, dealer), (OfflineMode::Distributed, dist)] {
            table.row(&[
                label.to_string(),
                mode.to_string(),
                format!("{:.0}", est.offline_s),
                format!("{:.0}", est.total_s()),
            ]);
        }
        assert_eq!(dealer.offline_s, 0.0, "dealer offline must be free for {label}");
        assert!(dist.offline_s > 0.0, "distributed offline must cost time for {label}");
        assert_eq!(dealer.comm_s, dist.comm_s, "online comm must not change for {label}");
        // Even paying for its own randomness, COPML stays ahead of the
        // dealer-assisted BH08 baseline — decentralization is affordable.
        assert!(
            dist.total_s() < bh08.total_s(),
            "{label} with distributed offline must still beat [BH08]"
        );
    }
    table.print();
    println!("table1 shape assertions passed");
}
