//! `fig_pipeline`: wall-time effect of the pipelined offline factory
//! (`--chunk`) and the multi-job `copml serve` daemon — the ISSUE-9
//! acceptance bench.
//!
//! Two questions, answered with real full-protocol runs at N ∈ {4, 9}
//! under `--offline distributed` (DN07 over the mesh — the mode with an
//! offline phase worth hiding):
//!
//! 1. **Single job:** how much of the offline generation moves off the
//!    critical path when the one-shot phase becomes a chunked background
//!    producer? Reported as the overlap ratio `hidden / (hidden +
//!    critical)` from the split phase-0 ledger, with `w_trace` asserted
//!    bit-identical to the one-shot run (the chunk-stability contract).
//! 2. **Job stream:** what does a 3-job pipelined serve run cost per job
//!    versus a cold-start single job? In steady state job `j+1`'s factory
//!    generates behind job `j`'s entire run, so the steady-state overlap
//!    ratio approaches 1 and per-job cost drops below the cold-start
//!    baseline — both asserted (overlap > 0.5 at N=9).
//!
//! Results are dumped to `BENCH_pipeline.json`.
//!
//! Run: `cargo bench --bench fig_pipeline`

use std::time::Instant;

use copml::coordinator::protocol::{self, ProtocolOutput};
use copml::coordinator::{CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::mpc::OfflineMode;
use copml::report::Json;

/// Chunk size for every pipelined run: small enough that the first pools
/// arrive quickly (fine-grained pipelining), large enough that producer
/// rounds stay batched.
const CHUNK: usize = 32;

fn base_cfg(ds: &Dataset, n: usize, k: usize, iters: usize, seed: u64) -> CopmlConfig {
    let mut cfg = CopmlConfig::for_dataset(ds, n, CaseParams::explicit(k, 1), seed);
    cfg.iters = iters;
    cfg.offline = OfflineMode::Distributed;
    cfg
}

/// Mean critical-path and hidden offline seconds across one run's
/// ledgers, plus the overlap ratio `hidden / (hidden + critical)`.
fn offline_split(po: &ProtocolOutput) -> (f64, f64, f64) {
    let nl = po.ledgers.len() as f64;
    let crit = po.ledgers.iter().map(|l| l.seconds[0]).sum::<f64>() / nl;
    let hidden = po.ledgers.iter().map(|l| l.offline_hidden_s).sum::<f64>() / nl;
    (crit, hidden, hidden / (hidden + crit).max(1e-12))
}

fn timed_train(cfg: &CopmlConfig, ds: &Dataset) -> (ProtocolOutput, f64) {
    let t0 = Instant::now();
    let po = protocol::train(cfg, ds).unwrap_or_else(|e| panic!("N={} train: {e}", cfg.n));
    (po, t0.elapsed().as_secs_f64())
}

/// One N-point of the bench: single-job one-shot vs pipelined, then
/// cold-start serve baseline vs a 3-job pipelined stream.
fn run_point(ds: &Dataset, n: usize, k: usize, iters: usize, seed: u64) -> Json {
    println!("— N={n} K={k} T=1, {iters} iterations, distributed offline —");

    // Single job, one-shot offline: the whole generation is critical-path.
    let cfg_oneshot = base_cfg(ds, n, k, iters, seed);
    let (po_oneshot, wall_oneshot) = timed_train(&cfg_oneshot, ds);
    let (crit_oneshot, hidden_oneshot, _) = offline_split(&po_oneshot);
    assert_eq!(hidden_oneshot, 0.0, "one-shot runs must report zero hidden offline seconds");

    // Single job, pipelined factory: same elements, chunked production.
    let mut cfg_pipe = cfg_oneshot.clone();
    cfg_pipe.chunk = Some(CHUNK);
    let (po_pipe, wall_pipe) = timed_train(&cfg_pipe, ds);
    assert_eq!(
        po_pipe.train.w_trace, po_oneshot.train.w_trace,
        "chunk-stability violated: pipelined w_trace diverged from one-shot at N={n}"
    );
    let (crit_pipe, hidden_pipe, ratio_train) = offline_split(&po_pipe);
    println!(
        "single job: one-shot {wall_oneshot:.3}s wall (offline {crit_oneshot:.3}s critical) | \
         pipelined {wall_pipe:.3}s wall (offline {crit_pipe:.3}s critical + {hidden_pipe:.3}s \
         hidden, overlap ratio {ratio_train:.2})"
    );

    // Cold-start baseline: a 1-job serve stream with one-shot offline —
    // mesh setup + full offline wait + training, nothing amortized.
    let t0 = Instant::now();
    let so_base = protocol::serve(&cfg_oneshot, ds, 1)
        .unwrap_or_else(|e| panic!("N={n} baseline serve: {e}"));
    let wall_base = t0.elapsed().as_secs_f64();
    assert!(so_base.failed.is_none(), "baseline serve failed: {:?}", so_base.failed);

    // 3-job pipelined stream: job j+1's factory prefetches behind job j.
    let jobs = 3usize;
    let t0 = Instant::now();
    let so = protocol::serve(&cfg_pipe, ds, jobs)
        .unwrap_or_else(|e| panic!("N={n} pipelined serve: {e}"));
    let wall_stream = t0.elapsed().as_secs_f64();
    assert!(so.failed.is_none(), "pipelined serve failed: {:?}", so.failed);
    assert_eq!(so.jobs.len(), jobs, "stream must complete all {jobs} jobs");
    // Job 0 shares seed and session 0 with the single-job runs above —
    // the serve stream must train it bit-identically.
    assert_eq!(
        so.jobs[0].train.w_trace, po_oneshot.train.w_trace,
        "serve job 0 diverged from the standalone run at N={n}"
    );

    let per_job = wall_stream / jobs as f64;
    let splits: Vec<(f64, f64, f64)> = so.jobs.iter().map(offline_split).collect();
    for (j, (crit, hidden, ratio)) in splits.iter().enumerate() {
        println!(
            "serve job {j}: offline {crit:.3}s critical + {hidden:.3}s hidden \
             (overlap ratio {ratio:.2})"
        );
    }
    // Steady state (the last job): its factory ran behind the whole
    // previous job, so nearly all its generation is hidden.
    let (_, _, steady_ratio) = splits[jobs - 1];
    println!(
        "serve stream: {jobs} jobs in {wall_stream:.3}s ({per_job:.3}s/job, {:.1} jobs/hour) \
         vs cold-start baseline {wall_base:.3}s/job; steady-state overlap ratio {steady_ratio:.2}"
    );
    assert!(
        per_job < wall_base,
        "pipelined per-job cost {per_job:.3}s must beat the cold-start \
         baseline {wall_base:.3}s at N={n}"
    );
    if n >= 9 {
        assert!(
            steady_ratio > 0.5,
            "steady-state overlap ratio {steady_ratio:.2} must exceed 0.5 at N={n} \
             (offline generation is not hiding behind the job stream)"
        );
    }

    Json::obj(vec![
        ("n", Json::num(n as f64)),
        ("k", Json::num(k as f64)),
        ("t", Json::num(1.0)),
        ("iters", Json::num(iters as f64)),
        ("chunk", Json::num(CHUNK as f64)),
        ("oneshot_wall_s", Json::num(wall_oneshot)),
        ("oneshot_offline_s", Json::num(crit_oneshot)),
        ("pipelined_wall_s", Json::num(wall_pipe)),
        ("pipelined_offline_critical_s", Json::num(crit_pipe)),
        ("pipelined_offline_hidden_s", Json::num(hidden_pipe)),
        ("overlap_ratio_single", Json::num(ratio_train)),
        ("serve_baseline_job_s", Json::num(wall_base)),
        ("serve_jobs", Json::num(jobs as f64)),
        ("serve_stream_wall_s", Json::num(wall_stream)),
        ("serve_per_job_s", Json::num(per_job)),
        ("serve_jobs_per_hour", Json::num(so.jobs_per_hour)),
        ("overlap_ratio_steady", Json::num(steady_ratio)),
    ])
}

fn main() {
    let ds = Dataset::synth(SynthSpec::smoke(), 91);
    let points = vec![
        // N=4: K=1, T=1 → recovery threshold 3·1+1 = 4 (no slack).
        run_point(&ds, 4, 1, 6, 91),
        // N=9: K=2, T=1 → recovery threshold 3·2+1 = 7.
        run_point(&ds, 9, 2, 8, 91),
    ];
    let doc = Json::obj(vec![
        ("bench", Json::str("fig_pipeline")),
        ("dataset", Json::str("smoke")),
        ("offline", Json::str("distributed")),
        ("points", Json::Arr(points)),
    ]);
    std::fs::write("BENCH_pipeline.json", doc.to_string()).expect("writing BENCH_pipeline.json");
    println!("wrote BENCH_pipeline.json");
    println!("fig_pipeline assertions passed");
}
