//! Fig. 3 (a)(b): total training time vs number of clients N for COPML
//! Case 1 / Case 2 and the [BH08] baseline, on CIFAR-10-like (9019×3073)
//! and GISETTE-like (6000×5000) shapes — 50 iterations over the 40 Mbps
//! WAN model with machine-calibrated compute, in **sequential and
//! 4-thread-parallel** kernel variants (`field::par`).
//!
//! Compute is *measured* (the real encoded-gradient kernel runs at the
//! exact per-client block shape for every N); communication bytes are
//! exact and charged through `net::wan` (see `bench::cost_model` docs and
//! EXPERIMENTS.md §Fig3 for the calibration note). Results are dumped to
//! `BENCH_fig3_training_time.json` for the perf trajectory.
//!
//! Run: `cargo bench --bench fig3_training_time`

use copml::bench::{time_it, BaselineCost, Calibration, CopmlCost};
use copml::coordinator::CaseParams;
use copml::field::{Field, MatShape, Parallelism};
use copml::mpc::OfflineMode;
use copml::net::wan::WanModel;
use copml::net::Wire;
use copml::prng::Rng;
use copml::report::{Json, Table};
use copml::runtime::{native::NativeKernel, GradKernel};

/// Measure the real per-client kernel for a (rows, d) block at the given
/// parallelism.
fn measured_kernel_s(f: Field, rows: usize, d: usize, par: Parallelism) -> f64 {
    let mut rng = Rng::seed_from_u64(42);
    let p = f.modulus();
    let x: Vec<u64> = (0..rows * d).map(|_| rng.gen_range(p)).collect();
    let w: Vec<u64> = (0..d).map(|_| rng.gen_range(p)).collect();
    let cq = vec![rng.gen_range(p), rng.gen_range(p)];
    let kernel = NativeKernel::with_parallelism(f, par);
    let shape = MatShape::new(rows, d);
    let iters = if rows * d > 4_000_000 { 3 } else { 7 };
    time_it("kernel", 1, iters, || {
        std::hint::black_box(kernel.encoded_gradient(&x, shape, &w, &cq));
    })
    .median_s
}

const PAR_THREADS: usize = 4;

fn run_dataset(
    label: &str,
    m: usize,
    d: usize,
    f: Field,
    cal: &Calibration,
    wan: &WanModel,
    json_rows: &mut Vec<Json>,
) {
    let iters = 50usize;
    let mut table = Table::new(
        &format!("Fig 3 — {label} ({m}×{d}), {iters} iterations, total time (s)"),
        &[
            "N",
            "COPML Case1",
            &format!("Case1 ({PAR_THREADS}t)"),
            "COPML Case2",
            "[BH08]",
            "[BGW88]",
            "BH08/Case1",
        ],
    );
    let mut max_speedup: f64 = 0.0;
    for n in [10usize, 20, 30, 40, 50] {
        let mut row = vec![n.to_string()];
        let mut case1_total = 0.0;
        let mut obj = vec![
            ("dataset", Json::str(label)),
            ("n", Json::num(n as f64)),
        ];
        for (ci, case) in [CaseParams::case1(n), CaseParams::case2(n)].into_iter().enumerate() {
            let rows_k = m.div_ceil(case.k);
            // REAL kernel measurement at this exact block shape.
            let comp_iter = measured_kernel_s(f, rows_k, d, Parallelism::sequential());
            let mut est = CopmlCost {
                n,
                k: case.k,
                t: case.t,
                r: 1,
                m,
                d,
                iters,
                batches: 1,
                subgroups: true,
                wire: Wire::U64,
                offline: OfflineMode::Dealer,
                trunc_bits: 25,
                stragglers: 0,
            }
            .estimate(cal, wan);
            est.comp_s = comp_iter * iters as f64;
            if ci == 0 {
                case1_total = est.total_s();
                obj.push(("copml_case1_s", Json::num(est.total_s())));
                row.push(format!("{:.0}", est.total_s()));
                // Sequential vs parallel variant of the same operating
                // point: only the measured compute changes; bytes are
                // identical (parallelism is intra-client).
                let comp_par = measured_kernel_s(f, rows_k, d, Parallelism::threads(PAR_THREADS));
                let mut est_par = est;
                est_par.comp_s = comp_par * iters as f64;
                obj.push(("copml_case1_par_s", Json::num(est_par.total_s())));
                obj.push(("kernel_speedup", Json::num(comp_iter / comp_par.max(1e-12))));
                row.push(format!("{:.0}", est_par.total_s()));
            } else {
                obj.push(("copml_case2_s", Json::num(est.total_s())));
                row.push(format!("{:.0}", est.total_s()));
            }
        }
        for bgw in [false, true] {
            let est = BaselineCost::paper(n, m, d, iters, bgw).estimate(cal, wan);
            let key = if bgw { "bgw_s" } else { "bh08_s" };
            obj.push((key, Json::num(est.total_s())));
            row.push(format!("{:.0}", est.total_s()));
        }
        let bh08 = BaselineCost::paper(n, m, d, iters, false).estimate(cal, wan);
        let speedup = bh08.total_s() / case1_total;
        max_speedup = max_speedup.max(speedup);
        row.push(format!("{speedup:.1}×"));
        table.row(&row);
        json_rows.push(Json::obj(obj));
    }
    table.print();
    println!("max speedup vs [BH08]: {max_speedup:.1}× (paper: 8.6× CIFAR-10, 16.4× GISETTE)\n");
}

fn main() {
    println!("calibrating primitives on this machine …");
    let cal = Calibration::measure(Field::paper_cifar());
    let wan = WanModel::paper();
    let mut json_rows: Vec<Json> = Vec::new();
    run_dataset("CIFAR-10-like", 9019, 3073, Field::paper_cifar(), &cal, &wan, &mut json_rows);
    run_dataset("GISETTE-like", 6000, 5000, Field::paper_gisette(), &cal, &wan, &mut json_rows);

    // Shape assertions (the reproduction claims):
    let bh08_n10 = BaselineCost::paper(10, 9019, 3073, 50, false).estimate(&cal, &wan);
    let bh08_n50 = BaselineCost::paper(50, 9019, 3073, 50, false).estimate(&cal, &wan);
    assert!(
        bh08_n50.total_s() > 2.0 * bh08_n10.total_s(),
        "baseline must grow with N"
    );
    let c1 = CaseParams::case1(50);
    let copml_50 = CopmlCost {
        n: 50,
        k: c1.k,
        t: c1.t,
        r: 1,
        m: 9019,
        d: 3073,
        iters: 50,
        batches: 1,
        subgroups: true,
        wire: Wire::U64,
        offline: OfflineMode::Dealer,
        trunc_bits: 25,
        stragglers: 0,
    };
    let copml_n50 = copml_50.estimate(&cal, &wan);
    assert!(
        bh08_n50.total_s() / copml_n50.total_s() > 8.0,
        "COPML must beat [BH08] by at least the paper's factor at N=50"
    );
    // Wire-packing ablation (p < 2^32): u32 frames halve COPML's comm
    // bytes — the comm term must shrink, never the compute terms.
    let packed = CopmlCost { wire: Wire::U32, ..copml_50 }.estimate(&cal, &wan);
    assert!(packed.comm_s < copml_n50.comm_s, "u32 packing must cut comm time");
    println!(
        "wire packing at N=50 Case 1: comm {:.0}s (u64) → {:.0}s (u32), total {:.0}s → {:.0}s",
        copml_n50.comm_s,
        packed.comm_s,
        copml_n50.total_s(),
        packed.total_s()
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("fig3_training_time")),
        ("par_threads", Json::num(PAR_THREADS as f64)),
        ("results", Json::Arr(json_rows)),
    ]);
    std::fs::write("BENCH_fig3_training_time.json", doc.to_string())
        .expect("writing BENCH_fig3_training_time.json");
    println!("wrote BENCH_fig3_training_time.json");
    println!("fig3 shape assertions passed");
}
