//! `fig_batch`: mini-batch SGD's per-iteration speedup and accuracy — the
//! `--batches` workload axis, measured on REAL full-protocol runs.
//!
//! Sweeps `B ∈ {1, 4, 16}` on a CIFAR-like-but-CI-sized task. For each B:
//!
//! 1. a full-protocol Hub run (N client threads, live quorum gathers) —
//!    its `w_trace` is asserted **bit-identical** to the central
//!    recursion (the batching analogue of the headline equivalence);
//! 2. the per-iteration *compute* phase from the live ledgers must shrink
//!    ~linearly in `1/B` (each round's kernel touches `rows_b/K × d`
//!    cells instead of `rows/K × d`) — the ISSUE's speed claim; the
//!    modeled cost (`bench::cost_model`, `batches` column) must show the
//!    same `1/B` law exactly;
//! 3. final test accuracy must stay within the fig4 tolerance (±4 points)
//!    of the full-batch run — mini-batch trades per-step cost for
//!    gradient noise, not for model quality.
//!
//! Results are dumped to `BENCH_batch.json` (CI-uploaded artifact).
//!
//! Run: `cargo bench --bench fig_batch`

use copml::bench::{Calibration, CopmlCost};
use copml::coordinator::{algo, protocol, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::mpc::OfflineMode;
use copml::net::wan::WanModel;
use copml::net::Wire;
use copml::report::Json;

/// Mean seconds per iteration of one ledger phase, averaged over every
/// client (N·iters samples — robust to scheduler noise on a loaded
/// runner).
fn mean_phase_per_iter(ledgers: &[protocol::ClientLedger], phase: usize, iters: usize) -> f64 {
    let total: f64 = ledgers.iter().map(|l| l.seconds[phase]).sum();
    total / (ledgers.len() * iters) as f64
}

fn main() {
    // CIFAR-like class-conditional structure at a CI-friendly size, rows
    // heavy enough that the per-iteration kernel dominates timer noise.
    let spec = SynthSpec {
        m_train: 4800,
        m_test: 1500,
        d: 128,
        rank: 6,
        confound: 0.05,
        signal_features: 60,
        signal_amp: 0.03,
        noise: 0.25,
        name: "batch-bench",
    };
    let ds = Dataset::synth(spec, 88);
    let (n, k, t, iters) = (10usize, 2usize, 1usize, 64usize);
    let sweep = [1usize, 4, 16];
    println!(
        "fig_batch: {} ({}×{}), N={n} K={k} T={t}, {iters} iterations, B ∈ {sweep:?}",
        ds.name, ds.m, ds.d
    );

    println!("calibrating primitives for the modeled column …");
    let base_cfg = CopmlConfig::for_dataset(&ds, n, CaseParams::explicit(k, t), 88);
    let cal = Calibration::measure(base_cfg.plan.field);
    let wan = WanModel::paper();

    let mut per_iter_compute = Vec::new();
    let mut per_iter_online = Vec::new();
    let mut modeled_comp = Vec::new();
    let mut accuracy = Vec::new();
    let mut json_rows: Vec<Json> = Vec::new();
    for &b in &sweep {
        let mut cfg = base_cfg.clone();
        cfg.iters = iters;
        cfg.batches = b;
        let reference = algo::train(&cfg, &ds).expect("algo reference");
        let run = protocol::train(&cfg, &ds).expect("full-protocol run");
        assert_eq!(
            run.train.w_trace, reference.w_trace,
            "B={b}: full protocol must match the central recursion bit for bit"
        );
        let compute_s = mean_phase_per_iter(&run.ledgers, 5, iters);
        let online_s: f64 = (4..8).map(|p| mean_phase_per_iter(&run.ledgers, p, iters)).sum();
        let est = CopmlCost {
            n,
            k,
            t,
            r: 1,
            m: ds.m,
            d: ds.d,
            iters,
            batches: b,
            subgroups: true,
            wire: Wire::U64,
            offline: OfflineMode::Dealer,
            trunc_bits: cfg.plan.k2 + cfg.plan.kappa,
            stragglers: 0,
        }
        .estimate(&cal, &wan);
        let acc = *run.train.test_accuracy.last().unwrap();
        println!(
            "B={b:>2}: compute {:.3} ms/iter · online {:.3} ms/iter · modeled comp {:.3} ms/iter · test-acc {acc:.4}",
            compute_s * 1e3,
            online_s * 1e3,
            est.comp_s / iters as f64 * 1e3
        );
        json_rows.push(Json::obj(vec![
            ("batches", Json::num(b as f64)),
            ("measured_compute_per_iter_s", Json::num(compute_s)),
            ("measured_online_per_iter_s", Json::num(online_s)),
            ("modeled_comp_per_iter_s", Json::num(est.comp_s / iters as f64)),
            ("modeled_total_s", Json::num(est.total_s())),
            ("final_test_accuracy", Json::num(acc)),
        ]));
        per_iter_compute.push(compute_s);
        per_iter_online.push(online_s);
        modeled_comp.push(est.comp_s / iters as f64);
        accuracy.push(acc);
    }

    // --- the claims -------------------------------------------------------
    // (1) modeled per-iteration compute follows the 1/B law exactly.
    for (i, &b) in sweep.iter().enumerate().skip(1) {
        let ratio = modeled_comp[0] / modeled_comp[i];
        assert!(
            (ratio - b as f64).abs() / b as f64 < 0.15,
            "modeled compute must scale ~1/B: B={b} ratio {ratio:.2}"
        );
    }
    // (2) measured per-iteration compute shrinks ~linearly in 1/B (wide
    // envelopes: tiny absolute times on a shared runner).
    assert!(
        per_iter_compute[1] < 0.75 * per_iter_compute[0],
        "B=4 compute {:.4} ms not < 0.75× full-batch {:.4} ms",
        per_iter_compute[1] * 1e3,
        per_iter_compute[0] * 1e3
    );
    assert!(
        per_iter_compute[2] < 0.45 * per_iter_compute[0],
        "B=16 compute {:.4} ms not < 0.45× full-batch {:.4} ms",
        per_iter_compute[2] * 1e3,
        per_iter_compute[0] * 1e3
    );
    // …and the whole online iteration gets faster, not just the kernel.
    assert!(
        per_iter_online[2] < per_iter_online[0],
        "B=16 online {:.4} ms/iter not below full-batch {:.4} ms/iter",
        per_iter_online[2] * 1e3,
        per_iter_online[0] * 1e3
    );
    // (3) accuracy parity within the fig4 tolerance.
    assert!(accuracy[0] > 0.7, "full-batch failed to converge: acc {}", accuracy[0]);
    for (i, &b) in sweep.iter().enumerate().skip(1) {
        assert!(
            (accuracy[i] - accuracy[0]).abs() < 0.04,
            "B={b}: accuracy {:.4} strays past the fig4 tolerance from full-batch {:.4}",
            accuracy[i],
            accuracy[0]
        );
    }

    let doc = Json::obj(vec![
        ("bench", Json::str("fig_batch")),
        ("dataset", Json::str(&ds.name)),
        ("m", Json::num(ds.m as f64)),
        ("d", Json::num(ds.d as f64)),
        ("n", Json::num(n as f64)),
        ("k", Json::num(k as f64)),
        ("t", Json::num(t as f64)),
        ("iters", Json::num(iters as f64)),
        ("results", Json::Arr(json_rows)),
    ]);
    std::fs::write("BENCH_batch.json", doc.to_string()).expect("writing BENCH_batch.json");
    println!("wrote BENCH_batch.json");
    println!("fig_batch assertions passed");
}
