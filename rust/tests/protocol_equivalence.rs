//! The repository's headline integrity test (DESIGN.md §6): the full
//! threaded COPML protocol, the algorithmic-fidelity central trainer, and
//! both conventional-MPC baselines all compute **bit-identical** model
//! iterates for the same seed — the protocols differ in *cost*, never in
//! *what they compute*. This is what makes the paper-scale accuracy runs
//! (Fig. 4, via algo mode) and timing runs (Fig. 3, via the cost model)
//! faithful to the full protocol.

use copml::coordinator::baseline::{BaselineConfig, MpcFlavor};
use copml::coordinator::{algo, baseline, protocol, CaseParams, CopmlConfig, FaultPlan};
use copml::data::{Dataset, SynthSpec};
use copml::field::KernelTier;
use copml::mpc::OfflineMode;
use copml::net::{Runtime, Wire};

fn tiny_cfg(n: usize, k: usize, t: usize, iters: usize, seed: u64, ds: &Dataset) -> CopmlConfig {
    let mut cfg = CopmlConfig::for_dataset(ds, n, CaseParams::explicit(k, t), seed);
    cfg.iters = iters;
    cfg
}

#[test]
fn full_protocol_equals_algo_across_configs() {
    let ds = Dataset::synth(SynthSpec::tiny(), 101);
    for (n, k, t) in [(4usize, 1usize, 1usize), (7, 2, 1), (10, 2, 2), (13, 3, 2)] {
        let cfg = tiny_cfg(n, k, t, 5, 101, &ds);
        let a = algo::train(&cfg, &ds).unwrap();
        let p = protocol::train(&cfg, &ds).unwrap();
        assert_eq!(a.w_trace, p.train.w_trace, "n={n} k={k} t={t}");
    }
}

#[test]
fn subgroup_optimization_does_not_change_results() {
    let ds = Dataset::synth(SynthSpec::tiny(), 102);
    let mut cfg = tiny_cfg(11, 2, 2, 4, 102, &ds);
    cfg.subgroups = true;
    let with = protocol::train(&cfg, &ds).unwrap();
    cfg.subgroups = false;
    let without = protocol::train(&cfg, &ds).unwrap();
    assert_eq!(with.train.w_trace, without.train.w_trace);
}

#[test]
fn baselines_equal_copml_trajectory() {
    let ds = Dataset::synth(SynthSpec::tiny(), 103);
    let cfg = tiny_cfg(7, 2, 1, 4, 103, &ds);
    let reference = algo::train(&cfg, &ds).unwrap();
    // Baselines run at K=1 internally but must land on the same iterates:
    // the decoded gradient is K-independent.
    for flavor in [MpcFlavor::Bgw, MpcFlavor::Bh08] {
        let bcfg = BaselineConfig::matching(&cfg, flavor);
        let out = baseline::train(&bcfg, &ds).unwrap();
        assert_eq!(out.train.w_trace, reference.w_trace, "{flavor:?}");
    }
}

#[test]
fn smoke_scale_equivalence_with_case_params() {
    // Larger config: smoke dataset (400×21), N=10 Case 1 (K=3, T=1).
    let ds = Dataset::synth(SynthSpec::smoke(), 104);
    let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 104);
    cfg.iters = 6;
    let a = algo::train(&cfg, &ds).unwrap();
    let p = protocol::train(&cfg, &ds).unwrap();
    assert_eq!(a.w_trace, p.train.w_trace);
    // and the trained model actually learns
    assert!(p.train.test_accuracy.last().unwrap() > &0.7);
}

#[test]
fn tcp_loopback_bit_identical_on_both_wire_formats() {
    // Acceptance: the full protocol over REAL sockets (every client its
    // own TCP endpoint on 127.0.0.1) computes a w_trace bit-identical to
    // the threaded Hub run and to algo mode, under both wire formats —
    // and u32 packing halves every per-phase ledger byte count exactly.
    let ds = Dataset::synth(SynthSpec::tiny(), 106);
    let cfg = tiny_cfg(7, 2, 1, 3, 106, &ds);
    let algo_out = algo::train(&cfg, &ds).unwrap();
    let hub = protocol::train(&cfg, &ds).unwrap();
    assert_eq!(algo_out.w_trace, hub.train.w_trace);
    let mut ledgers = Vec::new();
    for wire in [Wire::U64, Wire::U32] {
        let mut c = cfg.clone();
        c.wire = wire;
        let tcp = protocol::train_tcp_loopback(&c, &ds).unwrap();
        assert_eq!(tcp.train.w_trace, hub.train.w_trace, "wire={wire}");
        ledgers.push(tcp.ledgers);
    }
    for (i, (l64, l32)) in ledgers[0].iter().zip(&ledgers[1]).enumerate() {
        for p in 0..l64.bytes.len() {
            assert_eq!(
                l64.bytes[p],
                2 * l32.bytes[p],
                "client {i} phase {p}: u32 packing must halve payload bytes"
            );
        }
    }
    // And the u64 TCP ledger matches the Hub ledger byte for byte: the
    // transports charge identical payload accounting.
    for (lt, lh) in ledgers[0].iter().zip(&hub.ledgers) {
        assert_eq!(lt.bytes, lh.bytes);
    }
}

#[test]
fn offline_dealer_mode_is_default_and_stays_bit_identical() {
    // The mode switch must not move the default trajectory: an explicit
    // `OfflineMode::Dealer` run — Hub and TCP — matches the seed's algo
    // trace bit for bit, with a zero-byte offline ledger column.
    let ds = Dataset::synth(SynthSpec::tiny(), 107);
    let mut cfg = tiny_cfg(7, 2, 1, 3, 107, &ds);
    assert_eq!(cfg.offline, OfflineMode::Dealer, "dealer must remain the default");
    let reference = algo::train(&cfg, &ds).unwrap();
    cfg.offline = OfflineMode::Dealer; // explicit, not just the default
    let hub = protocol::train(&cfg, &ds).unwrap();
    assert_eq!(hub.train.w_trace, reference.w_trace, "Hub dealer trace moved");
    let tcp = protocol::train_tcp_loopback(&cfg, &ds).unwrap();
    assert_eq!(tcp.train.w_trace, reference.w_trace, "TCP dealer trace moved");
    for (i, l) in hub.ledgers.iter().enumerate() {
        assert_eq!(l.bytes[0], 0, "client {i}: dealer offline phase must be free");
    }
}

#[test]
fn distributed_offline_hub_tcp_bit_identical_and_dealer_free() {
    // The dealer-free phase is deterministic per seed, so Hub and real
    // TCP sockets must produce the same trajectory — and its traffic must
    // appear in the offline ledger column of every client.
    let ds = Dataset::synth(SynthSpec::tiny(), 108);
    let mut cfg = tiny_cfg(4, 1, 1, 2, 108, &ds);
    cfg.offline = OfflineMode::Distributed;
    let hub = protocol::train(&cfg, &ds).unwrap();
    let tcp = protocol::train_tcp_loopback(&cfg, &ds).unwrap();
    assert_eq!(
        hub.train.w_trace, tcp.train.w_trace,
        "distributed offline must be transport-invariant"
    );
    for (i, (lh, lt)) in hub.ledgers.iter().zip(&tcp.ledgers).enumerate() {
        assert!(lh.bytes[0] > 0, "client {i}: no offline traffic recorded");
        assert_eq!(lh.bytes[0], lt.bytes[0], "client {i}: Hub/TCP offline bytes differ");
    }
    // Different truncation randomness than the dealer's → different
    // (equally valid) trajectory; and the central trainer must refuse to
    // pretend it can replay it.
    let mut dealer_cfg = cfg.clone();
    dealer_cfg.offline = OfflineMode::Dealer;
    let dealer = protocol::train(&dealer_cfg, &ds).unwrap();
    assert_ne!(hub.train.w_trace, dealer.train.w_trace);
    let err = algo::train(&cfg, &ds).unwrap_err();
    assert!(err.contains("distributed"), "unexpected algo-mode error: {err}");
}

#[test]
fn distributed_offline_accuracy_within_fig4_tolerance() {
    // Fig. 4's tolerance (±4 accuracy points) applied to the mode switch:
    // the dealer-free run converges to the same quality on the tiny
    // geometry class — only the rounding randomness differs.
    let ds = Dataset::synth(SynthSpec::smoke(), 109);
    let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 109);
    cfg.iters = 25;
    let dealer = protocol::train(&cfg, &ds).unwrap();
    cfg.offline = OfflineMode::Distributed;
    let dist = protocol::train(&cfg, &ds).unwrap();
    let a = *dealer.train.test_accuracy.last().unwrap();
    let b = *dist.train.test_accuracy.last().unwrap();
    assert!((a - b).abs() < 0.04, "dealer acc {a} vs distributed acc {b}");
    assert!(b > 0.8, "distributed mode failed to converge (acc {b})");
}

#[test]
fn minibatch_bit_identical_algo_vs_full_on_hub_and_tcp_both_wires() {
    // Acceptance: `--batches B` is bit-identical between the central
    // recursion and the full MPC protocol — Hub and real TCP sockets,
    // both wire formats — for more than one B.
    let ds = Dataset::synth(SynthSpec::tiny(), 110);
    for b in [2usize, 3] {
        let mut cfg = tiny_cfg(7, 2, 1, 6, 110, &ds);
        cfg.batches = b;
        let reference = algo::train(&cfg, &ds).unwrap();
        for wire in [Wire::U64, Wire::U32] {
            let mut c = cfg.clone();
            c.wire = wire;
            let hub = protocol::train(&c, &ds).unwrap();
            assert_eq!(hub.train.w_trace, reference.w_trace, "hub B={b} {wire} wire");
            let tcp = protocol::train_tcp_loopback(&c, &ds).unwrap();
            assert_eq!(tcp.train.w_trace, reference.w_trace, "tcp B={b} {wire} wire");
        }
    }
}

#[test]
fn minibatch_distributed_offline_transport_invariant() {
    // Acceptance (both offline modes): the dealer-free offline phase under
    // batching — Hub and TCP must agree bit for bit; the dealer trace (a
    // different, equally valid truncation-randomness stream) differs.
    let ds = Dataset::synth(SynthSpec::tiny(), 111);
    let mut cfg = tiny_cfg(4, 1, 1, 4, 111, &ds);
    cfg.batches = 2;
    cfg.offline = OfflineMode::Distributed;
    let hub = protocol::train(&cfg, &ds).unwrap();
    let tcp = protocol::train_tcp_loopback(&cfg, &ds).unwrap();
    assert_eq!(
        hub.train.w_trace, tcp.train.w_trace,
        "mini-batch distributed offline must be transport-invariant"
    );
    for (i, l) in hub.ledgers.iter().enumerate() {
        assert!(l.bytes[0] > 0, "client {i}: no offline traffic recorded");
    }
    let mut dealer_cfg = cfg.clone();
    dealer_cfg.offline = OfflineMode::Dealer;
    let dealer = protocol::train(&dealer_cfg, &ds).unwrap();
    assert_ne!(hub.train.w_trace, dealer.train.w_trace);
}

#[test]
fn batches_one_reproduces_the_full_batch_trace() {
    // Acceptance: B = 1 is byte-for-byte today's full-batch pipeline —
    // identity permutation, one padded range, the same offline demand, the
    // same η factor — so an explicit `--batches 1` run must match the
    // default-config run exactly, in algo mode and the full protocol.
    let ds = Dataset::synth(SynthSpec::tiny(), 112);
    let cfg = tiny_cfg(7, 2, 1, 4, 112, &ds); // batches defaults to 1
    assert_eq!(cfg.batches, 1, "full batch must remain the default");
    let mut explicit = cfg.clone();
    explicit.batches = 1;
    let a = algo::train(&cfg, &ds).unwrap();
    let b = algo::train(&explicit, &ds).unwrap();
    assert_eq!(a.w_trace, b.w_trace);
    let p = protocol::train(&explicit, &ds).unwrap();
    assert_eq!(p.train.w_trace, a.w_trace);
}

#[test]
fn minibatch_baselines_equal_copml_trajectory() {
    // The Table-1/Fig-3 fairness invariant under batching: the K = 1
    // baselines follow the identical batch schedule (the BatchPlan
    // real-row partition is K-independent), so their iterates coincide
    // with COPML's for every flavour.
    let ds = Dataset::synth(SynthSpec::tiny(), 113);
    let mut cfg = tiny_cfg(7, 2, 1, 6, 113, &ds);
    cfg.batches = 3;
    let reference = algo::train(&cfg, &ds).unwrap();
    for flavor in [MpcFlavor::Bgw, MpcFlavor::Bh08] {
        let bcfg = BaselineConfig::matching(&cfg, flavor);
        let out = baseline::train(&bcfg, &ds).unwrap();
        assert_eq!(out.train.w_trace, reference.w_trace, "{flavor:?} B=3");
    }
}

#[test]
fn event_runtime_bit_identical_across_transports_wires_and_batches() {
    // ISSUE-6 acceptance: `--runtime event` (the poll-reactor party
    // runtime) is a transport-layer swap ONLY — for every combination of
    // transport (Hub, TCP loopback), wire format, and batch count, the
    // model trajectory is bit-identical to the threaded reference and to
    // the central recursion. Both runtimes drive the same per-round state
    // machine; only the socket-draining strategy differs.
    let ds = Dataset::synth(SynthSpec::tiny(), 114);
    for b in [1usize, 2] {
        let mut cfg = tiny_cfg(7, 2, 1, 4, 114, &ds);
        cfg.batches = b;
        let reference = algo::train(&cfg, &ds).unwrap();
        let threaded_hub = protocol::train(&cfg, &ds).unwrap();
        assert_eq!(threaded_hub.train.w_trace, reference.w_trace, "threaded hub B={b}");
        for wire in [Wire::U64, Wire::U32] {
            let mut c = cfg.clone();
            c.wire = wire;
            c.runtime = Runtime::Event;
            let hub = protocol::train(&c, &ds).unwrap();
            assert_eq!(hub.train.w_trace, reference.w_trace, "event hub B={b} {wire} wire");
            let tcp = protocol::train_tcp_loopback(&c, &ds).unwrap();
            assert_eq!(tcp.train.w_trace, reference.w_trace, "event tcp B={b} {wire} wire");
            // The reactor charges the same payload accounting as the
            // reader threads: byte ledgers must match the Hub run's.
            if wire == Wire::U64 {
                for (lt, lh) in tcp.ledgers.iter().zip(&threaded_hub.ledgers) {
                    assert_eq!(lt.bytes, lh.bytes, "event tcp ledger drifted (B={b})");
                }
            }
        }
    }
}

#[test]
fn event_runtime_distributed_offline_bit_identical() {
    // The dealer-free offline phase (DN07 extraction) has its own message
    // patterns (pairwise PRSS traffic, king openings); the event runtime
    // must replay them bit for bit on both transports.
    let ds = Dataset::synth(SynthSpec::tiny(), 115);
    let mut cfg = tiny_cfg(4, 1, 1, 2, 115, &ds);
    cfg.offline = OfflineMode::Distributed;
    let threaded_hub = protocol::train(&cfg, &ds).unwrap();
    let mut c = cfg.clone();
    c.runtime = Runtime::Event;
    let event_hub = protocol::train(&c, &ds).unwrap();
    assert_eq!(event_hub.train.w_trace, threaded_hub.train.w_trace, "event hub");
    let event_tcp = protocol::train_tcp_loopback(&c, &ds).unwrap();
    assert_eq!(event_tcp.train.w_trace, threaded_hub.train.w_trace, "event tcp");
    for (i, (le, lh)) in event_tcp.ledgers.iter().zip(&threaded_hub.ledgers).enumerate() {
        assert!(lh.bytes[0] > 0, "client {i}: no offline traffic recorded");
        assert_eq!(le.bytes[0], lh.bytes[0], "client {i}: event offline bytes drifted");
    }
}

#[test]
fn event_runtime_fault_injection_matches_threaded() {
    // Faults under the event runtime: a killed party's EOF now arrives
    // via the reactor instead of a dying reader thread, and a straggler's
    // late frames queue behind the poll loop — neither may move the
    // trajectory or change who gets excluded for dying. N=10, K=2, T=1 →
    // need 7, slack 3: enough to absorb one sustained straggler (party 8,
    // delayed every compute phase) plus one crash (party 9 at iteration
    // 1, excluded after 2 consecutive misses).
    let ds = Dataset::synth(SynthSpec::tiny(), 116);
    let mut cfg = tiny_cfg(10, 2, 1, 4, 116, &ds);
    cfg.faults = FaultPlan { delays: vec![(8, 40)], kills: vec![(9, 1)] };
    cfg.max_lag = Some(2);
    let need = cfg.recovery_threshold();
    assert!(cfg.n - need >= 2, "fixture needs quorum slack ≥ 2");
    let reference = algo::train(&cfg, &ds).unwrap();
    for runtime in [Runtime::Threaded, Runtime::Event] {
        let mut c = cfg.clone();
        c.runtime = runtime;
        let out = protocol::train_tcp_loopback(&c, &ds)
            .unwrap_or_else(|e| panic!("{runtime} faulted run failed: {e}"));
        assert_eq!(
            out.train.w_trace, reference.w_trace,
            "{runtime}: faults may cost time, never accuracy"
        );
        // The crash is deterministic (party 9 misses every quorum from
        // iteration 1 on), so exclusion must fire under either runtime.
        // The straggler's exclusion is timing-dependent — not asserted.
        assert!(
            out.ledgers[0].excluded.contains(&9),
            "{runtime}: killed party 9 not excluded: {:?}",
            out.ledgers[0].excluded
        );
        for (i, q) in out.ledgers[0].quorums.iter().enumerate() {
            assert!(q.len() >= need, "{runtime} round {i}: quorum {} < need {need}", q.len());
        }
    }
}

#[test]
fn mont_kernel_bit_identical_across_runtime_transport_wire() {
    // ISSUE-8 acceptance: `--kernel mont` is a *kernel-tier* swap only —
    // Montgomery form changes how products are reduced, never which
    // canonical residues come out. For the central recursion, the Hub
    // protocol, and real TCP sockets, under both party runtimes and both
    // wire formats, the Montgomery trajectory must match the Barrett
    // reference bit for bit. (Barrett stays the default and the oracle.)
    let ds = Dataset::synth(SynthSpec::tiny(), 117);
    let cfg = tiny_cfg(7, 2, 1, 4, 117, &ds);
    assert_eq!(cfg.kernel, KernelTier::Barrett, "barrett must remain the default");
    let reference = algo::train(&cfg, &ds).unwrap();

    let mut mont = cfg.clone();
    mont.kernel = KernelTier::Mont;
    let mont_algo = algo::train(&mont, &ds).unwrap();
    assert_eq!(mont_algo.w_trace, reference.w_trace, "algo mode");

    for runtime in [Runtime::Threaded, Runtime::Event] {
        for wire in [Wire::U64, Wire::U32] {
            let mut c = mont.clone();
            c.runtime = runtime;
            c.wire = wire;
            let hub = protocol::train(&c, &ds).unwrap();
            assert_eq!(hub.train.w_trace, reference.w_trace, "hub {runtime} {wire} wire");
            let tcp = protocol::train_tcp_loopback(&c, &ds).unwrap();
            assert_eq!(tcp.train.w_trace, reference.w_trace, "tcp {runtime} {wire} wire");
            // A kernel tier moves compute cost only: the byte ledgers must
            // match the Barrett wire accounting exactly.
            if wire == Wire::U64 {
                let mut b = c.clone();
                b.kernel = KernelTier::Barrett;
                let barrett_hub = protocol::train(&b, &ds).unwrap();
                for (lm, lb) in hub.ledgers.iter().zip(&barrett_hub.ledgers) {
                    assert_eq!(lm.bytes, lb.bytes, "mont ledger drifted ({runtime})");
                }
            }
        }
    }
}

#[test]
fn mont_kernel_bit_identical_for_baselines_and_batches() {
    // The tier threads through the conventional-MPC baselines and the
    // mini-batch pipeline too — same iterates everywhere.
    let ds = Dataset::synth(SynthSpec::tiny(), 118);
    let mut cfg = tiny_cfg(7, 2, 1, 6, 118, &ds);
    cfg.batches = 3;
    let reference = algo::train(&cfg, &ds).unwrap();
    let mut mont = cfg.clone();
    mont.kernel = KernelTier::Mont;
    assert_eq!(algo::train(&mont, &ds).unwrap().w_trace, reference.w_trace, "B=3 algo");
    assert_eq!(
        protocol::train(&mont, &ds).unwrap().train.w_trace,
        reference.w_trace,
        "B=3 hub"
    );
    for flavor in [MpcFlavor::Bgw, MpcFlavor::Bh08] {
        let bcfg = BaselineConfig::matching(&mont, flavor);
        assert_eq!(bcfg.kernel, KernelTier::Mont, "matching() must carry the tier");
        let out = baseline::train(&bcfg, &ds).unwrap();
        assert_eq!(out.train.w_trace, reference.w_trace, "{flavor:?} mont B=3");
    }
}

#[test]
fn pipelined_factory_bit_identical_across_runtime_transport_wire() {
    // ISSUE-9 acceptance: `--chunk` moves WHEN the offline pools are
    // generated (a background producer, chunk by chunk), never WHAT lands
    // in them. For every runtime × transport × wire combination the
    // pipelined distributed-offline run must match the one-shot reference
    // bit for bit — and the one-shot ledger must keep the legacy
    // accounting (zero hidden seconds).
    let ds = Dataset::synth(SynthSpec::tiny(), 119);
    let mut cfg = tiny_cfg(4, 1, 1, 3, 119, &ds);
    cfg.offline = OfflineMode::Distributed;
    let reference = protocol::train(&cfg, &ds).unwrap();
    for l in &reference.ledgers {
        assert_eq!(l.offline_hidden_s, 0.0, "one-shot runs must hide nothing");
    }
    for runtime in [Runtime::Threaded, Runtime::Event] {
        for wire in [Wire::U64, Wire::U32] {
            let mut c = cfg.clone();
            c.chunk = Some(16);
            c.runtime = runtime;
            c.wire = wire;
            let hub = protocol::train(&c, &ds).unwrap();
            assert_eq!(hub.train.w_trace, reference.train.w_trace, "hub {runtime} {wire} wire");
            let tcp = protocol::train_tcp_loopback(&c, &ds).unwrap();
            assert_eq!(tcp.train.w_trace, reference.train.w_trace, "tcp {runtime} {wire} wire");
        }
    }
}

#[test]
fn different_seeds_diverge() {
    // Sanity: the equality above is not vacuous (trajectories depend on
    // the truncation randomness).
    let ds = Dataset::synth(SynthSpec::tiny(), 105);
    let a = algo::train(&tiny_cfg(7, 2, 1, 4, 1, &ds), &ds).unwrap();
    let b = algo::train(&tiny_cfg(7, 2, 1, 4, 2, &ds), &ds).unwrap();
    assert_ne!(a.w_trace, b.w_trace);
}
