//! The repository's headline integrity test (DESIGN.md §6): the full
//! threaded COPML protocol, the algorithmic-fidelity central trainer, and
//! both conventional-MPC baselines all compute **bit-identical** model
//! iterates for the same seed — the protocols differ in *cost*, never in
//! *what they compute*. This is what makes the paper-scale accuracy runs
//! (Fig. 4, via algo mode) and timing runs (Fig. 3, via the cost model)
//! faithful to the full protocol.

use copml::coordinator::baseline::{BaselineConfig, MpcFlavor};
use copml::coordinator::{algo, baseline, protocol, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};

fn tiny_cfg(n: usize, k: usize, t: usize, iters: usize, seed: u64, ds: &Dataset) -> CopmlConfig {
    let mut cfg = CopmlConfig::for_dataset(ds, n, CaseParams::explicit(k, t), seed);
    cfg.iters = iters;
    cfg
}

#[test]
fn full_protocol_equals_algo_across_configs() {
    let ds = Dataset::synth(SynthSpec::tiny(), 101);
    for (n, k, t) in [(4usize, 1usize, 1usize), (7, 2, 1), (10, 2, 2), (13, 3, 2)] {
        let cfg = tiny_cfg(n, k, t, 5, 101, &ds);
        let a = algo::train(&cfg, &ds).unwrap();
        let p = protocol::train(&cfg, &ds).unwrap();
        assert_eq!(a.w_trace, p.train.w_trace, "n={n} k={k} t={t}");
    }
}

#[test]
fn subgroup_optimization_does_not_change_results() {
    let ds = Dataset::synth(SynthSpec::tiny(), 102);
    let mut cfg = tiny_cfg(11, 2, 2, 4, 102, &ds);
    cfg.subgroups = true;
    let with = protocol::train(&cfg, &ds).unwrap();
    cfg.subgroups = false;
    let without = protocol::train(&cfg, &ds).unwrap();
    assert_eq!(with.train.w_trace, without.train.w_trace);
}

#[test]
fn baselines_equal_copml_trajectory() {
    let ds = Dataset::synth(SynthSpec::tiny(), 103);
    let cfg = tiny_cfg(7, 2, 1, 4, 103, &ds);
    let reference = algo::train(&cfg, &ds).unwrap();
    // Baselines run at K=1 internally but must land on the same iterates:
    // the decoded gradient is K-independent.
    for flavor in [MpcFlavor::Bgw, MpcFlavor::Bh08] {
        let bcfg = BaselineConfig::matching(&cfg, flavor);
        let out = baseline::train(&bcfg, &ds).unwrap();
        assert_eq!(out.train.w_trace, reference.w_trace, "{flavor:?}");
    }
}

#[test]
fn smoke_scale_equivalence_with_case_params() {
    // Larger config: smoke dataset (400×21), N=10 Case 1 (K=3, T=1).
    let ds = Dataset::synth(SynthSpec::smoke(), 104);
    let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 104);
    cfg.iters = 6;
    let a = algo::train(&cfg, &ds).unwrap();
    let p = protocol::train(&cfg, &ds).unwrap();
    assert_eq!(a.w_trace, p.train.w_trace);
    // and the trained model actually learns
    assert!(p.train.test_accuracy.last().unwrap() > &0.7);
}

#[test]
fn different_seeds_diverge() {
    // Sanity: the equality above is not vacuous (trajectories depend on
    // the truncation randomness).
    let ds = Dataset::synth(SynthSpec::tiny(), 105);
    let a = algo::train(&tiny_cfg(7, 2, 1, 4, 1, &ds), &ds).unwrap();
    let b = algo::train(&tiny_cfg(7, 2, 1, 4, 2, &ds), &ds).unwrap();
    assert_ne!(a.w_trace, b.w_trace);
}
