//! End-to-end training behaviour of the full protocol: learning actually
//! happens, matches the plaintext reference closely (the Fig. 4 claim at
//! test scale), and failure modes surface as errors.

use copml::coordinator::{algo, protocol, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::ml;

#[test]
fn full_protocol_learns_smoke_dataset() {
    let ds = Dataset::synth(SynthSpec::smoke(), 201);
    let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case2(10), 201);
    cfg.iters = 25;
    let out = protocol::train(&cfg, &ds).unwrap();
    let acc = *out.train.test_accuracy.last().unwrap();
    assert!(acc > 0.82, "full-protocol test accuracy {acc}");
    assert!(out.train.loss.last().unwrap() < &out.train.loss[0]);
}

#[test]
fn secure_vs_plaintext_gap_small() {
    // Fig. 4's claim at test scale: COPML ≈ conventional LR.
    let ds = Dataset::synth(SynthSpec::smoke(), 202);
    let cfg = CopmlConfig::for_dataset(&ds, 13, CaseParams::case1(13), 202);
    let secure = algo::train(&cfg, &ds).unwrap();
    let plain = ml::train_logreg(
        &ds,
        &ml::LogRegOptions { iters: cfg.iters, eta: cfg.eta, ..Default::default() },
    );
    let gap =
        (plain.test_accuracy.last().unwrap() - secure.test_accuracy.last().unwrap()).abs();
    assert!(gap < 0.06, "gap {gap}");
}

#[test]
fn symmetric_rounding_keeps_fig4_accuracy() {
    // ISSUE-8 satellite: the quantizer's round-half-away fix (negative
    // half-ties now round away from zero, matching the paper's symmetric
    // Round) must keep the secure trajectory inside Fig. 4's tolerance of
    // the plaintext reference. Synthetic features are zero-centered, so
    // every quantize pass exercises negative inputs; a second seed guards
    // against a single lucky draw.
    for seed in [206u64, 207] {
        let ds = Dataset::synth(SynthSpec::smoke(), seed);
        let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), seed);
        cfg.iters = 25;
        let secure = algo::train(&cfg, &ds).unwrap();
        let plain = ml::train_logreg(
            &ds,
            &ml::LogRegOptions { iters: cfg.iters, eta: cfg.eta, ..Default::default() },
        );
        let ps = *plain.test_accuracy.last().unwrap();
        let ss = *secure.test_accuracy.last().unwrap();
        assert!((ps - ss).abs() < 0.06, "seed {seed}: plaintext {ps} vs secure {ss}");
        assert!(ss > 0.8, "seed {seed}: secure accuracy {ss} failed to converge");
    }
}

#[test]
fn insufficient_n_rejected() {
    let ds = Dataset::synth(SynthSpec::tiny(), 203);
    // K=3, T=2, r=1 → threshold 3·4+1 = 13 > 10
    let cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::explicit(3, 2), 203);
    assert!(protocol::train(&cfg, &ds).is_err());
    assert!(algo::train(&cfg, &ds).is_err());
}

#[test]
fn ledger_accounts_every_phase() {
    let ds = Dataset::synth(SynthSpec::tiny(), 204);
    let mut cfg = CopmlConfig::for_dataset(&ds, 7, CaseParams::explicit(2, 1), 204);
    cfg.iters = 3;
    let out = protocol::train(&cfg, &ds).unwrap();
    assert_eq!(out.ledgers.len(), 7);
    for (i, l) in out.ledgers.iter().enumerate() {
        assert!(l.total_seconds() > 0.0, "client {i} recorded no time");
        // every client shares its dataset and its results
        assert!(l.bytes[1] > 0, "client {i}: no dataset sharing bytes");
        assert!(l.bytes[6] > 0, "client {i}: no result bytes");
        // dealer mode (the default): the offline phase is free on the wire
        assert_eq!(l.bytes[0], 0, "client {i}: dealer offline phase sent bytes");
    }
}

#[test]
fn eta_within_lipschitz_bound_converges_monotonically() {
    // Theorem 1 premise: η ≤ 1/L → loss decreases (up to truncation noise).
    let ds = Dataset::synth(SynthSpec::smoke(), 205);
    let l = ml::logreg::lipschitz_constant(&ds, 30);
    let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 205);
    cfg.eta = (1.0 / l).min(2.0);
    // 1/L is small at this scale: widen l_e so e_q = Round(2^{l_e}·η/m) ≥ 1
    // (stage-2 width l_x + l_e must stay < k_2).
    cfg.plan.le = cfg.plan.k2 - cfg.plan.lx - 3;
    cfg.iters = 15;
    let out = algo::train(&cfg, &ds).unwrap();
    let first = out.loss[0];
    let last = *out.loss.last().unwrap();
    assert!(last < first, "loss {first} → {last}");
}
