//! Multi-job `copml serve` semantics (ISSUE-9 satellite): a stream of
//! jobs multiplexed over one held-open mesh must train every job
//! bit-identically to a standalone single-job run with the same seed.
//! Session ids renumber tags, never values — the SESSION stripe in
//! `net::tags` is invisible to the arithmetic.

use copml::coordinator::{protocol, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::mpc::OfflineMode;

fn serve_cfg(ds: &Dataset, seed: u64) -> CopmlConfig {
    let mut cfg = CopmlConfig::for_dataset(ds, 4, CaseParams::explicit(1, 1), seed);
    cfg.iters = 3;
    cfg
}

/// The standalone reference for serve job `j`: same seed schedule
/// (`base.wrapping_add(j)`), session 0, fresh mesh.
fn solo_cfg(cfg: &CopmlConfig, j: usize) -> CopmlConfig {
    let mut c = cfg.clone();
    c.seed = cfg.seed.wrapping_add(j as u64);
    c.session = 0;
    c.chunk = None;
    c
}

#[test]
fn serve_stream_matches_standalone_runs_dealer() {
    let ds = Dataset::synth(SynthSpec::tiny(), 300);
    let cfg = serve_cfg(&ds, 300);
    let so = protocol::serve(&cfg, &ds, 3).unwrap();
    assert!(so.failed.is_none(), "serve stream failed: {:?}", so.failed);
    assert_eq!(so.jobs.len(), 3);
    assert!(so.jobs_per_hour > 0.0);
    for (j, job) in so.jobs.iter().enumerate() {
        let solo = protocol::train(&solo_cfg(&cfg, j), &ds).unwrap();
        assert_eq!(
            job.train.w_trace, solo.train.w_trace,
            "serve job {j} diverged from the standalone run with the same seed"
        );
        // Dealer mode has no factory: every job's offline time is fully
        // on the critical path, exactly as in a standalone run.
        for (i, l) in job.ledgers.iter().enumerate() {
            assert_eq!(l.offline_hidden_s, 0.0, "job {j} client {i}: unexpected hidden seconds");
        }
    }
    // Jobs use distinct seeds, so consecutive jobs must not be clones.
    assert_ne!(so.jobs[0].train.w_trace, so.jobs[1].train.w_trace);
}

#[test]
fn serve_stream_matches_standalone_runs_distributed_chunked() {
    // The full pipeline: distributed DN07 offline, chunked factory, job
    // j+1's pools prefetched behind job j. Every job must still match a
    // standalone ONE-SHOT run — this cross-checks session transparency
    // and chunk stability in one pass.
    let ds = Dataset::synth(SynthSpec::tiny(), 301);
    let mut cfg = serve_cfg(&ds, 301);
    cfg.offline = OfflineMode::Distributed;
    cfg.chunk = Some(16);
    let so = protocol::serve(&cfg, &ds, 3).unwrap();
    assert!(so.failed.is_none(), "serve stream failed: {:?}", so.failed);
    assert_eq!(so.jobs.len(), 3);
    for (j, job) in so.jobs.iter().enumerate() {
        let solo = protocol::train(&solo_cfg(&cfg, j), &ds).unwrap();
        assert_eq!(
            job.train.w_trace, solo.train.w_trace,
            "pipelined serve job {j} diverged from the standalone one-shot run"
        );
    }
}

#[test]
fn serve_rejects_empty_job_stream() {
    let ds = Dataset::synth(SynthSpec::tiny(), 302);
    let cfg = serve_cfg(&ds, 302);
    assert!(protocol::serve(&cfg, &ds, 0).is_err());
}

#[test]
fn serve_stream_over_tcp_loopback() {
    // Same contract over real sockets: 2 jobs through the TCP loopback
    // mesh, each matching its standalone reference.
    let ds = Dataset::synth(SynthSpec::tiny(), 303);
    let cfg = serve_cfg(&ds, 303);
    let so = protocol::serve_tcp_loopback(&cfg, &ds, 2).unwrap();
    assert!(so.failed.is_none(), "tcp serve stream failed: {:?}", so.failed);
    assert_eq!(so.jobs.len(), 2);
    for (j, job) in so.jobs.iter().enumerate() {
        let solo = protocol::train(&solo_cfg(&cfg, j), &ds).unwrap();
        assert_eq!(job.train.w_trace, solo.train.w_trace, "tcp serve job {j} diverged");
    }
}
