//! Statistical privacy checks: what an adversary observing up to `T`
//! clients' views actually sees. These are sanity tests of the
//! information-theoretic arguments (Shamir hiding, Lagrange mask hiding),
//! not proofs — the proofs are the constructions themselves ([13], [32]).

use copml::field::{Field, P26};
use copml::lcc::Encoder;
use copml::prng::Rng;
use copml::shamir;

/// Crude uniformity check: split the field into 16 buckets; every bucket's
/// frequency within 20% of uniform.
fn assert_roughly_uniform(samples: &[u64], p: u64, ctx: &str) {
    let buckets = 16usize;
    let mut counts = vec![0usize; buckets];
    for &s in samples {
        counts[(s as u128 * buckets as u128 / p as u128) as usize] += 1;
    }
    let expect = samples.len() as f64 / buckets as f64;
    for (i, &c) in counts.iter().enumerate() {
        assert!(
            (c as f64 - expect).abs() < expect * 0.2,
            "{ctx}: bucket {i} count {c} vs {expect}"
        );
    }
}

#[test]
fn t_shamir_shares_of_distinct_secrets_indistinguishable() {
    // The T shares an adversary coalition sees have the same (uniform)
    // marginal regardless of the secret value.
    let f = Field::new(P26);
    let mut rng = Rng::seed_from_u64(1);
    let (n, t) = (5usize, 2usize);
    let trials = 3000;
    for secret in [0u64, 1, P26 / 2, P26 - 1] {
        let mut adversary_view = Vec::with_capacity(trials * t);
        for _ in 0..trials {
            let shares = shamir::share(f, &[secret], n, t, &mut rng);
            for s in shares.iter().take(t) {
                adversary_view.push(s[0]);
            }
        }
        assert_roughly_uniform(&adversary_view, P26, &format!("secret={secret}"));
    }
}

#[test]
fn t_encoded_matrices_hide_the_dataset() {
    // T colluding clients see T Lagrange-encoded matrices X̃; with T masks
    // these are uniform, independent of the data (paper §IV).
    let f = Field::new(P26);
    let (k, t, n) = (2usize, 2usize, 9usize);
    let enc = Encoder::standard(f, k, t, n);
    let mut rng = Rng::seed_from_u64(2);
    let trials = 1500;
    for dataset_fill in [0u64, 42, P26 - 7] {
        let parts_data = vec![vec![dataset_fill; 4]; k];
        let mut view = Vec::new();
        for _ in 0..trials {
            let masks = enc.gen_masks(4, &mut rng);
            let parts: Vec<&[u64]> = parts_data
                .iter()
                .map(|v| v.as_slice())
                .chain(masks.iter().map(|v| v.as_slice()))
                .collect();
            // adversary = clients 0 and 1
            for j in 0..t {
                let mut out = vec![0u64; 4];
                enc.encode_one(j, &parts, &mut out);
                view.extend_from_slice(&out);
            }
        }
        assert_roughly_uniform(&view, P26, &format!("fill={dataset_fill}"));
    }
}

#[test]
fn masked_opening_hides_product() {
    // The BH08 opening reveals only z − ρ, which is uniform.
    let f = Field::new(P26);
    let mut rng = Rng::seed_from_u64(3);
    let z = 123456u64; // "secret" product
    let samples: Vec<u64> = (0..20000).map(|_| f.sub(z, rng.gen_range(P26))).collect();
    assert_roughly_uniform(&samples, P26, "z − ρ");
}

#[test]
fn trunc_opening_is_statistically_masked() {
    // TruncPr opens b + 2^m·r'' + r'. For κ security bits the value b is
    // hidden up to statistical distance ~2^−κ; here we sanity-check that
    // the opened distribution's support is dominated by the mask range.
    let f = Field::new(P26);
    let (k, m, kappa) = (20u32, 8u32, 1u32);
    let mut rng = Rng::seed_from_u64(4);
    let b = 1u64 << 18;
    let mut opened = Vec::with_capacity(20000);
    for _ in 0..20000 {
        let rp = rng.gen_range(1 << m);
        let rpp = rng.gen_range(1 << (k + kappa - m));
        opened.push(f.add(b, f.add(f.mul(1 << m, rpp), rp)));
    }
    // mask range is 2^{k+κ} ≈ 2M: the observable support must span nearly
    // the whole mask range (b only offsets it), i.e. the opened value's
    // entropy is dominated by the mask, not by b.
    let max = *opened.iter().max().unwrap();
    let min = *opened.iter().min().unwrap();
    let span = max - min;
    let mask_range = (1u64 << (k + kappa)) + (1 << m);
    assert!((span as f64) > 0.99 * (mask_range as f64), "span {span} vs mask {mask_range}");
    assert!(min >= b && ((min - b) as f64) < 0.01 * (mask_range as f64));
}
