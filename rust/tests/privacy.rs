//! Statistical privacy checks: what an adversary observing up to `T`
//! clients' views actually sees. These are sanity tests of the
//! information-theoretic arguments (Shamir hiding, Lagrange mask hiding,
//! DN07 extraction hiding), not proofs — the proofs are the constructions
//! themselves ([13], [32], DN07).

use copml::field::{Field, P26};
use copml::lcc::Encoder;
use copml::mpc::offline::{extract, extraction_matrix, sqrt_mod};
use copml::prng::Rng;
use copml::shamir;

/// Crude uniformity check: split the field into 16 buckets; every bucket's
/// frequency within 20% of uniform.
fn assert_roughly_uniform(samples: &[u64], p: u64, ctx: &str) {
    let buckets = 16usize;
    let mut counts = vec![0usize; buckets];
    for &s in samples {
        counts[(s as u128 * buckets as u128 / p as u128) as usize] += 1;
    }
    let expect = samples.len() as f64 / buckets as f64;
    for (i, &c) in counts.iter().enumerate() {
        assert!(
            (c as f64 - expect).abs() < expect * 0.2,
            "{ctx}: bucket {i} count {c} vs {expect}"
        );
    }
}

#[test]
fn t_shamir_shares_of_distinct_secrets_indistinguishable() {
    // The T shares an adversary coalition sees have the same (uniform)
    // marginal regardless of the secret value.
    let f = Field::new(P26);
    let mut rng = Rng::seed_from_u64(1);
    let (n, t) = (5usize, 2usize);
    let trials = 3000;
    for secret in [0u64, 1, P26 / 2, P26 - 1] {
        let mut adversary_view = Vec::with_capacity(trials * t);
        for _ in 0..trials {
            let shares = shamir::share(f, &[secret], n, t, &mut rng);
            for s in shares.iter().take(t) {
                adversary_view.push(s[0]);
            }
        }
        assert_roughly_uniform(&adversary_view, P26, &format!("secret={secret}"));
    }
}

#[test]
fn t_encoded_matrices_hide_the_dataset() {
    // T colluding clients see T Lagrange-encoded matrices X̃; with T masks
    // these are uniform, independent of the data (paper §IV).
    let f = Field::new(P26);
    let (k, t, n) = (2usize, 2usize, 9usize);
    let enc = Encoder::standard(f, k, t, n);
    let mut rng = Rng::seed_from_u64(2);
    let trials = 1500;
    for dataset_fill in [0u64, 42, P26 - 7] {
        let parts_data = vec![vec![dataset_fill; 4]; k];
        let mut view = Vec::new();
        for _ in 0..trials {
            let masks = enc.gen_masks(4, &mut rng);
            let parts: Vec<&[u64]> = parts_data
                .iter()
                .map(|v| v.as_slice())
                .chain(masks.iter().map(|v| v.as_slice()))
                .collect();
            // adversary = clients 0 and 1
            for j in 0..t {
                let mut out = vec![0u64; 4];
                enc.encode_one(j, &parts, &mut out);
                view.extend_from_slice(&out);
            }
        }
        assert_roughly_uniform(&view, P26, &format!("fill={dataset_fill}"));
    }
}

#[test]
fn masked_opening_hides_product() {
    // The BH08 opening reveals only z − ρ, which is uniform.
    let f = Field::new(P26);
    let mut rng = Rng::seed_from_u64(3);
    let z = 123456u64; // "secret" product
    let samples: Vec<u64> = (0..20000).map(|_| f.sub(z, rng.gen_range(P26))).collect();
    assert_roughly_uniform(&samples, P26, "z − ρ");
}

// ---------------------------------------------------------------------
// Distributed offline phase (mpc::offline): transcript simulation of the
// joint view of T colluding parties, over several (N, T) geometries.
// ---------------------------------------------------------------------

/// Transcript of one extraction round, from the coalition's perspective:
/// everything parties `0..t` observe — their own shares of every dealt
/// batch (the messages they receive from honest dealers plus what they
/// dealt themselves) and their shares of every extracted output.
fn extraction_coalition_view(
    f: Field,
    n: usize,
    t: usize,
    honest_secret: u64,
    rng: &mut Rng,
) -> Vec<u64> {
    // Honest dealers (t..n) all deal `honest_secret`; corrupt dealers
    // (0..t) deal a fixed known value — worst case for the adversary's
    // inference problem, since its own contributions carry no entropy.
    let mut by_party: Vec<Vec<Vec<u64>>> = vec![Vec::new(); n];
    for dealer in 0..n {
        let secret = if dealer < t { 7u64 } else { honest_secret };
        let shares = shamir::share(f, &[secret], n, t, rng);
        for (i, s) in shares.into_iter().enumerate() {
            by_party[i].push(s);
        }
    }
    let matrix = extraction_matrix(f, n, t);
    let mut view = Vec::new();
    for inputs in by_party.iter().take(t) {
        // Received dealt shares from the honest dealers (the coalition's
        // own dealings are a function of its randomness — not evidence).
        for dealt in &inputs[t..] {
            view.push(dealt[0]);
        }
        // Shares of the extracted outputs (a public linear map of the
        // above — included to make the "joint view" literal).
        let views: Vec<&[u64]> = inputs.iter().map(|v| v.as_slice()).collect();
        for out in extract(f, &matrix, &views) {
            view.push(out[0]);
        }
    }
    view
}

#[test]
fn t_collusion_view_of_extraction_uniform() {
    // The joint view of any T colluding parties during DN07 extraction is
    // uniform regardless of the honest dealers' inputs — i.e. simulatable
    // without them. Checked over several (N, T) geometries and honest
    // inputs at the extremes of the field.
    let f = Field::new(P26);
    let mut rng = Rng::seed_from_u64(6);
    for (n, t) in [(4usize, 1usize), (7, 2), (9, 3)] {
        let trials = 9000 / n;
        for honest_secret in [0u64, 1, P26 - 1] {
            let mut view = Vec::new();
            for _ in 0..trials {
                view.extend(extraction_coalition_view(f, n, t, honest_secret, &mut rng));
            }
            assert_roughly_uniform(
                &view,
                P26,
                &format!("extraction view n={n} t={t} secret={honest_secret}"),
            );
        }
    }
}

#[test]
fn t_collusion_view_of_bit_generation_simulatable() {
    // Bit generation opens a² and keeps [b] = (c⁻¹a + 1)/2 secret. The
    // coalition sees: its T shares of [a] (uniform — Shamir) and the
    // public a². The opened value must carry NO information about the
    // bit: b is the sign of a, and a² forgets the sign. Checked by
    // correlation: P(b = 1) conditioned on the magnitude of a² stays ½.
    let f = Field::new(P26);
    let mut rng = Rng::seed_from_u64(7);
    let (n, t) = (5usize, 2usize);
    let trials = 6000;
    let mut share_view = Vec::with_capacity(trials * t);
    let mut opened_and_bit: Vec<(u64, u64)> = Vec::with_capacity(trials);
    let inv2 = f.inv(2);
    for _ in 0..trials {
        let a = rng.gen_range(P26 - 1) + 1; // nonzero, as the protocol retries 0
        let shares = shamir::share(f, &[a], n, t, &mut rng);
        for s in shares.iter().take(t) {
            share_view.push(s[0]);
        }
        let sq = f.mul(a, a);
        let c = sqrt_mod(f, sq);
        let b = f.mul(inv2, f.add(f.mul(f.inv(c), a), 1));
        assert!(b == 0 || b == 1, "bit out of domain");
        opened_and_bit.push((sq, b));
    }
    // (1) the coalition's a-shares are uniform;
    assert_roughly_uniform(&share_view, P26, "bit-gen a-share view");
    // (2) the public a² is independent of the bit: split the transcript
    // by the opened value's magnitude — both halves must be fair coins.
    opened_and_bit.sort_unstable();
    let half = opened_and_bit.len() / 2;
    for (name, slice) in
        [("low a²", &opened_and_bit[..half]), ("high a²", &opened_and_bit[half..])]
    {
        let ones: usize = slice.iter().filter(|&&(_, b)| b == 1).count();
        let frac = ones as f64 / slice.len() as f64;
        assert!(
            (frac - 0.5).abs() < 0.04,
            "{name}: P(b=1) = {frac} — opened square leaks the bit"
        );
    }
    // (3) the bit itself is unbiased.
    let ones: usize = opened_and_bit.iter().filter(|&&(_, b)| b == 1).count();
    let frac = ones as f64 / trials as f64;
    assert!((frac - 0.5).abs() < 0.025, "bit bias {frac}");
}

#[test]
fn coalition_cannot_reconstruct_extracted_outputs() {
    // Sanity that the threshold is real for the *outputs* too: T shares of
    // an extracted sharing interpolated as degree T−1 give the wrong
    // value (the coalition's marginal carries no reconstruction power).
    let f = Field::new(P26);
    let mut rng = Rng::seed_from_u64(8);
    let (n, t) = (7usize, 2usize);
    let matrix = extraction_matrix(f, n, t);
    let mut wrong = 0usize;
    let trials = 200;
    for _ in 0..trials {
        let secrets: Vec<u64> = (0..n).map(|_| rng.gen_range(P26)).collect();
        let mut by_party: Vec<Vec<Vec<u64>>> = vec![Vec::new(); n];
        for &s in &secrets {
            let shares = shamir::share(f, &[s], n, t, &mut rng);
            for (i, sh) in shares.into_iter().enumerate() {
                by_party[i].push(sh);
            }
        }
        let per_party: Vec<Vec<Vec<u64>>> = by_party
            .iter()
            .map(|inputs| {
                let views: Vec<&[u64]> = inputs.iter().map(|v| v.as_slice()).collect();
                extract(f, &matrix, &views)
            })
            .collect();
        // True value of output 0 (all n shares) vs the coalition's
        // under-determined degree-(t−1) guess from its t shares.
        let all: Vec<Vec<u64>> = (0..n).map(|p| vec![per_party[p][0][0]]).collect();
        let truth = shamir::reconstruct(f, &all, t)[0];
        let guess = shamir::reconstruct(f, &all[..t], t - 1)[0];
        if guess != truth {
            wrong += 1;
        }
    }
    assert!(
        wrong > trials * 9 / 10,
        "coalition guessed the extracted value too often ({wrong}/{trials})"
    );
}

#[test]
fn trunc_opening_is_statistically_masked() {
    // TruncPr opens b + 2^m·r'' + r'. For κ security bits the value b is
    // hidden up to statistical distance ~2^−κ; here we sanity-check that
    // the opened distribution's support is dominated by the mask range.
    let f = Field::new(P26);
    let (k, m, kappa) = (20u32, 8u32, 1u32);
    let mut rng = Rng::seed_from_u64(4);
    let b = 1u64 << 18;
    let mut opened = Vec::with_capacity(20000);
    for _ in 0..20000 {
        let rp = rng.gen_range(1 << m);
        let rpp = rng.gen_range(1 << (k + kappa - m));
        opened.push(f.add(b, f.add(f.mul(1 << m, rpp), rp)));
    }
    // mask range is 2^{k+κ} ≈ 2M: the observable support must span nearly
    // the whole mask range (b only offsets it), i.e. the opened value's
    // entropy is dominated by the mask, not by b.
    let max = *opened.iter().max().unwrap();
    let min = *opened.iter().min().unwrap();
    let span = max - min;
    let mask_range = (1u64 << (k + kappa)) + (1 << m);
    assert!((span as f64) > 0.99 * (mask_range as f64), "span {span} vs mask {mask_range}");
    assert!(min >= b && ((min - b) as f64) < 0.01 * (mask_range as f64));
}
