//! The `copml lint` gate, turned on itself.
//!
//! Two directions: (a) the crate's own source tree must be clean — this is
//! the same zero-findings bar the CI job enforces via `copml lint`, kept
//! here as well so a plain `cargo test` catches a regression before CI
//! does; (b) the analyzer must actually *fire* — a seeded tree with raw
//! tag arithmetic, a computed tag in a send, and HashMap iteration inside
//! `coordinator/` must produce findings for exactly those rules. A linter
//! that silently passes everything would satisfy (a) forever; (b) pins it
//! to keep working.

use std::fs;
use std::path::PathBuf;

use copml::analysis::run_lint;

#[test]
fn own_tree_has_zero_findings() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = run_lint(&root).expect("lint must be able to read its own tree");
    assert!(
        report.ok(),
        "the tree must lint clean — fix or justify each site:\n{}",
        report.render()
    );
    assert!(report.files_scanned > 10, "suspiciously few files scanned — wrong root?");
}

/// Temp tree that removes itself even when an assertion unwinds.
struct SeededTree {
    root: PathBuf,
}

impl SeededTree {
    fn new() -> Self {
        let root =
            std::env::temp_dir().join(format!("copml-lint-gate-{}", std::process::id()));
        // A stale tree from a crashed prior run with the same pid is
        // indistinguishable from ours — replace it wholesale.
        let _ = fs::remove_dir_all(&root);
        fs::create_dir_all(root.join("coordinator")).expect("create seeded tree");
        Self { root }
    }
}

impl Drop for SeededTree {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

#[test]
fn seeded_violations_fail_the_lint() {
    let tree = SeededTree::new();
    // Deliberately unhygienic protocol code: a tag computed by arithmetic,
    // an inline tag expression handed straight to `send`, and iteration
    // over a HashMap in coordinator state. None of this needs to compile —
    // the analyzer is source-level.
    let evil = r#"
use std::collections::HashMap;

pub fn evil_round(net: &Net, tag_base: u64, i: u64) {
    let round_tag = tag_base + 16 * i;
    let counts: HashMap<u64, u64> = HashMap::new();
    for (peer, n) in counts.iter() {
        let _ = (peer, n);
    }
    net.send(0, tag_base + 7, &[1, 2, 3]);
    let _ = round_tag;
}
"#;
    fs::write(tree.root.join("coordinator").join("evil.rs"), evil).expect("write evil.rs");

    let report = run_lint(&tree.root).expect("lint must read the seeded tree");
    assert!(!report.ok(), "seeded violations must fail the gate:\n{}", report.render());
    assert_eq!(report.files_scanned, 1);

    let fired: Vec<&str> = report.findings.iter().map(|f| f.rule).collect();
    for rule in ["tag-arith", "tag-computed", "map-iter"] {
        assert!(
            fired.contains(&rule),
            "expected a {rule} finding, got:\n{}",
            report.render()
        );
    }
    for f in &report.findings {
        assert_eq!(
            f.file, "coordinator/evil.rs",
            "finding attributed to the wrong file:\n{}",
            report.render()
        );
    }
}

#[test]
fn suppression_requires_a_justification() {
    let tree = SeededTree::new();
    // Same violation twice: once with a bare `allow` (must still fire) and
    // once with a justified one (must be silent).
    let src = r#"
pub fn bare(tag_base: u64, i: u64) -> u64 {
    // copml-lint: allow(tag-arith)
    tag_base + i
}

pub fn justified(tag_base: u64, i: u64) -> u64 {
    // copml-lint: allow(tag-arith) test fixture exercising the allocator math
    tag_base + i
}
"#;
    fs::write(tree.root.join("coordinator").join("suppress.rs"), src)
        .expect("write suppress.rs");

    let report = run_lint(&tree.root).expect("lint must read the seeded tree");
    assert_eq!(
        report.findings.len(),
        1,
        "bare allow() must not suppress; justified allow() must:\n{}",
        report.render()
    );
    assert_eq!(report.findings[0].rule, "tag-arith");
}
