//! Property-based tests (via `copml::testkit`) of the core algebraic
//! invariants every protocol layer relies on.

use copml::field::{vecops, Field, MatShape, P25, P26, P31};
use copml::lcc;
use copml::poly;
use copml::quant;
use copml::shamir;
use copml::testkit::{forall, Gen};

fn any_field(g: &mut Gen) -> Field {
    Field::new(*g.choose(&[97u64, 257, P25, P26, P31]))
}

#[test]
fn prop_field_ring_axioms() {
    forall("field ring axioms", 300, |g| {
        let f = any_field(g);
        let p = f.modulus();
        let (a, b, c) = (g.u64_below(p), g.u64_below(p), g.u64_below(p));
        assert_eq!(f.add(a, b), f.add(b, a));
        assert_eq!(f.mul(a, b), f.mul(b, a));
        assert_eq!(f.mul(a, f.add(b, c)), f.add(f.mul(a, b), f.mul(a, c)));
        assert_eq!(f.add(a, f.neg(a)), 0);
        if a != 0 {
            assert_eq!(f.mul(a, f.inv(a)), 1);
        }
        assert_eq!(f.sub(f.add(a, b), b), a);
    });
}

#[test]
fn prop_signed_embedding_homomorphic() {
    forall("signed embedding", 300, |g| {
        let f = any_field(g);
        let half = (f.modulus() / 4) as i64;
        let a = g.u64_below(half as u64) as i64 - half / 2;
        let b = g.u64_below(half as u64) as i64 - half / 2;
        assert_eq!(f.to_i64(f.add(f.from_i64(a), f.from_i64(b))), a + b);
    });
}

#[test]
fn prop_shamir_roundtrip_any_subset() {
    forall("shamir roundtrip", 60, |g| {
        let f = any_field(g);
        let n = g.usize_in(3, 12);
        let t = g.usize_in(1, n - 1);
        let len = g.usize_in(1, 40);
        let secret = g.vec_u64(len, f.modulus());
        let shares = shamir::share(f, &secret, n, t, g.rng());
        // random subset of size t+1
        let perm = g.rng().permutation(n);
        let subset: Vec<usize> = perm[..t + 1].to_vec();
        let pts: Vec<u64> = subset.iter().map(|&i| (i + 1) as u64).collect();
        let rec = shamir::Reconstructor::new(f, &pts);
        let views: Vec<&[u64]> = subset.iter().map(|&i| shares[i].as_slice()).collect();
        let mut out = vec![0u64; len];
        rec.reconstruct(f, &views, &mut out);
        assert_eq!(out, secret);
    });
}

#[test]
fn prop_share_encode_commutes() {
    // The protocol's core trick (Phase 2): Lagrange-encoding the *shares*
    // yields shares of the *encoding*.
    forall("share/encode commute", 40, |g| {
        let f = Field::new(P26);
        let n = g.usize_in(4, 9);
        let t_sh = g.usize_in(1, n - 2);
        let (k, t_enc) = (g.usize_in(1, 3), g.usize_in(1, 2));
        let len = g.usize_in(1, 12);
        let enc = lcc::Encoder::standard(f, k, t_enc, n);
        // plaintext parts + masks
        let parts: Vec<Vec<u64>> =
            (0..k + t_enc).map(|_| g.vec_u64(len, P26)).collect();
        // share every part
        let shares_per_part: Vec<Vec<Vec<u64>>> = parts
            .iter()
            .map(|part| shamir::share(f, part, n, t_sh, g.rng()))
            .collect();
        let target = g.usize_in(0, n - 1);
        // encode the plaintext
        let views: Vec<&[u64]> = parts.iter().map(|v| v.as_slice()).collect();
        let mut direct = vec![0u64; len];
        enc.encode_one(target, &views, &mut direct);
        // encode each party's shares, then reconstruct
        let enc_shares: Vec<Vec<u64>> = (0..n)
            .map(|i| {
                let sviews: Vec<&[u64]> =
                    shares_per_part.iter().map(|sp| sp[i].as_slice()).collect();
                let mut out = vec![0u64; len];
                enc.encode_one(target, &sviews, &mut out);
                out
            })
            .collect();
        let rec = shamir::reconstruct(f, &enc_shares, t_sh);
        assert_eq!(rec, direct);
    });
}

#[test]
fn prop_lagrange_interpolation_exact() {
    forall("lagrange interpolation", 80, |g| {
        let f = any_field(g);
        let deg = g.usize_in(0, 8);
        let coeffs = g.vec_u64(deg + 1, f.modulus());
        let xs: Vec<u64> = (1..=deg as u64 + 1).collect();
        let ys: Vec<u64> = xs.iter().map(|&x| poly::horner(f, &coeffs, x)).collect();
        let z = g.u64_below(f.modulus());
        assert_eq!(poly::interp_eval(f, &xs, &ys, z), poly::horner(f, &coeffs, z));
    });
}

#[test]
fn prop_quantize_dequantize_error_bounded() {
    forall("quantize error", 200, |g| {
        let f = Field::new(P26);
        let scale = g.usize_in(0, 12) as u32;
        let x = g.f64_in(-4.0, 4.0);
        let err = (quant::dequantize(f, quant::quantize(f, x, scale), scale) - x).abs();
        assert!(err <= 0.5 / (1u64 << scale) as f64 + 1e-12, "err {err} scale {scale}");
    });
}

#[test]
fn prop_matvec_linear() {
    forall("matvec linearity", 60, |g| {
        let f = any_field(g);
        let p = f.modulus();
        let (rows, cols) = (g.usize_in(1, 12), g.usize_in(1, 12));
        let a = g.vec_u64(rows * cols, p);
        let u = g.vec_u64(cols, p);
        let v = g.vec_u64(cols, p);
        let shape = MatShape::new(rows, cols);
        let sum: Vec<u64> = u.iter().zip(&v).map(|(&x, &y)| f.add(x, y)).collect();
        let lhs = vecops::matvec(f, &a, shape, &sum);
        let au = vecops::matvec(f, &a, shape, &u);
        let av = vecops::matvec(f, &a, shape, &v);
        let rhs: Vec<u64> = au.iter().zip(&av).map(|(&x, &y)| f.add(x, y)).collect();
        assert_eq!(lhs, rhs);
    });
}

#[test]
fn r3_ablation_trains_with_headroom_plan() {
    // Degree-3 sigmoid end to end (algo mode): needs the headroom prime so
    // the cubic coefficient survives quantization (quant docs).
    use copml::coordinator::{algo, CaseParams, CopmlConfig};
    use copml::data::{Dataset, SynthSpec};
    let ds = Dataset::synth(SynthSpec::smoke(), 77);
    let mut cfg = CopmlConfig::for_dataset(&ds, 22, CaseParams::explicit(2, 1), 77);
    cfg.r = 3; // recovery threshold 7(K+T−1)+1 = 15 ≤ 22
    cfg.plan = copml::quant::FpPlan::headroom();
    cfg.iters = 20;
    let out = algo::train(&cfg, &ds).unwrap();
    assert!(out.test_accuracy.last().unwrap() > &0.8, "r=3 accuracy");
}
