//! Model-zoo integrity (the workload-trait companion to
//! protocol_equivalence.rs): for every non-default workload the full
//! threaded protocol and the algorithmic-fidelity central trainer compute
//! **bit-identical** field-domain model traces — across party geometries,
//! kernel tiers, mini-batch schedules, and wire formats — and the secure
//! result lands within the fig4 tolerance of its own cleartext reference.
//! Binary logreg itself is covered exhaustively by protocol_equivalence.rs;
//! these tests pin the multi-channel (multinomial) and closed-form (linreg)
//! generalizations to the same standard.

use copml::coordinator::{algo, protocol, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::field::KernelTier;
use copml::ml::model::ridge_regression;
use copml::ml::{self, ModelKind};
use copml::net::Wire;
use copml::prng::Rng;
use copml::quant::{self, FpPlan};

/// Deterministic 3-class blobs: class `c` shifts feature `c` by +0.6,
/// features clamped to the plan's `[-1, 1]` range, bias column last.
fn three_class_dataset(seed: u64) -> Dataset {
    let mut rng = Rng::seed_from_u64(seed);
    let (m, m_test, d, classes) = (240usize, 60usize, 5usize, 3usize);
    let gen = |rng: &mut Rng, n: usize| {
        let mut x = vec![0.0f64; n * d];
        let mut y = vec![0.0f64; n];
        for i in 0..n {
            let c = i % classes;
            y[i] = c as f64;
            for j in 0..d - 1 {
                let mut v = 0.25 * rng.gen_normal();
                if j == c {
                    v += 0.6;
                }
                x[i * d + j] = v.clamp(-1.0, 1.0);
            }
            x[i * d + d - 1] = 1.0;
        }
        (x, y)
    };
    let (x, y) = gen(&mut rng, m);
    let (x_test, y_test) = gen(&mut rng, m_test);
    Dataset { name: "three-class".into(), x, y, x_test, y_test, m, d, classes }
}

fn zoo_cfg(
    model: ModelKind,
    ds: &Dataset,
    n: usize,
    k: usize,
    t: usize,
    iters: usize,
    seed: u64,
) -> CopmlConfig {
    let mut cfg = CopmlConfig::for_dataset(ds, n, CaseParams::explicit(k, t), seed);
    cfg.iters = iters;
    cfg.model = model;
    cfg
}

#[test]
fn multinomial_protocol_equals_algo_across_geometries() {
    let ds = three_class_dataset(201);
    for (n, k, t) in [(4usize, 1usize, 1usize), (7, 2, 1), (10, 2, 2)] {
        let cfg = zoo_cfg(ModelKind::Multinomial, &ds, n, k, t, 4, 201);
        let a = algo::train(&cfg, &ds).unwrap();
        let p = protocol::train(&cfg, &ds).unwrap();
        assert_eq!(a.w_trace, p.train.w_trace, "n={n} k={k} t={t}");
        // Every snapshot carries the full class-major d·C weight matrix.
        assert!(a.w_trace.iter().all(|w| w.len() == ds.d * ds.classes));
    }
}

#[test]
fn multinomial_bit_identical_across_kernel_batches_wire() {
    let ds = three_class_dataset(202);
    let cfg = zoo_cfg(ModelKind::Multinomial, &ds, 7, 2, 1, 3, 202);
    let reference = algo::train(&cfg, &ds).unwrap();

    let mut mont = cfg.clone();
    mont.kernel = KernelTier::Mont;
    assert_eq!(
        protocol::train(&mont, &ds).unwrap().train.w_trace,
        reference.w_trace,
        "kernel=mont moved the multinomial trace"
    );

    let mut batched = cfg.clone();
    batched.batches = 2;
    assert_eq!(
        algo::train(&batched, &ds).unwrap().w_trace,
        protocol::train(&batched, &ds).unwrap().train.w_trace,
        "batched multinomial protocol diverged from algo"
    );

    let mut packed = cfg.clone();
    packed.wire = Wire::U32;
    assert_eq!(
        protocol::train_tcp_loopback(&packed, &ds).unwrap().train.w_trace,
        reference.w_trace,
        "wire=u32 TCP multinomial trace moved"
    );
}

#[test]
fn multinomial_matches_cleartext_reference_within_fig4_tolerance() {
    let ds = three_class_dataset(203);
    let cfg = zoo_cfg(ModelKind::Multinomial, &ds, 7, 2, 1, 30, 203);
    let secure = algo::train(&cfg, &ds).unwrap();
    let plain = ml::train_multinomial(
        &ds,
        &ml::LogRegOptions { iters: cfg.iters, eta: cfg.eta, ..Default::default() },
    );
    let s = *secure.test_accuracy.last().unwrap();
    let r = *plain.test_accuracy.last().unwrap();
    assert!(s > 0.7, "secure multinomial accuracy {s:.4} did not learn");
    assert!((s - r).abs() < 0.04, "secure {s:.4} vs cleartext {r:.4} outside fig4 tolerance");
    // Classifier metric set: accuracy present, AUC undefined for C > 2,
    // R² not a classification metric.
    assert!(secure.test_metrics.accuracy.is_some());
    assert!(secure.test_metrics.auc.is_none());
    assert!(secure.test_metrics.r2.is_none());
}

#[test]
fn linreg_protocol_equals_algo_and_matches_ridge() {
    let ds = Dataset::synth(SynthSpec::tiny(), 204);
    for (n, k, t) in [(4usize, 1usize, 1usize), (7, 2, 1)] {
        let mut cfg = zoo_cfg(ModelKind::Linreg, &ds, n, k, t, 1, 204);
        // Headroom plan, as in fig_models: the one-shot closed form exposes
        // the raw data-quantization error with no iterations to average it.
        cfg.plan = FpPlan::headroom();
        let a = algo::train(&cfg, &ds).unwrap();
        let p = protocol::train(&cfg, &ds).unwrap();
        assert_eq!(a.w_trace, p.train.w_trace, "n={n} k={k} t={t}");
        assert_eq!(a.w_trace.len(), 1, "closed form = exactly one snapshot");

        // The secure β matches the cleartext ridge solve on the *quantized*
        // data coefficient-wise: field moments are exact sums of products of
        // multiples of 2^-lx (exactly representable in f64), both sides run
        // the same public `solve_normal_equations`, so the only divergence
        // is the final l_w = 9 weight rounding (≤ 2^-10 per coefficient).
        let q = |v: f64| {
            quant::round_half_away(v * (1 << cfg.plan.lx) as f64) as f64
                / (1u64 << cfg.plan.lx) as f64
        };
        let xq: Vec<f64> = ds.x.iter().map(|&v| q(v)).collect();
        let yq: Vec<f64> = ds.y.iter().map(|&v| q(v)).collect();
        let beta = ridge_regression(&xq, &yq, ds.d);
        assert_eq!(a.w.len(), beta.len());
        for (j, (&s, &c)) in a.w.iter().zip(&beta).enumerate() {
            assert!((s - c).abs() < 2e-3, "β[{j}]: secure {s:.5} vs cleartext {c:.5}");
        }
        // Regression metric set: R² present, classification metrics absent.
        assert!(p.train.test_metrics.r2.is_some());
        assert!(p.train.test_metrics.accuracy.is_none());
        assert!(p.train.test_metrics.auc.is_none());
    }
}

#[test]
fn linreg_r2_tracks_cleartext_reference() {
    // The fig4-tolerance assertion on a real CSV set lives in the
    // `fig_models` bench (breast.csv, m = 569, where data-quantization
    // noise averages out); here the 48-row synthetic set only supports a
    // ballpark bound against the exact-data reference.
    let ds = Dataset::synth(SynthSpec::tiny(), 205);
    let mut cfg = zoo_cfg(ModelKind::Linreg, &ds, 7, 2, 1, 1, 205);
    cfg.plan = FpPlan::headroom();
    let secure = algo::train(&cfg, &ds).unwrap();
    let reference = ModelKind::Linreg.model().reference(&ds, 1, cfg.eta, None);
    let s = *secure.test_accuracy.last().unwrap();
    let r = *reference.test_accuracy.last().unwrap();
    assert!((s - r).abs() < 0.2, "secure R² {s:.4} vs cleartext {r:.4} diverged");
}

#[test]
fn default_model_stays_logreg_and_logreg_trace_is_stable() {
    // The zoo must not move the default workload: an explicit
    // `ModelKind::Logreg` run matches the implicit-default run bit for bit.
    let ds = Dataset::synth(SynthSpec::tiny(), 206);
    let implicit = zoo_cfg(ModelKind::default(), &ds, 7, 2, 1, 3, 206);
    assert_eq!(implicit.model, ModelKind::Logreg);
    let mut explicit = implicit.clone();
    explicit.model = ModelKind::Logreg;
    assert_eq!(
        algo::train(&implicit, &ds).unwrap().w_trace,
        protocol::train(&explicit, &ds).unwrap().train.w_trace,
    );
}
