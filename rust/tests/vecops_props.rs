//! Property tests for `field::vecops` (and the `field::par` parallel
//! variants) against a naive per-element `u128` modular reference, with
//! deliberate stress at the **accumulation-budget boundaries** of
//! Appendix A: vector lengths and term counts of `budget − 1`, `budget`,
//! `budget + 1`, zero coefficients (the skip path), and saturated
//! `p − 1` inputs (maximal accumulator pressure).

use copml::field::{par, vecops, Field, MatShape, Parallelism, P25, P26, P31};
use copml::testkit::{forall, Gen};

/// The primes under test: paper-parity (budget ≈ 4096/8192) and the
/// headroom prime (budget = 4, forcing mid-sum reductions constantly).
const PRIMES: [u64; 4] = [97, P25, P26, P31];

fn dot_naive(p: u64, a: &[u64], b: &[u64]) -> u64 {
    let mut acc = 0u128;
    for (&x, &y) in a.iter().zip(b) {
        acc = (acc + x as u128 * y as u128) % p as u128;
    }
    acc as u64
}

fn weighted_sum_naive(p: u64, coeffs: &[u64], mats: &[&[u64]], n: usize) -> Vec<u64> {
    (0..n)
        .map(|i| {
            let mut acc = 0u128;
            for (&c, m) in coeffs.iter().zip(mats) {
                acc = (acc + c as u128 * m[i] as u128) % p as u128;
            }
            acc as u64
        })
        .collect()
}

fn axpy_naive(p: u64, out: &[u64], c: u64, x: &[u64]) -> Vec<u64> {
    out.iter()
        .zip(x)
        .map(|(&o, &v)| ((o as u128 + c as u128 * v as u128) % p as u128) as u64)
        .collect()
}

/// Lengths straddling the accumulation budget, clamped to something that
/// stays fast for the big-budget primes.
fn boundary_lengths(f: Field) -> Vec<usize> {
    let b = f.accum_budget().min(8192);
    vec![1, b.saturating_sub(1).max(1), b, b + 1, 2 * b + 3]
}

/// Value generator mixing uniform elements with saturated `p − 1` runs and
/// zeros — the extremes the budget discipline must survive.
fn stress_vec(g: &mut Gen, p: u64, n: usize) -> Vec<u64> {
    match g.usize_in(0, 2) {
        0 => g.vec_u64(n, p),
        1 => vec![p - 1; n],
        _ => (0..n)
            .map(|i| if i % 3 == 0 { 0 } else { p - 1 })
            .collect(),
    }
}

#[test]
fn prop_dot_budget_boundaries() {
    forall("dot at budget boundaries", 60, |g| {
        let f = Field::new(*g.choose(&PRIMES));
        let p = f.modulus();
        let n = *g.choose(&boundary_lengths(f));
        let a = stress_vec(g, p, n);
        let b = stress_vec(g, p, n);
        assert_eq!(
            vecops::dot(f, &a, &b),
            dot_naive(p, &a, &b),
            "p={p} n={n} budget={}",
            f.accum_budget()
        );
    });
}

#[test]
fn prop_weighted_sum_budget_boundaries() {
    // Term counts straddle the budget (the reduction trigger in
    // weighted_sum counts accumulated *terms*, not elements).
    forall("weighted_sum at budget boundaries", 30, |g| {
        let f = Field::new(*g.choose(&[P26, P31]));
        let p = f.modulus();
        let b = f.accum_budget().min(24);
        let k = *g.choose(&[1usize, b.saturating_sub(1).max(1), b, b + 1]);
        let n = g.usize_in(1, 300);
        let mats: Vec<Vec<u64>> = (0..k).map(|_| stress_vec(g, p, n)).collect();
        // Sprinkle zero coefficients: they must be skipped without
        // consuming accumulation budget or perturbing the result.
        let coeffs: Vec<u64> =
            (0..k).map(|_| if g.bool() { 0 } else { g.u64_below(p) }).collect();
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; n];
        vecops::weighted_sum(f, &coeffs, &views, &mut out);
        assert_eq!(out, weighted_sum_naive(p, &coeffs, &views, n), "p={p} k={k} n={n}");
    });
}

#[test]
fn prop_weighted_sum_all_max_terms_and_elements() {
    // Worst case everywhere: K+T terms of all-(p−1) matrices with (p−1)
    // coefficients, crossing the budget, for the tight-budget prime.
    let f = Field::new(P31);
    let p = f.modulus();
    let b = f.accum_budget(); // 4
    for k in [b - 1, b, b + 1, 3 * b + 1] {
        let n = 100;
        let mats: Vec<Vec<u64>> = (0..k).map(|_| vec![p - 1; n]).collect();
        let coeffs = vec![p - 1; k];
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; n];
        vecops::weighted_sum(f, &coeffs, &views, &mut out);
        assert_eq!(out, weighted_sum_naive(p, &coeffs, &views, n), "k={k}");
    }
}

#[test]
fn prop_axpy_matches_naive() {
    forall("axpy vs naive", 80, |g| {
        let f = Field::new(*g.choose(&PRIMES));
        let p = f.modulus();
        let n = g.usize_in(1, 500);
        let out0 = stress_vec(g, p, n);
        let x = stress_vec(g, p, n);
        let c = if g.bool() { p - 1 } else { g.u64_below(p) };
        let mut out = out0.clone();
        vecops::axpy(f, &mut out, c, &x);
        assert_eq!(out, axpy_naive(p, &out0, c, &x), "p={p} c={c}");
    });
}

#[test]
fn prop_matvec_and_transpose_budget_rows() {
    // Row counts straddling the budget exercise matvec_t's mid-loop
    // reduction; saturated inputs maximize accumulator pressure.
    forall("matvec/matvec_t at budget rows", 30, |g| {
        let f = Field::new(*g.choose(&[P26, P31]));
        let p = f.modulus();
        let b = f.accum_budget().min(64);
        let rows = *g.choose(&[1usize, b.saturating_sub(1).max(1), b, b + 1]);
        let cols = g.usize_in(1, 24);
        let a = stress_vec(g, p, rows * cols);
        let x = stress_vec(g, p, cols);
        let v = stress_vec(g, p, rows);
        let shape = MatShape::new(rows, cols);
        let y = vecops::matvec(f, &a, shape, &x);
        for r in 0..rows {
            assert_eq!(y[r], dot_naive(p, &a[r * cols..(r + 1) * cols], &x), "row {r}");
        }
        let yt = vecops::matvec_t(f, &a, shape, &v);
        for j in 0..cols {
            let col: Vec<u64> = (0..rows).map(|r| a[r * cols + j]).collect();
            assert_eq!(yt[j], dot_naive(p, &col, &v), "col {j}");
        }
    });
}

#[test]
fn prop_parallel_variants_bit_identical() {
    // The parallel layer must agree with the sequential kernels bit for
    // bit on arbitrary shapes and thread counts (including shapes around
    // the fan-out threshold, where some calls parallelize and some fall
    // back).
    forall("par variants == sequential", 12, |g| {
        let f = Field::new(*g.choose(&[P26, P31]));
        let p = f.modulus();
        let threads = g.usize_in(2, 8);
        let pp = Parallelism::threads(threads);

        let n = *g.choose(&[1000usize, 16_384, 40_000]);
        let k = g.usize_in(1, 9);
        let mats: Vec<Vec<u64>> = (0..k).map(|_| stress_vec(g, p, n)).collect();
        let coeffs: Vec<u64> =
            (0..k).map(|_| if g.bool() { 0 } else { g.u64_below(p) }).collect();
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut seq = vec![0u64; n];
        vecops::weighted_sum(f, &coeffs, &views, &mut seq);
        let mut parout = vec![0u64; n];
        par::weighted_sum(f, pp, &coeffs, &views, &mut parout);
        assert_eq!(parout, seq, "weighted_sum p={p} n={n} threads={threads}");

        let rows = g.usize_in(1, 600);
        let cols = g.usize_in(1, 70);
        let a = stress_vec(g, p, rows * cols);
        let x = stress_vec(g, p, cols);
        let v = stress_vec(g, p, rows);
        let shape = MatShape::new(rows, cols);
        assert_eq!(
            par::matvec(f, pp, &a, shape, &x),
            vecops::matvec(f, &a, shape, &x),
            "matvec {rows}x{cols} threads={threads}"
        );
        assert_eq!(
            par::matvec_t(f, pp, &a, shape, &v),
            vecops::matvec_t(f, &a, shape, &v),
            "matvec_t {rows}x{cols} threads={threads}"
        );
    });
}
