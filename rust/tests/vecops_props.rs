//! Property tests for `field::vecops` (and the `field::par` parallel
//! variants) against a naive per-element `u128` modular reference, with
//! deliberate stress at the **accumulation-budget boundaries** of
//! Appendix A: vector lengths and term counts of `budget − 1`, `budget`,
//! `budget + 1`, zero coefficients (the skip path), and saturated
//! `p − 1` inputs (maximal accumulator pressure).

use copml::field::{par, vecops, Field, KernelTier, MatShape, MontField, Parallelism, P25, P26, P31};
use copml::testkit::{forall, Gen};

/// The primes under test: paper-parity (budget ≈ 4096/8192) and the
/// headroom prime (budget = 4, forcing mid-sum reductions constantly).
const PRIMES: [u64; 4] = [97, P25, P26, P31];

fn dot_naive(p: u64, a: &[u64], b: &[u64]) -> u64 {
    let mut acc = 0u128;
    for (&x, &y) in a.iter().zip(b) {
        acc = (acc + x as u128 * y as u128) % p as u128;
    }
    acc as u64
}

fn weighted_sum_naive(p: u64, coeffs: &[u64], mats: &[&[u64]], n: usize) -> Vec<u64> {
    (0..n)
        .map(|i| {
            let mut acc = 0u128;
            for (&c, m) in coeffs.iter().zip(mats) {
                acc = (acc + c as u128 * m[i] as u128) % p as u128;
            }
            acc as u64
        })
        .collect()
}

fn axpy_naive(p: u64, out: &[u64], c: u64, x: &[u64]) -> Vec<u64> {
    out.iter()
        .zip(x)
        .map(|(&o, &v)| ((o as u128 + c as u128 * v as u128) % p as u128) as u64)
        .collect()
}

/// Lengths straddling the accumulation budget, clamped to something that
/// stays fast for the big-budget primes.
fn boundary_lengths(f: Field) -> Vec<usize> {
    let b = f.accum_budget().min(8192);
    vec![1, b.saturating_sub(1).max(1), b, b + 1, 2 * b + 3]
}

/// Value generator mixing uniform elements with saturated `p − 1` runs and
/// zeros — the extremes the budget discipline must survive.
fn stress_vec(g: &mut Gen, p: u64, n: usize) -> Vec<u64> {
    match g.usize_in(0, 2) {
        0 => g.vec_u64(n, p),
        1 => vec![p - 1; n],
        _ => (0..n)
            .map(|i| if i % 3 == 0 { 0 } else { p - 1 })
            .collect(),
    }
}

#[test]
fn prop_dot_budget_boundaries() {
    forall("dot at budget boundaries", 60, |g| {
        let f = Field::new(*g.choose(&PRIMES));
        let p = f.modulus();
        let n = *g.choose(&boundary_lengths(f));
        let a = stress_vec(g, p, n);
        let b = stress_vec(g, p, n);
        assert_eq!(
            vecops::dot(f, &a, &b),
            dot_naive(p, &a, &b),
            "p={p} n={n} budget={}",
            f.accum_budget()
        );
    });
}

#[test]
fn prop_weighted_sum_budget_boundaries() {
    // Term counts straddle the budget (the reduction trigger in
    // weighted_sum counts accumulated *terms*, not elements).
    forall("weighted_sum at budget boundaries", 30, |g| {
        let f = Field::new(*g.choose(&[P26, P31]));
        let p = f.modulus();
        let b = f.accum_budget().min(24);
        let k = *g.choose(&[1usize, b.saturating_sub(1).max(1), b, b + 1]);
        let n = g.usize_in(1, 300);
        let mats: Vec<Vec<u64>> = (0..k).map(|_| stress_vec(g, p, n)).collect();
        // Sprinkle zero coefficients: they must be skipped without
        // consuming accumulation budget or perturbing the result.
        let coeffs: Vec<u64> =
            (0..k).map(|_| if g.bool() { 0 } else { g.u64_below(p) }).collect();
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; n];
        vecops::weighted_sum(f, &coeffs, &views, &mut out);
        assert_eq!(out, weighted_sum_naive(p, &coeffs, &views, n), "p={p} k={k} n={n}");
    });
}

#[test]
fn prop_weighted_sum_all_max_terms_and_elements() {
    // Worst case everywhere: K+T terms of all-(p−1) matrices with (p−1)
    // coefficients, crossing the budget, for the tight-budget prime.
    let f = Field::new(P31);
    let p = f.modulus();
    let b = f.accum_budget(); // 4
    for k in [b - 1, b, b + 1, 3 * b + 1] {
        let n = 100;
        let mats: Vec<Vec<u64>> = (0..k).map(|_| vec![p - 1; n]).collect();
        let coeffs = vec![p - 1; k];
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; n];
        vecops::weighted_sum(f, &coeffs, &views, &mut out);
        assert_eq!(out, weighted_sum_naive(p, &coeffs, &views, n), "k={k}");
    }
}

#[test]
fn prop_axpy_matches_naive() {
    forall("axpy vs naive", 80, |g| {
        let f = Field::new(*g.choose(&PRIMES));
        let p = f.modulus();
        let n = g.usize_in(1, 500);
        let out0 = stress_vec(g, p, n);
        let x = stress_vec(g, p, n);
        let c = if g.bool() { p - 1 } else { g.u64_below(p) };
        let mut out = out0.clone();
        vecops::axpy(f, &mut out, c, &x);
        assert_eq!(out, axpy_naive(p, &out0, c, &x), "p={p} c={c}");
    });
}

#[test]
fn prop_matvec_and_transpose_budget_rows() {
    // Row counts straddling the budget exercise matvec_t's mid-loop
    // reduction; saturated inputs maximize accumulator pressure.
    forall("matvec/matvec_t at budget rows", 30, |g| {
        let f = Field::new(*g.choose(&[P26, P31]));
        let p = f.modulus();
        let b = f.accum_budget().min(64);
        let rows = *g.choose(&[1usize, b.saturating_sub(1).max(1), b, b + 1]);
        let cols = g.usize_in(1, 24);
        let a = stress_vec(g, p, rows * cols);
        let x = stress_vec(g, p, cols);
        let v = stress_vec(g, p, rows);
        let shape = MatShape::new(rows, cols);
        let y = vecops::matvec(f, &a, shape, &x);
        for r in 0..rows {
            assert_eq!(y[r], dot_naive(p, &a[r * cols..(r + 1) * cols], &x), "row {r}");
        }
        let yt = vecops::matvec_t(f, &a, shape, &v);
        for j in 0..cols {
            let col: Vec<u64> = (0..rows).map(|r| a[r * cols + j]).collect();
            assert_eq!(yt[j], dot_naive(p, &col, &v), "col {j}");
        }
    });
}

/// Adversarial lengths for the Montgomery ≡ Barrett grid: the empty and
/// singleton cases, the lane-block edges of the 8-wide kernels, and the
/// accumulation-budget boundary (clamped as in [`boundary_lengths`]).
fn mont_grid_lengths(f: Field) -> Vec<usize> {
    let b = f.accum_budget().min(8192);
    let l = vecops::LANES;
    vec![0, 1, l - 1, l, l + 1, b, b + 1]
}

#[test]
fn prop_mont_kernels_bit_identical_to_barrett() {
    // The Montgomery tier is value-transparent: on canonical inputs every
    // kernel must agree with the Barrett oracle bit for bit, at every
    // lane-block and budget boundary, under saturated (p − 1) pressure.
    forall("mont == barrett grid", 40, |g| {
        let f = Field::new(*g.choose(&[P25, P26, P31]));
        let p = f.modulus();
        let mf = MontField::new(f);
        let n = *g.choose(&mont_grid_lengths(f));

        let a = stress_vec(g, p, n);
        let b = stress_vec(g, p, n);
        let bm = mf.to_mont_vec(&b);
        assert_eq!(
            mf.dot_premont(&a, &bm),
            vecops::dot(f, &a, &b),
            "dot p={p} n={n} budget={}",
            f.accum_budget()
        );

        // matvec / matvec_t with `n` rows (the matvec_t flush boundary is
        // per-row, so row count is the adversarial axis).
        let cols = g.usize_in(1, 2 * vecops::LANES + 1);
        let m = stress_vec(g, p, n * cols);
        let x = stress_vec(g, p, cols);
        let v = stress_vec(g, p, n);
        let shape = MatShape::new(n, cols);
        assert_eq!(
            mf.matvec(&m, shape, &x),
            vecops::matvec(f, &m, shape, &x),
            "matvec {n}x{cols} p={p}"
        );
        assert_eq!(
            mf.matvec_t(&m, shape, &v),
            vecops::matvec_t(f, &m, shape, &v),
            "matvec_t {n}x{cols} p={p}"
        );

        // weighted_sum with a budget-straddling term count and zero
        // coefficients (the skip path must stay tier-invariant).
        let kb = f.accum_budget().min(24);
        let k = *g.choose(&[1usize, kb, kb + 1]);
        let wn = g.usize_in(1, 200);
        let mats: Vec<Vec<u64>> = (0..k).map(|_| stress_vec(g, p, wn)).collect();
        let coeffs: Vec<u64> =
            (0..k).map(|_| if g.bool() { 0 } else { g.u64_below(p) }).collect();
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut barrett = vec![0u64; wn];
        vecops::weighted_sum(f, &coeffs, &views, &mut barrett);
        let mut mont = vec![0u64; wn];
        mf.weighted_sum_premont(&mf.to_mont_vec(&coeffs), &views, &mut mont);
        assert_eq!(mont, barrett, "weighted_sum p={p} k={k} n={wn}");

        // Polynomial evaluation, including the empty map (≡ zero) and the
        // degree-0 constant.
        let deg = g.usize_in(0, 4);
        let pc = stress_vec(g, p, deg + 1);
        let mut zb = stress_vec(g, p, n);
        let mut zm = zb.clone();
        vecops::poly_eval_assign(f, &pc, &mut zb);
        mf.poly_eval_assign(&pc, &mut zm);
        assert_eq!(zm, zb, "poly_eval deg={deg} p={p} n={n}");
        let mut ze = zb.clone();
        mf.poly_eval_assign(&[], &mut ze);
        assert!(ze.iter().all(|&v| v == 0), "empty poly must map to zero");
    });
}

#[test]
fn prop_mont_tier_dispatchers_bit_identical() {
    // The `field::par` tier entry points with `KernelTier::Mont` must agree
    // with the Barrett tier across thread counts and fan-out shapes.
    forall("par tier mont == barrett", 10, |g| {
        let f = Field::new(*g.choose(&[P26, P31]));
        let p = f.modulus();
        let pp = Parallelism::threads(g.usize_in(1, 6));

        let rows = g.usize_in(1, 400);
        let cols = g.usize_in(1, 60);
        let a = stress_vec(g, p, rows * cols);
        let x = stress_vec(g, p, cols);
        let v = stress_vec(g, p, rows);
        let shape = MatShape::new(rows, cols);
        assert_eq!(
            par::matvec_tier(f, KernelTier::Mont, pp, &a, shape, &x),
            par::matvec_tier(f, KernelTier::Barrett, pp, &a, shape, &x),
            "matvec_tier {rows}x{cols} p={p}"
        );
        assert_eq!(
            par::matvec_t_tier(f, KernelTier::Mont, pp, &a, shape, &v),
            par::matvec_t_tier(f, KernelTier::Barrett, pp, &a, shape, &v),
            "matvec_t_tier {rows}x{cols} p={p}"
        );

        let n = *g.choose(&[257usize, 16_384]);
        let k = g.usize_in(1, 7);
        let mats: Vec<Vec<u64>> = (0..k).map(|_| stress_vec(g, p, n)).collect();
        let coeffs: Vec<u64> = (0..k).map(|_| g.u64_below(p)).collect();
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut barrett = vec![0u64; n];
        par::weighted_sum_tier(f, KernelTier::Barrett, pp, &coeffs, &views, &mut barrett);
        let mut mont = vec![0u64; n];
        par::weighted_sum_tier(f, KernelTier::Mont, pp, &coeffs, &views, &mut mont);
        assert_eq!(mont, barrett, "weighted_sum_tier p={p} k={k} n={n}");

        let pc = stress_vec(g, p, g.usize_in(1, 4));
        let mut zb = stress_vec(g, p, n);
        let mut zm = zb.clone();
        par::poly_eval_assign_tier(f, KernelTier::Barrett, pp, &pc, &mut zb);
        par::poly_eval_assign_tier(f, KernelTier::Mont, pp, &pc, &mut zm);
        assert_eq!(zm, zb, "poly_eval_assign_tier p={p} n={n}");
    });
}

#[test]
fn prop_parallel_variants_bit_identical() {
    // The parallel layer must agree with the sequential kernels bit for
    // bit on arbitrary shapes and thread counts (including shapes around
    // the fan-out threshold, where some calls parallelize and some fall
    // back).
    forall("par variants == sequential", 12, |g| {
        let f = Field::new(*g.choose(&[P26, P31]));
        let p = f.modulus();
        let threads = g.usize_in(2, 8);
        let pp = Parallelism::threads(threads);

        let n = *g.choose(&[1000usize, 16_384, 40_000]);
        let k = g.usize_in(1, 9);
        let mats: Vec<Vec<u64>> = (0..k).map(|_| stress_vec(g, p, n)).collect();
        let coeffs: Vec<u64> =
            (0..k).map(|_| if g.bool() { 0 } else { g.u64_below(p) }).collect();
        let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut seq = vec![0u64; n];
        vecops::weighted_sum(f, &coeffs, &views, &mut seq);
        let mut parout = vec![0u64; n];
        par::weighted_sum(f, pp, &coeffs, &views, &mut parout);
        assert_eq!(parout, seq, "weighted_sum p={p} n={n} threads={threads}");

        let rows = g.usize_in(1, 600);
        let cols = g.usize_in(1, 70);
        let a = stress_vec(g, p, rows * cols);
        let x = stress_vec(g, p, cols);
        let v = stress_vec(g, p, rows);
        let shape = MatShape::new(rows, cols);
        assert_eq!(
            par::matvec(f, pp, &a, shape, &x),
            vecops::matvec(f, &a, shape, &x),
            "matvec {rows}x{cols} threads={threads}"
        );
        assert_eq!(
            par::matvec_t(f, pp, &a, shape, &v),
            vecops::matvec_t(f, &a, shape, &v),
            "matvec_t {rows}x{cols} threads={threads}"
        );
    });
}
