//! Straggler-resilience integration tests: the quorum-based online phase
//! (first-arrival gathers, leader-agreed subsets, roster exclusion) must
//! (a) leave every trajectory bit-identical — interpolation from any
//! `need`-subset is exact (Theorem 1) — with and without faults, on both
//! transports, both wire formats, and both offline modes; and (b) leave
//! every mailbox empty after clean runs (tag-leak / tombstone hygiene).

use copml::coordinator::{algo, protocol, CaseParams, CopmlConfig, FaultPlan};
use copml::data::{Dataset, SynthSpec};
use copml::mpc::OfflineMode;
use copml::net::{Wire, ELEM_BYTES};

/// N=10, K=2, T=1 → recovery threshold 7, slack 3: the first-arrival
/// quorum path is ACTIVE on every round (unlike the legacy zero-slack
/// fixtures, where the gather is forced to the full roster).
fn slack_cfg(seed: u64, ds: &Dataset, iters: usize) -> CopmlConfig {
    let mut cfg = CopmlConfig::for_dataset(ds, 10, CaseParams::explicit(2, 1), seed);
    cfg.iters = iters;
    cfg
}

#[test]
fn quorum_slack_runs_match_algo_on_hub_and_tcp_both_wires() {
    // No faults, but real nondeterministic quorum composition: whichever
    // 7 of 10 answer first decode each round. The trace must still be
    // bit-identical to the central recursion, and no mailbox may leak.
    let ds = Dataset::synth(SynthSpec::tiny(), 301);
    let cfg = slack_cfg(301, &ds, 3);
    let need = cfg.recovery_threshold();
    assert!(cfg.n > need, "fixture must have quorum slack");
    let reference = algo::train(&cfg, &ds).unwrap();
    for wire in [Wire::U64, Wire::U32] {
        let mut c = cfg.clone();
        c.wire = wire;
        let hub = protocol::train(&c, &ds).unwrap();
        assert_eq!(hub.train.w_trace, reference.w_trace, "hub, {wire} wire");
        let tcp = protocol::train_tcp_loopback(&c, &ds).unwrap();
        assert_eq!(tcp.train.w_trace, reference.w_trace, "tcp, {wire} wire");
        for (i, l) in hub.ledgers.iter().chain(tcp.ledgers.iter()).enumerate() {
            assert_eq!(l.pending_at_exit, 0, "client {i}: mailbox leak ({wire} wire)");
            assert_eq!(l.quorums.len(), c.iters, "client {i}: missing quorum records");
            for q in &l.quorums {
                assert_eq!(q.len(), need, "client {i}: quorum must be exactly `need`");
            }
            assert!(l.excluded.is_empty(), "client {i}: spurious exclusion");
        }
    }
}

#[test]
fn quorum_slack_with_distributed_offline_is_transport_invariant() {
    // The dealer-free offline phase under a slack config: Hub and TCP
    // must agree bit for bit, offline traffic must be ledgered, and the
    // offline tags must be fully drained.
    let ds = Dataset::synth(SynthSpec::tiny(), 302);
    let mut cfg = slack_cfg(302, &ds, 2);
    cfg.offline = OfflineMode::Distributed;
    let hub = protocol::train(&cfg, &ds).unwrap();
    let tcp = protocol::train_tcp_loopback(&cfg, &ds).unwrap();
    assert_eq!(
        hub.train.w_trace, tcp.train.w_trace,
        "distributed offline + quorum gathers must be transport-invariant"
    );
    for (i, l) in hub.ledgers.iter().chain(tcp.ledgers.iter()).enumerate() {
        assert!(l.bytes[0] > 0, "client {i}: no offline traffic recorded");
        assert_eq!(l.pending_at_exit, 0, "client {i}: offline tags not drained");
    }
}

#[test]
fn mailbox_hygiene_on_no_slack_configs() {
    // The legacy fixed-order path (live == need): every party's mailbox —
    // queues AND forget-tombstones — must be empty at exit, on both
    // transports and with the distributed offline phase (regression guard
    // for the PR-2 tag-leak class).
    let ds = Dataset::synth(SynthSpec::tiny(), 303);
    let mut cfg = CopmlConfig::for_dataset(&ds, 7, CaseParams::explicit(2, 1), 303);
    cfg.iters = 3;
    assert_eq!(cfg.n, cfg.recovery_threshold(), "fixture must have zero slack");
    let hub = protocol::train(&cfg, &ds).unwrap();
    let tcp = protocol::train_tcp_loopback(&cfg, &ds).unwrap();
    cfg.offline = OfflineMode::Distributed;
    let dist = protocol::train(&cfg, &ds).unwrap();
    for (label, po) in [("hub", &hub), ("tcp", &tcp), ("hub distributed-offline", &dist)] {
        for (i, l) in po.ledgers.iter().enumerate() {
            assert_eq!(l.pending_at_exit, 0, "{label}: client {i} mailbox not drained");
        }
    }
}

#[test]
fn delayed_and_killed_parties_leave_the_trace_bit_identical() {
    // Acceptance: one party delayed far past the round time (a SUSTAINED
    // live straggler — N=11's tail subgroup {8,9,10} stays
    // reconstructable after the kill, so party 8 keeps running and is
    // excluded via --max-lag, exercising the self-exclusion path), plus
    // one party killed mid-training (slack 4 ≥ 2) — training completes,
    // both get excluded, and the trace matches the fault-free central
    // recursion bit for bit on Hub AND real sockets.
    let ds = Dataset::synth(SynthSpec::tiny(), 304);
    let mut clean = CopmlConfig::for_dataset(&ds, 11, CaseParams::explicit(2, 1), 304);
    clean.iters = 6;
    let reference = algo::train(&clean, &ds).unwrap();
    // Exclusion requires the injected delay to exceed a whole round (the
    // one-round grace): derive it from a measured healthy run instead of
    // hard-coding, so a loaded CI runner cannot make misses vanish.
    let healthy = protocol::train(&clean, &ds).unwrap();
    let healthy_iter_s =
        healthy.ledgers[0].seconds[4..8].iter().sum::<f64>() / clean.iters as f64;
    let delay_ms = ((healthy_iter_s * 20.0) * 1e3).ceil().max(100.0) as u64;
    let mut cfg = clean.clone();
    cfg.faults = FaultPlan { delays: vec![(8, delay_ms)], kills: vec![(10, 1)] };
    cfg.max_lag = Some(2);
    for (label, run) in [
        ("hub", protocol::train(&cfg, &ds).unwrap()),
        ("tcp", protocol::train_tcp_loopback(&cfg, &ds).unwrap()),
    ] {
        assert_eq!(
            run.train.w_trace, reference.w_trace,
            "{label}: faults may cost time, never accuracy"
        );
        let king = &run.ledgers[0];
        assert!(
            king.excluded.contains(&8),
            "{label}: delayed party must be excluded, got {:?}",
            king.excluded
        );
        assert!(
            king.excluded.contains(&10),
            "{label}: killed party must be excluded, got {:?}",
            king.excluded
        );
        // After the exclusions the roster still fills the threshold.
        let last_quorum = king.quorums.last().unwrap();
        assert!(last_quorum.len() >= cfg.recovery_threshold());
        assert!(!last_quorum.contains(&8) && !last_quorum.contains(&10));
    }
}

#[test]
fn minibatch_composes_with_faults_bit_identically() {
    // Batching × straggler machinery: a mini-batch run that loses one
    // party mid-training (excluded via --max-lag) must still match the
    // fault-free central recursion bit for bit — the decoded per-batch
    // gradient is an exact interpolation from whichever quorum answers.
    let ds = Dataset::synth(SynthSpec::tiny(), 308);
    let mut clean = CopmlConfig::for_dataset(&ds, 11, CaseParams::explicit(2, 1), 308);
    clean.iters = 6;
    clean.batches = 2;
    let reference = algo::train(&clean, &ds).unwrap();
    let mut cfg = clean.clone();
    cfg.faults = FaultPlan { delays: vec![], kills: vec![(10, 2)] };
    cfg.max_lag = Some(2);
    let run = protocol::train(&cfg, &ds).unwrap();
    assert_eq!(
        run.train.w_trace, reference.w_trace,
        "mini-batch + kill: faults may cost time, never accuracy"
    );
    assert!(
        run.ledgers[0].excluded.contains(&10),
        "killed party must be excluded: {:?}",
        run.ledgers[0].excluded
    );
}

#[test]
fn fault_plans_that_cannot_fill_a_quorum_are_rejected_upfront() {
    // Killing 3 parties also strands their 3 subgroup mates (a group
    // below T+1 live members cannot reconstruct its encodings): 6 lost >
    // slack 3. validate counts the collateral and rejects the plan with
    // a clear error before any thread runs.
    let ds = Dataset::synth(SynthSpec::tiny(), 306);
    let mut cfg = slack_cfg(306, &ds, 4);
    cfg.faults.kills = vec![(5, 0), (7, 0), (9, 0)];
    cfg.max_lag = Some(1);
    let err = protocol::train(&cfg, &ds).unwrap_err();
    assert!(err.contains("collateral"), "unexpected error: {err}");
}

#[test]
fn fault_plan_validation_is_clear() {
    let ds = Dataset::synth(SynthSpec::tiny(), 305);
    // kills without exclusion armed
    let mut cfg = slack_cfg(305, &ds, 2);
    cfg.faults.kills = vec![(9, 0)];
    let err = protocol::train(&cfg, &ds).unwrap_err();
    assert!(err.contains("max-lag"), "{err}");
    // faults may not target the king / quorum leader
    let mut cfg = slack_cfg(305, &ds, 2);
    cfg.faults.delays = vec![(0, 10)];
    let err = protocol::train(&cfg, &ds).unwrap_err();
    assert!(err.contains("party 0"), "{err}");
    // more faulted parties than Theorem-1 slack
    let mut cfg = slack_cfg(305, &ds, 2);
    cfg.faults.delays = vec![(5, 10), (6, 10), (7, 10), (8, 10)];
    cfg.max_lag = Some(2);
    let err = protocol::train(&cfg, &ds).unwrap_err();
    assert!(err.contains("slack") || err.contains("quorum"), "{err}");
    // naive (subgroups=false) layout: parties ≤ T are everyone's encode
    // sources and may not be faulted
    let mut cfg = slack_cfg(305, &ds, 2);
    cfg.subgroups = false;
    cfg.faults.delays = vec![(1, 10)];
    cfg.max_lag = Some(2);
    let err = protocol::train(&cfg, &ds).unwrap_err();
    assert!(err.contains("encode source"), "{err}");
    // fault injection and exclusion need the full protocol
    let mut cfg = slack_cfg(305, &ds, 2);
    cfg.faults.delays = vec![(3, 10)];
    let err = algo::train(&cfg, &ds).unwrap_err();
    assert!(err.contains("full"), "{err}");
    let mut cfg = slack_cfg(305, &ds, 2);
    cfg.max_lag = Some(2);
    let err = algo::train(&cfg, &ds).unwrap_err();
    assert!(err.contains("full"), "{err}");
    // out-of-range party id
    let mut cfg = slack_cfg(305, &ds, 2);
    cfg.faults.delays = vec![(99, 10)];
    let err = protocol::train(&cfg, &ds).unwrap_err();
    assert!(err.contains("99"), "{err}");
}

#[test]
fn quorum_announcement_bytes_are_exact() {
    // The roster message is the only byte-ledger change of the quorum
    // refactor, and only on slack configs: the king's share_results
    // phase carries (need+2) words to each of the n−1 peers per round;
    // everyone else's ledger is unchanged. (On zero-slack configs the
    // announcement is elided entirely — asserted by the untouched legacy
    // ledger tests.)
    let ds = Dataset::synth(SynthSpec::tiny(), 307);
    let cfg = slack_cfg(307, &ds, 3);
    let (n, need, iters) = (cfg.n as u64, cfg.recovery_threshold() as u64, cfg.iters as u64);
    let out = protocol::train(&cfg, &ds).unwrap();
    let d = ds.d as u64;
    let king = out.ledgers[0].bytes[6];
    let expect_king = ((n - 1) * d + (n - 1) * (need + 2)) * ELEM_BYTES * iters;
    assert_eq!(king, expect_king, "king share_results bytes (results + roster)");
    for (i, l) in out.ledgers.iter().enumerate().skip(1) {
        assert_eq!(
            l.bytes[6],
            (n - 1) * d * ELEM_BYTES * iters,
            "client {i}: non-king share_results bytes must be results only"
        );
    }
}
