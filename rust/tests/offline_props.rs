//! Property tests for the DN07 randomness-extraction core
//! (`mpc::offline`): degree exactness of the extracted sharings, double
//! sharing consistency, and the bijection argument behind the uniformity
//! claim — each over randomized `(N, T)` geometries via `testkit::forall`.

use copml::field::{Field, P26};
use copml::mpc::offline::{extract, extraction_matrix};
use copml::poly;
use copml::shamir;
use copml::testkit::{forall, Gen};

fn field() -> Field {
    Field::new(P26)
}

/// Random geometry with `n > 2t` (what the offline phase requires).
fn geometry(g: &mut Gen) -> (usize, usize) {
    let t = g.usize_in(1, 3);
    let n = g.usize_in(2 * t + 1, 2 * t + 5);
    (n, t)
}

/// Every party's share vector of each dealer's batch: `shares[party][dealer]`.
fn deal_all(
    f: Field,
    g: &mut Gen,
    n: usize,
    deg: usize,
    secrets: &[Vec<u64>],
) -> Vec<Vec<Vec<u64>>> {
    let mut by_party = vec![vec![Vec::new(); n]; n];
    for (j, s) in secrets.iter().enumerate() {
        let sh = shamir::share(f, s, n, deg, g.rng());
        for (i, si) in sh.into_iter().enumerate() {
            by_party[i][j] = si;
        }
    }
    by_party
}

/// Run the extraction on every party's inputs; returns
/// `outputs[party][output_index]` (each a share vector of length L).
fn extract_all(f: Field, n: usize, t: usize, by_party: &[Vec<Vec<u64>>]) -> Vec<Vec<Vec<u64>>> {
    let m = extraction_matrix(f, n, t);
    by_party
        .iter()
        .map(|inputs| {
            let views: Vec<&[u64]> = inputs.iter().map(|v| v.as_slice()).collect();
            extract(f, &m, &views)
        })
        .collect()
}

/// Shares of output `i`, element `e`, across all parties.
fn column(outputs: &[Vec<Vec<u64>>], i: usize, e: usize) -> Vec<u64> {
    outputs.iter().map(|per_party| per_party[i][e]).collect()
}

/// Degree check: the n shares lie on a polynomial of degree ≤ `deg`
/// (interpolating the first deg+1 shares predicts all others).
fn consistent_at_degree(f: Field, shares: &[u64], deg: usize) -> bool {
    let n = shares.len();
    if deg + 1 >= n {
        return true;
    }
    let pts = shamir::lambda_points(n);
    let rows = poly::coeff_matrix(f, &pts[..deg + 1], &pts[deg + 1..]);
    rows.iter().zip(&shares[deg + 1..]).all(|(row, &actual)| {
        let mut acc = 0u64;
        for (&c, &s) in row.iter().zip(&shares[..deg + 1]) {
            acc = f.add(acc, f.mul(c, s));
        }
        acc == actual
    })
}

#[test]
fn extracted_sharings_are_exactly_degree_t() {
    forall("extraction degree T", 40, |g: &mut Gen| {
        let f = field();
        let (n, t) = geometry(g);
        let l = g.usize_in(1, 4);
        let secrets: Vec<Vec<u64>> = (0..n).map(|_| g.vec_u64(l, P26)).collect();
        let outputs = extract_all(f, n, t, &deal_all(f, g, n, t, &secrets));
        for i in 0..n - t {
            for e in 0..l {
                let col = column(&outputs, i, e);
                assert!(
                    consistent_at_degree(f, &col, t),
                    "output {i} elem {e} not degree ≤ {t} (n={n})"
                );
                // Exactly degree t: a degree-(t−1) fit must fail (holds
                // with probability 1 − 1/p per case; seeds are fixed).
                assert!(
                    !consistent_at_degree(f, &col, t - 1),
                    "output {i} elem {e} degenerated below degree {t} (n={n})"
                );
            }
        }
    });
}

#[test]
fn extracted_double_sharings_consistent() {
    forall("double sharing extraction", 30, |g: &mut Gen| {
        let f = field();
        let (n, t) = geometry(g);
        let l = g.usize_in(1, 3);
        // Same dealer values under degree T and 2T — as the protocol deals.
        let secrets: Vec<Vec<u64>> = (0..n).map(|_| g.vec_u64(l, P26)).collect();
        let out_t = extract_all(f, n, t, &deal_all(f, g, n, t, &secrets));
        let out_2t = extract_all(f, n, t, &deal_all(f, g, n, 2 * t, &secrets));
        for i in 0..n - t {
            for e in 0..l {
                let col_t = column(&out_t, i, e);
                let col_2t = column(&out_2t, i, e);
                // Halves: degree exactly T resp. 2T …
                assert!(consistent_at_degree(f, &col_t, t));
                assert!(consistent_at_degree(f, &col_2t, 2 * t));
                assert!(!consistent_at_degree(f, &col_2t, 2 * t - 1), "2T half degenerated");
                // … hiding the same extracted value ρ.
                let sh_t: Vec<Vec<u64>> = col_t.iter().map(|&s| vec![s]).collect();
                let sh_2t: Vec<Vec<u64>> = col_2t.iter().map(|&s| vec![s]).collect();
                let rho_t = shamir::reconstruct(f, &sh_t, t);
                let rho_2t = shamir::reconstruct(f, &sh_2t, 2 * t);
                assert_eq!(rho_t, rho_2t, "double halves disagree (i={i}, e={e}, n={n})");
            }
        }
    });
}

#[test]
fn one_honest_dealer_acts_as_a_bijection() {
    // The DN07 uniformity argument, made concrete: fix every dealer's
    // input except dealer `h`'s (the adversary controls them arbitrarily);
    // the map from dealer h's secret to each extracted value is affine
    // with a nonzero slope (the Vandermonde coefficient), i.e. a bijection
    // of F_p — so a uniform honest input keeps every output uniform.
    forall("honest-dealer bijection", 30, |g: &mut Gen| {
        let f = field();
        let (n, t) = geometry(g);
        let h = g.usize_in(0, n - 1); // the one honest dealer
        let matrix = extraction_matrix(f, n, t);
        // Adversarially fixed contributions for everyone but h.
        let fixed: Vec<u64> = (0..n).map(|_| g.u64_below(P26)).collect();
        let (v1, v2) = (g.u64_below(P26), g.u64_below(P26));
        let extracted_value = |v_h: u64, i: usize| -> u64 {
            let mut acc = 0u64;
            for j in 0..n {
                let s = if j == h { v_h } else { fixed[j] };
                acc = f.add(acc, f.mul(matrix[i][j], s));
            }
            acc
        };
        for (i, row) in matrix.iter().enumerate() {
            // Slope = M[i][h] ≠ 0 (λ_h ≠ 0), so distinct inputs give
            // distinct outputs: the affine map is a bijection.
            assert!(row[h] != 0, "zero Vandermonde coefficient (i={i}, h={h})");
            let (o1, o2) = (extracted_value(v1, i), extracted_value(v2, i));
            assert_eq!(
                f.sub(o1, o2),
                f.mul(row[h], f.sub(v1, v2)),
                "output {i} not affine in the honest input"
            );
            if v1 != v2 {
                assert_ne!(o1, o2, "honest input change must move output {i}");
            }
        }
    });
}

#[test]
fn any_n_minus_t_columns_invertible() {
    // The matrix property the privacy argument rests on: every
    // (N−T)×(N−T) column submatrix of the extraction matrix is
    // invertible, so ANY set of n−t honest dealers (not just one) maps
    // bijectively onto the outputs.
    forall("extraction submatrix rank", 25, |g: &mut Gen| {
        let f = field();
        let (n, t) = geometry(g);
        let matrix = extraction_matrix(f, n, t);
        let e = n - t;
        // Random column subset of size n−t.
        let mut cols: Vec<usize> = (0..n).collect();
        for i in (1..cols.len()).rev() {
            let j = g.usize_in(0, i);
            cols.swap(i, j);
        }
        cols.truncate(e);
        // Gaussian elimination over F_p.
        let mut a: Vec<Vec<u64>> =
            (0..e).map(|r| cols.iter().map(|&c| matrix[r][c]).collect()).collect();
        let mut rank = 0usize;
        for col in 0..e {
            let Some(piv) = (rank..e).find(|&r| a[r][col] != 0) else { continue };
            a.swap(rank, piv);
            let inv = f.inv(a[rank][col]);
            for v in a[rank].iter_mut() {
                *v = f.mul(*v, inv);
            }
            let pivot_row = a[rank].clone();
            for (r, row) in a.iter_mut().enumerate() {
                if r != rank && row[col] != 0 {
                    let factor = row[col];
                    for (v, &pv) in row.iter_mut().zip(&pivot_row) {
                        *v = f.sub(*v, f.mul(factor, pv));
                    }
                }
            }
            rank += 1;
        }
        assert_eq!(rank, e, "singular {e}×{e} submatrix (n={n}, t={t}, cols {cols:?})");
    });
}
