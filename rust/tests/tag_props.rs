//! Tag-space properties of the full protocol (`net::tags`): across a grid
//! of configurations — batch counts, offline modes, quorum slack, socket
//! runtimes — every client must end a clean run with (a) an empty mailbox
//! (`pending_at_exit == 0`: every allocated tag was consumed or forgotten)
//! and (b) zero `(from, tag)` reuse (`tag_reuse == 0`: no two protocol
//! steps ever shared a tag — the dynamic complement of the const-asserted
//! window disjointness in `net::tags`). Debug builds (the `cargo test`
//! default) arm both the mailbox reuse counter and the shared
//! `SpmdTagTrace`, so a divergent allocation sequence fails these tests
//! with a pointed diagnostic instead of a 120 s receive timeout.

use copml::coordinator::{protocol, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::mpc::OfflineMode;
use copml::net::{tags, Runtime};

fn assert_tag_hygiene(out: &protocol::ProtocolOutput, label: &str) {
    assert!(!out.train.w_trace.is_empty(), "{label}: no iterations recorded");
    for (i, l) in out.ledgers.iter().enumerate() {
        assert_eq!(l.pending_at_exit, 0, "{label}: client {i} mailbox not drained");
        assert_eq!(
            l.tag_reuse, 0,
            "{label}: client {i} re-used a (from, tag) key after draining it — \
             two protocol steps shared a tag"
        );
    }
}

#[test]
fn no_tag_reuse_across_batch_offline_and_slack_grid() {
    // Hub transport over the full grid: zero-slack (N == need, fixed-order
    // gathers) and slack-3 (first-arrival quorums active) geometries ×
    // full-batch and B=3 mini-batch schedules × both offline providers.
    let ds = Dataset::synth(SynthSpec::tiny(), 401);
    for (n, slack_label) in [(7usize, "zero-slack"), (10, "slack-3")] {
        for batches in [1usize, 3] {
            for offline in [OfflineMode::Dealer, OfflineMode::Distributed] {
                let mut cfg =
                    CopmlConfig::for_dataset(&ds, n, CaseParams::explicit(2, 1), 401);
                cfg.iters = 3;
                cfg.batches = batches;
                cfg.offline = offline;
                let label = format!("{slack_label} B={batches} offline={offline}");
                cfg.validate(&ds).unwrap_or_else(|e| panic!("{label}: {e}"));
                let out = protocol::train(&cfg, &ds)
                    .unwrap_or_else(|e| panic!("{label}: {e}"));
                assert_tag_hygiene(&out, &label);
            }
        }
    }
}

#[test]
fn no_tag_reuse_on_tcp_under_both_runtimes() {
    // The socket transport drains peers into the same tagged mailbox via
    // reader threads or the poll reactor — tag hygiene must hold under
    // both, and the trajectories must agree.
    let ds = Dataset::synth(SynthSpec::tiny(), 402);
    let mut traces = Vec::new();
    for runtime in [Runtime::Threaded, Runtime::Event] {
        let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::explicit(2, 1), 402);
        cfg.iters = 3;
        cfg.runtime = runtime;
        let out = protocol::train_tcp_loopback(&cfg, &ds)
            .unwrap_or_else(|e| panic!("tcp {runtime}: {e}"));
        assert_tag_hygiene(&out, &format!("tcp {runtime}"));
        traces.push(out.train.w_trace);
    }
    assert_eq!(traces[0], traces[1], "runtimes must be value-transparent");
}

#[test]
fn no_tag_reuse_under_straggler_delays() {
    // A delayed party shifts real-time arrival order without changing the
    // SPMD allocation order — first-arrival gathers then consume tags in
    // nondeterministic wall-clock order, which is exactly the scenario the
    // reuse counter must stay silent on.
    let ds = Dataset::synth(SynthSpec::tiny(), 403);
    let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::explicit(2, 1), 403);
    cfg.iters = 4;
    cfg.faults.delays = vec![(8, 15)];
    let out = protocol::train(&cfg, &ds).expect("delayed run must complete");
    assert_tag_hygiene(&out, "delay 8:15ms");
}

#[test]
fn validate_rejects_configs_past_the_tag_windows() {
    // Satellite of the typed tag-space refactor: a config that would
    // exhaust a tag window mid-run is rejected up front with the budget
    // named, instead of panicking inside the allocator hours in.
    let ds = Dataset::synth(SynthSpec::tiny(), 404);
    let base = CopmlConfig::for_dataset(&ds, 10, CaseParams::explicit(2, 1), 404);

    let mut cfg = base.clone();
    cfg.iters = usize::try_from(tags::max_iters()).expect("64-bit target") + 1;
    let err = cfg.validate(&ds).unwrap_err();
    assert!(err.contains("ROUND tag window"), "unexpected error: {err}");

    let mut cfg = base.clone();
    cfg.batches = usize::try_from(tags::max_batches()).expect("64-bit target") + 1;
    let err = cfg.validate(&ds).unwrap_err();
    assert!(err.contains("ENCODE tag window"), "unexpected error: {err}");

    // The boundaries themselves are inside the windows: seeking the last
    // legal sub-window must not panic.
    let _ = tags::round_window((tags::max_iters() - 1) as usize);
    let _ = tags::encode_window((tags::max_batches() - 1) as usize);
}
