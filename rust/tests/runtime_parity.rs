//! PJRT ↔ native parity: the AOT-compiled JAX/Pallas artifacts must compute
//! exactly what the pure-rust kernel computes — this is the rust half of
//! the L1/L2 correctness story (the python half is pytest vs. ref.py).
//!
//! Requires `make artifacts` **and** building with `--features pjrt`
//! (without the feature this whole test file compiles to nothing); tests
//! are skipped (with a loud message) if the manifest is missing so
//! `cargo test --features pjrt` stays green pre-AOT.

#![cfg(feature = "pjrt")]

use copml::coordinator::{algo, protocol, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::field::{Field, MatShape};
use copml::prng::Rng;
use copml::runtime::native::NativeKernel;
use copml::runtime::pjrt::PjrtRuntime;
use copml::runtime::{Engine, GradKernel};
use std::path::Path;

fn runtime() -> Option<PjrtRuntime> {
    let dir = PjrtRuntime::default_dir();
    if !Path::new(&dir).join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(PjrtRuntime::load(&dir).expect("manifest exists but failed to load"))
}

#[test]
fn pjrt_matches_native_on_random_inputs() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::seed_from_u64(1);
    for &(p, degree, rows, cols) in
        &[(copml::field::P26, 1usize, 8usize, 9usize), (copml::field::P26, 1, 64, 21), (copml::field::P26, 3, 200, 21)]
    {
        if !rt.supports(p, degree, rows, cols) {
            eprintln!("SKIP shape p={p} d={degree} r={rows} c={cols}");
            continue;
        }
        let f = Field::new(p);
        let x: Vec<u64> = (0..rows * cols).map(|_| rng.gen_range(p)).collect();
        let w: Vec<u64> = (0..cols).map(|_| rng.gen_range(p)).collect();
        let cq: Vec<u64> = (0..=degree as u64).map(|_| rng.gen_range(p)).collect();
        let shape = MatShape::new(rows, cols);
        let native = NativeKernel::new(f).encoded_gradient(&x, shape, &w, &cq);
        let pjrt = rt.run(p, &x, shape, &w, &cq).expect("pjrt run");
        assert_eq!(native, pjrt, "p={p} degree={degree} rows={rows} cols={cols}");
    }
}

#[test]
fn pallas_and_jnp_flavours_agree_via_pjrt() {
    let Some(mut rt) = runtime() else { return };
    let p = copml::field::P26;
    let (rows, cols) = (16usize, 9usize);
    if !rt.supports(p, 1, rows, cols) {
        return;
    }
    let mut rng = Rng::seed_from_u64(2);
    let x: Vec<u64> = (0..rows * cols).map(|_| rng.gen_range(p)).collect();
    let w: Vec<u64> = (0..cols).map(|_| rng.gen_range(p)).collect();
    let cq: Vec<u64> = vec![rng.gen_range(p), rng.gen_range(p)];
    let shape = MatShape::new(rows, cols);
    let a = rt.run(p, &x, shape, &w, &cq).unwrap();
    rt.flavour = "jnp".into();
    if !rt.supports(p, 1, rows, cols) {
        return;
    }
    let b = rt.run(p, &x, shape, &w, &cq).unwrap();
    assert_eq!(a, b);
}

#[test]
fn row_bucket_padding_is_exact_through_pjrt() {
    let Some(rt) = runtime() else { return };
    let p = copml::field::P26;
    // 13 rows → bucket 16: the runtime pads with zero rows internally.
    let (rows, cols) = (13usize, 9usize);
    if !rt.supports(p, 1, rows, cols) {
        return;
    }
    let mut rng = Rng::seed_from_u64(3);
    let f = Field::new(p);
    let x: Vec<u64> = (0..rows * cols).map(|_| rng.gen_range(p)).collect();
    let w: Vec<u64> = (0..cols).map(|_| rng.gen_range(p)).collect();
    let cq: Vec<u64> = vec![rng.gen_range(p), rng.gen_range(p)];
    let shape = MatShape::new(rows, cols);
    let native = NativeKernel::new(f).encoded_gradient(&x, shape, &w, &cq);
    let pjrt = rt.run(p, &x, shape, &w, &cq).unwrap();
    assert_eq!(native, pjrt);
}

#[test]
fn full_protocol_with_pjrt_engine_matches_native() {
    // The end-to-end story: the threaded protocol with clients computing
    // through the AOT artifacts produces the same trajectory as with the
    // native engine (and hence as algo mode).
    if runtime().is_none() {
        return;
    }
    let ds = Dataset::synth(SynthSpec::tiny(), 55);
    let mut cfg = CopmlConfig::for_dataset(&ds, 7, CaseParams::explicit(2, 1), 55);
    cfg.iters = 3;
    let reference = algo::train(&cfg, &ds).unwrap();
    cfg.engine = Engine::Pjrt;
    let out = protocol::train(&cfg, &ds).unwrap();
    assert_eq!(out.train.w_trace, reference.w_trace);
}
