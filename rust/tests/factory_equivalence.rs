//! The chunk-stability contract of the pipelined offline factory
//! (`mpc::offline::start_factory`), ISSUE-9 satellite: the chunked pools
//! are **element-identical** to one-shot generation for every chunk size
//! — including the degenerate ones — so the model trajectory cannot
//! depend on the pipeline's granularity. `w_trace` bit-identity is the
//! acceptance oracle: it covers every pool (doubles, truncation pairs,
//! random sharings) end to end through the live protocol.

use copml::coordinator::algo::copml_demand;
use copml::coordinator::{protocol, CaseParams, CopmlConfig, QuantizedTask};
use copml::data::{Dataset, SynthSpec};
use copml::mpc::OfflineMode;
use copml::net::Wire;

fn dist_cfg(ds: &Dataset, n: usize, k: usize, t: usize, iters: usize, seed: u64) -> CopmlConfig {
    let mut cfg = CopmlConfig::for_dataset(ds, n, CaseParams::explicit(k, t), seed);
    cfg.iters = iters;
    cfg.offline = OfflineMode::Distributed;
    cfg
}

#[test]
fn chunked_equals_one_shot_across_chunk_grid_geometries_and_wires() {
    // Chunk grid: 1 (maximal pipelining — every element its own chunk),
    // 7 (odd, never divides a pool evenly), the largest pool size (each
    // pool lands in one chunk), and largest + 1 (the final chunk of every
    // pool is short). Geometries vary N, K, and T; both wire formats run
    // because the chunk schedule must be wire-invariant too.
    let ds = Dataset::synth(SynthSpec::tiny(), 200);
    for (n, k, t) in [(4usize, 1usize, 1usize), (7, 2, 1), (7, 1, 2)] {
        let cfg = dist_cfg(&ds, n, k, t, 2, 200);
        let reference = protocol::train(&cfg, &ds).unwrap();
        // The biggest single pool (randoms, for every geometry here) —
        // computed exactly as the protocol sizes its demand.
        let task = QuantizedTask::new(&cfg, &ds);
        let demand = copml_demand(&cfg, task.d, task.rows_padded, task.channels);
        let pool = demand
            .randoms
            .max(demand.doubles)
            .max(demand.truncs.iter().map(|&(_, c)| c).max().unwrap_or(0));
        assert!(pool > 7, "fixture too small for a meaningful chunk grid");
        for chunk in [1usize, 7, pool, pool + 1] {
            for wire in [Wire::U64, Wire::U32] {
                let mut c = cfg.clone();
                c.chunk = Some(chunk);
                c.wire = wire;
                let out = protocol::train(&c, &ds).unwrap();
                assert_eq!(
                    out.train.w_trace, reference.train.w_trace,
                    "chunk-stability violated: N={n} K={k} T={t} chunk={chunk} {wire} wire"
                );
                // The split ledger must conserve the offline accounting:
                // pipelining on ⇒ hidden + critical cover the generation,
                // with nothing negative.
                for (i, l) in out.ledgers.iter().enumerate() {
                    assert!(l.offline_hidden_s >= 0.0, "client {i}: negative hidden seconds");
                    assert!(l.seconds[0] >= 0.0, "client {i}: negative critical seconds");
                }
            }
        }
    }
}

#[test]
fn chunked_run_still_reports_offline_traffic() {
    // The OFFLINE-tagged byte counter feeds the ledger's phase-0 row under
    // pipelining too: a chunked distributed run must charge the same
    // offline bytes as the one-shot run (same elements, same messages,
    // different timing).
    let ds = Dataset::synth(SynthSpec::tiny(), 201);
    let cfg = dist_cfg(&ds, 4, 1, 1, 2, 201);
    let one_shot = protocol::train(&cfg, &ds).unwrap();
    let mut c = cfg.clone();
    c.chunk = Some(16);
    let chunked = protocol::train(&c, &ds).unwrap();
    for (i, (lc, lo)) in chunked.ledgers.iter().zip(&one_shot.ledgers).enumerate() {
        assert!(lc.bytes[0] > 0, "client {i}: chunked run recorded no offline traffic");
        // Chunked generation runs at least as many DN07 extraction batches
        // as one-shot (short final chunks round up), so the chunked run
        // may send slightly MORE on the offline tags — never less. (Online
        // rows are not compared byte-exactly here: the producer sends
        // concurrently with the phase-boundary samplers, so a message in
        // flight can be transiently misattributed between two rows.)
        assert!(
            lc.bytes[0] >= lo.bytes[0],
            "client {i}: chunked offline bytes {} below one-shot {}",
            lc.bytes[0],
            lo.bytes[0]
        );
    }
}
