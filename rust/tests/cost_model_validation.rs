//! The cost model's byte counts must match the threaded protocol's real
//! ledger — this is what makes the Fig. 3 / Table I simulations honest:
//! compute is measured, bytes are exact, only the NIC is modeled.

use copml::bench::cost_model::CopmlCost;
use copml::coordinator::algo::copml_demand;
use copml::coordinator::{protocol, CaseParams, CopmlConfig};
use copml::data::{Dataset, SynthSpec};
use copml::mpc::offline::distributed_bytes_for_party;
use copml::mpc::OfflineMode;
use copml::net::{Wire, ELEM_BYTES};

/// Analytic per-client bytes of the protocol phases (mirrors
/// `coordinator::protocol`), for a config with even client split.
fn analytic_bytes_per_iter(n: usize, t: usize, d: usize, subgroups: bool) -> u64 {
    let targets = if subgroups { t + 1 } else { t + 1 }; // reconstruction set size
    // model encode: send to (targets−1) group mates (own share stays local)
    let enc = (targets - 1) * d;
    // results: share_out to all n−1 peers
    let results = (n - 1) * d;
    (enc + results) as u64 * ELEM_BYTES
}

#[test]
fn ledger_matches_analytic_iteration_bytes() {
    let ds = Dataset::synth(SynthSpec::tiny(), 71);
    let (n, k, t, iters) = (10usize, 2usize, 2usize, 3usize);
    let mut cfg = CopmlConfig::for_dataset(&ds, n, CaseParams::explicit(k, t), 71);
    cfg.iters = iters;
    let out = protocol::train(&cfg, &ds).unwrap();

    // Phase 4 (encode_model) + phase 6 (share_results) bytes per client:
    // subgroup sizes can exceed t+1 for the tail group, so allow the
    // analytic value as a lower bound and a 2× envelope as upper.
    let lower = analytic_bytes_per_iter(n, t, ds.d, true) * iters as u64;
    for (i, l) in out.ledgers.iter().enumerate() {
        let measured = l.bytes[4] + l.bytes[6];
        assert!(
            measured >= lower && measured <= lower * 2 + 64,
            "client {i}: measured {measured}, analytic lower {lower}"
        );
    }
}

#[test]
fn trunc_open_bytes_king_shaped() {
    // King (client 0) sends ~2·(n−1)·d elements per iteration for the two
    // truncation openings; non-king clients with id ≤ t send their shares
    // up (2·d each).
    let ds = Dataset::synth(SynthSpec::tiny(), 72);
    let (n, t, iters) = (7usize, 1usize, 2usize);
    let mut cfg = CopmlConfig::for_dataset(&ds, n, CaseParams::explicit(2, t), 72);
    cfg.iters = iters;
    let out = protocol::train(&cfg, &ds).unwrap();
    let d = ds.d as u64;
    let king_decode = out.ledgers[0].bytes[7];
    let expected_king = 2 * (n as u64 - 1) * d * ELEM_BYTES * iters as u64;
    assert_eq!(king_decode, expected_king);
    // a far client (> t) sends nothing during decode/trunc
    assert_eq!(out.ledgers[n - 1].bytes[7], 0);
}

#[test]
fn minibatch_ledger_bytes_batch_invariant_per_iteration() {
    // The wire story of batching, pinned against the live ledger: every
    // per-iteration phase (model encode, compute, share results,
    // decode/trunc) moves d-sized vectors and must be byte-identical
    // across B; the one-time Xᵀ_b y_b reduction scales ×B exactly; and for
    // a geometry whose batches pad to the same total, the one-time encode
    // exchange is byte-identical too.
    let ds = Dataset::synth(SynthSpec::tiny(), 75); // m = 48
    let (n, k, t, iters, b) = (7usize, 2usize, 1usize, 6usize, 3usize);
    let mut cfg = CopmlConfig::for_dataset(&ds, n, CaseParams::explicit(k, t), 75);
    cfg.iters = iters;
    let full = protocol::train(&cfg, &ds).unwrap();
    cfg.batches = b;
    let mini = protocol::train(&cfg, &ds).unwrap();
    // 48 rows → batches of 16, each a multiple of K=2: padded totals match.
    for (i, (lf, lm)) in full.ledgers.iter().zip(&mini.ledgers).enumerate() {
        assert_eq!(lf.bytes[1], lm.bytes[1], "client {i}: share_dataset moved");
        assert_eq!(b as u64 * lf.bytes[2], lm.bytes[2], "client {i}: xty must scale ×B");
        assert_eq!(lf.bytes[3], lm.bytes[3], "client {i}: encode_dataset moved");
        for p in 4..8 {
            assert_eq!(lf.bytes[p], lm.bytes[p], "client {i} phase {p}: per-iter bytes moved");
        }
    }
}

#[test]
fn copml_cost_model_monotonic_in_n_for_fixed_kt() {
    // More clients, same (K,T): comm grows (more result shares), compute
    // constant.
    let cal = copml::bench::Calibration {
        muladd_per_s: 1e9,
        kernel_cells_per_s: 5e8,
        share_per_s: 2e8,
    };
    let wan = copml::net::wan::WanModel::paper();
    let mk = |n: usize| CopmlCost {
        n,
        k: 3,
        t: 1,
        r: 1,
        m: 2000,
        d: 100,
        iters: 10,
        batches: 1,
        subgroups: true,
        wire: Wire::U64,
        offline: OfflineMode::Dealer,
        trunc_bits: 25,
        stragglers: 0,
    }
    .estimate(&cal, &wan);
    let a = mk(10);
    let b = mk(30);
    assert!(b.comm_s > a.comm_s);
    assert!((b.comp_s - a.comp_s).abs() < 1e-9);
}

#[test]
fn distributed_offline_ledger_matches_exact_model() {
    // The offline column is itemized, not estimated: the live per-party
    // ledger of a distributed-offline run must equal the analytic byte
    // accounting term for term — including the king's asymmetric opening
    // traffic — and halve exactly under u32 packing.
    let ds = Dataset::synth(SynthSpec::tiny(), 74);
    let (n, k, t) = (7usize, 2usize, 1usize);
    let mut cfg = CopmlConfig::for_dataset(&ds, n, CaseParams::explicit(k, t), 74);
    cfg.iters = 2;
    cfg.offline = OfflineMode::Distributed;
    let demand = copml_demand(&cfg, ds.d, ds.padded_rows(cfg.k), cfg.channels(&ds));
    let mut u64_offline = Vec::new();
    for wire in [Wire::U64, Wire::U32] {
        cfg.wire = wire;
        let out = protocol::train(&cfg, &ds).unwrap();
        for (id, l) in out.ledgers.iter().enumerate() {
            let expect = distributed_bytes_for_party(
                n,
                t,
                &demand,
                cfg.plan.k2,
                cfg.plan.kappa,
                id,
                wire,
            );
            assert_eq!(l.bytes[0], expect, "party {id} offline bytes ({wire} wire)");
        }
        if wire == Wire::U64 {
            u64_offline = out.ledgers.iter().map(|l| l.bytes[0]).collect();
        } else {
            for (id, l) in out.ledgers.iter().enumerate() {
                assert_eq!(
                    u64_offline[id],
                    2 * l.bytes[0],
                    "party {id}: u32 packing must halve offline bytes"
                );
            }
        }
    }
    // The king's fan-out during bit openings makes its offline column the
    // largest — the asymmetry the cost model charges as the bottleneck.
    let king = u64_offline[0];
    assert!(
        u64_offline[1..].iter().all(|&b| b < king),
        "king must dominate offline traffic: {u64_offline:?}"
    );
}

#[test]
fn u32_wire_halves_live_ledger_and_cost_model() {
    // Acceptance: Wire::U32 reports exactly half the payload bytes of
    // Wire::U64 — in the live per-phase ledger of a protocol run, and in
    // the cost model's bytes term — without changing the trajectory.
    let ds = Dataset::synth(SynthSpec::tiny(), 73);
    let mut cfg = CopmlConfig::for_dataset(&ds, 7, CaseParams::explicit(2, 1), 73);
    cfg.iters = 2;
    let base = protocol::train(&cfg, &ds).unwrap();
    cfg.wire = Wire::U32;
    let packed = protocol::train(&cfg, &ds).unwrap();
    assert_eq!(
        base.train.w_trace, packed.train.w_trace,
        "wire packing must be value-transparent"
    );
    for (i, (a, b)) in base.ledgers.iter().zip(&packed.ledgers).enumerate() {
        for p in 0..a.bytes.len() {
            assert_eq!(a.bytes[p], 2 * b.bytes[p], "client {i} phase {p}");
        }
    }
    // Cost model: zero latency / per-message cost isolates the bytes term.
    let cal = copml::bench::Calibration {
        muladd_per_s: 1e9,
        kernel_cells_per_s: 5e8,
        share_per_s: 2e8,
    };
    let wan = copml::net::wan::WanModel { bandwidth_mbps: 40.0, latency_s: 0.0, msg_proc_s: 0.0 };
    let c64 = CopmlCost {
        n: 50,
        k: 16,
        t: 1,
        r: 1,
        m: 9019,
        d: 3073,
        iters: 50,
        batches: 1,
        subgroups: true,
        wire: Wire::U64,
        offline: OfflineMode::Dealer,
        trunc_bits: 25,
        stragglers: 0,
    };
    let c32 = CopmlCost { wire: Wire::U32, ..c64 };
    let e64 = c64.estimate(&cal, &wan);
    let e32 = c32.estimate(&cal, &wan);
    let ratio = e64.comm_s / e32.comm_s;
    assert!((ratio - 2.0).abs() < 1e-12, "cost-model comm ratio {ratio}");
    // Compute terms are wire-invariant — packing only touches bytes.
    assert_eq!(e64.comp_s, e32.comp_s);
    assert_eq!(e64.encdec_s, e32.encdec_s);
}
