//! CSV dataset loader for the model zoo (`--dataset csv:PATH`).
//!
//! Accepts the layout of the tfe-logistic benchmark corpora (default_credit
//! 30000×24, breast 569×31, sonar 208×61): one numeric record per line,
//! comma-separated, **no header required** (a single leading header line is
//! tolerated and skipped), label in the last column unless
//! [`CsvOptions::label_col`] says otherwise.
//!
//! The loader produces a [`Dataset`] in the invariant form every quant plan
//! assumes (`CopmlConfig::validate` hardcodes `max_abs_x = 1`):
//!
//! 1. deterministic train/test split — a seeded permutation
//!    (domain-separated from every protocol stream), first rows train;
//! 2. per-feature standardization with **train-split statistics**
//!    (`(x − μ)/σ`), then one global rescale so every feature of every
//!    split lies in `[−1, 1]`;
//! 3. a bias column fixed to `1.0` appended as the last feature.
//!
//! Labels: integer values with ≥ 2 distinct levels in `{0, …, 64}` are
//! classification classes (`Dataset::classes = max + 1`); anything else is
//! a regression target (`classes = 1`), rescaled into `[−1, 1]` when it
//! exceeds that range (R² is invariant under the shared scale).
//!
//! Every malformed input is a typed [`CsvError`] naming the offending
//! line — never a panic (ISSUE-10 hardening satellite).

use super::Dataset;
use crate::prng::Rng;

/// Stream label for the train/test-split permutation ("CSVS" in the high
/// bits) — domain-separated from the dealer, party, offline, and batch
/// streams so loading a CSV perturbs no protocol randomness.
const STREAM_SPLIT: u64 = 0x4353_5653_0000_0000;

/// Largest integer label value still treated as a class index; anything
/// above is a regression target (guards against id-like columns exploding
/// the one-vs-rest width).
const MAX_CLASS_LABEL: f64 = 64.0;

/// Typed loader failures, one per malformed-input family. Each `Display`
/// names the offending line/column so the CLI error is actionable.
#[derive(Debug, Clone, PartialEq)]
pub enum CsvError {
    /// The file could not be read at all.
    Io { path: String, cause: String },
    /// No data rows (empty file, or header/blank lines only).
    Empty,
    /// A field failed to parse as a number (1-based line/column).
    MalformedField { line: usize, column: usize, text: String },
    /// A row's field count differs from the first data row's (1-based line).
    WidthDrift { line: usize, expected: usize, got: usize },
    /// The requested label column does not exist at this width.
    LabelColumnOutOfRange { label_col: usize, width: usize },
    /// Fewer than [`MIN_ROWS`] records — no meaningful train/test split.
    TooFewRows { rows: usize },
    /// Rows narrower than 2 columns have no feature + label split.
    TooNarrow { width: usize },
    /// Classification labels must be the contiguous range `0..classes`.
    NegativeClassLabel { line: usize, value: f64 },
    /// Every label identical — nothing to fit.
    ConstantLabels,
}

/// Minimum record count the loader accepts (below this a held-out split is
/// meaningless).
pub const MIN_ROWS: usize = 8;

impl std::fmt::Display for CsvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsvError::Io { path, cause } => write!(f, "cannot read csv '{path}': {cause}"),
            CsvError::Empty => write!(f, "csv holds no data rows"),
            CsvError::MalformedField { line, column, text } => write!(
                f,
                "csv line {line}, column {column}: '{text}' is not a number \
                 (only line 1 may be a header)"
            ),
            CsvError::WidthDrift { line, expected, got } => write!(
                f,
                "csv line {line}: {got} fields, but the first data row has {expected} \
                 — ragged rows are not supported"
            ),
            CsvError::LabelColumnOutOfRange { label_col, width } => write!(
                f,
                "label column {label_col} out of range: rows have {width} columns (0..{})",
                width.saturating_sub(1)
            ),
            CsvError::TooFewRows { rows } => write!(
                f,
                "csv has only {rows} data rows; at least {MIN_ROWS} are needed for a \
                 train/test split"
            ),
            CsvError::TooNarrow { width } => write!(
                f,
                "csv rows have {width} column(s); at least one feature plus a label \
                 column are required"
            ),
            CsvError::NegativeClassLabel { line, value } => write!(
                f,
                "csv line {line}: class label {value} is negative — classification \
                 labels must be 0..C"
            ),
            CsvError::ConstantLabels => {
                write!(f, "every csv label is identical — nothing to fit")
            }
        }
    }
}

impl std::error::Error for CsvError {}

/// Loader knobs. `Default` matches the tfe-logistic conventions: label in
/// the last column, 20% held out for test.
#[derive(Clone, Debug)]
pub struct CsvOptions {
    /// 0-based label column; `None` → last column.
    pub label_col: Option<usize>,
    /// Fraction of rows held out as the test split (at least one row).
    pub test_fraction: f64,
    /// Seed of the split permutation (forked, domain-separated).
    pub seed: u64,
    /// Dataset name reported in summaries; `None` → derived from the path.
    pub name: Option<String>,
}

impl Default for CsvOptions {
    fn default() -> Self {
        CsvOptions { label_col: None, test_fraction: 0.2, seed: 0, name: None }
    }
}

/// Parse CSV text into numeric rows. Pure function of the text — all the
/// hardening property tests drive this directly, no files needed.
fn parse_table(text: &str) -> Result<Vec<Vec<f64>>, CsvError> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut width: Option<usize> = None;
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = raw.trim_end_matches('\r').trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        let mut row = Vec::with_capacity(fields.len());
        let mut bad: Option<CsvError> = None;
        for (col, field) in fields.iter().enumerate() {
            match field.trim().parse::<f64>() {
                Ok(v) if v.is_finite() => row.push(v),
                _ => {
                    bad = Some(CsvError::MalformedField {
                        line: line_no,
                        column: col + 1,
                        text: field.trim().to_string(),
                    });
                    break;
                }
            }
        }
        if let Some(err) = bad {
            // A single unparseable *first* line is a header: skip it.
            if rows.is_empty() && width.is_none() && line_no == 1 {
                continue;
            }
            return Err(err);
        }
        match width {
            None => width = Some(row.len()),
            Some(w) if w != row.len() => {
                return Err(CsvError::WidthDrift { line: line_no, expected: w, got: row.len() })
            }
            Some(_) => {}
        }
        rows.push(row);
    }
    if rows.is_empty() {
        return Err(CsvError::Empty);
    }
    Ok(rows)
}

/// Build a [`Dataset`] from parsed rows (split → standardize → bias column;
/// see the module docs for the exact pipeline).
fn dataset_from_rows(rows: Vec<Vec<f64>>, opts: &CsvOptions) -> Result<Dataset, CsvError> {
    let width = rows[0].len();
    if width < 2 {
        return Err(CsvError::TooNarrow { width });
    }
    let label_col = opts.label_col.unwrap_or(width - 1);
    if label_col >= width {
        return Err(CsvError::LabelColumnOutOfRange { label_col, width });
    }
    let rows_n = rows.len();
    if rows_n < MIN_ROWS {
        return Err(CsvError::TooFewRows { rows: rows_n });
    }

    // Label typing: contiguous small integers → classification.
    let labels: Vec<f64> = rows.iter().map(|r| r[label_col]).collect();
    let integral = labels.iter().all(|&v| v.fract() == 0.0 && v.abs() <= MAX_CLASS_LABEL);
    let (lmin, lmax) = labels
        .iter()
        .fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    if lmin == lmax {
        return Err(CsvError::ConstantLabels);
    }
    let classes = if integral {
        if lmin < 0.0 {
            let line = rows.iter().position(|r| r[label_col] < 0.0).unwrap_or(0) + 1;
            return Err(CsvError::NegativeClassLabel { line, value: lmin });
        }
        lmax as usize + 1
    } else {
        1
    };
    // Regression targets must fit the quant bound |y| ≤ 1 (scale is shared
    // by train and test, so R² is unchanged).
    let y_scale = if classes == 1 && lmax.abs().max(lmin.abs()) > 1.0 {
        1.0 / lmax.abs().max(lmin.abs())
    } else {
        1.0
    };

    // Deterministic split: seeded permutation, first rows train.
    let perm = Rng::seed_from_u64(opts.seed).fork(STREAM_SPLIT).permutation(rows_n);
    let m_test = ((rows_n as f64 * opts.test_fraction).round() as usize).clamp(1, rows_n - 2);
    let m_train = rows_n - m_test;

    let d_feat = width - 1;
    let d = d_feat + 1; // + bias column
    let feature_cols: Vec<usize> = (0..width).filter(|&c| c != label_col).collect();

    // Per-feature train statistics.
    let mut mean = vec![0.0f64; d_feat];
    let mut var = vec![0.0f64; d_feat];
    for &src in perm.iter().take(m_train) {
        for (j, &c) in feature_cols.iter().enumerate() {
            mean[j] += rows[src][c];
        }
    }
    for mj in mean.iter_mut() {
        *mj /= m_train as f64;
    }
    for &src in perm.iter().take(m_train) {
        for (j, &c) in feature_cols.iter().enumerate() {
            let dv = rows[src][c] - mean[j];
            var[j] += dv * dv;
        }
    }
    let std: Vec<f64> = var.iter().map(|&v| (v / m_train as f64).sqrt().max(1e-12)).collect();

    // Standardize everything with the train statistics, then find the
    // global max |x| so one shared rescale bounds BOTH splits in [−1, 1]
    // (the plan validator hardcodes max_abs_x = 1).
    let standardized: Vec<Vec<f64>> = perm
        .iter()
        .map(|&src| {
            feature_cols
                .iter()
                .enumerate()
                .map(|(j, &c)| (rows[src][c] - mean[j]) / std[j])
                .collect()
        })
        .collect();
    let max_abs = standardized
        .iter()
        .flat_map(|r| r.iter())
        .fold(1.0f64, |acc, &v| acc.max(v.abs()));

    let mut x = vec![0.0f64; m_train * d];
    let mut y = vec![0.0f64; m_train];
    let mut x_test = vec![0.0f64; m_test * d];
    let mut y_test = vec![0.0f64; m_test];
    for (i, row) in standardized.iter().enumerate() {
        let (dst, yv) = if i < m_train {
            (&mut x[i * d..(i + 1) * d], &mut y[i])
        } else {
            let t = i - m_train;
            (&mut x_test[t * d..(t + 1) * d], &mut y_test[t])
        };
        for (j, &v) in row.iter().enumerate() {
            dst[j] = v / max_abs;
        }
        dst[d_feat] = 1.0;
        *yv = labels[perm[i]] * y_scale;
    }

    let name = opts.name.clone().unwrap_or_else(|| "csv".to_string());
    Ok(Dataset { name, x, y, x_test, y_test, m: m_train, d, classes })
}

/// Parse CSV text into a [`Dataset`] (the file-less core `load` wraps).
pub fn parse(text: &str, opts: &CsvOptions) -> Result<Dataset, CsvError> {
    dataset_from_rows(parse_table(text)?, opts)
}

/// Load a CSV file from `path`.
pub fn load(path: &str, mut opts: CsvOptions) -> Result<Dataset, CsvError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| CsvError::Io { path: path.to_string(), cause: e.to_string() })?;
    if opts.name.is_none() {
        let stem = std::path::Path::new(path)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("csv")
            .to_string();
        opts.name = Some(stem);
    }
    parse(&text, &opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A well-formed K-class csv: 3 features + integer label, `rows` rows.
    fn good_csv(rows: usize, classes: usize) -> String {
        let mut s = String::new();
        for i in 0..rows {
            let a = (i % 7) as f64 * 0.3 - 1.0;
            let b = (i % 5) as f64 * 0.7;
            let c = (i % 3) as f64 - 1.0;
            let y = i % classes;
            s.push_str(&format!("{a},{b},{c},{y}\n"));
        }
        s
    }

    #[test]
    fn loads_and_standardizes() {
        let ds = parse(&good_csv(40, 2), &CsvOptions::default()).unwrap();
        assert_eq!(ds.d, 4); // 3 features + bias
        assert_eq!(ds.m + ds.y_test.len(), 40);
        assert_eq!(ds.classes, 2);
        // |x| ≤ 1 on both splits, bias column last
        for (i, &v) in ds.x.iter().chain(ds.x_test.iter()).enumerate() {
            assert!((-1.0..=1.0).contains(&v), "x[{i}] = {v}");
        }
        for i in 0..ds.m {
            assert_eq!(ds.x[i * ds.d + ds.d - 1], 1.0, "bias column");
        }
        // train features (near) zero-mean before the shared rescale
        for j in 0..ds.d - 1 {
            let mean: f64 = (0..ds.m).map(|i| ds.x[i * ds.d + j]).sum::<f64>() / ds.m as f64;
            assert!(mean.abs() < 0.25, "column {j} mean {mean}");
        }
    }

    #[test]
    fn split_is_deterministic_and_seed_sensitive() {
        let text = good_csv(50, 2);
        let a = parse(&text, &CsvOptions::default()).unwrap();
        let b = parse(&text, &CsvOptions::default()).unwrap();
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = parse(&text, &CsvOptions { seed: 1, ..Default::default() }).unwrap();
        assert_ne!(a.y, c.y, "different seed must reshuffle the split");
        assert_eq!(a.m, c.m);
    }

    #[test]
    fn header_line_tolerated() {
        let text = format!("f1,f2,f3,label\n{}", good_csv(20, 2));
        let ds = parse(&text, &CsvOptions::default()).unwrap();
        assert_eq!(ds.m + ds.y_test.len(), 20);
    }

    #[test]
    fn multiclass_counts_classes() {
        let ds = parse(&good_csv(30, 3), &CsvOptions::default()).unwrap();
        assert_eq!(ds.classes, 3);
        // labels preserved verbatim
        for &v in ds.y.iter().chain(ds.y_test.iter()) {
            assert!(v == 0.0 || v == 1.0 || v == 2.0);
        }
    }

    #[test]
    fn regression_labels_scaled_into_unit_range() {
        let mut s = String::new();
        for i in 0..20 {
            let (a, b) = (i as f64 * 0.1, 1.0 - i as f64 * 0.05);
            s.push_str(&format!("{a},{b},{}\n", i as f64 * 2.5 + 0.25));
        }
        let ds = parse(&s, &CsvOptions::default()).unwrap();
        assert_eq!(ds.classes, 1, "non-integer labels are a regression target");
        let max_abs =
            ds.y.iter().chain(ds.y_test.iter()).fold(0.0f64, |a, &v| a.max(v.abs()));
        assert!(max_abs <= 1.0 + 1e-12 && max_abs > 0.9, "y rescaled to [−1,1]: {max_abs}");
    }

    // ---- hardening property tests (ISSUE-10 satellite) -----------------

    #[test]
    fn malformed_row_names_line_and_column() {
        let mut text = good_csv(12, 2);
        text.push_str("0.1,oops,0.3,1\n");
        match parse(&text, &CsvOptions::default()) {
            Err(CsvError::MalformedField { line: 13, column: 2, text }) => {
                assert_eq!(text, "oops")
            }
            other => panic!("expected MalformedField, got {other:?}"),
        }
    }

    #[test]
    fn header_after_first_line_is_an_error() {
        let mut text = good_csv(5, 2);
        text.push_str("f1,f2,f3,label\n");
        text.push_str(&good_csv(5, 2));
        assert!(matches!(
            parse(&text, &CsvOptions::default()),
            Err(CsvError::MalformedField { line: 6, .. })
        ));
    }

    #[test]
    fn width_drift_names_line() {
        let mut text = good_csv(10, 2);
        text.push_str("0.1,0.2,1\n"); // 3 fields instead of 4
        assert!(matches!(
            parse(&text, &CsvOptions::default()),
            Err(CsvError::WidthDrift { line: 11, expected: 4, got: 3 })
        ));
    }

    #[test]
    fn empty_inputs_rejected() {
        assert_eq!(parse("", &CsvOptions::default()), Err(CsvError::Empty));
        assert_eq!(parse("\n\n  \n", &CsvOptions::default()), Err(CsvError::Empty));
        // header-only file is still empty of data
        assert_eq!(parse("a,b,c\n", &CsvOptions::default()), Err(CsvError::Empty));
    }

    #[test]
    fn out_of_range_label_column_rejected() {
        let opts = CsvOptions { label_col: Some(4), ..Default::default() };
        assert_eq!(
            parse(&good_csv(12, 2), &opts),
            Err(CsvError::LabelColumnOutOfRange { label_col: 4, width: 4 })
        );
    }

    #[test]
    fn too_few_rows_rejected() {
        assert_eq!(
            parse(&good_csv(MIN_ROWS - 1, 2), &CsvOptions::default()),
            Err(CsvError::TooFewRows { rows: MIN_ROWS - 1 })
        );
        assert!(parse(&good_csv(MIN_ROWS, 2), &CsvOptions::default()).is_ok());
    }

    #[test]
    fn single_column_rejected() {
        let text = "1\n0\n1\n0\n1\n0\n1\n0\n";
        assert_eq!(parse(text, &CsvOptions::default()), Err(CsvError::TooNarrow { width: 1 }));
    }

    #[test]
    fn negative_class_labels_rejected() {
        let mut s = String::new();
        for i in 0..12 {
            s.push_str(&format!("0.5,0.1,{}\n", if i % 2 == 0 { -1.0 } else { 1.0 }));
        }
        assert!(matches!(
            parse(&s, &CsvOptions::default()),
            Err(CsvError::NegativeClassLabel { .. })
        ));
    }

    #[test]
    fn constant_labels_rejected() {
        let mut s = String::new();
        for _ in 0..12 {
            s.push_str("0.5,0.1,1\n");
        }
        assert_eq!(parse(&s, &CsvOptions::default()), Err(CsvError::ConstantLabels));
    }

    #[test]
    fn nonfinite_fields_rejected() {
        let mut text = good_csv(10, 2);
        text.push_str("0.1,inf,0.3,1\n");
        assert!(matches!(
            parse(&text, &CsvOptions::default()),
            Err(CsvError::MalformedField { line: 11, column: 2, .. })
        ));
    }

    #[test]
    fn errors_render_actionable_messages() {
        let e = CsvError::WidthDrift { line: 9, expected: 31, got: 30 };
        assert!(e.to_string().contains("line 9"));
        let e = CsvError::MalformedField { line: 2, column: 5, text: "x".into() };
        assert!(e.to_string().contains("line 2") && e.to_string().contains("column 5"));
    }
}
