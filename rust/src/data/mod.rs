//! Datasets. The paper trains binary logistic regression on CIFAR-10
//! (plane vs car, `(m,d) = (9019, 3073)`) and GISETTE (digits 4 vs 9,
//! `(6000, 5000)`). Those corpora are not redistributable/downloadable in
//! this offline environment, so we build **deterministic synthetic
//! stand-ins with identical shapes** (see DESIGN.md §2): class-conditional
//! Gaussians on a shared low-rank subspace, feature-normalized to `[0, 1]`,
//! with separation tuned so plaintext logistic regression lands near the
//! paper's accuracies (~82% CIFAR-like, ~97.5% GISETTE-like).
//!
//! Protocol cost depends only on `(m, d, N, K, T, r)` — identical by
//! construction; accuracy curves depend on quantization/approximation
//! error, which the stand-ins exercise at the same scale.

use crate::prng::Rng;

pub mod csv;

/// Stream label for the batch-permutation RNG ("BTCH" in the high bits) —
/// domain-separated from the dealer stream labels (`mpc::dealer`), the
/// per-party online streams (`mpc::STREAM_PARTY`), and the offline-phase
/// streams (`mpc::offline`), so adding batching perturbs no other
/// randomness.
const STREAM_BATCH: u64 = 0x4254_4348_0000_0000;

/// Deterministic mini-batch partition of a dataset's training rows.
///
/// The `m` real rows are dealt into `B` batches by a **seeded permutation**
/// (identity for `B = 1`, so the full-batch layout — and every full-batch
/// trace — is reproduced bit for bit), split as evenly as `client_ranges`
/// splits clients (remainders to the first batches), and each batch is
/// **independently zero-padded** up to a multiple of `K` so the Lagrange
/// encoder can partition every batch into `K` equal submatrices
/// (`runtime::padding`: zero rows are provably inert in the gradient).
///
/// Two load-bearing invariants:
///
/// * each batch occupies one **contiguous padded row range**, with its
///   padding at the batch tail — so per-batch matrix views are plain
///   slices and `coordinator::protocol::padded_ranges` keeps working on
///   the concatenated layout;
/// * the real-row partition (which rows train in which batch, and hence
///   the per-batch learning-rate denominators) depends only on
///   `(m, B, seed)` — **never on `K`** — so the COPML trainers and the
///   `K = 1` conventional-MPC baselines walk bit-identical trajectories
///   (asserted in `tests/protocol_equivalence.rs`).
#[derive(Clone, Debug)]
pub struct BatchPlan {
    /// Number of batches `B` (iteration `i` trains on batch `i mod B`).
    pub b: usize,
    /// Permuted order of the `m` real rows: permuted slot `i` holds
    /// original dataset row `perm[i]`. Identity for `B = 1`.
    perm: Vec<usize>,
    /// Real (unpadded) rows per batch — the `η/m_b` denominators.
    real: Vec<usize>,
    /// Padded `[lo, hi)` row range per batch; `hi − lo` is a multiple of
    /// `K` and the ranges tile `0..rows_padded` in order.
    ranges: Vec<(usize, usize)>,
    rows_padded: usize,
}

impl BatchPlan {
    /// Build the plan for `m` rows, `K` Lagrange partitions, `B` batches.
    /// Deterministic in `seed` (the permutation comes from a
    /// domain-separated fork of the master seed).
    pub fn new(m: usize, k: usize, b: usize, seed: u64) -> BatchPlan {
        assert!(b >= 1, "batch count must be ≥ 1");
        assert!(k >= 1, "partition count must be ≥ 1");
        assert!(b <= m, "more batches ({b}) than samples ({m})");
        let perm: Vec<usize> = if b == 1 {
            (0..m).collect()
        } else {
            Rng::seed_from_u64(seed).fork(STREAM_BATCH).permutation(m)
        };
        let (base, extra) = (m / b, m % b);
        let mut real = Vec::with_capacity(b);
        let mut ranges = Vec::with_capacity(b);
        let mut off = 0usize;
        for i in 0..b {
            let mb = base + usize::from(i < extra);
            let pb = mb.div_ceil(k) * k;
            real.push(mb);
            ranges.push((off, off + pb));
            off += pb;
        }
        BatchPlan { b, perm, real, ranges, rows_padded: off }
    }

    /// Total padded rows `Σ_b (hi − lo)` — the row count of the
    /// concatenated per-batch-padded matrix.
    pub fn rows_padded(&self) -> usize {
        self.rows_padded
    }

    /// Padded `[lo, hi)` row ranges, one per batch, tiling `0..rows_padded`.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// Real (unpadded) sample count of batch `b` — the denominator of the
    /// batch's learning-rate factor `η/m_b`.
    pub fn real_rows(&self, b: usize) -> usize {
        self.real[b]
    }

    /// Which batch gradient-descent iteration `iter` trains on: the cyclic
    /// schedule `iter mod B` (shared bit-identically by the full protocol,
    /// the central recursion, and the baselines).
    pub fn batch_of_iter(&self, iter: usize) -> usize {
        iter % self.b
    }

    /// The batch-geometry feasibility rules, shared by every layer that
    /// accepts a batch count (`CopmlConfig::validate`, the conventional
    /// baselines, the cost model) so they can never drift on which
    /// geometries are legal: `B ≥ 1`, every batch holds at least one —
    /// and at least `K` — real rows, and the cyclic schedule visits every
    /// batch within `iters`.
    pub fn validate_geometry(m: usize, k: usize, b: usize, iters: usize) -> Result<(), String> {
        if b == 0 {
            return Err("--batches must be ≥ 1".into());
        }
        if b > m {
            return Err(format!(
                "--batches {b} exceeds the dataset's m = {m} samples: every batch \
                 needs at least one real row"
            ));
        }
        if m / b < k {
            return Err(format!(
                "infeasible batch geometry: rows_b = ⌊m/B⌋ = {} < K = {k} — every \
                 batch must hold at least K real rows (m = {m}, B = {b}); lower \
                 --batches or K",
                m / b
            ));
        }
        if b > iters {
            return Err(format!(
                "--batches {b} exceeds --iters {iters}: the cyclic schedule (batch = \
                 iter mod B) would never train on the tail batches"
            ));
        }
        Ok(())
    }

    /// `(padded_slot, original_row)` for every real row, in layout order —
    /// the scatter map quantization uses to build the permuted,
    /// per-batch-padded matrix (slots not named here are padding, zero).
    pub fn slots(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.perm.len());
        let mut i = 0usize;
        for (bi, &(lo, _)) in self.ranges.iter().enumerate() {
            for j in 0..self.real[bi] {
                out.push((lo + j, self.perm[i]));
                i += 1;
            }
        }
        out
    }
}

/// A dense supervised dataset, features in `[−1, 1]`, last feature column
/// fixed to 1 (bias). For classification workloads labels are the integers
/// `{0, …, classes−1}` stored as `f64`; for regression targets `classes`
/// is 1 and `y` is any real value in `[−1, 1]`.
#[derive(Clone)]
pub struct Dataset {
    pub name: String,
    /// Train features, row-major `(m × d)`.
    pub x: Vec<f64>,
    /// Train labels, length `m`.
    pub y: Vec<f64>,
    /// Test features `(m_test × d)`.
    pub x_test: Vec<f64>,
    /// Test labels.
    pub y_test: Vec<f64>,
    pub m: usize,
    pub d: usize,
    /// Number of label classes: 2 for binary classification (the synthetic
    /// generators), `C` for multi-class CSVs, 1 for regression targets.
    pub classes: usize,
}

/// Parameters of the synthetic generator.
///
/// The generator is `x = 0.5 ± signal + noise + confound`, column-centered
/// after generation (features end in `[−1, 1]`, bias column = 1):
///
/// * a **sparse class signal**: `signal_features` columns move by
///   `±signal_amp` with the label — class-mean gaps of the size real
///   CIFAR/GISETTE features exhibit, which is what bounds the gradient
///   (`g0max ≈ m·signal_amp`) and therefore the fixed-point plan;
/// * **independent noise** of scale `noise` — keeps `λ_max(XᵀX)` at the
///   Marchenko–Pastur scale so gradient descent with the paper's degree-1
///   sigmoid (no saturation!) is stable at the paper's step sizes. This is
///   the property the paper's real datasets must also have had for Fig. 4
///   to converge (DESIGN.md §2 documents this substitution);
/// * a small **low-rank confound** (`rank`, `confound`) for realism —
///   correlated nuisance structure that does not carry label signal.
#[derive(Clone, Copy, Debug)]
pub struct SynthSpec {
    pub m_train: usize,
    pub m_test: usize,
    /// Total feature count including the bias column.
    pub d: usize,
    /// Dimension of the low-rank nuisance subspace.
    pub rank: usize,
    /// Scale of the low-rank confound.
    pub confound: f64,
    /// Number of columns carrying class signal.
    pub signal_features: usize,
    /// Per-column class-mean half-gap.
    pub signal_amp: f64,
    /// Independent per-feature noise σ.
    pub noise: f64,
    pub name: &'static str,
}

impl SynthSpec {
    /// CIFAR-10-like stand-in: binary plane/car, 9019 train + 2000 test,
    /// d = 3073 (= 32·32·3 pixels + bias). Signal tuned for ~82%
    /// plaintext test accuracy (paper: 81.75%).
    pub fn cifar_like() -> SynthSpec {
        SynthSpec {
            m_train: 9019,
            m_test: 2000,
            d: 3073,
            rank: 24,
            confound: 0.08,
            signal_features: 120,
            signal_amp: 0.025,
            noise: 0.25,
            name: "cifar10-like",
        }
    }

    /// GISETTE-like stand-in: digits 4 vs 9, 6000 train + 1000 test,
    /// d = 5000. Tuned for ~97.5% plaintext accuracy (paper: 97.5%).
    pub fn gisette_like() -> SynthSpec {
        SynthSpec {
            m_train: 6000,
            m_test: 1000,
            d: 5000,
            rank: 30,
            confound: 0.06,
            signal_features: 250,
            signal_amp: 0.034,
            noise: 0.25,
            name: "gisette-like",
        }
    }

    /// Small smoke-test dataset for unit/integration tests.
    pub fn smoke() -> SynthSpec {
        SynthSpec {
            m_train: 400,
            m_test: 100,
            d: 21,
            rank: 4,
            confound: 0.05,
            signal_features: 12,
            signal_amp: 0.18,
            noise: 0.25,
            name: "smoke",
        }
    }

    /// Tiny dataset for full-fidelity protocol tests (threads move every
    /// share); keep m·d small.
    pub fn tiny() -> SynthSpec {
        SynthSpec {
            m_train: 48,
            m_test: 24,
            d: 9,
            rank: 2,
            confound: 0.05,
            signal_features: 6,
            signal_amp: 0.35,
            noise: 0.2,
            name: "tiny",
        }
    }
}

impl Dataset {
    /// Generate a dataset from a spec, deterministically from `seed`.
    pub fn synth(spec: SynthSpec, seed: u64) -> Dataset {
        let mut rng = Rng::seed_from_u64(seed ^ 0xC0DE_D0D0);
        let d_feat = spec.d - 1; // last column is the bias
        let s_feat = spec.signal_features.min(d_feat);

        // Low-rank nuisance mixing matrix A: d_feat × rank.
        let a: Vec<f64> = (0..d_feat * spec.rank)
            .map(|_| rng.gen_normal() / (spec.rank as f64).sqrt())
            .collect();
        // Which columns carry signal, and with which sign.
        let signal_cols = rng.permutation(d_feat);
        let signal_sign: Vec<f64> = (0..s_feat)
            .map(|_| if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 })
            .collect();

        let total = spec.m_train + spec.m_test;
        let mut x_raw = vec![0.0f64; total * d_feat];
        let mut y_all = vec![0.0f64; total];
        let mut z = vec![0.0f64; spec.rank];
        for i in 0..total {
            let label = (i % 2) as f64; // balanced classes
            y_all[i] = label;
            let sign = if label > 0.5 { 1.0 } else { -1.0 };
            for zk in z.iter_mut() {
                *zk = rng.gen_normal();
            }
            let row = &mut x_raw[i * d_feat..(i + 1) * d_feat];
            // pixel-like base + independent noise + low-rank confound
            for (j, rj) in row.iter_mut().enumerate() {
                let mut v = 0.5 + spec.noise * rng.gen_normal();
                for k in 0..spec.rank {
                    v += spec.confound * a[j * spec.rank + k] * z[k];
                }
                *rj = v.clamp(0.0, 1.0);
            }
            // sparse class signal
            for (si, &col) in signal_cols[..s_feat].iter().enumerate() {
                row[col] =
                    (row[col] + sign * signal_sign[si] * spec.signal_amp).clamp(0.0, 1.0);
            }
        }

        // Per-feature mean-centering (train statistics): removes the grand
        // mean eigendirection so gradient descent with the unsaturated
        // degree-1 link is stable at the paper's step sizes (see SynthSpec
        // docs). Features end in [−1, 1].
        for j in 0..d_feat {
            let mut mean = 0.0;
            for i in 0..spec.m_train {
                mean += x_raw[i * d_feat + j];
            }
            mean /= spec.m_train as f64;
            for i in 0..total {
                x_raw[i * d_feat + j] -= mean;
            }
        }

        // Shuffle train portion (classes were interleaved; keep it mixed
        // after client partitioning too).
        let perm = rng.permutation(spec.m_train);
        let mut x = vec![0.0f64; spec.m_train * spec.d];
        let mut y = vec![0.0f64; spec.m_train];
        for (dst, &src) in perm.iter().enumerate() {
            for j in 0..d_feat {
                x[dst * spec.d + j] = x_raw[src * d_feat + j];
            }
            x[dst * spec.d + d_feat] = 1.0; // bias column
            y[dst] = y_all[src];
        }
        let mut x_test = vec![0.0f64; spec.m_test * spec.d];
        let mut y_test = vec![0.0f64; spec.m_test];
        for i in 0..spec.m_test {
            let src = spec.m_train + i;
            for j in 0..d_feat {
                x_test[i * spec.d + j] = x_raw[src * d_feat + j];
            }
            x_test[i * spec.d + d_feat] = 1.0;
            y_test[i] = y_all[src];
        }

        Dataset {
            name: spec.name.to_string(),
            x,
            y,
            x_test,
            y_test,
            m: spec.m_train,
            d: spec.d,
            classes: 2,
        }
    }

    /// Split the training rows evenly across `n` clients (paper §V.A: "the
    /// dataset is distributed evenly across the clients"). Returns per-client
    /// row ranges `[start, end)`; remainders go to the first clients.
    pub fn client_ranges(&self, n: usize) -> Vec<(usize, usize)> {
        let base = self.m / n;
        let extra = self.m % n;
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        for j in 0..n {
            let len = base + usize::from(j < extra);
            out.push((start, start + len));
            start += len;
        }
        out
    }

    /// Number of rows after padding so `K` divides `m` (the protocol
    /// partitions the dataset into K equal submatrices; zero rows are
    /// provably inert in the gradient — see `runtime::padding`).
    pub fn padded_rows(&self, k: usize) -> usize {
        self.m.div_ceil(k) * k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Dataset::synth(SynthSpec::smoke(), 1);
        let b = Dataset::synth(SynthSpec::smoke(), 1);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = Dataset::synth(SynthSpec::smoke(), 2);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn shapes_match_paper() {
        let spec = SynthSpec::cifar_like();
        assert_eq!((spec.m_train, spec.d), (9019, 3073));
        assert_eq!(spec.m_test, 2000);
        let spec = SynthSpec::gisette_like();
        assert_eq!((spec.m_train, spec.d), (6000, 5000));
        assert_eq!(spec.m_test, 1000);
    }

    #[test]
    fn features_bounded_and_centered_with_bias() {
        let ds = Dataset::synth(SynthSpec::smoke(), 3);
        for (i, &v) in ds.x.iter().enumerate() {
            assert!((-1.0..=1.0).contains(&v), "x[{i}]={v}");
        }
        for i in 0..ds.m {
            assert_eq!(ds.x[i * ds.d + ds.d - 1], 1.0, "bias column");
        }
        // train columns are (near) zero-mean
        for j in 0..ds.d - 1 {
            let mean: f64 = (0..ds.m).map(|i| ds.x[i * ds.d + j]).sum::<f64>() / ds.m as f64;
            assert!(mean.abs() < 1e-9, "column {j} mean {mean}");
        }
    }

    #[test]
    fn labels_balanced() {
        let ds = Dataset::synth(SynthSpec::smoke(), 4);
        let ones = ds.y.iter().filter(|&&v| v > 0.5).count();
        assert!((ones as f64 - ds.m as f64 / 2.0).abs() <= 1.0);
    }

    #[test]
    fn client_ranges_cover_exactly() {
        let ds = Dataset::synth(SynthSpec::smoke(), 5);
        for n in [1usize, 3, 7, 13] {
            let ranges = ds.client_ranges(n);
            assert_eq!(ranges.len(), n);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges[n - 1].1, ds.m);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }

    #[test]
    fn batch_plan_full_batch_is_identity_layout() {
        // B = 1 must reproduce the classic layout exactly: identity
        // permutation, one range, the same padding `padded_rows` computes.
        let ds = Dataset::synth(SynthSpec::smoke(), 7);
        for k in [1usize, 3, 7] {
            let plan = BatchPlan::new(ds.m, k, 1, 99);
            assert_eq!(plan.rows_padded(), ds.padded_rows(k));
            assert_eq!(plan.ranges().to_vec(), vec![(0, ds.padded_rows(k))]);
            assert_eq!(plan.real_rows(0), ds.m);
            let slots = plan.slots();
            assert_eq!(slots.len(), ds.m);
            for (i, &(slot, src)) in slots.iter().enumerate() {
                assert_eq!((slot, src), (i, i), "B=1 must not permute");
            }
        }
    }

    #[test]
    fn batch_plan_partitions_exactly() {
        for (m, k, b) in [(48usize, 2usize, 3usize), (50, 3, 4), (400, 3, 16), (7, 1, 7)] {
            let plan = BatchPlan::new(m, k, b, 5);
            assert_eq!(plan.ranges().len(), b);
            // contiguous tiling, K | padded size, padding < K per batch
            let mut off = 0;
            let mut total_real = 0;
            for (bi, &(lo, hi)) in plan.ranges().iter().enumerate() {
                assert_eq!(lo, off, "batch {bi} not contiguous");
                let pb = hi - lo;
                assert_eq!(pb % k, 0, "batch {bi} padded size not divisible by K");
                let mb = plan.real_rows(bi);
                assert!(pb >= mb && pb < mb + k, "batch {bi} overpadded");
                total_real += mb;
                off = hi;
            }
            assert_eq!(off, plan.rows_padded());
            assert_eq!(total_real, m);
            // batch sizes even: differ by at most one real row
            let (mn, mx) = (0..b).fold((usize::MAX, 0), |(mn, mx), bi| {
                (mn.min(plan.real_rows(bi)), mx.max(plan.real_rows(bi)))
            });
            assert!(mx - mn <= 1, "uneven batches: {mn}..{mx}");
            // slots form a bijection real rows → distinct padded slots
            let slots = plan.slots();
            assert_eq!(slots.len(), m);
            let mut srcs: Vec<usize> = slots.iter().map(|&(_, s)| s).collect();
            srcs.sort_unstable();
            assert_eq!(srcs, (0..m).collect::<Vec<_>>());
            let mut dsts: Vec<usize> = slots.iter().map(|&(d, _)| d).collect();
            dsts.sort_unstable();
            dsts.dedup();
            assert_eq!(dsts.len(), m, "padded slots must be distinct");
        }
    }

    #[test]
    fn batch_plan_real_partition_is_k_invariant() {
        // The property the baseline bit-identity rests on: which real rows
        // land in which batch must not depend on K (only padding does).
        let (m, b, seed) = (400usize, 8usize, 11u64);
        let reference = BatchPlan::new(m, 1, b, seed);
        for k in [2usize, 3, 5, 16] {
            let plan = BatchPlan::new(m, k, b, seed);
            for bi in 0..b {
                assert_eq!(plan.real_rows(bi), reference.real_rows(bi), "k={k} batch {bi}");
            }
            // same rows in the same batches: compare per-batch source sets
            let by_batch = |p: &BatchPlan| -> Vec<Vec<usize>> {
                let slots = p.slots();
                let mut i = 0;
                (0..b)
                    .map(|bi| {
                        let mb = p.real_rows(bi);
                        let v: Vec<usize> = slots[i..i + mb].iter().map(|&(_, s)| s).collect();
                        i += mb;
                        v
                    })
                    .collect()
            };
            assert_eq!(by_batch(&plan), by_batch(&reference), "k={k}");
        }
    }

    #[test]
    fn batch_plan_deterministic_and_seed_sensitive() {
        let a = BatchPlan::new(100, 2, 4, 1);
        let b = BatchPlan::new(100, 2, 4, 1);
        assert_eq!(a.slots(), b.slots());
        let c = BatchPlan::new(100, 2, 4, 2);
        assert_ne!(a.slots(), c.slots(), "different seed must reshuffle");
        // schedule is the cyclic one
        assert_eq!(a.batch_of_iter(0), 0);
        assert_eq!(a.batch_of_iter(5), 1);
    }

    #[test]
    #[should_panic(expected = "more batches")]
    fn batch_plan_rejects_more_batches_than_samples() {
        BatchPlan::new(3, 1, 4, 1);
    }

    #[test]
    fn padded_rows_divisible() {
        let ds = Dataset::synth(SynthSpec::smoke(), 6);
        for k in [1usize, 3, 7, 16] {
            let p = ds.padded_rows(k);
            assert_eq!(p % k, 0);
            assert!(p >= ds.m && p < ds.m + k);
        }
    }
}
