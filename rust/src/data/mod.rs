//! Datasets. The paper trains binary logistic regression on CIFAR-10
//! (plane vs car, `(m,d) = (9019, 3073)`) and GISETTE (digits 4 vs 9,
//! `(6000, 5000)`). Those corpora are not redistributable/downloadable in
//! this offline environment, so we build **deterministic synthetic
//! stand-ins with identical shapes** (see DESIGN.md §2): class-conditional
//! Gaussians on a shared low-rank subspace, feature-normalized to `[0, 1]`,
//! with separation tuned so plaintext logistic regression lands near the
//! paper's accuracies (~82% CIFAR-like, ~97.5% GISETTE-like).
//!
//! Protocol cost depends only on `(m, d, N, K, T, r)` — identical by
//! construction; accuracy curves depend on quantization/approximation
//! error, which the stand-ins exercise at the same scale.

use crate::prng::Rng;

/// A dense binary-classification dataset, features in `[0, 1]`, last
/// feature column fixed to 1 (bias), labels in `{0, 1}`.
#[derive(Clone)]
pub struct Dataset {
    pub name: String,
    /// Train features, row-major `(m × d)`.
    pub x: Vec<f64>,
    /// Train labels, length `m`.
    pub y: Vec<f64>,
    /// Test features `(m_test × d)`.
    pub x_test: Vec<f64>,
    /// Test labels.
    pub y_test: Vec<f64>,
    pub m: usize,
    pub d: usize,
}

/// Parameters of the synthetic generator.
///
/// The generator is `x = 0.5 ± signal + noise + confound`, column-centered
/// after generation (features end in `[−1, 1]`, bias column = 1):
///
/// * a **sparse class signal**: `signal_features` columns move by
///   `±signal_amp` with the label — class-mean gaps of the size real
///   CIFAR/GISETTE features exhibit, which is what bounds the gradient
///   (`g0max ≈ m·signal_amp`) and therefore the fixed-point plan;
/// * **independent noise** of scale `noise` — keeps `λ_max(XᵀX)` at the
///   Marchenko–Pastur scale so gradient descent with the paper's degree-1
///   sigmoid (no saturation!) is stable at the paper's step sizes. This is
///   the property the paper's real datasets must also have had for Fig. 4
///   to converge (DESIGN.md §2 documents this substitution);
/// * a small **low-rank confound** (`rank`, `confound`) for realism —
///   correlated nuisance structure that does not carry label signal.
#[derive(Clone, Copy, Debug)]
pub struct SynthSpec {
    pub m_train: usize,
    pub m_test: usize,
    /// Total feature count including the bias column.
    pub d: usize,
    /// Dimension of the low-rank nuisance subspace.
    pub rank: usize,
    /// Scale of the low-rank confound.
    pub confound: f64,
    /// Number of columns carrying class signal.
    pub signal_features: usize,
    /// Per-column class-mean half-gap.
    pub signal_amp: f64,
    /// Independent per-feature noise σ.
    pub noise: f64,
    pub name: &'static str,
}

impl SynthSpec {
    /// CIFAR-10-like stand-in: binary plane/car, 9019 train + 2000 test,
    /// d = 3073 (= 32·32·3 pixels + bias). Signal tuned for ~82%
    /// plaintext test accuracy (paper: 81.75%).
    pub fn cifar_like() -> SynthSpec {
        SynthSpec {
            m_train: 9019,
            m_test: 2000,
            d: 3073,
            rank: 24,
            confound: 0.08,
            signal_features: 120,
            signal_amp: 0.025,
            noise: 0.25,
            name: "cifar10-like",
        }
    }

    /// GISETTE-like stand-in: digits 4 vs 9, 6000 train + 1000 test,
    /// d = 5000. Tuned for ~97.5% plaintext accuracy (paper: 97.5%).
    pub fn gisette_like() -> SynthSpec {
        SynthSpec {
            m_train: 6000,
            m_test: 1000,
            d: 5000,
            rank: 30,
            confound: 0.06,
            signal_features: 250,
            signal_amp: 0.034,
            noise: 0.25,
            name: "gisette-like",
        }
    }

    /// Small smoke-test dataset for unit/integration tests.
    pub fn smoke() -> SynthSpec {
        SynthSpec {
            m_train: 400,
            m_test: 100,
            d: 21,
            rank: 4,
            confound: 0.05,
            signal_features: 12,
            signal_amp: 0.18,
            noise: 0.25,
            name: "smoke",
        }
    }

    /// Tiny dataset for full-fidelity protocol tests (threads move every
    /// share); keep m·d small.
    pub fn tiny() -> SynthSpec {
        SynthSpec {
            m_train: 48,
            m_test: 24,
            d: 9,
            rank: 2,
            confound: 0.05,
            signal_features: 6,
            signal_amp: 0.35,
            noise: 0.2,
            name: "tiny",
        }
    }
}

impl Dataset {
    /// Generate a dataset from a spec, deterministically from `seed`.
    pub fn synth(spec: SynthSpec, seed: u64) -> Dataset {
        let mut rng = Rng::seed_from_u64(seed ^ 0xC0DE_D0D0);
        let d_feat = spec.d - 1; // last column is the bias
        let s_feat = spec.signal_features.min(d_feat);

        // Low-rank nuisance mixing matrix A: d_feat × rank.
        let a: Vec<f64> = (0..d_feat * spec.rank)
            .map(|_| rng.gen_normal() / (spec.rank as f64).sqrt())
            .collect();
        // Which columns carry signal, and with which sign.
        let signal_cols = rng.permutation(d_feat);
        let signal_sign: Vec<f64> = (0..s_feat)
            .map(|_| if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 })
            .collect();

        let total = spec.m_train + spec.m_test;
        let mut x_raw = vec![0.0f64; total * d_feat];
        let mut y_all = vec![0.0f64; total];
        let mut z = vec![0.0f64; spec.rank];
        for i in 0..total {
            let label = (i % 2) as f64; // balanced classes
            y_all[i] = label;
            let sign = if label > 0.5 { 1.0 } else { -1.0 };
            for zk in z.iter_mut() {
                *zk = rng.gen_normal();
            }
            let row = &mut x_raw[i * d_feat..(i + 1) * d_feat];
            // pixel-like base + independent noise + low-rank confound
            for (j, rj) in row.iter_mut().enumerate() {
                let mut v = 0.5 + spec.noise * rng.gen_normal();
                for k in 0..spec.rank {
                    v += spec.confound * a[j * spec.rank + k] * z[k];
                }
                *rj = v.clamp(0.0, 1.0);
            }
            // sparse class signal
            for (si, &col) in signal_cols[..s_feat].iter().enumerate() {
                row[col] =
                    (row[col] + sign * signal_sign[si] * spec.signal_amp).clamp(0.0, 1.0);
            }
        }

        // Per-feature mean-centering (train statistics): removes the grand
        // mean eigendirection so gradient descent with the unsaturated
        // degree-1 link is stable at the paper's step sizes (see SynthSpec
        // docs). Features end in [−1, 1].
        for j in 0..d_feat {
            let mut mean = 0.0;
            for i in 0..spec.m_train {
                mean += x_raw[i * d_feat + j];
            }
            mean /= spec.m_train as f64;
            for i in 0..total {
                x_raw[i * d_feat + j] -= mean;
            }
        }

        // Shuffle train portion (classes were interleaved; keep it mixed
        // after client partitioning too).
        let perm = rng.permutation(spec.m_train);
        let mut x = vec![0.0f64; spec.m_train * spec.d];
        let mut y = vec![0.0f64; spec.m_train];
        for (dst, &src) in perm.iter().enumerate() {
            for j in 0..d_feat {
                x[dst * spec.d + j] = x_raw[src * d_feat + j];
            }
            x[dst * spec.d + d_feat] = 1.0; // bias column
            y[dst] = y_all[src];
        }
        let mut x_test = vec![0.0f64; spec.m_test * spec.d];
        let mut y_test = vec![0.0f64; spec.m_test];
        for i in 0..spec.m_test {
            let src = spec.m_train + i;
            for j in 0..d_feat {
                x_test[i * spec.d + j] = x_raw[src * d_feat + j];
            }
            x_test[i * spec.d + d_feat] = 1.0;
            y_test[i] = y_all[src];
        }

        Dataset {
            name: spec.name.to_string(),
            x,
            y,
            x_test,
            y_test,
            m: spec.m_train,
            d: spec.d,
        }
    }

    /// Split the training rows evenly across `n` clients (paper §V.A: "the
    /// dataset is distributed evenly across the clients"). Returns per-client
    /// row ranges `[start, end)`; remainders go to the first clients.
    pub fn client_ranges(&self, n: usize) -> Vec<(usize, usize)> {
        let base = self.m / n;
        let extra = self.m % n;
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        for j in 0..n {
            let len = base + usize::from(j < extra);
            out.push((start, start + len));
            start += len;
        }
        out
    }

    /// Number of rows after padding so `K` divides `m` (the protocol
    /// partitions the dataset into K equal submatrices; zero rows are
    /// provably inert in the gradient — see `runtime::padding`).
    pub fn padded_rows(&self, k: usize) -> usize {
        self.m.div_ceil(k) * k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Dataset::synth(SynthSpec::smoke(), 1);
        let b = Dataset::synth(SynthSpec::smoke(), 1);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = Dataset::synth(SynthSpec::smoke(), 2);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn shapes_match_paper() {
        let spec = SynthSpec::cifar_like();
        assert_eq!((spec.m_train, spec.d), (9019, 3073));
        assert_eq!(spec.m_test, 2000);
        let spec = SynthSpec::gisette_like();
        assert_eq!((spec.m_train, spec.d), (6000, 5000));
        assert_eq!(spec.m_test, 1000);
    }

    #[test]
    fn features_bounded_and_centered_with_bias() {
        let ds = Dataset::synth(SynthSpec::smoke(), 3);
        for (i, &v) in ds.x.iter().enumerate() {
            assert!((-1.0..=1.0).contains(&v), "x[{i}]={v}");
        }
        for i in 0..ds.m {
            assert_eq!(ds.x[i * ds.d + ds.d - 1], 1.0, "bias column");
        }
        // train columns are (near) zero-mean
        for j in 0..ds.d - 1 {
            let mean: f64 = (0..ds.m).map(|i| ds.x[i * ds.d + j]).sum::<f64>() / ds.m as f64;
            assert!(mean.abs() < 1e-9, "column {j} mean {mean}");
        }
    }

    #[test]
    fn labels_balanced() {
        let ds = Dataset::synth(SynthSpec::smoke(), 4);
        let ones = ds.y.iter().filter(|&&v| v > 0.5).count();
        assert!((ones as f64 - ds.m as f64 / 2.0).abs() <= 1.0);
    }

    #[test]
    fn client_ranges_cover_exactly() {
        let ds = Dataset::synth(SynthSpec::smoke(), 5);
        for n in [1usize, 3, 7, 13] {
            let ranges = ds.client_ranges(n);
            assert_eq!(ranges.len(), n);
            assert_eq!(ranges[0].0, 0);
            assert_eq!(ranges[n - 1].1, ds.m);
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
        }
    }

    #[test]
    fn padded_rows_divisible() {
        let ds = Dataset::synth(SynthSpec::smoke(), 6);
        for k in [1usize, 3, 7, 16] {
            let p = ds.padded_rows(k);
            assert_eq!(p % k, 0);
            assert!(p >= ds.m && p < ds.m + k);
        }
    }
}
