//! The COPML coordinator — the paper's system contribution, orchestrated
//! from rust.
//!
//! Three trainers share one configuration ([`CopmlConfig`]) and one
//! quantization pipeline ([`QuantizedTask`]):
//!
//! * [`algo`] — *algorithmic-fidelity* mode: the exact field recursion of
//!   the protocol (same quantization, same Lagrange decode values, same
//!   TruncPr randomness from the same dealer seed) evaluated centrally.
//!   Bit-identical to the full protocol (asserted in
//!   `tests/protocol_equivalence.rs`); used for paper-scale accuracy runs
//!   (Fig. 4).
//! * [`protocol`] — the full threaded protocol: N client threads exchanging
//!   real shares over `net::local`, computing encoded gradients via
//!   [`crate::runtime`] (native or PJRT engine), decoding and updating the
//!   model inside MPC. Every byte that the paper's clients would exchange
//!   crosses a channel here.
//! * [`baseline`] — the conventional-MPC baselines (\[BGW88\] and \[BH08\])
//!   applied to the same task (Appendix C/D), for the Fig. 3 / Table I
//!   comparisons.

pub mod algo;
pub mod baseline;
pub mod protocol;
pub mod rounds;

use crate::data::{BatchPlan, Dataset};
use crate::field::{Field, KernelTier, Parallelism};
use crate::lcc;
use crate::ml::sigmoid::SigmoidPoly;
use crate::ml::{fit_sigmoid, ModelKind, ModelMetrics};
use crate::mpc::OfflineMode;
use crate::net::{Runtime, Wire};
use crate::quant::{self, FpPlan};
use crate::runtime::Engine;

/// Fault-injection plan for straggler/failure experiments: per-party
/// compute delays and kill points, threaded from the CLI (`--delay
/// id:ms`, `--kill-after id:iter`) into the full protocol. Faults only
/// perturb *timing and liveness* — the decoded gradients are exact
/// interpolations (Theorem 1), so a run that completes under faults has a
/// bit-identical `w_trace`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// `(party, milliseconds)`: injected compute-phase sleep per
    /// iteration — models slow hardware / a congested link.
    pub delays: Vec<(usize, u64)>,
    /// `(party, iteration)`: the party exits (closing its transport) at
    /// the start of that 0-based iteration — models a crash.
    pub kills: Vec<(usize, usize)>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.delays.is_empty() && self.kills.is_empty()
    }

    /// Injected per-iteration delay for `party`, if any.
    pub fn delay_ms(&self, party: usize) -> Option<u64> {
        self.delays.iter().find(|&&(p, _)| p == party).map(|&(_, ms)| ms)
    }

    /// Iteration at which `party` is killed, if any.
    pub fn kill_at(&self, party: usize) -> Option<usize> {
        self.kills.iter().find(|&&(p, _)| p == party).map(|&(_, it)| it)
    }

    /// Parse a CLI list like `"3:250,5:100"` into `(party, value)` pairs.
    pub fn parse_pairs(spec: &str, what: &str) -> Result<Vec<(usize, u64)>, String> {
        let mut out = Vec::new();
        for item in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let (id, val) = item
                .trim()
                .split_once(':')
                .ok_or_else(|| format!("invalid --{what} entry '{item}' (expected id:value)"))?;
            let id: usize = id
                .parse()
                .map_err(|_| format!("invalid party id in --{what} entry '{item}'"))?;
            let val: u64 = val
                .parse()
                .map_err(|_| format!("invalid value in --{what} entry '{item}'"))?;
            out.push((id, val));
        }
        Ok(out)
    }
}

/// Choice of COPML's `(K, T)` operating point (paper §V.A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CaseParams {
    pub k: usize,
    pub t: usize,
}

impl CaseParams {
    /// Case 1 — maximum parallelization: `K = ⌊(N−1)/3⌋`, `T = 1` (r = 1).
    pub fn case1(n: usize) -> CaseParams {
        CaseParams { k: (n - 1) / 3, t: 1 }
    }

    /// Case 2 — equal parallelization and privacy:
    /// `T = ⌊(N−3)/6⌋`, `K = ⌊(N+2)/3⌋ − T`.
    pub fn case2(n: usize) -> CaseParams {
        let t = ((n.saturating_sub(3)) / 6).max(1);
        CaseParams { k: ((n + 2) / 3).saturating_sub(t).max(1), t }
    }

    /// Explicit `(K, T)`.
    pub fn explicit(k: usize, t: usize) -> CaseParams {
        CaseParams { k, t }
    }
}

/// Full configuration of a COPML training run.
#[derive(Clone, Debug)]
pub struct CopmlConfig {
    /// Number of clients.
    pub n: usize,
    /// Privacy threshold.
    pub t: usize,
    /// Parallelization parameter (dataset split count).
    pub k: usize,
    /// Degree of the sigmoid approximation (paper uses 1).
    pub r: usize,
    /// Fixed-point plan (field, scales, truncation widths).
    pub plan: FpPlan,
    /// Gradient-descent iterations `J`.
    pub iters: usize,
    /// Mini-batch count `B` (`--batches`): the padded rows are dealt into
    /// `B` seeded-permutation batches ([`BatchPlan`]), each Lagrange-encoded
    /// **once up front** (amortized over all epochs), and iteration `i`
    /// trains on batch `i mod B` with learning-rate factor
    /// `Round(2^{l_e}·η/m_b)`. `1` (the default) is classic full-batch
    /// training, bit-identical to every pre-existing trace.
    pub batches: usize,
    /// Learning rate `η`.
    pub eta: f64,
    /// Master seed (dealer randomness, share randomness, masks).
    pub seed: u64,
    /// Which engine evaluates Eq. (7).
    pub engine: Engine,
    /// Half-range of the sigmoid least-squares fit.
    pub fit_range: f64,
    /// Use the footnote-4 subgroup optimization for encoding exchanges.
    pub subgroups: bool,
    /// Intra-client thread pool for the field hot paths (Lagrange
    /// encode/decode, the encoded-gradient kernel, the central recursion).
    /// Bit-identical results for every setting (`field::par` docs).
    pub parallelism: Parallelism,
    /// On-the-wire element encoding for the transports and their byte
    /// ledgers: 64-bit words as in the paper's MPI implementation
    /// ([`Wire::U64`], the default), or packed 32-bit words
    /// ([`Wire::U32`]) — lossless since `p < 2^31`, and half the payload
    /// bytes. Value-transparent: the model trajectory is bit-identical
    /// under either format.
    pub wire: Wire,
    /// How the socket transports drain peer connections: one blocking
    /// reader thread per peer ([`Runtime::Threaded`], the default and the
    /// bit-identity oracle) or a single shared `poll(2)` reactor thread
    /// over non-blocking sockets ([`Runtime::Event`] — the large-N
    /// runtime). Value-transparent: the trajectory is bit-identical under
    /// either, and the in-process hub ignores the choice entirely.
    pub runtime: Runtime,
    /// Who produces the offline randomness pools: the trusted dealer
    /// (footnote 3's crypto-service provider — the default, bit-identical
    /// to every pre-existing trace) or the dealer-free distributed phase
    /// ([`crate::mpc::offline`], DN07 extraction over the live transport).
    pub offline: OfflineMode,
    /// Injected faults for straggler experiments (full protocol only;
    /// empty = no faults, the default).
    pub faults: FaultPlan,
    /// Straggler exclusion threshold: a party that misses this many
    /// consecutive quorums is excluded for the rest of training (decided
    /// by the quorum leader, applied by every live party in the same
    /// round). `None` (the default) disables exclusion: late parties are
    /// skipped per-round but stay in the roster.
    pub max_lag: Option<usize>,
    /// Field-kernel tier for the hot paths (`--kernel barrett|mont`):
    /// scalar Barrett ([`crate::field::vecops`], the default and the
    /// bit-identity oracle) or lane-blocked batch-Montgomery
    /// ([`crate::field::mont`]). Value-transparent: both tiers compute
    /// exact mod-`p` arithmetic on canonical representatives, so the
    /// trajectory is bit-identical under either
    /// (`tests/protocol_equivalence.rs`).
    pub kernel: KernelTier,
    /// Pipelined offline factory (`--chunk C`): generate the distributed
    /// offline pools in `C`-sized chunks on a background producer thread
    /// while the online rounds consume them, instead of one blocking
    /// up-front pass. `None` (the default) is the legacy one-shot phase.
    /// Value-transparent: the chunk schedule is deterministic and the
    /// concatenated chunks are element-identical to the one-shot pools
    /// ([`crate::mpc::offline`] chunk-stability contract), so `w_trace`
    /// is bit-identical for every chunk size. Requires
    /// [`OfflineMode::Distributed`] (the dealer has no wire phase to
    /// hide) and an empty fault plan (a mid-production departure would
    /// strand the SPMD producers).
    pub chunk: Option<usize>,
    /// Serve-session id: which [`crate::net::tags`] session stripe this
    /// run's tags come from. `0` (the default) is the legacy tag layout,
    /// bit-identical to every pre-existing trace; `copml serve` runs job
    /// `j` in session `j` so consecutive jobs — and job `j+1`'s
    /// prefetched offline factory — share one mesh without tag reuse.
    /// Value-transparent: session ids renumber tags, never values.
    pub session: u64,
    /// Which workload to train (`--model logreg|multinomial|linreg`).
    /// [`ModelKind::Logreg`] (the default) is the seed workload, with a
    /// secure state vector of width `G = d·channels = d` — bit-identical
    /// to every pre-existing trace. Multinomial widens the state to
    /// `d·C` one-vs-rest channels over the same encoding; linreg replaces
    /// the iteration loop with one closed-form normal-equations round.
    pub model: ModelKind,
}

impl CopmlConfig {
    /// Sensible defaults for a dataset: paper-parity plan scaled to the
    /// dataset's width, `η = 2`, 50 iterations (the paper's count).
    pub fn for_dataset(ds: &Dataset, n: usize, case: CaseParams, seed: u64) -> CopmlConfig {
        let plan = if ds.d > 4096 { FpPlan::paper_gisette() } else { FpPlan::paper_cifar() };
        CopmlConfig {
            n,
            t: case.t,
            k: case.k,
            r: 1,
            plan,
            iters: 50,
            batches: 1,
            eta: 2.0,
            seed,
            engine: Engine::Native,
            fit_range: 4.0,
            subgroups: true,
            parallelism: Parallelism::sequential(),
            wire: Wire::U64,
            runtime: Runtime::Threaded,
            offline: OfflineMode::Dealer,
            faults: FaultPlan::default(),
            max_lag: None,
            kernel: KernelTier::Barrett,
            chunk: None,
            session: 0,
            model: ModelKind::Logreg,
        }
    }

    /// Gradient channels of the configured workload on `ds`
    /// (`G = d·channels` is the secure state width).
    pub fn channels(&self, ds: &Dataset) -> usize {
        self.model.channels(ds)
    }

    /// The recovery threshold `(2r+1)(K+T−1)+1` this config needs.
    pub fn recovery_threshold(&self) -> usize {
        lcc::recovery_threshold(self.r, self.k, self.t)
    }

    /// Validate `N ≥ (2r+1)(K+T−1)+1` (Theorem 1) and the fixed-point plan.
    pub fn validate(&self, ds: &Dataset) -> Result<(), String> {
        if self.k == 0 || self.t == 0 {
            return Err("K and T must be ≥ 1".into());
        }
        // Workload preconditions: label shape first (the clearest error
        // when model and dataset disagree), then the closed-form
        // restrictions — linreg runs one normal-equations round, so a
        // mini-batch schedule or a mid-iteration fault plan is
        // meaningless for it.
        let model = self.model.model();
        model.check_dataset(ds)?;
        if !model.iterative() {
            if self.batches != 1 {
                return Err(format!(
                    "--batches {} is meaningless for model {}: the closed-form solve \
                     aggregates the full dataset in one round",
                    self.batches, self.model
                ));
            }
            if !self.faults.is_empty() || self.max_lag.is_some() {
                return Err(format!(
                    "fault/straggler plans target the iteration loop, which model {} \
                     does not run (one closed-form round)",
                    self.model
                ));
            }
        }
        // The PJRT artifacts are AOT-compiled for a single d-wide model
        // vector; multi-channel workloads need the native kernel's
        // class-stacked pass.
        if self.engine == Engine::Pjrt && self.model != ModelKind::Logreg {
            return Err(format!(
                "engine=pjrt supports only the logreg workload (AOT artifacts are \
                 single-class); model {} needs engine=native",
                self.model
            ));
        }
        // Tag-space capacity (`net::tags`): every iteration claims one
        // ROUND-window stride and every batch one ENCODE-window stride.
        // A config that outruns either window would panic mid-run inside
        // the allocator — reject it here with the budget named instead
        // (checked before batch geometry so the tag-window diagnosis wins
        // for absurd batch counts).
        if (self.iters as u64) > crate::net::tags::max_iters() {
            return Err(format!(
                "iters={} exceeds the ROUND tag window capacity ({} iterations of {} \
                 tags each — see net::tags)",
                self.iters,
                crate::net::tags::max_iters(),
                crate::net::tags::ROUND_STRIDE
            ));
        }
        if (self.batches as u64) > crate::net::tags::max_batches() {
            return Err(format!(
                "batches={} exceeds the ENCODE tag window capacity ({} batches of {} \
                 tags each — see net::tags)",
                self.batches,
                crate::net::tags::max_batches(),
                crate::net::tags::ENCODE_STRIDE
            ));
        }
        // Serve-session geometry: the session must own a tag stripe, and
        // a stripe's round region is smaller than the legacy ROUND window
        // (sessions ≥ 1 — session 0 runs in the legacy windows and was
        // bounded above).
        if self.session >= crate::net::tags::max_sessions() {
            return Err(format!(
                "session={} exceeds the SESSIONS tag stripe capacity ({} sessions — \
                 see net::tags)",
                self.session,
                crate::net::tags::max_sessions()
            ));
        }
        if self.session >= 1 && (self.iters as u64) > crate::net::tags::max_session_iters() {
            return Err(format!(
                "iters={} exceeds session {}'s ROUND stripe capacity ({} iterations — \
                 see net::tags)",
                self.iters,
                self.session,
                crate::net::tags::max_session_iters()
            ));
        }
        // Pipelined offline factory preconditions.
        if let Some(chunk) = self.chunk {
            if chunk == 0 {
                return Err("--chunk must be ≥ 1".into());
            }
            if !matches!(self.offline, OfflineMode::Distributed) {
                return Err(
                    "--chunk requires --offline distributed: the dealer pool is replayed \
                     locally with no wire phase to pipeline"
                        .into(),
                );
            }
            if !self.faults.is_empty() {
                return Err(
                    "--chunk is incompatible with an injected fault plan: a departing \
                     party would strand the SPMD chunk producers mid-schedule"
                        .into(),
                );
            }
        }
        // Mini-batch geometry — the shared checker, so the trainers, the
        // baselines, and the cost model agree on which geometries are
        // legal (every batch needs ≥ K real rows and a schedule slot).
        BatchPlan::validate_geometry(ds.m, self.k, self.batches, self.iters)?;
        // Footnote-4 subgroups partition the clients into groups of T+1;
        // with N < 2(T+1) there is at most one (possibly undersized) group
        // (degenerate at N < T+1, e.g. N=3, T=3, where reconstruction is
        // under-determined). With the default r = 1 the recovery-threshold
        // check below already implies N ≥ 3T+1, so this guard exists to
        // name the failure mode precisely and to stay safe should `r` (a
        // public field) ever be set below 1.
        if self.n < 2 * (self.t + 1) {
            return Err(format!(
                "N={} too small for the subgroup geometry: need N ≥ 2(T+1) = {} (T={})",
                self.n,
                2 * (self.t + 1),
                self.t
            ));
        }
        let need = self.recovery_threshold();
        if self.n < need {
            return Err(format!(
                "N={} below recovery threshold (2r+1)(K+T−1)+1={need} (r={}, K={}, T={})",
                self.n, self.r, self.k, self.t
            ));
        }
        // Fault plan sanity: the quorum machinery tolerates slow and dead
        // parties, but party 0 is the king (opening hub) AND the quorum
        // leader — the protocol has no fail-over for it.
        let fault_ids = || {
            self.faults
                .delays
                .iter()
                .map(|&(id, _)| id)
                .chain(self.faults.kills.iter().map(|&(id, _)| id))
        };
        for id in fault_ids() {
            if id >= self.n {
                return Err(format!("fault plan names party {id}, but N = {}", self.n));
            }
            if id == 0 {
                return Err(
                    "fault plan may not target party 0: it is the king (opening hub) \
                     and quorum leader, with no fail-over"
                        .into(),
                );
            }
        }
        // Note on opening contributors: the per-round king openings are
        // the two TruncPr opens at degree T (contributors 0..=T — party
        // 0's own subgroup, protected by the king-strand check below);
        // the only degree-2T opening is the one-time Xᵀy reduction, which
        // completes before the earliest kill can fire. So kills of
        // parties above T need no special-casing here beyond the
        // collateral/slack accounting.
        for &(id, iter) in &self.faults.kills {
            if iter >= self.iters {
                return Err(format!(
                    "--kill-after {id}:{iter} can never fire: training runs {} \
                     iterations (kill points are 0-based)",
                    self.iters
                ));
            }
        }
        // Duplicate entries would silently shadow each other (the first
        // match wins in delay_ms/kill_at) — reject them instead.
        for (what, mut ids) in [
            ("delay", self.faults.delays.iter().map(|&(id, _)| id).collect::<Vec<_>>()),
            ("kill-after", self.faults.kills.iter().map(|&(id, _)| id).collect::<Vec<_>>()),
        ] {
            ids.sort_unstable();
            if ids.windows(2).any(|w| w[0] == w[1]) {
                return Err(format!("--{what} names the same party more than once"));
            }
        }
        if !self.faults.kills.is_empty() && self.max_lag.is_none() {
            return Err(
                "--kill-after requires --max-lag: without straggler exclusion the \
                 final model opening would block on the dead party"
                    .into(),
            );
        }
        if let Some(lag) = self.max_lag {
            if lag == 0 {
                return Err("--max-lag must be ≥ 1 (0 would exclude everyone)".into());
            }
            // With exclusion armed, every faulted party will eventually
            // leave the roster — and take subgroup collateral with it:
            // once a group has fewer than T+1 live members, its survivors
            // cannot reconstruct their encodings and halt too. Count the
            // full expected loss, not just the named parties.
            let mut faulted: Vec<usize> = fault_ids().collect();
            faulted.sort_unstable();
            faulted.dedup();
            let mut lost = faulted.clone();
            if self.subgroups {
                for &id in &faulted {
                    let group = protocol::subgroup(self.n, self.t, id);
                    let survivors = group.iter().filter(|j| !faulted.contains(j)).count();
                    if survivors < self.t + 1 {
                        lost.extend(group);
                    }
                }
                if lost.contains(&0) {
                    return Err(
                        "fault plan strands party 0 (the king / quorum leader): its \
                         subgroup would fall below T+1 live members once the faulted \
                         mates are excluded — fault parties outside party 0's subgroup"
                            .into(),
                    );
                }
            } else {
                // Naive layout: parties 0..=T are everyone's encode
                // sources; losing any of them strands the whole run.
                if let Some(&id) = faulted.iter().find(|&&id| id <= self.t) {
                    return Err(format!(
                        "fault plan targets party {id}, an encode source of the naive \
                         (subgroups=false) layout — every client needs its share"
                    ));
                }
            }
            lost.sort_unstable();
            lost.dedup();
            if self.n < need + lost.len() {
                return Err(format!(
                    "fault plan disables {} parties ({} named + subgroup collateral) but \
                     the quorum needs {need} of N={} (Theorem 1 slack N − need = {})",
                    lost.len(),
                    faulted.len(),
                    self.n,
                    self.n - need
                ));
            }
        }
        // Fixed-point budget, *measured* on the data: each workload probes
        // its own gradient (or opened-moment) magnitudes and runs the
        // Appendix-A checks — see `ml::model`. The trainers additionally
        // range-check every truncation input at runtime.
        model.validate_plan(&self.plan, ds, self.r)?;
        // The largest batch has the smallest learning-rate factor; if it
        // quantizes to zero the updates for that batch are no-ops. With
        // B = 1 this is exactly the legacy full-batch check. (The
        // closed-form workload takes no gradient steps, so η is unused.)
        let mb_max = ds.m.div_ceil(self.batches);
        if model.iterative() && self.plan.eta_factor(self.eta, mb_max) == 0 {
            return Err(format!(
                "learning rate quantizes to zero: Round(2^{}·{}/{mb_max}) = 0 \
                 (largest of {} batches) — raise η or l_e",
                self.plan.le, self.eta, self.batches
            ));
        }
        Ok(())
    }

    /// Fit and quantize the sigmoid polynomial for this config.
    ///
    /// Coefficient `i` is scaled at `2^{l_c+(1−i)(l_x+l_w)}` so every term
    /// of `ĝ(z_q)` lands on the common scale `2^{l_c+l_x+l_w}` (see
    /// `quant` module docs).
    pub fn quantized_sigmoid(&self) -> (SigmoidPoly, Vec<u64>) {
        let poly = fit_sigmoid(self.r, self.fit_range, 4000);
        let f = self.plan.field;
        let base = self.plan.lc as i64;
        let zscale = (self.plan.lx + self.plan.lw) as i64;
        let coeffs_q: Vec<u64> = poly
            .coeffs
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let exp = base + (1 - i as i64) * zscale;
                let scaled = c * 2f64.powi(exp as i32);
                f.from_i64(quant::round_half_away(scaled))
            })
            .collect();
        (poly, coeffs_q)
    }
}

/// The dataset quantized into the field in the [`BatchPlan`]'s permuted,
/// per-batch-padded layout (`K | rows` within every batch), plus the
/// per-batch quantized learning-rate factors — everything the secure
/// trainers consume. With `batches = 1` this is exactly the classic
/// full-batch layout (identity permutation, one padded range).
pub struct QuantizedTask {
    pub f: Field,
    /// Quantized features, `(rows_padded × d)`, scale `2^{l_x}` — rows in
    /// batch-plan order, padding rows zero at every batch tail.
    pub x_q: Vec<u64>,
    /// Quantized labels in the class-major channel layout, length
    /// `channels · rows_padded`: channel `c` of row `slot` sits at
    /// `c·rows_padded + slot` ([`crate::ml::Model::quantize_label`] picks
    /// the per-workload value and scale). Padding rows carry label 0 —
    /// inert, as their feature rows are zero. With one channel (the seed
    /// workload) this is exactly the legacy `rows_padded` vector.
    pub y_q: Vec<u64>,
    pub rows_padded: usize,
    pub d: usize,
    /// Gradient channels of the configured workload (`G = d·channels` is
    /// the secure state width; 1 for the seed workload).
    pub channels: usize,
    /// True (unpadded) sample count `m`.
    pub m: usize,
    /// Per-batch `e_q[b] = Round(2^{l_e}·η/m_b)` with `m_b` the batch's
    /// real-row count (`m_b = m` for full batch). Public constants, so the
    /// per-batch scaling stays a communication-free share operation.
    pub eta_qs: Vec<u64>,
    /// Quantized sigmoid coefficients (see `CopmlConfig::quantized_sigmoid`).
    pub coeffs_q: Vec<u64>,
    /// The real-valued fit (for reference links).
    pub poly: SigmoidPoly,
    /// The mini-batch partition this layout was built for.
    pub batches: BatchPlan,
}

impl QuantizedTask {
    pub fn new(cfg: &CopmlConfig, ds: &Dataset) -> QuantizedTask {
        let f = cfg.plan.field;
        let model = cfg.model.model();
        let channels = cfg.channels(ds);
        let plan = BatchPlan::new(ds.m, cfg.k, cfg.batches, cfg.seed);
        let rows_padded = plan.rows_padded();
        let mut x_q = vec![0u64; rows_padded * ds.d];
        let mut y_q = vec![0u64; channels * rows_padded];
        for (slot, src) in plan.slots() {
            for j in 0..ds.d {
                x_q[slot * ds.d + j] = quant::quantize(f, ds.x[src * ds.d + j], cfg.plan.lx);
            }
            for c in 0..channels {
                y_q[c * rows_padded + slot] = model.quantize_label(&cfg.plan, ds.y[src], c);
            }
        }
        let eta_qs: Vec<u64> =
            (0..plan.b).map(|b| cfg.plan.eta_factor(cfg.eta, plan.real_rows(b))).collect();
        let (poly, coeffs_q) = cfg.quantized_sigmoid();
        QuantizedTask {
            f,
            x_q,
            y_q,
            rows_padded,
            d: ds.d,
            channels,
            m: ds.m,
            eta_qs,
            coeffs_q,
            poly,
            batches: plan,
        }
    }

    /// The secure state width `G = d·channels`.
    pub fn width(&self) -> usize {
        self.d * self.channels
    }

    /// Channel `c` of the quantized labels (`rows_padded` elements).
    pub fn y_channel(&self, c: usize) -> &[u64] {
        &self.y_q[c * self.rows_padded..(c + 1) * self.rows_padded]
    }
}

/// Per-iteration outcome of a secure training run.
#[derive(Clone, Debug, Default)]
pub struct TrainOutput {
    /// Final model, dequantized.
    pub w: Vec<f64>,
    /// Final model in the field (scale `2^{l_w}`).
    pub w_field: Vec<u64>,
    /// Model snapshot per iteration (field domain, width `G = d·channels`)
    /// — for equivalence tests and accuracy traces. One entry total for
    /// the closed-form workload.
    pub w_trace: Vec<Vec<u64>>,
    /// Per-snapshot workload score on the train/test split (classification
    /// accuracy, or R² for regression — `Model::score`).
    pub train_accuracy: Vec<f64>,
    pub test_accuracy: Vec<f64>,
    pub loss: Vec<f64>,
    /// Full metric set of the final model on the train split
    /// (accuracy/AUC for classifiers, R² for regression).
    pub train_metrics: ModelMetrics,
    /// Full metric set of the final model on the test split.
    pub test_metrics: ModelMetrics,
}

impl TrainOutput {
    /// Fill score/loss traces and final metrics from the field-domain
    /// snapshots, dispatched through the configured workload.
    pub fn eval_traces(&mut self, cfg: &CopmlConfig, ds: &Dataset) {
        let model = cfg.model.model();
        let classes = ds.classes;
        self.train_accuracy.clear();
        self.test_accuracy.clear();
        self.loss.clear();
        for wq in &self.w_trace {
            let w = model.decode(&cfg.plan, wq);
            self.train_accuracy.push(model.score(&ds.x, &ds.y, ds.d, classes, &w));
            self.test_accuracy.push(model.score(&ds.x_test, &ds.y_test, ds.d, classes, &w));
            self.loss.push(model.loss(&ds.x, &ds.y, ds.d, classes, &w));
        }
        if let Some(wq) = self.w_trace.last() {
            self.w_field = wq.clone();
            self.w = model.decode(&cfg.plan, wq);
            self.train_metrics = model.metrics(&ds.x, &ds.y, ds.d, classes, &self.w);
            self.test_metrics = model.metrics(&ds.x_test, &ds.y_test, ds.d, classes, &self.w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;

    #[test]
    fn case_params_match_paper_n50() {
        // §V.A at N=50: Case 1 → K=16, T=1; Case 2 → T=7, K=⌊52/3⌋−7=10.
        assert_eq!(CaseParams::case1(50), CaseParams { k: 16, t: 1 });
        assert_eq!(CaseParams::case2(50), CaseParams { k: 10, t: 7 });
    }

    #[test]
    fn case_params_satisfy_threshold_for_all_n() {
        for n in 10..=60 {
            for case in [CaseParams::case1(n), CaseParams::case2(n)] {
                if case.k >= 1 {
                    assert!(
                        lcc::recovery_threshold(1, case.k, case.t) <= n,
                        "n={n} case={case:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn config_validation() {
        let ds = Dataset::synth(SynthSpec::smoke(), 1);
        let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 1);
        assert!(cfg.validate(&ds).is_ok(), "{:?}", cfg.validate(&ds));
        cfg.k = 10; // threshold 3·10+1 = 31 > 10
        assert!(cfg.validate(&ds).is_err());
    }

    #[test]
    fn validate_rejects_undersized_subgroup_geometry() {
        // n=3, t=3: fewer clients than one subgroup needs (group of
        // 3 < T+1 members — under-determined reconstruction). The explicit
        // guard names the geometry problem instead of a generic threshold
        // error, and holds even for non-default `r`.
        let ds = Dataset::synth(SynthSpec::tiny(), 1);
        let cfg = CopmlConfig::for_dataset(&ds, 3, CaseParams::explicit(1, 3), 1);
        let err = cfg.validate(&ds).unwrap_err();
        assert!(err.contains("subgroup"), "unexpected error: {err}");
        // The boundary itself is fine: n = 2(t+1).
        let ok = CopmlConfig::for_dataset(&ds, 4, CaseParams::explicit(1, 1), 1);
        assert!(ok.validate(&ds).is_ok(), "{:?}", ok.validate(&ds));
    }

    #[test]
    fn fault_plan_parsing() {
        assert_eq!(
            FaultPlan::parse_pairs("3:250, 5:100", "delay").unwrap(),
            vec![(3, 250), (5, 100)]
        );
        assert!(FaultPlan::parse_pairs("", "delay").unwrap().is_empty());
        assert!(FaultPlan::parse_pairs("3", "delay").is_err());
        assert!(FaultPlan::parse_pairs("x:1", "delay").is_err());
        assert!(FaultPlan::parse_pairs("1:y", "delay").is_err());
        let plan = FaultPlan { delays: vec![(3, 250)], kills: vec![(5, 2)] };
        assert_eq!(plan.delay_ms(3), Some(250));
        assert_eq!(plan.delay_ms(4), None);
        assert_eq!(plan.kill_at(5), Some(2));
        assert!(!plan.is_empty());
    }

    #[test]
    fn fault_plan_validation() {
        let ds = Dataset::synth(SynthSpec::tiny(), 9);
        // N=10, K=2, T=1: need 7, slack 3.
        let base = CopmlConfig::for_dataset(&ds, 10, CaseParams::explicit(2, 1), 9);
        let mut cfg = base.clone();
        cfg.faults.delays = vec![(8, 100)];
        assert!(cfg.validate(&ds).is_ok(), "{:?}", cfg.validate(&ds));
        cfg.max_lag = Some(2);
        cfg.faults.kills = vec![(9, 1)];
        assert!(cfg.validate(&ds).is_ok(), "{:?}", cfg.validate(&ds));
        // kills require exclusion to be armed
        cfg.max_lag = None;
        assert!(cfg.validate(&ds).unwrap_err().contains("max-lag"));
        // the king cannot be faulted
        let mut cfg = base.clone();
        cfg.faults.delays = vec![(0, 100)];
        assert!(cfg.validate(&ds).unwrap_err().contains("party 0"));
        // out-of-range ids are named
        let mut cfg = base.clone();
        cfg.faults.kills = vec![(12, 0)];
        cfg.max_lag = Some(1);
        assert!(cfg.validate(&ds).unwrap_err().contains("12"));
        // killing party 0's subgroup mate would strand the king (its
        // group falls below T+1) — rejected with the cause named; the
        // same holds for a delay whose exclusion strands the group
        let mut cfg = base.clone();
        cfg.faults.kills = vec![(1, 1)];
        cfg.max_lag = Some(2);
        assert!(cfg.validate(&ds).unwrap_err().contains("strands party 0"));
        let mut cfg = base.clone();
        cfg.faults.delays = vec![(1, 50)];
        cfg.max_lag = Some(2);
        assert!(cfg.validate(&ds).unwrap_err().contains("strands party 0"));
        // killing a party in (T, 2T] is legitimate: the per-round king
        // openings gather from 0..=T only, and the one-time degree-2T
        // opening precedes the earliest kill — the plan validates (its
        // subgroup mate is counted as collateral: lost {2,3} ≤ slack 3)
        let mut cfg = base.clone();
        cfg.faults.kills = vec![(2, 3)];
        cfg.max_lag = Some(2);
        assert!(cfg.validate(&ds).is_ok(), "{:?}", cfg.validate(&ds));
        // faulting more parties than the Theorem-1 slack is rejected
        let mut cfg = base.clone();
        cfg.faults.delays = vec![(5, 1), (6, 1), (7, 1), (8, 1)];
        cfg.max_lag = Some(2);
        assert!(cfg.validate(&ds).unwrap_err().contains("slack"));
        // a kill scheduled past the last iteration would never fire
        let mut cfg = base.clone();
        cfg.iters = 5;
        cfg.faults.kills = vec![(9, 7)];
        cfg.max_lag = Some(2);
        assert!(cfg.validate(&ds).unwrap_err().contains("never fire"));
        // duplicate fault entries silently shadow each other — rejected
        let mut cfg = base.clone();
        cfg.faults.delays = vec![(8, 100), (8, 900)];
        assert!(cfg.validate(&ds).unwrap_err().contains("more than once"));
        // --max-lag 0 is nonsense
        let mut cfg = base;
        cfg.max_lag = Some(0);
        assert!(cfg.validate(&ds).unwrap_err().contains("max-lag"));
    }

    #[test]
    fn validate_chunk_and_session_rules() {
        let ds = Dataset::synth(SynthSpec::tiny(), 7);
        let base = CopmlConfig::for_dataset(&ds, 4, CaseParams::explicit(1, 1), 7);
        // chunk requires the distributed offline phase
        let mut cfg = base.clone();
        cfg.chunk = Some(64);
        assert!(cfg.validate(&ds).unwrap_err().contains("distributed"));
        cfg.offline = OfflineMode::Distributed;
        assert!(cfg.validate(&ds).is_ok(), "{:?}", cfg.validate(&ds));
        // chunk = 0 is nonsense
        cfg.chunk = Some(0);
        assert!(cfg.validate(&ds).unwrap_err().contains("chunk"));
        // chunk is incompatible with injected faults
        let mut cfg = base.clone();
        cfg.offline = OfflineMode::Distributed;
        cfg.chunk = Some(8);
        cfg.faults.delays = vec![(3, 50)];
        assert!(cfg.validate(&ds).unwrap_err().contains("fault"));
        // any in-range session validates; out-of-range is named
        let mut cfg = base.clone();
        cfg.session = 2;
        assert!(cfg.validate(&ds).is_ok(), "{:?}", cfg.validate(&ds));
        cfg.session = crate::net::tags::max_sessions();
        assert!(cfg.validate(&ds).unwrap_err().contains("session"));
    }

    #[test]
    fn quantized_sigmoid_degree1_values() {
        let ds = Dataset::synth(SynthSpec::smoke(), 2);
        let cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 1);
        let (poly, cq) = cfg.quantized_sigmoid();
        let f = cfg.plan.field;
        // c0 ≈ 0.5 at scale 2^{lc+lx+lw}
        let scale = 2f64.powi((cfg.plan.lc + cfg.plan.lx + cfg.plan.lw) as i32);
        assert_eq!(cq[0], f.from_i64(quant::round_half_away(poly.coeffs[0] * scale)));
        assert!((f.to_i64(cq[0]) as f64 - 0.5 * scale).abs() <= 2.0, "c0_q = {}", f.to_i64(cq[0]));
        // c1 at scale lc = 3: Round(c1·8)
        assert_eq!(f.to_i64(cq[1]), quant::round_half_away(poly.coeffs[1] * 8.0));
        assert!(f.to_i64(cq[1]) >= 1);
    }

    #[test]
    fn quantized_task_pads_and_scales() {
        let ds = Dataset::synth(SynthSpec::smoke(), 3);
        let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::explicit(3, 1), 1);
        cfg.k = 3;
        let task = QuantizedTask::new(&cfg, &ds);
        assert_eq!(task.rows_padded % 3, 0);
        assert!(task.rows_padded >= ds.m);
        // padding rows all zero (B = 1: padding sits at the global tail)
        for i in ds.m..task.rows_padded {
            assert!(task.x_q[i * ds.d..(i + 1) * ds.d].iter().all(|&v| v == 0));
            assert_eq!(task.y_q[i], 0);
        }
        assert_eq!(task.eta_qs.len(), 1);
        assert!(task.eta_qs[0] >= 1);
    }

    #[test]
    fn quantized_task_batched_layout() {
        // B > 1: every batch padded to K | rows with zero rows at its own
        // tail, per-batch η factors keyed to the batch's real size, and
        // the multiset of real quantized rows preserved (a permutation).
        let ds = Dataset::synth(SynthSpec::smoke(), 4);
        let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::explicit(3, 1), 4);
        cfg.batches = 7;
        let task = QuantizedTask::new(&cfg, &ds);
        let plan = &task.batches;
        assert_eq!(plan.b, 7);
        assert_eq!(task.eta_qs.len(), 7);
        for (bi, &(lo, hi)) in plan.ranges().iter().enumerate() {
            assert_eq!((hi - lo) % cfg.k, 0, "batch {bi}");
            let mb = plan.real_rows(bi);
            assert_eq!(task.eta_qs[bi], cfg.plan.eta_factor(cfg.eta, mb), "batch {bi}");
            // padding rows of this batch are zero
            for i in lo + mb..hi {
                assert!(
                    task.x_q[i * ds.d..(i + 1) * ds.d].iter().all(|&v| v == 0),
                    "batch {bi} padding row {i}"
                );
                assert_eq!(task.y_q[i], 0);
            }
        }
        // real rows are a permutation of the B=1 quantization
        let full = QuantizedTask::new(
            &CopmlConfig { batches: 1, ..cfg.clone() },
            &ds,
        );
        let row = |xq: &[u64], i: usize| xq[i * ds.d..(i + 1) * ds.d].to_vec();
        let mut batched_rows: Vec<Vec<u64>> = plan
            .slots()
            .iter()
            .map(|&(slot, _)| row(&task.x_q, slot))
            .collect();
        let mut full_rows: Vec<Vec<u64>> = (0..ds.m).map(|i| row(&full.x_q, i)).collect();
        batched_rows.sort_unstable();
        full_rows.sort_unstable();
        assert_eq!(batched_rows, full_rows);
    }

    #[test]
    fn validate_batch_geometry() {
        let ds = Dataset::synth(SynthSpec::smoke(), 5); // m = 400
        let base = CopmlConfig::for_dataset(&ds, 10, CaseParams::explicit(3, 1), 5);
        let mut cfg = base.clone();
        cfg.batches = 8;
        assert!(cfg.validate(&ds).is_ok(), "{:?}", cfg.validate(&ds));
        // zero batches
        cfg.batches = 0;
        assert!(cfg.validate(&ds).unwrap_err().contains("batches"));
        // more batches than samples
        cfg.batches = ds.m + 1;
        assert!(cfg.validate(&ds).unwrap_err().contains("samples"));
        // rows_b < K
        cfg.batches = 200; // ⌊400/200⌋ = 2 < K = 3
        assert!(cfg.validate(&ds).unwrap_err().contains("rows_b"));
        // batches past the schedule
        let mut cfg = base;
        cfg.iters = 4;
        cfg.batches = 8;
        assert!(cfg.validate(&ds).unwrap_err().contains("iters"));
    }
}
