//! Algorithmic-fidelity COPML trainer: the *exact* field recursion the full
//! protocol computes, evaluated centrally.
//!
//! Soundness (DESIGN.md §6): for `N ≥ (2r+1)(K+T−1)+1` the Lagrange
//! decode is exact in `F_p`, secure additions/constant-multiplications are
//! exact, and the only randomness that *reaches the model trajectory* is
//! the TruncPr rounding randomness `(r', r'')` — which both trainers draw
//! from the same dealer streams ([`crate::mpc::dealer::DealerValues`], keyed
//! by `(seed, stream, index)`). The Lagrange masks `Z_k`/`v_k` and all
//! Shamir share randomness cancel by construction. Therefore the iterates
//! `w^{(t)}` here are **bit-identical** to the threaded protocol's
//! (asserted in `tests/protocol_equivalence.rs`), at a fraction of the
//! cost — which is what makes paper-scale accuracy runs (Fig. 4, N = 50,
//! CIFAR-sized data) tractable on one machine.
//!
//! The trainer also *range-checks* every value entering truncation against
//! `2^{k_2−1}` (the protocol cannot see these values; the simulator can),
//! turning fixed-point-plan violations into hard errors instead of silent
//! accuracy loss.

use super::{CopmlConfig, QuantizedTask, TrainOutput};
use crate::data::Dataset;
use crate::field::{par, vecops, MatShape};
use crate::mpc::dealer::{Dealer, DealerValues, Demand};
use crate::quant;

/// Offline-randomness demand of one COPML run (shared with the threaded
/// protocol so the streams line up). `channels` is the workload's
/// gradient-channel count (`QuantizedTask::channels`; 1 for the seed
/// workload, which makes every expression below collapse to the
/// pre-model-zoo demand).
pub fn copml_demand(cfg: &CopmlConfig, d: usize, rows_padded: usize, channels: usize) -> Demand {
    if !cfg.model.model().iterative() {
        // Closed-form normal equations: one BH08 degree reduction of the
        // concatenated degree-2T moment shares XᵀX (d²) and Xᵀy (d). No
        // truncation stages and no Lagrange masks — the dataset is
        // Shamir-shared with client-local randomness, not LCC-encoded.
        return Demand { doubles: d * (d + 1), truncs: vec![], randoms: 0 };
    }
    let iters = cfg.iters;
    let width = d * channels;
    Demand {
        // One BH08 degree reduction of the concatenated per-batch
        // G-vectors Xᵀ_b y_b (one-time; B·G elements, d for the seed
        // full-batch workload).
        doubles: width * cfg.batches,
        // Two truncation stages per iteration, G elements each —
        // iteration count, not batch count, sizes these pools.
        truncs: vec![
            (cfg.plan.k1_stage1(), width * iters),
            (cfg.plan.k1_stage2(), width * iters),
        ],
        // Lagrange masks: T data masks per batch of (rows_b/K)·d — summed
        // over batches that is T·(Σ_b rows_b/K)·d = T·(rows_padded/K)·d,
        // charged ONCE (the per-batch encodings are amortized across all
        // epochs) — plus T model masks of G per iteration (Eq. 4).
        randoms: cfg.t * (rows_padded / cfg.k) * d + cfg.t * width * iters,
    }
}

/// Central truncation replaying the dealer's `(r', r'')` for width `m`:
/// identical to what `mpc::Party::trunc_pr` computes on shares.
fn trunc_central(
    task: &QuantizedTask,
    vals: &mut DealerValues,
    a: &mut [u64],
    k: u32,
    m: u32,
) -> Result<(), String> {
    let f = task.f;
    let pow_km1 = f.reduce(1u64 << (k - 1));
    let pow_m = 1u64 << m;
    let inv2m = f.inv(pow_m);
    let offset = f.reduce(1u64 << (k - 1 - m));
    let (rp, rpp) = {
        let (rp, rpp) = vals.take_trunc_pair(a.len(), m);
        (rp.to_vec(), rpp.to_vec())
    };
    for (i, v) in a.iter_mut().enumerate() {
        // Range check: the value must lie in (−2^{k−1}, 2^{k−1}).
        let signed = f.to_i64(*v);
        if signed.unsigned_abs() >= 1u64 << (k - 1) {
            return Err(format!(
                "truncation range violation: |{signed}| ≥ 2^{} (element {i}, stage m={m}) — \
                 fixed-point plan too aggressive for this dataset",
                k - 1
            ));
        }
        let b = f.add(*v, pow_km1);
        // c = b + 2^m·r'' + r' — the value the protocol would open.
        let c = f.add(b, f.add(f.mul(pow_m, rpp[i]), rp[i]));
        let c_lo = c & (pow_m - 1);
        let num = f.add(f.sub(b, c_lo), rp[i]);
        *v = f.sub(f.mul(num, inv2m), offset);
    }
    Ok(())
}

/// Train COPML in algorithmic-fidelity mode. Returns the per-iteration
/// field-domain model trace (identical to the protocol's).
///
/// Requires [`crate::mpc::OfflineMode::Dealer`]: the central replay works
/// *because* the truncation randomness is a function of `(seed, stream,
/// index)` alone. A distributed offline phase has no such closed form —
/// its randomness exists only in the parties' joint execution — so
/// `offline = distributed` must run the full protocol (`mode full`).
pub fn train(cfg: &CopmlConfig, ds: &Dataset) -> Result<TrainOutput, String> {
    cfg.validate(ds)?;
    if cfg.offline != crate::mpc::OfflineMode::Dealer {
        return Err(
            "offline mode 'distributed' cannot be replayed centrally: the \
             algorithmic-fidelity trainer derives truncation randomness from \
             the dealer seed — run the full protocol instead (mode 'full')"
                .into(),
        );
    }
    if !cfg.faults.is_empty() || cfg.max_lag.is_some() {
        return Err(
            "fault injection and straggler exclusion (--delay/--kill-after/--max-lag) \
             only exist in the full protocol, where real messages can be late — \
             run with --mode full"
                .into(),
        );
    }
    let task = QuantizedTask::new(cfg, ds);
    train_task(cfg, ds, &task)
}

/// Inner trainer reusing a prepared [`QuantizedTask`].
pub fn train_task(
    cfg: &CopmlConfig,
    ds: &Dataset,
    task: &QuantizedTask,
) -> Result<TrainOutput, String> {
    if !cfg.model.model().iterative() {
        return train_task_moments(cfg, ds, task);
    }
    let f = task.f;
    let (rows, d, channels) = (task.rows_padded, task.d, task.channels);
    let width = task.width();
    let demand = copml_demand(cfg, d, rows, channels);
    let mut vals = Dealer::values(f, cfg.seed, &demand, cfg.plan.k2, cfg.plan.kappa);

    // One-time, per batch: Xᵀ_b y_b per channel (class-major concatenated
    // into one G-vector), aligned to the gradient scale 2^{l_c+l_x+l_w}
    // above its own l_x (paper Phase 2 end; scaling is a public-constant
    // mult). Mirrors the protocol's single concatenated BH08 reduction
    // over all batches.
    let pp = cfg.parallelism;
    let tier = cfg.kernel;
    let plan_b = &task.batches;
    let align = f.reduce(1u64 << (cfg.plan.lc + cfg.plan.lx + cfg.plan.lw));
    let mut xty: Vec<Vec<u64>> = Vec::with_capacity(plan_b.b);
    for &(lo, hi) in plan_b.ranges() {
        let sh = MatShape::new(hi - lo, d);
        let mut v = Vec::with_capacity(width);
        for c in 0..channels {
            let yc = task.y_channel(c);
            let mut vc =
                par::matvec_t_tier(f, tier, pp, &task.x_q[lo * d..hi * d], sh, &yc[lo..hi]);
            vecops::scale_assign(f, &mut vc, align);
            v.append(&mut vc);
        }
        xty.push(v);
    }

    let mut w = vec![0u64; width]; // w^(0) = 0 (see DESIGN.md: deterministic init)
    let mut out = TrainOutput::default();

    for iter in 0..cfg.iters {
        // batch b = iter mod B (full matrix for B = 1)
        let bi = plan_b.batch_of_iter(iter);
        let (lo, hi) = plan_b.ranges()[bi];
        let xb = &task.x_q[lo * d..hi * d];
        let sh = MatShape::new(hi - lo, d);
        // Per channel c (one pass for the seed workload):
        //   z = X_b·w_c        (scale l_x + l_w)
        //   ĝ(z)               (scale l_c + l_x + l_w)
        //   X_bᵀ ĝ             (scale 2l_x + l_w + l_c) — in the protocol
        // this is the Lagrange-decoded aggregate of the clients' Eq. (7)
        // results, class-major concatenated into one G-vector.
        let mut grad = Vec::with_capacity(width);
        for c in 0..channels {
            let mut z = par::matvec_tier(f, tier, pp, xb, sh, &w[c * d..(c + 1) * d]);
            par::poly_eval_assign_tier(f, tier, pp, &task.coeffs_q, &mut z);
            let mut gc = par::matvec_t_tier(f, tier, pp, xb, sh, &z);
            grad.append(&mut gc);
        }
        // − X_bᵀy_b (aligned)
        vecops::sub_assign(f, &mut grad, &xty[bi]);
        // Stage-1 truncation → scale l_x + l_w — ONE call on the whole
        // G-vector, so the dealer trunc stream is consumed in the same
        // order the protocol consumes it.
        trunc_central(task, &mut vals, &mut grad, cfg.plan.k2, cfg.plan.k1_stage1())?;
        // × e_q[b] = Round(2^{l_e}·η/m_b) (scale + l_e), stage-2
        // truncation → scale l_w.
        vecops::scale_assign(f, &mut grad, task.eta_qs[bi]);
        trunc_central(task, &mut vals, &mut grad, cfg.plan.k2, cfg.plan.k1_stage2())?;
        // w ← w − G₂
        vecops::sub_assign(f, &mut w, &grad);
        out.w_trace.push(w.clone());
    }

    out.eval_traces(cfg, ds);
    Ok(out)
}

/// Central replay of the closed-form normal-equations workload: the exact
/// field values the protocol's one BH08 round opens — XᵀX and Xᵀy at
/// scale `2^{2l_x}` (padding rows are zero, hence inert) — followed by
/// the same public dequantize → ridge solve → requantize every party
/// runs. No dealer randomness reaches the result (BH08 resharing cancels
/// exactly), so this is bit-identical to the protocol by construction.
fn train_task_moments(
    cfg: &CopmlConfig,
    ds: &Dataset,
    task: &QuantizedTask,
) -> Result<TrainOutput, String> {
    let f = task.f;
    let (rows, d) = (task.rows_padded, task.d);
    let y = task.y_channel(0);
    let mut moments = vec![0u64; d * (d + 1)];
    for i in 0..rows {
        let row = &task.x_q[i * d..(i + 1) * d];
        for j in 0..d {
            let xj = row[j];
            for k in 0..d {
                moments[j * d + k] = f.add(moments[j * d + k], f.mul(xj, row[k]));
            }
            moments[d * d + j] = f.add(moments[d * d + j], f.mul(xj, y[i]));
        }
    }
    let scale = 2 * cfg.plan.lx;
    let mut xtx = quant::dequantize_slice(f, &moments[..d * d], scale);
    let mut xty = quant::dequantize_slice(f, &moments[d * d..], scale);
    let beta = crate::ml::model::solve_normal_equations(&mut xtx, &mut xty, d);
    let mut out = TrainOutput::default();
    out.w_trace.push(quant::quantize_slice(f, &beta, cfg.plan.lw));
    out.eval_traces(cfg, ds);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CaseParams;
    use crate::data::SynthSpec;
    use crate::ml;

    #[test]
    fn converges_on_smoke_dataset() {
        let ds = Dataset::synth(SynthSpec::smoke(), 11);
        let cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 11);
        let out = train(&cfg, &ds).unwrap();
        let acc = *out.test_accuracy.last().unwrap();
        assert!(acc > 0.80, "secure training accuracy {acc}");
        // loss should be decreasing overall
        assert!(out.loss.last().unwrap() < &out.loss[0]);
    }

    #[test]
    fn close_to_plaintext_reference() {
        // Fig. 4's claim: COPML ≈ conventional logistic regression.
        let ds = Dataset::synth(SynthSpec::smoke(), 12);
        let cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case2(10), 12);
        let secure = train(&cfg, &ds).unwrap();
        let plain = ml::train_logreg(
            &ds,
            &ml::LogRegOptions { iters: cfg.iters, eta: cfg.eta, ..Default::default() },
        );
        let gap = (plain.test_accuracy.last().unwrap()
            - secure.test_accuracy.last().unwrap())
        .abs();
        assert!(gap < 0.08, "secure-vs-plaintext accuracy gap {gap}");
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = Dataset::synth(SynthSpec::smoke(), 13);
        let cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 99);
        let a = train(&cfg, &ds).unwrap();
        let b = train(&cfg, &ds).unwrap();
        assert_eq!(a.w_trace, b.w_trace);
        let mut cfg2 = cfg.clone();
        cfg2.seed = 100;
        let c = train(&cfg2, &ds).unwrap();
        assert_ne!(a.w_trace, c.w_trace, "different seed → different TruncPr rounding");
    }

    #[test]
    fn k_does_not_change_trajectory() {
        // K only partitions work; the decoded gradient — and hence the
        // trajectory — must be identical across K (padding differs, but
        // zero rows are inert).
        let ds = Dataset::synth(SynthSpec::smoke(), 14);
        let mut cfg = CopmlConfig::for_dataset(&ds, 13, CaseParams::explicit(2, 1), 14);
        cfg.iters = 8;
        let a = train(&cfg, &ds).unwrap();
        cfg.k = 4;
        let b = train(&cfg, &ds).unwrap();
        assert_eq!(a.w_trace, b.w_trace);
    }

    #[test]
    fn parallelism_does_not_change_trajectory() {
        // The parallel field layer must be bit-identical to the sequential
        // one (mod-p partial combination is exact) — the whole point of
        // threading Parallelism through the trainers without touching the
        // protocol-equivalence story.
        use crate::field::Parallelism;
        // Large enough that the matvec/matvec_t work exceeds the fan-out
        // threshold (m·d ≈ 42k cells > 2·MIN_PAR_WORK) — actually threads.
        let spec = SynthSpec { m_train: 2000, m_test: 100, ..SynthSpec::smoke() };
        let ds = Dataset::synth(spec, 16);
        let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 16);
        cfg.iters = 10;
        let seq = train(&cfg, &ds).unwrap();
        for threads in [2usize, 4] {
            cfg.parallelism = Parallelism::threads(threads);
            let par = train(&cfg, &ds).unwrap();
            assert_eq!(seq.w_trace, par.w_trace, "threads={threads}");
        }
    }

    #[test]
    fn kernel_tier_does_not_change_trajectory() {
        // Montgomery is a different reduction algorithm over the same exact
        // mod-p arithmetic: the central trainer's trajectory must be
        // bit-identical to the Barrett default, sequential and threaded.
        use crate::field::{KernelTier, Parallelism};
        let spec = SynthSpec { m_train: 2000, m_test: 100, ..SynthSpec::smoke() };
        let ds = Dataset::synth(spec, 16);
        let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 16);
        cfg.iters = 10;
        let barrett = train(&cfg, &ds).unwrap();
        cfg.kernel = KernelTier::Mont;
        for threads in [1usize, 4] {
            cfg.parallelism = Parallelism::threads(threads);
            let mont = train(&cfg, &ds).unwrap();
            assert_eq!(barrett.w_trace, mont.w_trace, "threads={threads}");
        }
    }

    #[test]
    fn minibatch_trajectory_is_k_invariant() {
        // The BatchPlan's real-row partition is K-independent, so K must
        // stay trajectory-neutral under batching too (per-batch padding
        // differs, but zero rows are inert).
        let ds = Dataset::synth(SynthSpec::smoke(), 17);
        let mut cfg = CopmlConfig::for_dataset(&ds, 13, CaseParams::explicit(2, 1), 17);
        cfg.iters = 8;
        cfg.batches = 4;
        let a = train(&cfg, &ds).unwrap();
        cfg.k = 4;
        let b = train(&cfg, &ds).unwrap();
        assert_eq!(a.w_trace, b.w_trace);
    }

    #[test]
    fn minibatch_converges_and_differs_from_full_batch() {
        let ds = Dataset::synth(SynthSpec::smoke(), 18);
        let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 18);
        cfg.iters = 40;
        let full = train(&cfg, &ds).unwrap();
        cfg.batches = 8;
        let mini = train(&cfg, &ds).unwrap();
        assert_ne!(full.w_trace, mini.w_trace, "batching must change the iterates");
        let a = *full.test_accuracy.last().unwrap();
        let b = *mini.test_accuracy.last().unwrap();
        assert!(b > 0.75, "mini-batch accuracy {b}");
        assert!((a - b).abs() < 0.08, "full {a} vs mini {b}");
    }

    #[test]
    fn range_violation_detected() {
        let ds = Dataset::synth(SynthSpec::smoke(), 15);
        let mut cfg = CopmlConfig::for_dataset(&ds, 10, CaseParams::case1(10), 15);
        // Absurd learning rate → huge update → stage-2 range violation.
        cfg.eta = 1e9;
        let r = train(&cfg, &ds);
        assert!(r.is_err() || r.unwrap().test_accuracy.last().unwrap() < &0.9);
    }
}
