//! Conventional-MPC baselines (paper §V.A.2, Appendix C/D): secure
//! logistic regression where **every multiplication pays a degree
//! reduction**, in the two flavours the paper benchmarks —
//! \[BGW88\] (online resharing, quadratic communication) and \[BH08\]
//! (offline double sharings + king, linear communication).
//!
//! This is the *naive* single-committee baseline of Appendix D: the whole
//! dataset is secret shared among all `N` clients and each client's compute
//! touches all of `X`. The paper's grouped optimization (G = 3 subgroups,
//! each handling `m/3` rows with threshold `⌊(N−3)/6⌋`) rescales compute
//! and communication by exact factors; the Fig. 3 / Table I harness applies
//! that rescaling through `bench::cost_model` (see DESIGN.md §4), while
//! this module provides the measured primitives and the correctness
//! evidence.
//!
//! The gradient here is algebraically identical to COPML's
//! (`Xᵀ(ĝ(Xw) − y·2^{l_c+l_x+l_w})`), and the TruncPr randomness comes
//! from the same dealer streams — so the baseline's model trajectory is
//! **bit-identical** to COPML's for the same seed (asserted in
//! `tests/protocol_equivalence.rs`): the protocols differ in cost, not in
//! what they compute. That is exactly the paper's framing.

use std::sync::Arc;
use std::time::Instant;

use crate::data::Dataset;
use crate::field::{par, KernelTier, MatShape, Parallelism};
use crate::mpc::dealer::{Dealer, Demand};
use crate::mpc::Party;
use crate::net::local::Hub;
use crate::shamir;

use super::{CopmlConfig, FaultPlan, QuantizedTask, TrainOutput};

/// Which multiplication protocol the baseline uses (Appendix C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MpcFlavor {
    /// Ben-Or–Goldwasser–Wigderson 1988: online resharing, `O(N²)` comm.
    Bgw,
    /// Beerliová-Trubíniová–Hirt 2008 / Damgård–Nielsen 2007: offline
    /// double sharings + king opening, `O(N)` comm.
    Bh08,
}

/// Phase labels of the baseline ledger.
pub const PHASES: [&str; 5] = [
    "share_dataset",
    "compute_local",
    "reduce_z",
    "reduce_grad",
    "trunc_update",
];

/// One client's baseline ledger.
#[derive(Clone, Debug, Default)]
pub struct BaselineLedger {
    pub seconds: [f64; 5],
    pub bytes: [u64; 5],
}

pub struct BaselineOutput {
    pub train: TrainOutput,
    pub ledgers: Vec<BaselineLedger>,
}

/// Baseline configuration: same task parameters as COPML, plus the flavour.
#[derive(Clone, Debug)]
pub struct BaselineConfig {
    pub n: usize,
    pub t: usize,
    pub plan: crate::quant::FpPlan,
    pub iters: usize,
    /// Mini-batch count, same schedule as [`CopmlConfig::batches`]
    /// (`batch = iter mod B`) — the baselines must train on the identical
    /// batch sequence for the Table-1/Fig-3 comparisons to stay
    /// apples-to-apples. The [`crate::data::BatchPlan`] real-row partition
    /// is K-independent, so the `K = 1` baseline sees exactly the rows
    /// COPML's batches hold.
    pub batches: usize,
    pub eta: f64,
    pub seed: u64,
    pub fit_range: f64,
    pub flavor: MpcFlavor,
    /// Intra-client thread pool for the share-matvec hot path (same
    /// semantics as [`CopmlConfig::parallelism`]).
    pub parallelism: Parallelism,
    /// Field-kernel tier for the share-matvec hot path (same semantics as
    /// [`CopmlConfig::kernel`]; bit-identical either way).
    pub kernel: KernelTier,
}

impl BaselineConfig {
    /// Match a COPML config (same plan/η/iters/batches/seed → same
    /// trajectory).
    pub fn matching(cfg: &CopmlConfig, flavor: MpcFlavor) -> BaselineConfig {
        BaselineConfig {
            n: cfg.n,
            t: cfg.t,
            plan: cfg.plan,
            iters: cfg.iters,
            batches: cfg.batches,
            eta: cfg.eta,
            seed: cfg.seed,
            fit_range: cfg.fit_range,
            flavor,
            parallelism: cfg.parallelism,
            kernel: cfg.kernel,
        }
    }

    fn as_copml(&self) -> CopmlConfig {
        CopmlConfig {
            n: self.n,
            t: self.t,
            k: 1,
            r: 1,
            plan: self.plan,
            iters: self.iters,
            batches: self.batches,
            eta: self.eta,
            seed: self.seed,
            engine: crate::runtime::Engine::Native,
            fit_range: self.fit_range,
            subgroups: false,
            parallelism: self.parallelism,
            wire: crate::net::Wire::U64,
            // Baselines reproduce the paper's dealer-assisted setups; the
            // dealer-free offline phase is a COPML-protocol feature.
            offline: crate::mpc::OfflineMode::Dealer,
            // Fault injection lives in the COPML quorum machinery, not in
            // the conventional baselines.
            faults: FaultPlan::default(),
            max_lag: None,
            kernel: self.kernel,
            runtime: crate::net::Runtime::Threaded,
            chunk: None,
            session: 0,
            // The Appendix C/D baselines are degree-1 secure *logistic
            // regression* by construction (the affine ĝ(z) step below);
            // other workloads go through the COPML trainers.
            model: crate::ml::ModelKind::Logreg,
        }
    }
}

struct ClientResult {
    id: usize,
    w_final: Vec<u64>,
    snapshots: Vec<Vec<u64>>,
    ledger: BaselineLedger,
}

/// Train the baseline with full fidelity (threads + real shares).
pub fn train(cfg: &BaselineConfig, ds: &Dataset) -> Result<BaselineOutput, String> {
    if cfg.n <= 2 * cfg.t {
        return Err(format!("baseline needs n > 2t (n={}, t={})", cfg.n, cfg.t));
    }
    // Batch-geometry sanity through the shared checker (K = 1 here — the
    // naive baselines never partition), so a bad batch count returns the
    // same clean error the COPML trainers give instead of panicking
    // inside BatchPlan::new.
    crate::data::BatchPlan::validate_geometry(ds.m, 1, cfg.batches, cfg.iters)
        .map_err(|e| format!("baseline batch plan: {e}"))?;
    let ccfg = cfg.as_copml();
    let task = Arc::new(QuantizedTask::new(&ccfg, ds));
    let f = task.f;
    let (n, t) = (cfg.n, cfg.t);
    let d = task.d;

    // Offline demand. Truncation streams must match COPML's demand layout
    // (same widths, same counts) so the trajectories coincide. BH08 pays
    // per-iteration degree reductions of that round's z (rows_b) and grad
    // (d) vectors — summed over the cyclic batch schedule.
    let doubles = match cfg.flavor {
        MpcFlavor::Bgw => 0,
        MpcFlavor::Bh08 => (0..cfg.iters)
            .map(|it| {
                let (blo, bhi) = task.batches.ranges()[task.batches.batch_of_iter(it)];
                (bhi - blo) + d
            })
            .sum(),
    };
    let demand = Demand {
        doubles,
        truncs: vec![
            (cfg.plan.k1_stage1(), d * cfg.iters),
            (cfg.plan.k1_stage2(), d * cfg.iters),
        ],
        randoms: 0,
    };
    let pools = Dealer::deal(f, n, t, &demand, cfg.plan.k2, cfg.plan.kappa, cfg.seed);
    let endpoints = Hub::new(n);

    let mut handles = Vec::new();
    for (ep, pool) in endpoints.into_iter().zip(pools) {
        let cfg = cfg.clone();
        let task = task.clone();
        handles.push(std::thread::spawn(move || {
            let party = Party::new(&ep, cfg.t, task.f, pool, cfg.seed);
            client_main(&party, &cfg, &task)
        }));
    }
    let mut results: Vec<ClientResult> = handles
        .into_iter()
        .map(|h| h.join().map_err(|_| "baseline client panicked".to_string()))
        .collect::<Result<_, _>>()?;
    results.sort_by_key(|r| r.id);

    for r in &results[1..] {
        if r.w_final != results[0].w_final {
            return Err("baseline clients disagree on the final model".into());
        }
    }
    let lambdas = shamir::lambda_points(n);
    let rec = shamir::Reconstructor::new(f, &lambdas[..t + 1]);
    let mut train = TrainOutput::default();
    for it in 0..cfg.iters {
        let views: Vec<&[u64]> =
            results[..t + 1].iter().map(|r| r.snapshots[it].as_slice()).collect();
        let mut w = vec![0u64; d];
        rec.reconstruct(f, &views, &mut w);
        train.w_trace.push(w);
    }
    train.eval_traces(&ccfg, ds);
    Ok(BaselineOutput { train, ledgers: results.into_iter().map(|r| r.ledger).collect() })
}

fn client_main(party: &Party, cfg: &BaselineConfig, task: &QuantizedTask) -> ClientResult {
    let f = task.f;
    let me = party.id;
    let n = cfg.n;
    let (rows, d) = (task.rows_padded, task.d);
    let plan_b = &task.batches;
    let bgw = cfg.flavor == MpcFlavor::Bgw;
    let mut ledger = BaselineLedger::default();
    // copml-lint: allow(wall-clock) phase-ledger stamp: measures elapsed time, never steers protocol state
    let mut mark_t = Instant::now();
    let mut mark_b = party.net.bytes_sent();
    macro_rules! tick {
        ($phase:expr) => {{
            ledger.seconds[$phase] += mark_t.elapsed().as_secs_f64();
            ledger.bytes[$phase] += party.net.bytes_sent() - mark_b;
            // copml-lint: allow(wall-clock) phase-ledger stamp: measures elapsed time, never steers protocol state
            mark_t = Instant::now();
            mark_b = party.net.bytes_sent();
        }};
    }

    // ---- share the dataset with everyone (naive Appendix D) ------------
    let ranges = super::protocol::padded_ranges(rows, n);
    let (lo, hi) = ranges[me];
    let tag_x = party.fresh_tag();
    let tag_y = party.fresh_tag();
    let own_x = party.share_out(&task.x_q[lo * d..hi * d], tag_x);
    let own_y = party.share_out(&task.y_q[lo..hi], tag_y);
    let mut x_share = vec![0u64; rows * d];
    let mut y_share = vec![0u64; rows];
    for (j, &(jl, jh)) in ranges.iter().enumerate() {
        let (xs, ys) = if j == me {
            (own_x.clone(), own_y.clone())
        } else {
            (party.net.recv(j, tag_x), party.net.recv(j, tag_y))
        };
        x_share[jl * d..jh * d].copy_from_slice(&xs);
        y_share[jl..jh].copy_from_slice(&ys);
    }
    // Residual offset: y·2^{l_c+l_x+l_w} (public constant multiplication).
    let align = f.reduce(1u64 << (cfg.plan.lc + cfg.plan.lx + cfg.plan.lw));
    let mut y_aligned = y_share;
    party.scale(&mut y_aligned, align);
    tick!(0);

    let mut w_share = vec![0u64; d];
    let mut snapshots = Vec::with_capacity(cfg.iters);
    let (c0q, c1q) = (task.coeffs_q[0], task.coeffs_q[1]);

    for it in 0..cfg.iters {
        // Mini-batch schedule, identical to COPML's (batch = iter mod B).
        let bi = plan_b.batch_of_iter(it);
        let (blo, bhi) = plan_b.ranges()[bi];
        let xb = &x_share[blo * d..bhi * d];
        let shb = MatShape::new(bhi - blo, d);
        // z = X_b·w — local share products, degree 2T.
        let z2t = par::matvec_tier(f, cfg.kernel, cfg.parallelism, xb, shb, &w_share);
        tick!(1);
        // degree reduction of the rows_b-vector (the step COPML avoids).
        let mut z = if bgw {
            party.degree_reduce_bgw(&z2t)
        } else {
            party.degree_reduce_bh08(&z2t).expect("baseline pools sized for demand")
        };
        tick!(2);
        // ĝ(z) − y_b·align, affine in the shares (r = 1).
        party.scale(&mut z, c1q);
        party.add_const(&mut z, c0q);
        party.sub(&mut z, &y_aligned[blo..bhi]);
        // grad = X_bᵀ·res — local products, degree 2T.
        let g2t = par::matvec_t_tier(f, cfg.kernel, cfg.parallelism, xb, shb, &z);
        tick!(1);
        let grad = if bgw {
            party.degree_reduce_bgw(&g2t)
        } else {
            party.degree_reduce_bh08(&g2t).expect("baseline pools sized for demand")
        };
        tick!(3);
        // two-stage truncation + update (identical to COPML's Phase 4).
        let mut g1 = party
            .trunc_pr(&grad, cfg.plan.k2, cfg.plan.k1_stage1(), cfg.plan.kappa, !bgw)
            .expect("baseline pools sized for demand");
        party.scale(&mut g1, task.eta_qs[bi]);
        let g2 = party
            .trunc_pr(&g1, cfg.plan.k2, cfg.plan.k1_stage2(), cfg.plan.kappa, !bgw)
            .expect("baseline pools sized for demand");
        party.sub(&mut w_share, &g2);
        snapshots.push(w_share.clone());
        tick!(4);
    }

    let w_final = party.open_broadcast(&w_share, cfg.t);
    ClientResult { id: me, w_final, snapshots, ledger }
}

/// Grouped-baseline rescaling of Appendix D: with `G = 3` subgroups each
/// of size `N/3` processing `m/3` rows at threshold `⌊(N−3)/6⌋`, per-client
/// compute and communication shrink by these factors relative to the naive
/// run measured above. Used by the Fig. 3 / Table I cost model.
pub struct GroupedScaling {
    /// Committee size (parties per group).
    pub committee: usize,
    /// Rows processed per client.
    pub rows_per_client_factor: f64,
}

impl GroupedScaling {
    pub fn paper_g3(n: usize) -> GroupedScaling {
        GroupedScaling { committee: (n / 3).max(1), rows_per_client_factor: 1.0 / 3.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{algo, CaseParams};
    use crate::data::SynthSpec;

    #[test]
    fn baseline_trajectory_matches_copml_algo() {
        // The baselines compute the same gradient with the same truncation
        // randomness → identical iterates. This is the paper's setup: same
        // task, different cost.
        let ds = Dataset::synth(SynthSpec::tiny(), 31);
        let mut ccfg = CopmlConfig::for_dataset(&ds, 5, CaseParams::explicit(1, 1), 31);
        ccfg.iters = 4;
        let reference = algo::train(&ccfg, &ds).unwrap();
        for flavor in [MpcFlavor::Bh08, MpcFlavor::Bgw] {
            let bcfg = BaselineConfig::matching(&ccfg, flavor);
            let out = train(&bcfg, &ds).unwrap();
            assert_eq!(out.train.w_trace, reference.w_trace, "{flavor:?}");
        }
    }

    #[test]
    fn bgw_sends_more_than_bh08() {
        let ds = Dataset::synth(SynthSpec::tiny(), 32);
        let base = BaselineConfig {
            n: 7,
            t: 2,
            plan: crate::quant::FpPlan::paper_cifar(),
            iters: 2,
            batches: 1,
            eta: 2.0,
            seed: 32,
            fit_range: 4.0,
            flavor: MpcFlavor::Bgw,
            parallelism: Parallelism::sequential(),
            kernel: KernelTier::Barrett,
        };
        let bgw = train(&base, &ds).unwrap();
        let bh = train(&BaselineConfig { flavor: MpcFlavor::Bh08, ..base }, &ds).unwrap();
        let bytes = |ledgers: &[BaselineLedger]| -> u64 {
            ledgers.iter().map(|l| l.bytes.iter().sum::<u64>()).sum()
        };
        assert!(
            bytes(&bgw.ledgers) > 2 * bytes(&bh.ledgers),
            "BGW {} vs BH08 {}",
            bytes(&bgw.ledgers),
            bytes(&bh.ledgers)
        );
    }

    #[test]
    fn minibatch_baseline_matches_copml_trajectory() {
        // The batch schedule and the K-independent real-row partition must
        // keep the baselines on COPML's exact mini-batch iterates.
        let ds = Dataset::synth(SynthSpec::tiny(), 33);
        let mut ccfg = CopmlConfig::for_dataset(&ds, 5, CaseParams::explicit(1, 1), 33);
        ccfg.iters = 6;
        ccfg.batches = 3;
        let reference = algo::train(&ccfg, &ds).unwrap();
        for flavor in [MpcFlavor::Bh08, MpcFlavor::Bgw] {
            let bcfg = BaselineConfig::matching(&ccfg, flavor);
            let out = train(&bcfg, &ds).unwrap();
            assert_eq!(out.train.w_trace, reference.w_trace, "{flavor:?} B=3");
        }
    }

    #[test]
    fn baseline_rejects_bad_batch_geometry() {
        let ds = Dataset::synth(SynthSpec::tiny(), 34);
        let mut cfg = BaselineConfig {
            n: 5,
            t: 1,
            plan: crate::quant::FpPlan::paper_cifar(),
            iters: 2,
            batches: 0,
            eta: 2.0,
            seed: 34,
            fit_range: 4.0,
            flavor: MpcFlavor::Bh08,
            parallelism: Parallelism::sequential(),
            kernel: KernelTier::Barrett,
        };
        assert!(train(&cfg, &ds).unwrap_err().contains("batches"));
        cfg.batches = ds.m + 1;
        assert!(train(&cfg, &ds).unwrap_err().contains("samples"));
        cfg.batches = 3; // > iters = 2
        assert!(train(&cfg, &ds).unwrap_err().contains("iters"));
    }

    #[test]
    fn grouped_scaling_matches_paper() {
        let g = GroupedScaling::paper_g3(50);
        assert_eq!(g.committee, 16);
        assert!((g.rows_per_client_factor - 1.0 / 3.0).abs() < 1e-12);
    }
}
