//! Explicit per-round protocol states for the iteration loop of
//! [`protocol`](super::protocol) — the event-driven restructuring of the
//! result-quorum machinery (ROADMAP item 1, large-N runtime).
//!
//! Each struct is one stage of an iteration expressed as a
//! [`RoundState`]: a `poll` pass consumes whatever relevant messages are
//! already queued and yields [`Step::Pending`](crate::net::Step) when a
//! tag has not arrived, instead of parking the client thread on one
//! specific peer. [`drive`](crate::net::drive) runs a state to
//! completion, sleeping on the transport's activity counter between
//! passes. Both `--runtime threaded` and `--runtime event` execute the
//! protocol through these same states — the runtime flag only changes
//! who feeds the mailbox (per-peer reader threads vs the shared
//! `net::reactor` poll loop) — which is what makes the two runtimes
//! bit-identical by construction.
//!
//! The states are tag-parameterized: the caller hands each one tags from
//! its own [`crate::net::tags`] window, so the same machinery serves
//! every tag session unchanged — a `copml serve` job in session `j`
//! passes tags from its `session_round_window(j, i)` stripe and never
//! collides with the offline factory concurrently prefetching job
//! `j+1`'s pools in the next stripe.
//!
//! Per-iteration state flow (every live party, iteration `i`):
//!
//! ```text
//!                 ┌────────────────────────────────────────────────┐
//!                 │ compute encoded gradient  (Eq. 7, local)       │
//!                 └───────────────┬────────────────────────────────┘
//!                                 │ share_out(result)
//!             leader (party 0)    │           follower (party ≠ 0)
//!            ┌────────────────────┴───────────────────┐
//!            ▼                                        ▼
//!  ┌───────────────────────┐              ┌───────────────────────┐
//!  │ AwaitEncodedGradients │              │   AwaitQuorumRoster   │
//!  │  first `need` arrive  │─roster msg──▶│  leader's member set  │
//!  └───────────┬───────────┘              └───────────┬───────────┘
//!              │                                      ▼
//!              │                          ┌───────────────────────┐
//!              │                          │   AwaitQuorumShares   │
//!              │                          │  members' result shares│
//!              │                          └───────────┬───────────┘
//!              └──────────────────┬───────────────────┘
//!                                 │ (no quorum slack: AwaitAllResults
//!                                 │  replaces all three — fixed order)
//!                                 ▼
//!                 ┌────────────────────────────────────────────────┐
//!                 │ decode Σf(X̃ᵢ) → gradient; TruncPr update       │
//!                 │ (king openings: non-king side = `AwaitKingOpen`│
//!                 │  in `crate::mpc`)                              │
//!                 └────────────────────────────────────────────────┘
//! ```

use crate::net::tags::Tag;
use crate::net::{PartyId, QuorumOutcome, RoundState, Step, Transport, TryRecv};

use super::protocol::decode_roster_msg;

/// Leader-side first-arrival quorum gather (the event-driven form of
/// [`crate::net::gather_quorum`]): collect the first `need` encoded-
/// gradient result shares across the live peers plus the leader's own.
/// Queued messages from a peer that has since died still count (they
/// were delivered); a peer whose stream closed before delivering can
/// never fill a slot and is retired from polling. Fails with the same
/// "quorum infeasible" wording as the blocking gather when every
/// remaining peer is gone.
pub struct AwaitEncodedGradients {
    tag: Tag,
    need: usize,
    /// Arrived contributions (leader's own seeded at construction).
    got: Vec<(PartyId, Vec<u64>)>,
    /// Peers that may still deliver.
    open: Vec<PartyId>,
    /// Peers whose stream closed before delivering, with causes.
    dead: Vec<(PartyId, String)>,
}

impl AwaitEncodedGradients {
    pub fn new(
        me: PartyId,
        peers: &[PartyId],
        tag: Tag,
        need: usize,
        own: Vec<u64>,
    ) -> AwaitEncodedGradients {
        assert!(
            peers.len() + 1 >= need,
            "quorum of {need} impossible over {} peers + self",
            peers.len()
        );
        AwaitEncodedGradients {
            tag,
            need,
            got: vec![(me, own)],
            open: peers.to_vec(),
            dead: Vec::new(),
        }
    }
}

impl RoundState for AwaitEncodedGradients {
    type Output = QuorumOutcome;

    fn poll(&mut self, net: &dyn Transport) -> Result<Step<QuorumOutcome>, String> {
        let mut i = 0;
        while i < self.open.len() && self.got.len() < self.need {
            let from = self.open[i];
            match net.try_recv(from, self.tag) {
                TryRecv::Ready(data) => {
                    self.got.push((from, data));
                    self.open.remove(i);
                }
                TryRecv::Closed(cause) => {
                    self.dead.push((from, cause));
                    self.open.remove(i);
                }
                TryRecv::Pending => i += 1,
            }
        }
        if self.got.len() >= self.need {
            let mut got = std::mem::take(&mut self.got);
            got.sort_by_key(|(id, _)| *id);
            let (members, payloads): (Vec<PartyId>, Vec<Vec<u64>>) = got.into_iter().unzip();
            // Late = every peer that had not delivered when the quorum
            // filled — still-open ones and dead ones alike, as in the
            // blocking gather (closed peers stay in its waiting set).
            let mut late: Vec<PartyId> = self
                .open
                .iter()
                .copied()
                .chain(self.dead.iter().map(|&(j, _)| j))
                .collect();
            late.sort_unstable();
            return Ok(Step::Ready(QuorumOutcome { members, payloads, late }));
        }
        if self.open.is_empty() {
            let causes: Vec<String> =
                self.dead.iter().map(|(j, r)| format!("party {j}: {r}")).collect();
            return Err(format!(
                "quorum infeasible: need {}, have {} — every remaining peer is gone ({})",
                self.need,
                self.got.len(),
                causes.join("; ")
            ));
        }
        Ok(Step::Pending)
    }

    fn describe(&self) -> String {
        format!(
            "AwaitEncodedGradients(tag {}, {}/{} in quorum)",
            self.tag,
            self.got.len(),
            self.need
        )
    }
}

/// Follower-side wait for the leader's per-round roster announcement:
/// the quorum member set plus any straggler exclusions, parsed and
/// validated ([`decode_roster_msg`]) the moment it arrives.
pub struct AwaitQuorumRoster {
    leader: PartyId,
    tag: Tag,
    n: usize,
}

impl AwaitQuorumRoster {
    pub fn new(leader: PartyId, tag: Tag, n: usize) -> AwaitQuorumRoster {
        AwaitQuorumRoster { leader, tag, n }
    }
}

impl RoundState for AwaitQuorumRoster {
    type Output = (Vec<usize>, Vec<usize>);

    fn poll(&mut self, net: &dyn Transport) -> Result<Step<Self::Output>, String> {
        match net.try_recv(self.leader, self.tag) {
            TryRecv::Ready(msg) => Ok(Step::Ready(decode_roster_msg(&msg, self.n)?)),
            TryRecv::Pending => Ok(Step::Pending),
            TryRecv::Closed(cause) => Err(format!("quorum announcement: {cause}")),
        }
    }

    fn describe(&self) -> String {
        format!("AwaitQuorumRoster(leader {}, tag {})", self.leader, self.tag)
    }
}

/// Shared mechanics of the ordered result-share gathers below: fill one
/// slot per listed party, opportunistically consuming whatever is queued
/// each pass. Error determinism matches the blocking fixed-order gather:
/// a closed peer only fails the round once every slot *before* it is
/// filled — the first unfilled member is always the one reported, no
/// matter in which order later peers were discovered dead.
struct OrderedGather {
    tag: Tag,
    members: Vec<PartyId>,
    slots: Vec<Option<Vec<u64>>>,
}

impl OrderedGather {
    fn new(me: PartyId, members: &[PartyId], tag: Tag, own: Vec<u64>, what: &str) -> OrderedGather {
        let mut own = Some(own);
        let mut slots: Vec<Option<Vec<u64>>> = vec![None; members.len()];
        for (idx, &j) in members.iter().enumerate() {
            if j == me {
                let own = own.take().unwrap_or_else(|| panic!("own result {what} twice"));
                slots[idx] = Some(own);
            }
        }
        OrderedGather { tag, members: members.to_vec(), slots }
    }

    /// One pass; `Err((j, cause))` names the first unfilled member whose
    /// stream is closed (only when every earlier slot is filled).
    fn poll(&mut self, net: &dyn Transport) -> Result<Step<Vec<Vec<u64>>>, (PartyId, String)> {
        let mut blocked = false;
        for (idx, &j) in self.members.iter().enumerate() {
            if self.slots[idx].is_some() {
                continue;
            }
            match net.try_recv(j, self.tag) {
                TryRecv::Ready(data) => self.slots[idx] = Some(data),
                TryRecv::Pending => blocked = true,
                TryRecv::Closed(cause) => {
                    if !blocked {
                        return Err((j, cause));
                    }
                    blocked = true; // sticky: re-reported once it is first
                }
            }
        }
        if blocked {
            Ok(Step::Pending)
        } else {
            let slots = std::mem::take(&mut self.slots);
            Ok(Step::Ready(slots.into_iter().map(|s| s.expect("all slots filled")).collect()))
        }
    }

    fn progress(&self) -> String {
        let filled = self.slots.iter().filter(|s| s.is_some()).count();
        format!("tag {}, {filled}/{} shares", self.tag, self.members.len())
    }
}

/// Follower-side gather of the announced quorum members' result shares,
/// in roster order (the caller's own share seeded at construction).
pub struct AwaitQuorumShares {
    inner: OrderedGather,
}

impl AwaitQuorumShares {
    pub fn new(me: PartyId, members: &[PartyId], tag: Tag, own: Vec<u64>) -> AwaitQuorumShares {
        AwaitQuorumShares {
            inner: OrderedGather::new(me, members, tag, own, "named in the quorum"),
        }
    }
}

impl RoundState for AwaitQuorumShares {
    type Output = Vec<Vec<u64>>;

    fn poll(&mut self, net: &dyn Transport) -> Result<Step<Vec<Vec<u64>>>, String> {
        self.inner
            .poll(net)
            .map_err(|(j, cause)| format!("result share from quorum member {j}: {cause}"))
    }

    fn describe(&self) -> String {
        format!("AwaitQuorumShares({})", self.inner.progress())
    }
}

/// Fixed-order gather of every live party's result share — the
/// no-quorum-slack round shape, identical on the wire to the pre-quorum
/// protocol while the roster is full (no roster message).
pub struct AwaitAllResults {
    inner: OrderedGather,
}

impl AwaitAllResults {
    pub fn new(me: PartyId, live: &[PartyId], tag: Tag, own: Vec<u64>) -> AwaitAllResults {
        AwaitAllResults { inner: OrderedGather::new(me, live, tag, own, "gathered") }
    }
}

impl RoundState for AwaitAllResults {
    type Output = Vec<Vec<u64>>;

    fn poll(&mut self, net: &dyn Transport) -> Result<Step<Vec<Vec<u64>>>, String> {
        self.inner
            .poll(net)
            .map_err(|(j, cause)| format!("result share from {j}: {cause}"))
    }

    fn describe(&self) -> String {
        format!("AwaitAllResults({})", self.inner.progress())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::local::Hub;
    use crate::net::{drive, Transport};

    #[test]
    fn await_encoded_gradients_matches_blocking_quorum_semantics() {
        let eps = Hub::new(4);
        for ep in &eps[1..3] {
            ep.send(0, 5, vec![ep.id() as u64 * 10]);
        }
        let st = AwaitEncodedGradients::new(0, &[1, 2, 3], 5, 3, vec![0]);
        let out = drive(&eps[0], st).unwrap();
        assert_eq!(out.members, vec![0, 1, 2]);
        assert_eq!(out.payloads, vec![vec![0], vec![10], vec![20]]);
        assert_eq!(out.late, vec![3]);
    }

    #[test]
    fn await_encoded_gradients_counts_queued_mail_from_dead_peers() {
        let eps = Hub::new(3);
        eps[1].send(0, 0, vec![11]);
        eps[1].leave("killed after sending");
        eps[2].send(0, 0, vec![22]);
        let st = AwaitEncodedGradients::new(0, &[1, 2], 0, 3, vec![0]);
        let out = drive(&eps[0], st).unwrap();
        assert_eq!(out.members, vec![0, 1, 2], "delivered-then-died still counts");
    }

    #[test]
    fn await_encoded_gradients_fails_like_the_blocking_gather() {
        let eps = Hub::new(3);
        eps[1].leave("killed by test");
        eps[2].leave("killed by test");
        let st = AwaitEncodedGradients::new(0, &[1, 2], 0, 3, vec![0]);
        let err = drive(&eps[0], st).unwrap_err();
        assert!(err.contains("quorum infeasible"), "{err}");
        assert!(err.contains("killed by test"), "{err}");
    }

    #[test]
    fn await_quorum_roster_surfaces_dead_leader() {
        let eps = Hub::new(2);
        eps[0].leave("leader crashed");
        let err = drive(&eps[1], AwaitQuorumRoster::new(0, 7, 2)).unwrap_err();
        assert!(err.contains("quorum announcement"), "{err}");
        assert!(err.contains("leader crashed"), "{err}");
    }

    #[test]
    fn ordered_gather_reports_the_first_unfilled_dead_member() {
        // Peer 2 dies first, but peer 1's share is still outstanding: the
        // error must name 1 once it dies too — never 2 while 1 is merely
        // slow, matching the blocking gather's in-order semantics.
        let eps = Hub::new(4);
        eps[2].leave("late death");
        let st = AwaitQuorumShares::new(0, &[0, 1, 2], 9, vec![0]);
        let err = std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                eps[1].leave("early death, reported late");
            });
            drive(&eps[0], st).unwrap_err()
        });
        assert!(err.contains("quorum member 1"), "{err}");
        assert!(err.contains("early death, reported late"), "{err}");
    }

    #[test]
    fn await_all_results_completes_out_of_order() {
        let eps = Hub::new(3);
        eps[2].send(0, 3, vec![22]); // higher id arrives first
        eps[1].send(0, 3, vec![11]);
        let shares = drive(&eps[0], AwaitAllResults::new(0, &[0, 1, 2], 3, vec![0])).unwrap();
        assert_eq!(shares, vec![vec![0], vec![11], vec![22]], "output stays in roster order");
    }
}
