//! The full COPML protocol (Algorithm 1), executed by `N` real clients
//! over any [`Transport`]: Shamir sharing of the per-client datasets, MPC
//! Lagrange encoding of data and model, per-client encoded gradients
//! (Eq. 7) through the [`crate::runtime`] engine (native or AOT/PJRT),
//! MPC decoding (Eq. 10), and the two-stage TruncPr model update — every
//! byte the paper's clients would exchange crosses a channel, and every
//! phase is timed and byte-accounted.
//!
//! Three entry points share the same client body ([`run_client`] /
//! `client_main`), so the trajectories are bit-identical by construction:
//!
//! * [`train`] — `N` client threads over the in-process [`Hub`];
//! * [`train_tcp_loopback`] — `N` client threads, each on its own
//!   [`crate::net::tcp::TcpTransport`] socket endpoint (real framed
//!   bytes over 127.0.0.1);
//! * [`run_client`] — ONE client over an already-established transport:
//!   the entry point of the `copml party` CLI for genuinely distributed
//!   runs (one OS process per party).

use std::sync::Arc;
use std::time::Instant;

use crate::data::Dataset;
use crate::field::{par, MatShape};
use crate::lcc;
use crate::mpc::{Dealer, Offline, OfflineMode, Party};
use crate::net::local::Hub;
use crate::net::Transport;
use crate::poly;
use crate::runtime::{native::NativeKernel, Engine, GradKernel, KernelServer};
use crate::shamir;

use super::algo::copml_demand;
use super::{CopmlConfig, QuantizedTask, TrainOutput};

/// Phase labels of the per-client ledger (order = execution order).
/// Phase 0 is the offline randomness generation: zero bytes under
/// [`crate::mpc::OfflineMode::Dealer`] (the crypto-service provider is
/// free on the wire), real DN07 traffic under
/// [`crate::mpc::OfflineMode::Distributed`].
pub const PHASES: [&str; 8] = [
    "offline",
    "share_dataset",
    "xty",
    "encode_dataset",
    "encode_model",
    "compute_gradient",
    "share_results",
    "decode_update",
];

/// One client's timing/byte ledger.
#[derive(Clone, Debug, Default)]
pub struct ClientLedger {
    /// Seconds per phase, aligned with [`PHASES`].
    pub seconds: [f64; 8],
    /// Payload bytes sent per phase.
    pub bytes: [u64; 8],
}

impl ClientLedger {
    pub fn total_seconds(&self) -> f64 {
        self.seconds.iter().sum()
    }
}

/// Result of a full-protocol run.
pub struct ProtocolOutput {
    pub train: TrainOutput,
    /// Per-client ledgers.
    pub ledgers: Vec<ClientLedger>,
}

/// Per-client subgroup of size `T+1` used for encode exchanges
/// (paper footnote 4). Returns the member ids of client `i`'s group.
fn subgroup(n: usize, t: usize, i: usize) -> Vec<usize> {
    let gsize = t + 1;
    let ngroups = (n / gsize).max(1);
    let g = (i / gsize).min(ngroups - 1);
    let lo = g * gsize;
    let hi = if g == ngroups - 1 { n } else { lo + gsize };
    (lo..hi).collect()
}

/// Who client `me` sends encodings to (`targets`) and receives its own
/// encoding's shares from (`sources`) during the encode exchanges.
///
/// * footnote-4 subgroups ON: both are `me`'s subgroup — every client
///   encodes for its `T+1` group-mates (balanced NICs);
/// * OFF (the naive layout): the fixed reconstruction set `{0..T}`
///   computes encodings for everyone, so clients `≤ T` send to all `N`.
fn encode_roles(n: usize, t: usize, me: usize, subgroups: bool) -> (Vec<usize>, Vec<usize>) {
    if subgroups {
        let g = subgroup(n, t, me);
        (g.clone(), g)
    } else if me <= t {
        ((0..n).collect(), (0..=t).collect())
    } else {
        (Vec::new(), (0..=t).collect())
    }
}

struct ClientCtx {
    cfg: CopmlConfig,
    task: Arc<QuantizedTask>,
    kernel: Box<dyn GradKernel>,
}

/// One client's result of a full-protocol run.
pub struct ClientOutput {
    pub id: usize,
    /// Opened final model (field domain).
    pub w_final: Vec<u64>,
    /// Per-iteration share snapshot of `[w]` (for god-mode trace recovery).
    pub w_share_snapshots: Vec<Vec<u64>>,
    pub ledger: ClientLedger,
}

/// Run the full protocol. Spawns `cfg.n` client threads over the
/// in-process [`Hub`]; the PJRT engine (if selected) is hosted on a
/// [`KernelServer`] thread.
pub fn train(cfg: &CopmlConfig, ds: &Dataset) -> Result<ProtocolOutput, String> {
    cfg.validate(ds)?;
    let f = cfg.plan.field;

    // PJRT lives on its own thread; clients get Send handles. The server
    // (when used) must outlive the client threads, hence the Option slot.
    #[allow(unused_mut)]
    let mut _server: Option<KernelServer> = None;
    let kernel_par = cfg.parallelism;
    let mk_kernel: Box<dyn Fn() -> Box<dyn GradKernel>> = match cfg.engine {
        Engine::Native => {
            Box::new(move || Box::new(NativeKernel::with_parallelism(f, kernel_par)))
        }
        #[cfg(feature = "pjrt")]
        Engine::Pjrt => {
            use crate::runtime::pjrt::PjrtRuntime;
            // Preflight the artifact load on a scratch thread (PjrtRuntime
            // is not Send, so it cannot be loaded here and moved into the
            // server). A load failure — missing artifacts, or the vendor
            // xla stub — surfaces as a clean Err instead of a panic
            // cascading across all N client threads.
            let dir = PjrtRuntime::default_dir();
            let probe_dir = dir.clone();
            std::thread::spawn(move || {
                PjrtRuntime::load(&probe_dir).map(|_| ()).map_err(|e| e.to_string())
            })
            .join()
            .map_err(|_| "PJRT preflight thread panicked".to_string())?
            .map_err(|e| format!("loading AOT artifacts (run `make artifacts`): {e}"))?;
            let server = KernelServer::spawn(move || {
                PjrtRuntime::load(&dir)
                    .expect("AOT artifacts loaded in preflight but failed in the kernel server")
            });
            let handle = server.handle();
            _server = Some(server);
            Box::new(move || Box::new(handle.clone()))
        }
        #[cfg(not(feature = "pjrt"))]
        Engine::Pjrt => {
            return Err(
                "engine 'pjrt' requires building with `--features pjrt` \
                 (this binary was built with the native engine only)"
                    .into(),
            )
        }
    };

    let endpoints = Hub::with_wire(cfg.n, cfg.wire);
    run_clients(cfg, ds, endpoints, &mk_kernel)
}

/// Run the full protocol with every client on its own TCP socket endpoint
/// over `127.0.0.1` ([`crate::net::tcp::loopback_mesh`]): separate
/// endpoints exchanging real framed bytes, same aggregation and god-mode
/// trace as [`train`]. Native engine only (the PJRT kernel server is a
/// single-process construct). Used by the equivalence tests and CI smoke.
pub fn train_tcp_loopback(cfg: &CopmlConfig, ds: &Dataset) -> Result<ProtocolOutput, String> {
    cfg.validate(ds)?;
    if !matches!(cfg.engine, Engine::Native) {
        return Err("tcp loopback training supports the native engine only".into());
    }
    let transports = crate::net::tcp::loopback_mesh(cfg.n, cfg.wire)
        .map_err(|e| format!("establishing the loopback TCP mesh: {e}"))?;
    let f = cfg.plan.field;
    let kernel_par = cfg.parallelism;
    let mk_kernel: Box<dyn Fn() -> Box<dyn GradKernel>> =
        Box::new(move || Box::new(NativeKernel::with_parallelism(f, kernel_par)));
    run_clients(cfg, ds, transports, &mk_kernel)
}

/// Run ONE client of the full protocol over an already-established
/// transport — the distributed entry point (`copml party`). The offline
/// pool comes from `cfg.offline`'s provider: under `dealer` every process
/// replays its pool from `cfg.seed` (the paper's crypto-service-provider
/// runs offline; here it is replayed from the shared seed); under
/// `distributed` the processes generate it collectively over the mesh —
/// zero dealer involvement. Either way every process executes the same
/// SPMD sequence as the threaded [`train`], so a mesh of `run_client`
/// processes matches the Hub run for the same configuration
/// (bit-identically — both modes are deterministic per seed).
pub fn run_client(
    cfg: &CopmlConfig,
    ds: &Dataset,
    net: &dyn Transport,
) -> Result<ClientOutput, String> {
    cfg.validate(ds)?;
    if net.n() != cfg.n {
        return Err(format!("transport has {} parties but cfg.n = {}", net.n(), cfg.n));
    }
    if !matches!(cfg.engine, Engine::Native) {
        return Err("distributed clients support the native engine only".into());
    }
    let task = Arc::new(QuantizedTask::new(cfg, ds));
    let f = task.f;
    let demand = copml_demand(cfg, task.d, task.rows_padded);
    // The offline phase runs first, over the same transport: the dealer
    // provider replays this party's pool from the shared seed (zero
    // traffic, bit-identical to `Dealer::deal(..)[id]`); the distributed
    // provider generates it collectively with the other parties (DN07,
    // real bytes — ledger phase 0).
    let t0 = Instant::now();
    let bytes_mark = net.bytes_sent();
    let pool = cfg.offline.provider().provide(
        net,
        f,
        cfg.t,
        &demand,
        cfg.plan.k2,
        cfg.plan.kappa,
        cfg.seed,
    );
    let offline_s = t0.elapsed().as_secs_f64();
    let offline_bytes = net.bytes_sent() - bytes_mark;
    let kernel: Box<dyn GradKernel> =
        Box::new(NativeKernel::with_parallelism(f, cfg.parallelism));
    let ctx = ClientCtx { cfg: cfg.clone(), task, kernel };
    let party = Party::new(net, cfg.t, f, pool, cfg.seed);
    let mut out = client_main(&party, ctx);
    out.ledger.seconds[0] = offline_s;
    out.ledger.bytes[0] = offline_bytes;
    Ok(out)
}

/// Spawn one client thread per transport endpoint, join, and aggregate:
/// final-model consensus, god-mode trace reconstruction from `T+1` share
/// snapshots, accuracy/loss traces. Transport-generic — [`train`] passes
/// Hub endpoints, [`train_tcp_loopback`] passes socket endpoints.
fn run_clients<T: Transport + Send + 'static>(
    cfg: &CopmlConfig,
    ds: &Dataset,
    transports: Vec<T>,
    mk_kernel: &dyn Fn() -> Box<dyn GradKernel>,
) -> Result<ProtocolOutput, String> {
    let task = Arc::new(QuantizedTask::new(cfg, ds));
    let f = task.f;
    let (n, t) = (cfg.n, cfg.t);
    assert_eq!(transports.len(), n, "one endpoint per client");
    let demand = copml_demand(cfg, task.d, task.rows_padded);

    // Dealer mode pre-deals all pools in ONE pass here (the provider's
    // `deal_one` is for one-process-per-party runs — calling it from
    // every client thread would redo the full N-party share evaluation N
    // times). The distributed phase has no central shortcut: each thread
    // runs the DN07 protocol over its own endpoint (ledger phase 0).
    let predealt: Vec<Option<Offline>> = match cfg.offline {
        OfflineMode::Dealer => {
            Dealer::deal(f, n, t, &demand, cfg.plan.k2, cfg.plan.kappa, cfg.seed)
                .into_iter()
                .map(Some)
                .collect()
        }
        OfflineMode::Distributed => (0..n).map(|_| None).collect(),
    };

    let mut handles = Vec::new();
    for (ep, dealt) in transports.into_iter().zip(predealt) {
        let ctx = ClientCtx { cfg: cfg.clone(), task: task.clone(), kernel: mk_kernel() };
        let seed = cfg.seed;
        let demand = demand.clone();
        handles.push(std::thread::spawn(move || {
            let (pool, offline_s, offline_bytes) = match dealt {
                // Crypto-service provider: pool already dealt, free on
                // the wire — the offline ledger row stays zero.
                Some(pool) => (pool, 0.0, 0),
                None => {
                    let t0 = Instant::now();
                    let bytes_mark = ep.bytes_sent();
                    let pool = ctx.cfg.offline.provider().provide(
                        &ep,
                        ctx.task.f,
                        ctx.cfg.t,
                        &demand,
                        ctx.cfg.plan.k2,
                        ctx.cfg.plan.kappa,
                        seed,
                    );
                    (pool, t0.elapsed().as_secs_f64(), ep.bytes_sent() - bytes_mark)
                }
            };
            let party = Party::new(&ep, ctx.cfg.t, ctx.task.f, pool, seed);
            let mut out = client_main(&party, ctx);
            out.ledger.seconds[0] = offline_s;
            out.ledger.bytes[0] = offline_bytes;
            out
        }));
    }
    let mut results: Vec<ClientOutput> = handles
        .into_iter()
        .map(|h| h.join().map_err(|_| "client thread panicked".to_string()))
        .collect::<Result<_, _>>()?;
    results.sort_by_key(|r| r.id);

    // All clients must agree on the final model.
    for r in &results[1..] {
        if r.w_final != results[0].w_final {
            return Err("clients disagree on the final model".into());
        }
    }

    // God-mode trace: reconstruct w^{(t)} from t+1 share snapshots.
    let lambdas = shamir::lambda_points(n);
    let rec = shamir::Reconstructor::new(f, &lambdas[..t + 1]);
    let mut train = TrainOutput::default();
    for it in 0..cfg.iters {
        let views: Vec<&[u64]> = results[..t + 1]
            .iter()
            .map(|r| r.w_share_snapshots[it].as_slice())
            .collect();
        let mut w = vec![0u64; task.d];
        rec.reconstruct(f, &views, &mut w);
        train.w_trace.push(w);
    }
    // Consistency: reconstructed last iterate must equal the opened model.
    if train.w_trace.last() != Some(&results[0].w_final) {
        return Err("opened model disagrees with reconstructed trace".into());
    }
    train.eval_traces(&cfg.plan, ds);
    Ok(ProtocolOutput { train, ledgers: results.into_iter().map(|r| r.ledger).collect() })
}

/// Padded per-client row ranges (padding rows belong to the last client,
/// which shares zeros for them — inert in the gradient).
pub(crate) fn padded_ranges(rows_padded: usize, n: usize) -> Vec<(usize, usize)> {
    let base = rows_padded / n;
    let extra = rows_padded % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for j in 0..n {
        let len = base + usize::from(j < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

fn client_main(party: &Party, ctx: ClientCtx) -> ClientOutput {
    let cfg = &ctx.cfg;
    let task = &ctx.task;
    let f = task.f;
    let me = party.id;
    let (n, t, k) = (cfg.n, cfg.t, cfg.k);
    let (rows, d) = (task.rows_padded, task.d);
    let rows_k = rows / k;
    let mut ledger = ClientLedger::default();
    struct PhaseTimer {
        start: Instant,
        bytes_mark: u64,
    }
    impl PhaseTimer {
        fn reset(&mut self, party: &Party) {
            self.start = Instant::now();
            self.bytes_mark = party.net.bytes_sent();
        }
        fn tick(&mut self, ledger: &mut ClientLedger, phase: usize, party: &Party) {
            ledger.seconds[phase] += self.start.elapsed().as_secs_f64();
            ledger.bytes[phase] += party.net.bytes_sent() - self.bytes_mark;
            self.reset(party);
        }
    }
    let mut timer = PhaseTimer { start: Instant::now(), bytes_mark: party.net.bytes_sent() };

    // ---- Phase: share the dataset (Algorithm 1, lines 1–3) -------------
    let ranges = padded_ranges(rows, n);
    let (lo, hi) = ranges[me];
    let my_x = &task.x_q[lo * d..hi * d];
    let my_y = &task.y_q[lo..hi];
    let tag_x = party.fresh_tag();
    let tag_y = party.fresh_tag();
    let own_x = party.share_out(my_x, tag_x);
    let own_y = party.share_out(my_y, tag_y);
    // Assemble [X]_me, [y]_me in global row order.
    let mut x_share = vec![0u64; rows * d];
    let mut y_share = vec![0u64; rows];
    for (j, &(jl, jh)) in ranges.iter().enumerate() {
        let (xs, ys) = if j == me {
            (own_x.clone(), own_y.clone())
        } else {
            (party.net.recv(j, tag_x), party.net.recv(j, tag_y))
        };
        x_share[jl * d..jh * d].copy_from_slice(&xs);
        y_share[jl..jh].copy_from_slice(&ys);
    }
    timer.tick(&mut ledger, 1, party);

    // ---- Phase: [Xᵀy], aligned (Algorithm 1, line 10) -------------------
    let pp = cfg.parallelism;
    let shape_full = MatShape::new(rows, d);
    let local = par::matvec_t(f, pp, &x_share, shape_full, &y_share); // deg 2T
    let mut xty = party.degree_reduce_bh08(&local); // deg T
    let align = f.reduce(1u64 << (cfg.plan.lc + cfg.plan.lx + cfg.plan.lw));
    party.scale(&mut xty, align);
    timer.tick(&mut ledger, 2, party);

    // ---- Phase: Lagrange-encode the dataset (Eq. 3; lines 5–9) ----------
    let enc = lcc::Encoder::standard(f, k, t, n);
    // Partition [X] into K parts + T mask shares from the offline pool.
    let parts: Vec<&[u64]> = (0..k).map(|kk| &x_share[kk * rows_k * d..(kk + 1) * rows_k * d]).collect();
    let masks: Vec<Vec<u64>> = (0..t).map(|_| party.random_share(rows_k * d)).collect();
    let all_parts: Vec<&[u64]> = parts.into_iter().chain(masks.iter().map(|m| m.as_slice())).collect();
    let (targets, sources) = encode_roles(n, t, me, cfg.subgroups);
    let tag_xenc = party.fresh_tag();
    // Compute and send [X̃_i]_me for every target i.
    let mut own_enc_share: Option<Vec<u64>> = None;
    for &i in &targets {
        let mut buf = vec![0u64; rows_k * d];
        enc.encode_one_par(pp, i, &all_parts, &mut buf);
        if i == me {
            own_enc_share = Some(buf);
        } else {
            party.net.send(i, tag_xenc, buf);
        }
    }
    // Reconstruct my encoded matrix X̃_me from the sources' shares.
    let source_pts: Vec<u64> = sources.iter().map(|&i| party.lambdas[i]).collect();
    let rec = shamir::Reconstructor::new(f, &source_pts);
    let enc_shares: Vec<Vec<u64>> = sources
        .iter()
        .map(|&i| {
            if i == me {
                own_enc_share.take().unwrap()
            } else {
                party.net.recv(i, tag_xenc)
            }
        })
        .collect();
    let views: Vec<&[u64]> = enc_shares.iter().map(|v| v.as_slice()).collect();
    let mut x_tilde = vec![0u64; rows_k * d];
    rec.reconstruct(f, &views, &mut x_tilde);
    drop(enc_shares);
    drop(x_share);
    timer.tick(&mut ledger, 3, party);

    // Precompute: model-encoding coefficient rows (Eq. 4 — the K data
    // slots all carry [w], so their coefficients collapse to a row sum).
    let (betas, alphas) = poly::standard_points(k + t, n);
    let enc_rows = poly::coeff_matrix(f, &betas, &alphas);
    let w_data_coeff: Vec<u64> = enc_rows
        .iter()
        .map(|row| row[..k].iter().fold(0u64, |acc, &c| f.add(acc, c)))
        .collect();
    // Decoder for the aggregate gradient (uses the first `need` clients).
    let need = cfg.recovery_threshold();
    let deg_f = 2 * cfg.r + 1;
    let decoder = lcc::Decoder::new(f, k, t, deg_f, &alphas[..need], &betas);
    let shape_k = MatShape::new(rows_k, d);

    let mut w_share = vec![0u64; d]; // shares of w^(0) = 0
    let mut snapshots: Vec<Vec<u64>> = Vec::with_capacity(cfg.iters);

    timer.reset(party);
    for _iter in 0..cfg.iters {
        // ---- encode the model (Eq. 4; lines 12–15) ----------------------
        let vmasks: Vec<Vec<u64>> = (0..t).map(|_| party.random_share(d)).collect();
        let tag_wenc = party.fresh_tag();
        let mut own_wenc: Option<Vec<u64>> = None;
        for &i in &targets {
            let mut buf = w_share.clone();
            party.scale(&mut buf, w_data_coeff[i]);
            for (kk, vm) in vmasks.iter().enumerate() {
                let c = enc_rows[i][k + kk];
                for (b, &v) in buf.iter_mut().zip(vm) {
                    *b = f.reduce(*b + c * v);
                }
            }
            if i == me {
                own_wenc = Some(buf);
            } else {
                party.net.send(i, tag_wenc, buf);
            }
        }
        let wenc_shares: Vec<Vec<u64>> = sources
            .iter()
            .map(|&i| {
                if i == me {
                    own_wenc.take().unwrap()
                } else {
                    party.net.recv(i, tag_wenc)
                }
            })
            .collect();
        let views: Vec<&[u64]> = wenc_shares.iter().map(|v| v.as_slice()).collect();
        let mut w_tilde = vec![0u64; d];
        rec.reconstruct(f, &views, &mut w_tilde);
        timer.tick(&mut ledger, 4, party);

        // ---- local encoded gradient (Eq. 7; line 16) --------------------
        let f_mine = ctx.kernel.encoded_gradient(&x_tilde, shape_k, &w_tilde, &task.coeffs_q);
        timer.tick(&mut ledger, 5, party);

        // ---- share the result (line 16b) --------------------------------
        let tag_res = party.fresh_tag();
        let own_res = party.share_out(&f_mine, tag_res);
        let result_shares: Vec<Vec<u64>> = (0..need)
            .map(|j| {
                if j == me {
                    own_res.clone()
                } else {
                    party.net.recv(j, tag_res)
                }
            })
            .collect();
        // Drain the rest (sent for cost parity; not needed to decode).
        for j in need..n {
            if j != me {
                let _ = party.net.recv(j, tag_res);
            }
        }
        timer.tick(&mut ledger, 6, party);

        // ---- decode + model update (Eq. 10–11; lines 18–23) -------------
        let views: Vec<&[u64]> = result_shares.iter().map(|v| v.as_slice()).collect();
        let mut grad = vec![0u64; d];
        decoder.decode_sum_par(pp, &views, &mut grad);
        party.sub(&mut grad, &xty);
        let mut g1 = party.trunc_pr(&grad, cfg.plan.k2, cfg.plan.k1_stage1(), cfg.plan.kappa, true);
        party.scale(&mut g1, task.eta_q);
        let g2 = party.trunc_pr(&g1, cfg.plan.k2, cfg.plan.k1_stage2(), cfg.plan.kappa, true);
        party.sub(&mut w_share, &g2);
        snapshots.push(w_share.clone());
        timer.tick(&mut ledger, 7, party);
    }

    // ---- final: open the model (lines 25–27) ----------------------------
    let w_final = party.open_broadcast(&w_share, t);

    ClientOutput { id: me, w_final, w_share_snapshots: snapshots, ledger }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CaseParams;
    use crate::data::SynthSpec;

    #[test]
    fn subgroups_cover_and_have_threshold_size() {
        for (n, t) in [(10usize, 1usize), (12, 2), (13, 3), (50, 7)] {
            for i in 0..n {
                let g = subgroup(n, t, i);
                assert!(g.len() >= t + 1, "n={n} t={t} i={i}: {g:?}");
                assert!(g.contains(&i));
            }
        }
    }

    #[test]
    fn encode_roles_are_consistent() {
        // Every (sender → receiver) edge implied by `targets` must appear
        // in the receiver's `sources`, and vice versa — no deadlock.
        for subgroups in [true, false] {
            for (n, t) in [(7usize, 1usize), (11, 2), (13, 3)] {
                let roles: Vec<_> =
                    (0..n).map(|i| encode_roles(n, t, i, subgroups)).collect();
                for me in 0..n {
                    for &dst in &roles[me].0 {
                        assert!(
                            roles[dst].1.contains(&me),
                            "edge {me}→{dst} missing in sources (subgroups={subgroups})"
                        );
                    }
                    for &src in &roles[me].1 {
                        assert!(
                            roles[src].0.contains(&me),
                            "source {src} of {me} does not target it (subgroups={subgroups})"
                        );
                    }
                    assert!(roles[me].1.len() >= t + 1, "need t+1 shares");
                }
            }
        }
    }

    #[test]
    fn padded_ranges_partition() {
        let r = padded_ranges(100, 7);
        assert_eq!(r[0].0, 0);
        assert_eq!(r[6].1, 100);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn run_client_mesh_matches_train() {
        // The distributed entry point: every party independently derives
        // its dealer pool from the shared seed and runs over its own TCP
        // endpoint — all must open the model `train` computes.
        let ds = Dataset::synth(SynthSpec::tiny(), 22);
        let mut cfg =
            super::super::CopmlConfig::for_dataset(&ds, 4, CaseParams::explicit(1, 1), 22);
        cfg.iters = 2;
        let reference = train(&cfg, &ds).unwrap();
        let transports = crate::net::tcp::loopback_mesh(cfg.n, cfg.wire).unwrap();
        let handles: Vec<_> = transports
            .into_iter()
            .map(|net| {
                let cfg = cfg.clone();
                let ds = ds.clone();
                std::thread::spawn(move || run_client(&cfg, &ds, &net).unwrap())
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(out.w_final, *reference.train.w_trace.last().unwrap());
        }
    }

    #[test]
    fn full_protocol_matches_algo_mode_tiny() {
        // The headline invariant: threaded protocol ≡ central recursion,
        // bit for bit. (The large-config version lives in
        // tests/protocol_equivalence.rs.)
        let ds = Dataset::synth(SynthSpec::tiny(), 21);
        let mut cfg = super::super::CopmlConfig::for_dataset(&ds, 7, CaseParams::explicit(2, 1), 21);
        cfg.iters = 4;
        let algo = super::super::algo::train(&cfg, &ds).unwrap();
        let full = train(&cfg, &ds).unwrap();
        assert_eq!(algo.w_trace, full.train.w_trace);
    }
}
