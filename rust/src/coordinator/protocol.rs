//! The full COPML protocol (Algorithm 1), executed by `N` real clients
//! over any [`Transport`]: Shamir sharing of the per-client datasets, MPC
//! Lagrange encoding of data and model, per-client encoded gradients
//! (Eq. 7) through the [`crate::runtime`] engine (native or AOT/PJRT),
//! MPC decoding (Eq. 10), and the two-stage TruncPr model update — every
//! byte the paper's clients would exchange crosses a channel, and every
//! phase is timed and byte-accounted.
//!
//! Three entry points share the same client body ([`run_client`] /
//! `client_main`), so the trajectories are bit-identical by construction:
//!
//! * [`train`] — `N` client threads over the in-process [`Hub`];
//! * [`train_tcp_loopback`] — `N` client threads, each on its own
//!   [`crate::net::tcp::TcpTransport`] socket endpoint (real framed
//!   bytes over 127.0.0.1);
//! * [`run_client`] — ONE client over an already-established transport:
//!   the entry point of the `copml party` CLI for genuinely distributed
//!   runs (one OS process per party).
//!
//! **Straggler resilience (Theorem 1 made operational):** whenever the
//! live roster exceeds the recovery threshold `need = (2r+1)(K+T−1)+1`,
//! the per-iteration encoded-gradient gather completes on the first
//! `need` arrivals instead of a fixed prefix: the quorum leader (party 0)
//! collects first-arrivals ([`super::rounds::AwaitEncodedGradients`];
//! [`crate::net::gather_quorum`] remains the blocking reference
//! implementation the rounds tests pin against), announces the
//! quorum composition, and every live party decodes from that same
//! subset through a per-subset [`crate::lcc::DecoderCache`]. Because
//! Lagrange interpolation is exact, the decoded gradient — and hence the
//! whole `w_trace` — is bit-identical regardless of which quorum answers.
//! A party that misses `max_lag` consecutive quorums is excluded for the
//! rest of training (roster-aware collectives in [`crate::mpc::Party`]);
//! injected faults for experiments come from
//! [`crate::coordinator::FaultPlan`] (`--delay`, `--kill-after`).
//!
//! **Event-driven rounds (`--runtime threaded|event`):** the per-iteration
//! result gathers run through the explicit per-round states of
//! [`super::rounds`] ([`super::rounds::AwaitEncodedGradients`],
//! [`super::rounds::AwaitQuorumRoster`], …) under *both* runtimes — the
//! flag only selects who feeds the socket transport's mailbox (per-peer
//! reader threads, or one shared `poll(2)` reactor thread for every
//! connection), which is why `w_trace` is bit-identical across runtimes
//! by construction. On the in-process [`Hub`] the choice is structurally
//! a no-op.
//!
//! **Mini-batch SGD (`--batches B`):** the padded rows are dealt into `B`
//! seeded-permutation batches ([`crate::data::BatchPlan`]); Phase 2
//! Lagrange-encodes **each batch once up front** (amortized across every
//! epoch — re-encoding per epoch would erase the speedup) and precomputes
//! the per-batch `Xᵀ_b y_b` through one concatenated BH08 reduction; the
//! iteration loop then trains batch `iter mod B`, shrinking per-round
//! compute by the batch ratio while every exchanged vector stays
//! `d`-sized. Batching composes with the quorum path above — the decoded
//! batch gradient is still an exact interpolation, so `w_trace` remains
//! bit-identical to the central recursion for every `B`.
//!
//! **Pipelined offline factory (`--chunk C`):** with
//! [`crate::mpc::OfflineMode::Distributed`], the offline randomness can be
//! generated in `C`-sized chunks on a background producer thread
//! ([`crate::mpc::offline::start_factory`]) while the online rounds
//! consume the pools — `take_*` blocks only when consumption outruns
//! production. The ledger's phase-0 row then splits: `seconds[0]` keeps
//! only the **critical-path** stall time, and the producer's remaining
//! generation time lands in [`ClientLedger::offline_hidden_s`]. The chunk
//! schedule is deterministic and element-identical to the one-shot pools
//! (chunk-stability contract, [`crate::mpc::offline`] docs), so `w_trace`
//! is bit-identical for every chunk size.
//!
//! **Multi-job serve ([`serve`] / [`serve_tcp_loopback`]):** the parties
//! hold one mesh open and run a stream of training jobs, job `j` in tag
//! session `j` ([`crate::net::tags`] SESSION stripes) with seed
//! `base + j`. With pipelining on, job `j+1`'s offline factory is
//! prefetched while job `j` trains — its pools fill behind the online
//! rounds, so steady-state jobs skip the cold-start offline wait. Session
//! ids renumber tags, never values, so every served job's `w_trace` is
//! bit-identical to a standalone run with the same seed.

use std::sync::Arc;
use std::time::Instant;

use crate::data::Dataset;
use crate::field::{par, Field, MatShape};
use crate::lcc;
use crate::mpc::offline::{self, Demand};
use crate::mpc::{Dealer, Offline, OfflineMode, Party};
use crate::net::local::Hub;
use crate::net::tags::{self, SpmdTagTrace};
use crate::net::{drive, Transport};
use crate::poly;
use crate::runtime::{native::NativeKernel, Engine, GradKernel, KernelServer};
use crate::shamir;

use super::algo::copml_demand;
use super::rounds::{
    AwaitAllResults, AwaitEncodedGradients, AwaitQuorumRoster, AwaitQuorumShares,
};
use super::{CopmlConfig, QuantizedTask, TrainOutput};

/// Phase labels of the per-client ledger (order = execution order).
/// Phase 0 is the offline randomness generation: zero bytes under
/// [`crate::mpc::OfflineMode::Dealer`] (the crypto-service provider is
/// free on the wire), real DN07 traffic under
/// [`crate::mpc::OfflineMode::Distributed`].
pub const PHASES: [&str; 8] = [
    "offline",
    "share_dataset",
    "xty",
    "encode_dataset",
    "encode_model",
    "compute_gradient",
    "share_results",
    "decode_update",
];

/// One client's timing/byte ledger.
#[derive(Clone, Debug, Default)]
pub struct ClientLedger {
    /// Seconds per phase, aligned with [`PHASES`]. Phase 0 ("offline") is
    /// the **on-critical-path** offline time: the full timed generation
    /// for a one-shot run (the legacy single number, bit-equal when
    /// pipelining is off), but only the consumer's feed-stall time when
    /// the chunked factory is on — the rest of the generation ran hidden
    /// behind the online rounds and is reported in `offline_hidden_s`.
    pub seconds: [f64; 8],
    /// Payload bytes sent per phase.
    pub bytes: [u64; 8],
    /// Offline generation seconds hidden behind the online rounds by the
    /// pipelined factory (`--chunk`): producer generation time minus the
    /// consumer's stall time. Zero whenever pipelining is off, keeping
    /// `seconds[0]` the complete legacy accounting on its own.
    pub offline_hidden_s: f64,
    /// Per-iteration quorum of the encoded-gradient decode: the client
    /// ids whose results interpolated this round's gradient (sorted).
    /// With no slack (`live == need`) this is the whole live roster.
    pub quorums: Vec<Vec<usize>>,
    /// Parties excluded from the roster during this client's run, in
    /// exclusion order (stragglers past `--max-lag`, killed peers).
    pub excluded: Vec<usize>,
    /// Undelivered mailbox state (queued messages + forget-tombstones) at
    /// client exit. Zero after any clean run — the mailbox-hygiene
    /// regression guard.
    pub pending_at_exit: usize,
    /// `(from, tag)` pairs that were delivered again after the mailbox
    /// had already drained them (debug builds; 0 in release). Any nonzero
    /// count means two protocol steps shared a tag — the dynamic
    /// complement of the static window discipline in [`crate::net::tags`].
    pub tag_reuse: usize,
}

impl ClientLedger {
    pub fn total_seconds(&self) -> f64 {
        self.seconds.iter().sum()
    }
}

/// Result of a full-protocol run.
pub struct ProtocolOutput {
    pub train: TrainOutput,
    /// Per-client ledgers.
    pub ledgers: Vec<ClientLedger>,
}

/// Per-client subgroup of size `T+1` used for encode exchanges
/// (paper footnote 4). Returns the member ids of client `i`'s group.
/// `pub(crate)` so `CopmlConfig::validate` can compute the subgroup
/// collateral of a fault plan.
pub(crate) fn subgroup(n: usize, t: usize, i: usize) -> Vec<usize> {
    let gsize = t + 1;
    let ngroups = (n / gsize).max(1);
    let g = (i / gsize).min(ngroups - 1);
    let lo = g * gsize;
    let hi = if g == ngroups - 1 { n } else { lo + gsize };
    (lo..hi).collect()
}

/// Who client `me` sends encodings to (`targets`) and receives its own
/// encoding's shares from (`sources`) during the encode exchanges.
///
/// * footnote-4 subgroups ON: both are `me`'s subgroup — every client
///   encodes for its `T+1` group-mates (balanced NICs);
/// * OFF (the naive layout): the fixed reconstruction set `{0..T}`
///   computes encodings for everyone, so clients `≤ T` send to all `N`.
fn encode_roles(n: usize, t: usize, me: usize, subgroups: bool) -> (Vec<usize>, Vec<usize>) {
    if subgroups {
        let g = subgroup(n, t, me);
        (g.clone(), g)
    } else if me <= t {
        ((0..n).collect(), (0..=t).collect())
    } else {
        (Vec::new(), (0..=t).collect())
    }
}

struct ClientCtx {
    cfg: CopmlConfig,
    task: Arc<QuantizedTask>,
    kernel: Box<dyn GradKernel>,
}

/// One client's result of a full-protocol run.
pub struct ClientOutput {
    pub id: usize,
    /// Opened final model (field domain) — `None` if this client halted
    /// early (fault-plan kill, straggler exclusion, dead subgroup mate).
    pub w_final: Option<Vec<u64>>,
    /// Per-iteration share snapshot of `[w]` (for god-mode trace recovery;
    /// partial for halted clients).
    pub w_share_snapshots: Vec<Vec<u64>>,
    pub ledger: ClientLedger,
    /// Why the client stopped early, when it did.
    pub halted: Option<String>,
}

impl ClientOutput {
    /// Quality metrics of this client's opened model on the test split,
    /// dispatched through the configured workload (accuracy/AUC for the
    /// classifiers, R² for regression) — `None` if the client halted
    /// before the final opening.
    pub fn test_metrics(
        &self,
        cfg: &CopmlConfig,
        ds: &Dataset,
    ) -> Option<crate::ml::ModelMetrics> {
        let model = cfg.model.model();
        let w = model.decode(&cfg.plan, self.w_final.as_ref()?);
        Some(model.metrics(&ds.x_test, &ds.y_test, ds.d, ds.classes, &w))
    }
}

/// Run the full protocol. Spawns `cfg.n` client threads over the
/// in-process [`Hub`]; the PJRT engine (if selected) is hosted on a
/// [`KernelServer`] thread.
pub fn train(cfg: &CopmlConfig, ds: &Dataset) -> Result<ProtocolOutput, String> {
    cfg.validate(ds)?;
    let f = cfg.plan.field;

    // PJRT lives on its own thread; clients get Send handles. The server
    // (when used) must outlive the client threads, hence the Option slot.
    #[allow(unused_mut)]
    let mut _server: Option<KernelServer> = None;
    let kernel_par = cfg.parallelism;
    let kernel_tier = cfg.kernel;
    let mk_kernel: Box<dyn Fn() -> Box<dyn GradKernel>> = match cfg.engine {
        Engine::Native => {
            Box::new(move || Box::new(NativeKernel::with_tier(f, kernel_par, kernel_tier)))
        }
        #[cfg(feature = "pjrt")]
        Engine::Pjrt => {
            use crate::runtime::pjrt::PjrtRuntime;
            // Preflight the artifact load on a scratch thread (PjrtRuntime
            // is not Send, so it cannot be loaded here and moved into the
            // server). A load failure — missing artifacts, or the vendor
            // xla stub — surfaces as a clean Err instead of a panic
            // cascading across all N client threads.
            let dir = PjrtRuntime::default_dir();
            let probe_dir = dir.clone();
            std::thread::spawn(move || {
                PjrtRuntime::load(&probe_dir).map(|_| ()).map_err(|e| e.to_string())
            })
            .join()
            .map_err(|_| "PJRT preflight thread panicked".to_string())?
            .map_err(|e| format!("loading AOT artifacts (run `make artifacts`): {e}"))?;
            let server = KernelServer::spawn(move || {
                PjrtRuntime::load(&dir)
                    .expect("AOT artifacts loaded in preflight but failed in the kernel server")
            });
            let handle = server.handle();
            _server = Some(server);
            Box::new(move || Box::new(handle.clone()))
        }
        #[cfg(not(feature = "pjrt"))]
        Engine::Pjrt => {
            return Err(
                "engine 'pjrt' requires building with `--features pjrt` \
                 (this binary was built with the native engine only)"
                    .into(),
            )
        }
    };

    let endpoints = Hub::with_wire(cfg.n, cfg.wire);
    run_clients(cfg, ds, endpoints, &mk_kernel)
}

/// Run the full protocol with every client on its own TCP socket endpoint
/// over `127.0.0.1` ([`crate::net::tcp::loopback_mesh`]): separate
/// endpoints exchanging real framed bytes, same aggregation and god-mode
/// trace as [`train`]. Native engine only (the PJRT kernel server is a
/// single-process construct). Used by the equivalence tests and CI smoke.
pub fn train_tcp_loopback(cfg: &CopmlConfig, ds: &Dataset) -> Result<ProtocolOutput, String> {
    cfg.validate(ds)?;
    if !matches!(cfg.engine, Engine::Native) {
        return Err("tcp loopback training supports the native engine only".into());
    }
    let transports = crate::net::tcp::loopback_mesh_runtime(cfg.n, cfg.wire, cfg.runtime)
        .map_err(|e| format!("establishing the loopback TCP mesh: {e}"))?;
    let f = cfg.plan.field;
    let kernel_par = cfg.parallelism;
    let kernel_tier = cfg.kernel;
    let mk_kernel: Box<dyn Fn() -> Box<dyn GradKernel>> =
        Box::new(move || Box::new(NativeKernel::with_tier(f, kernel_par, kernel_tier)));
    run_clients(cfg, ds, transports, &mk_kernel)
}

/// Run ONE client of the full protocol over an already-established
/// transport — the distributed entry point (`copml party`). The offline
/// pool comes from `cfg.offline`'s provider: under `dealer` every process
/// replays its pool from `cfg.seed` (the paper's crypto-service-provider
/// runs offline; here it is replayed from the shared seed); under
/// `distributed` the processes generate it collectively over the mesh —
/// zero dealer involvement. Either way every process executes the same
/// SPMD sequence as the threaded [`train`], so a mesh of `run_client`
/// processes matches the Hub run for the same configuration
/// (bit-identically — both modes are deterministic per seed).
pub fn run_client(
    cfg: &CopmlConfig,
    ds: &Dataset,
    net: &dyn Transport,
) -> Result<ClientOutput, String> {
    cfg.validate(ds)?;
    if net.n() != cfg.n {
        return Err(format!("transport has {} parties but cfg.n = {}", net.n(), cfg.n));
    }
    if !matches!(cfg.engine, Engine::Native) {
        return Err("distributed clients support the native engine only".into());
    }
    let task = Arc::new(QuantizedTask::new(cfg, ds));
    let f = task.f;
    let demand = copml_demand(cfg, task.d, task.rows_padded, task.channels);
    let kernel: Box<dyn GradKernel> =
        Box::new(NativeKernel::with_tier(f, cfg.parallelism, cfg.kernel));
    let ctx = ClientCtx { cfg: cfg.clone(), task, kernel };
    Ok(client_session(net, ctx, &demand, None, None))
}

/// Provision one client's offline pool — pre-dealt (dealer, zero wire),
/// one-shot from the mode's provider, or the chunked factory pipeline
/// when `cfg.chunk` is set — then run the client body over it and fill
/// the ledger's offline row.
///
/// The pipelined arm runs the producer on a scoped thread: `seconds[0]`
/// gets only the consumer's feed-**stall** time (the offline seconds that
/// stayed on the critical path) and [`ClientLedger::offline_hidden_s`]
/// the producer's remaining generation time, hidden behind the online
/// rounds. With pipelining off, `seconds[0]` is the whole timed
/// generation and `offline_hidden_s` stays zero — the legacy single
/// number, bit-equal.
fn client_session(
    net: &dyn Transport,
    ctx: ClientCtx,
    demand: &Demand,
    predealt: Option<Offline>,
    trace: Option<Arc<SpmdTagTrace>>,
) -> ClientOutput {
    let cfg = ctx.cfg.clone();
    let f = ctx.task.f;
    let out = if let Some(pool) = predealt {
        // Crypto-service provider, pre-dealt by the caller: free on the
        // wire — the offline ledger row stays zero.
        let party = Party::new(net, cfg.t, f, pool, cfg.seed);
        if let Some(tr) = trace {
            party.set_tag_trace(tr);
        }
        client_main(&party, ctx)
    } else if let Some(chunk) = cfg.chunk {
        // Pipelined factory: the producer generates the chunk schedule on
        // a scoped thread while `client_main` consumes the pools.
        let bytes_mark = net.bytes_sent_offline();
        let (mut out, stats) = std::thread::scope(|scope| {
            let (pool, factory) = offline::start_factory(
                scope,
                net,
                f,
                cfg.t,
                demand,
                cfg.plan.k2,
                cfg.plan.kappa,
                cfg.seed,
                chunk,
                cfg.session,
            );
            let party = Party::new(net, cfg.t, f, pool, cfg.seed);
            if let Some(tr) = trace {
                party.set_tag_trace(tr);
            }
            let out = client_main(&party, ctx);
            // Join BEFORE any departure below: the producer's SPMD
            // schedule needs the live mesh (the peers' producers consume
            // our deal/open rounds) and always runs to completion.
            let stats = factory.stats();
            factory.join();
            (out, stats)
        });
        out.ledger.seconds[0] = stats.stall_seconds();
        out.ledger.offline_hidden_s = (stats.gen_seconds() - stats.stall_seconds()).max(0.0);
        out.ledger.bytes[0] = net.bytes_sent_offline() - bytes_mark;
        out
    } else {
        // One-shot offline phase, first on the same transport: the dealer
        // provider replays this party's pool from the shared seed (zero
        // traffic, bit-identical to `Dealer::deal(..)[id]`); the
        // distributed provider generates it collectively with the other
        // parties (DN07, real bytes — ledger phase 0).
        // copml-lint: allow(wall-clock) offline phase-ledger stamp: measures elapsed time, never steers protocol state
        let t0 = Instant::now();
        let bytes_mark = net.bytes_sent();
        let pool = cfg.offline.provider().provide(
            net,
            f,
            cfg.t,
            demand,
            cfg.plan.k2,
            cfg.plan.kappa,
            cfg.seed,
            cfg.session,
        );
        let offline_s = t0.elapsed().as_secs_f64();
        let offline_bytes = net.bytes_sent() - bytes_mark;
        let party = Party::new(net, cfg.t, f, pool, cfg.seed);
        if let Some(tr) = trace {
            party.set_tag_trace(tr);
        }
        let mut out = client_main(&party, ctx);
        out.ledger.seconds[0] = offline_s;
        out.ledger.bytes[0] = offline_bytes;
        out
    };
    if let Some(reason) = &out.halted {
        // Departure AFTER any factory join above: peers' receives blocked
        // on this party fail fast with the reason instead of stalling,
        // and our mailbox stops growing.
        net.leave(reason);
    }
    out
}

/// Spawn one client thread per transport endpoint, join, and aggregate:
/// final-model consensus, god-mode trace reconstruction from `T+1` share
/// snapshots, accuracy/loss traces. Transport-generic — [`train`] passes
/// Hub endpoints, [`train_tcp_loopback`] passes socket endpoints.
fn run_clients<T: Transport + Send + 'static>(
    cfg: &CopmlConfig,
    ds: &Dataset,
    transports: Vec<T>,
    mk_kernel: &dyn Fn() -> Box<dyn GradKernel>,
) -> Result<ProtocolOutput, String> {
    let task = Arc::new(QuantizedTask::new(cfg, ds));
    let f = task.f;
    let (n, t) = (cfg.n, cfg.t);
    assert_eq!(transports.len(), n, "one endpoint per client");
    let demand = copml_demand(cfg, task.d, task.rows_padded, task.channels);

    // Dealer mode pre-deals all pools in ONE pass here (the provider's
    // `deal_one` is for one-process-per-party runs — calling it from
    // every client thread would redo the full N-party share evaluation N
    // times). The distributed phase has no central shortcut: each thread
    // runs the DN07 protocol over its own endpoint (ledger phase 0).
    let predealt: Vec<Option<Offline>> = match cfg.offline {
        OfflineMode::Dealer => {
            Dealer::deal(f, n, t, &demand, cfg.plan.k2, cfg.plan.kappa, cfg.seed)
                .into_iter()
                .map(Some)
                .collect()
        }
        OfflineMode::Distributed => (0..n).map(|_| None).collect(),
    };

    // Cross-party SPMD fingerprint (debug builds): every in-process party
    // reports each tag allocation into one shared trace, so a divergence
    // panics at the divergent allocation with the step name instead of
    // surfacing as a 120 s receive timeout. See `net::tags::SpmdTagTrace`.
    let trace = if cfg!(debug_assertions) { Some(SpmdTagTrace::new(n)) } else { None };

    let mut handles = Vec::new();
    for (ep, dealt) in transports.into_iter().zip(predealt) {
        let ctx = ClientCtx { cfg: cfg.clone(), task: task.clone(), kernel: mk_kernel() };
        let demand = demand.clone();
        let trace = trace.clone();
        handles.push(std::thread::spawn(move || client_session(&ep, ctx, &demand, dealt, trace)));
    }
    let results = join_client_threads(handles)?;
    aggregate_outputs(cfg, ds, &task, trace.as_deref(), results)
}

/// Join the per-client threads, surfacing a client's own panic message
/// (e.g. a clear infeasibility cause) instead of a generic note.
fn join_client_threads<R>(handles: Vec<std::thread::JoinHandle<R>>) -> Result<Vec<R>, String> {
    handles
        .into_iter()
        .map(|h| {
            h.join().map_err(|e| {
                let msg = e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "no panic message".into());
                format!("client thread panicked: {msg}")
            })
        })
        .collect()
}

/// Aggregate per-client outputs into a [`ProtocolOutput`]: final-model
/// consensus, SPMD tag-trace convergence (debug builds), god-mode trace
/// reconstruction from `T+1` share snapshots, accuracy/loss traces.
/// Shared by the single-job paths ([`train`], [`train_tcp_loopback`]) and
/// the per-job aggregation of the serve daemon.
fn aggregate_outputs(
    cfg: &CopmlConfig,
    ds: &Dataset,
    task: &QuantizedTask,
    trace: Option<&SpmdTagTrace>,
    mut results: Vec<ClientOutput>,
) -> Result<ProtocolOutput, String> {
    let f = task.f;
    let (n, t) = (cfg.n, cfg.t);
    results.sort_by_key(|r| r.id);

    // Clients that ran to completion (under faults, the killed/excluded
    // ones halt early with a recorded reason). The god-mode trace needs
    // T+1 full snapshot sets; fewer completers means the run failed.
    let completers: Vec<&ClientOutput> = results.iter().filter(|r| r.w_final.is_some()).collect();
    if completers.len() < t + 1 {
        let reasons: Vec<String> = results
            .iter()
            .filter_map(|r| r.halted.as_ref().map(|h| format!("party {}: {h}", r.id)))
            .collect();
        return Err(format!(
            "only {} of {n} clients completed training (need ≥ T+1 = {}): {}",
            completers.len(),
            t + 1,
            reasons.join("; ")
        ));
    }

    // All completing clients must agree on the final model.
    for r in &completers[1..] {
        if r.w_final != completers[0].w_final {
            return Err("clients disagree on the final model".into());
        }
    }

    // End-of-run SPMD check (debug builds): every completer must have
    // walked the full agreed tag-allocation sequence — a shorter walk is
    // a divergence `record` alone cannot see. Halted clients legitimately
    // stop early and are exempt.
    if let Some(tr) = &trace {
        let done: Vec<usize> = completers.iter().map(|r| r.id).collect();
        tr.assert_converged(&done);
    }

    // God-mode trace: reconstruct w^{(t)} from T+1 completers' share
    // snapshots (any T+1 evaluation points interpolate exactly, so which
    // completers is immaterial).
    let lambdas = shamir::lambda_points(n);
    let pts: Vec<u64> = completers[..t + 1].iter().map(|r| lambdas[r.id]).collect();
    let rec = shamir::Reconstructor::new(f, &pts);
    let mut train = TrainOutput::default();
    for it in 0..cfg.model.model().trace_len(cfg.iters) {
        let views: Vec<&[u64]> = completers[..t + 1]
            .iter()
            .map(|r| r.w_share_snapshots[it].as_slice())
            .collect();
        let mut w = vec![0u64; task.width()];
        rec.reconstruct(f, &views, &mut w);
        train.w_trace.push(w);
    }
    // Consistency: reconstructed last iterate must equal the opened model.
    if train.w_trace.last() != completers[0].w_final.as_ref() {
        return Err("opened model disagrees with reconstructed trace".into());
    }
    train.eval_traces(cfg, ds);
    Ok(ProtocolOutput { train, ledgers: results.into_iter().map(|r| r.ledger).collect() })
}

/// Result of a multi-job serve run ([`serve`] / [`serve_tcp_loopback`]).
pub struct ServeOutput {
    /// Per-job protocol outputs, in job order (completed jobs only).
    pub jobs: Vec<ProtocolOutput>,
    /// First failed job, if any: `(job index, reason)`. The stream stops
    /// at the first failure — later jobs never run.
    pub failed: Option<(usize, String)>,
    /// Wall seconds of the whole serve run (first spawn to last join).
    pub wall_s: f64,
    /// Completed jobs per hour of wall time.
    pub jobs_per_hour: f64,
}

/// Job `j`'s configuration in a serve stream: seed `base + j` (a distinct
/// model per job) in tag session `j` ([`crate::net::tags`] SESSION
/// stripes, so the jobs' tag spaces are disjoint on the shared mesh).
/// Session ids renumber tags, never values — job `j` trains bit-identically
/// to a standalone run with seed `base + j`.
fn job_config(cfg: &CopmlConfig, j: usize) -> CopmlConfig {
    let mut c = cfg.clone();
    c.seed = cfg.seed.wrapping_add(j as u64);
    c.session = j as u64;
    c
}

/// Serve a stream of `jobs` training jobs over ONE in-process mesh: the
/// parties keep the [`Hub`] open and run job `j` in tag session `j` with
/// seed `base + j`, so steady-state jobs skip mesh setup — and, with the
/// pipelined factory on (`cfg.chunk`), job `j+1`'s offline pools fill
/// behind job `j`'s online rounds, hiding the cold-start offline wait.
/// Native engine only.
pub fn serve(cfg: &CopmlConfig, ds: &Dataset, jobs: usize) -> Result<ServeOutput, String> {
    if !matches!(cfg.engine, Engine::Native) {
        return Err("serve supports the native engine only".into());
    }
    let f = cfg.plan.field;
    let kernel_par = cfg.parallelism;
    let kernel_tier = cfg.kernel;
    let mk_kernel: Box<dyn Fn() -> Box<dyn GradKernel>> =
        Box::new(move || Box::new(NativeKernel::with_tier(f, kernel_par, kernel_tier)));
    let endpoints = Hub::with_wire(cfg.n, cfg.wire);
    run_serve_clients(cfg, ds, endpoints, jobs, &mk_kernel)
}

/// [`serve`] over real loopback TCP sockets
/// ([`crate::net::tcp::loopback_mesh`]): the mesh is established once and
/// every job in the stream reuses it. Native engine only.
pub fn serve_tcp_loopback(
    cfg: &CopmlConfig,
    ds: &Dataset,
    jobs: usize,
) -> Result<ServeOutput, String> {
    if !matches!(cfg.engine, Engine::Native) {
        return Err("serve supports the native engine only".into());
    }
    let transports = crate::net::tcp::loopback_mesh_runtime(cfg.n, cfg.wire, cfg.runtime)
        .map_err(|e| format!("establishing the loopback TCP mesh: {e}"))?;
    let f = cfg.plan.field;
    let kernel_par = cfg.parallelism;
    let kernel_tier = cfg.kernel;
    let mk_kernel: Box<dyn Fn() -> Box<dyn GradKernel>> =
        Box::new(move || Box::new(NativeKernel::with_tier(f, kernel_par, kernel_tier)));
    run_serve_clients(cfg, ds, transports, jobs, &mk_kernel)
}

/// Spawn one serve thread per endpoint, each running the whole job
/// stream, then regroup the party-major outputs job-major and aggregate
/// every job like a standalone run.
fn run_serve_clients<T: Transport + Send + 'static>(
    cfg: &CopmlConfig,
    ds: &Dataset,
    transports: Vec<T>,
    jobs: usize,
    mk_kernel: &dyn Fn() -> Box<dyn GradKernel>,
) -> Result<ServeOutput, String> {
    if jobs == 0 {
        return Err("serve needs at least one job".into());
    }
    let n = cfg.n;
    assert_eq!(transports.len(), n, "one endpoint per client");
    // Validate the whole stream up front: every job must fit its session
    // stripe before the mesh commits to the first one.
    let job_cfgs: Vec<CopmlConfig> = (0..jobs).map(|j| job_config(cfg, j)).collect();
    for (j, c) in job_cfgs.iter().enumerate() {
        c.validate(ds).map_err(|e| format!("job {j}: {e}"))?;
    }
    let tasks: Vec<Arc<QuantizedTask>> =
        job_cfgs.iter().map(|c| Arc::new(QuantizedTask::new(c, ds))).collect();
    let f = tasks[0].f;
    // Demand geometry depends on dataset shape and plan only — identical
    // across the stream's jobs (their seeds differ, not their shapes).
    let demand = copml_demand(cfg, tasks[0].d, tasks[0].rows_padded, tasks[0].channels);

    // Dealer mode pre-deals every job's pools up front (same one-pass
    // rationale as `run_clients`); distributed jobs generate over the
    // mesh, one-shot or chunked per `cfg.chunk`.
    let predealt: Vec<Vec<Option<Offline>>> = match cfg.offline {
        OfflineMode::Dealer => {
            let mut per_party: Vec<Vec<Option<Offline>>> = (0..n).map(|_| Vec::new()).collect();
            for c in &job_cfgs {
                let pools = Dealer::deal(f, n, c.t, &demand, c.plan.k2, c.plan.kappa, c.seed);
                for (p, pool) in pools.into_iter().enumerate() {
                    per_party[p].push(Some(pool));
                }
            }
            per_party
        }
        OfflineMode::Distributed => (0..n).map(|_| (0..jobs).map(|_| None).collect()).collect(),
    };

    // copml-lint: allow(wall-clock) serve throughput stopwatch: feeds the jobs/hour report, never steers protocol state
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (ep, pools) in transports.into_iter().zip(predealt) {
        let job_cfgs = job_cfgs.clone();
        let tasks = tasks.clone();
        let demand = demand.clone();
        let kernels: Vec<Box<dyn GradKernel>> = (0..jobs).map(|_| mk_kernel()).collect();
        handles.push(std::thread::spawn(move || {
            serve_client(&ep, &job_cfgs, &tasks, &demand, pools, kernels)
        }));
    }
    let per_party = join_client_threads(handles)?;
    let wall_s = t0.elapsed().as_secs_f64();

    // Regroup party-major → job-major. A party that halted on job `j`
    // stops its stream there, so later jobs can come up short of `n`.
    let mut streams: Vec<std::vec::IntoIter<ClientOutput>> =
        per_party.into_iter().map(Vec::into_iter).collect();
    let mut jobs_out: Vec<ProtocolOutput> = Vec::new();
    let mut failed: Option<(usize, String)> = None;
    for j in 0..jobs {
        let outs: Vec<ClientOutput> = streams.iter_mut().filter_map(|s| s.next()).collect();
        if outs.len() < n {
            failed = Some((
                j,
                format!(
                    "only {} of {n} parties reached job {j} (the stream stops at a \
                     predecessor's halt)",
                    outs.len()
                ),
            ));
            break;
        }
        match aggregate_outputs(&job_cfgs[j], ds, &tasks[j], None, outs) {
            Ok(out) => jobs_out.push(out),
            Err(e) => {
                failed = Some((j, e));
                break;
            }
        }
    }
    let done = jobs_out.len();
    let jobs_per_hour = if wall_s > 0.0 { done as f64 * 3600.0 / wall_s } else { 0.0 };
    Ok(ServeOutput { jobs: jobs_out, failed, wall_s, jobs_per_hour })
}

/// One party's serve loop: the whole job stream over a single long-lived
/// transport, one tag session per job. With pipelining on, job `j+1`'s
/// factory starts before job `j` trains, so its pools fill behind job
/// `j`'s online rounds and the steady-state jobs skip the cold-start
/// offline wait. The loop stops at the first halted job — after joining
/// any in-flight producer, so no factory ever outlives the live mesh.
fn serve_client(
    net: &dyn Transport,
    job_cfgs: &[CopmlConfig],
    tasks: &[Arc<QuantizedTask>],
    demand: &Demand,
    pools: Vec<Option<Offline>>,
    kernels: Vec<Box<dyn GradKernel>>,
) -> Vec<ClientOutput> {
    let f = tasks[0].f;
    let mut outs: Vec<ClientOutput> = Vec::new();
    if let Some(chunk) = job_cfgs[0].chunk {
        // Pipelined stream (distributed-only per `validate`): one scope
        // owns every job's producer thread.
        debug_assert!(pools.iter().all(Option::is_none), "chunked serve pre-deals nothing");
        std::thread::scope(|scope| {
            let mut kernels = kernels.into_iter();
            let mut next = Some(start_job_factory(scope, net, f, &job_cfgs[0], demand, chunk));
            for (j, cfgj) in job_cfgs.iter().enumerate() {
                let (pool, factory) = next.take().expect("factory prefetched for this job");
                let bytes_mark = net.bytes_sent_offline();
                // Prefetch job j+1's pools behind job j's online rounds —
                // disjoint tag sessions keep the streams unambiguous.
                if j + 1 < job_cfgs.len() {
                    next = Some(start_job_factory(scope, net, f, &job_cfgs[j + 1], demand, chunk));
                }
                let party = Party::new(net, cfgj.t, f, pool, cfgj.seed);
                let ctx = ClientCtx {
                    cfg: cfgj.clone(),
                    task: tasks[j].clone(),
                    kernel: kernels.next().expect("one kernel per job"),
                };
                let mut out = client_main(&party, ctx);
                let stats = factory.stats();
                factory.join();
                out.ledger.seconds[0] = stats.stall_seconds();
                out.ledger.offline_hidden_s =
                    (stats.gen_seconds() - stats.stall_seconds()).max(0.0);
                // Approximate per-job attribution: the delta also counts
                // whatever the j+1 prefetch sent during job j.
                out.ledger.bytes[0] = net.bytes_sent_offline() - bytes_mark;
                let halted = out.halted.clone();
                outs.push(out);
                if let Some(reason) = halted {
                    // Join the prefetched producer BEFORE leaving: its
                    // SPMD schedule needs the live mesh and always runs
                    // to completion.
                    if let Some((_, prefetched)) = next.take() {
                        prefetched.join();
                    }
                    net.leave(&reason);
                    break;
                }
            }
        });
        outs
    } else {
        // Sequential stream: each job provisions its pool on entry
        // (pre-dealt under dealer mode, one-shot DN07 under distributed);
        // `client_session` departs the mesh itself on a halt.
        for (j, ((cfgj, kernel), pool)) in job_cfgs.iter().zip(kernels).zip(pools).enumerate() {
            let ctx = ClientCtx { cfg: cfgj.clone(), task: tasks[j].clone(), kernel };
            let out = client_session(net, ctx, demand, pool, None);
            let halted = out.halted.is_some();
            outs.push(out);
            if halted {
                break;
            }
        }
        outs
    }
}

/// Start the chunked offline factory for one serve job on `scope`: the
/// producer deals in the job's tag session from the job's seed.
fn start_job_factory<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    net: &'env dyn Transport,
    f: Field,
    cfg: &CopmlConfig,
    demand: &Demand,
    chunk: usize,
) -> (Offline, offline::FactoryHandle<'scope>) {
    offline::start_factory(
        scope,
        net,
        f,
        cfg.t,
        demand,
        cfg.plan.k2,
        cfg.plan.kappa,
        cfg.seed,
        chunk,
        cfg.session,
    )
}

/// Padded per-client row ranges (padding rows belong to the last client,
/// which shares zeros for them — inert in the gradient).
pub(crate) fn padded_ranges(rows_padded: usize, n: usize) -> Vec<(usize, usize)> {
    let base = rows_padded / n;
    let extra = rows_padded % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0;
    for j in 0..n {
        let len = base + usize::from(j < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// The quorum leader. Party 0 gathers the first-arrival result quorum and
/// broadcasts its composition (plus any straggler exclusions), so every
/// live party decodes from the *same* subset — without agreement the
/// decoded gradient *secrets* would still match (interpolation is exact),
/// but the parties' shares would sit on different polynomials and the
/// next opening would reconstruct garbage. Party 0 is already the king of
/// every opening, so this adds no new trust or fail-over assumption.
const QUORUM_LEADER: usize = 0;

/// Wire layout of the per-round roster message from the quorum leader:
/// `[member_count, members…, excluded_count, excluded…]`.
fn encode_roster_msg(members: &[usize], excluded: &[usize]) -> Vec<u64> {
    let mut msg = Vec::with_capacity(2 + members.len() + excluded.len());
    msg.push(members.len() as u64);
    msg.extend(members.iter().map(|&j| j as u64));
    msg.push(excluded.len() as u64);
    msg.extend(excluded.iter().map(|&j| j as u64));
    msg
}

/// Parse a roster message; `n` bounds the party ids. `pub(crate)` so the
/// follower round state ([`AwaitQuorumRoster`]) parses announcements the
/// moment they arrive.
pub(crate) fn decode_roster_msg(msg: &[u64], n: usize) -> Result<(Vec<usize>, Vec<usize>), String> {
    let take = |slice: &[u64], what: &str| -> Result<(Vec<usize>, usize), String> {
        let count = *slice.first().ok_or_else(|| format!("roster message truncated ({what})"))?
            as usize;
        // Bound via subtraction (len ≥ 1 here): `1 + count` would wrap
        // for a corrupt count of usize::MAX and bypass the guard.
        if slice.len() - 1 < count {
            return Err(format!("roster message truncated ({what}: {count} entries)"));
        }
        let ids: Vec<usize> = slice[1..1 + count].iter().map(|&v| v as usize).collect();
        if let Some(&bad) = ids.iter().find(|&&id| id >= n) {
            return Err(format!("roster message names party {bad} of {n}"));
        }
        Ok((ids, 1 + count))
    };
    let (members, used) = take(msg, "members")?;
    let (excluded, used2) = take(&msg[used..], "exclusions")?;
    if used + used2 != msg.len() {
        return Err("roster message has trailing data".into());
    }
    // The leader emits both lists strictly ascending; enforcing it here
    // rejects duplicates (a repeated member id would double-consume a
    // single result share and deadlock the gather) the same graceful way
    // as every other malformed-roster case.
    for (ids, what) in [(&members, "members"), (&excluded, "exclusions")] {
        if ids.windows(2).any(|w| w[0] >= w[1]) {
            return Err(format!("roster message {what} not strictly ascending"));
        }
    }
    if excluded.contains(&0) {
        return Err("roster message excludes party 0 (the king / quorum leader)".into());
    }
    Ok((members, excluded))
}

fn client_main(party: &Party, ctx: ClientCtx) -> ClientOutput {
    let me = party.id;
    let mut ledger = ClientLedger::default();
    let mut snapshots: Vec<Vec<u64>> = Vec::with_capacity(ctx.cfg.iters);
    let online = client_run(party, &ctx, &mut ledger, &mut snapshots);
    let (w_final, halted) = match online {
        Ok(w) => (Some(w), None),
        Err(reason) => (None, Some(reason)),
    };
    ledger.pending_at_exit = party.net.pending_messages();
    ledger.tag_reuse = party.net.tag_reuse();
    ClientOutput { id: me, w_final, w_share_snapshots: snapshots, ledger, halted }
}

/// Bytes this party has sent on ONLINE tags: the transport total minus
/// the OFFLINE-tagged traffic. The ledger's phase rows 1..8 charge online
/// bytes only, so a concurrently producing offline factory never blends
/// into them — and with pipelining off the offline counter is constant
/// while the online phases run, leaving every row bit-equal to the legacy
/// total-bytes accounting.
fn online_bytes(party: &Party) -> u64 {
    party.net.bytes_sent() - party.net.bytes_sent_offline()
}

/// The fallible SPMD body of one client: every phase of Algorithm 1 from
/// dataset sharing to the final opening, ticking `ledger` and pushing the
/// per-iteration `[w]` snapshots. Returns the opened final model, or the
/// halt reason — a fault-plan kill, an infeasible quorum, or an exhausted
/// offline pool ([`crate::mpc::OfflineError`] surfaces here as a typed
/// halt instead of a panic, so a serve daemon degrades rather than
/// crashes).
fn client_run(
    party: &Party,
    ctx: &ClientCtx,
    ledger: &mut ClientLedger,
    snapshots: &mut Vec<Vec<u64>>,
) -> Result<Vec<u64>, String> {
    let cfg = &ctx.cfg;
    let task = &ctx.task;
    let f = task.f;
    let me = party.id;
    let (n, t, k) = (cfg.n, cfg.t, cfg.k);
    let (rows, d) = (task.rows_padded, task.d);
    let (channels, width) = (task.channels, task.width());
    let plan_b = &task.batches;
    struct PhaseTimer {
        start: Instant,
        bytes_mark: u64,
    }
    impl PhaseTimer {
        fn reset(&mut self, party: &Party) {
            // copml-lint: allow(wall-clock) phase-ledger stamp: measures elapsed time, never steers protocol state
            self.start = Instant::now();
            self.bytes_mark = online_bytes(party);
        }
        fn tick(&mut self, ledger: &mut ClientLedger, phase: usize, party: &Party) {
            ledger.seconds[phase] += self.start.elapsed().as_secs_f64();
            ledger.bytes[phase] += online_bytes(party) - self.bytes_mark;
            self.reset(party);
        }
    }
    // copml-lint: allow(wall-clock) phase-ledger start stamp: measures elapsed time, never steers protocol state
    let mut timer = PhaseTimer { start: Instant::now(), bytes_mark: online_bytes(party) };

    // All protocol tags come from the typed session windows of
    // `net::tags` (session 0 ≡ the legacy layout); the seeks below are
    // SPMD steps every party performs at the same point.
    party.seek_tags(tags::session_setup(cfg.session));

    // ---- Phase: share the dataset (Algorithm 1, lines 1–3) -------------
    // Labels travel channel-major: one `share.y` message per peer holding
    // this party's row range for every gradient channel back to back —
    // byte-identical to the legacy single-channel payload for the seed
    // workload.
    let ranges = padded_ranges(rows, n);
    let (lo, hi) = ranges[me];
    let my_x = &task.x_q[lo * d..hi * d];
    let my_y: Vec<u64> = (0..channels)
        .flat_map(|c| task.y_channel(c)[lo..hi].iter().copied())
        .collect();
    let tag_x = party.tag("share.x");
    let tag_y = party.tag("share.y");
    let own_x = party.share_out(my_x, tag_x);
    let own_y = party.share_out(&my_y, tag_y);
    // Assemble [X]_me, [y]_me in global row order ([y] keeps the task's
    // class-major layout: channel c of row i at c·rows + i).
    let mut x_share = vec![0u64; rows * d];
    let mut y_share = vec![0u64; channels * rows];
    for (j, &(jl, jh)) in ranges.iter().enumerate() {
        let (xs, ys) = if j == me {
            (own_x.clone(), own_y.clone())
        } else {
            (party.net.recv(j, tag_x), party.net.recv(j, tag_y))
        };
        x_share[jl * d..jh * d].copy_from_slice(&xs);
        let seg = jh - jl;
        for c in 0..channels {
            y_share[c * rows + jl..c * rows + jh].copy_from_slice(&ys[c * seg..(c + 1) * seg]);
        }
    }
    timer.tick(ledger, 1, party);

    // ---- Closed-form workload: one secure normal-equations round --------
    // Instead of phases 3–6, the moments XᵀX and Xᵀy are computed as
    // degree-2T products of the dataset shares, pay ONE concatenated BH08
    // reduction (d² + d elements), and are opened; every party then runs
    // the identical public dequantize → ridge solve → requantize, so the
    // result "share" is the public β itself (a constant polynomial — any
    // T+1 interpolate it exactly, which keeps the aggregation and
    // god-mode trace machinery unchanged).
    if !cfg.model.model().iterative() {
        let mut moments = vec![0u64; d * (d + 1)];
        for i in 0..rows {
            let row = &x_share[i * d..(i + 1) * d];
            for j in 0..d {
                let xj = row[j];
                for jj in 0..d {
                    moments[j * d + jj] = f.add(moments[j * d + jj], f.mul(xj, row[jj]));
                }
                moments[d * d + j] = f.add(moments[d * d + j], f.mul(xj, y_share[i]));
            }
        }
        // deg 2T → deg T: d(d+1) doubles, the demand's whole pool.
        let reduced = party.degree_reduce_bh08(&moments).map_err(|e| e.to_string())?;
        timer.tick(ledger, 2, party);
        party.seek_tags(tags::session_final(cfg.session));
        let opened = party.open_broadcast(&reduced, t);
        let scale = 2 * cfg.plan.lx;
        let mut xtx = crate::quant::dequantize_slice(f, &opened[..d * d], scale);
        let mut xty = crate::quant::dequantize_slice(f, &opened[d * d..], scale);
        let beta = crate::ml::model::solve_normal_equations(&mut xtx, &mut xty, d);
        let w_q = crate::quant::quantize_slice(f, &beta, cfg.plan.lw);
        snapshots.push(w_q.clone());
        timer.tick(ledger, 7, party);
        return Ok(w_q);
    }

    // ---- Phase: per-batch [Xᵀ_b y_b], aligned (Algorithm 1, line 10) ----
    // All B local products are concatenated into one (B·d)-vector and pay
    // a single BH08 degree reduction — one protocol round regardless of B
    // (for B = 1 this is byte-identical to the classic full-batch phase).
    let pp = cfg.parallelism;
    let tier = cfg.kernel;
    let nb = plan_b.b;
    let mut local = vec![0u64; nb * width];
    for (bi, &(blo, bhi)) in plan_b.ranges().iter().enumerate() {
        let sh = MatShape::new(bhi - blo, d);
        for c in 0..channels {
            let lb = par::matvec_t_tier(
                f,
                tier,
                pp,
                &x_share[blo * d..bhi * d],
                sh,
                &y_share[c * rows + blo..c * rows + bhi],
            ); // deg 2T
            local[bi * width + c * d..bi * width + (c + 1) * d].copy_from_slice(&lb);
        }
    }
    // deg T, B·G doubles (batch-major, class-major within each batch)
    let mut xty_all = party.degree_reduce_bh08(&local).map_err(|e| e.to_string())?;
    let align = f.reduce(1u64 << (cfg.plan.lc + cfg.plan.lx + cfg.plan.lw));
    party.scale(&mut xty_all, align);
    let xty: Vec<Vec<u64>> =
        (0..nb).map(|bi| xty_all[bi * width..(bi + 1) * width].to_vec()).collect();
    drop(xty_all);
    timer.tick(ledger, 2, party);

    // ---- Phase: Lagrange-encode the dataset, once per batch (Eq. 3;
    // lines 5–9) ----------------------------------------------------------
    // Every batch is encoded ONE time here and reused by every epoch that
    // revisits it — the one-shot amortization that makes mini-batch
    // training pay the encode exchange exactly as often as full-batch
    // does. Each batch seeks its own `tags::session_encode_window(s, b)`;
    // all parties iterate batches in the same order, so the SPMD tag
    // sequence stays aligned.
    let enc = lcc::Encoder::standard(f, k, t, n);
    let (targets, sources) = encode_roles(n, t, me, cfg.subgroups);
    let source_pts: Vec<u64> = sources.iter().map(|&i| party.lambdas[i]).collect();
    let mut rec = shamir::Reconstructor::new(f, &source_pts);
    let mut x_tildes: Vec<Vec<u64>> = Vec::with_capacity(nb);
    let mut shapes_k: Vec<MatShape> = Vec::with_capacity(nb);
    for (bidx, &(blo, bhi)) in plan_b.ranges().iter().enumerate() {
        party.seek_tags(tags::session_encode_window(cfg.session, bidx));
        let rows_bk = (bhi - blo) / k;
        // Partition [X_b] into K parts + T mask shares from the offline
        // pool (per-batch masks — the Demand charges Σ_b rows_b/K once).
        let parts: Vec<&[u64]> = (0..k)
            .map(|kk| &x_share[(blo + kk * rows_bk) * d..(blo + (kk + 1) * rows_bk) * d])
            .collect();
        let masks: Vec<Vec<u64>> = (0..t)
            .map(|_| party.random_share(rows_bk * d))
            .collect::<Result<_, _>>()
            .map_err(|e| e.to_string())?;
        let all_parts: Vec<&[u64]> =
            parts.into_iter().chain(masks.iter().map(|m| m.as_slice())).collect();
        let tag_xenc = party.tag("encode.x");
        // Compute and send [X̃_{b,i}]_me for every target i.
        let mut own_enc_share: Option<Vec<u64>> = None;
        for &i in &targets {
            let mut buf = vec![0u64; rows_bk * d];
            enc.encode_one_tier(tier, pp, i, &all_parts, &mut buf);
            if i == me {
                own_enc_share = Some(buf);
            } else {
                party.net.send(i, tag_xenc, buf);
            }
        }
        // Reconstruct my encoded matrix X̃_{b,me} from the sources' shares.
        let enc_shares: Vec<Vec<u64>> = sources
            .iter()
            .map(|&i| {
                if i == me {
                    own_enc_share.take().unwrap()
                } else {
                    party.net.recv(i, tag_xenc)
                }
            })
            .collect();
        let views: Vec<&[u64]> = enc_shares.iter().map(|v| v.as_slice()).collect();
        let mut x_tilde = vec![0u64; rows_bk * d];
        rec.reconstruct(f, &views, &mut x_tilde);
        x_tildes.push(x_tilde);
        shapes_k.push(MatShape::new(rows_bk, d));
    }
    drop(x_share);
    timer.tick(ledger, 3, party);

    // Precompute: model-encoding coefficient rows (Eq. 4 — the K data
    // slots all carry [w], so their coefficients collapse to a row sum).
    let (betas, alphas) = poly::standard_points(k + t, n);
    let enc_rows = poly::coeff_matrix(f, &betas, &alphas);
    let w_data_coeff: Vec<u64> = enc_rows
        .iter()
        .map(|row| row[..k].iter().fold(0u64, |acc, &c| f.add(acc, c)))
        .collect();
    // Per-quorum decoder factory: the aggregate gradient decodes from
    // whichever `need` clients answer first — any such subset
    // interpolates the same value bit for bit (Theorem 1), so the
    // trajectory does not depend on quorum composition.
    let need = cfg.recovery_threshold();
    let deg_f = 2 * cfg.r + 1;
    let mut dec_cache = lcc::DecoderCache::new(f, k, t, deg_f, alphas.clone(), betas.clone());

    // Fault plan (straggler experiments): this party's injected
    // compute-phase delay and kill point, if any.
    let delay = cfg.faults.delay_ms(me).map(std::time::Duration::from_millis);
    let kill_at = cfg.faults.kill_at(me);
    // Straggler bookkeeping (quorum leader). `misses[j]` counts j's
    // consecutive quorum absences; every party applies the leader's
    // announced exclusions. The leader resolves round i's late set at
    // round i+1 (`pending_late`): a full round of grace, so a healthy
    // party that loses the first-arrival race by scheduler jitter has
    // long delivered by resolution time and never counts as a miss —
    // only parties lagging a whole round (or dead) accumulate misses.
    let mut misses = vec![0usize; n];
    let mut pending_late: Vec<usize> = Vec::new();
    let mut pending_tag: u64 = 0;
    // Live members of `sources`, tracked so the model-encode
    // reconstructor is rebuilt only when exclusions change it.
    let mut rec_sources: Vec<usize> = sources.clone();

    let mut w_share = vec![0u64; width]; // shares of w^(0) = 0

    timer.reset(party);
    (|| -> Result<Vec<u64>, String> {
        for iter in 0..cfg.iters {
            if kill_at == Some(iter) {
                return Err(format!("killed at iteration {iter} by the fault plan"));
            }
            // Every tag of this round comes from the iteration's own
            // ROUND_STRIDE-wide window — disjoint from every other round
            // by construction (`net::tags`).
            party.seek_tags(tags::session_round_window(cfg.session, iter));
            // One-line runtime marker (grep-asserted by CI): the iteration
            // loop below runs through the explicit per-round states of
            // `coordinator::rounds` under either runtime.
            if me == QUORUM_LEADER && iter == 0 {
                println!("round-state: party {me} iter {iter} runtime={}", cfg.runtime);
            }
            // Mini-batch schedule: iteration i trains on batch i mod B
            // (bit-identical across algo mode, both transports, and the
            // baselines — the schedule is pure arithmetic on `iter`).
            let bi = plan_b.batch_of_iter(iter);
            // Roster-adjusted encode roles for this round. Reconstruction
            // from any T+1 of the original sources is exact, so losing a
            // source is harmless until fewer than T+1 remain.
            let live_targets: Vec<usize> =
                targets.iter().copied().filter(|&j| party.is_live(j)).collect();
            let cur_sources: Vec<usize> =
                sources.iter().copied().filter(|&j| party.is_live(j)).collect();
            if cur_sources.len() < t + 1 {
                return Err(format!(
                    "subgroup reconstruction infeasible: only {} of {} encode sources \
                     live (need T+1 = {})",
                    cur_sources.len(),
                    sources.len(),
                    t + 1
                ));
            }
            // ---- encode the model (Eq. 4; lines 12–15) ------------------
            // The whole G-vector [w] (class-major) encodes in one pass:
            // masks, payloads, and message counts all scale by `channels`
            // with the tag sequence unchanged.
            let vmasks: Vec<Vec<u64>> = (0..t)
                .map(|_| party.random_share(width))
                .collect::<Result<_, _>>()
                .map_err(|e| e.to_string())?;
            let tag_wenc = party.tag("encode.w");
            let mut own_wenc: Option<Vec<u64>> = None;
            for &i in &live_targets {
                let mut buf = w_share.clone();
                party.scale(&mut buf, w_data_coeff[i]);
                for (kk, vm) in vmasks.iter().enumerate() {
                    let c = enc_rows[i][k + kk];
                    for (b, &v) in buf.iter_mut().zip(vm) {
                        *b = f.reduce(*b + c * v);
                    }
                }
                if i == me {
                    own_wenc = Some(buf);
                } else {
                    party.net.send(i, tag_wenc, buf);
                }
            }
            // Gather from the live sources, SKIPPING any that died since
            // the roster was last updated (exclusion lags death detection
            // by up to a round): any T+1 of the group's shares
            // reconstruct the encoding exactly, so a dead mate is only
            // fatal once fewer than T+1 sources actually answer.
            let mut got_sources: Vec<usize> = Vec::with_capacity(cur_sources.len());
            let mut wenc_shares: Vec<Vec<u64>> = Vec::with_capacity(cur_sources.len());
            for &i in &cur_sources {
                if i == me {
                    got_sources.push(i);
                    wenc_shares.push(own_wenc.take().unwrap());
                } else {
                    match party.net.recv_check(i, tag_wenc) {
                        Ok(s) => {
                            got_sources.push(i);
                            wenc_shares.push(s);
                        }
                        Err(_) => {} // freshly dead: skip while enough remain
                    }
                }
            }
            if got_sources.len() < t + 1 {
                return Err(format!(
                    "subgroup reconstruction infeasible: only {} of {} encode sources \
                     answered (need T+1 = {})",
                    got_sources.len(),
                    sources.len(),
                    t + 1
                ));
            }
            if got_sources != rec_sources {
                let pts: Vec<u64> = got_sources.iter().map(|&i| party.lambdas[i]).collect();
                rec = shamir::Reconstructor::new(f, &pts);
                rec_sources = got_sources;
            }
            let views: Vec<&[u64]> = wenc_shares.iter().map(|v| v.as_slice()).collect();
            let mut w_tilde = vec![0u64; width];
            rec.reconstruct(f, &views, &mut w_tilde);
            timer.tick(ledger, 4, party);

            // ---- local encoded gradient (Eq. 7; line 16) ----------------
            // The round's batch: compute scales with rows_b/K instead of
            // rows/K — the mini-batch speedup (decode and every other
            // per-round exchange below stay d-sized).
            let f_mine =
                ctx.kernel.encoded_gradient(&x_tildes[bi], shapes_k[bi], &w_tilde, &task.coeffs_q);
            if let Some(dl) = delay {
                std::thread::sleep(dl); // injected straggler (fault plan)
            }
            timer.tick(ledger, 5, party);

            // ---- share the result + first-arrival quorum (line 16b) -----
            let tag_res = party.tag("round.res");
            let tag_roster = party.tag("round.roster");
            let own_res = party.share_out(&f_mine, tag_res);
            let live_now = party.live_ids();
            let mut newly_excluded: Vec<usize> = Vec::new();
            let (members, result_shares) = if live_now.len() > need {
                if me == QUORUM_LEADER {
                    let peers: Vec<usize> =
                        live_now.iter().copied().filter(|&j| j != me).collect();
                    let out =
                        drive(party.net, AwaitEncodedGradients::new(me, &peers, tag_res, need, own_res))
                            .map_err(|e| format!("encoded-gradient gather: {e}"))?;
                    // Resolve the PREVIOUS round's late set, one round of
                    // grace later: delivered by now → keeping pace;
                    // still absent → a genuine miss.
                    for &j in &pending_late {
                        let arrived = party.net.forget(j, pending_tag);
                        if !party.is_live(j) {
                            continue;
                        }
                        if arrived {
                            misses[j] = 0;
                        } else {
                            misses[j] += 1;
                            if cfg.max_lag.map_or(false, |lag| misses[j] >= lag) {
                                newly_excluded.push(j);
                            }
                        }
                    }
                    for &j in &out.members {
                        misses[j] = 0;
                    }
                    // Never exclude below the recovery threshold: with
                    // more offenders than slack, the excess stays on
                    // probation (their miss counts keep them first in
                    // line next round).
                    newly_excluded.truncate(live_now.len().saturating_sub(need));
                    pending_late = out.late.clone();
                    pending_tag = tag_res;
                    let msg = encode_roster_msg(&out.members, &newly_excluded);
                    for &j in &peers {
                        party.net.send(j, tag_roster, msg.clone());
                    }
                    (out.members, out.payloads)
                } else {
                    let (m, x) =
                        drive(party.net, AwaitQuorumRoster::new(QUORUM_LEADER, tag_roster, n))?;
                    newly_excluded = x;
                    if newly_excluded.contains(&me) {
                        return Err(format!(
                            "excluded by the quorum leader after missing {} consecutive \
                             quorums (--max-lag)",
                            cfg.max_lag.unwrap_or(0)
                        ));
                    }
                    let shares =
                        drive(party.net, AwaitQuorumShares::new(me, &m, tag_res, own_res))?;
                    // Skip the non-members' results: already-arrived ones
                    // are dropped now, in-flight ones on arrival.
                    for &j in &live_now {
                        if j != me && !m.contains(&j) {
                            party.net.forget(j, tag_res);
                        }
                    }
                    (m, shares)
                }
            } else {
                // No slack: every live result is needed — fixed-order
                // gather, identical to the pre-quorum protocol while the
                // roster is full (no roster message on the wire).
                let shares =
                    drive(party.net, AwaitAllResults::new(me, &live_now, tag_res, own_res))?;
                (live_now.clone(), shares)
            };
            ledger.quorums.push(members.clone());
            for &j in &newly_excluded {
                party.exclude(j);
                ledger.excluded.push(j);
            }
            if party.live_count() < need {
                return Err(format!(
                    "exclusions dropped the roster below the recovery threshold: \
                     {} live < {need} needed",
                    party.live_count()
                ));
            }
            timer.tick(ledger, 6, party);

            // ---- decode + model update (Eq. 10–11; lines 18–23) ---------
            let views: Vec<&[u64]> = result_shares.iter().map(|v| v.as_slice()).collect();
            let mut grad = vec![0u64; width];
            dec_cache.get(&members).decode_sum_tier(tier, pp, &views, &mut grad);
            party.sub(&mut grad, &xty[bi]);
            let mut g1 = party
                .trunc_pr(&grad, cfg.plan.k2, cfg.plan.k1_stage1(), cfg.plan.kappa, true)
                .map_err(|e| e.to_string())?;
            party.scale(&mut g1, task.eta_qs[bi]);
            let g2 = party
                .trunc_pr(&g1, cfg.plan.k2, cfg.plan.k1_stage2(), cfg.plan.kappa, true)
                .map_err(|e| e.to_string())?;
            party.sub(&mut w_share, &g2);
            snapshots.push(w_share.clone());
            timer.tick(ledger, 7, party);
        }

        // Leader: resolve the final round's late set (skip-on-arrival
        // tombstones) so clean runs exit with an empty mailbox. FIFO
        // ordering guarantees the stragglers' last result shares land
        // before their final-open broadcasts below, clearing the
        // tombstones before exit.
        for &j in &pending_late {
            party.net.forget(j, pending_tag);
        }

        // ---- final: open the model (lines 25–27) ------------------------
        party.seek_tags(tags::session_final(cfg.session));
        Ok(party.open_broadcast(&w_share, t))
    })()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CaseParams;
    use crate::data::SynthSpec;

    #[test]
    fn subgroups_cover_and_have_threshold_size() {
        for (n, t) in [(10usize, 1usize), (12, 2), (13, 3), (50, 7)] {
            for i in 0..n {
                let g = subgroup(n, t, i);
                assert!(g.len() >= t + 1, "n={n} t={t} i={i}: {g:?}");
                assert!(g.contains(&i));
            }
        }
    }

    #[test]
    fn encode_roles_are_consistent() {
        // Every (sender → receiver) edge implied by `targets` must appear
        // in the receiver's `sources`, and vice versa — no deadlock.
        for subgroups in [true, false] {
            for (n, t) in [(7usize, 1usize), (11, 2), (13, 3)] {
                let roles: Vec<_> =
                    (0..n).map(|i| encode_roles(n, t, i, subgroups)).collect();
                for me in 0..n {
                    for &dst in &roles[me].0 {
                        assert!(
                            roles[dst].1.contains(&me),
                            "edge {me}→{dst} missing in sources (subgroups={subgroups})"
                        );
                    }
                    for &src in &roles[me].1 {
                        assert!(
                            roles[src].0.contains(&me),
                            "source {src} of {me} does not target it (subgroups={subgroups})"
                        );
                    }
                    assert!(roles[me].1.len() >= t + 1, "need t+1 shares");
                }
            }
        }
    }

    #[test]
    fn roster_msg_round_trip() {
        for (members, excluded) in [
            (vec![0usize, 2, 5, 7], vec![3usize]),
            (vec![0, 1, 2], vec![]),
            (vec![], vec![4, 6]),
        ] {
            let msg = encode_roster_msg(&members, &excluded);
            let (m, x) = decode_roster_msg(&msg, 8).unwrap();
            assert_eq!(m, members);
            assert_eq!(x, excluded);
        }
    }

    #[test]
    fn roster_msg_rejects_malformed() {
        assert!(decode_roster_msg(&[], 8).is_err(), "empty");
        assert!(decode_roster_msg(&[3, 0, 1], 8).is_err(), "truncated member list");
        assert!(decode_roster_msg(&[1, 0], 8).is_err(), "missing exclusion count");
        assert!(decode_roster_msg(&[1, 9, 0], 8).is_err(), "member id out of range");
        assert!(decode_roster_msg(&[u64::MAX, 0], 8).is_err(), "wrapping member count");
        assert!(decode_roster_msg(&[2, 3, 3, 0], 8).is_err(), "duplicate member id");
        assert!(decode_roster_msg(&[2, 3, 1, 0], 8).is_err(), "unsorted members");
        assert!(decode_roster_msg(&[1, 2, 1, 0], 8).is_err(), "excluding the king");
        let mut msg = encode_roster_msg(&[0, 1], &[2]);
        msg.push(7);
        assert!(decode_roster_msg(&msg, 8).is_err(), "trailing data");
    }

    #[test]
    fn padded_ranges_partition() {
        let r = padded_ranges(100, 7);
        assert_eq!(r[0].0, 0);
        assert_eq!(r[6].1, 100);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn run_client_mesh_matches_train() {
        // The distributed entry point: every party independently derives
        // its dealer pool from the shared seed and runs over its own TCP
        // endpoint — all must open the model `train` computes.
        let ds = Dataset::synth(SynthSpec::tiny(), 22);
        let mut cfg =
            super::super::CopmlConfig::for_dataset(&ds, 4, CaseParams::explicit(1, 1), 22);
        cfg.iters = 2;
        let reference = train(&cfg, &ds).unwrap();
        let transports = crate::net::tcp::loopback_mesh(cfg.n, cfg.wire).unwrap();
        let handles: Vec<_> = transports
            .into_iter()
            .map(|net| {
                let cfg = cfg.clone();
                let ds = ds.clone();
                std::thread::spawn(move || run_client(&cfg, &ds, &net).unwrap())
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            assert_eq!(
                out.w_final.expect("client must complete"),
                *reference.train.w_trace.last().unwrap()
            );
        }
    }

    #[test]
    fn full_protocol_matches_algo_mode_tiny() {
        // The headline invariant: threaded protocol ≡ central recursion,
        // bit for bit. (The large-config version lives in
        // tests/protocol_equivalence.rs.)
        let ds = Dataset::synth(SynthSpec::tiny(), 21);
        let mut cfg = super::super::CopmlConfig::for_dataset(&ds, 7, CaseParams::explicit(2, 1), 21);
        cfg.iters = 4;
        let algo = super::super::algo::train(&cfg, &ds).unwrap();
        let full = train(&cfg, &ds).unwrap();
        assert_eq!(algo.w_trace, full.train.w_trace);
    }

    #[test]
    fn full_protocol_matches_algo_mode_minibatch_tiny() {
        // Same invariant under the mini-batch pipeline: per-batch one-shot
        // encodings, the concatenated Xᵀ_b y_b reduction, and the cyclic
        // schedule must leave protocol ≡ algo bit for bit.
        let ds = Dataset::synth(SynthSpec::tiny(), 23);
        let mut cfg =
            super::super::CopmlConfig::for_dataset(&ds, 7, CaseParams::explicit(2, 1), 23);
        cfg.iters = 6;
        cfg.batches = 3;
        let algo = super::super::algo::train(&cfg, &ds).unwrap();
        let full = train(&cfg, &ds).unwrap();
        assert_eq!(algo.w_trace, full.train.w_trace);
    }
}
