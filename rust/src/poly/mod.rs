//! Polynomial interpolation over `F_p` — the engine behind Shamir secret
//! sharing and Lagrange coded computing.
//!
//! Everything COPML encodes or decodes is a univariate polynomial evaluated
//! at public points: secret shares are evaluations of random degree-`T`
//! polynomials (paper Phase 2), encoded datasets/models are evaluations of
//! the degree-`K+T−1` Lagrange polynomials `u(z)`, `v(z)` (Eqs. 3–4), and
//! gradient decoding interpolates the degree-`(2r+1)(K+T−1)` polynomial
//! `h(z) = f(u(z), v(z))` from the clients' results (Eq. 10).
//!
//! Because the evaluation points are *public constants* (Remark 3), every
//! interpolation reduces to a **matrix of public Lagrange coefficients**
//! applied as a weighted sum — [`coeff_matrix`] precomputes it once and
//! `field::weighted_sum` applies it, which is why COPML's encode/decode
//! needs no MPC multiplications.

use crate::field::Field;

/// Lagrange coefficient matrix `C[t][j] = Π_{l≠j} (targets[t] − xs[l]) /
/// (xs[j] − xs[l])`, so that for any polynomial `h` of degree `< xs.len()`:
/// `h(targets[t]) = Σ_j C[t][j] · h(xs[j])`.
///
/// Panics if `xs` contains duplicates.
///
/// Complexity `O(|xs|² + |targets|·|xs|)` using prefix/suffix products —
/// this runs once per configuration, not per iteration.
pub fn coeff_matrix(f: Field, xs: &[u64], targets: &[u64]) -> Vec<Vec<u64>> {
    let n = xs.len();
    assert!(n > 0);
    // Denominators d_j = Π_{l≠j} (x_j − x_l).
    let mut denom = vec![1u64; n];
    for j in 0..n {
        for l in 0..n {
            if l != j {
                let diff = f.sub(xs[j], xs[l]);
                assert!(diff != 0, "duplicate interpolation points");
                denom[j] = f.mul(denom[j], diff);
            }
        }
    }
    let denom_inv: Vec<u64> = denom.iter().map(|&d| f.inv(d)).collect();

    let mut rows = Vec::with_capacity(targets.len());
    for &z in targets {
        // prefix[j] = Π_{l<j} (z − x_l), suffix[j] = Π_{l>j} (z − x_l)
        let mut prefix = vec![1u64; n];
        for j in 1..n {
            prefix[j] = f.mul(prefix[j - 1], f.sub(z, xs[j - 1]));
        }
        let mut suffix = vec![1u64; n];
        for j in (0..n - 1).rev() {
            suffix[j] = f.mul(suffix[j + 1], f.sub(z, xs[j + 1]));
        }
        let row: Vec<u64> = (0..n)
            .map(|j| f.mul(f.mul(prefix[j], suffix[j]), denom_inv[j]))
            .collect();
        rows.push(row);
    }
    rows
}

/// Single-target convenience: coefficients to evaluate at `z`.
pub fn coeffs_at(f: Field, xs: &[u64], z: u64) -> Vec<u64> {
    coeff_matrix(f, xs, &[z]).pop().unwrap()
}

/// Interpolate scalar samples `(xs[j], ys[j])` and evaluate at `z`.
pub fn interp_eval(f: Field, xs: &[u64], ys: &[u64], z: u64) -> u64 {
    assert_eq!(xs.len(), ys.len());
    let c = coeffs_at(f, xs, z);
    let mut acc = 0u64;
    for (&ci, &yi) in c.iter().zip(ys) {
        acc = f.add(acc, f.mul(ci, yi));
    }
    acc
}

/// Evaluate the polynomial with coefficient vector `coeffs`
/// (`coeffs[i]` multiplies `z^i`) at `z` — Horner. Test helper and
/// share-polynomial evaluation.
pub fn horner(f: Field, coeffs: &[u64], z: u64) -> u64 {
    let mut acc = 0u64;
    for &c in coeffs.iter().rev() {
        acc = f.reduce(f.mul(acc, z) + c);
    }
    acc
}

/// The canonical COPML evaluation-point layout: `K+T` encoding points
/// `β_1..β_{K+T}` and `N` client points `α_1..α_N`, all distinct
/// (paper Phase 2 requires `{α_i} ∩ {β_k} = ∅`). We use
/// `β_k = k`, `α_i = K+T+i` (1-based), which are distinct for any
/// `N + K + T < p`.
pub fn standard_points(kt: usize, n: usize) -> (Vec<u64>, Vec<u64>) {
    let betas: Vec<u64> = (1..=kt as u64).collect();
    let alphas: Vec<u64> = (kt as u64 + 1..=(kt + n) as u64).collect();
    (betas, alphas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::P26;
    use crate::prng::Rng;

    #[test]
    fn interpolation_recovers_polynomial() {
        let f = Field::new(P26);
        let mut r = Rng::seed_from_u64(1);
        for deg in [0usize, 1, 3, 7, 20] {
            let coeffs: Vec<u64> = (0..=deg).map(|_| r.gen_range(P26)).collect();
            let xs: Vec<u64> = (1..=(deg as u64 + 1)).collect();
            let ys: Vec<u64> = xs.iter().map(|&x| horner(f, &coeffs, x)).collect();
            for _ in 0..5 {
                let z = r.gen_range(P26);
                assert_eq!(interp_eval(f, &xs, &ys, z), horner(f, &coeffs, z), "deg={deg}");
            }
        }
    }

    #[test]
    fn coeff_rows_sum_to_one() {
        // Interpolating the constant polynomial 1 must give 1: rows of the
        // coefficient matrix sum to 1 (partition-of-unity property).
        let f = Field::new(P26);
        let xs: Vec<u64> = (1..=12u64).collect();
        let targets: Vec<u64> = (100..120u64).collect();
        let m = coeff_matrix(f, &xs, &targets);
        for row in &m {
            let s = row.iter().fold(0u64, |acc, &c| f.add(acc, c));
            assert_eq!(s, 1);
        }
    }

    #[test]
    fn coeff_matrix_identity_on_nodes() {
        // Evaluating at the nodes themselves gives the identity matrix.
        let f = Field::new(P26);
        let xs: Vec<u64> = vec![3, 17, 99, 1000, 54321];
        let m = coeff_matrix(f, &xs, &xs);
        for (t, row) in m.iter().enumerate() {
            for (j, &c) in row.iter().enumerate() {
                assert_eq!(c, u64::from(t == j), "t={t} j={j}");
            }
        }
    }

    #[test]
    fn matches_prefix_suffix_vs_naive() {
        let f = Field::new(97);
        let xs = vec![1u64, 2, 5, 11];
        let targets = vec![20u64, 33];
        let fast = coeff_matrix(f, &xs, &targets);
        for (t, &z) in targets.iter().enumerate() {
            for j in 0..xs.len() {
                let mut num = 1u64;
                let mut den = 1u64;
                for l in 0..xs.len() {
                    if l != j {
                        num = f.mul(num, f.sub(z, xs[l]));
                        den = f.mul(den, f.sub(xs[j], xs[l]));
                    }
                }
                assert_eq!(fast[t][j], f.mul(num, f.inv(den)));
            }
        }
    }

    #[test]
    fn standard_points_disjoint_distinct() {
        let (betas, alphas) = standard_points(33, 50);
        assert_eq!(betas.len(), 33);
        assert_eq!(alphas.len(), 50);
        let mut all = betas.clone();
        all.extend(&alphas);
        let mut dedup = all.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(all.len(), dedup.len());
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_points_rejected() {
        let f = Field::new(97);
        coeff_matrix(f, &[1, 2, 2], &[5]);
    }
}
