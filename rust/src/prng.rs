//! Deterministic pseudo-random number generation.
//!
//! The offline image ships no `rand` crate, so the repository carries its own
//! small, well-tested generator: **xoshiro256++** seeded through SplitMix64
//! (the construction recommended by the xoshiro authors). Every experiment in
//! the repo is seeded, so all runs — secret sharing randomness, Lagrange
//! masks, truncation randomness, synthetic datasets — are reproducible
//! bit-for-bit.
//!
//! This PRNG is *not* cryptographically secure; in a deployment the dealer
//! and clients would use a CSPRNG. The protocol logic is agnostic to the
//! source of randomness (see `mpc::dealer`), and the statistical tests in
//! this module are about reproducibility, not security.

/// SplitMix64 step: used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. 256 bits of state, period 2^256 − 1.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a labelled subcomponent.
    ///
    /// Used to give each client / protocol phase its own stream so that
    /// adding a phase never perturbs the randomness of another.
    pub fn fork(&mut self, label: u64) -> Rng {
        let mut sm = self.next_u64() ^ label.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3]))
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` by rejection from the top 64-bit word
    /// (Lemire's multiply-shift with rejection; unbiased).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform field element in `[0, p)`.
    #[inline]
    pub fn gen_field(&mut self, p: u64) -> u64 {
        self.gen_range(p)
    }

    /// Fill a slice with uniform field elements.
    pub fn fill_field(&mut self, p: u64, out: &mut [u64]) {
        for v in out.iter_mut() {
            *v = self.gen_range(p);
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; this is not a hot path).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gen_range_unbiased_rough() {
        // Chi-square-ish sanity: bucket counts within 20% of expectation.
        let mut r = Rng::seed_from_u64(11);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[r.gen_range(8) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 2_000.0, "count {c}");
        }
    }

    #[test]
    fn gen_range_boundary_bounds_terminate_and_spread() {
        // Rejection sampling at the extremes: bound = u64::MAX (Lemire
        // threshold t = 1, rejection probability 2^-64), 2^63 + 1 (just
        // past the half-range), and p − 1 for the largest supported
        // modulus. Every call must terminate, stay under the bound, and
        // look roughly uniform (mean ≈ bound/2, both halves populated).
        use crate::field::P31;
        let mut r = Rng::seed_from_u64(21);
        let n = 4000u32;
        for &bound in &[u64::MAX, (1u64 << 63) + 1, P31 - 1] {
            let mut upper = 0usize;
            let mut sum: u128 = 0;
            for _ in 0..n {
                let v = r.gen_range(bound);
                assert!(v < bound, "bound {bound}: drew {v}");
                if v >= bound / 2 {
                    upper += 1;
                }
                sum += v as u128;
            }
            let frac = upper as f64 / n as f64;
            assert!((frac - 0.5).abs() < 0.08, "bound {bound}: upper-half fraction {frac}");
            let mean = sum as f64 / n as f64;
            let expect = bound as f64 / 2.0;
            assert!(
                (mean - expect).abs() / expect < 0.1,
                "bound {bound}: mean {mean} vs {expect}"
            );
        }
    }

    #[test]
    fn gen_field_stays_in_domain_at_p31() {
        // The headroom prime is the largest modulus the field layer
        // supports — the boundary where a rejection-sampling bias or an
        // off-by-one would first show.
        use crate::field::P31;
        let mut r = Rng::seed_from_u64(23);
        let (mut lo, mut hi) = (false, false);
        for _ in 0..20_000 {
            let v = r.gen_field(P31);
            assert!(v < P31, "gen_field left the domain: {v}");
            if v < P31 / 2 {
                lo = true;
            } else {
                hi = true;
            }
        }
        assert!(lo && hi, "gen_field never visited both halves of F_p");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::seed_from_u64(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn normal_mean_var() {
        let mut r = Rng::seed_from_u64(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::seed_from_u64(13);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
