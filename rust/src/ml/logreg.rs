//! Plaintext full-batch gradient-descent logistic regression — the
//! "conventional logistic regression" baseline of Fig. 4, and the reference
//! trajectory every secure trainer is compared against.
//!
//! Update rule (paper Eq. 2):
//! `w ← w − (η/m)·Xᵀ(g(X·w) − y)`, with `g` either the exact sigmoid or a
//! fitted polynomial (to isolate the polynomial-approximation error from
//! the quantization error in the accuracy ablations).

use super::sigmoid::{sigmoid, SigmoidPoly};
use crate::data::Dataset;

/// Options for the plaintext trainer.
#[derive(Clone, Debug)]
pub struct LogRegOptions {
    pub iters: usize,
    pub eta: f64,
    /// `None` → exact sigmoid; `Some(poly)` → polynomial link.
    pub link: Option<SigmoidPoly>,
    /// Record train/test accuracy every iteration (costs two passes).
    pub trace_accuracy: bool,
}

impl Default for LogRegOptions {
    fn default() -> Self {
        LogRegOptions { iters: 50, eta: 1.0, link: None, trace_accuracy: true }
    }
}

/// Per-iteration trace of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainTrace {
    pub w: Vec<f64>,
    pub loss: Vec<f64>,
    pub train_accuracy: Vec<f64>,
    pub test_accuracy: Vec<f64>,
}

/// Train on `ds.x/ds.y`; returns the final model and per-iteration trace.
pub fn train_logreg(ds: &Dataset, opt: &LogRegOptions) -> TrainTrace {
    let (m, d) = (ds.m, ds.d);
    let mut w = vec![0.0f64; d];
    let mut trace = TrainTrace::default();
    let mut z = vec![0.0f64; m];
    let mut grad = vec![0.0f64; d];

    for _ in 0..opt.iters {
        // z = X·w
        for i in 0..m {
            z[i] = ds.x[i * d..(i + 1) * d].iter().zip(&w).map(|(&a, &b)| a * b).sum();
        }
        // residual r = g(z) − y
        for i in 0..m {
            let g = match &opt.link {
                None => sigmoid(z[i]),
                Some(p) => p.eval(z[i]),
            };
            z[i] = g - ds.y[i];
        }
        // grad = Xᵀ r / m
        grad.fill(0.0);
        for i in 0..m {
            let r = z[i];
            if r != 0.0 {
                for (gj, &xij) in grad.iter_mut().zip(&ds.x[i * d..(i + 1) * d]) {
                    *gj += r * xij;
                }
            }
        }
        for (wj, gj) in w.iter_mut().zip(&grad) {
            *wj -= opt.eta / m as f64 * gj;
        }

        trace.loss.push(crate::ml::cross_entropy(&ds.x, &ds.y, d, &w));
        if opt.trace_accuracy {
            trace.train_accuracy.push(crate::ml::accuracy(&ds.x, &ds.y, d, &w));
            trace.test_accuracy.push(crate::ml::accuracy(&ds.x_test, &ds.y_test, d, &w));
        }
    }
    trace.w = w;
    trace
}

/// Lipschitz constant of the cross-entropy gradient: `L = ‖X‖₂²/4`
/// (paper Theorem 1). Estimated by power iteration on `XᵀX`.
pub fn lipschitz_constant(ds: &Dataset, iters: usize) -> f64 {
    let (m, d) = (ds.m, ds.d);
    let mut v = vec![1.0f64 / (d as f64).sqrt(); d];
    let mut xv = vec![0.0f64; m];
    for _ in 0..iters {
        for i in 0..m {
            xv[i] = ds.x[i * d..(i + 1) * d].iter().zip(&v).map(|(&a, &b)| a * b).sum();
        }
        let mut xtxv = vec![0.0f64; d];
        for i in 0..m {
            let s = xv[i];
            for (out, &xij) in xtxv.iter_mut().zip(&ds.x[i * d..(i + 1) * d]) {
                *out += s * xij;
            }
        }
        let norm = xtxv.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-30);
        for (vi, &ni) in v.iter_mut().zip(&xtxv) {
            *vi = ni / norm;
        }
    }
    // Rayleigh quotient after the last multiply ≈ λ_max(XᵀX) = ‖X‖₂².
    for i in 0..m {
        xv[i] = ds.x[i * d..(i + 1) * d].iter().zip(&v).map(|(&a, &b)| a * b).sum();
    }
    let lambda: f64 = xv.iter().map(|x| x * x).sum();
    lambda / 4.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;
    use crate::ml::fit_sigmoid;

    #[test]
    fn loss_monotone_decreasing_on_smoke() {
        let ds = Dataset::synth(SynthSpec::smoke(), 7);
        let trace = train_logreg(&ds, &LogRegOptions { iters: 30, eta: 1.0, ..Default::default() });
        for w in trace.loss.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "loss must not increase: {w:?}");
        }
        assert!(trace.loss.last().unwrap() < &trace.loss[0]);
    }

    #[test]
    fn smoke_dataset_learnable() {
        let ds = Dataset::synth(SynthSpec::smoke(), 8);
        let trace = train_logreg(&ds, &LogRegOptions { iters: 50, eta: 2.0, ..Default::default() });
        let acc = *trace.test_accuracy.last().unwrap();
        assert!(acc > 0.85, "smoke test accuracy {acc}");
    }

    #[test]
    fn poly_link_close_to_sigmoid_link() {
        let ds = Dataset::synth(SynthSpec::smoke(), 9);
        let exact = train_logreg(&ds, &LogRegOptions { iters: 40, eta: 1.0, ..Default::default() });
        let poly = fit_sigmoid(1, 4.0, 2000);
        let approx = train_logreg(
            &ds,
            &LogRegOptions { iters: 40, eta: 1.0, link: Some(poly), ..Default::default() },
        );
        let da = (exact.test_accuracy.last().unwrap() - approx.test_accuracy.last().unwrap()).abs();
        assert!(da < 0.06, "poly-link accuracy gap {da}");
    }

    #[test]
    fn lipschitz_positive_and_step_converges() {
        let ds = Dataset::synth(SynthSpec::smoke(), 10);
        let l = lipschitz_constant(&ds, 30);
        assert!(l > 0.0);
        // η = 1/L must give monotone decrease (Theorem 1 premise)
        let trace = train_logreg(
            &ds,
            &LogRegOptions { iters: 20, eta: 1.0 / l, trace_accuracy: false, ..Default::default() },
        );
        for w in trace.loss.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }
}
