//! The workload abstraction of the model zoo (ISSUE-10 tentpole): a
//! [`Model`] contract every training layer dispatches through instead of
//! assuming one d-vector sigmoid gradient.
//!
//! Three workloads implement it:
//!
//! | kind                    | secure path                             | paper anchor            |
//! |-------------------------|-----------------------------------------|-------------------------|
//! | [`ModelKind::Logreg`]   | encoded-gradient GD, 1 channel          | Fig. 4 GISETTE (§V.A)   |
//! | [`ModelKind::Multinomial`] | encoded-gradient GD, C one-vs-rest channels | Fig. 4 CIFAR-10 (§V.A) |
//! | [`ModelKind::Linreg`]   | closed-form normal equations, one BH08 reduction | PrivColl-style aggregation |
//!
//! The contract covers exactly what the coordinator layers need:
//!
//! * **channels** — how many d-wide gradient channels the secure state
//!   vector holds (`G = d·channels`); 1 reduces every width to the
//!   pre-refactor layout, which is what keeps binary logreg bit-identical;
//! * **cleartext reference step** — the f64 trajectory Fig.-4-style
//!   comparisons assert against;
//! * **quantization-plan derivation** — the measured gradient bound fed to
//!   [`FpPlan::validate`]/[`FpPlan::validate_classes`];
//! * **per-iteration truncation demand** — how many TruncPr pairs per
//!   width the offline phase must provision;
//! * **output decode + metrics** — field state → f64 weights →
//!   accuracy/AUC/R².
//!
//! Multinomial is trained as C one-vs-rest sigmoid-link problems sharing
//! one encoded dataset (the paper's CIFAR-10 setup quantizes exactly this
//! shape); linear regression solves `(XᵀX + λI)β = Xᵀy` where both moment
//! matrices are aggregated securely and opened — only the public solve
//! happens in f64.

use super::logreg::{train_logreg, LogRegOptions, TrainTrace};
use super::sigmoid::{sigmoid, solve_dense, SigmoidPoly};
use crate::data::Dataset;
use crate::quant::FpPlan;

/// Ridge multiplier of the secure normal-equations solve: `λ = RIDGE_REL ·
/// trace(XᵀX)/d`. Deterministic f64 — every party computes the identical
/// public solve, so shares of the result stay consistent.
pub const RIDGE_REL: f64 = 1e-6;

/// Which workload a run trains (`--model logreg|multinomial|linreg`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ModelKind {
    /// Binary logistic regression — the seed workload and bit-identity
    /// oracle of the refactor.
    #[default]
    Logreg,
    /// Multinomial logistic regression: a d×C weight matrix trained as C
    /// one-vs-rest polynomial-sigmoid channels over one shared encoding.
    Multinomial,
    /// Closed-form linear regression via securely aggregated normal
    /// equations (no iteration loop, no truncation).
    Linreg,
}

impl std::str::FromStr for ModelKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "logreg" => Ok(ModelKind::Logreg),
            "multinomial" => Ok(ModelKind::Multinomial),
            "linreg" => Ok(ModelKind::Linreg),
            other => Err(format!("unknown model '{other}' (logreg|multinomial|linreg)")),
        }
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.model().name())
    }
}

impl ModelKind {
    /// The workload behind this kind.
    pub fn model(self) -> &'static dyn Model {
        match self {
            ModelKind::Logreg => &Logreg,
            ModelKind::Multinomial => &Multinomial,
            ModelKind::Linreg => &Linreg,
        }
    }

    /// Gradient channels on `ds` (`G = d·channels`).
    pub fn channels(self, ds: &Dataset) -> usize {
        self.model().channels(ds.classes)
    }
}

/// Quality metrics of a decoded model on one dataset split. Which fields
/// are populated depends on the workload (classifiers report
/// accuracy/AUC, regression reports R²); `loss` is always the workload's
/// training objective.
#[derive(Clone, Copy, Debug, Default)]
pub struct ModelMetrics {
    pub accuracy: Option<f64>,
    pub auc: Option<f64>,
    pub r2: Option<f64>,
    pub loss: f64,
}

impl std::fmt::Display for ModelMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        let mut put = |f: &mut std::fmt::Formatter<'_>, k: &str, v: f64| {
            let sep = if first { "" } else { "  " };
            first = false;
            write!(f, "{sep}{k}={v:.4}")
        };
        if let Some(a) = self.accuracy {
            put(f, "accuracy", a)?;
        }
        if let Some(a) = self.auc {
            put(f, "auc", a)?;
        }
        if let Some(r) = self.r2 {
            put(f, "r2", r)?;
        }
        put(f, "loss", self.loss)
    }
}

/// The workload contract (module docs list the exact responsibilities).
/// All methods are deterministic pure functions — the protocol's
/// bit-identity guarantees extend through them.
pub trait Model: Sync {
    /// CLI/summary name (also the `--model` spelling).
    fn name(&self) -> &'static str;

    /// Gradient channels for a `classes`-class dataset: the secure state
    /// vector is `G = d·channels` wide, class-major.
    fn channels(&self, classes: usize) -> usize;

    /// Whether the workload runs the per-iteration encoded-gradient loop
    /// (false → one-shot closed form, phases 3–6 skipped).
    fn iterative(&self) -> bool;

    /// Entries in `w_trace` after `iters` configured iterations.
    fn trace_len(&self, iters: usize) -> usize {
        if self.iterative() {
            iters
        } else {
            1
        }
    }

    /// TruncPr pairs consumed per width over a whole run (stage 1 and
    /// stage 2 each consume this many) — the offline-demand contract.
    fn trunc_pairs(&self, d: usize, classes: usize, iters: usize) -> usize {
        if self.iterative() {
            d * self.channels(classes) * iters
        } else {
            0
        }
    }

    /// Dataset/label-shape preconditions (checked before any quantization).
    fn check_dataset(&self, ds: &Dataset) -> Result<(), String>;

    /// Quantization-plan derivation: measure the workload's gradient bound
    /// on `ds` and run the fixed-point budget checks (Appendix A).
    fn validate_plan(&self, plan: &FpPlan, ds: &Dataset, r: usize) -> Result<(), String>;

    /// Cleartext f64 reference trajectory (the Fig.-4 comparison target).
    fn reference(&self, ds: &Dataset, iters: usize, eta: f64, link: Option<&SigmoidPoly>)
        -> TrainTrace;

    /// Quantized label of raw value `y` for gradient channel `channel`
    /// (the class-major `y_q` layout of `QuantizedTask`): binary labels
    /// at scale `2^0` (the seed layout), one-vs-rest indicators at `2^0`
    /// for multinomial, regression targets at `2^{l_x}` so the secure
    /// `Xᵀy` products land on the common `2^{2l_x}` scale.
    fn quantize_label(&self, plan: &FpPlan, y: f64, channel: usize) -> u64;

    /// Decode a field-element state vector into f64 weights (all three
    /// workloads carry weights at scale `2^lw`).
    fn decode(&self, plan: &FpPlan, w_q: &[u64]) -> Vec<f64> {
        crate::quant::dequantize_slice(plan.field, w_q, plan.lw)
    }

    /// The workload's scalar quality score on a split (classification
    /// accuracy, or R² for regression) — the per-iteration trace metric.
    fn score(&self, x: &[f64], y: &[f64], d: usize, classes: usize, w: &[f64]) -> f64;

    /// The workload's training objective on a split.
    fn loss(&self, x: &[f64], y: &[f64], d: usize, classes: usize, w: &[f64]) -> f64;

    /// Full metric set on a split (what summaries and ClientOutput report).
    fn metrics(&self, x: &[f64], y: &[f64], d: usize, classes: usize, w: &[f64])
        -> ModelMetrics;
}

/// Binary logistic regression (the seed workload).
pub struct Logreg;

impl Model for Logreg {
    fn name(&self) -> &'static str {
        "logreg"
    }

    fn channels(&self, _classes: usize) -> usize {
        1
    }

    fn iterative(&self) -> bool {
        true
    }

    fn check_dataset(&self, ds: &Dataset) -> Result<(), String> {
        if ds.classes != 2 {
            return Err(format!(
                "model logreg needs binary {{0,1}} labels, but dataset '{}' has {} \
                 classes — use --model multinomial (or linreg for regression targets)",
                ds.name, ds.classes
            ));
        }
        Ok(())
    }

    fn validate_plan(&self, plan: &FpPlan, ds: &Dataset, r: usize) -> Result<(), String> {
        // Measured bound of the quantity actually truncated: the raw batch
        // gradient Xᵀ(ĝ(Xw) − y) at w = 0 (ĝ(0) = ½), with 30% slack for
        // drift over the run and an 8.0 floor for tiny datasets.
        let mut g0 = vec![0.0f64; ds.d];
        for i in 0..ds.m {
            let res = 0.5 - ds.y[i];
            for (gj, &xij) in g0.iter_mut().zip(&ds.x[i * ds.d..(i + 1) * ds.d]) {
                *gj += res * xij;
            }
        }
        let grad_bound = 1.3 * g0.iter().fold(8.0f64, |a, &b| a.max(b.abs()));
        let rep = plan.validate(ds.d, 1.0, 8.0 / ds.d as f64, grad_bound, r);
        if !rep.ok {
            return Err(format!("fixed-point plan invalid: {}", rep.errors.join("; ")));
        }
        Ok(())
    }

    fn reference(
        &self,
        ds: &Dataset,
        iters: usize,
        eta: f64,
        link: Option<&SigmoidPoly>,
    ) -> TrainTrace {
        train_logreg(
            ds,
            &LogRegOptions { iters, eta, link: link.cloned(), trace_accuracy: true },
        )
    }

    fn quantize_label(&self, plan: &FpPlan, y: f64, _channel: usize) -> u64 {
        crate::quant::quantize(plan.field, y, 0)
    }

    fn score(&self, x: &[f64], y: &[f64], d: usize, _classes: usize, w: &[f64]) -> f64 {
        crate::ml::accuracy(x, y, d, w)
    }

    fn loss(&self, x: &[f64], y: &[f64], d: usize, _classes: usize, w: &[f64]) -> f64 {
        crate::ml::cross_entropy(x, y, d, w)
    }

    fn metrics(&self, x: &[f64], y: &[f64], d: usize, classes: usize, w: &[f64]) -> ModelMetrics {
        ModelMetrics {
            accuracy: Some(crate::ml::accuracy(x, y, d, w)),
            auc: Some(auc(x, y, d, w)),
            r2: None,
            loss: self.loss(x, y, d, classes, w),
        }
    }
}

/// Multinomial logistic regression as C one-vs-rest sigmoid channels.
pub struct Multinomial;

impl Model for Multinomial {
    fn name(&self) -> &'static str {
        "multinomial"
    }

    fn channels(&self, classes: usize) -> usize {
        classes
    }

    fn iterative(&self) -> bool {
        true
    }

    fn check_dataset(&self, ds: &Dataset) -> Result<(), String> {
        if ds.classes < 2 {
            return Err(format!(
                "model multinomial needs integer class labels (≥ 2 classes), but \
                 dataset '{}' has a regression target — use --model linreg",
                ds.name
            ));
        }
        for (i, &v) in ds.y.iter().chain(ds.y_test.iter()).enumerate() {
            if v.fract() != 0.0 || v < 0.0 || v >= ds.classes as f64 {
                return Err(format!(
                    "model multinomial: label {v} at row {i} outside 0..{}",
                    ds.classes
                ));
            }
        }
        Ok(())
    }

    fn validate_plan(&self, plan: &FpPlan, ds: &Dataset, r: usize) -> Result<(), String> {
        // Per-class measured gradient bounds: the one-vs-rest labels are
        // imbalanced (class c is a 1/C minority), so each channel's raw
        // gradient Xᵀ(½ − y_c) has its own magnitude — the widest channel
        // sets the stage-1 budget and validate_classes names the rest.
        let mut bounds = Vec::with_capacity(ds.classes);
        for c in 0..ds.classes {
            let mut g0 = vec![0.0f64; ds.d];
            for i in 0..ds.m {
                let yc = if ds.y[i] == c as f64 { 1.0 } else { 0.0 };
                let res = 0.5 - yc;
                for (gj, &xij) in g0.iter_mut().zip(&ds.x[i * ds.d..(i + 1) * ds.d]) {
                    *gj += res * xij;
                }
            }
            bounds.push(1.3 * g0.iter().fold(8.0f64, |a, &b| a.max(b.abs())));
        }
        let rep = plan.validate_classes(ds.d, 1.0, 8.0 / ds.d as f64, &bounds, r);
        if !rep.ok {
            return Err(format!("fixed-point plan invalid: {}", rep.errors.join("; ")));
        }
        Ok(())
    }

    fn reference(
        &self,
        ds: &Dataset,
        iters: usize,
        eta: f64,
        link: Option<&SigmoidPoly>,
    ) -> TrainTrace {
        train_multinomial(
            ds,
            &LogRegOptions { iters, eta, link: link.cloned(), trace_accuracy: true },
        )
    }

    fn quantize_label(&self, plan: &FpPlan, y: f64, channel: usize) -> u64 {
        let indicator = if y == channel as f64 { 1.0 } else { 0.0 };
        crate::quant::quantize(plan.field, indicator, 0)
    }

    fn score(&self, x: &[f64], y: &[f64], d: usize, classes: usize, w: &[f64]) -> f64 {
        multiclass_accuracy(x, y, d, classes, w)
    }

    fn loss(&self, x: &[f64], y: &[f64], d: usize, classes: usize, w: &[f64]) -> f64 {
        one_vs_rest_cross_entropy(x, y, d, classes, w)
    }

    fn metrics(&self, x: &[f64], y: &[f64], d: usize, classes: usize, w: &[f64]) -> ModelMetrics {
        ModelMetrics {
            accuracy: Some(multiclass_accuracy(x, y, d, classes, w)),
            auc: None,
            r2: None,
            loss: self.loss(x, y, d, classes, w),
        }
    }
}

/// Closed-form linear regression via secure normal equations.
pub struct Linreg;

impl Model for Linreg {
    fn name(&self) -> &'static str {
        "linreg"
    }

    fn channels(&self, _classes: usize) -> usize {
        1
    }

    fn iterative(&self) -> bool {
        false
    }

    fn check_dataset(&self, ds: &Dataset) -> Result<(), String> {
        let max_abs =
            ds.y.iter().chain(ds.y_test.iter()).fold(0.0f64, |a, &v| a.max(v.abs()));
        if max_abs > 1.0 + 1e-9 {
            return Err(format!(
                "model linreg needs targets in [−1, 1] (max |y| = {max_abs:.3}) — the \
                 csv loader rescales regression targets automatically"
            ));
        }
        Ok(())
    }

    fn validate_plan(&self, plan: &FpPlan, ds: &Dataset, _r: usize) -> Result<(), String> {
        // The opened values are entries of XᵀX/Xᵀy at scale 2^{2lx}:
        // |Σ_i x_ij·x_ik| ≤ m with |x| ≤ 1, so the field must hold
        // m·2^{2lx} with a sign bit to spare.
        let bits = 2 * plan.lx + (usize::BITS - ds.m.leading_zeros()) as usize + 1;
        let field_bits = 63 - plan.field.modulus().leading_zeros() as usize;
        if bits > field_bits {
            return Err(format!(
                "model linreg: normal-equation entries need {bits} bits \
                 (2·lx = {} + log2(m = {}) + sign) but p has only {field_bits} — \
                 lower lx or shrink the dataset",
                2 * plan.lx,
                ds.m
            ));
        }
        Ok(())
    }

    fn reference(
        &self,
        ds: &Dataset,
        _iters: usize,
        _eta: f64,
        _link: Option<&SigmoidPoly>,
    ) -> TrainTrace {
        let beta = ridge_regression(&ds.x, &ds.y, ds.d);
        let mut trace = TrainTrace::default();
        trace.loss.push(mse(&ds.x, &ds.y, ds.d, &beta));
        trace.train_accuracy.push(r2(&ds.x, &ds.y, ds.d, &beta));
        trace.test_accuracy.push(r2(&ds.x_test, &ds.y_test, ds.d, &beta));
        trace.w = beta;
        trace
    }

    fn quantize_label(&self, plan: &FpPlan, y: f64, _channel: usize) -> u64 {
        crate::quant::quantize(plan.field, y, plan.lx)
    }

    fn score(&self, x: &[f64], y: &[f64], d: usize, _classes: usize, w: &[f64]) -> f64 {
        r2(x, y, d, w)
    }

    fn loss(&self, x: &[f64], y: &[f64], d: usize, _classes: usize, w: &[f64]) -> f64 {
        mse(x, y, d, w)
    }

    fn metrics(&self, x: &[f64], y: &[f64], d: usize, classes: usize, w: &[f64]) -> ModelMetrics {
        ModelMetrics {
            accuracy: None,
            auc: None,
            r2: Some(r2(x, y, d, w)),
            loss: self.loss(x, y, d, classes, w),
        }
    }
}

/// Plaintext one-vs-rest multinomial trainer: C independent sigmoid-link
/// gradient-descent channels sharing `X` (the cleartext twin of the secure
/// class-major update). `w` is class-major, length `d·C`.
pub fn train_multinomial(ds: &Dataset, opt: &LogRegOptions) -> TrainTrace {
    let (m, d, classes) = (ds.m, ds.d, ds.classes);
    let mut w = vec![0.0f64; d * classes];
    let mut trace = TrainTrace::default();
    let mut z = vec![0.0f64; m];
    let mut grad = vec![0.0f64; d];

    for _ in 0..opt.iters {
        for c in 0..classes {
            let wc = &mut w[c * d..(c + 1) * d];
            for i in 0..m {
                z[i] = ds.x[i * d..(i + 1) * d].iter().zip(wc.iter()).map(|(&a, &b)| a * b).sum();
            }
            for i in 0..m {
                let g = match &opt.link {
                    None => sigmoid(z[i]),
                    Some(p) => p.eval(z[i]),
                };
                let yc = if ds.y[i] == c as f64 { 1.0 } else { 0.0 };
                z[i] = g - yc;
            }
            grad.fill(0.0);
            for i in 0..m {
                let res = z[i];
                if res != 0.0 {
                    for (gj, &xij) in grad.iter_mut().zip(&ds.x[i * d..(i + 1) * d]) {
                        *gj += res * xij;
                    }
                }
            }
            for (wj, gj) in wc.iter_mut().zip(&grad) {
                *wj -= opt.eta / m as f64 * gj;
            }
        }
        trace.loss.push(one_vs_rest_cross_entropy(&ds.x, &ds.y, d, classes, &w));
        if opt.trace_accuracy {
            trace.train_accuracy.push(multiclass_accuracy(&ds.x, &ds.y, d, classes, &w));
            trace
                .test_accuracy
                .push(multiclass_accuracy(&ds.x_test, &ds.y_test, d, classes, &w));
        }
    }
    trace.w = w;
    trace
}

/// Cleartext ridge solve `(XᵀX + λI)β = Xᵀy` with `λ = RIDGE_REL ·
/// trace(XᵀX)/d` — the reference for the secure normal-equations path,
/// which runs [`solve_normal_equations`] on the opened (quantized) moments.
pub fn ridge_regression(x: &[f64], y: &[f64], d: usize) -> Vec<f64> {
    let m = y.len();
    let mut xtx = vec![0.0f64; d * d];
    let mut xty = vec![0.0f64; d];
    for i in 0..m {
        let row = &x[i * d..(i + 1) * d];
        for j in 0..d {
            xty[j] += row[j] * y[i];
            for k in 0..d {
                xtx[j * d + k] += row[j] * row[k];
            }
        }
    }
    solve_normal_equations(&mut xtx, &mut xty, d)
}

/// Shared public solve of the (already aggregated) normal equations —
/// called identically by every party on the opened moments and by the
/// cleartext reference, so secure runs agree bit-for-bit with each other.
/// Consumes its inputs (adds the ridge in place).
pub fn solve_normal_equations(xtx: &mut [f64], xty: &mut [f64], d: usize) -> Vec<f64> {
    let trace: f64 = (0..d).map(|j| xtx[j * d + j]).sum();
    let ridge = RIDGE_REL * (trace / d as f64).max(1e-12);
    for j in 0..d {
        xtx[j * d + j] += ridge;
    }
    solve_dense(xtx, xty, d)
}

/// Argmax classification accuracy of a class-major `d·C` weight matrix.
pub fn multiclass_accuracy(x: &[f64], y: &[f64], d: usize, classes: usize, w: &[f64]) -> f64 {
    let m = y.len();
    assert_eq!(w.len(), d * classes);
    let mut correct = 0usize;
    for i in 0..m {
        let row = &x[i * d..(i + 1) * d];
        let mut best = 0usize;
        let mut best_z = f64::NEG_INFINITY;
        for c in 0..classes {
            let z: f64 =
                row.iter().zip(&w[c * d..(c + 1) * d]).map(|(&a, &b)| a * b).sum();
            if z > best_z {
                best_z = z;
                best = c;
            }
        }
        if best as f64 == y[i] {
            correct += 1;
        }
    }
    correct as f64 / m as f64
}

/// Mean one-vs-rest cross-entropy of a class-major `d·C` weight matrix
/// (the multinomial training objective: each channel is a binary CE).
pub fn one_vs_rest_cross_entropy(
    x: &[f64],
    y: &[f64],
    d: usize,
    classes: usize,
    w: &[f64],
) -> f64 {
    let m = y.len();
    let mut loss = 0.0;
    for c in 0..classes {
        let wc = &w[c * d..(c + 1) * d];
        for i in 0..m {
            let z: f64 = x[i * d..(i + 1) * d].iter().zip(wc).map(|(&a, &b)| a * b).sum();
            let p = sigmoid(z).clamp(1e-12, 1.0 - 1e-12);
            let yc = if y[i] == c as f64 { 1.0 } else { 0.0 };
            loss -= yc * p.ln() + (1.0 - yc) * (1.0 - p).ln();
        }
    }
    loss / (m * classes) as f64
}

/// Area under the ROC curve of scores `x·w` against binary labels, by the
/// Mann–Whitney rank statistic with average ranks on ties (deterministic:
/// `total_cmp` ordering). Returns 0.5 when a class is absent.
pub fn auc(x: &[f64], y: &[f64], d: usize, w: &[f64]) -> f64 {
    let m = y.len();
    let mut scores: Vec<(f64, bool)> = (0..m)
        .map(|i| {
            let z: f64 = x[i * d..(i + 1) * d].iter().zip(w).map(|(&a, &b)| a * b).sum();
            (z, y[i] > 0.5)
        })
        .collect();
    let n_pos = scores.iter().filter(|&&(_, p)| p).count();
    let n_neg = m - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    scores.sort_by(|a, b| a.0.total_cmp(&b.0));
    // Sum of positive ranks, averaging within tie groups.
    let mut rank_pos = 0.0f64;
    let mut i = 0usize;
    while i < m {
        let mut j = i;
        while j < m && scores[j].0 == scores[i].0 {
            j += 1;
        }
        let avg_rank = (i + 1 + j) as f64 / 2.0; // mean of ranks i+1..=j
        for s in &scores[i..j] {
            if s.1 {
                rank_pos += avg_rank;
            }
        }
        i = j;
    }
    (rank_pos - (n_pos * (n_pos + 1)) as f64 / 2.0) / (n_pos * n_neg) as f64
}

/// Coefficient of determination `R² = 1 − SS_res/SS_tot` of predictions
/// `x·w` against targets `y` (0 when the targets are constant).
pub fn r2(x: &[f64], y: &[f64], d: usize, w: &[f64]) -> f64 {
    let m = y.len();
    let mean = y.iter().sum::<f64>() / m as f64;
    let mut ss_res = 0.0;
    let mut ss_tot = 0.0;
    for i in 0..m {
        let z: f64 = x[i * d..(i + 1) * d].iter().zip(w).map(|(&a, &b)| a * b).sum();
        ss_res += (y[i] - z) * (y[i] - z);
        ss_tot += (y[i] - mean) * (y[i] - mean);
    }
    if ss_tot < 1e-300 {
        return 0.0;
    }
    1.0 - ss_res / ss_tot
}

/// Mean squared error of predictions `x·w` against targets `y`.
pub fn mse(x: &[f64], y: &[f64], d: usize, w: &[f64]) -> f64 {
    let m = y.len();
    let mut acc = 0.0;
    for i in 0..m {
        let z: f64 = x[i * d..(i + 1) * d].iter().zip(w).map(|(&a, &b)| a * b).sum();
        acc += (y[i] - z) * (y[i] - z);
    }
    acc / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthSpec;

    fn three_class_dataset(seed: u64) -> Dataset {
        // Deterministic 3-class blobs: class c shifts feature c by ±.
        let mut rng = crate::prng::Rng::seed_from_u64(seed);
        let (m, m_test, d, classes) = (240usize, 60usize, 5usize, 3usize);
        let gen = |rng: &mut crate::prng::Rng, n: usize| {
            let mut x = vec![0.0f64; n * d];
            let mut y = vec![0.0f64; n];
            for i in 0..n {
                let c = i % classes;
                y[i] = c as f64;
                for j in 0..d - 1 {
                    let mut v = 0.25 * rng.gen_normal();
                    if j == c {
                        v += 0.6;
                    }
                    x[i * d + j] = v.clamp(-1.0, 1.0);
                }
                x[i * d + d - 1] = 1.0;
            }
            (x, y)
        };
        let (x, y) = gen(&mut rng, m);
        let (x_test, y_test) = gen(&mut rng, m_test);
        Dataset { name: "three-class".into(), x, y, x_test, y_test, m, d, classes }
    }

    #[test]
    fn model_kind_round_trips() {
        for (s, k) in [
            ("logreg", ModelKind::Logreg),
            ("multinomial", ModelKind::Multinomial),
            ("linreg", ModelKind::Linreg),
        ] {
            assert_eq!(s.parse::<ModelKind>().unwrap(), k);
            assert_eq!(k.to_string(), s);
        }
        assert!("svm".parse::<ModelKind>().is_err());
        assert_eq!(ModelKind::default(), ModelKind::Logreg);
    }

    #[test]
    fn channel_widths_per_workload() {
        let ds = three_class_dataset(1);
        assert_eq!(ModelKind::Logreg.model().channels(2), 1);
        assert_eq!(ModelKind::Multinomial.channels(&ds), 3);
        assert_eq!(ModelKind::Linreg.channels(&ds), 1);
        assert_eq!(ModelKind::Logreg.model().trace_len(40), 40);
        assert_eq!(ModelKind::Linreg.model().trace_len(40), 1);
        assert_eq!(ModelKind::Multinomial.model().trunc_pairs(5, 3, 10), 150);
        assert_eq!(ModelKind::Linreg.model().trunc_pairs(5, 1, 10), 0);
    }

    #[test]
    fn logreg_rejects_multiclass_dataset() {
        let ds = three_class_dataset(2);
        assert!(Logreg.check_dataset(&ds).is_err());
        assert!(Multinomial.check_dataset(&ds).is_ok());
        let binary = Dataset::synth(SynthSpec::smoke(), 3);
        assert!(Logreg.check_dataset(&binary).is_ok());
    }

    #[test]
    fn multinomial_reference_learns_three_classes() {
        let ds = three_class_dataset(4);
        let trace = train_multinomial(
            &ds,
            &LogRegOptions { iters: 60, eta: 2.0, ..Default::default() },
        );
        let acc = *trace.test_accuracy.last().unwrap();
        assert!(acc > 0.8, "3-class accuracy {acc}");
        for w in trace.loss.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "one-vs-rest loss must not increase: {w:?}");
        }
    }

    #[test]
    fn multinomial_two_class_matches_argmax_of_logreg_shape() {
        // With C = 2 the one-vs-rest channels are symmetric: argmax
        // accuracy must track the binary trainer closely.
        let ds = Dataset::synth(SynthSpec::smoke(), 5);
        let multi = train_multinomial(
            &ds,
            &LogRegOptions { iters: 40, eta: 1.0, ..Default::default() },
        );
        let binary = train_logreg(
            &ds,
            &LogRegOptions { iters: 40, eta: 1.0, ..Default::default() },
        );
        let gap = (multi.test_accuracy.last().unwrap()
            - binary.test_accuracy.last().unwrap())
        .abs();
        assert!(gap < 0.05, "C=2 multinomial vs binary accuracy gap {gap}");
    }

    #[test]
    fn linreg_reference_recovers_planted_model() {
        // y = x·β* exactly → ridge solve recovers β* and R² ≈ 1.
        let mut rng = crate::prng::Rng::seed_from_u64(6);
        let (m, d) = (120usize, 4usize);
        let beta_star = [0.4, -0.3, 0.2, 0.1];
        let mut x = vec![0.0f64; m * d];
        let mut y = vec![0.0f64; m];
        for i in 0..m {
            for j in 0..d - 1 {
                x[i * d + j] = (0.4 * rng.gen_normal()).clamp(-1.0, 1.0);
            }
            x[i * d + d - 1] = 1.0;
            y[i] = x[i * d..(i + 1) * d].iter().zip(&beta_star).map(|(&a, &b)| a * b).sum();
        }
        let beta = ridge_regression(&x, &y, d);
        for (b, bs) in beta.iter().zip(&beta_star) {
            assert!((b - bs).abs() < 1e-4, "recovered {b} vs planted {bs}");
        }
        assert!(r2(&x, &y, d, &beta) > 0.9999);
        assert!(mse(&x, &y, d, &beta) < 1e-8);
    }

    #[test]
    fn auc_separates_and_handles_ties() {
        // Perfect separator → AUC 1; anti-separator → 0; constant → 0.5.
        let x = vec![1.0, -1.0, 2.0, -2.0, 0.5, -0.5];
        let y = vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0];
        assert_eq!(auc(&x, &y, 1, &[1.0]), 1.0);
        assert_eq!(auc(&x, &y, 1, &[-1.0]), 0.0);
        assert_eq!(auc(&x, &y, 1, &[0.0]), 0.5, "all-tied scores average to 0.5");
        // one-class degenerate input
        assert_eq!(auc(&[1.0, 2.0], &[1.0, 1.0], 1, &[1.0]), 0.5);
    }

    #[test]
    fn r2_baselines() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = vec![2.0, 4.0, 6.0, 8.0];
        assert!((r2(&x, &y, 1, &[2.0]) - 1.0).abs() < 1e-12, "exact fit");
        // predicting the mean → R² = 0 needs an intercept; w = 0 predicts 0
        let r = r2(&x, &y, 1, &[0.0]);
        assert!(r < 0.0, "all-zero predictor must underperform the mean: {r}");
    }

    #[test]
    fn metrics_display_per_workload() {
        let ds = Dataset::synth(SynthSpec::smoke(), 7);
        let t = train_logreg(&ds, &LogRegOptions { iters: 20, eta: 1.0, ..Default::default() });
        let m = Logreg.metrics(&ds.x_test, &ds.y_test, ds.d, 2, &t.w);
        let s = m.to_string();
        assert!(s.contains("accuracy=") && s.contains("auc=") && s.contains("loss="), "{s}");
        assert!(m.auc.unwrap() > 0.85, "smoke AUC {:?}", m.auc);

        let lr = Linreg.reference(&ds, 0, 0.0, None);
        let m = Linreg.metrics(&ds.x_test, &ds.y_test, ds.d, 2, &lr.w);
        assert!(m.r2.is_some() && m.accuracy.is_none());
        assert!(m.to_string().contains("r2="));
    }
}
