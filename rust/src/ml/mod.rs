//! Plaintext machine learning: the f64 reference trainers ("conventional
//! logistic regression" of Fig. 4 and its multinomial/linear-regression
//! siblings), the least-squares polynomial fit of the sigmoid (Eq. 5),
//! quality metrics (accuracy, AUC, R²), and the [`model::Model`] workload
//! contract the secure layers dispatch through.

pub mod logreg;
pub mod model;
pub mod sigmoid;

pub use logreg::{train_logreg, LogRegOptions, TrainTrace};
pub use model::{
    auc, multiclass_accuracy, r2, train_multinomial, Model, ModelKind, ModelMetrics,
};
pub use sigmoid::{fit_sigmoid, sigmoid, SigmoidPoly};

/// Classification accuracy of model `w` on `(x, y)` using a polynomial or
/// exact link: prediction is `score > 0.5` where score = link(x·w). Any
/// monotone link gives the same result as thresholding `x·w > 0` only when
/// link(0)=0.5 — true for both the sigmoid and our fits.
pub fn accuracy(x: &[f64], y: &[f64], d: usize, w: &[f64]) -> f64 {
    let m = y.len();
    assert_eq!(x.len(), m * d);
    assert_eq!(w.len(), d);
    let mut correct = 0usize;
    for i in 0..m {
        let z: f64 = x[i * d..(i + 1) * d].iter().zip(w).map(|(&a, &b)| a * b).sum();
        let pred = if z > 0.0 { 1.0 } else { 0.0 };
        if (pred - y[i]).abs() < 0.5 {
            correct += 1;
        }
    }
    correct as f64 / m as f64
}

/// Cross-entropy loss (Eq. 1) with the exact sigmoid, clamped for
/// numerical safety.
pub fn cross_entropy(x: &[f64], y: &[f64], d: usize, w: &[f64]) -> f64 {
    let m = y.len();
    let mut loss = 0.0;
    for i in 0..m {
        let z: f64 = x[i * d..(i + 1) * d].iter().zip(w).map(|(&a, &b)| a * b).sum();
        let p = sigmoid(z).clamp(1e-12, 1.0 - 1e-12);
        loss -= y[i] * p.ln() + (1.0 - y[i]) * (1.0 - p).ln();
    }
    loss / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_of_perfect_separator() {
        // x = [z, 1] with label z>0; w = [1, 0] separates perfectly.
        let x = vec![1.0, 1.0, -1.0, 1.0, 2.0, 1.0, -2.0, 1.0];
        let y = vec![1.0, 0.0, 1.0, 0.0];
        assert_eq!(accuracy(&x, &y, 2, &[1.0, 0.0]), 1.0);
        assert_eq!(accuracy(&x, &y, 2, &[-1.0, 0.0]), 0.0);
    }

    #[test]
    fn cross_entropy_decreases_with_confidence() {
        let x = vec![1.0, -1.0];
        let y = vec![1.0, 0.0];
        let l1 = cross_entropy(&x, &y, 1, &[0.5]);
        let l2 = cross_entropy(&x, &y, 1, &[2.0]);
        assert!(l2 < l1);
    }
}
