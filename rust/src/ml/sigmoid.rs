//! Polynomial approximation of the sigmoid (paper Eq. 5): coefficients fit
//! by least squares on a grid, exactly as the paper describes ("evaluated
//! by fitting the sigmoid to the polynomial function via least squares
//! estimation"). Degree 1 is the paper's operating point (§V.A); degree 3
//! is supported for the ablation.

/// The exact sigmoid `g(z) = 1/(1+e^{−z})`.
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// A fitted polynomial `ĝ(z) = Σ c_i z^i`.
#[derive(Clone, Debug)]
pub struct SigmoidPoly {
    /// `coeffs[i]` multiplies `z^i`.
    pub coeffs: Vec<f64>,
    /// Half-range of the fit interval `[−r, r]`.
    pub half_range: f64,
}

impl SigmoidPoly {
    pub fn degree(&self) -> usize {
        self.coeffs.len() - 1
    }

    /// Evaluate `ĝ(z)`.
    pub fn eval(&self, z: f64) -> f64 {
        let mut acc = 0.0;
        for &c in self.coeffs.iter().rev() {
            acc = acc * z + c;
        }
        acc
    }

    /// Max absolute error against the true sigmoid over the fit interval.
    pub fn max_error(&self, samples: usize) -> f64 {
        (0..=samples)
            .map(|i| {
                let z = -self.half_range + 2.0 * self.half_range * i as f64 / samples as f64;
                (self.eval(z) - sigmoid(z)).abs()
            })
            .fold(0.0, f64::max)
    }
}

/// Least-squares fit of degree `degree` over `[−half_range, half_range]`
/// on a uniform grid. Solves the (small) normal equations by Gaussian
/// elimination with partial pivoting.
pub fn fit_sigmoid(degree: usize, half_range: f64, samples: usize) -> SigmoidPoly {
    assert!(degree >= 1 && degree <= 7);
    assert!(samples > degree * 4);
    let n = degree + 1;
    // Normal equations: (VᵀV) c = Vᵀ g, V_{ij} = z_i^j
    let mut ata = vec![0.0f64; n * n];
    let mut atb = vec![0.0f64; n];
    for i in 0..=samples {
        let z = -half_range + 2.0 * half_range * i as f64 / samples as f64;
        let g = sigmoid(z);
        let mut zp = vec![0.0f64; n];
        let mut acc = 1.0;
        for zj in zp.iter_mut() {
            *zj = acc;
            acc *= z;
        }
        for r in 0..n {
            atb[r] += zp[r] * g;
            for c in 0..n {
                ata[r * n + c] += zp[r] * zp[c];
            }
        }
    }
    let coeffs = solve_dense(&mut ata, &mut atb, n);
    SigmoidPoly { coeffs, half_range }
}

/// Gaussian elimination with partial pivoting for a dense n×n system
/// (the sigmoid fit's n ≤ 8 normal equations, and the model zoo's d×d
/// linear-regression normal equations — see `ml::model`). Consumes its
/// inputs.
pub(crate) fn solve_dense(a: &mut [f64], b: &mut [f64], n: usize) -> Vec<f64> {
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let diag = a[col * n + col];
        assert!(diag.abs() > 1e-300, "singular normal equations");
        for r in col + 1..n {
            let factor = a[r * n + col] / diag;
            if factor != 0.0 {
                for c in col..n {
                    a[r * n + c] -= factor * a[col * n + c];
                }
                b[r] -= factor * b[col];
            }
        }
    }
    let mut x = vec![0.0f64; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for c in col + 1..n {
            acc -= a[col * n + c] * x[c];
        }
        x[col] = acc / a[col * n + col];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!(sigmoid(10.0) > 0.9999);
        assert!(sigmoid(-10.0) < 0.0001);
        // symmetry g(-z) = 1 - g(z)
        for z in [0.3, 1.7, 5.0] {
            assert!((sigmoid(-z) - (1.0 - sigmoid(z))).abs() < 1e-12);
        }
    }

    #[test]
    fn degree1_fit_matches_expected_shape() {
        // Known result: LSE degree-1 fit of sigmoid on a symmetric interval
        // is c0 = 0.5 (by symmetry) and c1 > 0.
        let p = fit_sigmoid(1, 4.0, 2000);
        assert!((p.coeffs[0] - 0.5).abs() < 1e-6, "c0 = {}", p.coeffs[0]);
        assert!(p.coeffs[1] > 0.15 && p.coeffs[1] < 0.25, "c1 = {}", p.coeffs[1]);
    }

    #[test]
    fn degree3_fit_better_than_degree1() {
        let p1 = fit_sigmoid(1, 4.0, 2000);
        let p3 = fit_sigmoid(3, 4.0, 2000);
        assert!(p3.max_error(500) < p1.max_error(500));
        // odd symmetry: even coefficients ≈ 0 except c0 = 0.5
        assert!((p3.coeffs[0] - 0.5).abs() < 1e-6);
        assert!(p3.coeffs[2].abs() < 1e-8);
        assert!(p3.coeffs[3] < 0.0, "cubic term must bend toward saturation");
    }

    #[test]
    fn fit_error_reasonable() {
        // Degree-1 on [-4,4]: max error known to be ≈ 0.08–0.12.
        let p = fit_sigmoid(1, 4.0, 2000);
        let e = p.max_error(1000);
        assert!(e < 0.15, "max error {e}");
    }

    #[test]
    fn eval_horner_matches_direct() {
        let p = SigmoidPoly { coeffs: vec![0.5, 0.2, 0.0, -0.004], half_range: 4.0 };
        for z in [-3.0f64, -1.0, 0.0, 0.5, 2.9] {
            let direct: f64 = p.coeffs.iter().enumerate().map(|(i, c)| c * z.powi(i as i32)).sum();
            assert!((p.eval(z) - direct).abs() < 1e-12);
        }
    }

    #[test]
    fn solver_solves_known_system() {
        // [2 1; 1 3] x = [5; 10] → x = [1, 3]
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![5.0, 10.0];
        let x = solve_dense(&mut a, &mut b, 2);
        assert!((x[0] - 1.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
    }
}
