//! `copml` — CLI launcher for the COPML framework.
//!
//! ```text
//! copml train   --dataset smoke|cifar|gisette|csv:PATH --n 10 --case 1|2 [--k K --t T]
//!               [--model logreg|multinomial|linreg]  # workload (ml::Model zoo)
//!               [--iters 50] [--eta 2.0] [--mode algo|full] [--engine native|pjrt]
//!               [--batches B]            # mini-batch SGD: iteration i → batch i mod B
//!               [--threads 1]            # 0 = all cores (field::par)
//!               [--wire u64|u32]         # full mode: wire format / byte ledger
//!               [--offline dealer|distributed]  # full mode: offline randomness
//!               [--transport hub|tcp]    # full mode: in-process or TCP loopback
//!               [--runtime threaded|event]  # tcp: reader threads or poll reactor
//!               [--kernel barrett|mont]  # field kernel tier (bit-identical results)
//!               [--delay id:ms,...]      # full mode: per-iteration straggler sleep
//!               [--kill-after id:iter,...]  # full mode: kill party at iteration
//!               [--max-lag R]            # exclude after R consecutive missed quorums
//!               [--chunk C]              # pipelined offline factory (distributed only)
//! copml party   --id I --listen ADDR --peers A0,A1,...   # one distributed client
//!               [--wire u64|u32] [--offline dealer|distributed]
//!               [--runtime threaded|event] [+ train's dataset/config/fault options]
//! copml serve   --dataset smoke --n 4 --jobs J    # multi-job daemon over one mesh
//!               [--transport hub|tcp] [--chunk C] # job j+1 pools prefetch behind job j
//! copml bench   --dataset cifar --n 50 [--wire u64|u32]  # cost-model Table-I row
//!               [--offline dealer|distributed] [--stragglers S] [--batches B]
//!               [--runtime threaded|event]   # header note only (bytes are equal)
//! copml calibrate                                  # machine calibration
//! copml info                                       # config/threshold explorer
//! copml lint    [--root DIR]   # protocol static analyzer (CI gates on 0 findings)
//! ```
//!
//! Full usage and examples live in the top-level README (the distributed
//! mode — launching N `copml party` processes — has its own section).

// The binary never needs `unsafe`; the library's single allow-listed
// unsafe module is `net::reactor` (see `copml::analysis`).
#![forbid(unsafe_code)]

use copml::bench::{BaselineCost, Calibration, CopmlCost};
use copml::cli::Args;
use copml::coordinator::{algo, protocol, CaseParams, CopmlConfig, FaultPlan};
use copml::data::{BatchPlan, Dataset, SynthSpec};
use copml::field::{Field, Parallelism};
use copml::mpc::OfflineMode;
use copml::net::tcp::TcpTransport;
use copml::net::wan::WanModel;
use copml::net::{Runtime, Transport, Wire};
use copml::report::Table;
use copml::runtime::Engine;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("party") => cmd_party(&args),
        Some("serve") => cmd_serve(&args),
        Some("bench") => cmd_bench(&args),
        Some("calibrate") => cmd_calibrate(),
        Some("info") => cmd_info(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            eprintln!(
                "usage: copml <train|party|serve|bench|calibrate|info|lint> [options]   (see README)"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dataset_for(name: &str, seed: u64) -> Result<Dataset, String> {
    // `csv:PATH` loads a real dataset (tfe-logistic conventions: label in
    // the last column, 20% seeded held-out test split, train-stats
    // standardization — `data::csv`). Everything else is a synthetic spec.
    if let Some(path) = name.strip_prefix("csv:") {
        let opts = copml::data::csv::CsvOptions { seed, ..Default::default() };
        return copml::data::csv::load(path, opts).map_err(|e| format!("--dataset {name}: {e}"));
    }
    let spec = match name {
        "smoke" => SynthSpec::smoke(),
        "tiny" => SynthSpec::tiny(),
        "cifar" => SynthSpec::cifar_like(),
        "gisette" => SynthSpec::gisette_like(),
        other => {
            return Err(format!(
                "unknown dataset '{other}' (expected smoke|tiny|cifar|gisette|csv:PATH)"
            ))
        }
    };
    Ok(Dataset::synth(spec, seed))
}

/// The `train`/`party`-shared configuration options on top of a dataset.
fn config_from_args(args: &Args, ds: &Dataset, n: usize, seed: u64) -> Result<CopmlConfig, String> {
    let case = match args.get_or("case", 1usize)? {
        1 => CaseParams::case1(n),
        2 => CaseParams::case2(n),
        c => return Err(format!("--case must be 1 or 2 (got {c})")),
    };
    let mut cfg = CopmlConfig::for_dataset(ds, n, case, seed);
    // Workload selection (`--model logreg|multinomial|linreg`); logreg is
    // the default and bit-identical to every pre-existing trace.
    cfg.model = args.get_or("model", cfg.model)?;
    cfg.k = args.get_or("k", cfg.k)?;
    cfg.t = args.get_or("t", cfg.t)?;
    cfg.iters = args.get_or("iters", cfg.iters)?;
    cfg.batches = args.get_or("batches", cfg.batches)?;
    cfg.eta = args.get_or("eta", cfg.eta)?;
    cfg.wire = args.get_or("wire", Wire::U64)?;
    cfg.runtime = args.get_or("runtime", Runtime::Threaded)?;
    cfg.offline = args.get_or("offline", OfflineMode::Dealer)?;
    cfg.kernel = args.get_or("kernel", cfg.kernel)?;
    // Straggler experiments: injected faults + exclusion threshold
    // (validated against N/need in CopmlConfig::validate).
    if let Some(spec) = args.get("delay") {
        cfg.faults.delays = FaultPlan::parse_pairs(spec, "delay")?;
    }
    if let Some(spec) = args.get("kill-after") {
        cfg.faults.kills = FaultPlan::parse_pairs(spec, "kill-after")?
            .into_iter()
            .map(|(id, iter)| (id, iter as usize))
            .collect();
    }
    if args.get("max-lag").is_some() {
        cfg.max_lag = Some(args.get_or("max-lag", 0usize)?);
    }
    // Pipelined offline factory: generate the randomness in C-sized
    // chunks on a background producer (validate() requires --offline
    // distributed and no fault plan).
    if args.get("chunk").is_some() {
        cfg.chunk = Some(args.get_or("chunk", 0usize)?);
    }
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let seed = args.get_or("seed", 42u64)?;
    let ds = dataset_for(args.get("dataset").unwrap_or("smoke"), seed)?;
    let n = args.get_or("n", 10usize)?;
    let mut cfg = config_from_args(args, &ds, n, seed)?;
    cfg.engine = match args.get("engine").unwrap_or("native") {
        "native" => Engine::Native,
        "pjrt" => Engine::Pjrt,
        e => return Err(format!("unknown engine '{e}'")),
    };
    let mode = args.get("mode").unwrap_or("algo");
    cfg.parallelism = match args.get_or("threads", 1usize)? {
        0 if mode == "full" => {
            // Full-protocol mode already runs N concurrent client threads on
            // this machine; give each client its share of the cores instead
            // of oversubscribing N-fold.
            let cores =
                std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
            Parallelism::threads((cores / cfg.n.max(1)).max(1))
        }
        0 => Parallelism::auto(),
        n => Parallelism::threads(n),
    };
    println!(
        "COPML train: dataset={} (m={}, d={}, classes={})  model={}  N={} K={} T={} r={}  iters={} η={}  p={}  threads={}  offline={}  kernel={}",
        ds.name, ds.m, ds.d, ds.classes, cfg.model, cfg.n, cfg.k, cfg.t, cfg.r, cfg.iters,
        cfg.eta, cfg.plan.field.modulus(), cfg.parallelism.thread_count(), cfg.offline,
        cfg.kernel
    );
    // Batch schedule summary (grep-asserted by CI for --batches runs).
    // Infeasible geometries skip the print and fall through to validate's
    // clear error below.
    if (1..=ds.m).contains(&cfg.batches) && cfg.k >= 1 {
        let plan = BatchPlan::new(ds.m, cfg.k, cfg.batches, seed);
        let sizes: Vec<usize> = (0..plan.b).map(|b| plan.real_rows(b)).collect();
        println!(
            "batch schedule: B={} (real rows per batch {:?}, padded rows {}), iteration i → batch i mod {}",
            plan.b,
            sizes,
            plan.rows_padded(),
            plan.b
        );
    }
    let transport = args.get("transport").unwrap_or("hub");
    if transport != "hub" && mode != "full" {
        return Err(format!("--transport {transport} requires --mode full"));
    }
    let out = match mode {
        "algo" => algo::train(&cfg, &ds)?,
        "full" => {
            let po = match transport {
                "hub" => protocol::train(&cfg, &ds)?,
                "tcp" => protocol::train_tcp_loopback(&cfg, &ds)?,
                other => return Err(format!("unknown transport '{other}' (expected hub|tcp)")),
            };
            let mut table = Table::new(
                "per-client ledger (mean across clients)",
                &["phase", "seconds", "MB sent"],
            );
            for (i, phase) in protocol::PHASES.iter().enumerate() {
                let secs: f64 =
                    po.ledgers.iter().map(|l| l.seconds[i]).sum::<f64>() / po.ledgers.len() as f64;
                let mb: f64 = po.ledgers.iter().map(|l| l.bytes[i]).sum::<u64>() as f64
                    / po.ledgers.len() as f64
                    / 1e6;
                table.row(&[phase.to_string(), format!("{secs:.4}"), format!("{mb:.3}")]);
            }
            table.print();
            // Pipelined-offline split (only printed when --chunk hid
            // offline seconds behind the online rounds) — grep-asserted
            // by the fig_pipeline bench harness.
            let crit: f64 =
                po.ledgers.iter().map(|l| l.seconds[0]).sum::<f64>() / po.ledgers.len() as f64;
            let hidden: f64 = po.ledgers.iter().map(|l| l.offline_hidden_s).sum::<f64>()
                / po.ledgers.len() as f64;
            if hidden > 0.0 {
                println!(
                    "offline pipeline: critical {crit:.4}s + hidden {hidden:.4}s (overlap ratio {:.2})",
                    hidden / (hidden + crit).max(1e-12)
                );
            }
            // Quorum/straggler summary (king's ledger records every
            // round's quorum and exclusion) — grep-asserted by CI.
            let need = cfg.recovery_threshold();
            let l0 = &po.ledgers[0];
            let mut excluded = l0.excluded.clone();
            excluded.sort_unstable();
            let final_q = l0.quorums.last().map(|q| q.len()).unwrap_or(0);
            println!(
                "straggler summary: quorum need {need} of N={}, rounds {}, final quorum size {final_q}, excluded: {excluded:?}",
                cfg.n,
                l0.quorums.len()
            );
            po.train
        }
        m => return Err(format!("unknown mode '{m}'")),
    };
    // --verbose (a registered boolean flag — usable before the
    // subcommand too): print every iteration instead of every fifth.
    let every = if args.flag("verbose") { 1 } else { 5 };
    for (i, ((tr, te), loss)) in out
        .train_accuracy
        .iter()
        .zip(&out.test_accuracy)
        .zip(&out.loss)
        .enumerate()
    {
        if (i + 1) % every == 0 || i + 1 == out.loss.len() {
            println!(
                "iter {:>3}  loss {:.4}  train-score {:.4}  test-score {:.4}",
                i + 1,
                loss,
                tr,
                te
            );
        }
    }
    // Final-model quality through the workload's own metric set
    // (accuracy/AUC for the classifiers, R² for regression) — the line the
    // fig_models bench and EXPERIMENTS.md reference.
    println!(
        "train summary: model={}  train[{}]  test[{}]",
        cfg.model, out.train_metrics, out.test_metrics
    );
    Ok(())
}

/// One distributed client: establish the TCP mesh, run the full protocol,
/// print this party's ledger and final-model quality.
fn cmd_party(args: &Args) -> Result<(), String> {
    let id: usize = args
        .get("id")
        .ok_or("party needs --id I (0-based)")?
        .parse()
        .map_err(|_| "invalid --id (expected a 0-based integer)".to_string())?;
    let listen = args.get("listen").ok_or("party needs --listen ADDR (e.g. 127.0.0.1:9100)")?;
    let peers: Vec<String> = args
        .get("peers")
        .ok_or("party needs --peers A0,A1,… (every party's address, in id order)")?
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let n = peers.len();
    if id >= n {
        return Err(format!("--id {id} out of range for {n} peers"));
    }
    // Distributed clients run the native engine; reject --engine instead
    // of silently ignoring it (run_client would also refuse pjrt).
    if let Some(e) = args.get("engine") {
        if e != "native" {
            return Err(format!("party runs the native engine only (got --engine {e})"));
        }
    }
    let seed = args.get_or("seed", 42u64)?;
    let ds = dataset_for(args.get("dataset").unwrap_or("smoke"), seed)?;
    let mut cfg = config_from_args(args, &ds, n, seed)?;
    cfg.parallelism = match args.get_or("threads", 1usize)? {
        0 => Parallelism::auto(),
        nt => Parallelism::threads(nt),
    };
    println!(
        "COPML party {id}/{n}: listen={listen} wire={} runtime={} offline={}  dataset={} (m={}, d={})  K={} T={} iters={} B={}",
        cfg.wire, cfg.runtime, cfg.offline, ds.name, ds.m, ds.d, cfg.k, cfg.t, cfg.iters, cfg.batches
    );
    let net = TcpTransport::establish_runtime(id, listen, &peers, cfg.wire, cfg.runtime)
        .map_err(|e| format!("establishing the TCP mesh: {e}"))?;
    println!("party {id}: mesh up ({} peers), running the protocol …", n - 1);
    let t0 = std::time::Instant::now();
    let out = protocol::run_client(&cfg, &ds, &net)?;
    let mut table = Table::new(&format!("party {id} ledger"), &["phase", "seconds", "MB sent"]);
    for (i, phase) in protocol::PHASES.iter().enumerate() {
        table.row(&[
            phase.to_string(),
            format!("{:.4}", out.ledger.seconds[i]),
            format!("{:.3}", out.ledger.bytes[i] as f64 / 1e6),
        ]);
    }
    table.print();
    if out.ledger.offline_hidden_s > 0.0 {
        let crit = out.ledger.seconds[0];
        let hidden = out.ledger.offline_hidden_s;
        println!(
            "offline pipeline: critical {crit:.4}s + hidden {hidden:.4}s (overlap ratio {:.2})",
            hidden / (hidden + crit).max(1e-12)
        );
    }
    match out.test_metrics(&cfg, &ds) {
        Some(metrics) => {
            println!(
                "party {id} done in {:.2}s: test [{metrics}], {} B sent / {} B received ({} wire)",
                t0.elapsed().as_secs_f64(),
                net.bytes_sent(),
                net.bytes_received(),
                cfg.wire
            );
        }
        None => {
            // An expected fault-plan/straggler outcome, not an error: the
            // surviving quorum finishes training without this party.
            println!(
                "party {id} halted after {:.2}s: {}",
                t0.elapsed().as_secs_f64(),
                out.halted.as_deref().unwrap_or("unknown reason")
            );
        }
    }
    Ok(())
}

/// `copml serve`: hold one party mesh open and run a stream of training
/// jobs — job `j` trains in tag session `j` from seed `base + j`, so each
/// served job's model is bit-identical to a standalone `train` run with
/// that seed. With `--chunk`, job `j+1`'s offline pools are prefetched
/// behind job `j`'s online rounds. Prints per-job cost lines and the
/// summary line the CI smoke greps.
fn cmd_serve(args: &Args) -> Result<(), String> {
    let seed = args.get_or("seed", 42u64)?;
    let ds = dataset_for(args.get("dataset").unwrap_or("smoke"), seed)?;
    let n = args.get_or("n", 4usize)?;
    let jobs = args.get_or("jobs", 2usize)?;
    // Serve is native-engine only; reject --engine instead of ignoring it.
    if let Some(e) = args.get("engine") {
        if e != "native" {
            return Err(format!("serve runs the native engine only (got --engine {e})"));
        }
    }
    let mut cfg = config_from_args(args, &ds, n, seed)?;
    cfg.parallelism = match args.get_or("threads", 1usize)? {
        0 => {
            // N concurrent client threads share this machine — give each
            // its share of the cores (same rule as train --mode full).
            let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
            Parallelism::threads((cores / cfg.n.max(1)).max(1))
        }
        nt => Parallelism::threads(nt),
    };
    println!(
        "COPML serve: dataset={} (m={}, d={})  N={} K={} T={}  iters={} offline={} chunk={:?}  job stream of {jobs}",
        ds.name, ds.m, ds.d, cfg.n, cfg.k, cfg.t, cfg.iters, cfg.offline, cfg.chunk
    );
    let so = match args.get("transport").unwrap_or("hub") {
        "hub" => protocol::serve(&cfg, &ds, jobs)?,
        "tcp" => protocol::serve_tcp_loopback(&cfg, &ds, jobs)?,
        other => return Err(format!("unknown transport '{other}' (expected hub|tcp)")),
    };
    for (j, po) in so.jobs.iter().enumerate() {
        let nl = po.ledgers.len() as f64;
        let total: f64 = po.ledgers.iter().map(|l| l.total_seconds()).sum::<f64>() / nl;
        let crit: f64 = po.ledgers.iter().map(|l| l.seconds[0]).sum::<f64>() / nl;
        let hidden: f64 = po.ledgers.iter().map(|l| l.offline_hidden_s).sum::<f64>() / nl;
        let acc = po.train.test_accuracy.last().copied().unwrap_or(0.0);
        println!(
            "job {j}: total {total:.4}s  offline critical {crit:.4}s hidden {hidden:.4}s  test-acc {acc:.4}"
        );
    }
    if let Some((j, reason)) = &so.failed {
        println!("job {j}: FAILED — {reason}");
    }
    println!(
        "serve summary: jobs={} of {jobs} completed, wall {:.2}s, {:.1} jobs/hour",
        so.jobs.len(),
        so.wall_s,
        so.jobs_per_hour
    );
    if so.failed.is_some() {
        return Err("serve stream ended with a failed job".into());
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<(), String> {
    let seed = args.get_or("seed", 42u64)?;
    // The Table-I cost model (and the Appendix C/D baselines it compares
    // against) prices the degree-1 logreg pipeline; reject other workloads
    // instead of silently modeling the wrong one.
    if let Some(m) = args.get("model") {
        if m != "logreg" {
            return Err(format!("bench models the logreg workload only (got --model {m})"));
        }
    }
    let name = args.get("dataset").unwrap_or("cifar");
    let ds = dataset_for(name, seed)?;
    let n = args.get_or("n", 50usize)?;
    let iters = args.get_or("iters", 50usize)?;
    let wire: Wire = args.get_or("wire", Wire::U64)?;
    // Header note only: the runtime changes threads and wall-clock, never
    // bytes, so the modeled costs are runtime-invariant.
    let runtime: Runtime = args.get_or("runtime", Runtime::Threaded)?;
    let offline: OfflineMode = args.get_or("offline", OfflineMode::Dealer)?;
    // Straggler column: model S parties as excluded (N − S must stay at
    // or above each case's recovery threshold — estimate() checks).
    let stragglers = args.get_or("stragglers", 0usize)?;
    // Batches column: per-iteration compute scaled by rows_b/m, one-shot
    // per-batch encode charged up front (estimate() checks B ≥ 1).
    let batches = args.get_or("batches", 1usize)?;
    let plan = if ds.d > 4096 {
        copml::quant::FpPlan::paper_gisette()
    } else {
        copml::quant::FpPlan::paper_cifar()
    };
    println!("calibrating primitives …");
    let cal = Calibration::measure(plan.field);
    let wan = WanModel::paper();
    let mut table = Table::new(
        &format!("Table-I-style breakdown — {name}, N={n}, {iters} iterations, {batches} batches, {wire} wire, {runtime} runtime, {offline} offline, {stragglers} stragglers (modeled on measured primitives)"),
        &["Protocol", "Comp (s)", "Comm (s)", "Enc/Dec (s)", "Offline (s)", "Total (s)"],
    );
    let case1 = CaseParams::case1(n);
    let case2 = CaseParams::case2(n);
    for (label, k, t) in [
        ("COPML (Case 1)", case1.k, case1.t),
        ("COPML (Case 2)", case2.k, case2.t),
    ] {
        let c = CopmlCost {
            n,
            k,
            t,
            r: 1,
            m: ds.m,
            d: ds.d,
            iters,
            batches,
            subgroups: true,
            wire,
            offline,
            trunc_bits: plan.k2 + plan.kappa,
            stragglers,
        }
        .estimate(&cal, &wan);
        table.row_f64(label, &[c.comp_s, c.comm_s, c.encdec_s, c.offline_s, c.total_s()], 1);
    }
    for (label, bgw) in [("MPC using [BGW88]", true), ("MPC using [BH08]", false)] {
        // The baselines follow the same batch schedule (batch-fair table:
        // their per-iteration vectors shrink with B too).
        let mut bc = BaselineCost::paper(n, ds.m, ds.d, iters, bgw);
        bc.batches = batches;
        let c = bc.estimate(&cal, &wan);
        table.row_f64(label, &[c.comp_s, c.comm_s, c.encdec_s, c.offline_s, c.total_s()], 1);
    }
    table.print();
    Ok(())
}

fn cmd_calibrate() -> Result<(), String> {
    let cal = Calibration::measure(Field::paper_cifar());
    println!("machine calibration (p = 2^26 − 5):");
    println!("  weighted-sum muladd : {:.1} M element·terms/s", cal.muladd_per_s / 1e6);
    println!("  gradient kernel     : {:.1} M cells/s", cal.kernel_cells_per_s / 1e6);
    println!("  shamir share eval   : {:.1} M element·shares/s", cal.share_per_s / 1e6);
    Ok(())
}

/// `copml lint`: run the protocol static analyzer over the crate sources
/// (rule catalog in [`copml::analysis`]). Prints one line per finding plus
/// the summary line CI greps, and fails (exit 1) on any finding.
fn cmd_lint(args: &Args) -> Result<(), String> {
    let root = match args.get("root") {
        Some(r) => std::path::PathBuf::from(r),
        None => ["rust/src", "src"]
            .iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.join("lib.rs").is_file())
            // Fall back to the build-time source path (e.g. `cargo run --
            // lint` from an arbitrary working directory).
            .unwrap_or_else(|| {
                std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/src"))
            }),
    };
    let report = copml::analysis::run_lint(&root)?;
    print!("{}", report.render());
    if report.ok() {
        Ok(())
    } else {
        Err(format!(
            "{} lint finding(s) under {}",
            report.findings.len(),
            root.display()
        ))
    }
}

fn cmd_info(args: &Args) -> Result<(), String> {
    let n = args.get_or("n", 50usize)?;
    let mut table = Table::new(
        &format!("COPML operating points for N = {n} (r = 1)"),
        &["case", "K", "T", "recovery threshold"],
    );
    for (label, c) in [("Case 1", CaseParams::case1(n)), ("Case 2", CaseParams::case2(n))] {
        table.row(&[
            label.to_string(),
            c.k.to_string(),
            c.t.to_string(),
            copml::lcc::recovery_threshold(1, c.k, c.t).to_string(),
        ]);
    }
    table.print();
    Ok(())
}
