//! Row-bucket padding for AOT artifacts.
//!
//! PJRT executables are compiled for fixed shapes. Rather than one artifact
//! per exact `(m/K) × d` block, artifacts are compiled for a geometric
//! ladder of row counts and inputs are zero-padded up to the bucket.
//! Padding is exact for Eq. (7): a zero row `x_r = 0` contributes
//! `x_{r,j}·ĝ(x_r·w̃) = 0·ĝ(0) = 0` to every output coordinate (verified in
//! `runtime::native::tests::zero_rows_do_not_contribute` and in the
//! python kernel tests).

/// The row buckets artifacts are compiled for (geometric, ×2).
pub const ROW_BUCKETS: [usize; 10] = [8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096];

/// Smallest bucket `≥ rows`, or `None` if larger than every bucket.
pub fn bucket_rows(rows: usize) -> Option<usize> {
    ROW_BUCKETS.iter().copied().find(|&b| b >= rows)
}

/// Zero-pad a row-major `(rows × cols)` matrix to `target_rows`.
pub fn pad_rows(x: &[u64], rows: usize, cols: usize, target_rows: usize) -> Vec<u64> {
    assert_eq!(x.len(), rows * cols);
    assert!(target_rows >= rows);
    let mut out = Vec::with_capacity(target_rows * cols);
    out.extend_from_slice(x);
    out.resize(target_rows * cols, 0);
    out
}

/// Maximum wasted-compute ratio of the ladder (worst case one row past the
/// previous bucket): used by the §Perf analysis.
pub fn worst_waste_ratio() -> f64 {
    let mut worst: f64 = 0.0;
    for w in ROW_BUCKETS.windows(2) {
        let rows = w[0] + 1;
        worst = worst.max(w[1] as f64 / rows as f64);
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_monotone() {
        for w in ROW_BUCKETS.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_rows(1), Some(8));
        assert_eq!(bucket_rows(8), Some(8));
        assert_eq!(bucket_rows(9), Some(16));
        assert_eq!(bucket_rows(4096), Some(4096));
        assert_eq!(bucket_rows(4097), None);
    }

    #[test]
    fn pad_preserves_prefix_and_zeroes_rest() {
        let x = vec![1, 2, 3, 4, 5, 6];
        let padded = pad_rows(&x, 2, 3, 4);
        assert_eq!(&padded[..6], &x[..]);
        assert!(padded[6..].iter().all(|&v| v == 0));
        assert_eq!(padded.len(), 12);
    }

    #[test]
    fn waste_bounded_by_two() {
        assert!(worst_waste_ratio() <= 2.0);
    }
}
