//! PJRT engine: load the AOT-compiled JAX/Pallas artifacts and execute the
//! encoded gradient from rust. This is the production hot path — python is
//! involved only at build time (`make artifacts`).
//!
//! Interchange is **HLO text** (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) lists one
//! entry per compiled shape:
//! `{file, p, degree, rows, cols, kernel}` — `rows` are bucket sizes from
//! [`super::padding::ROW_BUCKETS`], `kernel` is `"pallas"` (L1 kernel) or
//! `"jnp"` (pure-jnp L2 reference lowering, used for parity testing).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

use super::{padding, GradKernelLocal};
use crate::field::MatShape;
use crate::report::Json;

/// One artifact's metadata from the manifest.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub file: String,
    pub p: u64,
    pub degree: usize,
    pub rows: usize,
    pub cols: usize,
    pub kernel: String,
}

/// Runtime over a directory of AOT artifacts. Not `Send` (PJRT client is
/// `Rc`-based) — host it behind a [`super::KernelServer`] for threaded use.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    entries: Vec<ArtifactMeta>,
    /// Executable cache keyed by manifest index.
    cache: RefCell<HashMap<usize, Rc<xla::PjRtLoadedExecutable>>>,
    /// Which kernel flavour to select ("pallas" or "jnp").
    pub flavour: String,
}

impl PjrtRuntime {
    /// Load the manifest from `dir` and create a CPU PJRT client.
    pub fn load(dir: &Path) -> Result<PjrtRuntime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let doc = Json::parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let mut entries = Vec::new();
        for a in doc
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts'"))?
        {
            entries.push(ArtifactMeta {
                file: a.get("file").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                p: a.get("p").and_then(|v| v.as_u64()).unwrap_or(0),
                degree: a.get("degree").and_then(|v| v.as_usize()).unwrap_or(0),
                rows: a.get("rows").and_then(|v| v.as_usize()).unwrap_or(0),
                cols: a.get("cols").and_then(|v| v.as_usize()).unwrap_or(0),
                kernel: a.get("kernel").and_then(|v| v.as_str()).unwrap_or("pallas").to_string(),
            });
        }
        if entries.is_empty() {
            bail!("manifest has no artifacts");
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(PjrtRuntime { client, dir: dir.to_path_buf(), entries, cache: RefCell::new(HashMap::new()), flavour: "pallas".into() })
    }

    /// Default artifact directory: `$COPML_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("COPML_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn entries(&self) -> &[ArtifactMeta] {
        &self.entries
    }

    /// Find the manifest entry for `(p, degree, cols)` whose row bucket fits
    /// `rows`.
    fn find(&self, p: u64, degree: usize, rows: usize, cols: usize) -> Result<usize> {
        let mut best: Option<usize> = None;
        for (i, e) in self.entries.iter().enumerate() {
            if e.p == p && e.degree == degree && e.cols == cols && e.kernel == self.flavour && e.rows >= rows {
                if best.map_or(true, |b| e.rows < self.entries[b].rows) {
                    best = Some(i);
                }
            }
        }
        best.ok_or_else(|| {
            anyhow!(
                "no artifact for p={p} degree={degree} cols={cols} rows≥{rows} flavour={} — \
                 add the shape to python/compile/aot.py and re-run `make artifacts`",
                self.flavour
            )
        })
    }

    /// True if an artifact covering this shape exists.
    pub fn supports(&self, p: u64, degree: usize, rows: usize, cols: usize) -> bool {
        self.find(p, degree, rows, cols).is_ok()
    }

    fn executable(&self, idx: usize) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(&idx) {
            return Ok(e.clone());
        }
        let meta = &self.entries[idx];
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", meta.file))?;
        let exe = Rc::new(exe);
        self.cache.borrow_mut().insert(idx, exe.clone());
        Ok(exe)
    }

    /// Execute Eq. (7) via the artifact, padding rows to the bucket.
    pub fn run(
        &self,
        p: u64,
        x_enc: &[u64],
        shape: MatShape,
        w_enc: &[u64],
        coeffs_q: &[u64],
    ) -> Result<Vec<u64>> {
        let degree = coeffs_q.len() - 1;
        let idx = self.find(p, degree, shape.rows, shape.cols)?;
        let bucket = self.entries[idx].rows;
        let exe = self.executable(idx)?;
        let padded;
        let x_view: &[u64] = if bucket == shape.rows {
            x_enc
        } else {
            padded = padding::pad_rows(x_enc, shape.rows, shape.cols, bucket);
            &padded
        };
        let x_lit = xla::Literal::vec1(x_view)
            .reshape(&[bucket as i64, shape.cols as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let w_lit = xla::Literal::vec1(w_enc);
        let c_lit = xla::Literal::vec1(coeffs_q);
        let result = exe
            .execute::<xla::Literal>(&[x_lit, w_lit, c_lit])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let tuple = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let out = tuple.to_vec::<u64>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        Ok(out)
    }
}

impl GradKernelLocal for PjrtRuntime {
    fn encoded_gradient_local(
        &self,
        x_enc: &[u64],
        shape: MatShape,
        w_enc: &[u64],
        coeffs_q: &[u64],
    ) -> Vec<u64> {
        // Modulus is implied by the artifact set: entries are filtered by p
        // at `find` time via the coordinator passing the right p.
        let p = self
            .entries
            .iter()
            .find(|e| e.cols == shape.cols)
            .map(|e| e.p)
            .expect("no artifact matches cols");
        self.run(p, x_enc, shape, w_enc, coeffs_q)
            .expect("PJRT execution failed")
    }
}
