//! Pure-rust engine for the encoded gradient — the reference the PJRT path
//! is validated against, and the default engine for heavily-threaded tests.

use super::{GradKernel, GradKernelLocal};
use crate::field::{par, vecops, Field, KernelTier, MatShape, MontField, Parallelism};

/// Computes `X̃ᵀ ĝ(X̃·w̃) mod p` with `field::vecops` (tiled accumulation,
/// Barrett reduction) or, under [`KernelTier::Mont`], the lane-blocked
/// batch-Montgomery kernels of `field::mont` — optionally row-blocked
/// across a scoped thread pool. Both tiers are bit-identical.
#[derive(Clone, Copy)]
pub struct NativeKernel {
    f: Field,
    par: Parallelism,
    tier: KernelTier,
}

/// Minimum matrix cells per worker before the kernel fans out.
const MIN_PAR_CELLS: usize = 1 << 15;

/// One fused pass over a row block (§Perf optimization #2): each row
/// computes `z_i = x_i·w̃`, `g_i = ĝ(z_i)`, and immediately accumulates
/// `g_i·x_i` into the output — halving the memory traffic of the naive
/// matvec → poly → matvecᵀ pipeline (the kernel is DRAM-bandwidth-bound at
/// paper shapes; 1.7× measured at 2048×3073). Returns a fully reduced
/// `cols`-vector.
fn fused_block(f: Field, x_block: &[u64], cols: usize, w_enc: &[u64], coeffs_q: &[u64]) -> Vec<u64> {
    let rows = x_block.len() / cols.max(1);
    let budget = f.accum_budget();
    let mut out = vec![0u64; cols];
    let mut pending = 0usize;
    for r in 0..rows {
        let row = &x_block[r * cols..(r + 1) * cols];
        // z = x_i · w̃ (tiled reduction)
        let z = vecops::dot(f, row, w_enc);
        // g = ĝ(z) by Horner
        let mut g = *coeffs_q
            .last()
            .expect("empty sigmoid coefficient vector: ĝ needs at least its constant term");
        for &c in coeffs_q.iter().rev().skip(1) {
            g = f.reduce(f.reduce(g * z) + c);
        }
        // out += g · x_i with budget-bounded accumulation
        if pending + 1 > budget {
            for o in out.iter_mut() {
                *o = f.reduce(*o);
            }
            pending = 0;
        }
        if g != 0 {
            for (o, &v) in out.iter_mut().zip(row) {
                *o += g * v;
            }
        }
        pending += 1;
    }
    for o in out.iter_mut() {
        *o = f.reduce(*o);
    }
    out
}

/// The fused pass on the Montgomery tier: per row, `z = x_i·w̄` via the
/// mixed-domain lane-blocked dot (plain matrix × pre-converted `w̄`, one
/// REDC per budget tile), `g = ĝ(z)` by mixed-domain Horner (one REDC per
/// step), then one `to_mont(g)` per row — amortized over `cols` — feeds the
/// raw lane-blocked output accumulation. The budget flush goes through a
/// separate canonical carry (`field::mont` module docs: a flushed value is
/// plain, incoming products still carry the `R` factor — they must not
/// share an accumulator).
fn fused_block_mont(
    mf: &MontField,
    x_block: &[u64],
    cols: usize,
    w_mont: &[u64],
    coeffs_q: &[u64],
) -> Vec<u64> {
    let f = mf.field();
    let rows = x_block.len() / cols.max(1);
    let budget = f.accum_budget();
    if rows > 0 {
        assert!(
            !coeffs_q.is_empty(),
            "empty sigmoid coefficient vector: ĝ needs at least its constant term"
        );
    }
    let mut acc = vec![0u64; cols]; // raw Montgomery-weighted sums
    let mut out = vec![0u64; cols]; // canonical carry
    let mut pending = 0usize;
    for r in 0..rows {
        let row = &x_block[r * cols..(r + 1) * cols];
        let z = mf.dot_premont(row, w_mont);
        let g = mf.poly_eval_one(coeffs_q, z);
        if pending + 1 > budget {
            for (o, a) in out.iter_mut().zip(acc.iter_mut()) {
                *o = f.add(*o, mf.redc(*a as u128));
                *a = 0;
            }
            pending = 0;
        }
        if g != 0 {
            vecops::axpy_raw_lanes(&mut acc, mf.to_mont(g), row);
        }
        pending += 1;
    }
    for (o, &a) in out.iter_mut().zip(acc.iter()) {
        *o = f.add(*o, mf.redc(a as u128));
    }
    out
}

impl NativeKernel {
    pub fn new(f: Field) -> NativeKernel {
        NativeKernel { f, par: Parallelism::sequential(), tier: KernelTier::Barrett }
    }

    /// Kernel that row-blocks Eq. (7) across `par` worker threads. Results
    /// are bit-identical to the sequential kernel: each block runs the same
    /// budget-disciplined fused pass, and reduced partials combine with
    /// exact mod-`p` addition.
    pub fn with_parallelism(f: Field, par: Parallelism) -> NativeKernel {
        NativeKernel { f, par, tier: KernelTier::Barrett }
    }

    /// Kernel with an explicit field-kernel tier (`--kernel barrett|mont`).
    pub fn with_tier(f: Field, par: Parallelism, tier: KernelTier) -> NativeKernel {
        NativeKernel { f, par, tier }
    }
}

impl GradKernel for NativeKernel {
    fn encoded_gradient(
        &self,
        x_enc: &[u64],
        shape: MatShape,
        w_enc: &[u64],
        coeffs_q: &[u64],
    ) -> Vec<u64> {
        let f = self.f;
        let (rows, cols) = (shape.rows, shape.cols);
        assert_eq!(x_enc.len(), rows * cols);
        if cols == 0 {
            assert!(w_enc.is_empty());
            return Vec::new();
        }
        // Multi-class models stack one `cols`-wide model vector per class
        // (class-major); each class runs the identical fused pass over the
        // shared encoded dataset, and the outputs concatenate class-major.
        // `classes == 1` is byte-for-byte the historical single-model path.
        assert!(
            !w_enc.is_empty() && w_enc.len() % cols == 0,
            "model vector length {} is not a positive multiple of cols {}",
            w_enc.len(),
            cols
        );
        let classes = w_enc.len() / cols;
        // One fan-out policy (Parallelism::workers_for): each worker gets
        // at least MIN_PAR_CELLS cells, and never more workers than rows.
        let workers = self.par.workers_for(rows * cols, MIN_PAR_CELLS).min(rows.max(1));
        let mut out = Vec::with_capacity(classes * cols);
        match self.tier {
            KernelTier::Barrett => {
                for wc in w_enc.chunks_exact(cols) {
                    if workers <= 1 {
                        out.extend_from_slice(&fused_block(f, x_enc, cols, wc, coeffs_q));
                    } else {
                        out.extend_from_slice(&par::row_block_reduce(
                            f,
                            x_enc,
                            rows,
                            cols,
                            workers,
                            |x_b, _first_row| fused_block(f, x_b, cols, wc, coeffs_q),
                        ));
                    }
                }
            }
            KernelTier::Mont => {
                let mf = MontField::new(f);
                let wm = mf.to_mont_vec(w_enc); // one conversion per pass
                for wmc in wm.chunks_exact(cols) {
                    if workers <= 1 {
                        out.extend_from_slice(&fused_block_mont(&mf, x_enc, cols, wmc, coeffs_q));
                    } else {
                        out.extend_from_slice(&par::row_block_reduce(
                            f,
                            x_enc,
                            rows,
                            cols,
                            workers,
                            |x_b, _first_row| fused_block_mont(&mf, x_b, cols, wmc, coeffs_q),
                        ));
                    }
                }
            }
        }
        out
    }
}

impl GradKernelLocal for NativeKernel {
    fn encoded_gradient_local(
        &self,
        x_enc: &[u64],
        shape: MatShape,
        w_enc: &[u64],
        coeffs_q: &[u64],
    ) -> Vec<u64> {
        self.encoded_gradient(x_enc, shape, w_enc, coeffs_q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::P26;
    use crate::prng::Rng;

    /// i128 reference implementation.
    fn reference(p: u64, x: &[u64], rows: usize, cols: usize, w: &[u64], c: &[u64]) -> Vec<u64> {
        let pm = p as u128;
        let mut z = vec![0u128; rows];
        for i in 0..rows {
            let mut acc = 0u128;
            for j in 0..cols {
                acc = (acc + x[i * cols + j] as u128 * w[j] as u128) % pm;
            }
            // poly
            let mut g = 0u128;
            let mut zp = 1u128;
            for &ci in c {
                g = (g + ci as u128 * zp) % pm;
                zp = zp * acc % pm;
            }
            z[i] = g;
        }
        let mut out = vec![0u64; cols];
        for j in 0..cols {
            let mut acc = 0u128;
            for i in 0..rows {
                acc = (acc + x[i * cols + j] as u128 * z[i]) % pm;
            }
            out[j] = acc as u64;
        }
        out
    }

    #[test]
    fn matches_i128_reference() {
        let f = Field::new(P26);
        let k = NativeKernel::new(f);
        let mut r = Rng::seed_from_u64(1);
        for (rows, cols, deg) in [(7usize, 5usize, 1usize), (16, 9, 3), (33, 21, 1)] {
            let x: Vec<u64> = (0..rows * cols).map(|_| r.gen_range(P26)).collect();
            let w: Vec<u64> = (0..cols).map(|_| r.gen_range(P26)).collect();
            let c: Vec<u64> = (0..=deg).map(|_| r.gen_range(P26)).collect();
            let got = k.encoded_gradient(&x, MatShape::new(rows, cols), &w, &c);
            let want = reference(P26, &x, rows, cols, &w, &c);
            assert_eq!(got, want, "rows={rows} cols={cols} deg={deg}");
        }
    }

    #[test]
    fn parallel_kernel_bit_identical_to_sequential() {
        // Above and below the fan-out threshold, across thread counts.
        let f = Field::new(P26);
        let mut r = Rng::seed_from_u64(7);
        for (rows, cols) in [(64usize, 33usize), (700, 97), (2048, 40)] {
            let x: Vec<u64> = (0..rows * cols).map(|_| r.gen_range(P26)).collect();
            let w: Vec<u64> = (0..cols).map(|_| r.gen_range(P26)).collect();
            let c: Vec<u64> = vec![r.gen_range(P26), r.gen_range(P26)];
            let shape = MatShape::new(rows, cols);
            let seq = NativeKernel::new(f).encoded_gradient(&x, shape, &w, &c);
            for threads in [2usize, 3, 4, 8] {
                let par = NativeKernel::with_parallelism(f, Parallelism::threads(threads))
                    .encoded_gradient(&x, shape, &w, &c);
                assert_eq!(par, seq, "{rows}x{cols} threads={threads}");
            }
        }
    }

    #[test]
    fn mont_tier_bit_identical_to_barrett() {
        // Kernel-tier transparency at the fused-gradient level: same
        // shapes as the parallel test, both primes (P31 forces the
        // mid-budget carry flush every 4 rows), sequential and threaded.
        for p in [P26, crate::field::P31] {
            let f = Field::new(p);
            let mut r = Rng::seed_from_u64(5);
            for (rows, cols) in [(1usize, 1usize), (9, 6), (64, 33), (700, 97)] {
                let x: Vec<u64> = (0..rows * cols).map(|_| r.gen_range(p)).collect();
                let w: Vec<u64> = (0..cols).map(|_| r.gen_range(p)).collect();
                let c: Vec<u64> = vec![r.gen_range(p), r.gen_range(p), r.gen_range(p)];
                let shape = MatShape::new(rows, cols);
                let barrett = NativeKernel::new(f).encoded_gradient(&x, shape, &w, &c);
                for threads in [1usize, 3, 8] {
                    let mont =
                        NativeKernel::with_tier(f, Parallelism::threads(threads), KernelTier::Mont)
                            .encoded_gradient(&x, shape, &w, &c);
                    assert_eq!(mont, barrett, "p={p} {rows}x{cols} threads={threads}");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "empty sigmoid coefficient vector")]
    fn empty_sigmoid_coefficients_panic_on_mont_tier_too() {
        let f = Field::new(P26);
        let k = NativeKernel::with_tier(f, Parallelism::sequential(), KernelTier::Mont);
        k.encoded_gradient(&[1, 2, 3, 4], MatShape::new(2, 2), &[1, 1], &[]);
    }

    #[test]
    #[should_panic(expected = "empty sigmoid coefficient vector")]
    fn empty_sigmoid_coefficients_panic_clearly() {
        // Regression: this used to die on an anonymous `last().unwrap()`.
        let f = Field::new(P26);
        let k = NativeKernel::new(f);
        k.encoded_gradient(&[1, 2, 3, 4], MatShape::new(2, 2), &[1, 1], &[]);
    }

    #[test]
    fn multiclass_pass_matches_per_class_calls() {
        // A stacked class-major model vector must produce exactly the
        // concatenation of C independent single-class passes — on both
        // kernel tiers, sequential and threaded.
        let f = Field::new(P26);
        let mut r = Rng::seed_from_u64(11);
        let (rows, cols, classes) = (40usize, 9usize, 3usize);
        let x: Vec<u64> = (0..rows * cols).map(|_| r.gen_range(P26)).collect();
        let w: Vec<u64> = (0..classes * cols).map(|_| r.gen_range(P26)).collect();
        let c: Vec<u64> = vec![r.gen_range(P26), r.gen_range(P26)];
        let shape = MatShape::new(rows, cols);
        for tier in [KernelTier::Barrett, KernelTier::Mont] {
            for threads in [1usize, 4] {
                let k = NativeKernel::with_tier(f, Parallelism::threads(threads), tier);
                let stacked = k.encoded_gradient(&x, shape, &w, &c);
                assert_eq!(stacked.len(), classes * cols);
                for cl in 0..classes {
                    let solo = k.encoded_gradient(&x, shape, &w[cl * cols..(cl + 1) * cols], &c);
                    assert_eq!(stacked[cl * cols..(cl + 1) * cols], solo[..], "class {cl}");
                }
            }
        }
    }

    #[test]
    fn zero_rows_do_not_contribute() {
        // The padding invariant: appending zero rows never changes f.
        let f = Field::new(P26);
        let k = NativeKernel::new(f);
        let mut r = Rng::seed_from_u64(2);
        let (rows, cols) = (9usize, 6usize);
        let x: Vec<u64> = (0..rows * cols).map(|_| r.gen_range(P26)).collect();
        let w: Vec<u64> = (0..cols).map(|_| r.gen_range(P26)).collect();
        let c = vec![123456u64, 777u64]; // ĝ(0) = c0 ≠ 0 — stresses the claim
        let base = k.encoded_gradient(&x, MatShape::new(rows, cols), &w, &c);
        let mut padded = x.clone();
        padded.extend(std::iter::repeat(0).take(5 * cols));
        let got = k.encoded_gradient(&padded, MatShape::new(rows + 5, cols), &w, &c);
        assert_eq!(got, base);
    }
}
