//! Execution runtime for the per-client encoded-gradient hot path
//! `f(X̃, w̃) = X̃ᵀ ĝ(X̃·w̃)` over `F_p` (paper Eq. 7).
//!
//! Two interchangeable engines implement [`GradKernel`]:
//!
//! * [`native::NativeKernel`] — the **default engine**: a pure-rust
//!   implementation on `field::vecops` (optionally row-blocked across
//!   threads via [`crate::field::par::Parallelism`]), used by the
//!   massively-threaded full-fidelity tests and as the baseline the PJRT
//!   path is cross-validated against (`tests/runtime_parity.rs`).
//! * `pjrt::PjrtRuntime` (behind the `pjrt` cargo feature) — loads the AOT
//!   artifacts (`artifacts/*.hlo.txt`, produced once by
//!   `python/compile/aot.py` from the JAX/Pallas L1+L2 stack), compiles
//!   them on the PJRT CPU client and executes them from rust. **Python
//!   never runs here.** `PjRtClient` is `Rc`-based (not `Send`), so
//!   [`KernelServer`] hosts it on a dedicated thread and hands out
//!   cloneable, `Send` [`KernelHandle`]s to the client threads.
//!
//! Artifacts are compiled for **row buckets** (`padding::bucket_rows`);
//! zero-padding rows is exact because a zero row contributes
//! `0·ĝ(0·w̃) = 0` to every output coordinate (see `padding` tests).

pub mod native;
pub mod padding;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::field::MatShape;

/// The per-client computation of Eq. (7): given the encoded data block and
/// the encoded model, return `X̃ᵀ ĝ(X̃·w̃) (mod p)`, where `ĝ` has the
/// provided quantized coefficients (`coeffs_q[i]` multiplies `z^i`).
pub trait GradKernel: Send {
    fn encoded_gradient(
        &self,
        x_enc: &[u64],
        shape: MatShape,
        w_enc: &[u64],
        coeffs_q: &[u64],
    ) -> Vec<u64>;
}

/// Which engine executes Eq. (7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Pure-rust field kernels (optionally multi-threaded — the default).
    Native,
    /// AOT-compiled JAX/Pallas artifacts via PJRT. Requires building with
    /// `--features pjrt`; selecting it otherwise is a runtime
    /// configuration error.
    Pjrt,
}

use std::sync::mpsc;

enum Request {
    Run {
        x_enc: Vec<u64>,
        shape: MatShape,
        w_enc: Vec<u64>,
        coeffs_q: Vec<u64>,
        reply: mpsc::Sender<Vec<u64>>,
    },
    Shutdown,
}

/// Dedicated thread owning the (non-`Send`) PJRT runtime; serves
/// [`KernelHandle`] requests. Requests are processed in FIFO order — in the
/// protocol's bulk-synchronous compute phase this serializes client
/// compute, which the timing ledger accounts for separately (the simulator
/// charges *measured single-client* time, not wall-clock of the
/// simulation).
pub struct KernelServer {
    tx: mpsc::Sender<Request>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl KernelServer {
    /// Spawn the server with a factory for the underlying kernel (the
    /// factory runs on the server thread, where `Rc`s are fine).
    pub fn spawn<F, K>(factory: F) -> KernelServer
    where
        F: FnOnce() -> K + Send + 'static,
        K: GradKernelLocal,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let join = std::thread::spawn(move || {
            let kernel = factory();
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Run { x_enc, shape, w_enc, coeffs_q, reply } => {
                        let out = kernel.encoded_gradient_local(&x_enc, shape, &w_enc, &coeffs_q);
                        let _ = reply.send(out);
                    }
                    Request::Shutdown => break,
                }
            }
        });
        KernelServer { tx, join: Some(join) }
    }

    /// A cloneable, `Send` handle for client threads.
    pub fn handle(&self) -> KernelHandle {
        KernelHandle { tx: self.tx.clone() }
    }
}

impl Drop for KernelServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Like [`GradKernel`] but without the `Send` bound — implemented by the
/// PJRT runtime, hosted behind a [`KernelServer`].
pub trait GradKernelLocal: 'static {
    fn encoded_gradient_local(
        &self,
        x_enc: &[u64],
        shape: MatShape,
        w_enc: &[u64],
        coeffs_q: &[u64],
    ) -> Vec<u64>;
}

/// `Send` handle to a [`KernelServer`].
#[derive(Clone)]
pub struct KernelHandle {
    tx: mpsc::Sender<Request>,
}

impl GradKernel for KernelHandle {
    fn encoded_gradient(
        &self,
        x_enc: &[u64],
        shape: MatShape,
        w_enc: &[u64],
        coeffs_q: &[u64],
    ) -> Vec<u64> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Run {
                x_enc: x_enc.to_vec(),
                shape,
                w_enc: w_enc.to_vec(),
                coeffs_q: coeffs_q.to_vec(),
                reply,
            })
            .expect("kernel server gone");
        rx.recv().expect("kernel server dropped reply")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{Field, P26};

    #[test]
    fn kernel_server_serves_native_kernel_across_threads() {
        let f = Field::new(P26);
        let server = KernelServer::spawn(move || native::NativeKernel::new(f));
        let handle = server.handle();
        let shape = MatShape::new(4, 3);
        let x: Vec<u64> = (1..=12).collect();
        let w: Vec<u64> = vec![1, 2, 3];
        let coeffs = vec![5u64, 7u64];
        let direct = native::NativeKernel::new(f).encoded_gradient(&x, shape, &w, &coeffs);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let h = handle.clone();
                let (x, w, c, direct) = (x.clone(), w.clone(), coeffs.clone(), direct.clone());
                std::thread::spawn(move || {
                    let out = h.encoded_gradient(&x, shape, &w, &c);
                    assert_eq!(out, direct);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
