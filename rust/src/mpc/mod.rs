//! Secure multi-party computation over Shamir shares (paper Appendix C).
//!
//! Semi-honest, information-theoretic MPC between `N` parties with
//! threshold `T`:
//!
//! * **addition / subtraction / multiplication-by-public-constant** — local,
//!   no communication (Remark 3: this is *all* that COPML's encode, decode
//!   and model-update linear algebra needs);
//! * **multiplication** of two shared values — the expensive step the
//!   *baselines* pay per iteration, in two flavours:
//!   [`Party::degree_reduce_bgw`] (BGW'88: online resharing, `O(N²)`
//!   communication) and [`Party::degree_reduce_bh08`] (BH08/DN07: offline
//!   double-sharings + a king party, `O(N)` communication);
//! * **secure truncation** [`Party::trunc_pr`] — the TruncPr protocol of
//!   Catrina–Saxena [37], used for the fixed-point model update (Phase 4);
//! * **open** — reconstruct a shared value, via full broadcast or via the
//!   king.
//!
//! All collectives operate element-wise on vectors of shares and consume
//! one transport tag each; parties execute the same SPMD sequence, so tags
//! stay aligned. Offline randomness (double sharings, truncation pairs,
//! random vectors) comes from an [`offline::OfflineProvider`]: either the
//! trusted [`dealer`] (the paper's crypto-service-provider assumption,
//! footnote 3) or the dealer-free distributed phase in [`offline`]
//! (DN07 randomness extraction — the pseudo-random-secret-sharing
//! alternative the same footnote names).

pub mod dealer;
pub mod offline;

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::field::{vecops, Field};
use crate::net::tags::{self, SpmdTagTrace, Tag, TagAlloc, TagRange};
use crate::net::{drive, PartyId, RoundState, Step, Transport, TryRecv};
use crate::poly;
use crate::prng::Rng;
use crate::shamir;

pub use dealer::Dealer;
pub use offline::{
    start_factory, FactoryHandle, FactoryStats, Offline, OfflineError, OfflineMode,
    OfflineProvider,
};

/// Stream label for party-local online randomness ("PRTY" in the high
/// bits, party id in the low bits). Distinct from every `mpc::dealer`
/// stream label, so no party's online stream can coincide with a dealer
/// offline stream.
const STREAM_PARTY: u64 = 0x5052_5459_0000_0000;

/// Domain-separated per-party RNG for online resharing randomness.
///
/// Forked from the master seed under a per-party label via the same
/// SplitMix64-based [`Rng::fork`] the dealer uses. The previous derivation
/// (`seed ^ (id << 32)`) left party 0's stream identical to the raw
/// `cfg.seed` stream — the same seed the dealer's offline pools derive
/// from — so online resharing randomness could correlate with dealer
/// randomness.
fn party_rng(seed: u64, id: PartyId) -> Rng {
    Rng::seed_from_u64(seed).fork(STREAM_PARTY | id as u64)
}

/// Event-driven wait for the king's opened value — the non-king side of
/// every king opening ([`open_via_king_set`]) expressed as a per-round
/// state: TruncPr's per-iteration opens flow through this under both
/// runtimes. A dead king fails with the exact message the blocking
/// receive would have panicked with (the caller re-panics it, preserving
/// behaviour — a lost king is unrecoverable).
struct AwaitKingOpen {
    me: PartyId,
    king: PartyId,
    tag_down: Tag,
}

impl RoundState for AwaitKingOpen {
    type Output = Vec<u64>;

    fn poll(&mut self, net: &dyn Transport) -> Result<Step<Vec<u64>>, String> {
        match net.try_recv(self.king, self.tag_down) {
            TryRecv::Ready(value) => Ok(Step::Ready(value)),
            TryRecv::Pending => Ok(Step::Pending),
            TryRecv::Closed(cause) => Err(format!(
                "party {} recv(from={}, tag={}): {cause}",
                self.me, self.king, self.tag_down
            )),
        }
    }

    fn describe(&self) -> String {
        format!("AwaitKingOpen(party {}, tag {})", self.me, self.tag_down)
    }
}

/// King-opening primitive over explicit participant sets, shared by the
/// online [`Party`] (which passes its live roster) and the offline session
/// ([`offline`], which always runs pre-exclusion over the full mesh):
/// `senders` ship their shares to the king (party 0) under `tag_up`; the
/// king reconstructs with `coeffs` (the evaluation-at-0 row over the
/// senders' λ points, in `senders` order) and sends the value to every
/// party in `recipients` under `tag_down`. `O(N)` total communication.
pub(crate) fn open_via_king_set(
    net: &dyn Transport,
    f: Field,
    coeffs: &[u64],
    tag_up: Tag,
    tag_down: Tag,
    share: &[u64],
    senders: &[PartyId],
    recipients: &[PartyId],
) -> Vec<u64> {
    const KING: PartyId = 0;
    let me = net.id();
    if me == KING {
        let mut contributions: Vec<Vec<u64>> = Vec::with_capacity(senders.len());
        for &peer in senders {
            contributions.push(if peer == KING {
                share.to_vec()
            } else {
                net.recv(peer, tag_up)
            });
        }
        let views: Vec<&[u64]> = contributions.iter().map(|v| v.as_slice()).collect();
        let mut value = vec![0u64; share.len()];
        vecops::weighted_sum(f, coeffs, &views, &mut value);
        for &peer in recipients {
            if peer != KING {
                net.send(peer, tag_down, value.clone());
            }
        }
        value
    } else {
        if senders.contains(&me) {
            net.send(KING, tag_up, share.to_vec());
        }
        match drive(net, AwaitKingOpen { me, king: KING, tag_down }) {
            Ok(value) => value,
            Err(e) => panic!("{e}"),
        }
    }
}

/// [`open_via_king_set`] over the classic fixed sets: parties `0..=deg`
/// send, everyone receives — the offline phase's shape (it runs before
/// any straggler exclusion can exist).
pub(crate) fn open_via_king(
    net: &dyn Transport,
    f: Field,
    coeffs: &[u64],
    tag_up: Tag,
    tag_down: Tag,
    share: &[u64],
    deg: usize,
) -> Vec<u64> {
    let senders: Vec<PartyId> = (0..=deg).collect();
    let recipients: Vec<PartyId> = (0..net.n()).collect();
    open_via_king_set(net, f, coeffs, tag_up, tag_down, share, &senders, &recipients)
}

/// One party's view of an `N`-party MPC session.
pub struct Party<'a> {
    pub id: PartyId,
    pub n: usize,
    pub t: usize,
    pub f: Field,
    pub net: &'a dyn Transport,
    /// Shamir evaluation points `λ_1..λ_N` (public).
    pub lambdas: Vec<u64>,
    /// Offline randomness pools (dealer-dealt or distributed-generated —
    /// [`offline::OfflineProvider`]).
    offline: RefCell<Offline>,
    /// Party-local randomness (for online resharing in BGW).
    rng: RefCell<Rng>,
    /// Tag allocator over the typed windows of [`tags`] (default:
    /// [`tags::FLAT`], counting from 0 like the legacy counter). All
    /// parties must allocate — and seek — in the same SPMD order.
    tags: RefCell<TagAlloc>,
    /// Cached reconstruction coefficient rows keyed by contributor set,
    /// FIFO-bounded at [`Party::RECON_CACHE_CAP`] (insertion-order deque
    /// evicts the oldest set). Contributor sets are roster prefixes, so
    /// in practice only exclusions rotate them — but unbounded growth
    /// under a churning roster is the same hazard
    /// [`crate::lcc::DecoderCache`] bounds, handled the same way.
    recon_cache: RefCell<(HashMap<Vec<PartyId>, Vec<u64>>, VecDeque<Vec<PartyId>>)>,
    /// Live roster: `live[j]` until party `j` is excluded (straggler past
    /// `max_lag`, fault-plan kill). Collectives send to and gather from
    /// live parties only; with everyone live the behaviour — and the byte
    /// ledger — is identical to the fixed-order protocol.
    live: RefCell<Vec<bool>>,
}

impl<'a> Party<'a> {
    pub fn new(
        net: &'a dyn Transport,
        t: usize,
        f: Field,
        offline: Offline,
        seed: u64,
    ) -> Party<'a> {
        let n = net.n();
        assert!(n > 2 * t, "need n > 2t to open degree-2t products (n={n}, t={t})");
        Party {
            id: net.id(),
            n,
            t,
            f,
            net,
            lambdas: shamir::lambda_points(n),
            offline: RefCell::new(offline),
            rng: RefCell::new(party_rng(seed, net.id())),
            tags: RefCell::new(TagAlloc::new(net.id(), tags::FLAT)),
            recon_cache: RefCell::new((HashMap::new(), VecDeque::new())),
            live: RefCell::new(vec![true; n]),
        }
    }

    /// Allocate the next protocol-step tag (identical across parties)
    /// from the current [`tags`] window. Growth is bounded: the
    /// allocator panics with the window name if a window is exhausted,
    /// so a long-running session can never bleed into the `1 << 62`
    /// offline range (see [`tags::OFFLINE`]); the coordinator's
    /// `validate` rejects configs that would get near a window edge
    /// up front.
    pub fn fresh_tag(&self) -> Tag {
        self.tag("step")
    }

    /// [`Party::fresh_tag`] with a named step `kind`, carried into the
    /// SPMD divergence diagnostics of [`SpmdTagTrace`].
    pub fn tag(&self, kind: &'static str) -> Tag {
        self.tags.borrow_mut().fresh(kind)
    }

    /// Jump the allocator to the start of `window` (e.g. the
    /// per-iteration [`tags::round_window`]). A seek is itself an SPMD
    /// step: every party must seek at the same point of the protocol.
    pub fn seek_tags(&self, window: TagRange) {
        self.tags.borrow_mut().seek(window);
    }

    /// Attach the shared cross-party allocation fingerprint (debug
    /// builds; see [`SpmdTagTrace`]).
    pub fn set_tag_trace(&self, trace: Arc<SpmdTagTrace>) {
        self.tags.borrow_mut().attach_trace(trace);
    }

    // ---------------------------------------------------------------
    // Roster (straggler exclusion).
    // ---------------------------------------------------------------

    /// Exclude `id` from every subsequent collective (the quorum leader
    /// announced it dead or persistently late). All live parties apply the
    /// same exclusions in the same round, so rosters stay aligned. The
    /// king (party 0) is the quorum leader and the opening hub; losing it
    /// is unrecoverable and rejected here with a clear error.
    pub fn exclude(&self, id: PartyId) {
        assert!(
            id != 0,
            "party 0 (the king / quorum leader) cannot be excluded — \
             the protocol has no king fail-over"
        );
        self.live.borrow_mut()[id] = false;
    }

    pub fn is_live(&self, id: PartyId) -> bool {
        self.live.borrow()[id]
    }

    /// Ids of the parties still in the protocol, ascending.
    pub fn live_ids(&self) -> Vec<PartyId> {
        self.live
            .borrow()
            .iter()
            .enumerate()
            .filter_map(|(j, &l)| l.then_some(j))
            .collect()
    }

    pub fn live_count(&self) -> usize {
        self.live.borrow().iter().filter(|&&l| l).count()
    }

    /// The first `deg+1` live parties — the contributor set for opening a
    /// degree-`deg` sharing. Any `deg+1` distinct evaluation points
    /// interpolate the polynomial exactly, so the roster prefix is as good
    /// as the classic `0..=deg` (and identical to it while nobody is
    /// excluded). Panics with a clear message when exclusions have made
    /// the opening infeasible.
    fn contributors(&self, deg: usize) -> Vec<PartyId> {
        let ids: Vec<PartyId> = self.live_ids().into_iter().take(deg + 1).collect();
        assert!(
            ids.len() == deg + 1,
            "exclusions make degree-{deg} opening infeasible: need {} shares, \
             only {} parties live",
            deg + 1,
            self.live_count()
        );
        ids
    }

    /// Bound of the reconstruction-coefficient cache: evicting the oldest
    /// contributor set beyond this keeps a long run with a churning
    /// roster from accumulating one coefficient row per distinct set.
    pub const RECON_CACHE_CAP: usize = 8;

    /// Reconstruction coefficients (at 0) for shares held by `ids` —
    /// interpolating a share polynomial of degree `ids.len() − 1`.
    /// Cached per contributor set, FIFO-bounded at
    /// [`Party::RECON_CACHE_CAP`].
    fn recon_coeffs_for(&self, ids: &[PartyId]) -> Vec<u64> {
        if let Some(c) = self.recon_cache.borrow().0.get(ids) {
            return c.clone();
        }
        let pts: Vec<u64> = ids.iter().map(|&j| self.lambdas[j]).collect();
        let c = poly::coeffs_at(self.f, &pts, 0);
        let mut cache = self.recon_cache.borrow_mut();
        let (map, order) = &mut *cache;
        if map.len() >= Self::RECON_CACHE_CAP {
            if let Some(oldest) = order.pop_front() {
                map.remove(&oldest);
            }
        }
        map.insert(ids.to_vec(), c.clone());
        order.push_back(ids.to_vec());
        c
    }

    /// Current number of cached reconstruction rows (regression tests).
    #[cfg(test)]
    fn recon_cache_len(&self) -> usize {
        self.recon_cache.borrow().0.len()
    }

    // ---------------------------------------------------------------
    // Local (communication-free) share arithmetic — Remark 3.
    // ---------------------------------------------------------------

    /// `[a] + [b]` element-wise.
    pub fn add(&self, a: &mut [u64], b: &[u64]) {
        vecops::add_assign(self.f, a, b);
    }

    /// `[a] − [b]` element-wise.
    pub fn sub(&self, a: &mut [u64], b: &[u64]) {
        vecops::sub_assign(self.f, a, b);
    }

    /// `c·[a]` for public `c`.
    pub fn scale(&self, a: &mut [u64], c: u64) {
        vecops::scale_assign(self.f, a, c);
    }

    /// `[a] + c` for public `c`: shares of a constant are the constant.
    pub fn add_const(&self, a: &mut [u64], c: u64) {
        for v in a.iter_mut() {
            *v = self.f.add(*v, c);
        }
    }

    // ---------------------------------------------------------------
    // Collectives.
    // ---------------------------------------------------------------

    /// Open degree-`deg` shares by full broadcast among the live parties
    /// (every live party learns the value; `O(N²)` total communication —
    /// the BGW-style opening). Reconstruction uses the first `deg+1` live
    /// shares — any `deg+1` points interpolate exactly, so the value is
    /// independent of the roster.
    pub fn open_broadcast(&self, share: &[u64], deg: usize) -> Vec<u64> {
        let tag = self.tag("open.bcast");
        let live = self.live_ids();
        for &peer in &live {
            if peer != self.id {
                self.net.send(peer, tag, share.to_vec());
            }
        }
        let contributors = self.contributors(deg);
        let coeffs = self.recon_coeffs_for(&contributors);
        let mut contributions: Vec<Vec<u64>> = Vec::with_capacity(contributors.len());
        for &peer in &contributors {
            contributions.push(if peer == self.id {
                share.to_vec()
            } else {
                self.net.recv(peer, tag)
            });
        }
        // Drain remaining live broadcasts so mailboxes stay tag-aligned.
        // Non-panicking: a peer that died without ever being excluded
        // (e.g. killed in the final rounds, after the last exclusion
        // opportunity) simply has nothing left to drain — its share was
        // not needed, only the contributors' were.
        for &peer in &live {
            if peer != self.id && !contributors.contains(&peer) {
                let _ = self.net.recv_check(peer, tag);
            }
        }
        let views: Vec<&[u64]> = contributions.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0u64; share.len()];
        vecops::weighted_sum(self.f, &coeffs, &views, &mut out);
        out
    }

    /// Open degree-`deg` shares via the king (party 0): the first `deg+1`
    /// live parties send their shares to the king, the king reconstructs
    /// and broadcasts the value to the live roster (`O(N)` total
    /// communication — the BH08-style opening).
    pub fn open_king(&self, share: &[u64], deg: usize) -> Vec<u64> {
        let tag_up = self.tag("king.up");
        let tag_down = self.tag("king.down");
        assert!(
            self.is_live(0),
            "king (party 0) is gone — king openings are infeasible"
        );
        let senders = self.contributors(deg);
        let coeffs = self.recon_coeffs_for(&senders);
        open_via_king_set(
            self.net,
            self.f,
            &coeffs,
            tag_up,
            tag_down,
            share,
            &senders,
            &self.live_ids(),
        )
    }

    /// Secret-share a vector this party knows in the clear: sends `[v]_j`
    /// to each live party `j`, returns own share. Counterpart of
    /// [`Party::receive_share_from`]. The sharing polynomial is evaluated
    /// at all `N` points regardless of the roster, so the share values —
    /// and hence the trajectory — do not depend on who is excluded.
    pub fn share_out(&self, value: &[u64], tag: Tag) -> Vec<u64> {
        let shares = shamir::share_at(
            self.f,
            value,
            &self.lambdas,
            self.t,
            &mut self.rng.borrow_mut(),
        );
        let mut own = Vec::new();
        for (j, s) in shares.into_iter().enumerate() {
            if j == self.id {
                own = s;
            } else if self.is_live(j) {
                self.net.send(j, tag, s);
            }
        }
        own
    }

    /// Receive the share of a value dealt by `from` via
    /// [`Party::share_out`].
    pub fn receive_share_from(&self, from: PartyId, tag: Tag) -> Vec<u64> {
        self.net.recv(from, tag)
    }

    // ---------------------------------------------------------------
    // Degree reduction (secure multiplication) — Appendix C.
    // ---------------------------------------------------------------

    /// BGW'88 degree reduction: convert degree-`2T` shares (e.g. the local
    /// products `[a]·[b]`) back to degree-`T` shares of the same values.
    ///
    /// Each party reshares its degree-2T share with a fresh degree-T
    /// polynomial; the new share is the reconstruction-weighted sum of the
    /// received sub-shares. `O(N²)` total communication.
    pub fn degree_reduce_bgw(&self, z: &[u64]) -> Vec<u64> {
        let tag = self.tag("reduce.bgw");
        let own_sub = self.share_out(z, tag);
        // Gather sub-shares from the first 2T+1 parties (sufficient to
        // interpolate the degree-2T polynomial); later parties still
        // reshared (cost charged), but their sub-shares are not needed.
        let deg = 2 * self.t;
        let fixed: Vec<PartyId> = (0..=deg).collect();
        let coeffs = self.recon_coeffs_for(&fixed);
        let mut subs: Vec<Vec<u64>> = Vec::with_capacity(deg + 1);
        for peer in 0..=deg {
            subs.push(if peer == self.id {
                own_sub.clone()
            } else {
                self.net.recv(peer, tag)
            });
        }
        for peer in deg + 1..self.n {
            if peer != self.id {
                let _ = self.net.recv(peer, tag);
            }
        }
        let views: Vec<&[u64]> = subs.iter().map(|v| v.as_slice()).collect();
        let mut out = vec![0u64; z.len()];
        vecops::weighted_sum(self.f, &coeffs, &views, &mut out);
        out
    }

    /// BH08/DN07 degree reduction using an offline double sharing
    /// `([ρ]_T, [ρ]_2T)`: publish `d = z − ρ` (degree 2T) via the king,
    /// then output `d + [ρ]_T`. `O(N)` total communication. Errs if the
    /// double-sharing pool cannot supply `z.len()` pairs.
    pub fn degree_reduce_bh08(&self, z: &[u64]) -> Result<Vec<u64>, OfflineError> {
        let len = z.len();
        let (rho_t, rho_2t) = self.offline.borrow_mut().take_double(len)?;
        let mut d = z.to_vec();
        vecops::sub_assign(self.f, &mut d, &rho_2t);
        let d_pub = self.open_king(&d, 2 * self.t);
        let mut out = rho_t;
        vecops::add_assign(self.f, &mut out, &d_pub);
        Ok(out)
    }

    /// Secure multiplication of two degree-T shared vectors (element-wise),
    /// choosing the reduction flavour. Only the BH08 path consumes offline
    /// material (and can therefore err).
    pub fn mul(&self, a: &[u64], b: &[u64], bgw: bool) -> Result<Vec<u64>, OfflineError> {
        assert_eq!(a.len(), b.len());
        let prod: Vec<u64> = a.iter().zip(b).map(|(&x, &y)| self.f.mul(x, y)).collect();
        if bgw {
            Ok(self.degree_reduce_bgw(&prod))
        } else {
            self.degree_reduce_bh08(&prod)
        }
    }

    // ---------------------------------------------------------------
    // Secure truncation — TruncPr of Catrina–Saxena [37].
    // ---------------------------------------------------------------

    /// Probabilistic truncation of degree-T shares: for each element with
    /// signed value `a ∈ (−2^{k−1}, 2^{k−1})`, returns shares of
    /// `⌊a/2^m⌋ + s` with `P(s=1) = (a mod 2^m)/2^m` — the paper's Phase-4
    /// rounding. Consumes one offline pair per element.
    ///
    /// Requires `2^k + 2^{k+κ} < p` (checked), `0 < m < k`. Errs if the
    /// width-`m` truncation pool cannot supply `a.len()` pairs.
    pub fn trunc_pr(
        &self,
        a: &[u64],
        k: u32,
        m: u32,
        kappa: u32,
        king: bool,
    ) -> Result<Vec<u64>, OfflineError> {
        assert!(m < k, "truncation amount must be < value bits");
        let p = self.f.modulus();
        assert!(
            (1u128 << k) + (1u128 << (k + kappa)) < p as u128,
            "field too small for TruncPr: 2^{k} + 2^{} ≥ p",
            k + kappa
        );
        let len = a.len();
        let (rp, rpp) = self.offline.borrow_mut().take_trunc_pair(len, m)?;
        // v = a + 2^{k−1} + 2^m·r'' + r'
        let pow_km1 = self.f.reduce(1u64 << (k - 1));
        let pow_m = 1u64 << m;
        let mut v = a.to_vec();
        for i in 0..len {
            let masked = self.f.add(self.f.mul(pow_m, rpp[i]), rp[i]);
            v[i] = self.f.add(self.f.add(v[i], pow_km1), masked);
        }
        let c = if king {
            self.open_king(&v, self.t)
        } else {
            self.open_broadcast(&v, self.t)
        };
        // z = (a + 2^{k−1} − (c mod 2^m) + r')·2^{−m} − 2^{k−1−m}
        let inv2m = self.f.inv(pow_m);
        let offset = self.f.reduce(1u64 << (k - 1 - m));
        let mut out = vec![0u64; len];
        for i in 0..len {
            // c is the true integer b + r (< p, no wraparound by the field
            // size check above), so "mod 2^m" is integer arithmetic.
            let c_lo = c[i] & (pow_m - 1);
            let num = self.f.add(self.f.sub(self.f.add(a[i], pow_km1), c_lo), rp[i]);
            out[i] = self.f.sub(self.f.mul(num, inv2m), offset);
        }
        Ok(out)
    }

    /// Fetch degree-T shares of a fresh uniformly random vector from the
    /// offline pool (model masks `v_k` of Eq. 4, initial model, …). Errs
    /// if the random pool cannot supply `len` elements.
    pub fn random_share(&self, len: usize) -> Result<Vec<u64>, OfflineError> {
        self.offline.borrow_mut().take_random(len)
    }
}

#[cfg(test)]
mod tests;
