//! Offline randomness: the pools the online protocol consumes, and **who
//! produces them**.
//!
//! The paper's footnote 3 allows two sources for the offline randomness
//! (double sharings, truncation pairs, random sharings):
//!
//! * a **crypto-service provider** — the trusted dealer of
//!   [`super::dealer`], replayed here from the shared seed
//!   ([`OfflineMode::Dealer`], the default; bit-identical to every
//!   pre-existing trace);
//! * **pseudo-random secret sharing by the parties themselves** —
//!   implemented here as a DN07-style *distributed offline phase*
//!   ([`OfflineMode::Distributed`]): no dealer, every pool is generated
//!   collectively over the live [`Transport`], and its traffic lands in
//!   the byte ledgers like any online phase.
//!
//! Both run behind the [`OfflineProvider`] trait, so the trainers select a
//! source without knowing how the pools were made.
//!
//! ## The distributed protocol (semi-honest, `N > 2T`)
//!
//! * **Random degree-`T` sharings** — DN07 batched generation: each party
//!   deals a random degree-`T` sharing of a fresh batch; a Vandermonde
//!   [`extraction_matrix`] turns the `N` dealt sharings into `N − T`
//!   outputs that remain uniform to any `T` colluding parties (any
//!   `N − T` columns of the matrix are invertible, so the honest dealers'
//!   inputs act as a bijection onto the outputs). Amortized cost:
//!   `N/(N−T) = O(1)` sharings dealt per usable output — `O(N)` field
//!   elements of traffic per output across all parties.
//! * **Double sharings** `([ρ]_T, [ρ]_2T)` — same extraction, run on a
//!   degree-`T` and a degree-`2T` dealing of the *same* dealer batches;
//!   the extraction is linear, so both halves reconstruct the same ρ.
//! * **Shared random bits** (for TruncPr pairs, Catrina–Saxena): take an
//!   extracted random `[a]_T`, square it locally (degree `2T`), open `a²`
//!   via the king, compute the canonical root `c = √(a²)` in public, and
//!   output `[b] = (c⁻¹·[a] + 1)/2` — a uniform bit, because the sign of
//!   `a` is uniform and independent of `a²`. Slots where `a² = 0` are
//!   discarded (all parties see the same opened values, so they agree)
//!   and regenerated.
//! * **Truncation pairs** `([r']_T, [r'']_T)` for width `m` — composed
//!   per pair from `m` bits (`r' = Σ 2^i b_i`) and `k₂+κ−m` bits
//!   (`r''`), entirely linear on the bit shares.
//!
//! The phase uses its own tag range ([`TAG_BASE`]) so it can run on the
//! same transport *before* the online tags start at 0, and a per-party
//! RNG fork domain-separated from both the dealer streams and the online
//! resharing streams. In a real deployment each party would seed from its
//! own entropy; here the forks derive from the shared run seed so
//! distributed runs stay reproducible (see `prng` module docs — the same
//! caveat the dealer carries).

use std::collections::HashMap;

use crate::field::{vecops, Field};
use crate::net::tags::{self, TagAlloc};
use crate::net::{PartyId, Transport, Wire};
use crate::poly;
use crate::prng::Rng;
use crate::shamir;

use super::dealer::Dealer;

/// First tag of the offline phase's private tag range
/// ([`tags::OFFLINE`]). The online protocol allocates from the windows
/// below it; disjointness is const-asserted in [`tags`], so the two can
/// never collide.
///
/// [`tags`]: crate::net::tags
/// [`tags::OFFLINE`]: crate::net::tags::OFFLINE
pub const TAG_BASE: u64 = crate::net::tags::OFFLINE.start;

/// Stream label for the per-party offline-phase RNG ("OFFL" in the high
/// bits, party id in the low bits). Distinct from every `mpc::dealer`
/// stream label and from `mpc::STREAM_PARTY`.
const STREAM_OFFLINE: u64 = 0x4F46_464C_0000_0000;

// ---------------------------------------------------------------------
// Pools (shared by both providers).
// ---------------------------------------------------------------------

/// Pool sizing for one protocol run.
#[derive(Clone, Debug, Default)]
pub struct Demand {
    /// Elements passing through BH08 degree reduction.
    pub doubles: usize,
    /// Elements passing through TruncPr, per truncation width `m`:
    /// `(m, count)`.
    pub truncs: Vec<(u32, usize)>,
    /// Elements of fresh random degree-T sharings.
    pub randoms: usize,
}

pub(crate) struct Stream {
    data: Vec<u64>,
    pos: usize,
}

impl Stream {
    pub(crate) fn new(data: Vec<u64>) -> Stream {
        Stream { data, pos: 0 }
    }
    fn take(&mut self, len: usize, what: &str) -> Vec<u64> {
        assert!(
            self.pos + len <= self.data.len(),
            "offline {what} pool exhausted (need {len} more of {})",
            self.data.len()
        );
        let lo = self.pos;
        self.pos += len;
        self.data[lo..lo + len].to_vec()
    }
}

/// Per-party pools of offline randomness. Streams are consumed linearly;
/// exhaustion panics with a sizing hint (the coordinator precomputes exact
/// demand).
pub struct Offline {
    pub(crate) double_t: Stream,
    pub(crate) double_2t: Stream,
    pub(crate) trunc_rp: HashMap<u32, Stream>,
    pub(crate) trunc_rpp: HashMap<u32, Stream>,
    pub(crate) random_t: Stream,
}

impl Default for Offline {
    fn default() -> Self {
        Offline {
            double_t: Stream::new(Vec::new()),
            double_2t: Stream::new(Vec::new()),
            trunc_rp: HashMap::new(),
            trunc_rpp: HashMap::new(),
            random_t: Stream::new(Vec::new()),
        }
    }
}

impl Offline {
    pub fn take_double(&mut self, len: usize) -> (Vec<u64>, Vec<u64>) {
        (
            self.double_t.take(len, "double-sharing"),
            self.double_2t.take(len, "double-sharing"),
        )
    }

    /// Take `len` truncation pairs for width `m`.
    pub fn take_trunc_pair(&mut self, len: usize, m: u32) -> (Vec<u64>, Vec<u64>) {
        let rp = self
            .trunc_rp
            .get_mut(&m)
            .unwrap_or_else(|| panic!("no truncation pool for width m={m}"))
            .take(len, "truncation");
        let rpp = self
            .trunc_rpp
            .get_mut(&m)
            .unwrap_or_else(|| panic!("no truncation pool for width m={m}"))
            .take(len, "truncation");
        (rp, rpp)
    }

    pub fn take_random(&mut self, len: usize) -> Vec<u64> {
        self.random_t.take(len, "random-share")
    }
}

// ---------------------------------------------------------------------
// Mode + provider trait.
// ---------------------------------------------------------------------

/// Who produces the offline pools.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OfflineMode {
    /// Trusted crypto-service provider (footnote 3), replayed from the
    /// shared seed. Free on the wire; the default, bit-identical to every
    /// pre-existing trace.
    #[default]
    Dealer,
    /// Dealer-free: the parties generate every pool collectively (DN07
    /// extraction + Catrina–Saxena bits) over the live transport. The
    /// offline phase becomes a real, byte-accounted protocol cost.
    Distributed,
}

impl OfflineMode {
    /// The provider implementing this mode.
    pub fn provider(self) -> Box<dyn OfflineProvider> {
        match self {
            OfflineMode::Dealer => Box::new(DealerProvider),
            OfflineMode::Distributed => Box::new(DistributedProvider),
        }
    }
}

impl std::fmt::Display for OfflineMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            OfflineMode::Dealer => "dealer",
            OfflineMode::Distributed => "distributed",
        })
    }
}

impl std::str::FromStr for OfflineMode {
    type Err = String;

    fn from_str(s: &str) -> Result<OfflineMode, String> {
        match s {
            "dealer" => Ok(OfflineMode::Dealer),
            "distributed" | "dist" => Ok(OfflineMode::Distributed),
            other => Err(format!(
                "unknown offline mode '{other}' (expected dealer|distributed)"
            )),
        }
    }
}

/// A source of per-party offline pools. `provide` runs on party
/// `net.id()`'s thread/process; the distributed provider communicates
/// over `net` (its own tag range), the dealer provider replays pools from
/// the shared seed without touching the wire.
pub trait OfflineProvider {
    fn mode(&self) -> OfflineMode;

    #[allow(clippy::too_many_arguments)]
    fn provide(
        &self,
        net: &dyn Transport,
        f: Field,
        t: usize,
        demand: &Demand,
        k2: u32,
        kappa: u32,
        seed: u64,
    ) -> Offline;
}

/// [`OfflineMode::Dealer`]: the crypto-service provider of
/// [`super::dealer`], replayed per party from the shared seed
/// (bit-identical to `Dealer::deal(..)[id]`).
pub struct DealerProvider;

impl OfflineProvider for DealerProvider {
    fn mode(&self) -> OfflineMode {
        OfflineMode::Dealer
    }

    fn provide(
        &self,
        net: &dyn Transport,
        f: Field,
        t: usize,
        demand: &Demand,
        k2: u32,
        kappa: u32,
        seed: u64,
    ) -> Offline {
        Dealer::deal_one(f, net.n(), t, demand, k2, kappa, seed, net.id())
    }
}

/// [`OfflineMode::Distributed`]: the dealer-free DN07 phase (module docs).
pub struct DistributedProvider;

impl OfflineProvider for DistributedProvider {
    fn mode(&self) -> OfflineMode {
        OfflineMode::Distributed
    }

    fn provide(
        &self,
        net: &dyn Transport,
        f: Field,
        t: usize,
        demand: &Demand,
        k2: u32,
        kappa: u32,
        seed: u64,
    ) -> Offline {
        generate(net, f, t, demand, k2, kappa, seed)
    }
}

// ---------------------------------------------------------------------
// Extraction core (pure — property-tested in tests/offline_props.rs).
// ---------------------------------------------------------------------

/// DN07 randomness-extraction matrix: `(N−T) × N` Vandermonde rows
/// `M[i][j] = λ_j^i` over the standard share points `λ_j = j+1`.
///
/// Any `N−T` columns form a transposed Vandermonde on distinct nonzero
/// points, hence are invertible: with at most `T` corrupt dealers, the
/// honest dealers' inputs map *bijectively* onto the `N−T` outputs, so
/// the outputs are uniform (and unknown) to the adversary as long as one
/// honest dealer's input was.
pub fn extraction_matrix(f: Field, n: usize, t: usize) -> Vec<Vec<u64>> {
    assert!(n > t, "need more parties than the threshold (n={n}, t={t})");
    let xs = shamir::lambda_points(n);
    (0..n - t)
        .map(|i| xs.iter().map(|&x| f.pow(x, i as u64)).collect())
        .collect()
}

/// Apply the extraction to one party's shares of the `N` dealt batches:
/// `inputs[j]` is this party's share vector of dealer `j`'s batch. Returns
/// `N−T` share vectors, one per extracted output sharing. Linear, so the
/// output shares lie on polynomials of the *same* degree as the inputs and
/// hide `Σ_j M[i][j]·s_j`.
pub fn extract(f: Field, matrix: &[Vec<u64>], inputs: &[&[u64]]) -> Vec<Vec<u64>> {
    matrix
        .iter()
        .map(|row| {
            let mut out = vec![0u64; inputs[0].len()];
            vecops::weighted_sum(f, row, inputs, &mut out);
            out
        })
        .collect()
}

/// Interleave the `N−T` extracted output vectors into consumption order
/// (slot-major: all outputs of batch slot 0, then slot 1, …) and truncate
/// to `count`. Deterministic, so every party consumes the same sharing at
/// the same pool index.
fn flatten_extracted(outs: Vec<Vec<u64>>, count: usize) -> Vec<u64> {
    let mut flat = Vec::with_capacity(count);
    let slots = outs.first().map_or(0, |o| o.len());
    'outer: for slot in 0..slots {
        for o in &outs {
            flat.push(o[slot]);
            if flat.len() == count {
                break 'outer;
            }
        }
    }
    assert_eq!(flat.len(), count, "extraction under-produced");
    flat
}

/// Modular square root by Tonelli–Shanks, with the `p ≡ 3 (mod 4)`
/// shortcut. Returns the **canonical** root `min(r, p−r)` so every party
/// derives the same public `c` from the same opened square. `a` must be a
/// quadratic residue (callers pass opened squares); panics otherwise.
pub fn sqrt_mod(f: Field, a: u64) -> u64 {
    let p = f.modulus();
    if a == 0 {
        return 0;
    }
    let r = if p % 4 == 3 {
        f.pow(a, (p + 1) / 4)
    } else {
        // Tonelli–Shanks: write p−1 = q·2^s with q odd.
        let mut q = p - 1;
        let mut s = 0u32;
        while q % 2 == 0 {
            q /= 2;
            s += 1;
        }
        // Any quadratic non-residue works as the generator seed.
        let mut z = 2u64;
        while f.pow(z, (p - 1) / 2) != p - 1 {
            z += 1;
        }
        let mut m = s;
        let mut c = f.pow(z, q);
        let mut tt = f.pow(a, q);
        let mut r = f.pow(a, (q + 1) / 2);
        while tt != 1 {
            // Find least i with t^(2^i) = 1.
            let mut i = 0u32;
            let mut probe = tt;
            while probe != 1 {
                probe = f.mul(probe, probe);
                i += 1;
                assert!(i < m, "sqrt_mod of a non-residue");
            }
            let b = f.pow(c, 1u64 << (m - i - 1));
            m = i;
            c = f.mul(b, b);
            tt = f.mul(tt, c);
            r = f.mul(r, b);
        }
        r
    };
    debug_assert_eq!(f.mul(r, r), a, "sqrt_mod produced a wrong root");
    r.min(p - r)
}

// ---------------------------------------------------------------------
// The distributed protocol session.
// ---------------------------------------------------------------------

struct Session<'a> {
    net: &'a dyn Transport,
    f: Field,
    n: usize,
    t: usize,
    lambdas: Vec<u64>,
    matrix: Vec<Vec<u64>>,
    rng: Rng,
    /// Allocator over [`tags::OFFLINE`] — the phase's private window.
    /// Separate-process parties cannot share an in-process
    /// [`tags::SpmdTagTrace`], so divergence here is caught by the
    /// mailbox's `(from, tag)` reuse counter instead.
    tags: TagAlloc,
}

impl Session<'_> {
    fn fresh_tag(&mut self) -> u64 {
        self.tags.fresh("offline.step")
    }

    /// Deal a degree-`deg` sharing of `vals` to everyone and collect every
    /// dealer's batch: returns `shares[j]` = this party's share of dealer
    /// `j`'s batch.
    fn deal_round(&mut self, vals: &[u64], deg: usize) -> Vec<Vec<u64>> {
        let tag = self.fresh_tag();
        let me = self.net.id();
        let shares = shamir::share_at(self.f, vals, &self.lambdas, deg, &mut self.rng);
        let mut own = Vec::new();
        for (j, s) in shares.into_iter().enumerate() {
            if j == me {
                own = s;
            } else {
                self.net.send(j, tag, s);
            }
        }
        (0..self.n)
            .map(|j| {
                if j == me {
                    std::mem::take(&mut own)
                } else {
                    self.net.recv(j, tag)
                }
            })
            .collect()
    }

    /// One extraction pass: everyone deals `l` fresh random values at
    /// degree `deg`; returns the `N−T` extracted output share vectors.
    fn extract_round(&mut self, l: usize, deg: usize) -> Vec<Vec<u64>> {
        let p = self.f.modulus();
        let vals: Vec<u64> = (0..l).map(|_| self.rng.gen_range(p)).collect();
        let dealt = self.deal_round(&vals, deg);
        let views: Vec<&[u64]> = dealt.iter().map(|v| v.as_slice()).collect();
        extract(self.f, &self.matrix, &views)
    }

    /// `count` extracted random degree-`deg` sharings, in consumption
    /// order.
    fn extract_random(&mut self, count: usize, deg: usize) -> Vec<u64> {
        if count == 0 {
            return Vec::new();
        }
        let l = count.div_ceil(self.n - self.t);
        flatten_extracted(self.extract_round(l, deg), count)
    }

    /// `count` extracted double sharings `([ρ]_T, [ρ]_2T)`: the same
    /// dealer batches shared at both degrees, extracted with the same
    /// matrix (linearity keeps the halves consistent).
    fn extract_doubles(&mut self, count: usize) -> (Vec<u64>, Vec<u64>) {
        if count == 0 {
            return (Vec::new(), Vec::new());
        }
        let p = self.f.modulus();
        let l = count.div_ceil(self.n - self.t);
        let vals: Vec<u64> = (0..l).map(|_| self.rng.gen_range(p)).collect();
        let dealt_t = self.deal_round(&vals, self.t);
        let dealt_2t = self.deal_round(&vals, 2 * self.t);
        let views_t: Vec<&[u64]> = dealt_t.iter().map(|v| v.as_slice()).collect();
        let views_2t: Vec<&[u64]> = dealt_2t.iter().map(|v| v.as_slice()).collect();
        let out_t = flatten_extracted(extract(self.f, &self.matrix, &views_t), count);
        let out_2t = flatten_extracted(extract(self.f, &self.matrix, &views_2t), count);
        (out_t, out_2t)
    }

    /// Open degree-`deg` shares via the king (party 0) — the shared
    /// [`super::open_via_king`] primitive, on the offline tag range.
    fn open_king(&mut self, share: &[u64], deg: usize) -> Vec<u64> {
        let tag_up = self.fresh_tag();
        let tag_down = self.fresh_tag();
        let coeffs = poly::coeffs_at(self.f, &self.lambdas[..deg + 1], 0);
        super::open_via_king(self.net, self.f, &coeffs, tag_up, tag_down, share, deg)
    }

    /// `count` shares of uniformly random bits (module docs): extracted
    /// random `[a]`, open `a²` via the king, `[b] = (c⁻¹[a]+1)/2` for the
    /// canonical root `c`. Slots with `a² = 0` are discarded consistently
    /// (the opened value is public) and regenerated in a further round.
    fn gen_bits(&mut self, count: usize) -> Vec<u64> {
        let f = self.f;
        let inv2 = f.inv(2);
        let mut bits = Vec::with_capacity(count);
        while bits.len() < count {
            let need = count - bits.len();
            let a = self.extract_random(need, self.t);
            let sq: Vec<u64> = a.iter().map(|&x| f.mul(x, x)).collect();
            let opened = self.open_king(&sq, 2 * self.t);
            for (&ai, &sqv) in a.iter().zip(&opened) {
                if sqv == 0 {
                    continue; // a = 0 carries no sign bit — retry the slot
                }
                let c = sqrt_mod(f, sqv);
                let signed = f.mul(f.inv(c), ai); // shares of ±1
                bits.push(f.mul(inv2, f.add(signed, 1)));
            }
        }
        bits
    }

    /// `count` truncation pairs for width `m`: `r' = Σ_{i<m} 2^i b_i`,
    /// `r'' = Σ_{i<k₂+κ−m} 2^i b_{m+i}` — the Catrina–Saxena composition,
    /// linear on the bit shares.
    fn trunc_pool(&mut self, m: u32, count: usize, k2: u32, kappa: u32) -> (Vec<u64>, Vec<u64>) {
        assert!(m < k2 + kappa);
        let f = self.f;
        let (wp, wpp) = (m as usize, (k2 + kappa - m) as usize);
        let bits = self.gen_bits(count * (wp + wpp));
        let compose = |chunk: &[u64]| -> u64 {
            let mut acc = 0u64;
            let mut pow = 1u64;
            for &b in chunk {
                acc = f.add(acc, f.mul(pow, b));
                pow = f.mul(pow, 2);
            }
            acc
        };
        let mut rp = Vec::with_capacity(count);
        let mut rpp = Vec::with_capacity(count);
        for j in 0..count {
            let base = j * (wp + wpp);
            rp.push(compose(&bits[base..base + wp]));
            rpp.push(compose(&bits[base + wp..base + wp + wpp]));
        }
        (rp, rpp)
    }
}

/// Run the distributed offline phase for party `net.id()`: generate every
/// pool `demand` asks for, collectively, with zero dealer involvement.
/// All parties must call this concurrently (SPMD) with the same
/// arguments. Pool order mirrors the dealer's (doubles, truncation widths
/// ascending, randoms).
pub fn generate(
    net: &dyn Transport,
    f: Field,
    t: usize,
    demand: &Demand,
    k2: u32,
    kappa: u32,
    seed: u64,
) -> Offline {
    let n = net.n();
    assert!(n > 2 * t, "need n > 2t to open squares during bit generation (n={n}, t={t})");
    let mut s = Session {
        net,
        f,
        n,
        t,
        lambdas: shamir::lambda_points(n),
        matrix: extraction_matrix(f, n, t),
        rng: Rng::seed_from_u64(seed).fork(STREAM_OFFLINE | net.id() as u64),
        tags: TagAlloc::new(net.id(), tags::OFFLINE),
    };
    let mut pool = Offline::default();

    let (dt, d2t) = s.extract_doubles(demand.doubles);
    pool.double_t = Stream::new(dt);
    pool.double_2t = Stream::new(d2t);

    let mut widths: Vec<(u32, usize)> = demand.truncs.clone();
    widths.sort_unstable();
    for (m, count) in widths {
        if count == 0 {
            continue;
        }
        let (rp, rpp) = s.trunc_pool(m, count, k2, kappa);
        pool.trunc_rp.insert(m, Stream::new(rp));
        pool.trunc_rpp.insert(m, Stream::new(rpp));
    }

    pool.random_t = Stream::new(s.extract_random(demand.randoms, t));
    pool
}

/// Exact payload bytes party `id` sends during [`generate`] (assuming no
/// `a² = 0` retry rounds — probability ≈ `bits/p` per run). Mirrors the
/// implementation term by term; validated against the live ledger in
/// `tests/cost_model_validation.rs`.
pub fn distributed_bytes_for_party(
    n: usize,
    t: usize,
    demand: &Demand,
    k2: u32,
    kappa: u32,
    id: PartyId,
    wire: Wire,
) -> u64 {
    let ex = n - t; // usable outputs per extraction batch
    let deal = |count: usize| -> u64 {
        if count == 0 {
            0
        } else {
            ((n - 1) * count.div_ceil(ex)) as u64
        }
    };
    // Doubles: two deal rounds (degree T and 2T) over the same batch size.
    let mut elems = 2 * deal(demand.doubles);
    // Trunc pools: per width, one bit per composed binary digit; each bit
    // costs one extracted `a` (a deal round) plus one king opening.
    for &(_, count) in &demand.truncs {
        if count == 0 {
            continue;
        }
        let bits = count * (k2 + kappa) as usize;
        elems += deal(bits);
        if id == 0 {
            elems += (bits * (n - 1)) as u64; // king broadcasts the squares
        } else if id <= 2 * t {
            elems += bits as u64; // share of the squares, up to the king
        }
    }
    // Random degree-T pool: one deal round.
    elems += deal(demand.randoms);
    elems * wire.elem_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{P25, P26};
    use crate::net::local::Hub;
    use crate::shamir::reconstruct;

    fn demand_basic() -> Demand {
        Demand { doubles: 10, truncs: vec![(5, 6), (10, 6)], randoms: 16 }
    }

    /// Run the distributed offline phase with `n` threads over the Hub and
    /// return every party's pool (id order) plus its sent-byte count.
    fn run_generate(
        f: Field,
        n: usize,
        t: usize,
        demand: &Demand,
        k2: u32,
        kappa: u32,
        seed: u64,
    ) -> Vec<(Offline, u64)> {
        let endpoints = Hub::new(n);
        let demand = demand.clone();
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let demand = demand.clone();
                std::thread::spawn(move || {
                    let pool = generate(&ep, f, t, &demand, k2, kappa, seed);
                    (pool, ep.bytes_sent())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn distributed_doubles_reconstruct_consistently() {
        let f = Field::new(P26);
        let (n, t) = (7usize, 2usize);
        let mut pools: Vec<Offline> = run_generate(f, n, t, &demand_basic(), 20, 1, 404)
            .into_iter()
            .map(|(p, _)| p)
            .collect();
        let taken: Vec<(Vec<u64>, Vec<u64>)> =
            pools.iter_mut().map(|p| p.take_double(10)).collect();
        let t_shares: Vec<Vec<u64>> = taken.iter().map(|(a, _)| a.clone()).collect();
        let t2_shares: Vec<Vec<u64>> = taken.iter().map(|(_, b)| b.clone()).collect();
        assert_eq!(reconstruct(f, &t_shares, t), reconstruct(f, &t2_shares, 2 * t));
    }

    #[test]
    fn distributed_trunc_pairs_in_range() {
        let f = Field::new(P26);
        let (n, t, k2, kappa) = (5usize, 1usize, 20u32, 1u32);
        let mut pools: Vec<Offline> = run_generate(f, n, t, &demand_basic(), k2, kappa, 405)
            .into_iter()
            .map(|(p, _)| p)
            .collect();
        for m in [5u32, 10] {
            let taken: Vec<(Vec<u64>, Vec<u64>)> =
                pools.iter_mut().map(|p| p.take_trunc_pair(6, m)).collect();
            let rp =
                reconstruct(f, &taken.iter().map(|x| x.0.clone()).collect::<Vec<_>>(), t);
            let rpp =
                reconstruct(f, &taken.iter().map(|x| x.1.clone()).collect::<Vec<_>>(), t);
            for &v in &rp {
                assert!(v < 1 << m, "r' = {v} out of range for m={m}");
            }
            for &v in &rpp {
                assert!(v < 1 << (k2 + kappa - m), "r'' = {v} out of range for m={m}");
            }
        }
    }

    #[test]
    fn distributed_randoms_are_valid_t_sharings() {
        let f = Field::new(P26);
        let (n, t) = (7usize, 2usize);
        let mut pools: Vec<Offline> = run_generate(f, n, t, &demand_basic(), 20, 1, 406)
            .into_iter()
            .map(|(p, _)| p)
            .collect();
        let shares: Vec<Vec<u64>> = pools.iter_mut().map(|p| p.take_random(16)).collect();
        // Any two (t+1)-subsets agree — the sharing is degree ≤ t.
        let a = reconstruct(f, &shares, t);
        let pts = shamir::lambda_points(n);
        let sel: Vec<u64> = pts[n - t - 1..].to_vec();
        let rec = shamir::Reconstructor::new(f, &sel);
        let views: Vec<&[u64]> = shares[n - t - 1..].iter().map(|s| s.as_slice()).collect();
        let mut b = vec![0u64; 16];
        rec.reconstruct(f, &views, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn distributed_generation_is_deterministic_per_seed() {
        let f = Field::new(P26);
        let (n, t) = (5usize, 1usize);
        let d = demand_basic();
        fn drain(pools: Vec<(Offline, u64)>) -> Vec<Vec<u64>> {
            pools
                .into_iter()
                .map(|(mut p, _)| {
                    let (mut v, d2) = p.take_double(10);
                    v.extend(d2);
                    for m in [5u32, 10] {
                        let (rp, rpp) = p.take_trunc_pair(6, m);
                        v.extend(rp);
                        v.extend(rpp);
                    }
                    v.extend(p.take_random(16));
                    v
                })
                .collect()
        }
        let a = drain(run_generate(f, n, t, &d, 20, 1, 7));
        let b = drain(run_generate(f, n, t, &d, 20, 1, 7));
        let c = drain(run_generate(f, n, t, &d, 20, 1, 8));
        assert_eq!(a, b, "same seed must reproduce every pool bit-for-bit");
        assert_ne!(a, c, "different seeds must produce different pools");
    }

    #[test]
    fn ledger_bytes_match_analytic_accounting() {
        let f = Field::new(P26);
        let (n, t, k2, kappa) = (7usize, 2usize, 20u32, 1u32);
        let d = demand_basic();
        for (id, (_, sent)) in run_generate(f, n, t, &d, k2, kappa, 407).into_iter().enumerate()
        {
            let expect =
                distributed_bytes_for_party(n, t, &d, k2, kappa, id, Wire::U64);
            assert_eq!(sent, expect, "party {id} byte accounting");
        }
    }

    #[test]
    #[should_panic(expected = "no truncation pool for width m=6")]
    fn trunc_rpp_mismatch_diagnosable() {
        // Regression: the r'' lookup used a bare `.unwrap()`, so an rp/rpp
        // width mismatch died with an anonymous Option panic instead of
        // the sizing hint the r' path gives.
        let mut pool = Offline::default();
        pool.trunc_rp.insert(6, Stream::new(vec![1, 2, 3]));
        let _ = pool.take_trunc_pair(1, 6);
    }

    #[test]
    fn sqrt_mod_both_residue_classes() {
        // P26 ≡ 3 (mod 4) takes the shortcut; P25 ≡ 1 (mod 4) exercises
        // Tonelli–Shanks proper.
        for p in [P26, P25] {
            let f = Field::new(p);
            let mut rng = Rng::seed_from_u64(9);
            for _ in 0..200 {
                let x = rng.gen_range(p);
                let sq = f.mul(x, x);
                let r = sqrt_mod(f, sq);
                assert_eq!(f.mul(r, r), sq, "p={p} x={x}");
                assert!(r <= p - r || r == 0, "canonical root must be the smaller one");
            }
            assert_eq!(sqrt_mod(f, 0), 0);
        }
    }

    #[test]
    fn distributed_pools_drive_trunc_pr() {
        // End-to-end: a Party running on distributed pools truncates
        // correctly (floor or floor+1, exact on multiples).
        use crate::mpc::Party;
        let f = Field::new(P26);
        let (n, t) = (5usize, 1usize);
        let (k, m, kappa) = (20u32, 5u32, 1u32);
        let vals_signed: Vec<i64> = vec![0, 64, 100, -64, -100, (1 << 19) - 1];
        let vals: Vec<u64> = vals_signed.iter().map(|&v| f.from_i64(v)).collect();
        let mut rng = Rng::seed_from_u64(31);
        let shares = shamir::share(f, &vals, n, t, &mut rng);
        let demand =
            Demand { doubles: 0, truncs: vec![(m, vals.len())], randoms: 0 };
        let endpoints = Hub::new(n);
        let handles: Vec<_> = endpoints
            .into_iter()
            .zip(shares)
            .map(|(ep, input)| {
                let demand = demand.clone();
                std::thread::spawn(move || {
                    let pool = generate(&ep, f, t, &demand, k, kappa, 33);
                    let party = Party::new(&ep, t, f, pool, 33);
                    let z = party.trunc_pr(&input, k, m, kappa, true);
                    party.open_broadcast(&z, t)
                })
            })
            .collect();
        let results: Vec<Vec<u64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in &results {
            for (i, &v) in vals_signed.iter().enumerate() {
                let got = f.to_i64(r[i]);
                let floor = v.div_euclid(1 << m);
                assert!(got == floor || got == floor + 1, "val {v}: got {got}");
                if v.rem_euclid(1 << m) == 0 {
                    assert_eq!(got, floor);
                }
            }
        }
    }
}
