//! Offline randomness: the pools the online protocol consumes, and **who
//! produces them**.
//!
//! The paper's footnote 3 allows two sources for the offline randomness
//! (double sharings, truncation pairs, random sharings):
//!
//! * a **crypto-service provider** — the trusted dealer of
//!   [`super::dealer`], replayed here from the shared seed
//!   ([`OfflineMode::Dealer`], the default; bit-identical to every
//!   pre-existing trace);
//! * **pseudo-random secret sharing by the parties themselves** —
//!   implemented here as a DN07-style *distributed offline phase*
//!   ([`OfflineMode::Distributed`]): no dealer, every pool is generated
//!   collectively over the live [`Transport`], and its traffic lands in
//!   the byte ledgers like any online phase.
//!
//! Both run behind the [`OfflineProvider`] trait, so the trainers select a
//! source without knowing how the pools were made.
//!
//! ## The distributed protocol (semi-honest, `N > 2T`)
//!
//! * **Random degree-`T` sharings** — DN07 batched generation: each party
//!   deals a random degree-`T` sharing of a fresh batch; a Vandermonde
//!   [`extraction_matrix`] turns the `N` dealt sharings into `N − T`
//!   outputs that remain uniform to any `T` colluding parties (any
//!   `N − T` columns of the matrix are invertible, so the honest dealers'
//!   inputs act as a bijection onto the outputs). Amortized cost:
//!   `N/(N−T) = O(1)` sharings dealt per usable output — `O(N)` field
//!   elements of traffic per output across all parties.
//! * **Double sharings** `([ρ]_T, [ρ]_2T)` — same extraction, run on a
//!   degree-`T` and a degree-`2T` dealing of the *same* dealer batches;
//!   the extraction is linear, so both halves reconstruct the same ρ.
//! * **Shared random bits** (for TruncPr pairs, Catrina–Saxena): take an
//!   extracted random `[a]_T`, square it locally (degree `2T`), open `a²`
//!   via the king, compute the canonical root `c = √(a²)` in public, and
//!   output `[b] = (c⁻¹·[a] + 1)/2` — a uniform bit, because the sign of
//!   `a` is uniform and independent of `a²`. Slots where `a² = 0` are
//!   discarded (all parties see the same opened values, so they agree)
//!   and regenerated.
//! * **Truncation pairs** `([r']_T, [r'']_T)` for width `m` — composed
//!   per pair from `m` bits (`r' = Σ 2^i b_i`) and `k₂+κ−m` bits
//!   (`r''`), entirely linear on the bit shares.
//!
//! ## The pipelined factory
//!
//! [`generate`] is the one-shot shape: block until every pool `demand`
//! asks for exists. [`start_factory`] is the pipelined shape: a background
//! producer thread walks a deterministic [`chunk_schedule`] (all doubles,
//! then all randoms, then truncation widths ascending in round-robin) and
//! feeds fixed-size [`PoolChunk`]s through a channel into a replenishable
//! [`Offline`] pool. `take_*` on the consumer side blocks (pumping the
//! channel) only when the online rounds outrun the producer, so offline
//! generation overlaps online computation instead of sitting on the
//! critical path. [`FactoryStats`] splits the wall time into *generated*
//! seconds (producer side) and *stalled* seconds (consumer side); the
//! difference is the hidden-offline time the ledger reports.
//!
//! ### Chunk-stability contract
//!
//! Chunked production is **element-identical** to one-shot production for
//! the same `(seed, demand)` — the protocol-equivalence acceptance oracle
//! (every `w_trace` stays bit-identical with pipelining on). Three
//! mechanisms guarantee it:
//!
//! 1. **Per-purpose RNG sub-streams.** [`Session`] forks one stream per
//!    (component, role) pair — double values, double degree-`T` coeffs,
//!    double degree-`2T` coeffs, random values, random coeffs, and a
//!    value/coeff pair per truncation width — in a fixed documented
//!    order. A draw's stream position depends only on how many elements
//!    of *that component* came before it, never on chunk boundaries.
//! 2. **Per-value coefficient dealing.** [`deal_round`] draws each
//!    value's `deg` sharing coefficients individually (Horner at batch
//!    width 1), unlike `shamir::share_at`, whose coefficient layout
//!    depends on the batch width and would shift under re-chunking.
//! 3. **Whole-slot extraction buffers.** Extraction yields `N−T` outputs
//!    per dealt slot; the session buffers leftovers between chunks (the
//!    buffer always holds `< N−T` elements), so the cumulative slot
//!    count after any chunking equals the one-shot `⌈count/(N−T)⌉`, and
//!    the slot-major consumption order is unchanged. Bit candidates are
//!    likewise buffered per width: the ready-bit stream is a prefix map
//!    of the deterministic candidate stream, so pair values are
//!    independent of how many candidates any refill happened to extract.
//!
//! Wire *content* is chunk-stable; wire *byte counts* for the bit pools
//! can differ slightly under chunking (candidates are opened in whole
//! extraction slots per refill). [`distributed_bytes_for_party`] models
//! the one-shot schedule and is validated against one-shot runs.
//!
//! ## Serve sessions
//!
//! The phase uses its own tag stripe ([`TAG_BASE`] for session 0) so it
//! can run on the same transport alongside the online windows. Under
//! `copml serve`, job `j` runs in session `j`: its offline traffic moves
//! to `tags::session_offline(j)`, letting job `j+1`'s factory pre-fill
//! pools while job `j` is still training on the same mesh. Session ids
//! change tag numbering only — never any RNG-derived value — so a job's
//! pools (and its `w_trace`) match a standalone single-job run with the
//! same seed.
//!
//! Each party's RNG forks derive from the shared run seed, domain-
//! separated from the dealer streams and the online resharing streams. In
//! a real deployment each party would seed from its own entropy; here the
//! forks derive from the shared run seed so distributed runs stay
//! reproducible (see `prng` module docs — the same caveat the dealer
//! carries).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::field::{vecops, Field};
use crate::net::tags::{self, TagAlloc};
use crate::net::{PartyId, Transport, Wire};
use crate::poly;
use crate::prng::Rng;
use crate::shamir;

use super::dealer::Dealer;

/// First tag of the offline phase's private tag range
/// ([`tags::OFFLINE`]). The online protocol allocates from the windows
/// below it; disjointness is const-asserted in [`tags`], so the two can
/// never collide. Serve sessions stripe this range via
/// [`tags::session_offline`].
///
/// [`tags`]: crate::net::tags
/// [`tags::OFFLINE`]: crate::net::tags::OFFLINE
/// [`tags::session_offline`]: crate::net::tags::session_offline
pub const TAG_BASE: u64 = crate::net::tags::OFFLINE.start;

/// Stream label for the per-party offline-phase RNG ("OFFL" in the high
/// bits, party id in the low bits). Distinct from every `mpc::dealer`
/// stream label and from `mpc::STREAM_PARTY`.
const STREAM_OFFLINE: u64 = 0x4F46_464C_0000_0000;

/// Sub-stream fork labels, forked from the per-party offline base RNG in
/// **exactly this order** (the fork operation advances the parent, so the
/// order is part of the determinism contract): double values, double
/// degree-`T` coefficients, double degree-`2T` coefficients, random
/// values, random coefficients, then per truncation width ascending
/// (`SUB_BIT_VALS | m`, `SUB_BIT_COEFF | m`).
const SUB_DOUBLE_VALS: u64 = 1;
const SUB_DOUBLE_COEFF_T: u64 = 2;
const SUB_DOUBLE_COEFF_2T: u64 = 3;
const SUB_RANDOM_VALS: u64 = 4;
const SUB_RANDOM_COEFF: u64 = 5;
const SUB_BIT_VALS: u64 = 0x1000;
const SUB_BIT_COEFF: u64 = 0x2000;

// ---------------------------------------------------------------------
// Pools (shared by both providers).
// ---------------------------------------------------------------------

/// Pool sizing for one protocol run.
#[derive(Clone, Debug, Default)]
pub struct Demand {
    /// Elements passing through BH08 degree reduction.
    pub doubles: usize,
    /// Elements passing through TruncPr, per truncation width `m`:
    /// `(m, count)`.
    pub truncs: Vec<(u32, usize)>,
    /// Elements of fresh random degree-T sharings.
    pub randoms: usize,
}

/// `demand`'s truncation widths with zero-count entries dropped and
/// duplicate widths merged, ascending — the canonical width list shared
/// by the session, the chunk schedule, and the byte model.
fn merged_widths(demand: &Demand) -> Vec<(u32, usize)> {
    let mut widths: Vec<(u32, usize)> =
        demand.truncs.iter().copied().filter(|&(_, c)| c > 0).collect();
    widths.sort_unstable();
    let mut merged: Vec<(u32, usize)> = Vec::new();
    for (m, c) in widths {
        match merged.last_mut() {
            Some(last) if last.0 == m => last.1 += c,
            _ => merged.push((m, c)),
        }
    }
    merged
}

/// A linearly-consumed pool that can also be **replenished** while it is
/// being drained (the factory feed appends chunks as the online phase
/// takes elements).
#[derive(Default)]
pub(crate) struct Stream {
    data: Vec<u64>,
    pos: usize,
}

impl Stream {
    pub(crate) fn new(data: Vec<u64>) -> Stream {
        Stream { data, pos: 0 }
    }

    fn available(&self) -> usize {
        self.data.len() - self.pos
    }

    fn extend(&mut self, vals: &[u64]) {
        self.data.extend_from_slice(vals);
    }

    fn push(&mut self, val: u64) {
        self.data.push(val);
    }

    /// Take the next `len` elements. Callers check [`Stream::available`]
    /// first (the typed-error paths live on [`Offline`]).
    fn take(&mut self, len: usize) -> Vec<u64> {
        assert!(
            self.pos + len <= self.data.len(),
            "stream over-read (guarded by Offline::take_*)"
        );
        let lo = self.pos;
        self.pos += len;
        let out = self.data[lo..lo + len].to_vec();
        // Reclaim the consumed prefix once it dominates — a long-lived
        // serve pool would otherwise retain every element ever fed.
        if self.pos > 4096 && self.pos * 2 > self.data.len() {
            self.data.drain(..self.pos);
            self.pos = 0;
        }
        out
    }
}

/// Typed failure of an offline pool: the serve daemon degrades (the job
/// halts with this as its reason) instead of crashing the mesh.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum OfflineError {
    /// A pool ran dry and no producer can refill it — the coordinator's
    /// demand precomputation and the consumption disagree.
    Exhausted {
        /// Which pool ("double-sharing", "truncation", "random-share").
        pool: &'static str,
        /// Elements the caller asked for.
        need: usize,
        /// Elements the pool could still supply.
        have: usize,
    },
    /// No truncation pool exists for width `m` (an rp/rpp width mismatch
    /// or a width the demand never declared).
    MissingWidth {
        /// The requested truncation width.
        m: u32,
    },
    /// The factory producer thread terminated before finishing its chunk
    /// schedule (it panicked or was torn down early).
    ProducerDied,
}

impl std::fmt::Display for OfflineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            OfflineError::Exhausted { pool, need, have } => {
                write!(f, "offline {pool} pool exhausted (need {need}, have {have})")
            }
            OfflineError::MissingWidth { m } => {
                write!(f, "no truncation pool for width m={m}")
            }
            OfflineError::ProducerDied => {
                f.write_str("offline factory producer died before completing its schedule")
            }
        }
    }
}

impl std::error::Error for OfflineError {}

/// One batch of offline material crossing from the factory producer to
/// the consuming pool, in deterministic schedule order.
enum PoolChunk {
    /// `count` double sharings: the degree-`T` and degree-`2T` halves.
    Double { t: Vec<u64>, t2: Vec<u64> },
    /// `count` truncation pairs for width `m`.
    Trunc { m: u32, rp: Vec<u64>, rpp: Vec<u64> },
    /// `count` random degree-`T` sharings.
    Random { vals: Vec<u64> },
}

/// Shared producer/consumer accounting for one factory: how long the
/// producer spent generating chunks, and how long the consumer spent
/// blocked waiting for one. `generated − stalled` is the offline time the
/// pipeline *hid* behind online rounds.
#[derive(Default)]
pub struct FactoryStats {
    gen_nanos: AtomicU64,
    stall_nanos: AtomicU64,
    done: AtomicBool,
}

impl FactoryStats {
    fn add_gen(&self, d: Duration) {
        self.gen_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn add_stall(&self, d: Duration) {
        self.stall_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn mark_completed(&self) {
        self.done.store(true, Ordering::Release);
    }

    fn completed(&self) -> bool {
        self.done.load(Ordering::Acquire)
    }

    /// Seconds the producer spent generating chunks (total offline work).
    pub fn gen_seconds(&self) -> f64 {
        self.gen_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Seconds the consumer spent blocked on the feed (the offline time
    /// that stayed on the critical path).
    pub fn stall_seconds(&self) -> f64 {
        self.stall_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }
}

/// The consumer half of a factory channel, owned by the [`Offline`] pool.
struct Feed {
    rx: mpsc::Receiver<PoolChunk>,
    stats: Arc<FactoryStats>,
}

/// Per-party pools of offline randomness. Streams are consumed linearly;
/// a factory-fed pool refills itself from its [`Feed`] on demand, and
/// exhaustion surfaces as a typed [`OfflineError`] (the coordinator
/// converts it into a halt reason).
pub struct Offline {
    pub(crate) double_t: Stream,
    pub(crate) double_2t: Stream,
    pub(crate) trunc_rp: HashMap<u32, Stream>,
    pub(crate) trunc_rpp: HashMap<u32, Stream>,
    pub(crate) random_t: Stream,
    feed: Option<Feed>,
}

impl Default for Offline {
    fn default() -> Self {
        Offline {
            double_t: Stream::new(Vec::new()),
            double_2t: Stream::new(Vec::new()),
            trunc_rp: HashMap::new(),
            trunc_rpp: HashMap::new(),
            random_t: Stream::new(Vec::new()),
            feed: None,
        }
    }
}

impl Offline {
    /// An empty pool pre-provisioned with `demand`'s truncation widths,
    /// so a factory-fed pool can distinguish "chunk not here yet" (pump
    /// the feed) from a genuinely undeclared width
    /// ([`OfflineError::MissingWidth`]).
    fn with_widths(demand: &Demand) -> Offline {
        let mut pool = Offline::default();
        for (m, _) in merged_widths(demand) {
            pool.trunc_rp.insert(m, Stream::default());
            pool.trunc_rpp.insert(m, Stream::default());
        }
        pool
    }

    /// Block on the feed for one more chunk and route it into the pools.
    /// `Ok(false)` means no more chunks can ever arrive (no feed, or the
    /// producer finished its schedule and the channel drained).
    fn pump(&mut self) -> Result<bool, OfflineError> {
        let Some(feed) = self.feed.as_ref() else {
            return Ok(false);
        };
        // copml-lint: allow(wall-clock) consumer-stall stopwatch for the ledger's critical-path vs hidden offline split
        let t0 = Instant::now();
        let msg = feed.rx.recv();
        feed.stats.add_stall(t0.elapsed());
        match msg {
            Ok(PoolChunk::Double { t, t2 }) => {
                self.double_t.extend(&t);
                self.double_2t.extend(&t2);
                Ok(true)
            }
            Ok(PoolChunk::Trunc { m, rp, rpp }) => {
                self.trunc_rp.entry(m).or_default().extend(&rp);
                self.trunc_rpp.entry(m).or_default().extend(&rpp);
                Ok(true)
            }
            Ok(PoolChunk::Random { vals }) => {
                self.random_t.extend(&vals);
                Ok(true)
            }
            Err(mpsc::RecvError) => {
                let done = feed.stats.completed();
                self.feed = None;
                if done {
                    Ok(false)
                } else {
                    Err(OfflineError::ProducerDied)
                }
            }
        }
    }

    /// Take `len` double sharings (the degree-`T` and degree-`2T`
    /// halves), pumping the factory feed if the pool is short.
    pub fn take_double(&mut self, len: usize) -> Result<(Vec<u64>, Vec<u64>), OfflineError> {
        while self.double_t.available() < len || self.double_2t.available() < len {
            if !self.pump()? {
                return Err(OfflineError::Exhausted {
                    pool: "double-sharing",
                    need: len,
                    have: self.double_t.available().min(self.double_2t.available()),
                });
            }
        }
        Ok((self.double_t.take(len), self.double_2t.take(len)))
    }

    /// Take `len` truncation pairs for width `m`, pumping the factory
    /// feed if the pool is short.
    pub fn take_trunc_pair(
        &mut self,
        len: usize,
        m: u32,
    ) -> Result<(Vec<u64>, Vec<u64>), OfflineError> {
        loop {
            let rp_have = self.trunc_rp.get(&m).map(Stream::available);
            let rpp_have = self.trunc_rpp.get(&m).map(Stream::available);
            if rp_have.is_some_and(|h| h >= len) && rpp_have.is_some_and(|h| h >= len) {
                break;
            }
            if !self.pump()? {
                let (Some(rp), Some(rpp)) = (rp_have, rpp_have) else {
                    return Err(OfflineError::MissingWidth { m });
                };
                return Err(OfflineError::Exhausted {
                    pool: "truncation",
                    need: len,
                    have: rp.min(rpp),
                });
            }
        }
        let rp = self.trunc_rp.get_mut(&m).expect("availability checked above").take(len);
        let rpp = self.trunc_rpp.get_mut(&m).expect("availability checked above").take(len);
        Ok((rp, rpp))
    }

    /// Take `len` random degree-`T` sharings, pumping the factory feed if
    /// the pool is short.
    pub fn take_random(&mut self, len: usize) -> Result<Vec<u64>, OfflineError> {
        while self.random_t.available() < len {
            if !self.pump()? {
                return Err(OfflineError::Exhausted {
                    pool: "random-share",
                    need: len,
                    have: self.random_t.available(),
                });
            }
        }
        Ok(self.random_t.take(len))
    }
}

// ---------------------------------------------------------------------
// Mode + provider trait.
// ---------------------------------------------------------------------

/// Who produces the offline pools.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OfflineMode {
    /// Trusted crypto-service provider (footnote 3), replayed from the
    /// shared seed. Free on the wire; the default, bit-identical to every
    /// pre-existing trace.
    #[default]
    Dealer,
    /// Dealer-free: the parties generate every pool collectively (DN07
    /// extraction + Catrina–Saxena bits) over the live transport. The
    /// offline phase becomes a real, byte-accounted protocol cost.
    Distributed,
}

impl OfflineMode {
    /// The provider implementing this mode.
    pub fn provider(self) -> Box<dyn OfflineProvider> {
        match self {
            OfflineMode::Dealer => Box::new(DealerProvider),
            OfflineMode::Distributed => Box::new(DistributedProvider),
        }
    }
}

impl std::fmt::Display for OfflineMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            OfflineMode::Dealer => "dealer",
            OfflineMode::Distributed => "distributed",
        })
    }
}

impl std::str::FromStr for OfflineMode {
    type Err = String;

    fn from_str(s: &str) -> Result<OfflineMode, String> {
        match s {
            "dealer" => Ok(OfflineMode::Dealer),
            "distributed" | "dist" => Ok(OfflineMode::Distributed),
            other => Err(format!(
                "unknown offline mode '{other}' (expected dealer|distributed)"
            )),
        }
    }
}

/// A source of per-party offline pools. `provide` runs on party
/// `net.id()`'s thread/process; the distributed provider communicates
/// over `net` (session `session`'s offline tag stripe), the dealer
/// provider replays pools from the shared seed without touching the wire.
pub trait OfflineProvider {
    /// The mode this provider implements.
    fn mode(&self) -> OfflineMode;

    /// Produce the pools `demand` asks for, one-shot.
    #[allow(clippy::too_many_arguments)]
    fn provide(
        &self,
        net: &dyn Transport,
        f: Field,
        t: usize,
        demand: &Demand,
        k2: u32,
        kappa: u32,
        seed: u64,
        session: u64,
    ) -> Offline;
}

/// [`OfflineMode::Dealer`]: the crypto-service provider of
/// [`super::dealer`], replayed per party from the shared seed
/// (bit-identical to `Dealer::deal(..)[id]`).
pub struct DealerProvider;

impl OfflineProvider for DealerProvider {
    fn mode(&self) -> OfflineMode {
        OfflineMode::Dealer
    }

    fn provide(
        &self,
        net: &dyn Transport,
        f: Field,
        t: usize,
        demand: &Demand,
        k2: u32,
        kappa: u32,
        seed: u64,
        _session: u64,
    ) -> Offline {
        Dealer::deal_one(f, net.n(), t, demand, k2, kappa, seed, net.id())
    }
}

/// [`OfflineMode::Distributed`]: the dealer-free DN07 phase (module docs).
pub struct DistributedProvider;

impl OfflineProvider for DistributedProvider {
    fn mode(&self) -> OfflineMode {
        OfflineMode::Distributed
    }

    fn provide(
        &self,
        net: &dyn Transport,
        f: Field,
        t: usize,
        demand: &Demand,
        k2: u32,
        kappa: u32,
        seed: u64,
        session: u64,
    ) -> Offline {
        generate_in_session(net, f, t, demand, k2, kappa, seed, session)
    }
}

// ---------------------------------------------------------------------
// Extraction core (pure — property-tested in tests/offline_props.rs).
// ---------------------------------------------------------------------

/// DN07 randomness-extraction matrix: `(N−T) × N` Vandermonde rows
/// `M[i][j] = λ_j^i` over the standard share points `λ_j = j+1`.
///
/// Any `N−T` columns form a transposed Vandermonde on distinct nonzero
/// points, hence are invertible: with at most `T` corrupt dealers, the
/// honest dealers' inputs map *bijectively* onto the `N−T` outputs, so
/// the outputs are uniform (and unknown) to the adversary as long as one
/// honest dealer's input was.
pub fn extraction_matrix(f: Field, n: usize, t: usize) -> Vec<Vec<u64>> {
    assert!(n > t, "need more parties than the threshold (n={n}, t={t})");
    let xs = shamir::lambda_points(n);
    (0..n - t)
        .map(|i| xs.iter().map(|&x| f.pow(x, i as u64)).collect())
        .collect()
}

/// Apply the extraction to one party's shares of the `N` dealt batches:
/// `inputs[j]` is this party's share vector of dealer `j`'s batch. Returns
/// `N−T` share vectors, one per extracted output sharing. Linear, so the
/// output shares lie on polynomials of the *same* degree as the inputs and
/// hide `Σ_j M[i][j]·s_j`.
pub fn extract(f: Field, matrix: &[Vec<u64>], inputs: &[&[u64]]) -> Vec<Vec<u64>> {
    matrix
        .iter()
        .map(|row| {
            let mut out = vec![0u64; inputs[0].len()];
            vecops::weighted_sum(f, row, inputs, &mut out);
            out
        })
        .collect()
}

/// Modular square root by Tonelli–Shanks, with the `p ≡ 3 (mod 4)`
/// shortcut. Returns the **canonical** root `min(r, p−r)` so every party
/// derives the same public `c` from the same opened square. `a` must be a
/// quadratic residue (callers pass opened squares); panics otherwise.
pub fn sqrt_mod(f: Field, a: u64) -> u64 {
    let p = f.modulus();
    if a == 0 {
        return 0;
    }
    let r = if p % 4 == 3 {
        f.pow(a, (p + 1) / 4)
    } else {
        // Tonelli–Shanks: write p−1 = q·2^s with q odd.
        let mut q = p - 1;
        let mut s = 0u32;
        while q % 2 == 0 {
            q /= 2;
            s += 1;
        }
        // Any quadratic non-residue works as the generator seed.
        let mut z = 2u64;
        while f.pow(z, (p - 1) / 2) != p - 1 {
            z += 1;
        }
        let mut m = s;
        let mut c = f.pow(z, q);
        let mut tt = f.pow(a, q);
        let mut r = f.pow(a, (q + 1) / 2);
        while tt != 1 {
            // Find least i with t^(2^i) = 1.
            let mut i = 0u32;
            let mut probe = tt;
            while probe != 1 {
                probe = f.mul(probe, probe);
                i += 1;
                assert!(i < m, "sqrt_mod of a non-residue");
            }
            let b = f.pow(c, 1u64 << (m - i - 1));
            m = i;
            c = f.mul(b, b);
            tt = f.mul(tt, c);
            r = f.mul(r, b);
        }
        r
    };
    debug_assert_eq!(f.mul(r, r), a, "sqrt_mod produced a wrong root");
    r.min(p - r)
}

// ---------------------------------------------------------------------
// Collective rounds (free functions so the session can lend its tag
// allocator and one RNG sub-stream without aliasing `&mut self`).
// ---------------------------------------------------------------------

/// Deal a degree-`deg` sharing of `vals` to everyone and collect every
/// dealer's batch: returns `shares[j]` = this party's share of dealer
/// `j`'s batch.
///
/// Coefficients are drawn **per value** from `coeff_rng` (`deg` draws per
/// value, Horner at batch width 1): the stream position after dealing `k`
/// values is `k·deg` no matter how the values were chunked into rounds —
/// the chunk-stability contract (module docs).
fn deal_round(
    net: &dyn Transport,
    f: Field,
    lambdas: &[u64],
    tags: &mut TagAlloc,
    coeff_rng: &mut Rng,
    vals: &[u64],
    deg: usize,
) -> Vec<Vec<u64>> {
    let n = net.n();
    let me = net.id();
    let tag = tags.fresh("offline.step");
    let p = f.modulus();
    let mut shares = vec![vec![0u64; vals.len()]; n];
    let mut coeffs = vec![0u64; deg];
    for (e, &v) in vals.iter().enumerate() {
        coeff_rng.fill_field(p, &mut coeffs);
        for (j, &lambda) in lambdas.iter().enumerate() {
            let mut acc = 0u64;
            for k in (0..deg).rev() {
                acc = f.reduce(f.mul(acc, lambda) + coeffs[k]);
            }
            shares[j][e] = f.reduce(f.mul(acc, lambda) + v);
        }
    }
    let mut own = Vec::new();
    for (j, s) in shares.into_iter().enumerate() {
        if j == me {
            own = s;
        } else {
            net.send(j, tag, s);
        }
    }
    (0..n)
        .map(|j| {
            if j == me {
                std::mem::take(&mut own)
            } else {
                net.recv(j, tag)
            }
        })
        .collect()
}

/// Open degree-`deg` shares via the king (party 0) — the shared
/// [`super::open_via_king`] primitive, on the session's offline stripe.
fn open_round(
    net: &dyn Transport,
    f: Field,
    lambdas: &[u64],
    tags: &mut TagAlloc,
    share: &[u64],
    deg: usize,
) -> Vec<u64> {
    let tag_up = tags.fresh("offline.step");
    let tag_down = tags.fresh("offline.step");
    let coeffs = poly::coeffs_at(f, &lambdas[..deg + 1], 0);
    super::open_via_king(net, f, &coeffs, tag_up, tag_down, share, deg)
}

/// Extract `dealt` (every dealer's batch, `l` slots each) and append the
/// `N−T` outputs per slot to `buf` in slot-major consumption order (all
/// outputs of slot 0, then slot 1, …) — the same element order for every
/// party and every chunking.
fn append_extracted(f: Field, matrix: &[Vec<u64>], dealt: &[Vec<u64>], buf: &mut Vec<u64>) {
    let views: Vec<&[u64]> = dealt.iter().map(|v| v.as_slice()).collect();
    let outs = extract(f, matrix, &views);
    let slots = outs.first().map_or(0, |o| o.len());
    for slot in 0..slots {
        for o in &outs {
            buf.push(o[slot]);
        }
    }
}

// ---------------------------------------------------------------------
// The distributed protocol session.
// ---------------------------------------------------------------------

/// Shared-bit generator state for one truncation width: its two RNG
/// sub-streams and the ready-bit buffer (a prefix map of the width's
/// deterministic candidate stream — see the chunk-stability contract).
struct BitGen {
    rng_vals: Rng,
    rng_coeff: Rng,
    ready: Stream,
}

/// One party's incremental distributed-offline producer. Both the
/// one-shot [`generate`] and the factory producer drive the same session
/// type, so their outputs are element-identical by construction.
struct Session<'a> {
    net: &'a dyn Transport,
    f: Field,
    n: usize,
    t: usize,
    k2: u32,
    kappa: u32,
    lambdas: Vec<u64>,
    matrix: Vec<Vec<u64>>,
    /// Allocator over the session's [`tags::session_offline`] stripe.
    /// Separate-process parties cannot share an in-process
    /// [`tags::SpmdTagTrace`], so divergence here is caught by the
    /// mailbox's `(from, tag)` reuse counter instead.
    tags: TagAlloc,
    /// Canonical `(width, count)` list ([`merged_widths`]).
    widths: Vec<(u32, usize)>,
    rng_dbl_vals: Rng,
    rng_dbl_coeff_t: Rng,
    rng_dbl_coeff_2t: Rng,
    rng_rnd_vals: Rng,
    rng_rnd_coeff: Rng,
    bits: HashMap<u32, BitGen>,
    /// Whole-slot extraction leftovers (always `< N−T` elements), carried
    /// between chunks so cumulative slot counts match the one-shot run.
    buf_dbl_t: Vec<u64>,
    buf_dbl_2t: Vec<u64>,
    buf_rnd: Vec<u64>,
}

impl Session<'_> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        net: &dyn Transport,
        f: Field,
        t: usize,
        demand: &Demand,
        k2: u32,
        kappa: u32,
        seed: u64,
        session: u64,
    ) -> Session<'_> {
        let n = net.n();
        assert!(n > 2 * t, "need n > 2t to open squares during bit generation (n={n}, t={t})");
        let mut base = Rng::seed_from_u64(seed).fork(STREAM_OFFLINE | net.id() as u64);
        // Fork order is part of the determinism contract (label docs).
        let rng_dbl_vals = base.fork(SUB_DOUBLE_VALS);
        let rng_dbl_coeff_t = base.fork(SUB_DOUBLE_COEFF_T);
        let rng_dbl_coeff_2t = base.fork(SUB_DOUBLE_COEFF_2T);
        let rng_rnd_vals = base.fork(SUB_RANDOM_VALS);
        let rng_rnd_coeff = base.fork(SUB_RANDOM_COEFF);
        let widths = merged_widths(demand);
        let mut bits = HashMap::new();
        for &(m, _) in &widths {
            let rng_vals = base.fork(SUB_BIT_VALS | m as u64);
            let rng_coeff = base.fork(SUB_BIT_COEFF | m as u64);
            bits.insert(m, BitGen { rng_vals, rng_coeff, ready: Stream::default() });
        }
        Session {
            net,
            f,
            n,
            t,
            k2,
            kappa,
            lambdas: shamir::lambda_points(n),
            matrix: extraction_matrix(f, n, t),
            tags: TagAlloc::new(net.id(), tags::session_offline(session)),
            widths,
            rng_dbl_vals,
            rng_dbl_coeff_t,
            rng_dbl_coeff_2t,
            rng_rnd_vals,
            rng_rnd_coeff,
            bits,
            buf_dbl_t: Vec::new(),
            buf_dbl_2t: Vec::new(),
            buf_rnd: Vec::new(),
        }
    }

    /// The next `count` double sharings `([ρ]_T, [ρ]_2T)`: the same
    /// dealer batches shared at both degrees, extracted with the same
    /// matrix (linearity keeps the halves consistent).
    fn produce_doubles(&mut self, count: usize) -> (Vec<u64>, Vec<u64>) {
        if count == 0 {
            return (Vec::new(), Vec::new());
        }
        let ex = self.n - self.t;
        if self.buf_dbl_t.len() < count {
            let l = (count - self.buf_dbl_t.len()).div_ceil(ex);
            let p = self.f.modulus();
            let mut vals = vec![0u64; l];
            for v in vals.iter_mut() {
                *v = self.rng_dbl_vals.gen_range(p);
            }
            let dealt_t = deal_round(
                self.net,
                self.f,
                &self.lambdas,
                &mut self.tags,
                &mut self.rng_dbl_coeff_t,
                &vals,
                self.t,
            );
            let dealt_2t = deal_round(
                self.net,
                self.f,
                &self.lambdas,
                &mut self.tags,
                &mut self.rng_dbl_coeff_2t,
                &vals,
                2 * self.t,
            );
            append_extracted(self.f, &self.matrix, &dealt_t, &mut self.buf_dbl_t);
            append_extracted(self.f, &self.matrix, &dealt_2t, &mut self.buf_dbl_2t);
        }
        let out_t: Vec<u64> = self.buf_dbl_t.drain(..count).collect();
        let out_2t: Vec<u64> = self.buf_dbl_2t.drain(..count).collect();
        (out_t, out_2t)
    }

    /// The next `count` random degree-`T` sharings, in consumption order.
    fn produce_randoms(&mut self, count: usize) -> Vec<u64> {
        if count == 0 {
            return Vec::new();
        }
        let ex = self.n - self.t;
        if self.buf_rnd.len() < count {
            let l = (count - self.buf_rnd.len()).div_ceil(ex);
            let p = self.f.modulus();
            let mut vals = vec![0u64; l];
            for v in vals.iter_mut() {
                *v = self.rng_rnd_vals.gen_range(p);
            }
            let dealt = deal_round(
                self.net,
                self.f,
                &self.lambdas,
                &mut self.tags,
                &mut self.rng_rnd_coeff,
                &vals,
                self.t,
            );
            append_extracted(self.f, &self.matrix, &dealt, &mut self.buf_rnd);
        }
        self.buf_rnd.drain(..count).collect()
    }

    /// Ensure width `m`'s ready-bit buffer holds at least `need` bit
    /// shares (module docs): extract candidates `[a]`, open `a²` via the
    /// king, `[b] = (c⁻¹[a]+1)/2` for the canonical root `c`. Slots with
    /// `a² = 0` are discarded consistently (the opened value is public)
    /// and regenerated in a further pass. Every extracted candidate is
    /// opened, so leftovers carry over to later chunks.
    fn refill_bits(&mut self, m: u32, need: usize) {
        let f = self.f;
        let t = self.t;
        let ex = self.n - self.t;
        let p = f.modulus();
        let inv2 = f.inv(2);
        loop {
            let bg = self.bits.get_mut(&m).expect("width registered in Session::new");
            let have = bg.ready.available();
            if have >= need {
                return;
            }
            let l = (need - have).div_ceil(ex);
            let mut vals = vec![0u64; l];
            for v in vals.iter_mut() {
                *v = bg.rng_vals.gen_range(p);
            }
            let dealt = deal_round(
                self.net,
                f,
                &self.lambdas,
                &mut self.tags,
                &mut bg.rng_coeff,
                &vals,
                t,
            );
            let mut a = Vec::with_capacity(l * ex);
            append_extracted(f, &self.matrix, &dealt, &mut a);
            let sq: Vec<u64> = a.iter().map(|&x| f.mul(x, x)).collect();
            let opened = open_round(self.net, f, &self.lambdas, &mut self.tags, &sq, 2 * t);
            let bg = self.bits.get_mut(&m).expect("width registered in Session::new");
            for (&ai, &sqv) in a.iter().zip(&opened) {
                if sqv == 0 {
                    continue; // a = 0 carries no sign bit — retry the slot
                }
                let c = sqrt_mod(f, sqv);
                let signed = f.mul(f.inv(c), ai); // shares of ±1
                bg.ready.push(f.mul(inv2, f.add(signed, 1)));
            }
        }
    }

    /// The next `count` truncation pairs for width `m`: `r' = Σ_{i<m}
    /// 2^i b_i`, `r'' = Σ_{i<k₂+κ−m} 2^i b_{m+i}` — the Catrina–Saxena
    /// composition, linear on the bit shares.
    fn produce_truncs(&mut self, m: u32, count: usize) -> (Vec<u64>, Vec<u64>) {
        assert!(m < self.k2 + self.kappa);
        let f = self.f;
        let (wp, wpp) = (m as usize, (self.k2 + self.kappa - m) as usize);
        self.refill_bits(m, count * (wp + wpp));
        let bg = self.bits.get_mut(&m).expect("width registered in Session::new");
        let compose = |chunk: &[u64]| -> u64 {
            let mut acc = 0u64;
            let mut pow = 1u64;
            for &b in chunk {
                acc = f.add(acc, f.mul(pow, b));
                pow = f.mul(pow, 2);
            }
            acc
        };
        let mut rp = Vec::with_capacity(count);
        let mut rpp = Vec::with_capacity(count);
        for _ in 0..count {
            rp.push(compose(&bg.ready.take(wp)));
            rpp.push(compose(&bg.ready.take(wpp)));
        }
        (rp, rpp)
    }
}

/// Run the distributed offline phase for party `net.id()` in session 0:
/// generate every pool `demand` asks for, collectively, with zero dealer
/// involvement. All parties must call this concurrently (SPMD) with the
/// same arguments. Pool order mirrors the dealer's (doubles, truncation
/// widths ascending, randoms).
pub fn generate(
    net: &dyn Transport,
    f: Field,
    t: usize,
    demand: &Demand,
    k2: u32,
    kappa: u32,
    seed: u64,
) -> Offline {
    generate_in_session(net, f, t, demand, k2, kappa, seed, 0)
}

/// [`generate`] on serve session `session`'s offline tag stripe. Session
/// ids change tag numbering only, never RNG-derived values, so the pools
/// are independent of `session`.
#[allow(clippy::too_many_arguments)]
pub fn generate_in_session(
    net: &dyn Transport,
    f: Field,
    t: usize,
    demand: &Demand,
    k2: u32,
    kappa: u32,
    seed: u64,
    session: u64,
) -> Offline {
    let mut s = Session::new(net, f, t, demand, k2, kappa, seed, session);
    let mut pool = Offline::with_widths(demand);

    let (dt, d2t) = s.produce_doubles(demand.doubles);
    pool.double_t = Stream::new(dt);
    pool.double_2t = Stream::new(d2t);

    let widths = s.widths.clone();
    for (m, count) in widths {
        let (rp, rpp) = s.produce_truncs(m, count);
        pool.trunc_rp.insert(m, Stream::new(rp));
        pool.trunc_rpp.insert(m, Stream::new(rpp));
    }

    pool.random_t = Stream::new(s.produce_randoms(demand.randoms));
    pool
}

// ---------------------------------------------------------------------
// The pipelined factory.
// ---------------------------------------------------------------------

/// One piece of the deterministic production plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ChunkSpec {
    Double { count: usize },
    Random { count: usize },
    Trunc { m: u32, count: usize },
}

/// Split `demand` into `chunk`-sized pieces in production order: all
/// doubles, then all randoms (both consumed early — BH08 of `XᵀY` and the
/// encode masks run before iteration 0), then truncation widths ascending
/// in round-robin (consumed gradually, one batch per SGD iteration — the
/// material the pipeline actually hides). The plan is a pure function of
/// `(demand, chunk)`, identical on every party.
fn chunk_schedule(demand: &Demand, chunk: usize) -> Vec<ChunkSpec> {
    assert!(chunk > 0, "chunk size must be at least 1");
    let mut plan = Vec::new();
    let mut rem = demand.doubles;
    while rem > 0 {
        let c = rem.min(chunk);
        plan.push(ChunkSpec::Double { count: c });
        rem -= c;
    }
    let mut rem = demand.randoms;
    while rem > 0 {
        let c = rem.min(chunk);
        plan.push(ChunkSpec::Random { count: c });
        rem -= c;
    }
    let mut rems = merged_widths(demand);
    while rems.iter().any(|&(_, r)| r > 0) {
        for w in rems.iter_mut() {
            if w.1 == 0 {
                continue;
            }
            let c = w.1.min(chunk);
            plan.push(ChunkSpec::Trunc { m: w.0, count: c });
            w.1 -= c;
        }
    }
    plan
}

/// The factory producer loop: generate each scheduled chunk and hand it
/// to the consumer. Runs SPMD with every peer's producer.
fn producer_main(
    session: &mut Session<'_>,
    plan: &[ChunkSpec],
    tx: &mpsc::Sender<PoolChunk>,
    stats: &FactoryStats,
) {
    for spec in plan {
        // copml-lint: allow(wall-clock) producer stopwatch feeding FactoryStats, the source of the ledger's hidden-offline row
        let t0 = Instant::now();
        let msg = match *spec {
            ChunkSpec::Double { count } => {
                let (t, t2) = session.produce_doubles(count);
                PoolChunk::Double { t, t2 }
            }
            ChunkSpec::Random { count } => {
                PoolChunk::Random { vals: session.produce_randoms(count) }
            }
            ChunkSpec::Trunc { m, count } => {
                let (rp, rpp) = session.produce_truncs(m, count);
                PoolChunk::Trunc { m, rp, rpp }
            }
        };
        stats.add_gen(t0.elapsed());
        // The consumer may have halted and dropped its receiver; keep
        // producing anyway — the schedule is SPMD and the peers' still-
        // running producers need this party's deal and open rounds.
        let _ = tx.send(msg);
    }
    stats.mark_completed();
}

/// A running factory producer: join it after the consumer is done with
/// the pool (its final chunks may still be in flight), and read its
/// [`FactoryStats`] for the ledger split.
pub struct FactoryHandle<'scope> {
    join: std::thread::ScopedJoinHandle<'scope, ()>,
    stats: Arc<FactoryStats>,
}

impl FactoryHandle<'_> {
    /// The stats shared with the pool's feed.
    pub fn stats(&self) -> Arc<FactoryStats> {
        Arc::clone(&self.stats)
    }

    /// Wait for the producer to finish its schedule.
    pub fn join(self) {
        self.join.join().expect("offline factory producer panicked");
    }
}

/// Start the pipelined offline factory for party `net.id()` on `scope`:
/// a background producer generates `demand` in `chunk`-sized pieces
/// (deterministic [`chunk_schedule`]) while the returned [`Offline`] pool
/// is consumed; `take_*` blocks only when consumption outruns production.
/// All parties must start their factories concurrently (SPMD) with the
/// same arguments. The concatenated chunks are element-identical to
/// [`generate`] with the same `(seed, demand)` — the chunk-stability
/// contract (module docs).
#[allow(clippy::too_many_arguments)]
pub fn start_factory<'scope, 'env>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    net: &'env dyn Transport,
    f: Field,
    t: usize,
    demand: &Demand,
    k2: u32,
    kappa: u32,
    seed: u64,
    chunk: usize,
    session: u64,
) -> (Offline, FactoryHandle<'scope>) {
    let plan = chunk_schedule(demand, chunk);
    let mut producer = Session::new(net, f, t, demand, k2, kappa, seed, session);
    let (tx, rx) = mpsc::channel();
    let stats = Arc::new(FactoryStats::default());
    let producer_stats = Arc::clone(&stats);
    let join = scope.spawn(move || {
        producer_main(&mut producer, &plan, &tx, &producer_stats);
    });
    let mut pool = Offline::with_widths(demand);
    pool.feed = Some(Feed { rx, stats: Arc::clone(&stats) });
    (pool, FactoryHandle { join, stats })
}

/// Exact payload bytes party `id` sends during one-shot [`generate`]
/// (assuming no `a² = 0` retry rounds — probability ≈ `bits/p` per run).
/// Mirrors the implementation term by term; validated against the live
/// ledger in `tests/cost_model_validation.rs`. Chunked factory runs can
/// send slightly more on the bit pools (candidates are opened in whole
/// extraction slots per refill), so this models the pipelining-off
/// schedule only.
pub fn distributed_bytes_for_party(
    n: usize,
    t: usize,
    demand: &Demand,
    k2: u32,
    kappa: u32,
    id: PartyId,
    wire: Wire,
) -> u64 {
    let ex = n - t; // usable outputs per extraction batch
    let deal = |count: usize| -> u64 {
        if count == 0 {
            0
        } else {
            ((n - 1) * count.div_ceil(ex)) as u64
        }
    };
    // Doubles: two deal rounds (degree T and 2T) over the same batch size.
    let mut elems = 2 * deal(demand.doubles);
    // Trunc pools: per width, one bit per composed binary digit; each bit
    // costs one extracted candidate `a` (a deal round), and every
    // candidate in the extracted slots is opened via the king.
    for (_, count) in merged_widths(demand) {
        let bits = count * (k2 + kappa) as usize;
        let cands = bits.div_ceil(ex) * ex;
        elems += deal(bits);
        if id == 0 {
            elems += (cands * (n - 1)) as u64; // king broadcasts the squares
        } else if id <= 2 * t {
            elems += cands as u64; // share of the squares, up to the king
        }
    }
    // Random degree-T pool: one deal round.
    elems += deal(demand.randoms);
    elems * wire.elem_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{P25, P26};
    use crate::net::local::Hub;
    use crate::shamir::reconstruct;

    fn demand_basic() -> Demand {
        Demand { doubles: 10, truncs: vec![(5, 6), (10, 6)], randoms: 16 }
    }

    /// Run the distributed offline phase with `n` threads over the Hub and
    /// return every party's pool (id order) plus its sent-byte count.
    fn run_generate(
        f: Field,
        n: usize,
        t: usize,
        demand: &Demand,
        k2: u32,
        kappa: u32,
        seed: u64,
    ) -> Vec<(Offline, u64)> {
        let endpoints = Hub::new(n);
        let demand = demand.clone();
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let demand = demand.clone();
                std::thread::spawn(move || {
                    let pool = generate(&ep, f, t, &demand, k2, kappa, seed);
                    (pool, ep.bytes_sent())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    /// Drain every pool `demand` declares, in the canonical order, into
    /// one flat vector (pool-equality fingerprint).
    fn drain_pool(pool: &mut Offline, demand: &Demand) -> Vec<u64> {
        let mut v = Vec::new();
        let (dt, d2t) = pool.take_double(demand.doubles).expect("doubles sized by demand");
        v.extend(dt);
        v.extend(d2t);
        for &(m, count) in &demand.truncs {
            let (rp, rpp) = pool.take_trunc_pair(count, m).expect("truncs sized by demand");
            v.extend(rp);
            v.extend(rpp);
        }
        v.extend(pool.take_random(demand.randoms).expect("randoms sized by demand"));
        v
    }

    /// Run the pipelined factory with `n` threads over the Hub, drain
    /// every pool, and return each party's fingerprint.
    #[allow(clippy::too_many_arguments)]
    fn run_factory(
        f: Field,
        n: usize,
        t: usize,
        demand: &Demand,
        k2: u32,
        kappa: u32,
        seed: u64,
        chunk: usize,
    ) -> Vec<Vec<u64>> {
        let endpoints = Hub::new(n);
        let demand = demand.clone();
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let demand = demand.clone();
                std::thread::spawn(move || {
                    std::thread::scope(|s| {
                        let (mut pool, handle) =
                            start_factory(s, &ep, f, t, &demand, k2, kappa, seed, chunk, 0);
                        let v = drain_pool(&mut pool, &demand);
                        handle.join();
                        v
                    })
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn distributed_doubles_reconstruct_consistently() {
        let f = Field::new(P26);
        let (n, t) = (7usize, 2usize);
        let mut pools: Vec<Offline> = run_generate(f, n, t, &demand_basic(), 20, 1, 404)
            .into_iter()
            .map(|(p, _)| p)
            .collect();
        let taken: Vec<(Vec<u64>, Vec<u64>)> =
            pools.iter_mut().map(|p| p.take_double(10).unwrap()).collect();
        let t_shares: Vec<Vec<u64>> = taken.iter().map(|(a, _)| a.clone()).collect();
        let t2_shares: Vec<Vec<u64>> = taken.iter().map(|(_, b)| b.clone()).collect();
        assert_eq!(reconstruct(f, &t_shares, t), reconstruct(f, &t2_shares, 2 * t));
    }

    #[test]
    fn distributed_trunc_pairs_in_range() {
        let f = Field::new(P26);
        let (n, t, k2, kappa) = (5usize, 1usize, 20u32, 1u32);
        let mut pools: Vec<Offline> = run_generate(f, n, t, &demand_basic(), k2, kappa, 405)
            .into_iter()
            .map(|(p, _)| p)
            .collect();
        for m in [5u32, 10] {
            let taken: Vec<(Vec<u64>, Vec<u64>)> =
                pools.iter_mut().map(|p| p.take_trunc_pair(6, m).unwrap()).collect();
            let rp =
                reconstruct(f, &taken.iter().map(|x| x.0.clone()).collect::<Vec<_>>(), t);
            let rpp =
                reconstruct(f, &taken.iter().map(|x| x.1.clone()).collect::<Vec<_>>(), t);
            for &v in &rp {
                assert!(v < 1 << m, "r' = {v} out of range for m={m}");
            }
            for &v in &rpp {
                assert!(v < 1 << (k2 + kappa - m), "r'' = {v} out of range for m={m}");
            }
        }
    }

    #[test]
    fn distributed_randoms_are_valid_t_sharings() {
        let f = Field::new(P26);
        let (n, t) = (7usize, 2usize);
        let mut pools: Vec<Offline> = run_generate(f, n, t, &demand_basic(), 20, 1, 406)
            .into_iter()
            .map(|(p, _)| p)
            .collect();
        let shares: Vec<Vec<u64>> =
            pools.iter_mut().map(|p| p.take_random(16).unwrap()).collect();
        // Any two (t+1)-subsets agree — the sharing is degree ≤ t.
        let a = reconstruct(f, &shares, t);
        let pts = shamir::lambda_points(n);
        let sel: Vec<u64> = pts[n - t - 1..].to_vec();
        let rec = shamir::Reconstructor::new(f, &sel);
        let views: Vec<&[u64]> = shares[n - t - 1..].iter().map(|s| s.as_slice()).collect();
        let mut b = vec![0u64; 16];
        rec.reconstruct(f, &views, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn distributed_generation_is_deterministic_per_seed() {
        let f = Field::new(P26);
        let (n, t) = (5usize, 1usize);
        let d = demand_basic();
        let drain = |pools: Vec<(Offline, u64)>| -> Vec<Vec<u64>> {
            pools.into_iter().map(|(mut p, _)| drain_pool(&mut p, &d)).collect()
        };
        let a = drain(run_generate(f, n, t, &d, 20, 1, 7));
        let b = drain(run_generate(f, n, t, &d, 20, 1, 7));
        let c = drain(run_generate(f, n, t, &d, 20, 1, 8));
        assert_eq!(a, b, "same seed must reproduce every pool bit-for-bit");
        assert_ne!(a, c, "different seeds must produce different pools");
    }

    #[test]
    fn chunked_factory_matches_one_shot_pools() {
        // The acceptance oracle in miniature: any chunking of the factory
        // yields exactly the one-shot pools (the integration suite in
        // tests/factory_equivalence.rs widens the grid).
        let f = Field::new(P26);
        let (n, t, k2, kappa) = (5usize, 1usize, 20u32, 1u32);
        let d = demand_basic();
        let reference: Vec<Vec<u64>> = run_generate(f, n, t, &d, k2, kappa, 501)
            .into_iter()
            .map(|(mut p, _)| drain_pool(&mut p, &d))
            .collect();
        for chunk in [1usize, 3, 64] {
            let got = run_factory(f, n, t, &d, k2, kappa, 501, chunk);
            assert_eq!(got, reference, "chunk={chunk} must reproduce the one-shot pools");
        }
    }

    #[test]
    fn factory_exhaustion_after_completion_is_typed() {
        let f = Field::new(P26);
        let (n, t) = (4usize, 1usize);
        let d = Demand { doubles: 5, truncs: vec![], randoms: 0 };
        let endpoints = Hub::new(n);
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let d = d.clone();
                std::thread::spawn(move || {
                    std::thread::scope(|s| {
                        let (mut pool, handle) =
                            start_factory(s, &ep, f, t, &d, 20, 1, 77, 2, 0);
                        pool.take_double(5).expect("pool sized for demand");
                        let err = pool.take_double(1).unwrap_err();
                        handle.join();
                        assert!(
                            matches!(
                                err,
                                OfflineError::Exhausted { pool: "double-sharing", .. }
                            ),
                            "got {err:?}"
                        );
                    })
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn chunk_schedule_covers_demand_round_robin() {
        let d = demand_basic();
        let plan = chunk_schedule(&d, 4);
        let (mut doubles, mut randoms) = (0usize, 0usize);
        let mut truncs: HashMap<u32, usize> = HashMap::new();
        for spec in &plan {
            match *spec {
                ChunkSpec::Double { count } => doubles += count,
                ChunkSpec::Random { count } => randoms += count,
                ChunkSpec::Trunc { m, count } => *truncs.entry(m).or_insert(0) += count,
            }
        }
        assert_eq!(doubles, d.doubles);
        assert_eq!(randoms, d.randoms);
        assert_eq!(truncs.get(&5), Some(&6));
        assert_eq!(truncs.get(&10), Some(&6));
        // every piece respects the cap, and widths alternate fairly
        for spec in &plan {
            let c = match *spec {
                ChunkSpec::Double { count }
                | ChunkSpec::Random { count }
                | ChunkSpec::Trunc { count, .. } => count,
            };
            assert!(c >= 1 && c <= 4, "chunk cap violated: {spec:?}");
        }
        assert_eq!(
            &plan[plan.len() - 4..],
            &[
                ChunkSpec::Trunc { m: 5, count: 4 },
                ChunkSpec::Trunc { m: 10, count: 4 },
                ChunkSpec::Trunc { m: 5, count: 2 },
                ChunkSpec::Trunc { m: 10, count: 2 },
            ],
            "trunc widths must round-robin"
        );
    }

    #[test]
    fn pool_exhaustion_is_typed() {
        let mut pool = Offline {
            double_t: Stream::new(vec![1, 2, 3]),
            double_2t: Stream::new(vec![1, 2, 3]),
            ..Offline::default()
        };
        let err = pool.take_double(4).unwrap_err();
        assert_eq!(
            err,
            OfflineError::Exhausted { pool: "double-sharing", need: 4, have: 3 }
        );
        assert!(err.to_string().contains("exhausted"), "got: {err}");
    }

    #[test]
    fn ledger_bytes_match_analytic_accounting() {
        let f = Field::new(P26);
        let (n, t, k2, kappa) = (7usize, 2usize, 20u32, 1u32);
        let d = demand_basic();
        for (id, (_, sent)) in run_generate(f, n, t, &d, k2, kappa, 407).into_iter().enumerate()
        {
            let expect =
                distributed_bytes_for_party(n, t, &d, k2, kappa, id, Wire::U64);
            assert_eq!(sent, expect, "party {id} byte accounting");
        }
    }

    #[test]
    fn trunc_rpp_mismatch_diagnosable() {
        // Regression: the r'' lookup used a bare `.unwrap()`, so an rp/rpp
        // width mismatch died with an anonymous Option panic. Now it is a
        // typed MissingWidth the serve daemon can degrade on.
        let mut pool = Offline::default();
        pool.trunc_rp.insert(6, Stream::new(vec![1, 2, 3]));
        let err = pool.take_trunc_pair(1, 6).unwrap_err();
        assert_eq!(err, OfflineError::MissingWidth { m: 6 });
        assert_eq!(err.to_string(), "no truncation pool for width m=6");
    }

    #[test]
    fn sqrt_mod_both_residue_classes() {
        // P26 ≡ 3 (mod 4) takes the shortcut; P25 ≡ 1 (mod 4) exercises
        // Tonelli–Shanks proper.
        for p in [P26, P25] {
            let f = Field::new(p);
            let mut rng = Rng::seed_from_u64(9);
            for _ in 0..200 {
                let x = rng.gen_range(p);
                let sq = f.mul(x, x);
                let r = sqrt_mod(f, sq);
                assert_eq!(f.mul(r, r), sq, "p={p} x={x}");
                assert!(r <= p - r || r == 0, "canonical root must be the smaller one");
            }
            assert_eq!(sqrt_mod(f, 0), 0);
        }
    }

    #[test]
    fn distributed_pools_drive_trunc_pr() {
        // End-to-end: a Party running on distributed pools truncates
        // correctly (floor or floor+1, exact on multiples).
        use crate::mpc::Party;
        let f = Field::new(P26);
        let (n, t) = (5usize, 1usize);
        let (k, m, kappa) = (20u32, 5u32, 1u32);
        let vals_signed: Vec<i64> = vec![0, 64, 100, -64, -100, (1 << 19) - 1];
        let vals: Vec<u64> = vals_signed.iter().map(|&v| f.from_i64(v)).collect();
        let mut rng = Rng::seed_from_u64(31);
        let shares = shamir::share(f, &vals, n, t, &mut rng);
        let demand =
            Demand { doubles: 0, truncs: vec![(m, vals.len())], randoms: 0 };
        let endpoints = Hub::new(n);
        let handles: Vec<_> = endpoints
            .into_iter()
            .zip(shares)
            .map(|(ep, input)| {
                let demand = demand.clone();
                std::thread::spawn(move || {
                    let pool = generate(&ep, f, t, &demand, k, kappa, 33);
                    let party = Party::new(&ep, t, f, pool, 33);
                    let z = party
                        .trunc_pr(&input, k, m, kappa, true)
                        .expect("truncation pool sized for demand");
                    party.open_broadcast(&z, t)
                })
            })
            .collect();
        let results: Vec<Vec<u64>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for r in &results {
            for (i, &v) in vals_signed.iter().enumerate() {
                let got = f.to_i64(r[i]);
                let floor = v.div_euclid(1 << m);
                assert!(got == floor || got == floor + 1, "val {v}: got {got}");
                if v.rem_euclid(1 << m) == 0 {
                    assert_eq!(got, floor);
                }
            }
        }
    }
}
