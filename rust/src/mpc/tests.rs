//! Multi-threaded tests of the MPC collectives: every protocol is run by
//! `n` real threads over the local transport and checked against plaintext.

use super::dealer::{Dealer, Demand};
use super::*;
use crate::field::P26;
use crate::net::local::Hub;
use crate::shamir;

/// Run `n` parties, each executing `body`, and return their results in id
/// order. Shares of `secrets` are dealt beforehand: party i receives
/// `inputs[i]`.
fn run_parties<R, F>(
    n: usize,
    t: usize,
    f: Field,
    demand: Demand,
    k2_kappa: (u32, u32),
    inputs: Vec<Vec<Vec<u64>>>,
    body: F,
) -> Vec<R>
where
    R: Send + 'static,
    F: Fn(&Party, Vec<Vec<u64>>) -> R + Send + Sync + Clone + 'static,
{
    assert_eq!(inputs.len(), n);
    let pools = Dealer::deal(f, n, t, &demand, k2_kappa.0, k2_kappa.1, 0xD1CE);
    let endpoints = Hub::new(n);
    let mut handles = Vec::new();
    for ((ep, pool), input) in endpoints.into_iter().zip(pools).zip(inputs) {
        let body = body.clone();
        handles.push(std::thread::spawn(move || {
            let party = Party::new(&ep, t, f, pool, 42);
            body(&party, input)
        }));
    }
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Deal shares of `values` to n parties (index 0 of each party's input).
fn deal(f: Field, values: &[u64], n: usize, t: usize, seed: u64) -> Vec<Vec<Vec<u64>>> {
    let mut rng = crate::prng::Rng::seed_from_u64(seed);
    let shares = shamir::share(f, values, n, t, &mut rng);
    shares.into_iter().map(|s| vec![s]).collect()
}

#[test]
fn party_rng_domain_separated() {
    // Regression: party 0's online stream used to equal the raw
    // `Rng::seed_from_u64(seed)` stream the dealer's forks derive from.
    let seed = 0xABCD_1234u64;
    let mut master = crate::prng::Rng::seed_from_u64(seed);
    let mut p0 = party_rng(seed, 0);
    let same = (0..64).filter(|_| master.next_u64() == p0.next_u64()).count();
    assert!(same < 2, "party 0 must not track the master seed stream");
    // Parties are pairwise independent streams.
    for (a, b) in [(0usize, 1usize), (1, 2), (0, 7)] {
        let mut ra = party_rng(seed, a);
        let mut rb = party_rng(seed, b);
        let same = (0..64).filter(|_| ra.next_u64() == rb.next_u64()).count();
        assert!(same < 2, "parties {a} and {b} share a stream");
    }
    // Deterministic per (seed, id).
    let mut x = party_rng(seed, 3);
    let mut y = party_rng(seed, 3);
    for _ in 0..16 {
        assert_eq!(x.next_u64(), y.next_u64());
    }
}

#[test]
fn open_broadcast_and_king_agree() {
    let f = Field::new(P26);
    let (n, t) = (5usize, 2usize);
    let secret: Vec<u64> = vec![3, 1 << 20, P26 - 1, 0];
    let inputs = deal(f, &secret, n, t, 7);
    let secret2 = secret.clone();
    let results = run_parties(
        n,
        t,
        f,
        Demand::default(),
        (20, 1),
        inputs,
        move |party, input| {
            let a = party.open_broadcast(&input[0], party.t);
            let b = party.open_king(&input[0], party.t);
            assert_eq!(a, b);
            a
        },
    );
    for r in results {
        assert_eq!(r, secret2);
    }
}

#[test]
fn roster_aware_openings_skip_excluded_party() {
    // An excluded straggler neither sends nor receives; the survivors'
    // openings reconstruct from the first deg+1 LIVE shares and reach the
    // same value — any deg+1 points interpolate exactly.
    let f = Field::new(P26);
    let (n, t) = (6usize, 2usize);
    let secret: Vec<u64> = vec![5, P26 - 3, 1 << 10];
    let inputs = deal(f, &secret, n, t, 17);
    let secret2 = secret.clone();
    let results = run_parties(
        n,
        t,
        f,
        Demand::default(),
        (20, 1),
        inputs,
        move |party, input| {
            // Exclude party 1 — INSIDE the default contributor prefix
            // {0..=2t}, so the roster genuinely changes who reconstructs.
            let gone = 1;
            if party.id == gone {
                party.net.leave("excluded by test");
                return Vec::new();
            }
            party.exclude(gone);
            let a = party.open_broadcast(&input[0], party.t);
            let b = party.open_king(&input[0], party.t);
            assert_eq!(a, b, "broadcast and king openings must agree post-exclusion");
            a
        },
    );
    for (id, r) in results.iter().enumerate() {
        if id != 1 {
            assert_eq!(r, &secret2, "party {id}");
        }
    }
}

#[test]
fn excluding_the_king_is_rejected() {
    let f = Field::new(P26);
    let eps = Hub::new(3);
    let pool = Dealer::deal(f, 3, 1, &Demand::default(), 20, 1, 0xD1CE).remove(0);
    let party = Party::new(&eps[0], 1, f, pool, 42);
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| party.exclude(0)))
        .unwrap_err();
    let msg = err
        .downcast_ref::<String>()
        .map(|s| s.as_str())
        .or_else(|| err.downcast_ref::<&str>().copied())
        .expect("panic payload");
    assert!(msg.contains("king"), "{msg}");
}

#[test]
fn recon_cache_is_bounded_and_correct_under_rotating_exclusions() {
    // Regression: the reconstruction-coefficient cache used to grow one
    // row per distinct contributor set with no bound — a run whose roster
    // churns (rotating exclusions) would accumulate them forever.
    let f = Field::new(P26);
    let n = 16usize;
    let eps = Hub::new(n);
    let pool = Dealer::deal(f, n, 3, &Demand::default(), 20, 1, 0xD1CE).remove(0);
    let party = Party::new(&eps[0], 3, f, pool, 42);
    let deg = 3usize;
    // Slide the contributor window across the roster: every rotation is a
    // distinct set, the churn an exclusion-heavy run produces.
    for round in 0..3 * Party::RECON_CACHE_CAP {
        let start = round % (n - deg);
        let ids: Vec<PartyId> = (start..=start + deg).collect();
        let coeffs = party.recon_coeffs_for(&ids);
        let pts: Vec<u64> = ids.iter().map(|&j| party.lambdas[j]).collect();
        assert_eq!(
            coeffs,
            crate::poly::coeffs_at(f, &pts, 0),
            "cached row must stay correct (round {round})"
        );
        assert!(
            party.recon_cache_len() <= Party::RECON_CACHE_CAP,
            "cache grew past its bound ({} sets)",
            party.recon_cache_len()
        );
    }
    // The first set was evicted rounds ago; re-requesting recomputes the
    // identical row — eviction is invisible apart from the recompute.
    let ids: Vec<PartyId> = (0..=deg).collect();
    let pts: Vec<u64> = ids.iter().map(|&j| party.lambdas[j]).collect();
    assert_eq!(party.recon_coeffs_for(&ids), crate::poly::coeffs_at(f, &pts, 0));
}

#[test]
fn secure_addition_is_free_and_correct() {
    let f = Field::new(P26);
    let (n, t) = (4usize, 1usize);
    let a: Vec<u64> = vec![10, 20, 30];
    let b: Vec<u64> = vec![5, P26 - 1, 7];
    let mut rng = crate::prng::Rng::seed_from_u64(9);
    let sa = shamir::share(f, &a, n, t, &mut rng);
    let sb = shamir::share(f, &b, n, t, &mut rng);
    let inputs: Vec<Vec<Vec<u64>>> = sa.into_iter().zip(sb).map(|(x, y)| vec![x, y]).collect();
    let expect: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| f.add(x, y)).collect();
    let results = run_parties(
        n,
        t,
        f,
        Demand::default(),
        (20, 1),
        inputs,
        |party, input| {
            let bytes_before = party.net.bytes_sent();
            let mut s = input[0].clone();
            party.add(&mut s, &input[1]);
            assert_eq!(party.net.bytes_sent(), bytes_before, "addition must be local");
            party.open_broadcast(&s, party.t)
        },
    );
    for r in results {
        assert_eq!(r, expect);
    }
}

#[test]
fn bgw_multiplication_correct() {
    let f = Field::new(P26);
    let (n, t) = (5usize, 2usize); // n ≥ 2t+1
    let a: Vec<u64> = vec![1234, 99999, P26 - 5];
    let b: Vec<u64> = vec![777, 1, 2];
    let mut rng = crate::prng::Rng::seed_from_u64(11);
    let sa = shamir::share(f, &a, n, t, &mut rng);
    let sb = shamir::share(f, &b, n, t, &mut rng);
    let inputs: Vec<Vec<Vec<u64>>> = sa.into_iter().zip(sb).map(|(x, y)| vec![x, y]).collect();
    let expect: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| f.mul(x, y)).collect();
    let results = run_parties(
        n,
        t,
        f,
        Demand::default(),
        (20, 1),
        inputs,
        |party, input| {
            let prod = party.mul(&input[0], &input[1], true).unwrap();
            party.open_broadcast(&prod, party.t)
        },
    );
    for r in results {
        assert_eq!(r, expect);
    }
}

#[test]
fn bh08_multiplication_correct() {
    let f = Field::new(P26);
    let (n, t) = (7usize, 3usize);
    let a: Vec<u64> = (0..20).map(|i| i * 31 % P26).collect();
    let b: Vec<u64> = (0..20).map(|i| (i * i + 5) % P26).collect();
    let mut rng = crate::prng::Rng::seed_from_u64(13);
    let sa = shamir::share(f, &a, n, t, &mut rng);
    let sb = shamir::share(f, &b, n, t, &mut rng);
    let inputs: Vec<Vec<Vec<u64>>> = sa.into_iter().zip(sb).map(|(x, y)| vec![x, y]).collect();
    let expect: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| f.mul(x, y)).collect();
    let results = run_parties(
        n,
        t,
        f,
        Demand { doubles: 20, ..Default::default() },
        (20, 1),
        inputs,
        |party, input| {
            let prod = party.mul(&input[0], &input[1], false).unwrap();
            party.open_broadcast(&prod, party.t)
        },
    );
    for r in results {
        assert_eq!(r, expect);
    }
}

#[test]
fn bh08_cheaper_than_bgw_in_bytes() {
    let f = Field::new(P26);
    let (n, t) = (7usize, 3usize);
    let len = 64usize;
    let a: Vec<u64> = (0..len as u64).collect();
    let mut rng = crate::prng::Rng::seed_from_u64(17);
    let sa = shamir::share(f, &a, n, t, &mut rng);
    let inputs: Vec<Vec<Vec<u64>>> = sa.into_iter().map(|x| vec![x]).collect();
    let results = run_parties(
        n,
        t,
        f,
        Demand { doubles: len, ..Default::default() },
        (20, 1),
        inputs,
        |party, input| {
            let before = party.net.bytes_sent();
            let _ = party.degree_reduce_bgw(&input[0]);
            let bgw = party.net.bytes_sent() - before;
            let before = party.net.bytes_sent();
            party.degree_reduce_bh08(&input[0]).unwrap();
            let bh08 = party.net.bytes_sent() - before;
            (bgw, bh08)
        },
    );
    let bgw_total: u64 = results.iter().map(|r| r.0).sum();
    let bh08_total: u64 = results.iter().map(|r| r.1).sum();
    assert!(
        bh08_total * 2 < bgw_total,
        "BH08 ({bh08_total} B) should be ≪ BGW ({bgw_total} B)"
    );
}

#[test]
fn trunc_pr_floor_plus_bernoulli() {
    // For each element: result ∈ {⌊a/2^m⌋, ⌊a/2^m⌋+1}; exact when a is a
    // multiple of 2^m.
    let f = Field::new(P26);
    let (n, t) = (5usize, 2usize);
    let (k, m, kappa) = (20u32, 8u32, 1u32);
    let vals_signed: Vec<i64> = vec![0, 256, 300, -256, -300, 511, -1, (1 << 19) - 1, -(1 << 19) + 1];
    let vals: Vec<u64> = vals_signed.iter().map(|&v| f.from_i64(v)).collect();
    let inputs = deal(f, &vals, n, t, 19);
    let results = run_parties(
        n,
        t,
        f,
        Demand { doubles: 0, truncs: vec![(m, vals.len())], randoms: 0 },
        (k, kappa),
        inputs,
        move |party, input| {
            let z = party.trunc_pr(&input[0], k, m, kappa, true).unwrap();
            party.open_broadcast(&z, party.t)
        },
    );
    for r in &results {
        for (i, &v) in vals_signed.iter().enumerate() {
            let got = f.to_i64(r[i]);
            let floor = v.div_euclid(1 << m);
            assert!(
                got == floor || got == floor + 1,
                "val {v}: got {got}, floor {floor}"
            );
            if v.rem_euclid(1 << m) == 0 {
                assert_eq!(got, floor, "exact multiple must truncate exactly");
            }
        }
    }
}

#[test]
fn trunc_pr_statistical_mean() {
    // Across many elements with the same value, the mean result ≈ a/2^m
    // (unbiasedness of the stochastic rounding: E[z] = a/2^m).
    let f = Field::new(P26);
    let (n, t) = (4usize, 1usize);
    let (k, m, kappa) = (20u32, 8u32, 1u32);
    let count = 3000usize;
    let a_val: i64 = 300; // 300/256 = 1.171875
    let vals: Vec<u64> = vec![f.from_i64(a_val); count];
    let inputs = deal(f, &vals, n, t, 23);
    let results = run_parties(
        n,
        t,
        f,
        Demand { doubles: 0, truncs: vec![(m, count)], randoms: 0 },
        (k, kappa),
        inputs,
        move |party, input| {
            let z = party.trunc_pr(&input[0], k, m, kappa, true).unwrap();
            party.open_broadcast(&z, party.t)
        },
    );
    let mean: f64 =
        results[0].iter().map(|&v| f.to_i64(v) as f64).sum::<f64>() / count as f64;
    let expect = a_val as f64 / 256.0;
    assert!(
        (mean - expect).abs() < 0.03,
        "mean {mean} vs {expect} — stochastic rounding should be unbiased"
    );
}

#[test]
fn random_share_reconstructs_consistently() {
    let f = Field::new(P26);
    let (n, t) = (5usize, 2usize);
    let inputs: Vec<Vec<Vec<u64>>> = vec![vec![]; n];
    let results = run_parties(
        n,
        t,
        f,
        Demand { doubles: 0, truncs: vec![], randoms: 8 },
        (20, 1),
        inputs,
        |party, _input| {
            let r = party.random_share(8).unwrap();
            party.open_broadcast(&r, party.t)
        },
    );
    for r in &results[1..] {
        assert_eq!(*r, results[0]);
    }
}

#[test]
fn secure_inner_product_via_local_sums() {
    // Local share products summed give a degree-2T share of the inner
    // product; one reduction + open recovers ⟨a,b⟩ — the pattern the
    // baseline secure matmul uses.
    let f = Field::new(P26);
    let (n, t) = (5usize, 2usize);
    let d = 30usize;
    let a: Vec<u64> = (1..=d as u64).collect();
    let b: Vec<u64> = (1..=d as u64).map(|v| v * 7 % P26).collect();
    let mut rng = crate::prng::Rng::seed_from_u64(29);
    let sa = shamir::share(f, &a, n, t, &mut rng);
    let sb = shamir::share(f, &b, n, t, &mut rng);
    let inputs: Vec<Vec<Vec<u64>>> = sa.into_iter().zip(sb).map(|(x, y)| vec![x, y]).collect();
    let expect = {
        let mut acc = 0u64;
        for i in 0..d {
            acc = f.add(acc, f.mul(a[i], b[i]));
        }
        acc
    };
    let results = run_parties(
        n,
        t,
        f,
        Demand { doubles: 1, ..Default::default() },
        (20, 1),
        inputs,
        |party, input| {
            let local = crate::field::vecops::dot(party.f, &input[0], &input[1]);
            let reduced = party.degree_reduce_bh08(&[local]).unwrap();
            party.open_broadcast(&reduced, party.t)[0]
        },
    );
    for r in results {
        assert_eq!(r, expect);
    }
}
