//! Experiment reporting: a tiny JSON value/serializer (no `serde` in the
//! offline image) and aligned-column table printing for the bench harness.

mod json;
mod table;

pub use json::Json;
pub use table::Table;
