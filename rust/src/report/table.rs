//! Aligned-column table printing for bench/experiment output, mirroring the
//! row layout of the paper's tables.

/// A simple right-aligned table with a header row.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: first cell is a label, the rest are f64s with `prec`
    /// decimal places.
    pub fn row_f64(&mut self, label: &str, values: &[f64], prec: usize) -> &mut Self {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| format!("{v:.prec$}")));
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    line.push_str(&format!("{:<width$}", c, width = widths[i]));
                } else {
                    line.push_str(&format!("  {:>width$}", c, width = widths[i]));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Table I", &["Protocol", "Comp (s)", "Total (s)"]);
        t.row(&["MPC [BGW88]".into(), "918".into(), "22384".into()]);
        t.row(&["COPML (Case 1)".into(), "141".into(), "440".into()]);
        let r = t.render();
        assert!(r.contains("Table I"));
        let lines: Vec<&str> = r.lines().collect();
        // all data lines same width
        assert_eq!(lines[2].len(), lines[3].len());
        assert!(r.contains("22384"));
    }

    #[test]
    fn row_f64_formats() {
        let mut t = Table::new("", &["who", "v"]);
        t.row_f64("a", &[1.23456], 2);
        assert!(t.render().contains("1.23"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(&["only one".into()]);
    }
}
