//! Minimal JSON value + serializer + parser.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`, written by
//! `python/compile/aot.py`) and for dumping experiment results. Supports the
//! full JSON grammar except exotic number formats; numbers parse to f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(v: f64) -> Json {
        Json::Num(v)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Field access for objects; `None` for anything else.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|v| v as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|v| v as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 9e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing content at byte {pos}"));
        }
        Ok(v)
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'n' => expect_lit(b, pos, "null", Json::Null),
        b't' => expect_lit(b, pos, "true", Json::Bool(true)),
        b'f' => expect_lit(b, pos, "false", Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected , or ] at byte {pos}", pos = *pos)),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected : at byte {pos}", pos = *pos));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                map.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("expected , or }} at byte {pos}", pos = *pos)),
                }
            }
        }
        _ => parse_number(b, pos),
    }
}

fn expect_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {pos}", pos = *pos))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}", pos = *pos));
    }
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err("bad escape".into()),
                }
                *pos += 1;
            }
            c => {
                // UTF-8 passthrough: collect the full multibyte char.
                let ch_len = match c {
                    0x00..=0x7f => 1,
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                let chunk = std::str::from_utf8(&b[*pos..*pos + ch_len])
                    .map_err(|_| "invalid utf8 in string")?;
                s.push_str(chunk);
                *pos += ch_len;
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad number")?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("invalid number '{text}' at byte {start}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_document() {
        let doc = Json::obj(vec![
            ("name", Json::str("copml")),
            ("n", Json::num(50.0)),
            ("ratio", Json::num(16.4)),
            ("flags", Json::arr(vec![Json::Bool(true), Json::Null])),
            (
                "nested",
                Json::obj(vec![("k", Json::num(16.0)), ("t", Json::num(1.0))]),
            ),
        ]);
        let s = doc.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn parses_whitespace_and_escapes() {
        let v = Json::parse(" { \"a\\n\" : [ 1 , 2.5 , \"x\\\"y\" ] } ").unwrap();
        assert_eq!(v.get("a\n").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(2.5).to_string(), "2.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
        let esc = Json::parse("\"\\u0041\\u00e9\"").unwrap();
        assert_eq!(esc.as_str().unwrap(), "Aé");
    }
}
