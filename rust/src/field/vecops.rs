//! Batch (vector / matrix) operations over `F_p` — the L3 hot path.
//!
//! All matrices are dense row-major `&[u64]` with a [`MatShape`]. The
//! overflow discipline follows Appendix A of the paper: u64 accumulators,
//! one modular reduction per [`Field::accum_budget`] accumulated products
//! ("modular operation after the inner product instead of per element").
//!
//! The two operations that dominate COPML's runtime are:
//! * [`weighted_sum`] — Lagrange encoding/decoding (Eqs. 3, 4, 10) is a
//!   weighted sum of `K+T` matrices with public coefficients;
//! * [`matvec`] / [`matvec_t`] — the encoded gradient `X̃ᵀ ĝ(X̃·w̃)` (Eq. 7)
//!   when executed on the native fallback instead of PJRT.

use super::Field;

/// Lane width of the blocked raw-accumulation helpers below. Eight u64
/// lanes fill two AVX2 registers (or one AVX-512 register); the fixed
/// width is what lets the autovectorizer emit SIMD multiply-adds.
pub const LANES: usize = 8;

/// Raw (reduction-free) lane-blocked `acc[i] += c·x[i]` — the inner loop of
/// the Montgomery kernel tier ([`super::mont`]). No modular reduction, no
/// iterator chain, no branch: a fixed [`LANES`]-wide block of indexed
/// multiply-adds the autovectorizer turns into SIMD, plus a scalar tail.
/// The caller owns the overflow discipline ([`Field::accum_budget`]).
#[inline]
pub fn axpy_raw_lanes(acc: &mut [u64], c: u64, x: &[u64]) {
    debug_assert_eq!(acc.len(), x.len());
    let n = acc.len();
    let mut j = 0;
    while j + LANES <= n {
        acc[j] += c * x[j];
        acc[j + 1] += c * x[j + 1];
        acc[j + 2] += c * x[j + 2];
        acc[j + 3] += c * x[j + 3];
        acc[j + 4] += c * x[j + 4];
        acc[j + 5] += c * x[j + 5];
        acc[j + 6] += c * x[j + 6];
        acc[j + 7] += c * x[j + 7];
        j += LANES;
    }
    while j < n {
        acc[j] += c * x[j];
        j += 1;
    }
}

/// Raw (reduction-free) lane-blocked `Σ a[i]·b[i]` over one accumulation-
/// budget tile — the other half of the [`super::mont`] inner loops. The
/// caller guarantees `a.len() ≤ accum_budget` so the u64 sum cannot wrap.
#[inline]
pub fn dot_raw_lanes(a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut lanes = [0u64; LANES];
    let mut j = 0;
    while j + LANES <= n {
        lanes[0] += a[j] * b[j];
        lanes[1] += a[j + 1] * b[j + 1];
        lanes[2] += a[j + 2] * b[j + 2];
        lanes[3] += a[j + 3] * b[j + 3];
        lanes[4] += a[j + 4] * b[j + 4];
        lanes[5] += a[j + 5] * b[j + 5];
        lanes[6] += a[j + 6] * b[j + 6];
        lanes[7] += a[j + 7] * b[j + 7];
        j += LANES;
    }
    let mut t = 0u64;
    while j < n {
        t += a[j] * b[j];
        j += 1;
    }
    // Pairwise lane fold (outside the hot loop, so plain adds are fine).
    t + ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
}

/// Row-major dense matrix shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MatShape {
    pub rows: usize,
    pub cols: usize,
}

impl MatShape {
    pub fn new(rows: usize, cols: usize) -> MatShape {
        MatShape { rows, cols }
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// `a[i] ← a[i] + b[i] (mod p)`.
pub fn add_assign(f: Field, a: &mut [u64], b: &[u64]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x = f.add(*x, y);
    }
}

/// `a[i] ← a[i] − b[i] (mod p)`.
pub fn sub_assign(f: Field, a: &mut [u64], b: &[u64]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, &y) in a.iter_mut().zip(b) {
        *x = f.sub(*x, y);
    }
}

/// `a[i] ← c · a[i] (mod p)`.
pub fn scale_assign(f: Field, a: &mut [u64], c: u64) {
    for x in a.iter_mut() {
        *x = f.mul(*x, c);
    }
}

/// `out[i] ← out[i] + c · x[i] (mod p)` — multiplication by a public
/// constant, the only multiplication Lagrange encode/decode needs
/// (paper Remark 3: no communication).
pub fn axpy(f: Field, out: &mut [u64], c: u64, x: &[u64]) {
    debug_assert_eq!(out.len(), x.len());
    for (o, &v) in out.iter_mut().zip(x) {
        // o < p, c·v < (p−1)² ≤ 2^62 → sum fits u64.
        *o = f.reduce(*o + c * v);
    }
}

/// `out ← Σ_k coeffs[k] · mats[k]` (mod p), blocked for cache friendliness.
///
/// This is one Lagrange evaluation point of Eq. (3)/(4)/(10). Processes
/// elements in blocks: for each block, accumulates all `K+T` terms in u64
/// (reducing only when the accumulation budget is hit), then reduces once.
pub fn weighted_sum(f: Field, coeffs: &[u64], mats: &[&[u64]], out: &mut [u64]) {
    assert_eq!(coeffs.len(), mats.len());
    let n = out.len();
    for m in mats {
        assert_eq!(m.len(), n, "matrix size mismatch in weighted_sum");
    }
    out.fill(0);
    let budget = f.accum_budget();
    const BLOCK: usize = 4096;
    let mut start = 0;
    while start < n {
        let end = (start + BLOCK).min(n);
        let out_b = &mut out[start..end];
        let mut pending = 0usize;
        for (k, m) in mats.iter().enumerate() {
            let c = coeffs[k];
            if c == 0 {
                continue;
            }
            let m_b = &m[start..end];
            if pending + 1 > budget {
                for o in out_b.iter_mut() {
                    *o = f.reduce(*o);
                }
                pending = 0;
            }
            for (o, &v) in out_b.iter_mut().zip(m_b) {
                *o += c * v;
            }
            pending += 1;
        }
        for o in out_b.iter_mut() {
            *o = f.reduce(*o);
        }
        start = end;
    }
}

/// Inner product `Σ a[i]·b[i] (mod p)`, reduced once per budget-sized tile —
/// exactly the paper's "mod after the inner product" when the vector fits
/// the budget (d = 3072 < 4096 for p = 2^26 − 5).
pub fn dot(f: Field, a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    let budget = f.accum_budget();
    let mut acc = 0u64;
    for (ca, cb) in a.chunks(budget).zip(b.chunks(budget)) {
        let mut t = 0u64;
        for (&x, &y) in ca.iter().zip(cb) {
            t += x * y;
        }
        acc = f.reduce(f.reduce(t) + acc);
    }
    acc
}

/// `y = A·x` for row-major `A: (m × d)`, `x: (d)`.
pub fn matvec(f: Field, a: &[u64], shape: MatShape, x: &[u64]) -> Vec<u64> {
    assert_eq!(a.len(), shape.len());
    assert_eq!(x.len(), shape.cols);
    let mut y = Vec::with_capacity(shape.rows);
    for r in 0..shape.rows {
        let row = &a[r * shape.cols..(r + 1) * shape.cols];
        y.push(dot(f, row, x));
    }
    y
}

/// `y = Aᵀ·v` for row-major `A: (m × d)`, `v: (m)`, without materializing
/// the transpose: `y[j] += A[i][j]·v[i]`, reducing every budget rows.
pub fn matvec_t(f: Field, a: &[u64], shape: MatShape, v: &[u64]) -> Vec<u64> {
    assert_eq!(a.len(), shape.len());
    assert_eq!(v.len(), shape.rows);
    let budget = f.accum_budget();
    let mut y = vec![0u64; shape.cols];
    let mut pending = 0usize;
    for r in 0..shape.rows {
        let c = v[r];
        let row = &a[r * shape.cols..(r + 1) * shape.cols];
        if pending + 1 > budget {
            for o in y.iter_mut() {
                *o = f.reduce(*o);
            }
            pending = 0;
        }
        if c != 0 {
            for (o, &x) in y.iter_mut().zip(row) {
                *o += c * x;
            }
        }
        pending += 1;
    }
    for o in y.iter_mut() {
        *o = f.reduce(*o);
    }
    y
}

/// Dense `C = A·B` for `A: (m × k)`, `B: (k × n)` (used by tests and the
/// secure-matmul baselines; the COPML hot path only needs matvec).
pub fn matmul(f: Field, a: &[u64], sa: MatShape, b: &[u64], sb: MatShape) -> Vec<u64> {
    assert_eq!(sa.cols, sb.rows);
    assert_eq!(a.len(), sa.len());
    assert_eq!(b.len(), sb.len());
    let budget = f.accum_budget();
    let (m, kk, n) = (sa.rows, sa.cols, sb.cols);
    let mut c = vec![0u64; m * n];
    // ikj loop with per-row-of-B accumulation; reduce every `budget` k-steps.
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        let mut pending = 0usize;
        for k in 0..kk {
            let aik = a[i * kk + k];
            if pending + 1 > budget {
                for o in crow.iter_mut() {
                    *o = f.reduce(*o);
                }
                pending = 0;
            }
            if aik != 0 {
                let brow = &b[k * n..(k + 1) * n];
                for (o, &x) in crow.iter_mut().zip(brow) {
                    *o += aik * x;
                }
            }
            pending += 1;
        }
        for o in crow.iter_mut() {
            *o = f.reduce(*o);
        }
    }
    c
}

/// Element-wise polynomial evaluation `z[i] ← Σ_j coeffs[j]·z[i]^j (mod p)`
/// by Horner's rule — the polynomial sigmoid `ĝ` of Eq. (5).
///
/// An empty `coeffs` is the zero polynomial: `z` is zero-filled. (It used
/// to hit a bare `.unwrap()`; the fused kernel in `runtime::native` still
/// rejects an empty sigmoid with a named-culprit panic, because there a
/// zero ĝ silently trains nothing.)
pub fn poly_eval_assign(f: Field, coeffs: &[u64], z: &mut [u64]) {
    let Some((&last, head)) = coeffs.split_last() else {
        z.fill(0);
        return;
    };
    for v in z.iter_mut() {
        let x = *v;
        let mut acc = last;
        for &c in head.iter().rev() {
            acc = f.reduce(f.mul(acc, x) + c);
        }
        *v = acc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::P26;
    use crate::prng::Rng;

    fn rand_vec(r: &mut Rng, p: u64, n: usize) -> Vec<u64> {
        (0..n).map(|_| r.gen_range(p)).collect()
    }

    /// Naive i128 reference for all ops.
    fn dot_naive(p: u64, a: &[u64], b: &[u64]) -> u64 {
        let mut acc = 0u128;
        for (&x, &y) in a.iter().zip(b) {
            acc = (acc + x as u128 * y as u128) % p as u128;
        }
        acc as u64
    }

    #[test]
    fn dot_matches_naive_all_primes() {
        for p in [97u64, crate::field::P25, P26, crate::field::P31] {
            let f = Field::new(p);
            let mut r = Rng::seed_from_u64(1);
            for n in [0usize, 1, 7, 100, 5000] {
                let a = rand_vec(&mut r, p, n);
                let b = rand_vec(&mut r, p, n);
                assert_eq!(dot(f, &a, &b), dot_naive(p, &a, &b), "p={p} n={n}");
            }
        }
    }

    #[test]
    fn dot_worst_case_no_overflow() {
        // All entries p−1: maximal accumulation pressure.
        for p in [P26, crate::field::P31] {
            let f = Field::new(p);
            let a = vec![p - 1; 10_000];
            assert_eq!(dot(f, &a, &a), dot_naive(p, &a, &a));
        }
    }

    #[test]
    fn weighted_sum_matches_naive() {
        let f = Field::new(P26);
        let mut r = Rng::seed_from_u64(2);
        let n = 10_000;
        let k = 33; // K+T for N=50 Case 1-ish
        let mats: Vec<Vec<u64>> = (0..k).map(|_| rand_vec(&mut r, P26, n)).collect();
        let coeffs = rand_vec(&mut r, P26, k);
        let refs: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; n];
        weighted_sum(f, &coeffs, &refs, &mut out);
        for i in 0..n {
            let mut acc = 0u128;
            for j in 0..k {
                acc = (acc + coeffs[j] as u128 * mats[j][i] as u128) % P26 as u128;
            }
            assert_eq!(out[i], acc as u64, "i={i}");
        }
    }

    #[test]
    fn weighted_sum_tight_budget_prime() {
        // p = 2^31−1 has accum budget 4: forces mid-sum reductions.
        let p = crate::field::P31;
        let f = Field::new(p);
        let mut r = Rng::seed_from_u64(3);
        let n = 100;
        let k = 20;
        let mats: Vec<Vec<u64>> = (0..k).map(|_| rand_vec(&mut r, p, n)).collect();
        let coeffs = rand_vec(&mut r, p, k);
        let refs: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
        let mut out = vec![0u64; n];
        weighted_sum(f, &coeffs, &refs, &mut out);
        for i in 0..n {
            let mut acc = 0u128;
            for j in 0..k {
                acc = (acc + coeffs[j] as u128 * mats[j][i] as u128) % p as u128;
            }
            assert_eq!(out[i], acc as u64);
        }
    }

    #[test]
    fn matvec_and_transpose_match_naive() {
        let f = Field::new(P26);
        let mut r = Rng::seed_from_u64(4);
        let (m, d) = (57, 43);
        let a = rand_vec(&mut r, P26, m * d);
        let x = rand_vec(&mut r, P26, d);
        let v = rand_vec(&mut r, P26, m);
        let y = matvec(f, &a, MatShape::new(m, d), &x);
        for i in 0..m {
            assert_eq!(y[i], dot_naive(P26, &a[i * d..(i + 1) * d], &x));
        }
        let yt = matvec_t(f, &a, MatShape::new(m, d), &v);
        for j in 0..d {
            let col: Vec<u64> = (0..m).map(|i| a[i * d + j]).collect();
            assert_eq!(yt[j], dot_naive(P26, &col, &v), "col {j}");
        }
    }

    #[test]
    fn matvec_t_large_exceeds_budget() {
        // rows > accum budget for p=2^31−1 (budget 4) exercises mid-loop
        // reduction.
        let p = crate::field::P31;
        let f = Field::new(p);
        let mut r = Rng::seed_from_u64(5);
        let (m, d) = (100, 8);
        let a = rand_vec(&mut r, p, m * d);
        let v = rand_vec(&mut r, p, m);
        let yt = matvec_t(f, &a, MatShape::new(m, d), &v);
        for j in 0..d {
            let col: Vec<u64> = (0..m).map(|i| a[i * d + j]).collect();
            assert_eq!(yt[j], dot_naive(p, &col, &v));
        }
    }

    #[test]
    fn matmul_matches_naive() {
        let f = Field::new(P26);
        let mut r = Rng::seed_from_u64(6);
        let (m, k, n) = (13, 29, 7);
        let a = rand_vec(&mut r, P26, m * k);
        let b = rand_vec(&mut r, P26, k * n);
        let c = matmul(f, &a, MatShape::new(m, k), &b, MatShape::new(k, n));
        for i in 0..m {
            for j in 0..n {
                let arow = &a[i * k..(i + 1) * k];
                let bcol: Vec<u64> = (0..k).map(|t| b[t * n + j]).collect();
                assert_eq!(c[i * n + j], dot_naive(P26, arow, &bcol));
            }
        }
    }

    #[test]
    fn poly_eval_horner_matches_naive() {
        let f = Field::new(P26);
        let mut r = Rng::seed_from_u64(7);
        let coeffs = rand_vec(&mut r, P26, 4); // degree 3
        let mut z = rand_vec(&mut r, P26, 50);
        let z0 = z.clone();
        poly_eval_assign(f, &coeffs, &mut z);
        for (i, &x) in z0.iter().enumerate() {
            let mut acc = 0u128;
            let mut xp = 1u128;
            for &c in &coeffs {
                acc = (acc + c as u128 * xp) % P26 as u128;
                xp = xp * x as u128 % P26 as u128;
            }
            assert_eq!(z[i], acc as u64, "i={i}");
        }
    }

    #[test]
    fn poly_eval_length_boundaries() {
        let f = Field::new(P26);
        // Empty coefficient slice = zero polynomial (the old code hit a
        // bare unwrap here).
        let mut z = vec![3u64, 0, P26 - 1];
        poly_eval_assign(f, &[], &mut z);
        assert_eq!(z, vec![0, 0, 0]);
        // Degree 0: constant map regardless of input.
        let mut z = vec![3u64, 0, P26 - 1];
        poly_eval_assign(f, &[7], &mut z);
        assert_eq!(z, vec![7, 7, 7]);
        // Degree 1 over an empty input slice: no-op, no panic.
        let mut z: Vec<u64> = vec![];
        poly_eval_assign(f, &[1, 2], &mut z);
        assert!(z.is_empty());
    }

    #[test]
    fn raw_lane_helpers_match_scalar() {
        // axpy_raw_lanes / dot_raw_lanes vs the plain loops, across the
        // lane boundary and with saturated (p−1) entries within budget.
        let p = P26;
        let f = Field::new(p);
        let mut r = Rng::seed_from_u64(9);
        for n in [0usize, 1, LANES - 1, LANES, LANES + 1, 3 * LANES + 5, 1000] {
            let a = rand_vec(&mut r, p, n);
            let b = rand_vec(&mut r, p, n);
            let c = r.gen_range(p);
            let mut acc = vec![0u64; n];
            axpy_raw_lanes(&mut acc, c, &a);
            let want: Vec<u64> = a.iter().map(|&x| c * x).collect();
            assert_eq!(acc, want, "axpy n={n}");
            assert!(n <= f.accum_budget());
            let mut t = 0u64;
            for (&x, &y) in a.iter().zip(&b) {
                t += x * y;
            }
            assert_eq!(dot_raw_lanes(&a, &b), t, "dot n={n}");
        }
    }

    #[test]
    fn add_sub_scale_roundtrip() {
        let f = Field::new(P26);
        let mut r = Rng::seed_from_u64(8);
        let a0 = rand_vec(&mut r, P26, 256);
        let b = rand_vec(&mut r, P26, 256);
        let mut a = a0.clone();
        add_assign(f, &mut a, &b);
        sub_assign(f, &mut a, &b);
        assert_eq!(a, a0);
        let c = r.gen_range(P26 - 1) + 1;
        scale_assign(f, &mut a, c);
        scale_assign(f, &mut a, f.inv(c));
        assert_eq!(a, a0);
    }
}
