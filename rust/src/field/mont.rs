//! Batch-Montgomery kernel tier (`--kernel mont`) — the SIMD-shaped
//! alternative to the scalar Barrett kernels of [`super::vecops`].
//!
//! ## Why a second tier
//!
//! Barrett's [`super::Field::reduce`] ends in a `while r >= p` correction:
//! a data-dependent branch in the middle of every reduction, which is what
//! keeps the autovectorizer from turning the hot loops into SIMD code.
//! Montgomery REDC with `R = 2^64` is branchless (one conditional subtract,
//! expressible as straight-line arithmetic) and — more importantly for the
//! shapes COPML runs — lets whole matvec/weighted_sum/fused-gradient passes
//! run on *raw u64 accumulation* with exactly one REDC per accumulator
//! flush, the same budget discipline as Appendix A.
//!
//! ## The mixed-domain trick
//!
//! The classical recipe converts both operands into Montgomery form. That
//! would mean converting the large `X̃` matrix every pass — exactly the
//! transform cost the tier must amortize away. Instead every kernel here
//! keeps the matrix operand **plain** and converts only the small vector
//! operand (`w̃`, decode coefficients, `v`) once per pass:
//!
//! ```text
//! REDC(Σ_j x_j · w̄_j) = Σ_j x_j · w_j · R · R⁻¹ = Σ_j x_j · w_j  (mod p)
//! ```
//!
//! with `w̄ = w·R mod p` the Montgomery image. One product of a plain and a
//! Montgomery operand is `< (p−1)²` like any Barrett product, so the
//! [`super::Field::accum_budget`] bound carries over unchanged; and the
//! REDC of the raw sum lands directly back in the **plain canonical**
//! domain — which is why every kernel below is bit-identical to its
//! Barrett twin (both compute exact mod-`p` arithmetic on canonical
//! representatives; `tests/vecops_props.rs` pins the grid).
//!
//! **Domain-mixing hazard:** a mid-budget flush must NOT REDC in place and
//! keep accumulating — the flushed value is plain while incoming products
//! still carry the `R` factor. Every kernel keeps a separate canonical
//! *carry* accumulator: on flush, `carry += REDC(acc); acc = 0`.
//!
//! ## Lane blocking
//!
//! Inner loops are fixed [`LANES`]-wide indexed blocks (see
//! [`super::vecops::axpy_raw_lanes`] / [`super::vecops::dot_raw_lanes`]) —
//! no iterator chains, no per-element branch — the shape LLVM's
//! autovectorizer reliably turns into SIMD multiply-adds without any
//! `core::arch` unsafe.

use super::{vecops, Field, MatShape};

pub use super::vecops::LANES;

/// Which field-kernel tier the hot paths run on (`--kernel barrett|mont`).
///
/// Barrett is the default and the bit-identity oracle; Montgomery is the
/// lane-blocked fast tier. The choice is value-transparent: both tiers
/// produce canonical `[0, p)` representatives of the same exact mod-`p`
/// results, so every trainer's `w_trace` is bit-identical under either
/// (locked in by `tests/protocol_equivalence.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelTier {
    /// Scalar Barrett kernels ([`super::vecops`]) — default, oracle.
    #[default]
    Barrett,
    /// Batch-Montgomery lane-blocked kernels (this module).
    Mont,
}

impl std::str::FromStr for KernelTier {
    type Err = String;
    fn from_str(s: &str) -> Result<KernelTier, String> {
        match s {
            "barrett" => Ok(KernelTier::Barrett),
            "mont" => Ok(KernelTier::Mont),
            other => Err(format!("unknown kernel tier '{other}' (expected barrett|mont)")),
        }
    }
}

impl std::fmt::Display for KernelTier {
    fn fmt(&self, fm: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelTier::Barrett => write!(fm, "barrett"),
            KernelTier::Mont => write!(fm, "mont"),
        }
    }
}

/// Montgomery context for a [`Field`]: `R = 2^64`, precomputed
/// `n' = −p⁻¹ mod 2^64` and `r2 = R² mod p`.
///
/// Cheap to copy; pass by value (it embeds the [`Field`]).
#[derive(Clone, Copy, Debug)]
pub struct MontField {
    f: Field,
    p: u64,
    /// `−p⁻¹ mod 2^64` (Hensel-lifted).
    np: u64,
    /// `2^128 mod p` — the to-form multiplier.
    r2: u64,
}

impl MontField {
    pub fn new(f: Field) -> MontField {
        let p = f.modulus();
        // p⁻¹ mod 2^64 by Newton–Hensel lifting: odd p starts with 3
        // correct low bits (p·p ≡ 1 mod 8); each step doubles them, so 5
        // steps reach ≥ 96 ≥ 64 bits.
        let mut inv = p;
        for _ in 0..5 {
            inv = inv.wrapping_mul(2u64.wrapping_sub(p.wrapping_mul(inv)));
        }
        debug_assert_eq!(p.wrapping_mul(inv), 1);
        let r1 = f.reduce_u128(1u128 << 64); // 2^64 mod p
        MontField { f, p, np: inv.wrapping_neg(), r2: f.mul(r1, r1) }
    }

    #[inline(always)]
    pub fn field(&self) -> Field {
        self.f
    }

    /// Montgomery reduction: `REDC(t) = t·R⁻¹ mod p`, canonical `[0, p)`.
    /// Valid for any `t < R·p` — in particular any raw u64 accumulator
    /// (`t < 2^64 < R·p`) and any product of two canonical elements.
    #[inline(always)]
    pub fn redc(&self, t: u128) -> u64 {
        debug_assert!(t < (self.p as u128) << 64);
        let m = (t as u64).wrapping_mul(self.np);
        // t + m·p ≡ 0 mod R, and < R·p + R·p, so u < 2p: one subtract.
        let u = ((t + m as u128 * self.p as u128) >> 64) as u64;
        if u >= self.p {
            u - self.p
        } else {
            u
        }
    }

    /// Into Montgomery form: `x̄ = x·R mod p`.
    #[inline(always)]
    pub fn to_mont(&self, x: u64) -> u64 {
        debug_assert!(x < self.p);
        self.redc(x as u128 * self.r2 as u128)
    }

    /// Out of Montgomery form: `x̄·R⁻¹ = x mod p`.
    #[inline(always)]
    pub fn from_mont(&self, x: u64) -> u64 {
        self.redc(x as u128)
    }

    /// Batched to-form conversion — the one transform a kernel pass pays,
    /// amortized over the whole matvec/weighted-sum it feeds.
    pub fn to_mont_vec(&self, xs: &[u64]) -> Vec<u64> {
        xs.iter().map(|&x| self.to_mont(x)).collect()
    }

    /// Batched from-form conversion (the inverse of [`MontField::to_mont_vec`]).
    pub fn from_mont_vec(&self, xs: &[u64]) -> Vec<u64> {
        xs.iter().map(|&x| self.from_mont(x)).collect()
    }

    /// Inner product `Σ a[i]·b[i] mod p` with `b_mont` pre-converted
    /// ([`MontField::to_mont_vec`]): raw lane-blocked accumulation per
    /// budget tile, one REDC per tile, canonical plain result.
    pub fn dot_premont(&self, a: &[u64], b_mont: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b_mont.len());
        let f = self.f;
        let budget = f.accum_budget();
        let mut acc = 0u64; // canonical carry (plain domain)
        let mut start = 0;
        while start < a.len() {
            let end = (start + budget).min(a.len());
            let t = vecops::dot_raw_lanes(&a[start..end], &b_mont[start..end]);
            acc = f.add(acc, self.redc(t as u128));
            start = end;
        }
        acc
    }

    /// `y = A·x` with the `x` conversion paid once up front.
    pub fn matvec(&self, a: &[u64], shape: MatShape, x: &[u64]) -> Vec<u64> {
        self.matvec_premont(a, shape, &self.to_mont_vec(x))
    }

    /// [`MontField::matvec`] with `x_mont` pre-converted (row-block callers
    /// share one conversion across all workers).
    pub fn matvec_premont(&self, a: &[u64], shape: MatShape, x_mont: &[u64]) -> Vec<u64> {
        assert_eq!(a.len(), shape.len());
        assert_eq!(x_mont.len(), shape.cols);
        let mut y = Vec::with_capacity(shape.rows);
        for r in 0..shape.rows {
            y.push(self.dot_premont(&a[r * shape.cols..(r + 1) * shape.cols], x_mont));
        }
        y
    }

    /// `y = Aᵀ·v` with the `v` conversion paid once up front.
    pub fn matvec_t(&self, a: &[u64], shape: MatShape, v: &[u64]) -> Vec<u64> {
        self.matvec_t_premont(a, shape, &self.to_mont_vec(v))
    }

    /// [`MontField::matvec_t`] with `v_mont` pre-converted. Raw lane-blocked
    /// column accumulation; the budget flush goes through the separate
    /// canonical carry (see module docs on domain mixing).
    pub fn matvec_t_premont(&self, a: &[u64], shape: MatShape, v_mont: &[u64]) -> Vec<u64> {
        assert_eq!(a.len(), shape.len());
        assert_eq!(v_mont.len(), shape.rows);
        let f = self.f;
        let budget = f.accum_budget();
        let cols = shape.cols;
        let mut acc = vec![0u64; cols]; // raw (Montgomery-weighted) sums
        let mut out = vec![0u64; cols]; // canonical carry
        let mut pending = 0usize;
        for r in 0..shape.rows {
            if pending + 1 > budget {
                for j in 0..cols {
                    out[j] = f.add(out[j], self.redc(acc[j] as u128));
                    acc[j] = 0;
                }
                pending = 0;
            }
            let c = v_mont[r];
            if c != 0 {
                vecops::axpy_raw_lanes(&mut acc, c, &a[r * cols..(r + 1) * cols]);
            }
            pending += 1;
        }
        for j in 0..cols {
            out[j] = f.add(out[j], self.redc(acc[j] as u128));
        }
        out
    }

    /// `out ← Σ_k coeffs[k]·mats[k] mod p` with the coefficient conversion
    /// paid once ([`MontField::weighted_sum_premont`] for pre-converted
    /// coefficients).
    pub fn weighted_sum(&self, coeffs: &[u64], mats: &[&[u64]], out: &mut [u64]) {
        self.weighted_sum_premont(&self.to_mont_vec(coeffs), mats, out);
    }

    /// [`MontField::weighted_sum`] with `coeffs_mont` pre-converted.
    /// Element-blocked like [`vecops::weighted_sum`]; `out` doubles as the
    /// raw accumulator, a scratch carry holds the canonical flushes.
    pub fn weighted_sum_premont(&self, coeffs_mont: &[u64], mats: &[&[u64]], out: &mut [u64]) {
        assert_eq!(coeffs_mont.len(), mats.len());
        let n = out.len();
        for m in mats {
            assert_eq!(m.len(), n, "matrix size mismatch in weighted_sum");
        }
        let f = self.f;
        let budget = f.accum_budget();
        const BLOCK: usize = 4096;
        out.fill(0);
        let mut carry = vec![0u64; BLOCK.min(n)];
        let mut start = 0;
        while start < n {
            let end = (start + BLOCK).min(n);
            let out_b = &mut out[start..end];
            let carry_b = &mut carry[..end - start];
            carry_b.fill(0);
            let mut pending = 0usize;
            for (k, m) in mats.iter().enumerate() {
                let c = coeffs_mont[k]; // c̄ = 0 ⟺ c = 0: skip path intact
                if c == 0 {
                    continue;
                }
                if pending + 1 > budget {
                    for (o, cb) in out_b.iter_mut().zip(carry_b.iter_mut()) {
                        *cb = f.add(*cb, self.redc(*o as u128));
                        *o = 0;
                    }
                    pending = 0;
                }
                vecops::axpy_raw_lanes(out_b, c, &m[start..end]);
                pending += 1;
            }
            for (o, &cb) in out_b.iter_mut().zip(carry_b.iter()) {
                *o = f.add(cb, self.redc(*o as u128));
            }
            start = end;
        }
    }

    /// One Horner evaluation `ĝ(z)` in the mixed domain: `z` is converted
    /// once, the accumulator and coefficients stay plain, so every step is
    /// a single REDC (`REDC(acc·z̄) = acc·z`) against the Barrett path's
    /// two reductions. `coeffs` must be non-empty (callers own the
    /// named-culprit message).
    #[inline]
    pub fn poly_eval_one(&self, coeffs: &[u64], z: u64) -> u64 {
        debug_assert!(!coeffs.is_empty());
        let f = self.f;
        let zm = self.to_mont(z);
        let mut acc = coeffs[coeffs.len() - 1];
        for idx in (0..coeffs.len() - 1).rev() {
            acc = f.add(self.redc(acc as u128 * zm as u128), coeffs[idx]);
        }
        acc
    }

    /// Element-wise polynomial evaluation by mixed-domain Horner. The
    /// empty-coefficient case is the zero polynomial (`z` is zero-filled),
    /// matching [`vecops::poly_eval_assign`].
    pub fn poly_eval_assign(&self, coeffs: &[u64], z: &mut [u64]) {
        if coeffs.is_empty() {
            z.fill(0);
            return;
        }
        for v in z.iter_mut() {
            *v = self.poly_eval_one(coeffs, *v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{P25, P26, P31};
    use crate::prng::Rng;

    const PRIMES: [u64; 4] = [97, P25, P26, P31];

    #[test]
    fn redc_and_form_round_trips() {
        for p in PRIMES {
            let mf = MontField::new(Field::new(p));
            let mut r = Rng::seed_from_u64(1);
            for x in [0, 1, 2, p - 2, p - 1] {
                assert_eq!(mf.from_mont(mf.to_mont(x)), x, "p={p} x={x}");
            }
            for _ in 0..2000 {
                let x = r.gen_range(p);
                assert_eq!(mf.from_mont(mf.to_mont(x)), x, "p={p} x={x}");
            }
        }
    }

    #[test]
    fn redc_matches_definition() {
        // REDC(t) = t·R⁻¹ mod p for raw u64 sums and full products.
        for p in PRIMES {
            let f = Field::new(p);
            let mf = MontField::new(f);
            let rinv = f.inv(f.reduce_u128(1u128 << 64));
            let mut r = Rng::seed_from_u64(2);
            for _ in 0..2000 {
                let t = r.next_u64() as u128 % ((p as u128) << 33);
                let want = f.mul(f.reduce_u128(t), rinv);
                assert_eq!(mf.redc(t), want, "p={p} t={t}");
            }
        }
    }

    #[test]
    fn mixed_domain_product_is_plain() {
        // REDC(a · b̄) = a·b mod p — the invariant every kernel rests on.
        for p in PRIMES {
            let f = Field::new(p);
            let mf = MontField::new(f);
            let mut r = Rng::seed_from_u64(3);
            for _ in 0..2000 {
                let a = r.gen_range(p);
                let b = r.gen_range(p);
                assert_eq!(
                    mf.redc(a as u128 * mf.to_mont(b) as u128),
                    f.mul(a, b),
                    "p={p} a={a} b={b}"
                );
            }
        }
    }

    #[test]
    fn dot_matches_barrett_saturated() {
        // All-(p−1) vectors across the budget boundary: maximal raw-
        // accumulator pressure for the tight-budget prime (budget 4).
        for p in [P26, P31] {
            let f = Field::new(p);
            let mf = MontField::new(f);
            let b = f.accum_budget().min(8192);
            for n in [0usize, 1, LANES - 1, LANES, LANES + 1, b, b + 1, 3 * b + 2] {
                let a = vec![p - 1; n];
                assert_eq!(
                    mf.dot_premont(&a, &mf.to_mont_vec(&a)),
                    vecops::dot(f, &a, &a),
                    "p={p} n={n}"
                );
            }
        }
    }

    #[test]
    fn poly_eval_matches_barrett() {
        let f = Field::new(P26);
        let mf = MontField::new(f);
        let mut r = Rng::seed_from_u64(4);
        for deg in [0usize, 1, 3, 7] {
            let coeffs: Vec<u64> = (0..=deg).map(|_| r.gen_range(P26)).collect();
            let z0: Vec<u64> = (0..100).map(|_| r.gen_range(P26)).collect();
            let mut a = z0.clone();
            let mut b = z0.clone();
            vecops::poly_eval_assign(f, &coeffs, &mut a);
            mf.poly_eval_assign(&coeffs, &mut b);
            assert_eq!(a, b, "deg={deg}");
        }
        // Zero polynomial: both tiers define it as the zero map.
        let mut z = vec![5u64, 7, 9];
        mf.poly_eval_assign(&[], &mut z);
        assert_eq!(z, vec![0, 0, 0]);
    }

    #[test]
    fn kernel_tier_parses_and_displays() {
        assert_eq!("barrett".parse::<KernelTier>().unwrap(), KernelTier::Barrett);
        assert_eq!("mont".parse::<KernelTier>().unwrap(), KernelTier::Mont);
        assert!("montgomery".parse::<KernelTier>().is_err());
        assert_eq!(KernelTier::default(), KernelTier::Barrett);
        assert_eq!(KernelTier::Mont.to_string(), "mont");
        assert_eq!(KernelTier::Barrett.to_string(), "barrett");
    }
}
