//! Prime-field arithmetic `F_p` — the substrate every protocol layer runs on.
//!
//! COPML quantizes all data into a prime field (paper §III Phase 1 /
//! Appendix A). The paper's reference prime for CIFAR-10-scale data is
//! `p = 2^26 − 5`, chosen so a full inner product over `d = 3072` columns can
//! be accumulated in u64 **with a single modular reduction at the end**
//! (`d·(p−1)² ≤ 2^64 − 1`). This module generalizes that trick: every
//! accumulating operation reduces once per [`Field::accum_budget`] terms, so
//! the same code is correct for headroom primes like `2^31 − 1` (where only
//! 4 products fit) and fast for the paper-parity prime (4096 products fit).
//!
//! Negative values use the two's-complement-style embedding of Appendix A:
//! `φ(x) = x` for `x ≥ 0` and `p + x` for `x < 0` ([`Field::from_i64`] /
//! [`Field::to_i64`]).
//!
//! Reduction is Barrett (`μ = ⌊2^64/p⌋`): a runtime-`p` `%` compiles to a
//! hardware divide (~25 cycles); Barrett is two multiplies and a correction.

pub mod mont;
pub mod par;
mod primes;
pub mod vecops;

pub use mont::{KernelTier, MontField};
pub use par::Parallelism;
pub use primes::{is_prime_u64, prev_prime, P25, P26, P31};
pub use vecops::MatShape;

/// Context for arithmetic modulo a prime `p < 2^31`.
///
/// Cheap to copy; pass by value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Field {
    /// The prime modulus.
    p: u64,
    /// Barrett constant `⌊2^64 / p⌋`.
    mu: u64,
    /// `⌊p / 2⌋` — threshold of the signed embedding.
    half: u64,
    /// How many products `(p−1)²` fit in a u64 accumulator on top of a
    /// reduced value `< p`.
    accum_budget: usize,
}

impl Field {
    /// Create a field context. Panics if `p` is not an odd prime `< 2^31`
    /// (products of two reduced elements must fit in u64).
    pub fn new(p: u64) -> Field {
        assert!(p > 2 && p < (1 << 31), "modulus must be in (2, 2^31)");
        assert!(is_prime_u64(p), "modulus {p} is not prime");
        let mu = ((1u128 << 64) / p as u128) as u64; // ⌊2^64 / p⌋
        let sq = (p - 1) as u128 * (p - 1) as u128;
        let budget = ((u64::MAX as u128 - (p - 1) as u128) / sq) as usize;
        Field {
            p,
            mu,
            half: p / 2,
            accum_budget: budget.max(1),
        }
    }

    /// Paper-parity field for CIFAR-10-like data: `p = 2^26 − 5`.
    pub fn paper_cifar() -> Field {
        Field::new(P26)
    }

    /// Field satisfying `d·(p−1)² ≤ 2^64` for GISETTE-like `d = 5000`:
    /// `p = 2^25 − 39`.
    pub fn paper_gisette() -> Field {
        Field::new(P25)
    }

    #[inline(always)]
    pub fn modulus(&self) -> u64 {
        self.p
    }

    /// Number of `(p−1)²` products that can be accumulated in u64 between
    /// reductions.
    #[inline(always)]
    pub fn accum_budget(&self) -> usize {
        self.accum_budget
    }

    /// Barrett-reduce any u64 to `[0, p)`.
    #[inline(always)]
    pub fn reduce(&self, x: u64) -> u64 {
        // q = floor(x * mu / 2^64) ≈ floor(x / p), off by at most 2.
        let q = ((x as u128 * self.mu as u128) >> 64) as u64;
        let mut r = x.wrapping_sub(q.wrapping_mul(self.p));
        while r >= self.p {
            r -= self.p;
        }
        r
    }

    /// Reduce a u128 (e.g. a `(p−1)²`-scale product chain accumulated past
    /// the u64 budget) to `[0, p)` — **two-stage Barrett**, honoring the
    /// module's no-hardware-divide contract:
    ///
    /// 1. fold the high word: `x = hi·2^64 + lo ≡ (hi mod p)·(2^64 mod p)
    ///    + (lo mod p)`, with both per-word reductions Barrett
    ///    ([`Field::reduce`]) and `2^64 mod p` recovered from the Barrett
    ///    constant for free (`2^64 − μ·p`, exact in wrapping arithmetic);
    /// 2. one more Barrett reduction of the folded product (inside
    ///    [`Field::mul`]) plus a modular add.
    ///
    /// A u128 `%` on a runtime modulus would lower to a `__umodti3` call
    /// (~100 cycles); this is four multiplies and change.
    #[inline(always)]
    pub fn reduce_u128(&self, x: u128) -> u64 {
        let hi = (x >> 64) as u64;
        let lo = x as u64;
        if hi == 0 {
            return self.reduce(lo);
        }
        // 2^64 mod p = 2^64 − μ·p: μ·p ∈ (2^64 − p, 2^64) for any non-power-
        // of-two p, so the wrapping negation is exactly the residue.
        let r64 = 0u64.wrapping_sub(self.mu.wrapping_mul(self.p));
        self.add(self.mul(self.reduce(hi), r64), self.reduce(lo))
    }

    #[inline(always)]
    pub fn add(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        let s = a + b;
        if s >= self.p {
            s - self.p
        } else {
            s
        }
    }

    #[inline(always)]
    pub fn sub(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        if a >= b {
            a - b
        } else {
            a + self.p - b
        }
    }

    #[inline(always)]
    pub fn neg(&self, a: u64) -> u64 {
        debug_assert!(a < self.p);
        if a == 0 {
            0
        } else {
            self.p - a
        }
    }

    #[inline(always)]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.p && b < self.p);
        // p < 2^31 so the product fits in u64.
        self.reduce(a * b)
    }

    /// Modular exponentiation (square-and-multiply).
    pub fn pow(&self, mut base: u64, mut exp: u64) -> u64 {
        base = self.reduce(base);
        let mut acc = 1u64;
        while exp > 0 {
            if exp & 1 == 1 {
                acc = self.mul(acc, base);
            }
            base = self.mul(base, base);
            exp >>= 1;
        }
        acc
    }

    /// Multiplicative inverse by Fermat's little theorem (`p` prime).
    /// Panics on zero.
    #[inline]
    pub fn inv(&self, a: u64) -> u64 {
        assert!(a % self.p != 0, "inverse of zero");
        self.pow(a, self.p - 2)
    }

    /// Signed embedding `φ` of Appendix A: map `x ∈ [−p/2, p/2]` into the
    /// field.
    #[inline(always)]
    pub fn from_i64(&self, x: i64) -> u64 {
        let m = x.rem_euclid(self.p as i64);
        m as u64
    }

    /// Inverse of the signed embedding: field element → signed integer in
    /// `(−p/2, p/2]`.
    #[inline(always)]
    pub fn to_i64(&self, v: u64) -> i64 {
        debug_assert!(v < self.p);
        if v > self.half {
            v as i64 - self.p as i64
        } else {
            v as i64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    #[test]
    fn basic_ops_small_prime() {
        let f = Field::new(97);
        assert_eq!(f.add(90, 10), 3);
        assert_eq!(f.sub(3, 10), 90);
        assert_eq!(f.mul(96, 96), 1); // (-1)^2
        assert_eq!(f.neg(0), 0);
        assert_eq!(f.neg(1), 96);
    }

    #[test]
    fn reduce_matches_modulo_exhaustive_random() {
        let f = Field::paper_cifar();
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.next_u64();
            assert_eq!(f.reduce(x), x % P26);
        }
        // boundary values
        for x in [0, 1, P26 - 1, P26, P26 + 1, u64::MAX, u64::MAX - 1] {
            assert_eq!(f.reduce(x), x % P26);
        }
    }

    #[test]
    fn reduce_u128_matches_modulo_boundaries_and_random() {
        // Exhaustive boundary sweep: multiples of p (±1) at every scale a
        // u128 can hold, (p−1)²-scale products and their d-accumulated
        // sums, word boundaries, and u128 extremes — plus random probes.
        for p in [97u64, P25, P26, P31] {
            let f = Field::new(p);
            let pp = p as u128;
            let sq = (pp - 1) * (pp - 1);
            let mut xs: Vec<u128> = vec![
                0,
                1,
                pp - 1,
                pp,
                pp + 1,
                u64::MAX as u128,
                (u64::MAX as u128) + 1,
                u128::MAX - 1,
                u128::MAX,
                sq - 1,
                sq,
                sq + 1,
                sq * 2,
                sq * 3073, // the paper's d-term accumulation scale
                sq * 5000,
            ];
            for k in [1u128, 2, 1 << 20, 1 << 40, (1u128 << 64) / pp, u128::MAX / pp] {
                let base = pp * k;
                xs.push(base - 1);
                xs.push(base);
                if let Some(v) = base.checked_add(1) {
                    xs.push(v);
                }
            }
            for x in xs {
                assert_eq!(f.reduce_u128(x), (x % pp) as u64, "p={p} x={x}");
            }
            let mut r = Rng::seed_from_u64(17);
            for _ in 0..5000 {
                let x = ((r.next_u64() as u128) << 64) | r.next_u64() as u128;
                assert_eq!(f.reduce_u128(x), (x % pp) as u64, "p={p} x={x}");
            }
        }
    }

    #[test]
    fn inverse_round_trips() {
        for p in [97, P25, P26, P31] {
            let f = Field::new(p);
            let mut r = Rng::seed_from_u64(2);
            for _ in 0..200 {
                let a = r.gen_range(p - 1) + 1;
                let ai = f.inv(a);
                assert_eq!(f.mul(a, ai), 1, "p={p} a={a}");
            }
        }
    }

    #[test]
    fn pow_matches_naive() {
        let f = Field::new(101);
        for base in 1..20u64 {
            let mut acc = 1u64;
            for e in 0..12u64 {
                assert_eq!(f.pow(base, e), acc);
                acc = f.mul(acc, base);
            }
        }
    }

    #[test]
    fn signed_embedding_round_trips() {
        let f = Field::paper_cifar();
        for x in [-5i64, -1, 0, 1, 5, -(P26 as i64) / 2 + 1, (P26 as i64) / 2] {
            assert_eq!(f.to_i64(f.from_i64(x)), x, "x={x}");
        }
    }

    #[test]
    fn signed_arithmetic_consistent() {
        // φ(a)·φ(b) = φ(a·b) as long as |a·b| < p/2.
        let f = Field::paper_cifar();
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..1000 {
            let a = r.gen_range(4096) as i64 - 2048;
            let b = r.gen_range(4096) as i64 - 2048;
            let v = f.mul(f.from_i64(a), f.from_i64(b));
            assert_eq!(f.to_i64(v), a * b);
        }
    }

    #[test]
    fn accum_budget_paper_prime() {
        let f = Field::paper_cifar();
        // Paper: d(p−1)² ≤ 2^64 − 1 must hold for d = 3072 (it does; in
        // fact ~4096 terms fit).
        assert!(f.accum_budget() >= 3073, "budget={}", f.accum_budget());
        let g = Field::paper_gisette();
        assert!(g.accum_budget() >= 5000, "budget={}", g.accum_budget());
    }

    #[test]
    #[should_panic(expected = "not prime")]
    fn rejects_composite() {
        Field::new(1 << 20);
    }
}
