//! Scoped-thread execution layer for the `F_p` hot paths — COPML's
//! *parallel* half of the scalability claim.
//!
//! The paper's pitch over conventional MPC is that the per-client load
//! shrinks with `K` **and** that each client's remaining work is dense
//! linear algebra that parallelizes trivially. This module row/column-blocks
//! the three dominant kernels — [`weighted_sum`] (Lagrange encode/decode),
//! [`matvec`] and [`matvec_t`] (the native encoded-gradient path) — across
//! a [`Parallelism`]-sized scoped thread pool (`std::thread::scope`; the
//! offline image has no `rayon`).
//!
//! **Exactness.** Every worker runs the *same* sequential kernel from
//! [`super::vecops`] on its block, so the Appendix-A accumulation-budget
//! discipline (one Barrett reduction per [`super::Field::accum_budget`]
//! accumulated products) holds per block; partial outputs are combined with
//! exact mod-`p` addition, which is associative and commutative. Results
//! are therefore **bit-identical** to the sequential kernels for every
//! thread count — asserted by the tests below and by
//! `coordinator::algo::tests::parallelism_does_not_change_trajectory`.

use super::{vecops, Field, KernelTier, MatShape, MontField};

/// Minimum number of output elements (or matrix cells) a worker must have
/// before spawning a thread is worth the ~10 µs overhead.
pub const MIN_PAR_WORK: usize = 1 << 13;

/// Degree of intra-client parallelism for the field hot paths.
///
/// Threaded from [`crate::coordinator::CopmlConfig`] through the trainers
/// so per-client Lagrange encode/decode and the encoded-gradient kernel
/// fan out across cores. The default is sequential: the full-fidelity
/// protocol already runs `N` client threads, and tests stay deterministic
/// in thread count (results are identical either way — see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    threads: usize,
}

impl Parallelism {
    /// Single-threaded execution (the default).
    pub fn sequential() -> Parallelism {
        Parallelism { threads: 1 }
    }

    /// Use up to `n` worker threads (clamped to ≥ 1).
    pub fn threads(n: usize) -> Parallelism {
        Parallelism { threads: n.max(1) }
    }

    /// Use every available core (`std::thread::available_parallelism`).
    pub fn auto() -> Parallelism {
        Parallelism::threads(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    }

    /// Configured thread cap.
    pub fn thread_count(&self) -> usize {
        self.threads
    }

    pub fn is_sequential(&self) -> bool {
        self.threads == 1
    }

    /// Worker count for a workload of `work` units with a caller-chosen
    /// minimum chunk: never more threads than keeps each worker above
    /// `min_chunk` units. Shared by this module and the fused kernel in
    /// `runtime::native` so the fan-out policy has one implementation.
    pub(crate) fn workers_for(&self, work: usize, min_chunk: usize) -> usize {
        if self.threads <= 1 || work < 2 * min_chunk {
            1
        } else {
            self.threads.min(work / min_chunk).max(1)
        }
    }

    /// Worker count for a workload of `work` elements: never more threads
    /// than keeps each worker above [`MIN_PAR_WORK`] elements.
    fn workers(&self, work: usize) -> usize {
        self.workers_for(work, MIN_PAR_WORK)
    }
}

impl Default for Parallelism {
    fn default() -> Parallelism {
        Parallelism::sequential()
    }
}

/// Parallel `out ← Σ_k coeffs[k] · mats[k] (mod p)`: the output (and every
/// input matrix) is split into contiguous element blocks, one sequential
/// [`vecops::weighted_sum`] per worker. Bit-identical to the sequential
/// call.
pub fn weighted_sum(f: Field, par: Parallelism, coeffs: &[u64], mats: &[&[u64]], out: &mut [u64]) {
    let workers = par.workers(out.len());
    if workers <= 1 {
        vecops::weighted_sum(f, coeffs, mats, out);
        return;
    }
    assert_eq!(coeffs.len(), mats.len());
    for m in mats {
        assert_eq!(m.len(), out.len(), "matrix size mismatch in weighted_sum");
    }
    let chunk = out.len().div_ceil(workers);
    std::thread::scope(|s| {
        for (ci, out_b) in out.chunks_mut(chunk).enumerate() {
            let lo = ci * chunk;
            let hi = lo + out_b.len();
            s.spawn(move || {
                let sub: Vec<&[u64]> = mats.iter().map(|m| &m[lo..hi]).collect();
                vecops::weighted_sum(f, coeffs, &sub, out_b);
            });
        }
    });
}

/// Parallel `y = A·x`: rows are split into contiguous blocks, one
/// sequential [`vecops::matvec`] per worker writing its own slice of `y`.
pub fn matvec(f: Field, par: Parallelism, a: &[u64], shape: MatShape, x: &[u64]) -> Vec<u64> {
    assert_eq!(a.len(), shape.len());
    assert_eq!(x.len(), shape.cols);
    let workers = par.workers(shape.len());
    if workers <= 1 || shape.rows == 0 || shape.cols == 0 {
        return vecops::matvec(f, a, shape, x);
    }
    let rows_chunk = shape.rows.div_ceil(workers);
    let mut y = vec![0u64; shape.rows];
    std::thread::scope(|s| {
        for (y_b, a_b) in y.chunks_mut(rows_chunk).zip(a.chunks(rows_chunk * shape.cols)) {
            s.spawn(move || {
                let block = vecops::matvec(f, a_b, MatShape::new(y_b.len(), shape.cols), x);
                y_b.copy_from_slice(&block);
            });
        }
    });
    y
}

/// Row-blocked map-reduce over a row-major `(rows × cols)` matrix: split
/// into contiguous row blocks (one per worker), run `block` on each —
/// `block(row_block, first_row)` must return a fully reduced
/// `cols`-vector — and combine the partials with exact mod-`p` addition.
/// The single implementation of the scatter/gather scaffolding shared by
/// [`matvec_t`] and the fused kernel in `runtime::native`.
///
/// Caller guarantees `workers ≥ 2`, `cols > 0`, `a.len() == rows·cols`.
pub(crate) fn row_block_reduce<F>(
    f: Field,
    a: &[u64],
    rows: usize,
    cols: usize,
    workers: usize,
    block: F,
) -> Vec<u64>
where
    F: Fn(&[u64], usize) -> Vec<u64> + Sync,
{
    debug_assert!(workers >= 2 && cols > 0 && a.len() == rows * cols);
    let rows_chunk = rows.div_ceil(workers);
    let partials: Vec<Vec<u64>> = std::thread::scope(|s| {
        let handles: Vec<_> = a
            .chunks(rows_chunk * cols)
            .enumerate()
            .map(|(ci, a_b)| {
                let block = &block;
                s.spawn(move || block(a_b, ci * rows_chunk))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("row-block worker panicked")).collect()
    });
    let mut y = vec![0u64; cols];
    for p_b in &partials {
        vecops::add_assign(f, &mut y, p_b);
    }
    y
}

/// Parallel `y = Aᵀ·v`: rows are split into blocks; each worker runs the
/// sequential [`vecops::matvec_t`] over its block (budget discipline
/// intact), producing a reduced partial `d`-vector; partials are combined
/// with exact mod-`p` addition.
pub fn matvec_t(f: Field, par: Parallelism, a: &[u64], shape: MatShape, v: &[u64]) -> Vec<u64> {
    assert_eq!(a.len(), shape.len());
    assert_eq!(v.len(), shape.rows);
    let workers = par.workers(shape.len());
    if workers <= 1 || shape.rows == 0 || shape.cols == 0 {
        return vecops::matvec_t(f, a, shape, v);
    }
    row_block_reduce(f, a, shape.rows, shape.cols, workers, |a_b, r0| {
        let rows_b = a_b.len() / shape.cols;
        vecops::matvec_t(f, a_b, MatShape::new(rows_b, shape.cols), &v[r0..r0 + rows_b])
    })
}

/// Parallel element-wise polynomial evaluation (the sigmoid `ĝ` applied to
/// `z = X·w`): embarrassingly parallel over elements.
pub fn poly_eval_assign(f: Field, par: Parallelism, coeffs: &[u64], z: &mut [u64]) {
    let workers = par.workers(z.len());
    if workers <= 1 {
        vecops::poly_eval_assign(f, coeffs, z);
        return;
    }
    let chunk = z.len().div_ceil(workers);
    std::thread::scope(|s| {
        for z_b in z.chunks_mut(chunk) {
            s.spawn(move || vecops::poly_eval_assign(f, coeffs, z_b));
        }
    });
}

// ---------------------------------------------------------------------
// Kernel-tier dispatch (`--kernel barrett|mont`).
//
// Each `_tier` entry point is the single place a trainer hot path decides
// which kernel substrate runs. The Barrett arm is exactly the existing
// function above; the Montgomery arm pays the batched to-form conversion
// of the SMALL operand once, then reuses the same chunking/row-block
// scaffolding with the lane-blocked `mont` kernels — so the per-worker
// blocks see pre-converted operands and the transform cost is amortized
// across the whole pass regardless of thread count. Both arms produce
// canonical `[0, p)` results of the same exact mod-p computation, hence
// bit-identical outputs (pinned by `tests/vecops_props.rs`).
// ---------------------------------------------------------------------

/// Tier-dispatched [`weighted_sum`].
pub fn weighted_sum_tier(
    f: Field,
    tier: KernelTier,
    par: Parallelism,
    coeffs: &[u64],
    mats: &[&[u64]],
    out: &mut [u64],
) {
    match tier {
        KernelTier::Barrett => weighted_sum(f, par, coeffs, mats, out),
        KernelTier::Mont => {
            let mf = MontField::new(f);
            let cm = mf.to_mont_vec(coeffs); // one conversion, all workers
            let workers = par.workers(out.len());
            if workers <= 1 {
                mf.weighted_sum_premont(&cm, mats, out);
                return;
            }
            assert_eq!(coeffs.len(), mats.len());
            for m in mats {
                assert_eq!(m.len(), out.len(), "matrix size mismatch in weighted_sum");
            }
            let chunk = out.len().div_ceil(workers);
            let cm = cm.as_slice();
            std::thread::scope(|s| {
                for (ci, out_b) in out.chunks_mut(chunk).enumerate() {
                    let lo = ci * chunk;
                    let hi = lo + out_b.len();
                    s.spawn(move || {
                        let sub: Vec<&[u64]> = mats.iter().map(|m| &m[lo..hi]).collect();
                        mf.weighted_sum_premont(cm, &sub, out_b);
                    });
                }
            });
        }
    }
}

/// Tier-dispatched [`matvec`].
pub fn matvec_tier(
    f: Field,
    tier: KernelTier,
    par: Parallelism,
    a: &[u64],
    shape: MatShape,
    x: &[u64],
) -> Vec<u64> {
    match tier {
        KernelTier::Barrett => matvec(f, par, a, shape, x),
        KernelTier::Mont => {
            assert_eq!(a.len(), shape.len());
            assert_eq!(x.len(), shape.cols);
            let mf = MontField::new(f);
            let xm = mf.to_mont_vec(x);
            let workers = par.workers(shape.len());
            if workers <= 1 || shape.rows == 0 || shape.cols == 0 {
                return mf.matvec_premont(a, shape, &xm);
            }
            let rows_chunk = shape.rows.div_ceil(workers);
            let mut y = vec![0u64; shape.rows];
            let xm = xm.as_slice();
            std::thread::scope(|s| {
                for (y_b, a_b) in
                    y.chunks_mut(rows_chunk).zip(a.chunks(rows_chunk * shape.cols))
                {
                    s.spawn(move || {
                        let block =
                            mf.matvec_premont(a_b, MatShape::new(y_b.len(), shape.cols), xm);
                        y_b.copy_from_slice(&block);
                    });
                }
            });
            y
        }
    }
}

/// Tier-dispatched [`matvec_t`].
pub fn matvec_t_tier(
    f: Field,
    tier: KernelTier,
    par: Parallelism,
    a: &[u64],
    shape: MatShape,
    v: &[u64],
) -> Vec<u64> {
    match tier {
        KernelTier::Barrett => matvec_t(f, par, a, shape, v),
        KernelTier::Mont => {
            assert_eq!(a.len(), shape.len());
            assert_eq!(v.len(), shape.rows);
            let mf = MontField::new(f);
            let vm = mf.to_mont_vec(v);
            let workers = par.workers(shape.len());
            if workers <= 1 || shape.rows == 0 || shape.cols == 0 {
                return mf.matvec_t_premont(a, shape, &vm);
            }
            let vm = vm.as_slice();
            row_block_reduce(f, a, shape.rows, shape.cols, workers, |a_b, r0| {
                let rows_b = a_b.len() / shape.cols;
                mf.matvec_t_premont(a_b, MatShape::new(rows_b, shape.cols), &vm[r0..r0 + rows_b])
            })
        }
    }
}

/// Tier-dispatched [`poly_eval_assign`].
pub fn poly_eval_assign_tier(
    f: Field,
    tier: KernelTier,
    par: Parallelism,
    coeffs: &[u64],
    z: &mut [u64],
) {
    match tier {
        KernelTier::Barrett => poly_eval_assign(f, par, coeffs, z),
        KernelTier::Mont => {
            let mf = MontField::new(f);
            let workers = par.workers(z.len());
            if workers <= 1 {
                mf.poly_eval_assign(coeffs, z);
                return;
            }
            let chunk = z.len().div_ceil(workers);
            std::thread::scope(|s| {
                for z_b in z.chunks_mut(chunk) {
                    s.spawn(move || mf.poly_eval_assign(coeffs, z_b));
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{P26, P31};
    use crate::prng::Rng;

    fn rand_vec(r: &mut Rng, p: u64, n: usize) -> Vec<u64> {
        (0..n).map(|_| r.gen_range(p)).collect()
    }

    #[test]
    fn parallelism_constructors() {
        assert_eq!(Parallelism::sequential().thread_count(), 1);
        assert!(Parallelism::sequential().is_sequential());
        assert_eq!(Parallelism::threads(0).thread_count(), 1);
        assert_eq!(Parallelism::threads(6).thread_count(), 6);
        assert!(Parallelism::auto().thread_count() >= 1);
        assert_eq!(Parallelism::default(), Parallelism::sequential());
    }

    #[test]
    fn small_workloads_stay_sequential() {
        let par = Parallelism::threads(8);
        assert_eq!(par.workers(100), 1);
        assert_eq!(par.workers(2 * MIN_PAR_WORK - 1), 1);
        assert!(par.workers(16 * MIN_PAR_WORK) > 1);
    }

    #[test]
    fn weighted_sum_bit_identical_across_thread_counts() {
        // Sizes straddle the chunking boundaries; P31 forces mid-sum
        // reductions (accum budget 4).
        for p in [P26, P31] {
            let f = Field::new(p);
            let mut r = Rng::seed_from_u64(1);
            for n in [1usize, 1000, 2 * MIN_PAR_WORK, 2 * MIN_PAR_WORK + 17, 100_000] {
                let k = 9;
                let mats: Vec<Vec<u64>> = (0..k).map(|_| rand_vec(&mut r, p, n)).collect();
                let coeffs = rand_vec(&mut r, p, k);
                let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
                let mut seq = vec![0u64; n];
                vecops::weighted_sum(f, &coeffs, &views, &mut seq);
                for threads in [1usize, 2, 3, 4, 7] {
                    let mut out = vec![0u64; n];
                    weighted_sum(f, Parallelism::threads(threads), &coeffs, &views, &mut out);
                    assert_eq!(out, seq, "p={p} n={n} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn matvec_bit_identical_across_thread_counts() {
        for p in [P26, P31] {
            let f = Field::new(p);
            let mut r = Rng::seed_from_u64(2);
            for (rows, cols) in [(1usize, 64usize), (300, 77), (1024, 64), (57, 1)] {
                let a = rand_vec(&mut r, p, rows * cols);
                let x = rand_vec(&mut r, p, cols);
                let shape = MatShape::new(rows, cols);
                let seq = vecops::matvec(f, &a, shape, &x);
                for threads in [2usize, 4, 5] {
                    let got = matvec(f, Parallelism::threads(threads), &a, shape, &x);
                    assert_eq!(got, seq, "p={p} {rows}x{cols} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn matvec_t_bit_identical_across_thread_counts() {
        for p in [P26, P31] {
            let f = Field::new(p);
            let mut r = Rng::seed_from_u64(3);
            for (rows, cols) in [(1usize, 64usize), (300, 77), (1024, 64), (2048, 9)] {
                let a = rand_vec(&mut r, p, rows * cols);
                let v = rand_vec(&mut r, p, rows);
                let shape = MatShape::new(rows, cols);
                let seq = vecops::matvec_t(f, &a, shape, &v);
                for threads in [2usize, 4, 5] {
                    let got = matvec_t(f, Parallelism::threads(threads), &a, shape, &v);
                    assert_eq!(got, seq, "p={p} {rows}x{cols} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn matvec_t_worst_case_elements_parallel() {
        // All entries p−1 at a budget-4 prime: maximal accumulation
        // pressure per block, with partial recombination on top.
        let f = Field::new(P31);
        let (rows, cols) = (4096usize, 8usize);
        let a = vec![P31 - 1; rows * cols];
        let v = vec![P31 - 1; rows];
        let shape = MatShape::new(rows, cols);
        assert_eq!(
            matvec_t(f, Parallelism::threads(4), &a, shape, &v),
            vecops::matvec_t(f, &a, shape, &v)
        );
    }

    #[test]
    fn poly_eval_bit_identical() {
        let f = Field::new(P26);
        let mut r = Rng::seed_from_u64(4);
        let coeffs = rand_vec(&mut r, P26, 4);
        let z0 = rand_vec(&mut r, P26, 3 * MIN_PAR_WORK + 5);
        let mut seq = z0.clone();
        vecops::poly_eval_assign(f, &coeffs, &mut seq);
        for threads in [2usize, 4] {
            let mut z = z0.clone();
            poly_eval_assign(f, Parallelism::threads(threads), &coeffs, &mut z);
            assert_eq!(z, seq, "threads={threads}");
        }
    }

    #[test]
    fn mont_tier_bit_identical_across_thread_counts() {
        // The tier dispatch must be value-transparent: every `_tier` entry
        // point under KernelTier::Mont matches its Barrett twin exactly,
        // sequential and threaded, at both a roomy and a budget-4 prime.
        for p in [P26, P31] {
            let f = Field::new(p);
            let mut r = Rng::seed_from_u64(11);
            let (rows, cols) = (600usize, 77usize);
            let a = rand_vec(&mut r, p, rows * cols);
            let x = rand_vec(&mut r, p, cols);
            let v = rand_vec(&mut r, p, rows);
            let shape = MatShape::new(rows, cols);
            let k = 9;
            let n = 2 * MIN_PAR_WORK + 17;
            let mats: Vec<Vec<u64>> = (0..k).map(|_| rand_vec(&mut r, p, n)).collect();
            let views: Vec<&[u64]> = mats.iter().map(|m| m.as_slice()).collect();
            let coeffs = rand_vec(&mut r, p, k);
            let poly = rand_vec(&mut r, p, 4);
            let z0 = rand_vec(&mut r, p, n);
            for threads in [1usize, 3, 4] {
                let par = Parallelism::threads(threads);
                assert_eq!(
                    matvec_tier(f, KernelTier::Mont, par, &a, shape, &x),
                    matvec_tier(f, KernelTier::Barrett, par, &a, shape, &x),
                    "matvec p={p} threads={threads}"
                );
                assert_eq!(
                    matvec_t_tier(f, KernelTier::Mont, par, &a, shape, &v),
                    matvec_t_tier(f, KernelTier::Barrett, par, &a, shape, &v),
                    "matvec_t p={p} threads={threads}"
                );
                let mut wb = vec![0u64; n];
                let mut wm = vec![0u64; n];
                weighted_sum_tier(f, KernelTier::Barrett, par, &coeffs, &views, &mut wb);
                weighted_sum_tier(f, KernelTier::Mont, par, &coeffs, &views, &mut wm);
                assert_eq!(wb, wm, "weighted_sum p={p} threads={threads}");
                let mut zb = z0.clone();
                let mut zm = z0.clone();
                poly_eval_assign_tier(f, KernelTier::Barrett, par, &poly, &mut zb);
                poly_eval_assign_tier(f, KernelTier::Mont, par, &poly, &mut zm);
                assert_eq!(zb, zm, "poly_eval p={p} threads={threads}");
            }
        }
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let f = Field::new(P26);
        let par = Parallelism::threads(4);
        let mut out: Vec<u64> = Vec::new();
        weighted_sum(f, par, &[], &[], &mut out);
        assert!(out.is_empty());
        let y = matvec(f, par, &[], MatShape::new(0, 5), &[1, 2, 3, 4, 5]);
        assert!(y.is_empty());
        let yt = matvec_t(f, par, &[], MatShape::new(0, 3), &[]);
        assert_eq!(yt, vec![0, 0, 0]);
    }
}
