//! Primality testing and the primes used by the paper's experiments.

/// `2^26 − 5` — the paper's 64-bit-implementation prime for CIFAR-10
/// (`d = 3072`; Appendix A: largest prime with `d(p−1)² ≤ 2^64 − 1`).
pub const P26: u64 = (1 << 26) - 5;

/// `2^25 − 39` — analogous prime for GISETTE-scale width (`d = 5000`).
pub const P25: u64 = (1 << 25) - 39;

/// `2^31 − 1` (Mersenne) — headroom prime for accuracy studies; inner
/// products must be tiled every ~4 terms (see `Field::accum_budget`).
pub const P31: u64 = (1 << 31) - 1;

/// Deterministic Miller–Rabin for u64.
///
/// The witness set {2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37} is proven
/// sufficient for all n < 3.3·10^24, which covers u64.
pub fn is_prime_u64(n: u64) -> bool {
    if n < 2 {
        return false;
    }
    for &p in &[2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        if n == p {
            return true;
        }
        if n % p == 0 {
            return false;
        }
    }
    // n − 1 = d · 2^s with d odd
    let mut d = n - 1;
    let mut s = 0u32;
    while d & 1 == 0 {
        d >>= 1;
        s += 1;
    }
    'witness: for &a in &[2u64, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37] {
        let mut x = mod_pow_u64(a, d, n);
        if x == 1 || x == n - 1 {
            continue;
        }
        for _ in 0..s - 1 {
            x = mod_mul_u64(x, x, n);
            if x == n - 1 {
                continue 'witness;
            }
        }
        return false;
    }
    true
}

#[inline]
fn mod_mul_u64(a: u64, b: u64, m: u64) -> u64 {
    ((a as u128 * b as u128) % m as u128) as u64
}

fn mod_pow_u64(mut base: u64, mut exp: u64, m: u64) -> u64 {
    base %= m;
    let mut acc = 1u64;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mod_mul_u64(acc, base, m);
        }
        base = mod_mul_u64(base, base, m);
        exp >>= 1;
    }
    acc
}

/// Largest prime `≤ n` (linear scan with Miller–Rabin; used by the
/// quantization planner to pick a dataset-specific modulus).
pub fn prev_prime(mut n: u64) -> u64 {
    assert!(n >= 2);
    loop {
        if is_prime_u64(n) {
            return n;
        }
        n -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_primes() {
        for p in [2u64, 3, 5, 97, 101, P25, P26, P31, 67108837] {
            assert!(is_prime_u64(p), "{p} should be prime");
        }
    }

    #[test]
    fn known_composites() {
        for c in [1u64, 4, 100, (1 << 26) - 1, (1 << 26) - 3, 67108859 * 3] {
            assert!(!is_prime_u64(c), "{c} should be composite");
        }
    }

    #[test]
    fn paper_prime_is_exactly_prev_prime_under_2_26() {
        // The paper picks the largest prime avoiding overflow; verify
        // 2^26 − 5 is the largest prime ≤ 2^26.
        assert_eq!(prev_prime(1 << 26), P26);
        assert_eq!(prev_prime(1 << 25), P25);
    }

    #[test]
    fn small_range_against_sieve() {
        // Cross-check Miller–Rabin against trial division for n < 2000.
        for n in 0u64..2000 {
            let naive = n >= 2 && (2..n).take_while(|d| d * d <= n).all(|d| n % d != 0);
            assert_eq!(is_prime_u64(n), naive, "n={n}");
        }
    }
}
