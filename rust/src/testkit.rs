//! Minimal property-based testing harness.
//!
//! The offline image ships no `proptest`/`quickcheck`, so this module
//! provides the small subset the test suite needs: seeded generators and a
//! `forall` runner that reports the failing case index and seed so any
//! failure is reproducible with [`run_case`].
//!
//! ```no_run
//! # // no_run: doctest binaries don't get the libxla rpath in this image
//! use copml::testkit::{forall, Gen};
//! forall("add commutes", 200, |g: &mut Gen| {
//!     let (a, b) = (g.u64_below(1000), g.u64_below(1000));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::prng::Rng;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    /// Case index (0..cases); properties can use it to scale sizes.
    pub case: usize,
}

impl Gen {
    pub fn u64_below(&mut self, bound: u64) -> u64 {
        self.rng.gen_range(bound)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.gen_range((hi - lo + 1) as u64) as usize
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.gen_f64()
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.gen_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_u64(&mut self, len: usize, bound: u64) -> Vec<u64> {
        (0..len).map(|_| self.rng.gen_range(bound)).collect()
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.gen_range(xs.len() as u64) as usize]
    }

    /// Access the underlying PRNG (for domain-specific generators).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` on `cases` seeded random inputs. Panics (re-raising the
/// property's panic) with the case index and seed on first failure.
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    let base_seed = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Rng::seed_from_u64(seed), case };
            prop(&mut g);
        });
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Re-run a single failing case by seed (debugging aid).
pub fn run_case<F: FnOnce(&mut Gen)>(seed: u64, case: usize, prop: F) {
    let mut g = Gen { rng: Rng::seed_from_u64(seed), case };
    prop(&mut g);
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert two f64 slices are element-wise close.
pub fn assert_allclose(a: &[f64], b: &[f64], atol: f64, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= atol,
            "{ctx}: index {i}: {x} vs {y} (atol {atol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    #[test]
    fn forall_runs_all_cases() {
        static N: AtomicUsize = AtomicUsize::new(0);
        forall("counter", 17, |_| {
            N.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(N.load(Ordering::SeqCst), 17);
    }

    #[test]
    fn forall_is_deterministic() {
        static VALS: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        forall("det", 5, |g| VALS.lock().unwrap().push(g.u64_below(1 << 40)));
        let first: Vec<u64> = std::mem::take(&mut *VALS.lock().unwrap());
        forall("det", 5, |g| VALS.lock().unwrap().push(g.u64_below(1 << 40)));
        let second: Vec<u64> = std::mem::take(&mut *VALS.lock().unwrap());
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall("fails", 64, |g| {
            let v = g.u64_below(16);
            assert!(v < 15, "hit the 1/16 case eventually");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        forall("ranges", 100, |g| {
            let n = g.usize_in(3, 9);
            assert!((3..=9).contains(&n));
            let x = g.f64_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&x));
        });
    }

    #[test]
    fn allclose_passes_within_tolerance() {
        assert_allclose(&[1.0, 2.0], &[1.0005, 1.9995], 1e-2, "ok");
    }
}
