//! The rule set behind `copml lint` — see [`crate::analysis`] for the
//! catalog and suppression mechanics.
//!
//! Every rule is a pure function from a lexed file to findings. Rules see
//! the token stream with `#[cfg(test)]` items already stripped (tests may
//! use literal tags and wall clocks freely), plus the comment side table
//! for the `SAFETY:` audit.

use std::collections::{HashMap, HashSet};

use super::lexer::{lex, strip_cfg_test, Comment, Tok, TokKind};
use super::Finding;

/// Arithmetic and compound-assignment operators banned next to tag-like
/// identifiers. Comparisons and plain `=` stay legal; `<<`/`>>` are
/// handled separately so `Vec<Tag>>` in a generic position never trips.
const ARITH: &[&str] = &[
    "+", "-", "*", "/", "%", "+=", "-=", "*=", "/=", "%=", "<<=", ">>=", "&=", "|=", "^=",
];
const SHIFT: &[&str] = &["<<", ">>"];

/// Transport calls whose **second** argument is the message tag.
const COMM: &[&str] = &["send", "recv", "recv_check", "recv_any", "try_recv", "forget"];

/// Iteration methods that expose `HashMap`/`HashSet` ordering.
const ITER_METHODS: &[&str] = &["iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "drain"];

/// Receive-shaped calls for the `recv-unwrap` rule.
const RECVISH: &[&str] = &["recv", "recv_check", "recv_any", "try_recv", "pop_result", "pop_any", "try_pop"];

/// Files allowed to read wall clocks: the receive-deadline machinery that
/// *implements* timeouts (and the ledger plumbing in `net/mod.rs`). All
/// other protocol-state code must take timing through the phase ledger.
const WALL_CLOCK_ALLOW: &[&str] = &["net/mailbox.rs", "net/mod.rs", "net/tcp.rs"];

/// The only file allowed to contain `unsafe` (the poll(2) FFI).
const UNSAFE_ALLOW: &[&str] = &["net/reactor.rs"];

/// Lint one file. `rel` is the path relative to the scanned source root,
/// with `/` separators (e.g. `coordinator/protocol.rs`).
pub fn lint_file(rel: &str, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let toks = strip_cfg_test(&lexed.toks);
    let mut out = Vec::new();
    rule_tag_arith(rel, &toks, &mut out);
    rule_tag_computed(rel, &toks, &mut out);
    rule_map_iter(rel, &toks, &mut out);
    rule_wall_clock(rel, &toks, &mut out);
    rule_thread_id(rel, &toks, &mut out);
    rule_recv_unwrap(rel, &toks, &mut out);
    rule_unsafe_block(rel, &toks, &lexed.comments, &mut out);
    let sups = suppressions(&lexed.comments);
    out.retain(|f| !sups.get(f.rule).is_some_and(|lines| lines.contains(&f.line)));
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Parse `// copml-lint: allow(rule-id) justification` comments. A
/// suppression covers its own line and the line below, and is honored
/// **only** when a non-empty justification follows the closing paren —
/// an unjustified suppression is silently ignored, so the finding stands.
fn suppressions(comments: &[Comment]) -> HashMap<String, HashSet<usize>> {
    let mut map: HashMap<String, HashSet<usize>> = HashMap::new();
    for c in comments {
        let body = c.text.trim_start_matches('/').trim();
        let Some(rest) = body.strip_prefix("copml-lint:") else { continue };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix("allow(") else { continue };
        let Some(close) = rest.find(')') else { continue };
        let rule = rest[..close].trim();
        let justification = rest[close + 1..].trim();
        if rule.is_empty() || justification.is_empty() {
            continue;
        }
        let entry = map.entry(rule.to_string()).or_default();
        entry.insert(c.line);
        entry.insert(c.line + 1);
    }
    map
}

fn in_protocol_dirs(rel: &str) -> bool {
    rel.starts_with("coordinator/") || rel.starts_with("mpc/") || rel.starts_with("net/")
}

/// Identifiers the tag-discipline rules treat as tags.
fn is_tag_ident(t: &Tok) -> bool {
    if t.kind != TokKind::Ident {
        return false;
    }
    let l = t.text.to_ascii_lowercase();
    l == "tag" || l.contains("tag_") || l.contains("_tag")
}

fn is_operand(t: Option<&Tok>) -> bool {
    matches!(
        t,
        Some(t) if t.kind == TokKind::Ident
            || t.kind == TokKind::Num
            || t.text == ")"
            || t.text == "]"
    )
}

/// `tag-arith`: no raw arithmetic on tag-like identifiers outside the
/// allocator module — tags come from `net::tags::TagAlloc`, never from
/// `base + offset` math that can silently diverge across parties.
fn rule_tag_arith(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if rel == "net/tags.rs" || rel.starts_with("analysis/") {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if !is_tag_ident(t) {
            continue;
        }
        let next = toks.get(i + 1);
        let after_op = |n: &Tok| {
            // `tag << 2` yes; `Vec<Tag>> =` no (shift must feed an operand)
            let follows = toks.get(i + 2);
            matches!(follows, Some(f) if f.kind == TokKind::Ident || f.kind == TokKind::Num || f.text == "(")
                && SHIFT.contains(&n.text.as_str())
        };
        let flagged_right = match next {
            Some(n) if n.kind == TokKind::Punct && ARITH.contains(&n.text.as_str()) => true,
            Some(n) if n.kind == TokKind::Punct && after_op(n) => true,
            _ => false,
        };
        let flagged_left = i >= 2
            && toks[i - 1].kind == TokKind::Punct
            && (ARITH.contains(&toks[i - 1].text.as_str()) || SHIFT.contains(&toks[i - 1].text.as_str()))
            && is_operand(toks.get(i - 2));
        if flagged_right || flagged_left {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "tag-arith",
                msg: format!(
                    "raw arithmetic on tag-like identifier `{}` — allocate tags through `net::tags::TagAlloc` instead",
                    t.text
                ),
            });
        }
    }
}

/// `tag-computed`: the tag argument of `.send`/`.recv`/`.recv_check`/
/// `.recv_any`/`.try_recv`/`.forget` must be a plain identifier path or
/// literal, not an inline expression.
fn rule_tag_computed(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if rel == "net/tags.rs" || rel.starts_with("analysis/") {
        return;
    }
    let mut i = 0usize;
    while i + 2 < toks.len() {
        let is_call = toks[i].text == "."
            && toks[i + 1].kind == TokKind::Ident
            && COMM.contains(&toks[i + 1].text.as_str())
            && toks[i + 2].text == "(";
        if !is_call {
            i += 1;
            continue;
        }
        let name = toks[i + 1].text.clone();
        let line = toks[i + 1].line;
        // split the argument list at depth-1 commas
        let mut depth = 1i64;
        let mut j = i + 3;
        let mut args: Vec<Vec<&Tok>> = vec![Vec::new()];
        while j < toks.len() && depth > 0 {
            match toks[j].text.as_str() {
                "(" | "[" | "{" => {
                    depth += 1;
                    args.last_mut().expect("args starts non-empty").push(&toks[j]);
                }
                ")" | "]" | "}" => {
                    depth -= 1;
                    if depth > 0 {
                        args.last_mut().expect("args starts non-empty").push(&toks[j]);
                    }
                }
                "," if depth == 1 => args.push(Vec::new()),
                _ => args.last_mut().expect("args starts non-empty").push(&toks[j]),
            }
            j += 1;
        }
        // one-argument `send` (mpsc channels etc.) carries no tag
        if args.len() >= 2 {
            let tag_arg = &args[1];
            let simple = !tag_arg.is_empty()
                && tag_arg.iter().all(|t| {
                    t.kind == TokKind::Ident
                        || t.kind == TokKind::Num
                        || t.text == "."
                        || t.text == "::"
                });
            if !simple {
                out.push(Finding {
                    file: rel.to_string(),
                    line,
                    rule: "tag-computed",
                    msg: format!(
                        "computed tag expression in `.{name}(..)` — bind the tag from `net::tags` to a local first"
                    ),
                });
            }
        }
        i = j;
    }
}

/// `map-iter`: no iteration over `HashMap`/`HashSet` in protocol state —
/// iteration order is randomized per process and breaks SPMD lock-step.
fn rule_map_iter(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_protocol_dirs(rel) {
        return;
    }
    // names declared in this file with a HashMap/HashSet type or initializer
    let mut names: HashSet<&str> = HashSet::new();
    for i in 0..toks.len() {
        if toks[i].text == "HashMap" || toks[i].text == "HashSet" {
            if i >= 2
                && (toks[i - 1].text == ":" || toks[i - 1].text == "=")
                && toks[i - 2].kind == TokKind::Ident
            {
                names.insert(toks[i - 2].text.as_str());
            }
        }
    }
    for i in 0..toks.len() {
        // name.iter() / name.keys() / …
        if toks[i].kind == TokKind::Ident
            && names.contains(toks[i].text.as_str())
            && toks.get(i + 1).is_some_and(|t| t.text == ".")
            && toks.get(i + 2).is_some_and(|t| ITER_METHODS.contains(&t.text.as_str()))
            && toks.get(i + 3).is_some_and(|t| t.text == "(")
        {
            out.push(Finding {
                file: rel.to_string(),
                line: toks[i].line,
                rule: "map-iter",
                msg: format!(
                    "iteration over hash collection `{}` (`.{}()`) in protocol state — order is nondeterministic",
                    toks[i].text,
                    toks[i + 2].text
                ),
            });
        }
        // for … in <expr containing a hash-typed name> { …
        if toks[i].text == "for" && toks[i].kind == TokKind::Ident {
            let mut j = i + 1;
            let mut found_in = None;
            while j < toks.len() && j < i + 40 && toks[j].text != "{" {
                if toks[j].text == "in" && toks[j].kind == TokKind::Ident {
                    found_in = Some(j);
                    break;
                }
                j += 1;
            }
            if let Some(in_idx) = found_in {
                let mut k = in_idx + 1;
                while k < toks.len() && toks[k].text != "{" {
                    if toks[k].kind == TokKind::Ident && names.contains(toks[k].text.as_str()) {
                        out.push(Finding {
                            file: rel.to_string(),
                            line: toks[k].line,
                            rule: "map-iter",
                            msg: format!(
                                "`for … in` over hash collection `{}` in protocol state — order is nondeterministic",
                                toks[k].text
                            ),
                        });
                        break;
                    }
                    k += 1;
                }
            }
        }
    }
}

/// `wall-clock`: no `Instant::now`/`SystemTime` in protocol state outside
/// the receive-deadline machinery — timing goes through the phase ledger.
fn rule_wall_clock(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_protocol_dirs(rel) || WALL_CLOCK_ALLOW.contains(&rel) {
        return;
    }
    for i in 0..toks.len() {
        let instant_now = toks[i].text == "Instant"
            && toks.get(i + 1).is_some_and(|t| t.text == "::")
            && toks.get(i + 2).is_some_and(|t| t.text == "now");
        let system_time = toks[i].text == "SystemTime" && toks[i].kind == TokKind::Ident;
        if instant_now || system_time {
            out.push(Finding {
                file: rel.to_string(),
                line: toks[i].line,
                rule: "wall-clock",
                msg: "wall-clock read in protocol state — route timing through the phase ledger (or justify with a suppression)".to_string(),
            });
        }
    }
}

/// `thread-id`: no `thread::current()`/`ThreadId` dependence in protocol
/// state — party identity comes from `Transport::id`, never the OS.
fn rule_thread_id(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_protocol_dirs(rel) {
        return;
    }
    for i in 0..toks.len() {
        let current = toks[i].text == "thread"
            && toks.get(i + 1).is_some_and(|t| t.text == "::")
            && toks.get(i + 2).is_some_and(|t| t.text == "current");
        let thread_id = toks[i].text == "ThreadId" && toks[i].kind == TokKind::Ident;
        if current || thread_id {
            out.push(Finding {
                file: rel.to_string(),
                line: toks[i].line,
                rule: "thread-id",
                msg: "thread-identity dependence in protocol state — party identity is `Transport::id`".to_string(),
            });
        }
    }
}

/// `recv-unwrap`: no bare `.unwrap()` on the same line as a receive call —
/// a failed receive must surface its cause (`expect`/`?`), not a bare
/// panic with no context.
fn rule_recv_unwrap(rel: &str, toks: &[Tok], out: &mut Vec<Finding>) {
    if !in_protocol_dirs(rel) {
        return;
    }
    let mut unwrap_lines: HashSet<usize> = HashSet::new();
    let mut recv_lines: HashSet<usize> = HashSet::new();
    for i in 0..toks.len() {
        if toks[i].text == "." && toks.get(i + 2).is_some_and(|t| t.text == "(") {
            if let Some(name) = toks.get(i + 1) {
                if name.kind == TokKind::Ident {
                    if name.text == "unwrap" {
                        unwrap_lines.insert(name.line);
                    } else if RECVISH.contains(&name.text.as_str()) {
                        recv_lines.insert(name.line);
                    }
                }
            }
        }
    }
    let mut lines: Vec<usize> = unwrap_lines.intersection(&recv_lines).copied().collect();
    lines.sort_unstable();
    for line in lines {
        out.push(Finding {
            file: rel.to_string(),
            line,
            rule: "recv-unwrap",
            msg: "bare `unwrap()` on a receive path — use `expect` with context or propagate the error".to_string(),
        });
    }
}

/// `unsafe-block`: every `unsafe` must live in an allow-listed file and
/// carry a `// SAFETY:` comment within the 3 preceding lines.
fn rule_unsafe_block(rel: &str, toks: &[Tok], comments: &[Comment], out: &mut Vec<Finding>) {
    for t in toks {
        if t.kind != TokKind::Ident || t.text != "unsafe" {
            continue;
        }
        if !UNSAFE_ALLOW.contains(&rel) {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "unsafe-block",
                msg: format!(
                    "`unsafe` outside the allow-list ({}) — the crate is `deny(unsafe_code)` everywhere else",
                    UNSAFE_ALLOW.join(", ")
                ),
            });
            continue;
        }
        let documented = comments
            .iter()
            .any(|c| c.text.contains("SAFETY:") && c.line <= t.line && t.line - c.line <= 3);
        if !documented {
            out.push(Finding {
                file: rel.to_string(),
                line: t.line,
                rule: "unsafe-block",
                msg: "`unsafe` without a `// SAFETY:` comment within the 3 preceding lines".to_string(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(rel: &str, src: &str) -> Vec<&'static str> {
        lint_file(rel, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn tag_arith_fires_on_offsets_and_shifts() {
        assert_eq!(rules_fired("mpc/x.rs", "let t = tag_base + i;"), vec!["tag-arith"]);
        assert_eq!(rules_fired("mpc/x.rs", "let t = 2 * round_tag;"), vec!["tag-arith"]);
        assert_eq!(rules_fired("mpc/x.rs", "let t = tag_hi << 4;"), vec!["tag-arith"]);
        assert_eq!(rules_fired("mpc/x.rs", "my_tag += 1;"), vec!["tag-arith"]);
    }

    #[test]
    fn tag_arith_allows_compares_assigns_and_generics() {
        assert!(rules_fired("mpc/x.rs", "if tag_x == other { }").is_empty());
        assert!(rules_fired("mpc/x.rs", "let tag_x = party.tag(kind);").is_empty());
        assert!(rules_fired("mpc/x.rs", "fn f(tag: Tag) -> Vec<Tag> { v }").is_empty());
        assert!(rules_fired("mpc/x.rs", "let m: HashMap<u64, Vec<Tag>> = make();").is_empty());
        // the allocator module itself is exempt
        assert!(rules_fired("net/tags.rs", "let t = tag_base + 1;").is_empty());
    }

    #[test]
    fn tag_computed_fires_on_inline_expressions_only() {
        assert_eq!(rules_fired("mpc/x.rs", "net.send(to, base + i, data);"), vec!["tag-computed"]);
        assert_eq!(rules_fired("net/x.rs", "net.recv(from, self.tag(kind))"), vec!["tag-computed"]);
        assert!(rules_fired("mpc/x.rs", "net.send(to, tag_x, data);").is_empty());
        assert!(rules_fired("mpc/x.rs", "net.recv(from, tags::DEPART)").is_empty());
        // mpsc-style one-argument send carries no tag
        assert!(rules_fired("coordinator/x.rs", "tx.send(result).ok();").is_empty());
    }

    #[test]
    fn map_iter_fires_in_protocol_dirs_only() {
        let src = "let mut m: HashMap<u64, u64> = HashMap::new();\nfor (k, v) in m.iter() { }";
        assert_eq!(rules_fired("coordinator/x.rs", src), vec!["map-iter", "map-iter"]);
        assert!(rules_fired("report.rs", src).is_empty());
        // lookups and mutation stay legal
        let ok = "let mut m: HashMap<u64, u64> = HashMap::new();\nm.insert(1, 2); let v = m.get(&1);";
        assert!(rules_fired("coordinator/x.rs", ok).is_empty());
    }

    #[test]
    fn wall_clock_and_thread_id_scoping() {
        assert_eq!(rules_fired("coordinator/x.rs", "let t0 = Instant::now();"), vec!["wall-clock"]);
        assert!(rules_fired("net/tcp.rs", "let t0 = Instant::now();").is_empty());
        assert_eq!(
            rules_fired("mpc/x.rs", "let me = thread::current().id();"),
            vec!["thread-id"]
        );
    }

    #[test]
    fn recv_unwrap_is_same_line_only() {
        assert_eq!(
            rules_fired("net/x.rs", "let v = net.recv_check(from, tag).unwrap();"),
            vec!["recv-unwrap"]
        );
        let multi = "let v = net\n    .recv_check(from, tag);\nlet w = opt.unwrap();";
        assert!(rules_fired("net/x.rs", multi).is_empty());
    }

    #[test]
    fn unsafe_audit_checks_allow_list_and_safety_comment() {
        assert_eq!(rules_fired("mpc/x.rs", "unsafe { go() }"), vec!["unsafe-block"]);
        assert_eq!(rules_fired("net/reactor.rs", "unsafe { go() }"), vec!["unsafe-block"]);
        let ok = "// SAFETY: fd is live and repr(C)\nunsafe { go() }";
        assert!(rules_fired("net/reactor.rs", ok).is_empty());
    }

    #[test]
    fn suppression_needs_a_justification() {
        let justified =
            "// copml-lint: allow(wall-clock) ledger start stamp, not protocol state\nlet t = Instant::now();";
        assert!(rules_fired("coordinator/x.rs", justified).is_empty());
        let bare = "// copml-lint: allow(wall-clock)\nlet t = Instant::now();";
        assert_eq!(rules_fired("coordinator/x.rs", bare), vec!["wall-clock"]);
    }

    #[test]
    fn cfg_test_items_are_exempt() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests { fn t() { let x = tag_base + 1; } }";
        assert!(rules_fired("mpc/x.rs", src).is_empty());
    }
}
