//! A minimal, hand-rolled Rust lexer for [`crate::analysis`] (`copml lint`).
//!
//! This is **not** a general Rust front-end: it produces exactly the token
//! stream the lint rules in [`crate::analysis::rules`] need — identifiers,
//! numbers, string/char literals, lifetimes and punctuation, each stamped
//! with its 1-based source line — plus a side table of comments (used for
//! the `SAFETY:` audit and `copml-lint: allow(..)` suppressions). It keeps
//! the repo's vendored-only policy: no syn, no proc-macro2, just `std`.
//!
//! Handled edge cases (each has a unit test below):
//!
//! * nested block comments (`/* a /* b */ c */`),
//! * raw and byte strings (`r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`),
//! * char literal vs. lifetime disambiguation (`'a'` vs. `'static`),
//! * multi-character operators by longest match (`<<=` before `<<`
//!   before `<`),
//! * `#[cfg(test)]` item stripping for both the semicolon form
//!   (`#[cfg(test)] mod tests;`) and brace-matched bodies.

/// Token classes `copml lint` distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (the rules match keywords by text).
    Ident,
    /// Numeric literal (integer or float, any base).
    Num,
    /// String literal (escaped, raw, or byte); text is the raw source span.
    Str,
    /// Char literal, e.g. `'a'` or `'\n'`.
    Char,
    /// Lifetime, e.g. `'static`.
    Lifetime,
    /// Punctuation/operator, longest-match (`::`, `->`, `<<=`, …).
    Punct,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// A comment (line or block) with the line it starts on.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// Lexer output: code tokens plus the comment side table.
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

/// Three- and two-character operators, tried longest-first so `<<=` never
/// lexes as `<<` `=`.
const OPS3: &[&str] = &["<<=", ">>=", "..=", "..."];
const OPS2: &[&str] = &[
    "::", "->", "=>", "==", "!=", "<=", ">=", "&&", "||", "+=", "-=", "*=", "/=", "%=", "^=",
    "&=", "|=", "<<", ">>", "..",
];

/// Tokenize `src`. Never fails: unrecognized bytes become 1-char `Punct`
/// tokens, which is good enough for linting (rustc has already accepted
/// the file if it is in the tree).
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c.is_whitespace() {
            i += 1;
        } else if c == '/' && chars.get(i + 1) == Some(&'/') {
            let start = i;
            while i < chars.len() && chars[i] != '\n' {
                i += 1;
            }
            comments.push(Comment { line, text: chars[start..i].iter().collect() });
        } else if c == '/' && chars.get(i + 1) == Some(&'*') {
            let (start, start_line) = (i, line);
            i += 2;
            let mut depth = 1usize;
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            comments.push(Comment { line: start_line, text: chars[start..i].iter().collect() });
        } else if c == '"' {
            let (text, ni, nl) = lex_escaped_string(&chars, i, line);
            toks.push(Tok { kind: TokKind::Str, text, line });
            i = ni;
            line = nl;
        } else if c == '\'' {
            let (tok, ni, nl) = lex_char_or_lifetime(&chars, i, line);
            toks.push(tok);
            i = ni;
            line = nl;
        } else if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            let next = chars.get(i).copied();
            let raw_prefix = (text == "r" || text == "br") && matches!(next, Some('"') | Some('#'));
            let byte_prefix = text == "b" && next == Some('"');
            if raw_prefix {
                let (s, ni, nl) = lex_raw_string(&chars, i, line);
                toks.push(Tok { kind: TokKind::Str, text: format!("{text}{s}"), line });
                i = ni;
                line = nl;
            } else if byte_prefix {
                let (s, ni, nl) = lex_escaped_string(&chars, i, line);
                toks.push(Tok { kind: TokKind::Str, text: format!("{text}{s}"), line });
                i = ni;
                line = nl;
            } else {
                toks.push(Tok { kind: TokKind::Ident, text, line });
            }
        } else if c.is_ascii_digit() {
            let start = i;
            while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            // float continuation: `1.5` but not `1.method()` or `0..n`
            if chars.get(i) == Some(&'.') && chars.get(i + 1).is_some_and(|d| d.is_ascii_digit()) {
                i += 1;
                while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
            }
            toks.push(Tok { kind: TokKind::Num, text: chars[start..i].iter().collect(), line });
        } else {
            let rest: String = chars[i..chars.len().min(i + 3)].iter().collect();
            let op = OPS3
                .iter()
                .find(|o| rest.starts_with(**o))
                .or_else(|| OPS2.iter().find(|o| rest.starts_with(**o)));
            let text = match op {
                Some(o) => (*o).to_string(),
                None => c.to_string(),
            };
            i += text.chars().count();
            toks.push(Tok { kind: TokKind::Punct, text, line });
        }
    }
    Lexed { toks, comments }
}

/// Lex an escaped (non-raw) string starting at the opening quote.
/// Returns (source text, next index, next line).
fn lex_escaped_string(chars: &[char], mut i: usize, mut line: usize) -> (String, usize, usize) {
    let start = i;
    i += 1; // opening quote
    while i < chars.len() {
        match chars[i] {
            '\\' => i += 2,
            '"' => {
                i += 1;
                break;
            }
            '\n' => {
                line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    (chars[start..i.min(chars.len())].iter().collect(), i, line)
}

/// Lex a raw string body starting at the `#`s or `"` after the `r`/`br`
/// prefix. Returns (source text from that point, next index, next line).
fn lex_raw_string(chars: &[char], mut i: usize, mut line: usize) -> (String, usize, usize) {
    let start = i;
    let mut hashes = 0usize;
    while chars.get(i) == Some(&'#') {
        hashes += 1;
        i += 1;
    }
    i += 1; // opening quote
    while i < chars.len() {
        if chars[i] == '\n' {
            line += 1;
            i += 1;
        } else if chars[i] == '"' && chars[i + 1..].iter().take(hashes).filter(|c| **c == '#').count() == hashes {
            i += 1 + hashes;
            break;
        } else {
            i += 1;
        }
    }
    (chars[start..i.min(chars.len())].iter().collect(), i, line)
}

/// Disambiguate `'a'` (char) from `'static` (lifetime) at a `'`.
fn lex_char_or_lifetime(chars: &[char], mut i: usize, mut line: usize) -> (Tok, usize, usize) {
    let start = i;
    let start_line = line;
    let next = chars.get(i + 1).copied();
    let is_lifetime = matches!(next, Some(c) if c.is_alphabetic() || c == '_')
        && chars.get(i + 2) != Some(&'\'');
    if is_lifetime {
        i += 1;
        while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
            i += 1;
        }
        let text: String = chars[start..i].iter().collect();
        return (Tok { kind: TokKind::Lifetime, text, line }, i, line);
    }
    i += 1; // opening quote
    while i < chars.len() {
        match chars[i] {
            '\\' => i += 2,
            '\'' => {
                i += 1;
                break;
            }
            '\n' => {
                line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    let text: String = chars[start..i.min(chars.len())].iter().collect();
    (Tok { kind: TokKind::Char, text, line: start_line }, i, line)
}

/// Drop `#[cfg(test)]` items from the token stream: the attribute, any
/// stacked attributes after it, and the item itself — either up to a `;`
/// at depth 0 (`mod tests;`) or through its brace-matched body.
///
/// The match is exact (`cfg` `(` `test` `)`): `#[cfg(not(test))]` and
/// `cfg!(test)` are *not* stripped.
pub fn strip_cfg_test(toks: &[Tok]) -> Vec<Tok> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text == "#" && toks.get(i + 1).is_some_and(|t| t.text == "[") {
            let (inner, end) = scan_attr(toks, i + 1);
            let is_cfg_test = inner.len() == 4
                && inner[0].text == "cfg"
                && inner[1].text == "("
                && inner[2].text == "test"
                && inner[3].text == ")";
            if is_cfg_test {
                let mut j = end;
                // stacked attributes on the same item
                while j < toks.len()
                    && toks[j].text == "#"
                    && toks.get(j + 1).is_some_and(|t| t.text == "[")
                {
                    let (_, e2) = scan_attr(toks, j + 1);
                    j = e2;
                }
                // the item body
                let mut depth = 0i64;
                let mut entered_brace = false;
                while j < toks.len() {
                    match toks[j].text.as_str() {
                        "(" | "[" => depth += 1,
                        "{" => {
                            depth += 1;
                            entered_brace = true;
                        }
                        ")" | "]" => depth -= 1,
                        "}" => {
                            depth -= 1;
                            if entered_brace && depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        ";" if depth == 0 && !entered_brace => {
                            j += 1;
                            break;
                        }
                        _ => {}
                    }
                    j += 1;
                }
                i = j;
                continue;
            }
        }
        out.push(toks[i].clone());
        i += 1;
    }
    out
}

/// From the index of an attribute's `[`, return its inner tokens and the
/// index just past the matching `]`.
fn scan_attr<'a>(toks: &'a [Tok], open: usize) -> (Vec<&'a Tok>, usize) {
    let mut depth = 0i64;
    let mut inner = Vec::new();
    let mut i = open;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "[" => {
                depth += 1;
                if depth > 1 {
                    inner.push(&toks[i]);
                }
            }
            "]" => {
                depth -= 1;
                if depth == 0 {
                    return (inner, i + 1);
                }
                inner.push(&toks[i]);
            }
            _ => inner.push(&toks[i]),
        }
        i += 1;
    }
    (inner, i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_numbers_and_longest_match_ops() {
        assert_eq!(texts("a <<= 1 << 2 <= 3"), vec!["a", "<<=", "1", "<<", "2", "<=", "3"]);
        assert_eq!(texts("x..=y .. z"), vec!["x", "..=", "y", "..", "z"]);
        assert_eq!(texts("p::q->r"), vec!["p", "::", "q", "->", "r"]);
        assert_eq!(texts("1.5 + v2.iter"), vec!["1.5", "+", "v2", ".", "iter"]);
    }

    #[test]
    fn nested_block_comments_and_line_numbers() {
        let l = lex("a /* x /* y */ z */ b\nc");
        assert_eq!(l.toks.iter().map(|t| t.text.as_str()).collect::<Vec<_>>(), vec!["a", "b", "c"]);
        assert_eq!(l.toks[1].line, 1);
        assert_eq!(l.toks[2].line, 2);
        assert_eq!(l.comments.len(), 1);
    }

    #[test]
    fn raw_and_byte_strings_swallow_contents() {
        let l = lex(r##"let s = r#"tag + 1 "quoted" "#; next"##);
        let texts: Vec<_> = l.toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts[..3], ["let", "s", "="]);
        assert_eq!(l.toks[3].kind, TokKind::Str);
        assert_eq!(texts[4..], [";", "next"]);
        let l2 = lex(r#"b"bytes \" still" x"#);
        assert_eq!(l2.toks[0].kind, TokKind::Str);
        assert_eq!(l2.toks[1].text, "x");
    }

    #[test]
    fn char_vs_lifetime() {
        let l = lex(r"'a' 'static '_ '\n' x");
        let kinds: Vec<_> = l.toks.iter().map(|t| t.kind).collect();
        assert_eq!(
            kinds,
            vec![TokKind::Char, TokKind::Lifetime, TokKind::Lifetime, TokKind::Char, TokKind::Ident]
        );
    }

    #[test]
    fn strips_cfg_test_semicolon_and_brace_forms() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests;\nfn b() {}";
        let kept = strip_cfg_test(&lex(src).toks);
        let texts: Vec<_> = kept.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["fn", "a", "(", ")", "{", "}", "fn", "b", "(", ")", "{", "}"]);

        let src2 = "#[cfg(test)]\nmod tests { fn t() { let x = vec![1]; } }\nfn c() {}";
        let kept2 = strip_cfg_test(&lex(src2).toks);
        let texts2: Vec<_> = kept2.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts2, vec!["fn", "c", "(", ")", "{", "}"]);
    }

    #[test]
    fn does_not_strip_cfg_not_test() {
        let src = "#[cfg(not(test))]\nfn keep() {}";
        let kept = strip_cfg_test(&lex(src).toks);
        assert!(kept.iter().any(|t| t.text == "keep"));
    }

    #[test]
    fn strips_stacked_attributes_with_cfg_test() {
        let src = "#[cfg(test)]\n#[allow(dead_code)]\nfn gone() {}\nfn kept() {}";
        let kept = strip_cfg_test(&lex(src).toks);
        assert!(!kept.iter().any(|t| t.text == "gone"));
        assert!(kept.iter().any(|t| t.text == "kept"));
    }
}
