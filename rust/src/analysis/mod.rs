//! `copml lint` — a source-level static analyzer for the protocol tree.
//!
//! COPML is an SPMD protocol: every party must allocate message tags in
//! the same order, consume randomness in the same order, and never branch
//! protocol state on anything local (wall clocks, thread identity, hash
//! iteration order). A violation does not fail loudly — it shows up as a
//! garbage decode or a 120 s receive timeout in a 50-party run. This
//! module enforces the discipline *statically*, at the source level, with
//! a hand-rolled lexer ([`lexer`]) and a small rule engine ([`rules`]) —
//! no external parser crates, matching the repo's vendored-only policy.
//!
//! Run it as `copml lint` (CI gates on zero findings) or in-process via
//! [`run_lint`].
//!
//! ## Rule catalog
//!
//! | rule | what it bans | where |
//! |------|--------------|-------|
//! | `tag-arith` | arithmetic on tag-like identifiers (`tag_base + i`) | everywhere except `net/tags.rs` |
//! | `tag-computed` | inline tag expressions in `.send`/`.recv`-family calls | everywhere except `net/tags.rs` |
//! | `map-iter` | iterating `HashMap`/`HashSet` in protocol state | `coordinator/`, `mpc/`, `net/` |
//! | `wall-clock` | `Instant::now`/`SystemTime` outside the deadline machinery | `coordinator/`, `mpc/`, `net/` minus `net/{mailbox,mod,tcp}.rs` |
//! | `thread-id` | `thread::current()`/`ThreadId` dependence | `coordinator/`, `mpc/`, `net/` |
//! | `recv-unwrap` | bare `.unwrap()` on the same line as a receive call | `coordinator/`, `mpc/`, `net/` |
//! | `unsafe-block` | `unsafe` outside `net/reactor.rs`, or without `// SAFETY:` | everywhere |
//!
//! `#[cfg(test)]` items are exempt (tests use literal tags and wall clocks
//! freely), as are out-of-line test modules — files named `tests.rs`, the
//! bodies of `#[cfg(test)] mod tests;` declarations. A finding can be
//! suppressed in place with
//!
//! ```text
//! // copml-lint: allow(rule-id) why this site is sound
//! ```
//!
//! on the finding's line or the line above — the justification text is
//! **mandatory**; a bare `allow(rule-id)` is ignored and the finding
//! stands.

pub mod lexer;
pub mod rules;

use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

/// One lint rule's identity, for the catalog and the CI rule-count pin.
pub struct Rule {
    pub id: &'static str,
    pub desc: &'static str,
}

/// The full rule catalog. The CI gate greps the rendered summary for
/// `copml lint: {RULES.len()} rules`, so adding a rule means updating the
/// pinned count in `.github/workflows/ci.yml` — a deliberate speed bump.
pub const RULES: &[Rule] = &[
    Rule { id: "tag-arith", desc: "no raw arithmetic on tag-like identifiers outside net/tags.rs" },
    Rule { id: "tag-computed", desc: "transport calls take a pre-bound tag, not an inline expression" },
    Rule { id: "map-iter", desc: "no HashMap/HashSet iteration in protocol state" },
    Rule { id: "wall-clock", desc: "no Instant::now/SystemTime outside the deadline machinery" },
    Rule { id: "thread-id", desc: "no thread::current()/ThreadId dependence in protocol state" },
    Rule { id: "recv-unwrap", desc: "no bare unwrap() on receive paths" },
    Rule { id: "unsafe-block", desc: "unsafe only in net/reactor.rs, and only with a // SAFETY: comment" },
];

/// One finding: file-relative path, 1-based line, rule id, message.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

/// The result of linting a source tree.
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl LintReport {
    /// True when the tree is clean (the CI gate).
    pub fn ok(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report: one `path:line: [rule] msg` line per finding
    /// plus a summary line the CI job greps verbatim.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for f in &self.findings {
            let _ = writeln!(s, "{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
        }
        let _ = writeln!(
            s,
            "copml lint: {} rules, {} findings ({} files scanned)",
            RULES.len(),
            self.findings.len(),
            self.files_scanned
        );
        s
    }
}

/// Lint every `.rs` file under `root` (the crate's `src/` directory).
/// Deterministic: files are visited in sorted path order and findings are
/// sorted by (file, line, rule).
pub fn run_lint(root: &Path) -> Result<LintReport, String> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let src = fs::read_to_string(path)
            .map_err(|e| format!("copml lint: cannot read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        findings.extend(rules::lint_file(&rel, &src));
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(LintReport { findings, files_scanned: files.len() })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = fs::read_dir(dir)
        .map_err(|e| format!("copml lint: cannot list {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("copml lint: bad entry under {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs")
            // Out-of-line test modules (`#[cfg(test)] mod tests;` bodies)
            // are exempt exactly like inline `#[cfg(test)]` items.
            && path.file_stem().map_or(true, |s| s != "tests")
        {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_catalog_ids_are_unique_and_counted() {
        let mut ids: Vec<_> = RULES.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), RULES.len(), "duplicate rule id in RULES");
        assert_eq!(RULES.len(), 7, "CI pins the rule count; update ci.yml when adding a rule");
    }

    #[test]
    fn render_contains_the_ci_summary_line() {
        let report = LintReport { findings: vec![], files_scanned: 3 };
        assert!(report.ok());
        assert!(report.render().contains("copml lint: 7 rules, 0 findings"));
    }
}
