//! Fixed-point quantization into `F_p` (paper Phase 1 / Appendix A) and the
//! scale-management plan that keeps every truncated value inside `k_2` bits.
//!
//! ## Scales
//!
//! | quantity | scale |
//! |---|---|
//! | data `X` | `2^{l_x}` |
//! | model `w` | `2^{l_w}` |
//! | sigmoid poly coefficients | `2^{l_c}` (degree-dependent, see below) |
//! | learning-rate factor `η/m` | `2^{l_e}` |
//!
//! With the degree-`r` approximation `ĝ(z) = Σ c_i z^i` evaluated at
//! `z = X_q·w_q` (scale `2^{l_x+l_w}`), every term is brought to the common
//! scale `2^{l_c+l_x+l_w}` by quantizing `c_i` at `2^{l_c+(1−i)(l_x+l_w)}`.
//! For `i ≥ 2` this exponent can go negative, underflowing the coefficient
//! to zero — the quantitative reason the paper finds `r = 1` the practical
//! choice (§V.A); [`FpPlan::validate`] reports it.
//!
//! ## Two-stage truncation
//!
//! The gradient `Xᵀ(ĝ − y)` sits at scale `2^{2l_x+l_w+l_c}`. The update
//! `w ← w − (η/m)·grad` is done as (§III Phase 4, via `mpc::trunc`):
//!
//! 1. `G₁ = TruncPr(grad_q, l_x + l_c)` → scale `2^{l_x+l_w}`
//! 2. `G₂ = TruncPr(e_q·G₁, l_x + l_e)` → scale `2^{l_w}`, `w ← w − G₂`
//!
//! so the paper's `k_1` = `2l_x + l_c + l_e` total bits truncated per
//! iteration. Each TruncPr input must lie in `(−2^{k_2−1}, 2^{k_2−1})`;
//! [`FpPlan::validate`] checks both stages against a caller-supplied
//! gradient bound, plus the statistical-privacy condition `p ≥ 2^{k_2+κ}`
//! and the inner-product tiling condition.

use crate::field::{is_prime_u64, Field, P25, P26, P31};

/// Round to the nearest integer, half-ties **away from zero** — the one
/// rounding rule every quantization site shares (data, learning-rate
/// factor, sigmoid coefficients).
///
/// The paper's `Round` (Appendix A, Eq. 13) is stated for non-negative
/// inputs, where half-away coincides with its round-half-up. The old code
/// extended it to negatives as `⌊v + 0.5⌋`, which rounds negative half-ties
/// toward +∞ (−2.5 → −2): an asymmetric rule that biases quantized values
/// of symmetric data upward. This helper pins the symmetric extension
/// (−2.5 → −3) and guards the `as i64` cast: at f64 extremes (±∞, NaN, or
/// magnitudes ≥ 2^63) the cast would silently saturate, so those inputs
/// panic with a named culprit instead.
#[inline]
pub fn round_half_away(v: f64) -> i64 {
    assert!(v.is_finite(), "quantizer rounding input is not finite: {v}");
    let r = if v >= 0.0 { (v + 0.5).floor() } else { (v - 0.5).ceil() };
    // r is integral; i64 covers exactly [−2^63, 2^63) of the integral f64s.
    assert!(
        (-9_223_372_036_854_775_808.0..9_223_372_036_854_775_808.0).contains(&r),
        "quantizer rounding overflows i64: input {v}"
    );
    r as i64
}

/// Quantize one real number at `scale` bits: `φ(Round(2^scale · x))`
/// (Appendix A, Eqs. 13–14), with `Round` = [`round_half_away`].
#[inline]
pub fn quantize(f: Field, x: f64, scale: u32) -> u64 {
    let v = x * (1u64 << scale) as f64;
    f.from_i64(round_half_away(v))
}

/// Inverse: field element → real at `scale` bits.
#[inline]
pub fn dequantize(f: Field, v: u64, scale: u32) -> f64 {
    f.to_i64(v) as f64 / (1u64 << scale) as f64
}

pub fn quantize_slice(f: Field, xs: &[f64], scale: u32) -> Vec<u64> {
    xs.iter().map(|&x| quantize(f, x, scale)).collect()
}

pub fn dequantize_slice(f: Field, vs: &[u64], scale: u32) -> Vec<f64> {
    vs.iter().map(|&v| dequantize(f, v, scale)).collect()
}

/// Fixed-point plan: field + scales + truncation parameters.
#[derive(Clone, Copy, Debug)]
pub struct FpPlan {
    pub field: Field,
    /// Data scale bits `l_x`.
    pub lx: u32,
    /// Model scale bits `l_w`.
    pub lw: u32,
    /// Sigmoid-coefficient scale bits `l_c`.
    pub lc: u32,
    /// Learning-rate-factor scale bits `l_e`.
    pub le: u32,
    /// Bit bound on values entering truncation (paper `k_2`).
    pub k2: u32,
    /// Statistical security slack `κ`: `p ≥ 2^{k_2+κ}`.
    pub kappa: u32,
}

/// Outcome of [`FpPlan::validate`].
#[derive(Clone, Debug, Default)]
pub struct PlanReport {
    pub ok: bool,
    pub errors: Vec<String>,
    pub warnings: Vec<String>,
}

impl FpPlan {
    /// Paper-parity plan for CIFAR-10-like data: `p = 2^26 − 5`,
    /// `(k_1, k_2) = (21, 24)` (§V.A — `k_1 = 2l_x+l_c+l_e = 21`).
    pub fn paper_cifar() -> FpPlan {
        FpPlan { field: Field::new(P26), lx: 2, lw: 7, lc: 3, le: 14, k2: 24, kappa: 1 }
    }

    /// Paper-parity plan for GISETTE-like data: `(k_1, k_2) = (22, 24)`.
    pub fn paper_gisette() -> FpPlan {
        FpPlan { field: Field::new(P25), lx: 2, lw: 6, lc: 3, le: 15, k2: 23, kappa: 1 }
    }

    /// Headroom plan (`p = 2^31 − 1`): more fractional bits everywhere,
    /// used by the accuracy ablation. Inner products tile every 4 terms.
    pub fn headroom() -> FpPlan {
        FpPlan { field: Field::new(P31), lx: 3, lw: 9, lc: 4, le: 16, k2: 29, kappa: 1 }
    }

    /// The paper's `k_1`: total bits truncated per iteration.
    pub fn k1_total(&self) -> u32 {
        2 * self.lx + self.lc + self.le
    }

    /// Stage-1 truncation amount (`l_x + l_c`).
    pub fn k1_stage1(&self) -> u32 {
        self.lx + self.lc
    }

    /// Stage-2 truncation amount (`l_x + l_e`).
    pub fn k1_stage2(&self) -> u32 {
        self.lx + self.le
    }

    /// Scale of the raw decoded gradient `Xᵀ(ĝ − y)`.
    pub fn grad_scale(&self) -> u32 {
        2 * self.lx + self.lw + self.lc
    }

    /// Quantized learning-rate factor `e_q = Round(2^{l_e}·η/m)`.
    pub fn eta_factor(&self, eta: f64, m: usize) -> u64 {
        let v = eta / m as f64 * (1u64 << self.le) as f64;
        let r = round_half_away(v);
        assert!(r >= 0, "negative learning rate");
        self.field.from_i64(r)
    }

    /// Validate the plan for a dataset with `m` samples, features bounded by
    /// `max_abs_x`, model bounded by `w_bound`, and a caller-estimated bound
    /// on the real-valued gradient `max_abs_grad` (`≤ m·max|x|·max|ĝ−y|`
    /// worst case; empirically far smaller).
    pub fn validate(&self, d: usize, max_abs_x: f64, w_bound: f64, max_abs_grad: f64, r: usize) -> PlanReport {
        let mut rep = PlanReport { ok: true, ..Default::default() };
        let p = self.field.modulus();
        let err = |rep: &mut PlanReport, s: String| {
            rep.ok = false;
            rep.errors.push(s);
        };

        // (1) prime sanity
        if !is_prime_u64(p) {
            err(&mut rep, format!("modulus {p} not prime"));
        }
        // (2) statistical truncation privacy: p ≥ 2^{k2+κ}
        if (p as f64) < 2f64.powi((self.k2 + self.kappa) as i32) {
            err(&mut rep, format!("p={p} < 2^(k2+kappa)=2^{}", self.k2 + self.kappa));
        }
        // (3) z = X·w magnitude must embed: |z|·2^{lx+lw} < p/2
        let zmax = max_abs_x * w_bound * d as f64; // coarse; caller may refine w_bound
        let zq = zmax * 2f64.powi((self.lx + self.lw) as i32);
        if zq >= (p / 2) as f64 {
            err(&mut rep, format!("inner product overflows signed range: |z_q|≈{zq:.1e} ≥ p/2"));
        }
        // (4) stage-1 truncation input: grad at scale 2^{2lx+lw+lc}
        let g1 = max_abs_grad * 2f64.powi(self.grad_scale() as i32);
        if g1 >= 2f64.powi(self.k2 as i32 - 1) {
            err(&mut rep, format!("stage-1 truncation input {g1:.2e} ≥ 2^(k2-1)=2^{}", self.k2 - 1));
        }
        // (5) stage-2 truncation input: e_q·G1; G1 ≈ grad·2^{lx+lw}
        let g2 = max_abs_grad * 2f64.powi((self.lx + self.lw + self.le) as i32) / 1.0;
        // e_q·G1 where e_q ≈ 2^{le}·η/m ≤ 2^{le}: bound conservatively with η/m ≤ 1.
        if g2 >= 2f64.powi(self.k2 as i32 - 1) * 2f64.powi(self.le as i32) {
            // effectively never fires with sane η/m; precise check is done at
            // runtime in debug builds (mpc::trunc asserts range).
            rep.warnings.push("stage-2 bound is learning-rate dependent".into());
        }
        // (6) high-degree sigmoid coefficients underflow? (the r=1 story)
        for i in 2..=r {
            let exp = self.lc as i64 + (1 - i as i64) * (self.lx + self.lw) as i64;
            if exp < 0 {
                rep.warnings.push(format!(
                    "degree-{i} coefficient scaled at 2^{exp} underflows; r=1 recommended (paper §V.A)"
                ));
            }
        }
        // (7) k1 consistency
        if self.k1_total() != self.k1_stage1() + self.k1_stage2() {
            err(&mut rep, "k1 stage split inconsistent".into());
        }
        rep
    }

    /// Multi-class extension of [`validate`] (ISSUE-10 satellite): a
    /// class-major gradient vector holds `C = grad_bounds.len()` channels
    /// truncated together, and the one-vs-rest labels are imbalanced, so
    /// every channel carries its **own** measured bound and must respect
    /// the Appendix-A stage-1 budget `2^{k_2−1}` individually.
    ///
    /// Runs the base checks with the worst channel's bound, then re-checks
    /// per class so the error **names the violating class**; the C-wide
    /// headroom (the worst channel's spare bits under the budget) lands in
    /// the warnings when it drops below one bit.
    pub fn validate_classes(
        &self,
        d: usize,
        max_abs_x: f64,
        w_bound: f64,
        grad_bounds: &[f64],
        r: usize,
    ) -> PlanReport {
        assert!(!grad_bounds.is_empty(), "at least one class gradient bound required");
        let (worst_class, worst) = grad_bounds
            .iter()
            .enumerate()
            .fold((0usize, 0.0f64), |(wc, wb), (c, &b)| if b > wb { (c, b) } else { (wc, wb) });
        let mut rep = self.validate(d, max_abs_x, w_bound, worst, r);
        let budget = 2f64.powi(self.k2 as i32 - 1);
        let scale = 2f64.powi(self.grad_scale() as i32);
        for (c, &bound) in grad_bounds.iter().enumerate() {
            let g1 = bound * scale;
            if g1 >= budget {
                rep.ok = false;
                rep.errors.push(format!(
                    "class {c}: stage-1 truncation input {g1:.2e} ≥ 2^(k2-1)=2^{} \
                     (measured per-class gradient bound {bound:.1})",
                    self.k2 - 1
                ));
            }
        }
        // C-wide headroom: spare bits of the widest channel under the edge.
        let headroom_bits = (budget / (worst * scale)).log2();
        if rep.ok && headroom_bits < 1.0 {
            rep.warnings.push(format!(
                "multi-class headroom: only {headroom_bits:.2} bits left under \
                 2^(k2-1) across {} channels (worst: class {worst_class}, bound \
                 {worst:.1}) — one doubling of the gradient overflows stage 1",
                grad_bounds.len()
            ));
        }
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_round_trips_small_values() {
        let f = Field::new(P26);
        for &x in &[0.0, 0.5, -0.5, 0.123, -0.987, 1.0, -1.0, 3.75] {
            let q = quantize(f, x, 8);
            let back = dequantize(f, q, 8);
            assert!((back - x).abs() <= 1.0 / 256.0 + 1e-12, "x={x} back={back}");
        }
    }

    #[test]
    fn quantize_matches_paper_round_rule() {
        // Round(x) = floor(x) if frac < 0.5 else floor(x)+1  (Eq. 13,
        // stated for x ≥ 0; negatives take the symmetric extension below).
        let f = Field::new(P26);
        assert_eq!(f.to_i64(quantize(f, 0.4999, 0)), 0);
        assert_eq!(f.to_i64(quantize(f, 0.5, 0)), 1);
        assert_eq!(f.to_i64(quantize(f, 1.4, 0)), 1);
        assert_eq!(f.to_i64(quantize(f, -0.4, 0)), 0);
        assert_eq!(f.to_i64(quantize(f, -0.6, 0)), -1);
    }

    #[test]
    fn rounding_is_symmetric_half_away() {
        // The old ⌊v + 0.5⌋ sent −2.5 → −2 (toward +∞); the pinned rule is
        // half-away-from-zero, so Round(−x) = −Round(x) for every x.
        for (v, want) in [
            (0.5, 1i64),
            (-0.5, -1),
            (1.5, 2),
            (-1.5, -2),
            (2.5, 3),
            (-2.5, -3),
            (-2.4999, -2),
            (-3.0, -3),
            (0.0, 0),
            (-0.0, 0),
        ] {
            assert_eq!(round_half_away(v), want, "v={v}");
            assert_eq!(round_half_away(-v), -want, "v={}", -v);
        }
    }

    #[test]
    fn rounding_matches_rational_reference() {
        // Boundary grid against an exact integer reference: every dyadic
        // v = n/4 is exact in f64, and Round(n/4) = sign(n)·⌊(|n| + 2)/4⌋
        // in integer arithmetic (half-away). Covers ties, near-ties, and
        // both signs over a range wider than any quantization scale hits.
        for n in -4000i64..=4000 {
            let v = n as f64 / 4.0;
            let want = n.signum() * ((n.abs() + 2) / 4);
            assert_eq!(round_half_away(v), want, "n={n}");
        }
        // The same grid through quantize(): scale 2 turns x = n/16 into
        // v = n/4, and the signed embedding must return the reference.
        let f = Field::new(P26);
        for n in -4000i64..=4000 {
            let x = n as f64 / 16.0;
            let want = n.signum() * ((n.abs() + 2) / 4);
            assert_eq!(f.to_i64(quantize(f, x, 2)), want, "n={n}");
        }
    }

    #[test]
    #[should_panic(expected = "not finite")]
    fn rounding_rejects_nan() {
        round_half_away(f64::NAN);
    }

    #[test]
    #[should_panic(expected = "not finite")]
    fn rounding_rejects_infinity() {
        round_half_away(f64::NEG_INFINITY);
    }

    #[test]
    #[should_panic(expected = "overflows i64")]
    fn rounding_rejects_f64_extremes() {
        // Pre-fix this cast saturated silently at i64::MIN/MAX.
        round_half_away(1e300);
    }

    #[test]
    fn rounding_accepts_i64_edge() {
        // Largest integral f64 strictly below 2^63 and −2^63 itself.
        assert_eq!(round_half_away(-9_223_372_036_854_775_808.0), i64::MIN);
        let below = 9_223_372_036_854_774_784.0f64; // 2^63 − 1024
        assert_eq!(round_half_away(below), 9_223_372_036_854_774_784);
    }

    #[test]
    fn negative_embedding_is_two_complement_style() {
        // φ(x) = p + x for x < 0  (Eq. 14)
        let f = Field::new(P26);
        let q = quantize(f, -1.0, 4);
        assert_eq!(q, P26 - 16);
    }

    #[test]
    fn paper_plans_validate() {
        // Gradient bound budget of the paper plan: 2^{k2−1}/2^{grad_scale}
        // = 2^23/2^15 = 256 — consistent with real-data class-mean feature
        // gaps at m ≈ 9000 (see DESIGN.md §5); the trainers range-check at
        // runtime.
        // Measured initial-gradient bounds of the synthetic stand-ins:
        // cifar-like ≈ 239, gisette-like ≈ 368 (probe in EXPERIMENTS.md).
        let p = FpPlan::paper_cifar();
        let rep = p.validate(3073, 1.0, 4.0 / 3073.0, 350.0, 1);
        assert!(rep.ok, "errors: {:?}", rep.errors);
        assert_eq!(p.k1_total(), 21); // paper: (k1,k2)=(21,24) for CIFAR-10

        let g = FpPlan::paper_gisette();
        assert_eq!(g.k1_total(), 22); // paper: (22,24) → our k2=23 for p=2^25
        let rep = g.validate(5000, 1.0, 4.0 / 5000.0, 480.0, 1);
        assert!(rep.ok, "errors: {:?}", rep.errors);
    }

    #[test]
    fn headroom_plan_validates() {
        let p = FpPlan::headroom();
        let rep = p.validate(3073, 1.0, 4.0 / 3073.0, 480.0, 1);
        assert!(rep.ok, "errors: {:?}", rep.errors);
        // strictly more fractional bits everywhere than the paper plan
        let c = FpPlan::paper_cifar();
        assert!(p.lx > c.lx && p.lw > c.lw && p.lc > c.lc);
    }

    #[test]
    fn r3_warns_about_underflow() {
        let p = FpPlan::paper_cifar();
        let rep = p.validate(3073, 1.0, 4.0 / 3073.0, 350.0, 3);
        assert!(rep.warnings.iter().any(|w| w.contains("underflows")));
    }

    #[test]
    fn overflow_detected() {
        // A plan with absurd scales must fail validation.
        let mut p = FpPlan::paper_cifar();
        p.lx = 12;
        p.lw = 12;
        let rep = p.validate(3073, 1.0, 1.0, 9019.0, 1);
        assert!(!rep.ok);
    }

    #[test]
    fn validate_classes_at_budget_edge() {
        // Appendix-A boundary for paper_cifar: grad_scale = 2·2+7+3 = 14,
        // k2−1 = 23, so the per-class budget edge sits at bound = 2^9 = 512.
        let p = FpPlan::paper_cifar();

        // Exactly at the edge → error naming the class.
        let rep = p.validate_classes(3073, 1.0, 4.0 / 3073.0, &[100.0, 512.0, 100.0], 1);
        assert!(!rep.ok);
        assert!(
            rep.errors.iter().any(|e| e.contains("class 1")),
            "edge violation must name the class: {:?}",
            rep.errors
        );
        // Classes under the edge must not be named.
        assert!(!rep.errors.iter().any(|e| e.contains("class 0") || e.contains("class 2")));

        // One step under the edge → ok, but the C-wide headroom warning
        // fires (less than one spare bit).
        let rep = p.validate_classes(3073, 1.0, 4.0 / 3073.0, &[100.0, 511.0, 100.0], 1);
        assert!(rep.ok, "errors: {:?}", rep.errors);
        assert!(
            rep.warnings.iter().any(|w| w.contains("headroom") && w.contains("class 1")),
            "sub-bit margin must warn with the worst class: {:?}",
            rep.warnings
        );

        // A full bit of margin → clean report.
        let rep = p.validate_classes(3073, 1.0, 4.0 / 3073.0, &[100.0, 256.0, 100.0], 1);
        assert!(rep.ok);
        assert!(!rep.warnings.iter().any(|w| w.contains("headroom")), "{:?}", rep.warnings);
    }

    #[test]
    fn validate_classes_single_class_matches_validate() {
        // C = 1 must reduce to the scalar path (the logreg oracle).
        let p = FpPlan::paper_cifar();
        let a = p.validate(3073, 1.0, 4.0 / 3073.0, 350.0, 1);
        let b = p.validate_classes(3073, 1.0, 4.0 / 3073.0, &[350.0], 1);
        assert_eq!(a.ok, b.ok);
        assert_eq!(a.errors, b.errors);
    }

    #[test]
    fn eta_factor_reasonable() {
        let p = FpPlan::paper_cifar();
        let e = p.eta_factor(2.0, 9019);
        // 2^14 · 2/9019 ≈ 3.63 → rounds to 4
        assert_eq!(e, 4);
    }
}
