//! Lagrange coded computing (LCC) — the heart of COPML's parallelization
//! (paper Phase 2, Eqs. 3–4; decoding Eq. 10; from Yu et al., AISTATS'19).
//!
//! The dataset is split into `K` partitions `X_1..X_K`; together with `T`
//! uniformly random masks `Z_{K+1}..Z_{K+T}` they define the degree-
//! `K+T−1` polynomial `u(z)` with `u(β_k) = X_k` (data) and `u(β_{K+k}) =
//! Z_k` (masks). Client `i` receives the evaluation `X̃_i = u(α_i)` — a
//! matrix of **1/K-th** the dataset size. Any `T` evaluations are jointly
//! uniform (the masks), giving information-theoretic privacy; and for any
//! polynomial `f` of total degree `D`, `h(z) = f(u(z), v(z))` has degree
//! `≤ D(K+T−1)`, so `D(K+T−1)+1` client results interpolate `h` and reveal
//! `f(X_k, w) = h(β_k)` for all `k` at once.
//!
//! Because the evaluation points are public, encoding and decoding are
//! weighted sums with public coefficients — they commute with Shamir secret
//! sharing, which is why COPML can encode *shares* and never expose the
//! data (Phase 2) — see `tests/protocol_equivalence.rs` for the
//! share/encode commutation test.

use crate::field::{par, vecops, Field, KernelTier, Parallelism};
use crate::poly;
use crate::prng::Rng;

/// Minimum number of client results needed to decode a degree-`2r+1`
/// computation: `(2r+1)(K+T−1)+1` (paper Theorem 1).
pub fn recovery_threshold(r: usize, k: usize, t: usize) -> usize {
    (2 * r + 1) * (k + t - 1) + 1
}

/// Maximum parallelization for given `n`, `t`, `r`:
/// largest `K` with `n ≥ (2r+1)(K+T−1)+1`.
///
/// Edge cases, made explicit:
///
/// * `n < d+1` (with `d = 2r+1`): even `K = 1, T = 1` needs `d+1` results
///   to interpolate a degree-`d` polynomial, so no parallelization exists
///   at all — returns 0.
/// * `(n−1)/d ≤ t−1`: the privacy masks alone exhaust the degree budget;
///   `saturating_sub` is the underflow guard that clamps this to 0 (both
///   operands are unsigned — a plain `-` would wrap).
pub fn max_k(n: usize, t: usize, r: usize) -> usize {
    let d = 2 * r + 1;
    if n < d + 1 {
        return 0;
    }
    ((n - 1) / d).saturating_sub(t - 1)
}

/// Precomputed Lagrange encoder: maps `K` data partitions + `T` masks to
/// `N` encoded evaluations.
pub struct Encoder {
    /// `coeffs[j][k]`: weight of partition/mask `k` in client `j`'s
    /// encoding — `Π_{l≠k} (α_j − β_l)/(β_k − β_l)`.
    coeffs: Vec<Vec<u64>>,
    field: Field,
    pub k: usize,
    pub t: usize,
}

impl Encoder {
    /// Build an encoder for `K` partitions, `T` masks, clients at `alphas`,
    /// encoding points `betas` (length `K+T`, disjoint from `alphas`).
    pub fn new(field: Field, k: usize, t: usize, betas: &[u64], alphas: &[u64]) -> Encoder {
        assert_eq!(betas.len(), k + t);
        for a in alphas {
            assert!(!betas.contains(a), "alphas and betas must be disjoint");
        }
        Encoder { coeffs: poly::coeff_matrix(field, betas, alphas), field, k, t }
    }

    /// Standard points: `β = 1..K+T`, `α = K+T+1..K+T+N`.
    pub fn standard(field: Field, k: usize, t: usize, n: usize) -> Encoder {
        let (betas, alphas) = poly::standard_points(k + t, n);
        Encoder::new(field, k, t, &betas, &alphas)
    }

    pub fn n(&self) -> usize {
        self.coeffs.len()
    }

    /// Encode for client `j`: `X̃_j = Σ_k coeffs[j][k]·parts[k]`.
    /// `parts` = `K` data partitions followed by `T` masks, all equal-sized.
    pub fn encode_one(&self, j: usize, parts: &[&[u64]], out: &mut [u64]) {
        assert_eq!(parts.len(), self.k + self.t);
        vecops::weighted_sum(self.field, &self.coeffs[j], parts, out);
    }

    /// [`Encoder::encode_one`] with the weighted sum element-blocked across
    /// `par` worker threads (bit-identical output).
    pub fn encode_one_par(&self, pp: Parallelism, j: usize, parts: &[&[u64]], out: &mut [u64]) {
        assert_eq!(parts.len(), self.k + self.t);
        par::weighted_sum(self.field, pp, &self.coeffs[j], parts, out);
    }

    /// [`Encoder::encode_one_par`] on an explicit kernel tier
    /// (`--kernel barrett|mont`; bit-identical output either way).
    pub fn encode_one_tier(
        &self,
        tier: KernelTier,
        pp: Parallelism,
        j: usize,
        parts: &[&[u64]],
        out: &mut [u64],
    ) {
        assert_eq!(parts.len(), self.k + self.t);
        par::weighted_sum_tier(self.field, tier, pp, &self.coeffs[j], parts, out);
    }

    /// Encode for every client. Returns `N` encoded matrices.
    pub fn encode_all(&self, parts: &[&[u64]]) -> Vec<Vec<u64>> {
        let len = parts[0].len();
        (0..self.n())
            .map(|j| {
                let mut out = vec![0u64; len];
                self.encode_one(j, parts, &mut out);
                out
            })
            .collect()
    }

    /// Generate the `T` uniform masks (paper: `Z_k ~ U(F_p^{m/K × d})`).
    pub fn gen_masks(&self, len: usize, rng: &mut Rng) -> Vec<Vec<u64>> {
        (0..self.t)
            .map(|_| {
                let mut z = vec![0u64; len];
                rng.fill_field(self.field.modulus(), &mut z);
                z
            })
            .collect()
    }
}

/// Precomputed Lagrange decoder: interpolates `h(z)` of degree
/// `≤ deg_f·(K+T−1)` from client results at a subset of `alphas` and
/// re-evaluates at `β_1..β_K` (Eq. 10).
pub struct Decoder {
    /// `coeffs[k][j]`: weight of client result `j` in `h(β_k)`.
    coeffs: Vec<Vec<u64>>,
    field: Field,
}

impl Decoder {
    /// `alphas_used`: the evaluation points of the clients whose results we
    /// have (e.g. the fastest ones); must number at least
    /// `deg_f·(K+T−1)+1` where `deg_f = 2r+1`.
    pub fn new(
        field: Field,
        k: usize,
        t: usize,
        deg_f: usize,
        alphas_used: &[u64],
        betas: &[u64],
    ) -> Decoder {
        let need = deg_f * (k + t - 1) + 1;
        assert!(
            alphas_used.len() >= need,
            "recovery threshold not met: have {}, need {need}",
            alphas_used.len()
        );
        assert!(betas.len() >= k);
        Decoder {
            coeffs: poly::coeff_matrix(field, alphas_used, &betas[..k]),
            field,
        }
    }

    /// Decode partition `k`'s result `f(X_k, w) = h(β_k)` from the client
    /// results (same order as `alphas_used`).
    pub fn decode_one(&self, k: usize, results: &[&[u64]], out: &mut [u64]) {
        vecops::weighted_sum(self.field, &self.coeffs[k], results, out);
    }

    /// Aggregate decode weights `Σ_k coeffs[k][j]` (Eq. 11 collapsed into
    /// one weighted sum).
    fn sum_coeffs(&self, n: usize) -> Vec<u64> {
        let f = self.field;
        let mut agg = vec![0u64; n];
        for row in &self.coeffs {
            assert_eq!(row.len(), n);
            for (a, &c) in agg.iter_mut().zip(row) {
                *a = f.add(*a, c);
            }
        }
        agg
    }

    /// Decode and **aggregate** all `K` partitions:
    /// `Σ_k f(X_k, w) = Xᵀ ĝ(X·w)` (Eq. 11). One pass: the aggregate
    /// weights are `Σ_k coeffs[k][j]`, so this is a single weighted sum.
    pub fn decode_sum(&self, results: &[&[u64]], out: &mut [u64]) {
        let agg = self.sum_coeffs(results.len());
        vecops::weighted_sum(self.field, &agg, results, out);
    }

    /// [`Decoder::decode_sum`] with the weighted sum element-blocked across
    /// `par` worker threads (bit-identical output).
    pub fn decode_sum_par(&self, pp: Parallelism, results: &[&[u64]], out: &mut [u64]) {
        let agg = self.sum_coeffs(results.len());
        par::weighted_sum(self.field, pp, &agg, results, out);
    }

    /// [`Decoder::decode_sum_par`] on an explicit kernel tier
    /// (`--kernel barrett|mont`; bit-identical output either way).
    pub fn decode_sum_tier(
        &self,
        tier: KernelTier,
        pp: Parallelism,
        results: &[&[u64]],
        out: &mut [u64],
    ) {
        let agg = self.sum_coeffs(results.len());
        par::weighted_sum_tier(self.field, tier, pp, &agg, results, out);
    }
}

/// Per-quorum [`Decoder`] factory for the straggler-resilient online phase:
/// builds the decoder from the evaluation points of the clients that
/// *actually answered* a round (any `deg_f(K+T−1)+1` of them interpolate
/// `h` exactly — Theorem 1 — so the decoded gradient is bit-identical
/// regardless of which quorum it is), caching the coefficient matrices by
/// member subset. Quorum composition is sticky in practice (the same fast
/// clients answer round after round), so the cache stays tiny; it is
/// bounded at [`DecoderCache::CAPACITY`] entries regardless.
pub struct DecoderCache {
    field: Field,
    k: usize,
    t: usize,
    deg_f: usize,
    /// Evaluation point of client `j` is `alphas[j]`.
    alphas: Vec<u64>,
    betas: Vec<u64>,
    cache: std::collections::HashMap<Vec<usize>, std::rc::Rc<Decoder>>,
    /// Insertion order for eviction (oldest first).
    order: std::collections::VecDeque<Vec<usize>>,
}

impl DecoderCache {
    /// Cached coefficient matrices. Evicting the oldest subset beyond this
    /// keeps a run with churning quorums (parties joining/leaving the fast
    /// set) from accumulating one `K×need` matrix per distinct subset.
    pub const CAPACITY: usize = 8;

    pub fn new(
        field: Field,
        k: usize,
        t: usize,
        deg_f: usize,
        alphas: Vec<u64>,
        betas: Vec<u64>,
    ) -> DecoderCache {
        DecoderCache {
            field,
            k,
            t,
            deg_f,
            alphas,
            betas,
            cache: std::collections::HashMap::new(),
            order: std::collections::VecDeque::new(),
        }
    }

    /// Decoder for the quorum `members` (ascending client ids, each
    /// indexing into `alphas`). Builds and caches on first sight.
    pub fn get(&mut self, members: &[usize]) -> std::rc::Rc<Decoder> {
        if let Some(d) = self.cache.get(members) {
            return d.clone();
        }
        let pts: Vec<u64> = members.iter().map(|&j| self.alphas[j]).collect();
        let dec = std::rc::Rc::new(Decoder::new(
            self.field,
            self.k,
            self.t,
            self.deg_f,
            &pts,
            &self.betas,
        ));
        if self.cache.len() >= Self::CAPACITY {
            if let Some(oldest) = self.order.pop_front() {
                self.cache.remove(&oldest);
            }
        }
        self.cache.insert(members.to_vec(), dec.clone());
        self.order.push_back(members.to_vec());
        dec
    }

    /// Number of cached subsets (tests).
    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::field::{MatShape, P26};

    fn setup(k: usize, t: usize, n: usize) -> (Field, Encoder) {
        let f = Field::new(P26);
        (f, Encoder::standard(f, k, t, n))
    }

    #[test]
    fn recovery_threshold_matches_paper() {
        // r=1, Case 1 at N=50: K=16, T=1 → threshold 3·16+1 = 49 ≤ 50 ✓
        assert_eq!(recovery_threshold(1, 16, 1), 49);
        // Case 2 at N=50: T=7, K=⌊52/3⌋−7=10 → 3·16+1 = 49 ≤ 50 ✓
        assert_eq!(recovery_threshold(1, 10, 7), 49);
        assert!(recovery_threshold(1, 17, 1) > 50);
    }

    #[test]
    fn max_k_consistent_with_threshold() {
        for n in [4usize, 10, 31, 50] {
            for t in [1usize, 2, 7] {
                for r in [1usize, 3] {
                    let k = max_k(n, t, r);
                    if k >= 1 {
                        assert!(recovery_threshold(r, k, t) <= n, "n={n} t={t} r={r} k={k}");
                        assert!(recovery_threshold(r, k + 1, t) > n);
                    }
                }
            }
        }
    }

    #[test]
    fn encode_evaluates_data_at_betas() {
        // u(β_k) = X_k: encoding then "decoding with deg_f=1 at the same
        // betas" recovers the partitions.
        let (f, enc) = setup(3, 2, 8);
        let mut rng = Rng::seed_from_u64(1);
        let len = 40;
        let parts_data: Vec<Vec<u64>> = (0..3)
            .map(|_| (0..len).map(|_| rng.gen_range(P26)).collect())
            .collect();
        let masks = enc.gen_masks(len, &mut rng);
        let parts: Vec<&[u64]> = parts_data.iter().chain(masks.iter()).map(|v| v.as_slice()).collect();
        let encoded = enc.encode_all(&parts);

        // u has degree K+T−1 = 4, so deg_f=1 needs (K+T−1)+1 = 5 points.
        let (betas, alphas) = poly::standard_points(5, 8);
        let dec = Decoder::new(f, 3, 2, 1, &alphas, &betas);
        let views: Vec<&[u64]> = encoded.iter().map(|v| v.as_slice()).collect();
        for k in 0..3 {
            let mut out = vec![0u64; len];
            dec.decode_one(k, &views, &mut out);
            assert_eq!(out, parts_data[k], "partition {k}");
        }
    }

    #[test]
    fn end_to_end_quadratic_function() {
        // f(x) = x∘x (deg 2): encode, square each encoded value, decode with
        // ≥ 2(K+T−1)+1 results, compare against squaring the partitions.
        let f = Field::new(P26);
        let (k, t, n) = (4usize, 2usize, 11usize);
        let enc = Encoder::standard(f, k, t, n);
        let mut rng = Rng::seed_from_u64(2);
        let len = 16;
        let parts_data: Vec<Vec<u64>> = (0..k)
            .map(|_| (0..len).map(|_| rng.gen_range(P26)).collect())
            .collect();
        let masks = enc.gen_masks(len, &mut rng);
        let parts: Vec<&[u64]> = parts_data.iter().chain(masks.iter()).map(|v| v.as_slice()).collect();
        let encoded = enc.encode_all(&parts);

        let squared: Vec<Vec<u64>> = encoded
            .iter()
            .map(|e| e.iter().map(|&v| f.mul(v, v)).collect())
            .collect();

        let (betas, alphas) = poly::standard_points(k + t, n);
        let need = 2 * (k + t - 1) + 1; // 11
        assert!(n >= need);
        let dec = Decoder::new(f, k, t, 2, &alphas[..need], &betas);
        let views: Vec<&[u64]> = squared[..need].iter().map(|v| v.as_slice()).collect();
        for kk in 0..k {
            let mut out = vec![0u64; len];
            dec.decode_one(kk, &views, &mut out);
            let expect: Vec<u64> = parts_data[kk].iter().map(|&v| f.mul(v, v)).collect();
            assert_eq!(out, expect, "partition {kk}");
        }
    }

    #[test]
    fn end_to_end_gradient_shape_function() {
        // The real COPML computation: f(X, w) = Xᵀ·(c0 + c1·(X·w)) — degree
        // 3 in the encoded variables (deg 2r+1 with r=1).
        let f = Field::new(P26);
        let (k, t) = (2usize, 1usize);
        let deg_f = 3;
        let n = recovery_threshold(1, k, t) + 1; // 8
        let enc = Encoder::standard(f, k, t, n);
        let mut rng = Rng::seed_from_u64(3);
        let (rows, d) = (6usize, 5usize); // rows per partition
        let len = rows * d;
        let shape = MatShape::new(rows, d);
        let xparts: Vec<Vec<u64>> = (0..k)
            .map(|_| (0..len).map(|_| rng.gen_range(P26)).collect())
            .collect();
        let xmasks = enc.gen_masks(len, &mut rng);
        let xall: Vec<&[u64]> = xparts.iter().chain(xmasks.iter()).map(|v| v.as_slice()).collect();
        let xenc = enc.encode_all(&xall);

        // model: same w for every partition slot + T random masks (Eq. 4)
        let w: Vec<u64> = (0..d).map(|_| rng.gen_range(P26)).collect();
        let wparts: Vec<Vec<u64>> = (0..k).map(|_| w.clone()).collect();
        let wmasks = enc.gen_masks(d, &mut rng);
        let wall: Vec<&[u64]> = wparts.iter().chain(wmasks.iter()).map(|v| v.as_slice()).collect();
        let wenc = enc.encode_all(&wall);

        let (c0, c1) = (12345u64, 678u64);
        let eval = |x: &[u64], wv: &[u64]| -> Vec<u64> {
            let mut z = vecops::matvec(f, x, shape, wv);
            for v in z.iter_mut() {
                *v = f.reduce(f.mul(c1, *v) + c0);
            }
            vecops::matvec_t(f, x, shape, &z)
        };

        let results: Vec<Vec<u64>> = (0..n).map(|j| eval(&xenc[j], &wenc[j])).collect();
        let (betas, alphas) = poly::standard_points(k + t, n);
        let need = deg_f * (k + t - 1) + 1;
        let dec = Decoder::new(f, k, t, deg_f, &alphas[..need], &betas);
        let views: Vec<&[u64]> = results[..need].iter().map(|v| v.as_slice()).collect();

        // per-partition check
        for kk in 0..k {
            let mut out = vec![0u64; d];
            dec.decode_one(kk, &views, &mut out);
            assert_eq!(out, eval(&xparts[kk], &w), "partition {kk}");
        }
        // aggregated check (Eq. 11)
        let mut agg = vec![0u64; d];
        dec.decode_sum(&views, &mut agg);
        let mut expect = vec![0u64; d];
        for kk in 0..k {
            vecops::add_assign(f, &mut expect, &eval(&xparts[kk], &w));
        }
        assert_eq!(agg, expect);
    }

    #[test]
    fn par_encode_decode_bit_identical() {
        let f = Field::new(P26);
        let (k, t, n) = (4usize, 2usize, 11usize);
        let enc = Encoder::standard(f, k, t, n);
        let mut rng = Rng::seed_from_u64(9);
        let len = 40_000; // above the fan-out threshold
        let parts_data: Vec<Vec<u64>> = (0..k)
            .map(|_| (0..len).map(|_| rng.gen_range(P26)).collect())
            .collect();
        let masks = enc.gen_masks(len, &mut rng);
        let parts: Vec<&[u64]> =
            parts_data.iter().chain(masks.iter()).map(|v| v.as_slice()).collect();
        let mut seq = vec![0u64; len];
        enc.encode_one(3, &parts, &mut seq);
        let mut par_out = vec![0u64; len];
        enc.encode_one_par(Parallelism::threads(4), 3, &parts, &mut par_out);
        assert_eq!(par_out, seq);

        let (betas, alphas) = poly::standard_points(k + t, n);
        let need = 2 * (k + t - 1) + 1;
        let dec = Decoder::new(f, k, t, 2, &alphas[..need], &betas);
        let results: Vec<Vec<u64>> = (0..need)
            .map(|_| (0..len).map(|_| rng.gen_range(P26)).collect())
            .collect();
        let views: Vec<&[u64]> = results.iter().map(|v| v.as_slice()).collect();
        let mut a = vec![0u64; len];
        dec.decode_sum(&views, &mut a);
        let mut b = vec![0u64; len];
        dec.decode_sum_par(Parallelism::threads(4), &views, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn masked_encodings_look_uniform() {
        // With T=1 mask, a single client's encoding of a constant dataset
        // should be statistically uniform — mean ≈ p/2.
        let f = Field::new(P26);
        let enc = Encoder::standard(f, 2, 1, 4);
        let mut rng = Rng::seed_from_u64(4);
        let len = 1;
        let parts_data = [vec![7u64], vec![7u64]];
        let trials = 4000;
        let mut sum = 0f64;
        for _ in 0..trials {
            let masks = enc.gen_masks(len, &mut rng);
            let parts: Vec<&[u64]> =
                parts_data.iter().map(|v| v.as_slice()).chain(masks.iter().map(|v| v.as_slice())).collect();
            let mut out = vec![0u64; len];
            enc.encode_one(0, &parts, &mut out);
            sum += out[0] as f64;
        }
        let mean = sum / trials as f64;
        let expect = (P26 / 2) as f64;
        assert!((mean - expect).abs() / expect < 0.05, "mean {mean}");
    }

    #[test]
    fn any_quorum_subset_decodes_identically() {
        // The property the straggler-resilient online phase rests on
        // (Theorem 1): h has degree ≤ deg_f(K+T−1), so ANY need-subset of
        // client results interpolates the same Σ_k h(β_k) — bit for bit.
        let f = Field::new(P26);
        let (k, t, n) = (2usize, 1usize, 10usize);
        let deg_f = 3;
        let need = recovery_threshold(1, k, t); // 7
        let enc = Encoder::standard(f, k, t, n);
        let mut rng = Rng::seed_from_u64(11);
        let len = 24;
        let parts_data: Vec<Vec<u64>> = (0..k)
            .map(|_| (0..len).map(|_| rng.gen_range(P26)).collect())
            .collect();
        let masks = enc.gen_masks(len, &mut rng);
        let parts: Vec<&[u64]> =
            parts_data.iter().chain(masks.iter()).map(|v| v.as_slice()).collect();
        let encoded = enc.encode_all(&parts);
        // deg-3 computation: elementwise cube
        let results: Vec<Vec<u64>> = encoded
            .iter()
            .map(|e| e.iter().map(|&v| f.mul(f.mul(v, v), v)).collect())
            .collect();

        let (betas, alphas) = poly::standard_points(k + t, n);
        let mut cache = DecoderCache::new(f, k, t, deg_f, alphas, betas);
        let subsets: [&[usize]; 4] =
            [&[0, 1, 2, 3, 4, 5, 6], &[3, 4, 5, 6, 7, 8, 9], &[0, 2, 4, 5, 6, 8, 9], &[1, 2, 3, 5, 7, 8, 9]];
        let mut reference: Option<Vec<u64>> = None;
        for members in subsets {
            assert_eq!(members.len(), need);
            let dec = cache.get(members);
            let views: Vec<&[u64]> =
                members.iter().map(|&j| results[j].as_slice()).collect();
            let mut out = vec![0u64; len];
            dec.decode_sum(&views, &mut out);
            match &reference {
                None => reference = Some(out),
                Some(want) => assert_eq!(&out, want, "subset {members:?}"),
            }
        }
        // repeated subsets hit the cache (no rebuild), distinct ones fill it
        assert_eq!(cache.len(), subsets.len());
        let again = cache.get(subsets[0]);
        let views: Vec<&[u64]> = subsets[0].iter().map(|&j| results[j].as_slice()).collect();
        let mut out = vec![0u64; len];
        again.decode_sum(&views, &mut out);
        assert_eq!(Some(out), reference);
        assert_eq!(cache.len(), subsets.len());
    }

    #[test]
    fn decoder_cache_is_bounded() {
        let f = Field::new(P26);
        let (k, t, n) = (1usize, 1usize, 16usize);
        let need = recovery_threshold(1, k, t); // 4
        let (betas, alphas) = poly::standard_points(k + t, n);
        let mut cache = DecoderCache::new(f, k, t, 3, alphas, betas);
        for start in 0..DecoderCache::CAPACITY + 3 {
            let members: Vec<usize> = (start..start + need).map(|j| j % n).collect();
            let mut members = members;
            members.sort_unstable();
            members.dedup();
            if members.len() < need {
                continue;
            }
            cache.get(&members);
            assert!(cache.len() <= DecoderCache::CAPACITY, "cache grew past its bound");
        }
    }

    #[test]
    #[should_panic(expected = "recovery threshold")]
    fn decoder_rejects_too_few_points() {
        let f = Field::new(P26);
        let (betas, alphas) = poly::standard_points(5, 8);
        Decoder::new(f, 3, 2, 3, &alphas[..5], &betas); // need 3·4+1=13
    }
}
