//! Networking: the transport abstraction the MPC protocols run on, plus the
//! WAN cost model used to reproduce the paper's EC2 timing experiments.
//!
//! Three backends implement [`Transport`]:
//!
//! * [`local::Hub`] — threads + in-process mailboxes, *really* moving share
//!   data. Used by the full-fidelity protocol (tests, examples) and to
//!   validate the byte ledger of the simulator.
//! * [`tcp::TcpTransport`] — length-prefixed framed messages over real
//!   `TcpStream`s, one per peer, drained into the same tagged-mailbox
//!   semantics by per-peer reader threads or by one shared poll reactor
//!   ([`Runtime`]). One OS process per party in a real deployment
//!   (`copml party`), or the loopback mesh ([`tcp::loopback_mesh`]) for
//!   tests and demos.
//! * the virtual-clock simulation in [`wan`] + `bench::cost_model` — exact
//!   byte counts charged against a bandwidth/latency model
//!   (paper setup: 40 Mbps WAN between EC2 m3.xlarge instances).
//!
//! Messages carry `Vec<u64>` field elements. The on-wire element encoding
//! is configurable ([`Wire`]): 64-bit words as in the paper's 64-bit MPI
//! implementation, or packed 32-bit words — lossless because every
//! supported modulus satisfies `p < 2^31` — which halves payload bytes
//! (the packing ablation of EXPERIMENTS.md, now a real measurable change
//! on the socket transport). Byte ledgers are therefore wire-format
//! dependent ([`Wire::elem_bytes`]); [`ELEM_BYTES`] is the 64-bit default
//! used by the baselines' accounting.

// Receive paths must name their failure: a bare `unwrap()` in the
// transport layer turns a dead peer or a poisoned mailbox lock into an
// anonymous panic. Denied module-wide as a clippy restriction lint
// (tests exempt); `copml lint`'s recv-unwrap rule enforces the same
// discipline at the source level across the whole protocol tree.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod local;
mod mailbox;
mod reactor;
pub mod tags;
pub mod tcp;
pub mod wan;
pub mod wire;

pub use mailbox::{AnyRecv, TryRecv};
pub use wire::Wire;

use std::time::{Duration, Instant};

/// Party identifier (0-based).
pub type PartyId = usize;

/// How a socket transport drains its peer connections into the mailbox.
///
/// Value-transparent by construction: both runtimes feed the same
/// tagged-mailbox delivery semantics, so the protocol — and every trained
/// `w_trace` — is bit-identical under either (pinned by
/// `tests/protocol_equivalence.rs`). The in-process [`local::Hub`] has no
/// sockets to drain, so the choice is structurally a no-op there.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Runtime {
    /// One blocking reader thread per peer connection — the original
    /// architecture and the bit-identity oracle. A loopback mesh pays
    /// `n(n−1)` reader threads.
    Threaded,
    /// One poll-driven reactor thread over non-blocking sockets for all
    /// connections (a whole loopback mesh shares a single reactor): the
    /// large-N runtime (ROADMAP item 1).
    Event,
}

impl std::fmt::Display for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Runtime::Threaded => "threaded",
            Runtime::Event => "event",
        })
    }
}

impl std::str::FromStr for Runtime {
    type Err = String;

    fn from_str(s: &str) -> Result<Runtime, String> {
        match s {
            "threaded" => Ok(Runtime::Threaded),
            "event" => Ok(Runtime::Event),
            other => Err(format!("unknown runtime '{other}' (expected threaded|event)")),
        }
    }
}

/// Bytes per transmitted field element under the default 64-bit wire
/// format ([`Wire::U64`] — the paper's 64-bit MPI implementation). The
/// packed alternative is [`Wire::U32`].
pub const ELEM_BYTES: u64 = Wire::U64.elem_bytes();

/// A point-to-point, tagged, blocking transport between `n` parties.
///
/// Tags order protocol steps: all parties execute the same SPMD sequence of
/// collectives, each consuming one tag, so a `(from, tag)` pair uniquely
/// identifies a message.
pub trait Transport: Send + Sync {
    fn id(&self) -> PartyId;
    fn n(&self) -> usize;
    /// Asynchronous send of `data` to party `to` under `tag`. Best-effort
    /// towards a dead peer: the failure surfaces on the *receive* side
    /// (the peer's closed mailbox), never as a send panic.
    fn send(&self, to: PartyId, tag: u64, data: Vec<u64>);
    /// Blocking receive of the message from `from` under `tag`.
    fn recv(&self, from: PartyId, tag: u64) -> Vec<u64>;
    /// Blocking receive that reports a dead peer as `Err` (with the
    /// recorded cause) instead of panicking — lets the protocol halt
    /// gracefully when a load-bearing peer is gone.
    fn recv_check(&self, from: PartyId, tag: u64) -> Result<Vec<u64>, String>;
    /// First-arrival receive: the next message under `tag` from *any* of
    /// `froms`, tagged with who sent it. Closed peers are skipped (they
    /// can never deliver); [`AnyRecv::NoneLive`] when every named peer is
    /// gone, [`AnyRecv::TimedOut`] after `timeout`.
    fn recv_any(&self, froms: &[PartyId], tag: u64, timeout: Duration) -> AnyRecv;
    /// Non-blocking receive attempt: the per-round state machines
    /// ([`RoundState`]) poll through this instead of parking a thread per
    /// peer. Same precedence as the blocking pop — queued data is
    /// consumed before a recorded close is reported.
    fn try_recv(&self, from: PartyId, tag: u64) -> TryRecv;
    /// Monotone mailbox event counter: bumped on every delivery, peer
    /// close, and shutdown. Snapshot it *before* a [`RoundState::poll`]
    /// pass; [`Transport::wait_activity`] with that snapshot returns
    /// immediately if anything landed during the pass (no lost wakeup).
    fn activity(&self) -> u64;
    /// Park until the activity counter advances past `since` or `timeout`
    /// elapses. Returns the current counter value (`== since` only on
    /// timeout).
    fn wait_activity(&self, since: u64, timeout: Duration) -> u64;
    /// Discard one `(from, tag)` message: now if delivered (returns
    /// `true`), or on arrival via a one-shot tombstone (returns `false`).
    /// The return value is the straggler signal — `false` means the peer
    /// had not produced the message by the time the protocol moved on.
    fn forget(&self, from: PartyId, tag: u64) -> bool;
    /// Undelivered mailbox state: queued `(from, tag)` entries plus
    /// outstanding forget-tombstones. Zero at the end of a clean run
    /// (mailbox-hygiene tests).
    fn pending_messages(&self) -> usize;
    /// Announce departure mid-protocol (fault-plan kill, straggler
    /// exclusion): peers' blocked receives on this party fail fast with
    /// `reason`, and this party's own mailbox discards future deliveries.
    fn leave(&self, reason: &str);
    /// Total payload bytes this party has sent.
    fn bytes_sent(&self) -> u64;
    /// Total payload bytes this party has received.
    fn bytes_received(&self) -> u64;
    /// The subset of [`Transport::bytes_sent`] carried under the offline
    /// tag stripe ([`tags::OFFLINE`]) — the traffic a pipelined factory
    /// can move off the critical path. The ledger subtracts it from the
    /// online phases' byte deltas so their rows stay exact whether the
    /// offline phase ran inline or overlapped. Transports that do not
    /// track the split report 0.
    fn bytes_sent_offline(&self) -> u64 {
        0
    }
    /// Debug-build `(from, tag)` reuse count observed by this party's
    /// mailbox: deliveries whose key had already been delivered *and
    /// drained* earlier in the run. A clean SPMD run never reuses a key
    /// (see [`tags`]); a nonzero count is the dynamic symptom of tag
    /// divergence on deployments where the in-process
    /// [`tags::SpmdTagTrace`] cannot be shared. Always 0 in release
    /// builds and on transports without a mailbox.
    fn tag_reuse(&self) -> usize {
        0
    }
}

/// Outcome of one non-blocking [`RoundState::poll`] pass.
pub enum Step<T> {
    /// The round completed with this output.
    Ready(T),
    /// Some tag has not arrived yet: park until the next mailbox activity
    /// and poll again.
    Pending,
}

/// One per-round stage of the protocol's iteration loop (await the
/// encoded gradients, await the quorum roster, await a king opening, …)
/// expressed as an explicit state over the message stream: each
/// [`poll`](RoundState::poll) consumes whatever relevant messages are
/// queued and yields [`Step::Pending`] when a tag is not available yet,
/// instead of blocking a thread on it.
///
/// Both runtimes execute the protocol through these states (see
/// [`drive`]), which is what makes `--runtime event` bit-identical to the
/// threaded oracle by construction; the runtime flag only changes who
/// feeds the mailbox (reader threads vs the reactor).
pub trait RoundState {
    type Output;
    /// One non-blocking pass: consume available messages, advance
    /// internal state. `Err` is a protocol-fatal condition (a
    /// load-bearing peer died, an infeasible quorum) with the recorded
    /// cause.
    fn poll(&mut self, net: &dyn Transport) -> Result<Step<Self::Output>, String>;
    /// Short label naming the round, used in timeout diagnostics.
    fn describe(&self) -> String;
}

/// Run a [`RoundState`] to completion: poll, and between polls park on
/// the transport's activity counter. The counter is snapshotted *before*
/// each poll pass, so a delivery that lands mid-pass makes the park
/// return immediately — the classic scan-then-sleep lost-wakeup race
/// cannot occur. Fails (rather than deadlocks) if the state is still
/// pending after the receive timeout.
pub fn drive<S: RoundState>(net: &dyn Transport, mut state: S) -> Result<S::Output, String> {
    let deadline = Instant::now() + mailbox::RECV_TIMEOUT;
    loop {
        let since = net.activity();
        match state.poll(net)? {
            Step::Ready(out) => return Ok(out),
            Step::Pending => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(format!(
                        "{} timed out after {:?} — protocol deadlock",
                        state.describe(),
                        mailbox::RECV_TIMEOUT
                    ));
                }
                net.wait_activity(since, deadline - now);
            }
        }
    }
}

/// Result of [`gather_quorum`]: the first-arrival quorum, sorted by party
/// id, plus the peers that had not delivered when the quorum filled.
pub struct QuorumOutcome {
    /// The quorum member ids, ascending (includes the gatherer).
    pub members: Vec<PartyId>,
    /// Payloads aligned with `members` (the gatherer's own entry included).
    pub payloads: Vec<Vec<u64>>,
    /// Peers in `froms` that were not part of the quorum.
    pub late: Vec<PartyId>,
}

/// Gather the first `need` messages under `tag` across `froms` plus the
/// caller's own contribution `own` — the quorum primitive of the
/// straggler-resilient online phase (paper Theorem 1: any
/// `(2r+1)(K+T−1)+1` results decode). Returns as soon as `need` messages
/// are in hand, naming the members; peers that were late are reported for
/// straggler accounting instead of being waited on. Closed (dead) peers
/// are skipped; if live peers cannot fill the quorum the gather fails
/// with a clear error rather than deadlocking.
pub fn gather_quorum(
    t: &dyn Transport,
    froms: &[PartyId],
    tag: u64,
    need: usize,
    own: Vec<u64>,
) -> Result<QuorumOutcome, String> {
    let me = t.id();
    assert!(
        froms.len() + 1 >= need,
        "quorum of {need} impossible over {} peers + self",
        froms.len()
    );
    let mut got: Vec<(PartyId, Vec<u64>)> = Vec::with_capacity(need);
    got.push((me, own));
    let mut waiting: Vec<PartyId> = froms.to_vec();
    while got.len() < need {
        match t.recv_any(&waiting, tag, mailbox::RECV_TIMEOUT) {
            AnyRecv::Delivered(from, data) => {
                waiting.retain(|&j| j != from);
                got.push((from, data));
            }
            AnyRecv::NoneLive(causes) => {
                return Err(format!(
                    "quorum infeasible: need {need}, have {} — every remaining peer is gone ({causes})",
                    got.len()
                ));
            }
            AnyRecv::TimedOut => {
                return Err(format!(
                    "quorum gather timed out: need {need}, have {} after {:?} (tag {tag})",
                    got.len(),
                    mailbox::RECV_TIMEOUT
                ));
            }
        }
    }
    got.sort_by_key(|(id, _)| *id);
    let (members, payloads): (Vec<PartyId>, Vec<Vec<u64>>) = got.into_iter().unzip();
    Ok(QuorumOutcome { members, payloads, late: waiting })
}

/// Send to every other party (not self).
pub fn broadcast(t: &dyn Transport, tag: u64, data: &[u64]) {
    for peer in 0..t.n() {
        if peer != t.id() {
            t.send(peer, tag, data.to_vec());
        }
    }
}

/// Gather one message from every party (own contribution passed in).
/// Returns `n` vectors indexed by party.
pub fn gather_all(t: &dyn Transport, tag: u64, own: Vec<u64>) -> Vec<Vec<u64>> {
    let me = t.id();
    (0..t.n())
        .map(|peer| {
            if peer == me {
                own.clone()
            } else {
                t.recv(peer, tag)
            }
        })
        .collect()
}
