//! Networking: the transport abstraction the MPC protocols run on, plus the
//! WAN cost model used to reproduce the paper's EC2 timing experiments.
//!
//! Three backends implement [`Transport`]:
//!
//! * [`local::Hub`] — threads + in-process mailboxes, *really* moving share
//!   data. Used by the full-fidelity protocol (tests, examples) and to
//!   validate the byte ledger of the simulator.
//! * [`tcp::TcpTransport`] — length-prefixed framed messages over real
//!   `TcpStream`s, one per peer, with a per-peer reader thread feeding the
//!   same tagged-mailbox semantics. One OS process per party in a real
//!   deployment (`copml party`), or the loopback mesh
//!   ([`tcp::loopback_mesh`]) for tests and demos.
//! * the virtual-clock simulation in [`wan`] + `bench::cost_model` — exact
//!   byte counts charged against a bandwidth/latency model
//!   (paper setup: 40 Mbps WAN between EC2 m3.xlarge instances).
//!
//! Messages carry `Vec<u64>` field elements. The on-wire element encoding
//! is configurable ([`Wire`]): 64-bit words as in the paper's 64-bit MPI
//! implementation, or packed 32-bit words — lossless because every
//! supported modulus satisfies `p < 2^31` — which halves payload bytes
//! (the packing ablation of EXPERIMENTS.md, now a real measurable change
//! on the socket transport). Byte ledgers are therefore wire-format
//! dependent ([`Wire::elem_bytes`]); [`ELEM_BYTES`] is the 64-bit default
//! used by the baselines' accounting.

pub mod local;
mod mailbox;
pub mod tcp;
pub mod wan;
pub mod wire;

pub use wire::Wire;

/// Party identifier (0-based).
pub type PartyId = usize;

/// Bytes per transmitted field element under the default 64-bit wire
/// format ([`Wire::U64`] — the paper's 64-bit MPI implementation). The
/// packed alternative is [`Wire::U32`].
pub const ELEM_BYTES: u64 = Wire::U64.elem_bytes();

/// A point-to-point, tagged, blocking transport between `n` parties.
///
/// Tags order protocol steps: all parties execute the same SPMD sequence of
/// collectives, each consuming one tag, so a `(from, tag)` pair uniquely
/// identifies a message.
pub trait Transport: Send + Sync {
    fn id(&self) -> PartyId;
    fn n(&self) -> usize;
    /// Asynchronous send of `data` to party `to` under `tag`.
    fn send(&self, to: PartyId, tag: u64, data: Vec<u64>);
    /// Blocking receive of the message from `from` under `tag`.
    fn recv(&self, from: PartyId, tag: u64) -> Vec<u64>;
    /// Total payload bytes this party has sent.
    fn bytes_sent(&self) -> u64;
    /// Total payload bytes this party has received.
    fn bytes_received(&self) -> u64;
}

/// Send to every other party (not self).
pub fn broadcast(t: &dyn Transport, tag: u64, data: &[u64]) {
    for peer in 0..t.n() {
        if peer != t.id() {
            t.send(peer, tag, data.to_vec());
        }
    }
}

/// Gather one message from every party (own contribution passed in).
/// Returns `n` vectors indexed by party.
pub fn gather_all(t: &dyn Transport, tag: u64, own: Vec<u64>) -> Vec<Vec<u64>> {
    let me = t.id();
    (0..t.n())
        .map(|peer| {
            if peer == me {
                own.clone()
            } else {
                t.recv(peer, tag)
            }
        })
        .collect()
}
