//! Networking: the transport abstraction the MPC protocols run on, plus the
//! WAN cost model used to reproduce the paper's EC2 timing experiments.
//!
//! Three backends implement [`Transport`]:
//!
//! * [`local::Hub`] — threads + in-process mailboxes, *really* moving share
//!   data. Used by the full-fidelity protocol (tests, examples) and to
//!   validate the byte ledger of the simulator.
//! * [`tcp::TcpTransport`] — length-prefixed framed messages over real
//!   `TcpStream`s, one per peer, with a per-peer reader thread feeding the
//!   same tagged-mailbox semantics. One OS process per party in a real
//!   deployment (`copml party`), or the loopback mesh
//!   ([`tcp::loopback_mesh`]) for tests and demos.
//! * the virtual-clock simulation in [`wan`] + `bench::cost_model` — exact
//!   byte counts charged against a bandwidth/latency model
//!   (paper setup: 40 Mbps WAN between EC2 m3.xlarge instances).
//!
//! Messages carry `Vec<u64>` field elements. The on-wire element encoding
//! is configurable ([`Wire`]): 64-bit words as in the paper's 64-bit MPI
//! implementation, or packed 32-bit words — lossless because every
//! supported modulus satisfies `p < 2^31` — which halves payload bytes
//! (the packing ablation of EXPERIMENTS.md, now a real measurable change
//! on the socket transport). Byte ledgers are therefore wire-format
//! dependent ([`Wire::elem_bytes`]); [`ELEM_BYTES`] is the 64-bit default
//! used by the baselines' accounting.

pub mod local;
mod mailbox;
pub mod tcp;
pub mod wan;
pub mod wire;

pub use mailbox::AnyRecv;
pub use wire::Wire;

use std::time::Duration;

/// Party identifier (0-based).
pub type PartyId = usize;

/// Bytes per transmitted field element under the default 64-bit wire
/// format ([`Wire::U64`] — the paper's 64-bit MPI implementation). The
/// packed alternative is [`Wire::U32`].
pub const ELEM_BYTES: u64 = Wire::U64.elem_bytes();

/// A point-to-point, tagged, blocking transport between `n` parties.
///
/// Tags order protocol steps: all parties execute the same SPMD sequence of
/// collectives, each consuming one tag, so a `(from, tag)` pair uniquely
/// identifies a message.
pub trait Transport: Send + Sync {
    fn id(&self) -> PartyId;
    fn n(&self) -> usize;
    /// Asynchronous send of `data` to party `to` under `tag`. Best-effort
    /// towards a dead peer: the failure surfaces on the *receive* side
    /// (the peer's closed mailbox), never as a send panic.
    fn send(&self, to: PartyId, tag: u64, data: Vec<u64>);
    /// Blocking receive of the message from `from` under `tag`.
    fn recv(&self, from: PartyId, tag: u64) -> Vec<u64>;
    /// Blocking receive that reports a dead peer as `Err` (with the
    /// recorded cause) instead of panicking — lets the protocol halt
    /// gracefully when a load-bearing peer is gone.
    fn recv_check(&self, from: PartyId, tag: u64) -> Result<Vec<u64>, String>;
    /// First-arrival receive: the next message under `tag` from *any* of
    /// `froms`, tagged with who sent it. Closed peers are skipped (they
    /// can never deliver); [`AnyRecv::NoneLive`] when every named peer is
    /// gone, [`AnyRecv::TimedOut`] after `timeout`.
    fn recv_any(&self, froms: &[PartyId], tag: u64, timeout: Duration) -> AnyRecv;
    /// Discard one `(from, tag)` message: now if delivered (returns
    /// `true`), or on arrival via a one-shot tombstone (returns `false`).
    /// The return value is the straggler signal — `false` means the peer
    /// had not produced the message by the time the protocol moved on.
    fn forget(&self, from: PartyId, tag: u64) -> bool;
    /// Undelivered mailbox state: queued `(from, tag)` entries plus
    /// outstanding forget-tombstones. Zero at the end of a clean run
    /// (mailbox-hygiene tests).
    fn pending_messages(&self) -> usize;
    /// Announce departure mid-protocol (fault-plan kill, straggler
    /// exclusion): peers' blocked receives on this party fail fast with
    /// `reason`, and this party's own mailbox discards future deliveries.
    fn leave(&self, reason: &str);
    /// Total payload bytes this party has sent.
    fn bytes_sent(&self) -> u64;
    /// Total payload bytes this party has received.
    fn bytes_received(&self) -> u64;
}

/// Result of [`gather_quorum`]: the first-arrival quorum, sorted by party
/// id, plus the peers that had not delivered when the quorum filled.
pub struct QuorumOutcome {
    /// The quorum member ids, ascending (includes the gatherer).
    pub members: Vec<PartyId>,
    /// Payloads aligned with `members` (the gatherer's own entry included).
    pub payloads: Vec<Vec<u64>>,
    /// Peers in `froms` that were not part of the quorum.
    pub late: Vec<PartyId>,
}

/// Gather the first `need` messages under `tag` across `froms` plus the
/// caller's own contribution `own` — the quorum primitive of the
/// straggler-resilient online phase (paper Theorem 1: any
/// `(2r+1)(K+T−1)+1` results decode). Returns as soon as `need` messages
/// are in hand, naming the members; peers that were late are reported for
/// straggler accounting instead of being waited on. Closed (dead) peers
/// are skipped; if live peers cannot fill the quorum the gather fails
/// with a clear error rather than deadlocking.
pub fn gather_quorum(
    t: &dyn Transport,
    froms: &[PartyId],
    tag: u64,
    need: usize,
    own: Vec<u64>,
) -> Result<QuorumOutcome, String> {
    let me = t.id();
    assert!(
        froms.len() + 1 >= need,
        "quorum of {need} impossible over {} peers + self",
        froms.len()
    );
    let mut got: Vec<(PartyId, Vec<u64>)> = Vec::with_capacity(need);
    got.push((me, own));
    let mut waiting: Vec<PartyId> = froms.to_vec();
    while got.len() < need {
        match t.recv_any(&waiting, tag, mailbox::RECV_TIMEOUT) {
            AnyRecv::Delivered(from, data) => {
                waiting.retain(|&j| j != from);
                got.push((from, data));
            }
            AnyRecv::NoneLive(causes) => {
                return Err(format!(
                    "quorum infeasible: need {need}, have {} — every remaining peer is gone ({causes})",
                    got.len()
                ));
            }
            AnyRecv::TimedOut => {
                return Err(format!(
                    "quorum gather timed out: need {need}, have {} after {:?} (tag {tag})",
                    got.len(),
                    mailbox::RECV_TIMEOUT
                ));
            }
        }
    }
    got.sort_by_key(|(id, _)| *id);
    let (members, payloads): (Vec<PartyId>, Vec<Vec<u64>>) = got.into_iter().unzip();
    Ok(QuorumOutcome { members, payloads, late: waiting })
}

/// Send to every other party (not self).
pub fn broadcast(t: &dyn Transport, tag: u64, data: &[u64]) {
    for peer in 0..t.n() {
        if peer != t.id() {
            t.send(peer, tag, data.to_vec());
        }
    }
}

/// Gather one message from every party (own contribution passed in).
/// Returns `n` vectors indexed by party.
pub fn gather_all(t: &dyn Transport, tag: u64, own: Vec<u64>) -> Vec<Vec<u64>> {
    let me = t.id();
    (0..t.n())
        .map(|peer| {
            if peer == me {
                own.clone()
            } else {
                t.recv(peer, tag)
            }
        })
        .collect()
}
