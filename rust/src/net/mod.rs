//! Networking: the transport abstraction the MPC protocols run on, plus the
//! WAN cost model used to reproduce the paper's EC2 timing experiments.
//!
//! Two backends implement [`Transport`]:
//!
//! * [`local::Hub`] — threads + channels, *really* moving share data.
//!   Used by the full-fidelity protocol (tests, examples) and to validate
//!   the byte ledger of the simulator.
//! * the virtual-clock simulation in [`wan`] + `bench::cost_model` — exact
//!   byte counts charged against a bandwidth/latency model
//!   (paper setup: 40 Mbps WAN between EC2 m3.xlarge instances).
//!
//! Messages carry `Vec<u64>` field elements. On the wire the paper's MPI
//! implementation moves 64-bit words; [`ELEM_BYTES`] makes that explicit
//! (an ablation in `bench/` explores 32-bit packing, since `p < 2^32`).

pub mod local;
pub mod wan;

/// Party identifier (0-based).
pub type PartyId = usize;

/// Bytes per transmitted field element (64-bit words, as in the paper's
/// 64-bit MPI implementation).
pub const ELEM_BYTES: u64 = 8;

/// A point-to-point, tagged, blocking transport between `n` parties.
///
/// Tags order protocol steps: all parties execute the same SPMD sequence of
/// collectives, each consuming one tag, so a `(from, tag)` pair uniquely
/// identifies a message.
pub trait Transport: Send + Sync {
    fn id(&self) -> PartyId;
    fn n(&self) -> usize;
    /// Asynchronous send of `data` to party `to` under `tag`.
    fn send(&self, to: PartyId, tag: u64, data: Vec<u64>);
    /// Blocking receive of the message from `from` under `tag`.
    fn recv(&self, from: PartyId, tag: u64) -> Vec<u64>;
    /// Total payload bytes this party has sent.
    fn bytes_sent(&self) -> u64;
    /// Total payload bytes this party has received.
    fn bytes_received(&self) -> u64;
}

/// Send to every other party (not self).
pub fn broadcast(t: &dyn Transport, tag: u64, data: &[u64]) {
    for peer in 0..t.n() {
        if peer != t.id() {
            t.send(peer, tag, data.to_vec());
        }
    }
}

/// Gather one message from every party (own contribution passed in).
/// Returns `n` vectors indexed by party.
pub fn gather_all(t: &dyn Transport, tag: u64, own: Vec<u64>) -> Vec<Vec<u64>> {
    let me = t.id();
    (0..t.n())
        .map(|peer| {
            if peer == me {
                own.clone()
            } else {
                t.recv(peer, tag)
            }
        })
        .collect()
}
