//! Poll-driven socket reactor: the event runtime's replacement for the
//! per-peer reader threads of [`crate::net::tcp`].
//!
//! One OS thread multiplexes every registered connection through a
//! hand-rolled `poll(2)` readiness loop (no async runtime, no extra
//! crates): sockets are switched to non-blocking mode, readable bytes are
//! accumulated per connection, and complete length-prefixed frames
//! ([`crate::net::wire`]) are decoded incrementally and pushed into the
//! owning party's [`TagMailbox`] — the same tagged delivery surface the
//! reader threads feed, so everything above the mailbox (blocking `recv`,
//! quorum gathers, the per-round state machines of
//! [`crate::coordinator::rounds`]) is runtime-agnostic.
//!
//! Failure handling mirrors the reader threads byte for byte: EOF records
//! `connection closed` / `connection died mid-frame` (depending on
//! whether a frame was in flight), an oversized length prefix records the
//! `corrupt frame: oversized payload` cause *without* allocating, a
//! payload that does not decode records `corrupt frame: …`, and a
//! [`DEPART_TAG`] control frame records the peer's own halt reason — so
//! blocked rounds fail fast with identical causes under either runtime
//! (the replayed fault-path tests in `net::tcp` pin this).
//!
//! A `UnixStream` self-wake pair interrupts a parked `poll` for dynamic
//! registration and shutdown. The reactor thread exits when the last
//! owning transport drops its [`Reactor`] handle.

// The crate forbids unsafe code everywhere else (`lib.rs`); this module
// is the one allow-listed exception — the two `poll(2)` FFI call sites
// below — and `copml lint`'s unsafe audit pins exactly that.
#![allow(unsafe_code)]

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use super::mailbox::TagMailbox;
use super::tcp::{words_to_reason, DEPART_TAG, MAX_FRAME_BYTES};
use super::wire::{self, Wire, HEADER_BYTES};
use super::PartyId;

// `struct pollfd` and the event bits from `<poll.h>`, declared by hand so
// the reactor needs no extra crate: std already links libc on every unix
// target. `nfds_t` is `unsigned long` on Linux (the platform this crate
// targets and CI runs on).
#[repr(C)]
struct PollFd {
    fd: RawFd,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
}

/// Block until `fd` is writable. The event runtime's sockets are
/// non-blocking (the reader half shares the open file description with
/// the writer half via `try_clone`, so `O_NONBLOCK` applies to both), and
/// a full socket buffer turns `write` into `WouldBlock` — this is the
/// wait that turns the non-blocking writer back into the blocking
/// `write_all` semantics the send path expects. Error/hangup readiness
/// returns `Ok` too: the caller's next write surfaces the actual error
/// (sends are best-effort towards dead peers).
pub(crate) fn wait_writable(fd: RawFd) -> io::Result<()> {
    loop {
        let mut pfd = PollFd { fd, events: POLLOUT, revents: 0 };
        // SAFETY: `pfd` is a live, exclusively-borrowed PollFd matching
        // the kernel's `struct pollfd` layout (#[repr(C)] above), nfds=1
        // covers exactly that one element, and poll(2) writes only the
        // `revents` field within it.
        let rc = unsafe { poll(&mut pfd, 1, -1) };
        if rc < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return Err(e);
        }
        if pfd.revents & (POLLOUT | POLLERR | POLLHUP | POLLNVAL) != 0 {
            return Ok(());
        }
    }
}

/// One registered connection: a non-blocking read half plus the
/// incremental frame-decode state ferrying its bytes into the owning
/// party's mailbox.
struct Conn {
    stream: TcpStream,
    /// Peer id the frames come from.
    from: PartyId,
    wire: Wire,
    mailbox: Arc<TagMailbox>,
    /// The owning transport's received-bytes ledger.
    received: Arc<AtomicU64>,
    /// Bytes read but not yet assembled into a complete frame.
    buf: Vec<u8>,
}

impl Conn {
    /// Drain everything readable right now and deliver the complete
    /// frames. Returns `false` when the stream ended (EOF, error, corrupt
    /// frame, departure notice) — the cause is recorded on the mailbox
    /// and the connection is dropped from the loop.
    fn service(&mut self) -> bool {
        let mut scratch = [0u8; 64 * 1024];
        loop {
            match self.stream.read(&mut scratch) {
                Ok(0) => {
                    // EOF. Same causes the reader threads record: a death
                    // between frames is an orderly close, a death with a
                    // frame in flight truncated it.
                    let cause = if self.buf.is_empty() {
                        "connection closed: end of stream".to_string()
                    } else {
                        "connection died mid-frame: end of stream".to_string()
                    };
                    self.mailbox.close(self.from, cause);
                    return false;
                }
                Ok(k) => {
                    self.buf.extend_from_slice(&scratch[..k]);
                    if !self.deliver_frames() {
                        return false;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    let cause = if self.buf.is_empty() {
                        format!("connection closed: {e}")
                    } else {
                        format!("connection died mid-frame: {e}")
                    };
                    self.mailbox.close(self.from, cause);
                    return false;
                }
            }
        }
    }

    /// Decode and deliver every complete frame in `buf`, leaving any
    /// partial tail for the next readiness event. Returns `false` on a
    /// terminal frame (corrupt or departure) with the cause recorded.
    fn deliver_frames(&mut self) -> bool {
        let mut consumed = 0usize;
        loop {
            let avail = self.buf.len() - consumed;
            if avail < HEADER_BYTES {
                break;
            }
            let header: [u8; HEADER_BYTES] = self.buf[consumed..consumed + HEADER_BYTES]
                .try_into()
                .expect("HEADER_BYTES-long slice into a HEADER_BYTES array");
            let (payload_len, tag) = wire::decode_header(&header);
            if payload_len > MAX_FRAME_BYTES {
                // Reject by the cap before reserving a single byte — same
                // guard as the reader threads.
                self.mailbox.close(
                    self.from,
                    format!(
                        "corrupt frame: oversized payload ({payload_len} B > {MAX_FRAME_BYTES} B cap)"
                    ),
                );
                return false;
            }
            let total = HEADER_BYTES + payload_len as usize;
            if avail < total {
                break; // partial frame: wait for more bytes
            }
            let payload = &self.buf[consumed + HEADER_BYTES..consumed + total];
            match wire::decode_payload(self.wire, payload) {
                Ok(data) => {
                    if tag == DEPART_TAG {
                        // Control frame, not ledgered: the peer announces
                        // its own departure with the real halt reason.
                        self.mailbox
                            .close(self.from, format!("peer left: {}", words_to_reason(&data)));
                        return false;
                    }
                    // Ledger only deliveries the mailbox accepted (frames
                    // landing after this party left are discarded unseen).
                    if self.mailbox.push(self.from, tag, data) {
                        self.received.fetch_add(payload_len as u64, Ordering::Relaxed);
                    }
                }
                Err(e) => {
                    self.mailbox.close(self.from, format!("corrupt frame: {e}"));
                    return false;
                }
            }
            consumed += total;
        }
        self.buf.drain(..consumed);
        true
    }
}

struct Shared {
    /// Write end of the self-wake pair: one byte unparks `poll`.
    wake_tx: UnixStream,
    /// Connections registered since the last loop pass.
    pending: Mutex<Vec<Conn>>,
    shutdown: AtomicBool,
}

/// Handle to one reactor thread. Clone-shared (via `Arc`) by every
/// transport it serves — a loopback mesh runs its whole `N`-party socket
/// fabric on a single reactor. Dropping the last handle shuts the thread
/// down and joins it.
pub(crate) struct Reactor {
    shared: Arc<Shared>,
    thread: Option<JoinHandle<()>>,
}

impl Reactor {
    /// Start the reactor thread (named `copml-reactor` in thread listings,
    /// so the bench's thread accounting can point at it).
    pub(crate) fn spawn() -> io::Result<Reactor> {
        let (wake_tx, wake_rx) = UnixStream::pair()?;
        wake_rx.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            wake_tx,
            pending: Mutex::new(Vec::new()),
            shutdown: AtomicBool::new(false),
        });
        let shared2 = shared.clone();
        let thread = std::thread::Builder::new()
            .name("copml-reactor".into())
            .spawn(move || event_loop(&shared2, &wake_rx))?;
        Ok(Reactor { shared, thread: Some(thread) })
    }

    /// Hand a connection's read half to the reactor: frames from `from`
    /// flow into `mailbox`, accepted payload bytes into `received`.
    /// Switches the stream non-blocking (which, via the shared file
    /// description, also makes the transport's write half non-blocking —
    /// see [`wait_writable`]).
    pub(crate) fn register(
        &self,
        stream: TcpStream,
        from: PartyId,
        wire: Wire,
        mailbox: Arc<TagMailbox>,
        received: Arc<AtomicU64>,
    ) -> io::Result<()> {
        stream.set_nonblocking(true)?;
        self.shared
            .pending
            .lock()
            .expect("reactor registration lock poisoned")
            .push(Conn { stream, from, wire, mailbox, received, buf: Vec::new() });
        self.wake();
        Ok(())
    }

    fn wake(&self) {
        let _ = (&self.shared.wake_tx).write(&[1]);
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.wake();
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }
}

fn event_loop(shared: &Shared, wake_rx: &UnixStream) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut fds: Vec<PollFd> = Vec::new();
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        {
            let mut pending = shared.pending.lock().expect("reactor registration lock poisoned");
            conns.append(&mut pending);
        }
        // fds[0] is the wake pipe; fds[i + 1] tracks conns[i].
        fds.clear();
        fds.push(PollFd { fd: wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
        for c in &conns {
            fds.push(PollFd { fd: c.stream.as_raw_fd(), events: POLLIN, revents: 0 });
        }
        // SAFETY: `fds` is a live Vec of #[repr(C)] PollFd whose length
        // is passed as nfds, so the kernel reads/writes only within the
        // allocation; `fds` is not touched again until poll returns.
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, -1) };
        if rc < 0 {
            if io::Error::last_os_error().kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return; // poll itself failed: no recovery that isn't a spin
        }
        if fds[0].revents != 0 {
            drain_wake(wake_rx);
        }
        // Service every connection with readiness (including error/hangup
        // states — `service` turns those into recorded close causes) and
        // drop the ones whose stream ended.
        let mut keep = Vec::with_capacity(conns.len());
        for (i, mut c) in conns.drain(..).enumerate() {
            if fds[i + 1].revents == 0 || c.service() {
                keep.push(c);
            }
        }
        conns = keep;
    }
}

/// Swallow whatever wake bytes have accumulated.
fn drain_wake(wake_rx: &UnixStream) {
    let mut buf = [0u8; 64];
    loop {
        match (&*wake_rx).read(&mut buf) {
            Ok(0) => return,
            Ok(_) => {}
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return, // WouldBlock: drained
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;
    use std::time::Duration;

    /// A raw loopback TCP pair: (write end, read end registered later).
    fn tcp_pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let tx = TcpStream::connect(addr).unwrap();
        let (rx, _) = l.accept().unwrap();
        (tx, rx)
    }

    #[test]
    fn frames_split_across_arbitrary_write_boundaries() {
        // The incremental decoder must reassemble frames no matter how
        // the byte stream is chopped — single bytes, header/payload
        // splits, two frames in one burst.
        let reactor = Reactor::spawn().unwrap();
        let (mut tx, rx) = tcp_pair();
        let mailbox = Arc::new(TagMailbox::default());
        let received = Arc::new(AtomicU64::new(0));
        reactor.register(rx, 1, Wire::U64, mailbox.clone(), received.clone()).unwrap();

        // Frame 1 dribbled one byte at a time.
        let f1 = wire::encode_frame(Wire::U64, 7, &[10, 20, 30]);
        for b in &f1 {
            tx.write_all(std::slice::from_ref(b)).unwrap();
            tx.flush().unwrap();
        }
        assert_eq!(mailbox.pop_blocking(0, 1, 7), vec![10, 20, 30]);

        // Frames 2+3 in a single burst, plus the header of frame 4.
        let f2 = wire::encode_frame(Wire::U64, 8, &[1]);
        let f3 = wire::encode_frame(Wire::U64, 9, &[2, 3]);
        let f4 = wire::encode_frame(Wire::U64, 10, &[4]);
        let mut burst = Vec::new();
        burst.extend_from_slice(&f2);
        burst.extend_from_slice(&f3);
        burst.extend_from_slice(&f4[..HEADER_BYTES]);
        tx.write_all(&burst).unwrap();
        assert_eq!(mailbox.pop_blocking(0, 1, 8), vec![1]);
        assert_eq!(mailbox.pop_blocking(0, 1, 9), vec![2, 3]);
        // ... and frame 4 completes later.
        tx.write_all(&f4[HEADER_BYTES..]).unwrap();
        assert_eq!(mailbox.pop_blocking(0, 1, 10), vec![4]);
        assert_eq!(received.load(Ordering::Relaxed), 7 * 8, "7 u64 payload words ledgered");
        assert_eq!(mailbox.pending_entries(), 0);
    }

    #[test]
    fn one_reactor_serves_many_connections() {
        let reactor = Reactor::spawn().unwrap();
        let mailbox = Arc::new(TagMailbox::default());
        let received = Arc::new(AtomicU64::new(0));
        let mut txs = Vec::new();
        for from in 1..=4usize {
            let (tx, rx) = tcp_pair();
            reactor
                .register(rx, from, Wire::U32, mailbox.clone(), received.clone())
                .unwrap();
            txs.push((from, tx));
        }
        for (from, tx) in &mut txs {
            let frame = wire::encode_frame(Wire::U32, 5, &[*from as u64]);
            tx.write_all(&frame).unwrap();
        }
        for (from, _) in &txs {
            assert_eq!(mailbox.pop_blocking(0, *from, 5), vec![*from as u64]);
        }
    }

    #[test]
    fn eof_closes_with_recorded_cause_and_drop_joins() {
        let reactor = Reactor::spawn().unwrap();
        let (tx, rx) = tcp_pair();
        let mailbox = Arc::new(TagMailbox::default());
        reactor
            .register(rx, 2, Wire::U64, mailbox.clone(), Arc::new(AtomicU64::new(0)))
            .unwrap();
        drop(tx); // peer dies between frames
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            match mailbox.try_pop(2, 0) {
                super::super::mailbox::TryRecv::Closed(cause) => {
                    assert!(cause.contains("connection closed"), "{cause}");
                    break;
                }
                _ if std::time::Instant::now() > deadline => panic!("close never recorded"),
                _ => std::thread::sleep(Duration::from_millis(5)),
            }
        }
        drop(reactor); // must join the thread, not leak or hang
    }
}
