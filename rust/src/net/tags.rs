//! The typed protocol tag-space: every message tag the protocol ever
//! puts on the wire is allocated out of a named, disjoint-by-construction
//! window declared here.
//!
//! ## Why a declared space
//!
//! COPML is SPMD: all parties execute the same sequence of collectives,
//! each consuming one tag, so a `(from, tag)` pair uniquely identifies a
//! message. The invariant that makes this sound — *every party allocates
//! tags in exactly the same order* — used to live implicitly in two bare
//! counters (`Party::fresh_tag` counting up from 0, the offline
//! `Session` counting up from `1 << 62`). A divergence (one party takes
//! a branch that allocates, another does not) produced either a silent
//! garbage decode or a 120 s receive timeout with no hint of *which*
//! allocation diverged. This module makes the space explicit:
//!
//! * named [`TagRange`] windows, disjoint by `const` assertion;
//! * a cursor allocator ([`TagAlloc`]) that panics on window exhaustion
//!   instead of silently bleeding into a neighbouring range;
//! * a debug-only cross-party fingerprint ([`SpmdTagTrace`]) that
//!   compares every party's allocation sequence and names the **first
//!   divergent allocation** the moment it happens.
//!
//! ## Range map
//!
//! | window            | range                       | stride | used for |
//! |-------------------|-----------------------------|--------|----------|
//! | [`SETUP`]         | `[0, 2^16)`                 | —      | dataset share-out, initial-model degree reduction |
//! | [`ENCODE`]        | `[2^16, 2^24)`              | [`ENCODE_STRIDE`] per batch | per-batch LCC encode exchange ([`encode_window`]) |
//! | [`FINAL`]         | `[2^24, 2^24 + 16)`         | —      | final model opening |
//! | [`ROUND`]         | `[2^32, 2^56)`              | [`ROUND_STRIDE`] per iteration | per-iteration gradient round ([`round_window`]) |
//! | [`SESSIONS`]      | `[2^56, 2^62)`              | [`SESSION_STRIDE`] per session | online stripes of serve sessions ≥ 1 ([`session_setup`] …) |
//! | [`OFFLINE`]       | `[2^62, 2^64 − 1)`          | [`SESSION_STRIDE`] per session | DN07 distributed offline phase ([`session_offline`]; runs first) |
//! | [`DEPART`]        | `2^64 − 1` (single tag)     | —      | transport-level departure control frame |
//! | [`FLAT`]          | `[0, 2^62)` (union view)    | —      | default window of a fresh [`Party`]: baselines and unit tests that never seek |
//!
//! The gap `[2^24 + 16, 2^32)` is deliberately unassigned headroom.
//! [`FLAT`] overlaps the online windows by design — it is the legacy
//! "count from zero" view used by code that never calls
//! [`Party::seek_tags`]; the full protocol always seeks into the named
//! windows, and the two styles are never mixed within one run.
//!
//! ## The SESSION dimension
//!
//! `copml serve` multiplexes a *stream of training jobs* over one held-open
//! mesh, each job under its own session id `s`. Session 0 is, tag for tag,
//! the legacy single-job layout above — a session-0 run is bit-identical
//! on the wire to a pre-session run. Sessions `s ≥ 1` get a
//! [`SESSION_STRIDE`]-wide online stripe carved from [`SESSIONS`]
//! (mirroring the legacy sub-window offsets within the stripe) and a
//! [`SESSION_STRIDE`]-wide offline stripe inside [`OFFLINE`], so job
//! `j+1`'s background pool generation can overlap job `j`'s online rounds
//! on the same transport without a single tag collision.
//!
//! Tag *values* never enter payloads or byte ledgers (ledgers count
//! payload bytes only), so re-homing an allocation site into a different
//! window cannot change a trained `w_trace` — pinned by the
//! `protocol_equivalence` suite.
//!
//! [`Party`]: crate::mpc::Party
//! [`Party::seek_tags`]: crate::mpc::Party::seek_tags

use std::sync::{Arc, Mutex};

use super::PartyId;

/// A protocol message tag. Alias of the wire representation; the typed
/// structure lives in the [`TagRange`] windows, not in the scalar.
pub type Tag = u64;

/// A named, half-open window `[start, end)` of the tag space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TagRange {
    /// Window name, used in exhaustion panics and divergence diagnostics.
    pub name: &'static str,
    /// First tag of the window (inclusive).
    pub start: Tag,
    /// One past the last tag of the window (exclusive).
    pub end: Tag,
}

impl TagRange {
    /// Number of tags in the window.
    pub const fn capacity(&self) -> u64 {
        self.end - self.start
    }

    /// Whether `t` falls inside the window.
    pub const fn contains(&self, t: Tag) -> bool {
        self.start <= t && t < self.end
    }
}

/// One-time setup collectives: dataset share-out and the initial-model
/// degree reduction. A handful of tags used; 2^16 reserved.
pub const SETUP: TagRange = TagRange { name: "setup", start: 0, end: 1 << 16 };

/// Per-batch LCC encode exchange. Each mini-batch `b` gets the
/// [`ENCODE_STRIDE`]-wide sub-window [`encode_window`]`(b)`.
pub const ENCODE: TagRange = TagRange { name: "encode", start: 1 << 16, end: 1 << 24 };

/// Tags reserved per mini-batch inside [`ENCODE`] (the encode exchange
/// uses 1 today; the stride leaves headroom for richer encode rounds).
pub const ENCODE_STRIDE: u64 = 4;

/// Final model opening, after the iteration loop.
pub const FINAL: TagRange = TagRange { name: "final", start: 1 << 24, end: (1 << 24) + 16 };

/// Per-iteration gradient rounds. Each iteration `i` gets the
/// [`ROUND_STRIDE`]-wide sub-window [`round_window`]`(i)`.
pub const ROUND: TagRange = TagRange { name: "round", start: 1 << 32, end: 1 << 56 };

/// Tags reserved per iteration inside [`ROUND`]: today's protocol uses 7
/// (encoded-model exchange, result gather, quorum roster, two king
/// openings of two truncations); 16 leaves headroom.
pub const ROUND_STRIDE: u64 = 16;

/// Online stripes of serve sessions `s ≥ 1`: session `s` owns the
/// [`SESSION_STRIDE`]-wide stripe starting at
/// `SESSIONS.start + (s−1)·SESSION_STRIDE`, with the legacy sub-window
/// offsets (setup/encode/final/round) mirrored inside the stripe.
/// Session 0 uses the legacy windows above directly.
pub const SESSIONS: TagRange = TagRange { name: "sessions", start: 1 << 56, end: 1 << 62 };

/// Tag-space width of one serve session: its online stripe inside
/// [`SESSIONS`] (sessions ≥ 1) and its offline stripe inside [`OFFLINE`]
/// (every session) are each this wide.
pub const SESSION_STRIDE: u64 = 1 << 40;

/// The DN07 distributed offline phase, which runs *first* over the same
/// transport. Kept at the historical `1 << 62` base so the offline phase
/// can never collide with any online window below it. Session `s` of a
/// serve run allocates from the [`session_offline`]`(s)` stripe; session
/// 0's stripe starts exactly at the historical base, so single-job runs
/// are unchanged.
pub const OFFLINE: TagRange = TagRange { name: "offline", start: 1 << 62, end: u64::MAX };

/// The transport-level departure control frame (`net::tcp::DEPART_TAG`):
/// the one tag that is *not* a protocol step, reserved above every
/// window (note [`OFFLINE`] is half-open and excludes it).
pub const DEPART: Tag = u64::MAX;

/// The whole pre-offline space as one flat window: the default window of
/// a fresh `Party`, allocating from 0 exactly like the legacy counter.
/// Baselines and unit tests run entirely inside it; the full protocol
/// re-seeks into the named windows above and never mixes the two styles
/// in one run.
pub const FLAT: TagRange = TagRange { name: "flat", start: 0, end: OFFLINE.start };

const fn disjoint(a: &TagRange, b: &TagRange) -> bool {
    a.end <= b.start || b.end <= a.start
}

// The named windows are pairwise disjoint, DEPART sits outside all of
// them, and FLAT (the legacy union view) covers exactly the pre-offline
// space — checked at compile time, so a window edit that introduces an
// overlap is a build error, not a runtime cross-wire.
const _: () = {
    assert!(disjoint(&SETUP, &ENCODE));
    assert!(disjoint(&SETUP, &FINAL));
    assert!(disjoint(&SETUP, &ROUND));
    assert!(disjoint(&SETUP, &OFFLINE));
    assert!(disjoint(&ENCODE, &FINAL));
    assert!(disjoint(&ENCODE, &ROUND));
    assert!(disjoint(&ENCODE, &OFFLINE));
    assert!(disjoint(&FINAL, &ROUND));
    assert!(disjoint(&FINAL, &OFFLINE));
    assert!(disjoint(&ROUND, &OFFLINE));
    assert!(disjoint(&SESSIONS, &SETUP));
    assert!(disjoint(&SESSIONS, &ENCODE));
    assert!(disjoint(&SESSIONS, &FINAL));
    assert!(disjoint(&SESSIONS, &ROUND));
    assert!(disjoint(&SESSIONS, &OFFLINE));
    assert!(!SETUP.contains(DEPART));
    assert!(!ENCODE.contains(DEPART));
    assert!(!FINAL.contains(DEPART));
    assert!(!ROUND.contains(DEPART));
    assert!(!SESSIONS.contains(DEPART));
    assert!(!OFFLINE.contains(DEPART));
    assert!(FLAT.start == 0 && FLAT.end == OFFLINE.start);
    assert!(SETUP.capacity() >= 16);
    assert!(FINAL.capacity() >= 1);
    // Session geometry: the legacy sub-window offsets must fit inside one
    // stripe, and the OFFLINE region must hold an offline stripe for every
    // session the online SESSIONS region can hold.
    assert!(SESSIONS.capacity() % SESSION_STRIDE == 0);
    assert!((1 << 32) < SESSION_STRIDE); // the round sub-offset fits in a stripe
    assert!(OFFLINE.capacity() / SESSION_STRIDE >= 1 + SESSIONS.capacity() / SESSION_STRIDE);
};

/// Most mini-batches the [`ENCODE`] window can hold.
pub const fn max_batches() -> u64 {
    ENCODE.capacity() / ENCODE_STRIDE
}

/// Most SGD iterations the [`ROUND`] window can hold.
pub const fn max_iters() -> u64 {
    ROUND.capacity() / ROUND_STRIDE
}

/// The [`ENCODE_STRIDE`]-wide sub-window of mini-batch `batch`.
/// Panics past [`max_batches`] (the coordinator's `validate` rejects such
/// configs up front with a friendlier error).
pub fn encode_window(batch: usize) -> TagRange {
    let b = batch as u64;
    assert!(b < max_batches(), "batch {batch} exceeds the ENCODE tag window ({} batches max)", max_batches());
    let start = ENCODE.start + b * ENCODE_STRIDE;
    TagRange { name: "encode", start, end: start + ENCODE_STRIDE }
}

/// The [`ROUND_STRIDE`]-wide sub-window of SGD iteration `iter`.
/// Panics past [`max_iters`] (the coordinator's `validate` rejects such
/// configs up front with a friendlier error).
pub fn round_window(iter: usize) -> TagRange {
    let i = iter as u64;
    assert!(i < max_iters(), "iteration {iter} exceeds the ROUND tag window ({} iterations max)", max_iters());
    let start = ROUND.start + i * ROUND_STRIDE;
    TagRange { name: "round", start, end: start + ROUND_STRIDE }
}

/// Most serve sessions the tag space can hold: session 0 (the legacy
/// windows) plus one [`SESSIONS`] stripe per session ≥ 1.
pub const fn max_sessions() -> u64 {
    1 + SESSIONS.capacity() / SESSION_STRIDE
}

/// Base tag of session `s`'s online stripe (`s ≥ 1` only — session 0
/// lives in the legacy windows, which have no common base).
fn session_base(session: u64) -> Tag {
    assert!(
        1 <= session && session < max_sessions(),
        "session {session} outside the SESSIONS stripe region ({} sessions max)",
        max_sessions()
    );
    SESSIONS.start + (session - 1) * SESSION_STRIDE
}

/// Session `s`'s setup window: the legacy [`SETUP`] at `s = 0`, the
/// stripe-local mirror otherwise.
pub fn session_setup(session: u64) -> TagRange {
    if session == 0 {
        return SETUP;
    }
    let base = session_base(session);
    TagRange { name: "setup", start: base + SETUP.start, end: base + SETUP.end }
}

/// Session `s`'s encode window for mini-batch `batch` (legacy
/// [`encode_window`] at `s = 0`). Every session holds [`max_batches`]
/// batches — the stripe mirrors the full legacy ENCODE region.
pub fn session_encode_window(session: u64, batch: usize) -> TagRange {
    let w = encode_window(batch);
    if session == 0 {
        return w;
    }
    let base = session_base(session);
    TagRange { name: "encode", start: base + w.start, end: base + w.end }
}

/// Session `s`'s final-opening window (legacy [`FINAL`] at `s = 0`).
pub fn session_final(session: u64) -> TagRange {
    if session == 0 {
        return FINAL;
    }
    let base = session_base(session);
    TagRange { name: "final", start: base + FINAL.start, end: base + FINAL.end }
}

/// Most SGD iterations one session-stripe round region holds (sessions
/// ≥ 1; session 0 has the larger legacy [`max_iters`] budget).
pub const fn max_session_iters() -> u64 {
    (SESSION_STRIDE - ROUND.start) / ROUND_STRIDE
}

/// Session `s`'s round window for iteration `iter` (legacy
/// [`round_window`] at `s = 0`). The stripe's round region spans
/// `[base + 2^32, base + SESSION_STRIDE)`.
pub fn session_round_window(session: u64, iter: usize) -> TagRange {
    if session == 0 {
        return round_window(iter);
    }
    let base = session_base(session);
    let i = iter as u64;
    assert!(
        i < max_session_iters(),
        "iteration {iter} exceeds session {session}'s ROUND stripe ({} iterations max)",
        max_session_iters()
    );
    let start = base + ROUND.start + i * ROUND_STRIDE;
    TagRange { name: "round", start, end: start + ROUND_STRIDE }
}

/// Session `s`'s offline stripe inside [`OFFLINE`]. Session 0's stripe
/// starts at the historical `1 << 62` base, so pre-session offline tag
/// sequences are reproduced exactly.
pub fn session_offline(session: u64) -> TagRange {
    assert!(
        session < max_sessions(),
        "session {session} outside the OFFLINE stripe region ({} sessions max)",
        max_sessions()
    );
    let start = OFFLINE.start + session * SESSION_STRIDE;
    TagRange { name: "offline", start, end: start + SESSION_STRIDE }
}

/// Cursor allocator over one [`TagRange`] window at a time.
///
/// This is the *only* place protocol code obtains tags: `fresh` hands out
/// the window's tags in order and panics with the window name on
/// exhaustion — the static growth bound that keeps long-running sessions
/// from bleeding into the `1 << 62` offline range. With a
/// [`SpmdTagTrace`] attached (debug builds), every allocation is also
/// cross-checked against the other parties' sequences.
#[derive(Debug)]
pub struct TagAlloc {
    party: PartyId,
    window: TagRange,
    cursor: Tag,
    trace: Option<Arc<SpmdTagTrace>>,
}

impl TagAlloc {
    /// Allocator for `party`, positioned at the start of `window`.
    pub fn new(party: PartyId, window: TagRange) -> TagAlloc {
        TagAlloc { party, window, cursor: window.start, trace: None }
    }

    /// Jump to the start of `window` (e.g. the per-iteration
    /// [`round_window`]). Seeks are themselves SPMD steps: every party
    /// must seek at the same point of the protocol.
    pub fn seek(&mut self, window: TagRange) {
        self.window = window;
        self.cursor = window.start;
    }

    /// Attach the cross-party fingerprint; every subsequent allocation
    /// is recorded and compared (see [`SpmdTagTrace`]).
    pub fn attach_trace(&mut self, trace: Arc<SpmdTagTrace>) {
        self.trace = Some(trace);
    }

    /// The window currently allocated from.
    pub fn window(&self) -> TagRange {
        self.window
    }

    /// Allocate the next tag of the current window. `kind` is a static
    /// label naming the protocol step (e.g. `"king.up"`), carried into
    /// divergence diagnostics.
    pub fn fresh(&mut self, kind: &'static str) -> Tag {
        let t = self.cursor;
        assert!(
            self.window.contains(t),
            "tag window '{}' [{}, {}) exhausted at step '{kind}' (party {}): \
             the protocol allocated more tags than the window holds",
            self.window.name,
            self.window.start,
            self.window.end,
            self.party,
        );
        self.cursor = t + 1;
        if let Some(tr) = &self.trace {
            tr.record(self.party, kind, t);
        }
        t
    }
}

/// Cross-party fingerprint of the SPMD tag-allocation sequence.
///
/// One instance is shared by every in-process party of a run (debug
/// builds only — `coordinator::protocol::run_clients` wires it up under
/// `cfg!(debug_assertions)`). The first party to reach allocation `i`
/// defines the expected `(kind, tag)` pair; every other party's `i`-th
/// allocation is compared against it, so a divergence panics *at the
/// divergent allocation* — naming the step — instead of surfacing 120 s
/// later as a receive timeout. [`assert_converged`](Self::assert_converged)
/// closes the loop at run end: every completing party must have produced
/// the full sequence (catching a party that silently allocated fewer).
///
/// Separate-process deployments (`copml party`) cannot share an
/// instance; there the dynamic complement is the per-mailbox `(from,
/// tag)` reuse counter (`Transport::tag_reuse`).
#[derive(Debug)]
pub struct SpmdTagTrace {
    inner: Mutex<TraceInner>,
}

#[derive(Debug)]
struct TraceInner {
    /// The agreed allocation sequence, extended by whichever party gets
    /// to each index first.
    expected: Vec<(&'static str, Tag)>,
    /// Per-party progress through `expected`.
    cursors: Vec<usize>,
}

impl SpmdTagTrace {
    /// Fresh trace for an `n`-party run.
    pub fn new(n: usize) -> Arc<SpmdTagTrace> {
        Arc::new(SpmdTagTrace {
            inner: Mutex::new(TraceInner { expected: Vec::new(), cursors: vec![0; n] }),
        })
    }

    /// Record (and cross-check) one allocation by `party`. Panics with
    /// the first divergent allocation if `party` disagrees with the
    /// sequence established by the parties ahead of it.
    pub fn record(&self, party: PartyId, kind: &'static str, tag: Tag) {
        let mut g = self.inner.lock().expect("tag trace lock poisoned");
        let i = g.cursors[party];
        g.cursors[party] += 1;
        if i == g.expected.len() {
            g.expected.push((kind, tag));
        } else {
            let (ek, et) = g.expected[i];
            assert!(
                ek == kind && et == tag,
                "SPMD tag divergence at allocation #{i}: party {party} allocated \
                 '{kind}' (tag {tag}) where the parties ahead of it allocated \
                 '{ek}' (tag {et}) — the parties are no longer executing the \
                 same protocol step sequence",
            );
        }
    }

    /// Number of allocations in the agreed sequence so far.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("tag trace lock poisoned").expected.len()
    }

    /// Whether nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// End-of-run check: every party in `completers` must have walked
    /// the full agreed sequence. A shorter walk means that party skipped
    /// allocations the others performed — a divergence `record` alone
    /// cannot see.
    pub fn assert_converged(&self, completers: &[PartyId]) {
        let g = self.inner.lock().expect("tag trace lock poisoned");
        for &p in completers {
            assert!(
                g.cursors[p] == g.expected.len(),
                "SPMD tag divergence at run end: party {p} performed {} tag \
                 allocations but the agreed sequence has {} — party {p} skipped \
                 allocations the other parties performed",
                g.cursors[p],
                g.expected.len(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_are_disjoint_and_exclude_depart() {
        let named = [SETUP, ENCODE, FINAL, ROUND, SESSIONS, OFFLINE];
        for (i, a) in named.iter().enumerate() {
            for b in &named[i + 1..] {
                assert!(disjoint(a, b), "{} overlaps {}", a.name, b.name);
            }
            assert!(!a.contains(DEPART), "{} contains DEPART", a.name);
        }
        assert_eq!(FLAT.end, OFFLINE.start);
    }

    #[test]
    fn windows_stay_inside_their_parent_range() {
        let last_enc = encode_window((max_batches() - 1) as usize);
        assert!(ENCODE.contains(last_enc.start) && last_enc.end <= ENCODE.end);
        let last_rnd = round_window((max_iters() - 1) as usize);
        assert!(ROUND.contains(last_rnd.start) && last_rnd.end <= ROUND.end);
        assert_eq!(encode_window(0).start, ENCODE.start);
        assert_eq!(round_window(0).start, ROUND.start);
        // Consecutive windows abut without overlap.
        assert_eq!(encode_window(0).end, encode_window(1).start);
        assert_eq!(round_window(0).end, round_window(1).start);
    }

    #[test]
    fn session_zero_is_the_legacy_layout() {
        // Bit-compatibility anchor: a session-0 run must allocate exactly
        // the tags a pre-session run allocated.
        assert_eq!(session_setup(0), SETUP);
        assert_eq!(session_encode_window(0, 3), encode_window(3));
        assert_eq!(session_final(0), FINAL);
        assert_eq!(session_round_window(0, 7), round_window(7));
        assert_eq!(session_offline(0).start, OFFLINE.start);
        assert_eq!(session_offline(0).capacity(), SESSION_STRIDE);
    }

    #[test]
    fn session_windows_stay_inside_their_regions_and_never_collide() {
        // A handful of sessions, including the last representable one:
        // every online window inside SESSIONS (or the legacy region for
        // s = 0), every offline window inside OFFLINE, and the windows of
        // distinct sessions pairwise disjoint.
        let sessions = [0, 1, 2, 5, max_sessions() - 1];
        let windows = |s: u64| {
            [
                session_setup(s),
                session_encode_window(s, 0),
                session_encode_window(s, (max_batches() - 1) as usize),
                session_final(s),
                session_round_window(s, 0),
                session_round_window(s, (max_session_iters() - 1) as usize),
                session_offline(s),
            ]
        };
        for &s in &sessions {
            for w in windows(s) {
                assert!(w.capacity() >= 1, "s={s} {}", w.name);
                assert!(!w.contains(DEPART), "s={s} {}", w.name);
                if w.name == "offline" {
                    assert!(OFFLINE.contains(w.start) && w.end <= OFFLINE.end, "s={s}");
                } else if s == 0 {
                    assert!(w.end <= SESSIONS.start, "s=0 {} must stay legacy", w.name);
                } else {
                    assert!(SESSIONS.contains(w.start) && w.end <= SESSIONS.end, "s={s} {}", w.name);
                }
            }
        }
        for (i, &a) in sessions.iter().enumerate() {
            for &b in &sessions[i + 1..] {
                for wa in windows(a) {
                    for wb in windows(b) {
                        assert!(disjoint(&wa, &wb), "s{a}/{} overlaps s{b}/{}", wa.name, wb.name);
                    }
                }
            }
        }
        // Within one session, the mirrored sub-windows stay disjoint too.
        let w1 = windows(1);
        for (i, a) in w1.iter().enumerate() {
            for b in &w1[i + 1..] {
                assert!(disjoint(a, b), "session 1: {} overlaps {}", a.name, b.name);
            }
        }
    }

    #[test]
    #[should_panic(expected = "outside the SESSIONS stripe region")]
    fn session_past_capacity_panics() {
        session_setup(max_sessions());
    }

    #[test]
    fn alloc_counts_up_and_seeks_reset() {
        let mut a = TagAlloc::new(0, SETUP);
        assert_eq!(a.fresh("a"), SETUP.start);
        assert_eq!(a.fresh("b"), SETUP.start + 1);
        a.seek(round_window(3));
        assert_eq!(a.fresh("c"), round_window(3).start);
    }

    #[test]
    #[should_panic(expected = "exhausted")]
    fn alloc_panics_on_window_exhaustion() {
        let tiny = TagRange { name: "tiny", start: 10, end: 12 };
        let mut a = TagAlloc::new(0, tiny);
        a.fresh("x");
        a.fresh("y");
        a.fresh("z"); // third tag of a 2-tag window
    }

    #[test]
    fn trace_accepts_identical_sequences() {
        let tr = SpmdTagTrace::new(3);
        for step in 0..4u64 {
            for p in 0..3 {
                tr.record(p, "step", step);
            }
        }
        tr.assert_converged(&[0, 1, 2]);
        assert_eq!(tr.len(), 4);
    }

    #[test]
    #[should_panic(expected = "SPMD tag divergence at allocation #1")]
    fn trace_names_first_divergent_allocation() {
        let tr = SpmdTagTrace::new(2);
        tr.record(0, "share.x", 0);
        tr.record(0, "king.up", 1);
        tr.record(1, "share.x", 0);
        tr.record(1, "open.bcast", 1); // diverges here, at index 1
    }

    #[test]
    #[should_panic(expected = "divergence at run end")]
    fn trace_catches_short_walks_at_run_end() {
        let tr = SpmdTagTrace::new(2);
        tr.record(0, "share.x", 0);
        tr.record(0, "share.y", 1);
        tr.record(1, "share.x", 0); // party 1 stops early
        tr.assert_converged(&[0, 1]);
    }

    #[test]
    fn alloc_reports_through_attached_trace() {
        let tr = SpmdTagTrace::new(2);
        let mut a0 = TagAlloc::new(0, SETUP);
        let mut a1 = TagAlloc::new(1, SETUP);
        a0.attach_trace(Arc::clone(&tr));
        a1.attach_trace(Arc::clone(&tr));
        a0.fresh("share.x");
        a1.fresh("share.x");
        tr.assert_converged(&[0, 1]);
    }
}
