//! TCP transport: the full-fidelity protocol over real sockets.
//!
//! Each party is one endpoint of a full mesh of `TcpStream`s (one OS
//! process per party in a real deployment via `copml party`, or one thread
//! per party in the loopback harness). Messages are length-prefixed frames
//! ([`crate::net::wire`]) drained into the shared tagged mailbox
//! (`TagMailbox`), so the blocking tagged-`recv` semantics of
//! [`Transport`] — and everything built on them: the MPC collectives, the
//! byte ledger, the SPMD tag discipline — run unmodified over the network.
//! *How* the sockets are drained is the [`Runtime`] choice:
//!
//! * [`Runtime::Threaded`] — a per-peer **reader thread** per socket (the
//!   original architecture, and the bit-identity oracle). Simple, but a
//!   loopback mesh pays ~N² reader threads at large N.
//! * [`Runtime::Event`] — all sockets registered with one poll-driven
//!   reactor thread (`net::reactor`, a hand-rolled `poll(2)` readiness
//!   loop) over non-blocking I/O; a whole loopback mesh runs its socket
//!   fabric on a single shared reactor. Same frames, same mailbox, same
//!   recorded failure causes.
//!
//! Either way, socket buffers stay decoupled from protocol progress: a
//! peer's send never blocks on our `recv` order.
//!
//! Mesh construction is deterministic and deadlock-free: party `i` *dials*
//! every lower-numbered peer (retrying while it boots) and *accepts* a
//! connection from every higher-numbered one. A 13-byte handshake
//! (`magic | wire code | party id`) identifies the dialer and rejects
//! mixed wire-format meshes at connect time.

use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::mailbox::TagMailbox;
use super::reactor::{self, Reactor};
use super::wire::{self, Wire, HEADER_BYTES};
use super::{AnyRecv, PartyId, Runtime, Transport, TryRecv};

/// Handshake magic ("COPML wire").
const MAGIC: [u8; 4] = *b"CPML";
/// How long `establish` keeps retrying dials / waiting for accepts while
/// the rest of the mesh boots.
const MESH_TIMEOUT: Duration = Duration::from_secs(60);
/// Per-connection handshake read budget on the accept side — a silent
/// stray socket (scanner, health probe) must not stall the accept loop.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);
/// Pause between dial retries against a peer that is not up yet.
const DIAL_RETRY: Duration = Duration::from_millis(50);
/// Upper bound on a single frame's payload. Far above any protocol
/// message (the largest is a dataset-share block, well under 1 GiB), but
/// small enough that a corrupt or hostile length prefix cannot drive the
/// reader thread (or the reactor) into a multi-gigabyte allocation.
pub(crate) const MAX_FRAME_BYTES: u32 = 1 << 30;
/// Reserved tag of the departure notice a leaving party sends before
/// shutting its sockets ([`Transport::leave`]): the payload carries the
/// halt reason (one byte per word — tiny, wire-format agnostic), so peers
/// record the *actual* cause ("killed at iteration 3 …") instead of a
/// generic EOF. Protocol tags come from the typed windows of
/// [`super::tags`], every one of which excludes [`super::tags::DEPART`]
/// by const assertion — so this control tag can never collide.
pub(crate) const DEPART_TAG: u64 = super::tags::DEPART;

/// Encode a departure reason for the [`DEPART_TAG`] payload.
fn reason_to_words(reason: &str) -> Vec<u64> {
    reason.bytes().map(u64::from).collect()
}

/// Decode a [`DEPART_TAG`] payload back into the departure reason. The
/// words carry UTF-8 bytes (halt reasons contain em dashes), so decode
/// them as UTF-8, not byte-per-char Latin-1.
pub(crate) fn words_to_reason(words: &[u64]) -> String {
    let bytes: Vec<u8> = words.iter().map(|&w| w as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

fn bad_proto(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// One party's endpoint of an `n`-party TCP mesh.
pub struct TcpTransport {
    id: PartyId,
    n: usize,
    wire: Wire,
    /// Write halves, indexed by peer id (`None` for self).
    writers: Vec<Option<Mutex<TcpStream>>>,
    mailbox: Arc<TagMailbox>,
    sent: AtomicU64,
    sent_offline: AtomicU64,
    received: Arc<AtomicU64>,
    /// Per-peer reader threads ([`Runtime::Threaded`]; empty under the
    /// event runtime).
    readers: Vec<JoinHandle<()>>,
    /// The reactor draining this endpoint's sockets ([`Runtime::Event`];
    /// `None` under the threaded runtime). Possibly shared with other
    /// endpoints (the loopback mesh); the thread is joined when the last
    /// handle drops.
    reactor: Option<Arc<Reactor>>,
}

impl TcpTransport {
    /// Bind `listen` and build the mesh under the threaded runtime.
    /// `peers[j]` is the address party `j` listens on, as reachable from
    /// this host; `peers[id]` (our own entry) is ignored. Blocks until
    /// all `n − 1` connections are up (bounded by an internal timeout).
    pub fn establish(
        id: PartyId,
        listen: &str,
        peers: &[String],
        wire: Wire,
    ) -> io::Result<TcpTransport> {
        Self::establish_runtime(id, listen, peers, wire, Runtime::Threaded)
    }

    /// [`TcpTransport::establish`] with an explicit [`Runtime`]: per-peer
    /// reader threads, or one poll-driven reactor for all of this
    /// endpoint's sockets (`copml party --runtime event`).
    pub fn establish_runtime(
        id: PartyId,
        listen: &str,
        peers: &[String],
        wire: Wire,
        runtime: Runtime,
    ) -> io::Result<TcpTransport> {
        let listener = TcpListener::bind(listen)?;
        Self::establish_on_runtime(id, listener, peers, wire, runtime)
    }

    /// Like [`TcpTransport::establish`] with an already-bound listener
    /// (the loopback launcher binds all listeners up front so ephemeral
    /// ports are known before any dial).
    pub fn establish_on(
        id: PartyId,
        listener: TcpListener,
        peers: &[String],
        wire: Wire,
    ) -> io::Result<TcpTransport> {
        Self::establish_on_with(id, listener, peers, wire, None)
    }

    /// [`TcpTransport::establish_on`] with an explicit [`Runtime`]. Under
    /// [`Runtime::Event`] this endpoint gets its own reactor; the
    /// loopback mesh shares one reactor across all `n` endpoints instead
    /// (see [`loopback_mesh_runtime`]).
    pub fn establish_on_runtime(
        id: PartyId,
        listener: TcpListener,
        peers: &[String],
        wire: Wire,
        runtime: Runtime,
    ) -> io::Result<TcpTransport> {
        let reactor = match runtime {
            Runtime::Threaded => None,
            Runtime::Event => Some(Arc::new(Reactor::spawn()?)),
        };
        Self::establish_on_with(id, listener, peers, wire, reactor)
    }

    fn establish_on_with(
        id: PartyId,
        listener: TcpListener,
        peers: &[String],
        wire: Wire,
        reactor: Option<Arc<Reactor>>,
    ) -> io::Result<TcpTransport> {
        let n = peers.len();
        assert!(id < n, "party id {id} out of range for {n} peers");
        let deadline = Instant::now() + MESH_TIMEOUT;
        let mut streams: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        // Dial every lower-numbered peer (it accepts ids above its own).
        for (peer, slot) in streams.iter_mut().enumerate().take(id) {
            *slot = Some(dial(&peers[peer], id, wire, deadline)?);
        }
        // Accept one connection from every higher-numbered peer, in
        // whatever order they come up; the handshake names the dialer.
        for _ in id + 1..n {
            let (s, from) = accept(&listener, id, n, wire, deadline)?;
            if streams[from].is_some() {
                return Err(bad_proto(format!("duplicate connection from party {from}")));
            }
            streams[from] = Some(s);
        }
        drop(listener);

        let mailbox = Arc::new(TagMailbox::default());
        let received = Arc::new(AtomicU64::new(0));
        let mut writers = Vec::with_capacity(n);
        let mut readers = Vec::new();
        for (peer, slot) in streams.into_iter().enumerate() {
            match slot {
                None => writers.push(None),
                Some(s) => {
                    // Protocol messages are latency-sensitive whole frames.
                    s.set_nodelay(true).ok();
                    let reader = s.try_clone()?;
                    match &reactor {
                        // Event runtime: the reactor drains this socket
                        // (and flips the shared file description
                        // non-blocking — the send path compensates, see
                        // `write_frame`).
                        Some(r) => {
                            r.register(reader, peer, wire, mailbox.clone(), received.clone())?
                        }
                        None => {
                            let mb = mailbox.clone();
                            let rc = received.clone();
                            readers.push(std::thread::spawn(move || {
                                reader_loop(reader, peer, wire, &mb, &rc)
                            }));
                        }
                    }
                    writers.push(Some(Mutex::new(s)));
                }
            }
        }
        Ok(TcpTransport {
            id,
            n,
            wire,
            writers,
            mailbox,
            sent: AtomicU64::new(0),
            sent_offline: AtomicU64::new(0),
            received,
            readers,
            reactor,
        })
    }

    /// The wire format this mesh was established with.
    pub fn wire(&self) -> Wire {
        self.wire
    }

    /// Write one encoded frame to an already-locked peer stream,
    /// best-effort (`false` = the peer's socket rejected it; the failure
    /// surfaces receive-side). Under the threaded runtime the stream is
    /// blocking and this is a plain `write_all`; under the event runtime
    /// the stream is non-blocking (its file description is shared with
    /// the reactor-registered read half), so `WouldBlock` parks on
    /// `POLLOUT` until the socket drains — restoring blocking-send
    /// semantics without ever blocking the reactor.
    fn write_frame(&self, s: &mut TcpStream, frame: &[u8]) -> bool {
        if self.reactor.is_none() {
            return s.write_all(frame).is_ok();
        }
        let mut off = 0;
        while off < frame.len() {
            match s.write(&frame[off..]) {
                Ok(0) => return false,
                Ok(k) => off += k,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if reactor::wait_writable(s.as_raw_fd()).is_err() {
                        return false;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return false,
            }
        }
        true
    }
}

fn dial(addr: &str, my_id: PartyId, wire: Wire, deadline: Instant) -> io::Result<TcpStream> {
    loop {
        match TcpStream::connect(addr) {
            Ok(mut s) => {
                s.set_read_timeout(Some(MESH_TIMEOUT))?;
                let mut hello = [0u8; 13];
                hello[..4].copy_from_slice(&MAGIC);
                hello[4] = wire.code();
                hello[5..].copy_from_slice(&(my_id as u64).to_le_bytes());
                s.write_all(&hello)?;
                // The acceptor echoes magic + wire code as the ack.
                let mut echo = [0u8; 5];
                s.read_exact(&mut echo)?;
                if echo[..4] != MAGIC || echo[4] != wire.code() {
                    return Err(bad_proto(format!(
                        "handshake with {addr} failed: wire-format mismatch (ours: {wire})"
                    )));
                }
                s.set_read_timeout(None)?;
                return Ok(s);
            }
            Err(e) => {
                // Only errors a still-booting peer can cause are worth
                // retrying; anything else (DNS failure, unreachable
                // network) is permanent and surfaces immediately.
                let retryable = matches!(
                    e.kind(),
                    io::ErrorKind::ConnectionRefused
                        | io::ErrorKind::ConnectionReset
                        | io::ErrorKind::TimedOut
                );
                if !retryable || Instant::now() >= deadline {
                    return Err(e);
                }
                std::thread::sleep(DIAL_RETRY);
            }
        }
    }
}

/// Accept connections until one passes the handshake as a valid peer.
///
/// Connections that are not copml peers at all — port scanners, health
/// probes, silent sockets (bad magic, handshake EOF, per-connection
/// handshake timeout) — are dropped and the loop keeps listening; a lone
/// stray connection must not abort the whole mesh. Genuine copml
/// misconfiguration (correct magic but wrong wire format or an
/// out-of-range party id) fails fast with a clear error.
fn accept(
    listener: &TcpListener,
    my_id: PartyId,
    n: usize,
    wire: Wire,
    deadline: Instant,
) -> io::Result<(TcpStream, PartyId)> {
    listener.set_nonblocking(true)?;
    loop {
        let mut s = loop {
            match listener.accept() {
                Ok((s, _addr)) => break s,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            format!("party {my_id} timed out waiting for peers to connect"),
                        ));
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => return Err(e),
            }
        };
        match handshake_accept(&mut s, my_id, n, wire)? {
            Some(from) => return Ok((s, from)),
            None => continue, // stray connection: drop `s`, keep listening
        }
    }
}

/// Acceptor side of the handshake. `Ok(Some(id))` — valid peer;
/// `Ok(None)` — stray connection to drop; `Err` — a copml peer with a
/// conflicting configuration (abort the mesh).
fn handshake_accept(
    s: &mut TcpStream,
    my_id: PartyId,
    n: usize,
    wire: Wire,
) -> io::Result<Option<PartyId>> {
    s.set_nonblocking(false)?;
    // Real dialers send their hello immediately after connect; a silent
    // socket must not stall the accept loop for the whole mesh timeout.
    s.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    let mut hello = [0u8; 13];
    if s.read_exact(&mut hello).is_err() || hello[..4] != MAGIC {
        return Ok(None);
    }
    if hello[4] != wire.code() {
        return Err(bad_proto(format!(
            "wire-format mismatch: this party uses {wire}, the dialer does not"
        )));
    }
    let from =
        u64::from_le_bytes(hello[5..13].try_into().expect("8-byte slice of a 13-byte hello"))
            as usize;
    if from <= my_id || from >= n {
        return Err(bad_proto(format!(
            "unexpected dialer id {from} (party {my_id} accepts ids {}..{n})",
            my_id + 1
        )));
    }
    let mut echo = [0u8; 5];
    echo[..4].copy_from_slice(&MAGIC);
    echo[4] = wire.code();
    s.write_all(&echo)?;
    s.set_read_timeout(None)?;
    Ok(Some(from))
}

/// Drain one peer's socket into the mailbox until EOF/shutdown. The
/// termination cause is recorded on the mailbox, so a `recv` blocked on a
/// dead peer fails immediately with that cause instead of sitting out the
/// 120-second deadlock timeout and blaming the protocol.
fn reader_loop(
    mut stream: TcpStream,
    from: PartyId,
    wire: Wire,
    mailbox: &TagMailbox,
    received: &AtomicU64,
) {
    let mut header = [0u8; HEADER_BYTES];
    loop {
        // EOF or shutdown: the peer (or our Drop) closed the connection.
        if let Err(e) = stream.read_exact(&mut header) {
            mailbox.close(from, format!("connection closed: {e}"));
            return;
        }
        let (payload_len, tag) = wire::decode_header(&header);
        if payload_len > MAX_FRAME_BYTES {
            // A corrupt length prefix must not become a giant allocation
            // (and certainly not a reader-thread abort).
            mailbox.close(
                from,
                format!("corrupt frame: oversized payload ({payload_len} B > {MAX_FRAME_BYTES} B cap)"),
            );
            return;
        }
        let mut payload = vec![0u8; payload_len as usize];
        if let Err(e) = stream.read_exact(&mut payload) {
            mailbox.close(from, format!("connection died mid-frame: {e}"));
            return;
        }
        let data = match wire::decode_payload(wire, &payload) {
            Ok(d) => d,
            Err(e) => {
                mailbox.close(from, format!("corrupt frame: {e}"));
                return;
            }
        };
        if tag == DEPART_TAG {
            // Control frame, not ledgered: the peer announces its own
            // departure with the real halt reason.
            mailbox.close(from, format!("peer left: {}", words_to_reason(&data)));
            return;
        }
        // Ledger only deliveries the mailbox accepted: frames landing
        // after this party left (shutdown) are discarded unseen, so they
        // are not received in any meaningful sense.
        if mailbox.push(from, tag, data) {
            received.fetch_add(payload_len as u64, Ordering::Relaxed);
        }
    }
}

impl Transport for TcpTransport {
    fn id(&self) -> PartyId {
        self.id
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&self, to: PartyId, tag: u64, data: Vec<u64>) {
        assert!(to < self.n, "send to unknown party {to}");
        assert!(to != self.id, "self-send is a protocol bug");
        let frame = wire::encode_frame(self.wire, tag, &data);
        let wrote = {
            let mut s = self.writers[to]
                .as_ref()
                .expect("no connection slot for peer")
                .lock()
                .expect("writer lock poisoned");
            // Best-effort: a dead peer (fault-plan kill, crashed process)
            // surfaces on the receive side via its closed mailbox; a send
            // into its reset socket must not take this party down.
            self.write_frame(&mut s, &frame)
        };
        if wrote {
            // Ledger counts payload bytes (header excluded), matching `local`.
            let bytes = data.len() as u64 * self.wire.elem_bytes();
            self.sent.fetch_add(bytes, Ordering::Relaxed);
            if super::tags::OFFLINE.contains(tag) {
                self.sent_offline.fetch_add(bytes, Ordering::Relaxed);
            }
        }
    }

    fn recv(&self, from: PartyId, tag: u64) -> Vec<u64> {
        assert!(from < self.n && from != self.id, "recv from unknown party {from}");
        self.mailbox.pop_blocking(self.id, from, tag)
    }

    fn recv_check(&self, from: PartyId, tag: u64) -> Result<Vec<u64>, String> {
        assert!(from < self.n && from != self.id, "recv from unknown party {from}");
        self.mailbox.pop_result(self.id, from, tag)
    }

    fn recv_any(&self, froms: &[PartyId], tag: u64, timeout: Duration) -> AnyRecv {
        self.mailbox.pop_any(self.id, froms, tag, timeout)
    }

    fn try_recv(&self, from: PartyId, tag: u64) -> TryRecv {
        assert!(from < self.n && from != self.id, "recv from unknown party {from}");
        self.mailbox.try_pop(from, tag)
    }

    fn activity(&self) -> u64 {
        self.mailbox.activity()
    }

    fn wait_activity(&self, since: u64, timeout: Duration) -> u64 {
        self.mailbox.wait_activity(since, timeout)
    }

    fn forget(&self, from: PartyId, tag: u64) -> bool {
        self.mailbox.forget(from, tag)
    }

    fn pending_messages(&self) -> usize {
        self.mailbox.pending_entries()
    }

    fn leave(&self, reason: &str) {
        // Tell every peer WHY before hanging up ([`DEPART_TAG`] control
        // frame, best-effort), then shut the sockets down — their reader
        // threads record the reason, and blocked receives on this party
        // fail with it instead of a generic EOF.
        let frame = wire::encode_frame(self.wire, DEPART_TAG, &reason_to_words(reason));
        for m in self.writers.iter().flatten() {
            if let Ok(mut s) = m.lock() {
                let _ = self.write_frame(&mut s, &frame);
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        self.mailbox.shutdown();
    }

    fn bytes_sent(&self) -> u64 {
        self.sent.load(Ordering::Relaxed)
    }

    fn bytes_received(&self) -> u64 {
        self.received.load(Ordering::Relaxed)
    }

    fn bytes_sent_offline(&self) -> u64 {
        self.sent_offline.load(Ordering::Relaxed)
    }

    fn tag_reuse(&self) -> usize {
        self.mailbox.tag_reuse()
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        for m in self.writers.iter().flatten() {
            if let Ok(s) = m.lock() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Launch an `n`-party full mesh over `127.0.0.1` ephemeral ports: every
/// party is its own socket endpoint, established concurrently on its own
/// thread. Returns endpoints in id order. This is the loopback launcher
/// used by the equivalence tests, CI smoke runs, and local demos; real
/// deployments run one `copml party` process per endpoint instead.
pub fn loopback_mesh(n: usize, wire: Wire) -> io::Result<Vec<TcpTransport>> {
    loopback_mesh_runtime(n, wire, Runtime::Threaded)
}

/// [`loopback_mesh`] with an explicit [`Runtime`]. Thread accounting is
/// where the runtimes diverge: the threaded mesh spawns a reader thread
/// per connection end — `n(n−1)` across the process, the ~N² that makes
/// N≥25 loopback runs thrash — while the event mesh registers every
/// socket with ONE shared reactor thread (`copml-reactor`), so the whole
/// fabric adds a single OS thread regardless of `n` (the `fig_runtime`
/// bench pins this).
pub fn loopback_mesh_runtime(
    n: usize,
    wire: Wire,
    runtime: Runtime,
) -> io::Result<Vec<TcpTransport>> {
    let mut listeners = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        let l = TcpListener::bind("127.0.0.1:0")?;
        addrs.push(l.local_addr()?.to_string());
        listeners.push(l);
    }
    let reactor = match runtime {
        Runtime::Threaded => None,
        Runtime::Event => Some(Arc::new(Reactor::spawn()?)),
    };
    let handles: Vec<_> = listeners
        .into_iter()
        .enumerate()
        .map(|(id, l)| {
            let addrs = addrs.clone();
            let reactor = reactor.clone();
            std::thread::spawn(move || {
                TcpTransport::establish_on_with(id, l, &addrs, wire, reactor)
            })
        })
        .collect();
    let mut out = Vec::with_capacity(n);
    for h in handles {
        let ep = h
            .join()
            .map_err(|_| io::Error::new(io::ErrorKind::Other, "mesh setup thread panicked"))??;
        out.push(ep);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{broadcast, gather_all};

    const RUNTIMES: [Runtime; 2] = [Runtime::Threaded, Runtime::Event];

    fn pair(wire: Wire) -> (TcpTransport, TcpTransport) {
        pair_rt(wire, Runtime::Threaded)
    }

    fn pair_rt(wire: Wire, runtime: Runtime) -> (TcpTransport, TcpTransport) {
        let mut eps = loopback_mesh_runtime(2, wire, runtime).unwrap();
        let b = eps.pop().unwrap();
        let a = eps.pop().unwrap();
        (a, b)
    }

    #[test]
    fn point_to_point_over_sockets() {
        for runtime in RUNTIMES {
            for wire in [Wire::U64, Wire::U32] {
                let (a, b) = pair_rt(wire, runtime);
                let h = std::thread::spawn(move || {
                    a.send(1, 7, vec![1, 2, 3]);
                    a.recv(1, 8)
                });
                assert_eq!(b.recv(0, 7), vec![1, 2, 3]);
                b.send(0, 8, vec![9]);
                assert_eq!(h.join().unwrap(), vec![9]);
            }
        }
    }

    #[test]
    fn out_of_order_tags_over_sockets() {
        let (a, b) = pair(Wire::U64);
        a.send(1, 2, vec![22]);
        a.send(1, 1, vec![11]);
        assert_eq!(b.recv(0, 1), vec![11]);
        assert_eq!(b.recv(0, 2), vec![22]);
    }

    #[test]
    fn byte_ledger_counts_payload_and_halves_under_u32() {
        let mut by_wire = Vec::new();
        for wire in [Wire::U64, Wire::U32] {
            let (a, b) = pair(wire);
            a.send(1, 0, vec![5; 100]);
            let got = b.recv(0, 0);
            assert_eq!(got, vec![5; 100]);
            assert_eq!(a.bytes_sent(), 100 * wire.elem_bytes());
            assert_eq!(b.bytes_received(), 100 * wire.elem_bytes());
            by_wire.push(a.bytes_sent());
        }
        assert_eq!(by_wire[0], 2 * by_wire[1]);
    }

    #[test]
    fn broadcast_gather_over_four_socket_parties() {
        // Both runtimes drive the same mesh collective; the event variant
        // runs all 12 connection ends on one shared reactor thread.
        for runtime in RUNTIMES {
            let eps = loopback_mesh_runtime(4, Wire::U32, runtime).unwrap();
            let handles: Vec<_> = eps
                .into_iter()
                .map(|ep| {
                    std::thread::spawn(move || {
                        let own = vec![ep.id() as u64 * 100];
                        broadcast(&ep, 0, &own);
                        let all = gather_all(&ep, 0, own);
                        all.iter().map(|v| v[0]).collect::<Vec<u64>>()
                    })
                })
                .collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), vec![0, 100, 200, 300]);
            }
        }
    }

    #[test]
    fn stray_connection_does_not_abort_the_mesh() {
        // A port scanner / health probe hitting the listen port during
        // boot must be dropped, not abort mesh establishment.
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a0 = l0.local_addr().unwrap().to_string();
        let addrs = vec![a0.clone(), l1.local_addr().unwrap().to_string()];
        let mut stray = TcpStream::connect(&a0).unwrap();
        stray.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        let addrs2 = addrs.clone();
        let h0 =
            std::thread::spawn(move || TcpTransport::establish_on(0, l0, &addrs, Wire::U64));
        let h1 =
            std::thread::spawn(move || TcpTransport::establish_on(1, l1, &addrs2, Wire::U64));
        let t0 = h0.join().unwrap().expect("party 0 must survive the stray connection");
        let t1 = h1.join().unwrap().expect("party 1 must connect normally");
        t1.send(0, 0, vec![1, 2]);
        assert_eq!(t0.recv(1, 0), vec![1, 2]);
        drop(stray);
    }

    #[test]
    fn leave_reason_reaches_peers() {
        // An explicit departure must surface its real cause at peers, not
        // a generic EOF — post-mortems over sockets need the reason.
        for runtime in RUNTIMES {
            let (a, b) = pair_rt(Wire::U32, runtime);
            a.leave("killed at iteration 3 — by the fault plan"); // em dash: UTF-8 survives
            let err = b.recv_check(0, 0).unwrap_err();
            assert!(err.contains("killed at iteration 3 — by"), "{err}");
            // and the departed party's own mailbox discards deliveries
            b.send(0, 1, vec![7]);
            assert_eq!(a.pending_messages(), 0);
        }
    }

    #[test]
    fn dead_peer_fails_recv_fast() {
        // A peer process dying must surface as an immediate "peer is gone"
        // failure on blocked receives, not a 120 s deadlock timeout.
        for runtime in RUNTIMES {
            let (a, b) = pair_rt(Wire::U64, runtime);
            drop(a); // party 0 dies: its Drop shuts the sockets down
            let t0 = std::time::Instant::now();
            let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| b.recv(0, 0)))
                .unwrap_err();
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(10),
                "recv must fail fast, not wait out the deadlock timeout"
            );
            let msg = err.downcast_ref::<String>().expect("panic payload");
            assert!(msg.contains("peer is gone"), "{msg}");
        }
    }

    /// Party 0 of a 2-party mesh, with "party 1" actually a raw socket the
    /// test drives by hand (valid handshake, then arbitrary bytes) — the
    /// rig for the malformed-frame hardening tests, replayed under both
    /// runtimes (reader thread and reactor must record identical causes).
    fn mesh_with_raw_peer_rt(wire: Wire, runtime: Runtime) -> (TcpTransport, TcpStream) {
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l0.local_addr().unwrap().to_string();
        // party 1 never listens — it dials party 0 (dial-low rule).
        let addrs = vec![addr.clone(), "127.0.0.1:1".to_string()];
        let h0 = std::thread::spawn(move || {
            TcpTransport::establish_on_runtime(0, l0, &addrs, wire, runtime)
        });
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut hello = [0u8; 13];
        hello[..4].copy_from_slice(&MAGIC);
        hello[4] = wire.code();
        hello[5..].copy_from_slice(&1u64.to_le_bytes());
        s.write_all(&hello).unwrap();
        let mut echo = [0u8; 5];
        s.read_exact(&mut echo).unwrap();
        (h0.join().unwrap().expect("mesh must establish"), s)
    }

    /// Assert that party 0's blocked receive on the malformed peer fails
    /// fast with the recorded corrupt-frame cause — the reader thread
    /// closed the mailbox cleanly instead of panicking, hanging, or
    /// swallowing the frame.
    fn assert_recv_fails_with(t0: TcpTransport, needle: &str) {
        let start = std::time::Instant::now();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t0.recv(1, 0)))
            .unwrap_err();
        assert!(
            start.elapsed() < Duration::from_secs(10),
            "recv must fail fast on a malformed frame, not wait out the deadlock timeout"
        );
        let msg = err.downcast_ref::<String>().expect("panic payload");
        assert!(msg.contains(needle), "expected cause '{needle}' in: {msg}");
    }

    #[test]
    fn oversized_frame_is_rejected_without_allocation() {
        // A length prefix of u32::MAX must be rejected by the cap, not
        // turned into a 4 GiB allocation in the reader thread / reactor.
        for runtime in RUNTIMES {
            let (t0, mut s) = mesh_with_raw_peer_rt(Wire::U64, runtime);
            let mut header = [0u8; HEADER_BYTES];
            header[..4].copy_from_slice(&u32::MAX.to_le_bytes());
            s.write_all(&header).unwrap();
            assert_recv_fails_with(t0, "oversized payload");
        }
    }

    #[test]
    fn odd_length_frame_is_rejected() {
        // 7 payload bytes is not a multiple of the 8-byte u64 element.
        for runtime in RUNTIMES {
            let (t0, mut s) = mesh_with_raw_peer_rt(Wire::U64, runtime);
            let mut frame = Vec::new();
            frame.extend_from_slice(&7u32.to_le_bytes());
            frame.extend_from_slice(&0u64.to_le_bytes());
            frame.extend_from_slice(&[0xAB; 7]);
            s.write_all(&frame).unwrap();
            assert_recv_fails_with(t0, "not a multiple");
        }
    }

    #[test]
    fn truncated_frame_is_rejected() {
        // Header promises 16 bytes, the connection dies after 5.
        for runtime in RUNTIMES {
            let (t0, mut s) = mesh_with_raw_peer_rt(Wire::U32, runtime);
            let mut frame = Vec::new();
            frame.extend_from_slice(&16u32.to_le_bytes());
            frame.extend_from_slice(&3u64.to_le_bytes());
            frame.extend_from_slice(&[0x01; 5]);
            s.write_all(&frame).unwrap();
            drop(s);
            assert_recv_fails_with(t0, "connection");
        }
    }

    #[test]
    fn random_garbage_never_panics_the_reader() {
        // Property-style sweep: random byte blobs after a valid handshake
        // must always end in a *recorded* close cause (clean reader exit),
        // never a hang — a reader panic would leave the mailbox open and
        // the recv below would sit out the 120 s deadlock timeout. Run
        // under both runtimes: the reactor's incremental decoder faces the
        // same blobs as the reader threads' read_exact loop.
        let mut rng = crate::prng::Rng::seed_from_u64(0xBADF00D);
        for runtime in RUNTIMES {
            for trial in 0..8u64 {
                let wire = if trial % 2 == 0 { Wire::U64 } else { Wire::U32 };
                let (t0, mut s) = mesh_with_raw_peer_rt(wire, runtime);
                let len = 1 + (rng.gen_range(64) as usize);
                let blob: Vec<u8> = (0..len).map(|_| rng.gen_range(256) as u8).collect();
                s.write_all(&blob).unwrap();
                drop(s); // EOF terminates whatever partial frame the blob left
                let start = std::time::Instant::now();
                let err =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t0.recv(1, 0)))
                        .unwrap_err();
                assert!(
                    start.elapsed() < Duration::from_secs(10),
                    "trial {trial}: reader must close the mailbox, not leave recv hanging"
                );
                let msg = err.downcast_ref::<String>().expect("panic payload");
                assert!(msg.contains("peer is gone"), "trial {trial}: {msg}");
            }
        }
    }

    #[test]
    fn mixed_wire_mesh_is_rejected() {
        // Party 0 expects u64 frames, party 1 dials with u32: the
        // handshake must fail on at least one side (and not hang).
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let addrs = vec![
            l0.local_addr().unwrap().to_string(),
            l1.local_addr().unwrap().to_string(),
        ];
        let addrs2 = addrs.clone();
        let h0 =
            std::thread::spawn(move || TcpTransport::establish_on(0, l0, &addrs, Wire::U64));
        let h1 =
            std::thread::spawn(move || TcpTransport::establish_on(1, l1, &addrs2, Wire::U32));
        let r0 = h0.join().unwrap();
        let r1 = h1.join().unwrap();
        assert!(r0.is_err() || r1.is_err(), "mixed wire formats must not connect");
    }
}
