//! Thread-local transport: `n` parties exchanging real share data through
//! in-process mailboxes. The full-fidelity protocol backend.
//!
//! Delivery runs through the same `TagMailbox` as the TCP transport
//! (drained `(from, tag)` entries are removed, so long runs stay bounded);
//! the byte ledger charges [`Wire::elem_bytes`] per element — no bytes are
//! actually serialized in-process, but the accounting matches what the
//! socket transport puts on the wire for the same configuration.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::mailbox::TagMailbox;
use super::{PartyId, Transport, Wire};

/// Shared state for an `n`-party in-process network.
pub struct Hub {
    boxes: Vec<TagMailbox>,
    sent: Vec<AtomicU64>,
    received: Vec<AtomicU64>,
    elem_bytes: u64,
}

impl Hub {
    /// Create a hub and hand out one endpoint per party (64-bit wire
    /// accounting, as in the paper's MPI implementation).
    pub fn new(n: usize) -> Vec<Endpoint> {
        Self::with_wire(n, Wire::U64)
    }

    /// Create a hub whose byte ledger accounts elements at the given wire
    /// format's width.
    pub fn with_wire(n: usize, wire: Wire) -> Vec<Endpoint> {
        let hub = Arc::new(Hub {
            boxes: (0..n).map(|_| TagMailbox::default()).collect(),
            sent: (0..n).map(|_| AtomicU64::new(0)).collect(),
            received: (0..n).map(|_| AtomicU64::new(0)).collect(),
            elem_bytes: wire.elem_bytes(),
        });
        (0..n)
            .map(|id| Endpoint { id, n, hub: hub.clone() })
            .collect()
    }
}

/// One party's handle onto the [`Hub`].
pub struct Endpoint {
    id: PartyId,
    n: usize,
    hub: Arc<Hub>,
}

impl Transport for Endpoint {
    fn id(&self) -> PartyId {
        self.id
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&self, to: PartyId, tag: u64, data: Vec<u64>) {
        assert!(to < self.n, "send to unknown party {to}");
        assert!(to != self.id, "self-send is a protocol bug");
        let bytes = data.len() as u64 * self.hub.elem_bytes;
        self.hub.sent[self.id].fetch_add(bytes, Ordering::Relaxed);
        self.hub.received[to].fetch_add(bytes, Ordering::Relaxed);
        self.hub.boxes[to].push(self.id, tag, data);
    }

    fn recv(&self, from: PartyId, tag: u64) -> Vec<u64> {
        self.hub.boxes[self.id].pop_blocking(self.id, from, tag)
    }

    fn bytes_sent(&self) -> u64 {
        self.hub.sent[self.id].load(Ordering::Relaxed)
    }

    fn bytes_received(&self) -> u64 {
        self.hub.received[self.id].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{broadcast, gather_all, ELEM_BYTES};
    use std::thread;

    #[test]
    fn point_to_point_delivery() {
        let eps = Hub::new(2);
        let (a, b) = {
            let mut it = eps.into_iter();
            (it.next().unwrap(), it.next().unwrap())
        };
        let h = thread::spawn(move || {
            a.send(1, 7, vec![1, 2, 3]);
            a.recv(1, 8)
        });
        assert_eq!(b.recv(0, 7), vec![1, 2, 3]);
        b.send(0, 8, vec![9]);
        assert_eq!(h.join().unwrap(), vec![9]);
    }

    #[test]
    fn out_of_order_tags() {
        let eps = Hub::new(2);
        let (a, b) = {
            let mut it = eps.into_iter();
            (it.next().unwrap(), it.next().unwrap())
        };
        a.send(1, 2, vec![22]);
        a.send(1, 1, vec![11]);
        // receive in tag order regardless of arrival order
        assert_eq!(b.recv(0, 1), vec![11]);
        assert_eq!(b.recv(0, 2), vec![22]);
    }

    #[test]
    fn byte_accounting() {
        let eps = Hub::new(3);
        eps[0].send(1, 0, vec![0; 10]);
        eps[0].send(2, 0, vec![0; 5]);
        assert_eq!(eps[0].bytes_sent(), 15 * ELEM_BYTES);
        assert_eq!(eps[1].bytes_received(), 10 * ELEM_BYTES);
        assert_eq!(eps[2].bytes_received(), 5 * ELEM_BYTES);
    }

    #[test]
    fn u32_wire_accounting_halves_bytes() {
        let eps = Hub::with_wire(2, Wire::U32);
        eps[0].send(1, 0, vec![0; 10]);
        assert_eq!(eps[0].bytes_sent(), 10 * Wire::U32.elem_bytes());
        assert_eq!(eps[0].bytes_sent() * 2, 10 * ELEM_BYTES);
    }

    #[test]
    fn broadcast_gather_round_trip() {
        let n = 4;
        let eps = Hub::new(n);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                thread::spawn(move || {
                    let own = vec![ep.id() as u64 * 100];
                    broadcast(&ep, 0, &own);
                    let all = gather_all(&ep, 0, own);
                    all.iter().map(|v| v[0]).collect::<Vec<u64>>()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![0, 100, 200, 300]);
        }
    }

    #[test]
    fn queued_duplicate_tags_fifo() {
        let eps = Hub::new(2);
        eps[0].send(1, 5, vec![1]);
        eps[0].send(1, 5, vec![2]);
        assert_eq!(eps[1].recv(0, 5), vec![1]);
        assert_eq!(eps[1].recv(0, 5), vec![2]);
    }

    #[test]
    fn drained_mailbox_entries_are_removed() {
        // Regression: every collective consumes a fresh tag, so leaving
        // empty (from, tag) queues behind grows memory without bound over
        // long training runs.
        let eps = Hub::new(2);
        for tag in 0..100 {
            eps[0].send(1, tag, vec![1, 2, 3]);
        }
        for tag in 0..100 {
            assert_eq!(eps[1].recv(0, tag), vec![1, 2, 3]);
        }
        assert_eq!(eps[1].hub.boxes[1].pending_entries(), 0);
    }
}
