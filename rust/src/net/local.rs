//! Thread-local transport: `n` parties exchanging real share data through
//! in-process mailboxes. The full-fidelity protocol backend.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::{PartyId, Transport, ELEM_BYTES};

/// How long a `recv` waits before declaring the protocol deadlocked.
const RECV_TIMEOUT: Duration = Duration::from_secs(120);

#[derive(Default)]
struct Mailbox {
    // (from, tag) -> queued payloads
    queues: Mutex<HashMap<(PartyId, u64), VecDeque<Vec<u64>>>>,
    signal: Condvar,
}

/// Shared state for an `n`-party in-process network.
pub struct Hub {
    boxes: Vec<Arc<Mailbox>>,
    sent: Vec<Arc<AtomicU64>>,
    received: Vec<Arc<AtomicU64>>,
}

impl Hub {
    /// Create a hub and hand out one endpoint per party.
    pub fn new(n: usize) -> Vec<Endpoint> {
        let hub = Arc::new(Hub {
            boxes: (0..n).map(|_| Arc::new(Mailbox::default())).collect(),
            sent: (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect(),
            received: (0..n).map(|_| Arc::new(AtomicU64::new(0))).collect(),
        });
        (0..n)
            .map(|id| Endpoint { id, n, hub: hub.clone() })
            .collect()
    }
}

/// One party's handle onto the [`Hub`].
pub struct Endpoint {
    id: PartyId,
    n: usize,
    hub: Arc<Hub>,
}

impl Transport for Endpoint {
    fn id(&self) -> PartyId {
        self.id
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&self, to: PartyId, tag: u64, data: Vec<u64>) {
        assert!(to < self.n, "send to unknown party {to}");
        assert!(to != self.id, "self-send is a protocol bug");
        self.hub.sent[self.id].fetch_add(data.len() as u64 * ELEM_BYTES, Ordering::Relaxed);
        self.hub.received[to].fetch_add(data.len() as u64 * ELEM_BYTES, Ordering::Relaxed);
        let mbox = &self.hub.boxes[to];
        let mut q = mbox.queues.lock().unwrap();
        q.entry((self.id, tag)).or_default().push_back(data);
        mbox.signal.notify_all();
    }

    fn recv(&self, from: PartyId, tag: u64) -> Vec<u64> {
        let mbox = &self.hub.boxes[self.id];
        let mut q = mbox.queues.lock().unwrap();
        loop {
            if let Some(queue) = q.get_mut(&(from, tag)) {
                if let Some(data) = queue.pop_front() {
                    return data;
                }
            }
            let (guard, timeout) = mbox
                .signal
                .wait_timeout(q, RECV_TIMEOUT)
                .expect("mailbox lock poisoned");
            q = guard;
            if timeout.timed_out() {
                panic!(
                    "party {} recv(from={from}, tag={tag}) timed out — protocol deadlock",
                    self.id
                );
            }
        }
    }

    fn bytes_sent(&self) -> u64 {
        self.hub.sent[self.id].load(Ordering::Relaxed)
    }

    fn bytes_received(&self) -> u64 {
        self.hub.received[self.id].load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{broadcast, gather_all};
    use std::thread;

    #[test]
    fn point_to_point_delivery() {
        let eps = Hub::new(2);
        let (a, b) = {
            let mut it = eps.into_iter();
            (it.next().unwrap(), it.next().unwrap())
        };
        let h = thread::spawn(move || {
            a.send(1, 7, vec![1, 2, 3]);
            a.recv(1, 8)
        });
        assert_eq!(b.recv(0, 7), vec![1, 2, 3]);
        b.send(0, 8, vec![9]);
        assert_eq!(h.join().unwrap(), vec![9]);
    }

    #[test]
    fn out_of_order_tags() {
        let eps = Hub::new(2);
        let (a, b) = {
            let mut it = eps.into_iter();
            (it.next().unwrap(), it.next().unwrap())
        };
        a.send(1, 2, vec![22]);
        a.send(1, 1, vec![11]);
        // receive in tag order regardless of arrival order
        assert_eq!(b.recv(0, 1), vec![11]);
        assert_eq!(b.recv(0, 2), vec![22]);
    }

    #[test]
    fn byte_accounting() {
        let eps = Hub::new(3);
        eps[0].send(1, 0, vec![0; 10]);
        eps[0].send(2, 0, vec![0; 5]);
        assert_eq!(eps[0].bytes_sent(), 15 * ELEM_BYTES);
        assert_eq!(eps[1].bytes_received(), 10 * ELEM_BYTES);
        assert_eq!(eps[2].bytes_received(), 5 * ELEM_BYTES);
    }

    #[test]
    fn broadcast_gather_round_trip() {
        let n = 4;
        let eps = Hub::new(n);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                thread::spawn(move || {
                    let own = vec![ep.id() as u64 * 100];
                    broadcast(&ep, 0, &own);
                    let all = gather_all(&ep, 0, own);
                    all.iter().map(|v| v[0]).collect::<Vec<u64>>()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![0, 100, 200, 300]);
        }
    }

    #[test]
    fn queued_duplicate_tags_fifo() {
        let eps = Hub::new(2);
        eps[0].send(1, 5, vec![1]);
        eps[0].send(1, 5, vec![2]);
        assert_eq!(eps[1].recv(0, 5), vec![1]);
        assert_eq!(eps[1].recv(0, 5), vec![2]);
    }
}
