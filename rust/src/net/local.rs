//! Thread-local transport: `n` parties exchanging real share data through
//! in-process mailboxes. The full-fidelity protocol backend.
//!
//! Delivery runs through the same `TagMailbox` as the TCP transport
//! (drained `(from, tag)` entries are removed, so long runs stay bounded);
//! the byte ledger charges [`Wire::elem_bytes`] per element — no bytes are
//! actually serialized in-process, but the accounting matches what the
//! socket transport puts on the wire for the same configuration.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::mailbox::TagMailbox;
use super::{AnyRecv, PartyId, Transport, TryRecv, Wire};

/// Shared state for an `n`-party in-process network.
pub struct Hub {
    boxes: Vec<TagMailbox>,
    sent: Vec<AtomicU64>,
    sent_offline: Vec<AtomicU64>,
    received: Vec<AtomicU64>,
    elem_bytes: u64,
}

impl Hub {
    /// Create a hub and hand out one endpoint per party (64-bit wire
    /// accounting, as in the paper's MPI implementation).
    pub fn new(n: usize) -> Vec<Endpoint> {
        Self::with_wire(n, Wire::U64)
    }

    /// Create a hub whose byte ledger accounts elements at the given wire
    /// format's width.
    pub fn with_wire(n: usize, wire: Wire) -> Vec<Endpoint> {
        let hub = Arc::new(Hub {
            boxes: (0..n).map(|_| TagMailbox::default()).collect(),
            sent: (0..n).map(|_| AtomicU64::new(0)).collect(),
            sent_offline: (0..n).map(|_| AtomicU64::new(0)).collect(),
            received: (0..n).map(|_| AtomicU64::new(0)).collect(),
            elem_bytes: wire.elem_bytes(),
        });
        (0..n)
            .map(|id| Endpoint { id, n, hub: hub.clone() })
            .collect()
    }
}

/// One party's handle onto the [`Hub`].
pub struct Endpoint {
    id: PartyId,
    n: usize,
    hub: Arc<Hub>,
}

impl Transport for Endpoint {
    fn id(&self) -> PartyId {
        self.id
    }

    fn n(&self) -> usize {
        self.n
    }

    fn send(&self, to: PartyId, tag: u64, data: Vec<u64>) {
        assert!(to < self.n, "send to unknown party {to}");
        assert!(to != self.id, "self-send is a protocol bug");
        let bytes = data.len() as u64 * self.hub.elem_bytes;
        // Ledger only deliveries the peer's mailbox accepted — a send to
        // a departed peer is dropped, not counted. (On TCP the receive
        // side applies the same rule; the send side is best-effort there,
        // since a write into a dying socket can still land in the kernel
        // buffer — fault-run SENT ledgers are approximate on TCP.
        // Clean-run ledgers, the ones the tests pin byte-for-byte, are
        // exact and transport-invariant either way.)
        if self.hub.boxes[to].push(self.id, tag, data) {
            self.hub.sent[self.id].fetch_add(bytes, Ordering::Relaxed);
            if super::tags::OFFLINE.contains(tag) {
                self.hub.sent_offline[self.id].fetch_add(bytes, Ordering::Relaxed);
            }
            self.hub.received[to].fetch_add(bytes, Ordering::Relaxed);
        }
    }

    fn recv(&self, from: PartyId, tag: u64) -> Vec<u64> {
        self.hub.boxes[self.id].pop_blocking(self.id, from, tag)
    }

    fn recv_check(&self, from: PartyId, tag: u64) -> Result<Vec<u64>, String> {
        self.hub.boxes[self.id].pop_result(self.id, from, tag)
    }

    fn recv_any(&self, froms: &[PartyId], tag: u64, timeout: Duration) -> AnyRecv {
        self.hub.boxes[self.id].pop_any(self.id, froms, tag, timeout)
    }

    fn try_recv(&self, from: PartyId, tag: u64) -> TryRecv {
        assert!(from < self.n && from != self.id, "recv from unknown party {from}");
        self.hub.boxes[self.id].try_pop(from, tag)
    }

    fn activity(&self) -> u64 {
        self.hub.boxes[self.id].activity()
    }

    fn wait_activity(&self, since: u64, timeout: Duration) -> u64 {
        self.hub.boxes[self.id].wait_activity(since, timeout)
    }

    fn forget(&self, from: PartyId, tag: u64) -> bool {
        self.hub.boxes[self.id].forget(from, tag)
    }

    fn pending_messages(&self) -> usize {
        self.hub.boxes[self.id].pending_entries()
    }

    fn leave(&self, reason: &str) {
        for (peer, mb) in self.hub.boxes.iter().enumerate() {
            if peer != self.id {
                mb.close(self.id, reason.to_string());
            }
        }
        self.hub.boxes[self.id].shutdown();
    }

    fn bytes_sent(&self) -> u64 {
        self.hub.sent[self.id].load(Ordering::Relaxed)
    }

    fn bytes_received(&self) -> u64 {
        self.hub.received[self.id].load(Ordering::Relaxed)
    }

    fn bytes_sent_offline(&self) -> u64 {
        self.hub.sent_offline[self.id].load(Ordering::Relaxed)
    }

    fn tag_reuse(&self) -> usize {
        self.hub.boxes[self.id].tag_reuse()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{broadcast, gather_all, ELEM_BYTES};
    use std::thread;

    #[test]
    fn point_to_point_delivery() {
        let eps = Hub::new(2);
        let (a, b) = {
            let mut it = eps.into_iter();
            (it.next().unwrap(), it.next().unwrap())
        };
        let h = thread::spawn(move || {
            a.send(1, 7, vec![1, 2, 3]);
            a.recv(1, 8)
        });
        assert_eq!(b.recv(0, 7), vec![1, 2, 3]);
        b.send(0, 8, vec![9]);
        assert_eq!(h.join().unwrap(), vec![9]);
    }

    #[test]
    fn out_of_order_tags() {
        let eps = Hub::new(2);
        let (a, b) = {
            let mut it = eps.into_iter();
            (it.next().unwrap(), it.next().unwrap())
        };
        a.send(1, 2, vec![22]);
        a.send(1, 1, vec![11]);
        // receive in tag order regardless of arrival order
        assert_eq!(b.recv(0, 1), vec![11]);
        assert_eq!(b.recv(0, 2), vec![22]);
    }

    #[test]
    fn byte_accounting() {
        let eps = Hub::new(3);
        eps[0].send(1, 0, vec![0; 10]);
        eps[0].send(2, 0, vec![0; 5]);
        assert_eq!(eps[0].bytes_sent(), 15 * ELEM_BYTES);
        assert_eq!(eps[1].bytes_received(), 10 * ELEM_BYTES);
        assert_eq!(eps[2].bytes_received(), 5 * ELEM_BYTES);
    }

    #[test]
    fn u32_wire_accounting_halves_bytes() {
        let eps = Hub::with_wire(2, Wire::U32);
        eps[0].send(1, 0, vec![0; 10]);
        assert_eq!(eps[0].bytes_sent(), 10 * Wire::U32.elem_bytes());
        assert_eq!(eps[0].bytes_sent() * 2, 10 * ELEM_BYTES);
    }

    #[test]
    fn broadcast_gather_round_trip() {
        let n = 4;
        let eps = Hub::new(n);
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                thread::spawn(move || {
                    let own = vec![ep.id() as u64 * 100];
                    broadcast(&ep, 0, &own);
                    let all = gather_all(&ep, 0, own);
                    all.iter().map(|v| v[0]).collect::<Vec<u64>>()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![0, 100, 200, 300]);
        }
    }

    #[test]
    fn queued_duplicate_tags_fifo() {
        let eps = Hub::new(2);
        eps[0].send(1, 5, vec![1]);
        eps[0].send(1, 5, vec![2]);
        assert_eq!(eps[1].recv(0, 5), vec![1]);
        assert_eq!(eps[1].recv(0, 5), vec![2]);
    }

    #[test]
    fn gather_quorum_takes_first_arrivals_and_names_stragglers() {
        use crate::net::gather_quorum;
        let mut eps = Hub::new(4);
        let slow = eps.pop().unwrap(); // party 3
        let gatherer = eps.remove(0); // party 0
        // parties 1 and 2 deliver immediately; party 3 holds back
        for ep in &eps {
            ep.send(0, 5, vec![ep.id() as u64 * 10]);
        }
        let out = gather_quorum(&gatherer, &[1, 2, 3], 5, 3, vec![0]).unwrap();
        assert_eq!(out.members, vec![0, 1, 2]);
        assert_eq!(out.payloads, vec![vec![0], vec![10], vec![20]]);
        assert_eq!(out.late, vec![3], "the straggler must be named, not waited on");
        // the straggler's late message is dropped on arrival once forgotten
        assert!(!gatherer.forget(3, 5), "message must not have arrived yet");
        slow.send(0, 5, vec![30]);
        // drop-on-arrival is async from this thread's view; the push above
        // ran synchronously through the Hub, so the tombstone is cleared.
        assert_eq!(gatherer.pending_messages(), 0);
    }

    #[test]
    fn gather_quorum_fails_clearly_when_live_peers_cannot_fill_it() {
        use crate::net::gather_quorum;
        let eps = Hub::new(3);
        eps[1].leave("killed by test");
        eps[2].leave("killed by test");
        let err = gather_quorum(&eps[0], &[1, 2], 0, 3, vec![0]).unwrap_err();
        assert!(err.contains("quorum infeasible"), "{err}");
        assert!(err.contains("killed by test"), "{err}");
    }

    #[test]
    fn leave_fails_peer_recvs_and_discards_own_mail() {
        let eps = Hub::new(2);
        eps[0].send(1, 0, vec![1]);
        eps[1].leave("fault-plan kill");
        // messages sent to the departed party are discarded, not queued —
        // and not ledgered (parity with TCP's failed-write accounting)
        let sent_mark = eps[0].bytes_sent();
        let recv_mark = eps[1].bytes_received();
        eps[0].send(1, 1, vec![2]);
        assert_eq!(eps[1].pending_messages(), 0);
        assert_eq!(eps[0].bytes_sent(), sent_mark, "sends to a departed peer must not count");
        assert_eq!(eps[1].bytes_received(), recv_mark, "a departed peer receives nothing");
        // a blocked receive on the departed party fails fast with the cause
        let err = eps[0].recv_check(1, 0).unwrap_err();
        assert!(err.contains("fault-plan kill"), "{err}");
    }

    #[test]
    fn drained_mailbox_entries_are_removed() {
        // Regression: every collective consumes a fresh tag, so leaving
        // empty (from, tag) queues behind grows memory without bound over
        // long training runs.
        let eps = Hub::new(2);
        for tag in 0..100 {
            eps[0].send(1, tag, vec![1, 2, 3]);
        }
        for tag in 0..100 {
            assert_eq!(eps[1].recv(0, tag), vec![1, 2, 3]);
        }
        assert_eq!(eps[1].hub.boxes[1].pending_entries(), 0);
    }
}
